//! The paper-reproduction driver: regenerates every table and figure in the
//! paper's evaluation (DESIGN.md §4) against the real serving stack.
//!
//! ```sh
//! cargo run --release --example paper_tables -- --table 1 --prompts 64 --seeds 3
//! cargo run --release --example paper_tables -- --table all
//! ```
//!
//! Tables: 1, 3, 4..8, fig3, fig4, motivating, all.  Results print to
//! stdout; EXPERIMENTS.md records canonical runs.

use std::sync::Arc;

use specd::backend::NativeBackend;
use specd::config::ExperimentConfig;
use specd::experiments::{motivating_table, Harness};
use specd::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let table = args.get_or("table", "1").to_string();
    if table == "motivating" {
        println!("{}", motivating_table());
        return Ok(());
    }
    let dir = args
        .get("artifacts")
        .map(String::from)
        .or_else(|| std::env::var("SPECD_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".into());
    let backend =
        Arc::new(NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0)?);
    let cfg = ExperimentConfig {
        prompts_per_dataset: args.usize_or("prompts", 32)?,
        seeds: (0..args.u64_or("seeds", 3)?).collect(),
        max_new_tokens: args.usize_or("max-new-tokens", 40)?,
    };
    println!(
        "# paper_tables --table {table} ({} prompts/dataset, {} seeds, {} new tokens)\n",
        cfg.prompts_per_dataset,
        cfg.seeds.len(),
        cfg.max_new_tokens
    );
    let h = Harness::new(backend, cfg)?;
    let t0 = std::time::Instant::now();
    match table.as_str() {
        "1" => println!("{}", h.table1()?),
        "3" => println!("{}", h.table3()?),
        "fig3" => println!("{}", h.fig3()?),
        "fig4" => println!("{}", h.fig4()?),
        "4" | "5" | "6" | "7" | "8" => println!("{}", h.appendix_table(table.parse()?)?),
        "all" => {
            println!("{}", motivating_table());
            println!("{}", h.table1()?);
            println!("{}", h.table3()?);
            println!("{}", h.fig3()?);
            println!("{}", h.fig4()?);
            for i in 4..=8 {
                println!("{}", h.appendix_table(i)?);
            }
        }
        other => anyhow::bail!("unknown table '{other}'"),
    }
    eprintln!("[paper_tables] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
