//! End-to-end serving demo: starts the serving tier (router + replicas)
//! + HTTP server on a loopback port over the native backend (hermetic —
//! trained weights only if an artifact bundle exists), fires a small
//! mixed-length workload from several client threads, and reports
//! latency/throughput — the serving-paper E2E driver (EXPERIMENTS.md
//! records a run).  Short requests complete and their slots are refilled
//! while long ones are still decoding (continuous batching, DESIGN.md
//! §7) — visible in the `specd_slot_occupancy` / `specd_slots_refilled`
//! metrics printed at the end, next to the router's per-replica blocks
//! and prefix-cache counters (DESIGN.md §14).

use std::sync::Arc;
use std::time::Instant;

use specd::backend::{Backend, NativeBackend};
use specd::config::{Config, EngineConfig};
use specd::serve::Router;
use specd::server::{client, serve, ServerState};
use specd::stats::mean_std;
use specd::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend =
        Arc::new(NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0)?);
    let datasets = Dataset::load_or_synthetic(backend.info().artifacts_dir.as_deref())?;
    let cfg = Config::default();
    let engine_cfg = EngineConfig { max_new_tokens: 32, ..Default::default() };
    let router = Router::spawn(backend, engine_cfg, &cfg.server, &cfg.router)?;
    let state = Arc::new(ServerState { router, datasets });

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    {
        let st = state.clone();
        std::thread::spawn(move || {
            let _ = serve(listener, st);
        });
    }
    println!("serving on http://{addr}");

    // Warm up (first batch pays allocator/cache warmup; on PJRT-style
    // backends this is where program compilation would land).
    let t0 = Instant::now();
    client::generate(&addr, "gsm8k", 8, 99)?;
    println!("warmup: {:?}", t0.elapsed());

    // 4 client threads x 4 requests, mixed datasets and mixed lengths ->
    // the continuous batcher refills short rows' slots mid-decode.
    let n_clients = 4;
    let per_client = 4;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            let mut toks = 0usize;
            let ds = ["gsm8k", "wmt", "xsum", "sharegpt"][c % 4];
            let max_new = [32, 4, 16, 8][c % 4];
            for r in 0..per_client {
                let resp =
                    client::generate(&addr, ds, max_new, (c * 100 + r) as u64).unwrap();
                lat.push(resp.latency_ms);
                toks += resp.n_tokens;
            }
            (lat, toks)
        }));
    }
    let mut all_lat = Vec::new();
    let mut total_tokens = 0usize;
    for h in handles {
        let (lat, toks) = h.join().unwrap();
        all_lat.extend(lat);
        total_tokens += toks;
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mean, std) = mean_std(&all_lat);
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\n{} requests, {total_tokens} tokens in {wall:.2}s -> {:.1} tok/s",
        n_clients * per_client,
        total_tokens as f64 / wall
    );
    println!(
        "request latency: mean {mean:.0}±{std:.0} ms, p50 {:.0} ms, max {:.0} ms",
        all_lat[all_lat.len() / 2],
        all_lat.last().unwrap()
    );
    let (_, metrics) = client::get(&addr, "/metrics")?;
    println!("\nserver metrics:\n{metrics}");
    Ok(())
}
