//! Quickstart: decode a few prompts with token vs block verification on
//! the pure-Rust native backend and print the paper's headline comparison.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! # Running without artifacts
//!
//! No setup is needed: with default cargo features and no `artifacts/`
//! directory, the native backend initialises deterministic seeded weights
//! (a correlated target/drafter family, see `backend::native`) and
//! synthetic prompt sets, so this example — like the tests, the benches
//! and `specd serve` — runs fully hermetically.  The block-efficiency gap
//! it prints is the paper's never-worse guarantee in action.
//!
//! To use trained weights instead, build the AOT bundle (`make
//! artifacts`) or point SPECD_ARTIFACTS at one; the native backend then
//! loads `weights_*.bin`.  The PJRT execution path additionally needs
//! `cargo build --features pjrt` with the real `xla` crate vendored in.

use std::sync::Arc;

use specd::backend::{Backend, NativeBackend};
use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::verify::Algo;
use specd::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend =
        Arc::new(NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0)?);
    let info = backend.info().clone();
    println!(
        "native backend: batch={} max_len={} vocab={} ({})",
        info.batch,
        info.max_len,
        info.vocab_size,
        if info.artifacts_dir.is_some() { "trained weights" } else { "seeded weights" },
    );

    let datasets = Dataset::load_or_synthetic(info.artifacts_dir.as_deref())?;
    let ds = datasets.iter().find(|d| d.name == "gsm8k").expect("gsm8k loaded");
    let prompts = ds.take(16);
    let seeds: [u64; 2] = [0, 1];

    println!(
        "\nblock efficiency, {} prompts x {} seeds (higher is better):",
        prompts.len(),
        seeds.len()
    );
    println!("{:>6} {:>10} {:>10} {:>8}", "gamma", "token BE", "block BE", "gain%");
    for gamma in [4usize, 8] {
        let mut be = [0.0f64; 2];
        for (ai, algo) in [Algo::Token, Algo::Block].into_iter().enumerate() {
            let mut emitted = 0usize;
            let mut iters = 0usize;
            for &seed in &seeds {
                let engine = SpecEngine::new(
                    backend.clone(),
                    EngineConfig { gamma, algo, max_new_tokens: 48, ..Default::default() },
                )?;
                for rep in engine.run_prompts(&prompts, seed)? {
                    for row in &rep.rows {
                        emitted += row.emitted;
                        iters += row.iterations;
                    }
                }
            }
            be[ai] = emitted as f64 / iters.max(1) as f64;
        }
        println!(
            "{gamma:>6} {:>10.3} {:>10.3} {:>7.2}%",
            be[0],
            be[1],
            (be[1] - be[0]) / be[0] * 100.0
        );
    }
    println!(
        "\npaper claim: block >= token for every gamma (Theorem 2); \
         Table 1 reports +5-8% wall-clock at gamma=8 with trained drafters"
    );
    Ok(())
}
