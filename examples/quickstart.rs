//! Quickstart: load the AOT bundle, decode a few prompts with block
//! verification, and print per-request stats.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::runtime::Runtime;
use specd::verify::Algo;
use specd::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Arc::new(Runtime::load(std::path::Path::new(&dir))?);
    println!(
        "loaded bundle: batch={} max_len={} vocab={} ({} programs)",
        rt.manifest.batch,
        rt.manifest.max_len,
        rt.manifest.vocab_size,
        rt.manifest.programs.len()
    );

    let ds = Dataset::load(rt.artifacts_dir(), "gsm8k")?;
    let engine = SpecEngine::new(
        rt.clone(),
        EngineConfig { gamma: 8, algo: Algo::Block, ..Default::default() },
    )?;

    let prompts = ds.take(4);
    let report = engine.run_batch(&prompts, 0)?;
    println!(
        "\nbatch of {} prompts decoded in {:?} ({} device iterations)\n",
        prompts.len(),
        report.wall,
        report.device_iterations
    );
    for (i, row) in report.rows.iter().enumerate() {
        println!(
            "prompt {i}: {} tokens in {} target calls (BE {:.2}, finish {:?})\n  tokens: {:?}",
            row.tokens.len(),
            row.iterations,
            row.block_efficiency(),
            row.finish,
            &row.tokens[..row.tokens.len().min(16)],
        );
    }
    println!(
        "\naggregate block efficiency: {:.3} (paper Table 1 reports ~3.5-4.2 \
         for good drafters at gamma=8)",
        report.block_efficiency()
    );
    Ok(())
}
