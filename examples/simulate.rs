//! Distribution-level study (no artifacts needed): regenerates the paper's
//! §2 motivating example exactly and sweeps the Theorem-2 gap across
//! drafter quality and draft length on synthetic Markov model pairs.

use specd::experiments::motivating_table;
use specd::sim::{self, MarkovPair};
use specd::verify::Algo;

fn main() {
    println!("{}", motivating_table());

    println!("Block-efficiency gap vs drafter quality and gamma (exact enumeration):");
    println!(
        "{:>6} {:>3} {:>12} {:>12} {:>12} {:>9}",
        "mix", "γ", "token E[τ]", "block E[τ]", "ideal", "gain%"
    );
    for mix in [0.3, 0.6, 0.9] {
        let pair = MarkovPair::random(4, mix, 17);
        for gamma in [2, 4] {
            let t = sim::exact::expected_tau_token(&pair, gamma);
            let b = sim::exact::expected_tau_block(&pair, gamma);
            let f = sim::exact::fullinfo_bound(&pair, gamma);
            println!(
                "{mix:>6.2} {gamma:>3} {t:>12.4} {b:>12.4} {f:>12.4} {:>8.2}%",
                (b - t) / t * 100.0
            );
        }
    }

    println!("\nEnd-to-end simulated decode (100k tokens each, gamma=6):");
    let pair = MarkovPair::random(16, 0.75, 3);
    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        let s = sim::simulate(&pair, 6, algo, 100_000, 11);
        println!(
            "  {algo:<7} BE {:.3}  ({} iterations, tau histogram {:?})",
            s.block_efficiency(),
            s.iterations,
            s.tau_hist
        );
    }
}
