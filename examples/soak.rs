//! Serving soak (CI gate): boot the full HTTP stack on the hermetic
//! native backend, fire ~200 mixed-length concurrent requests from many
//! client threads, and require every response to be 200 or 429 with no
//! hangs — this hammers the continuous batcher's admit/step/release path
//! end to end (DESIGN.md §7).
//!
//! ```sh
//! cargo run --release --example soak            # 200 requests
//! cargo run --release --example soak -- --requests=50
//! ```
//!
//! Exit codes: 0 pass, 1 bad responses, 2 watchdog timeout (hang).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use specd::backend::NativeBackend;
use specd::config::{Config, EngineConfig};
use specd::coordinator::Coordinator;
use specd::server::{client, serve, ServerState};
use specd::util::json;
use specd::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let total: usize = std::env::args()
        .find_map(|a| a.strip_prefix("--requests=").and_then(|v| v.parse().ok()))
        .unwrap_or(200);

    let backend = Arc::new(NativeBackend::seeded(0x50a4));
    let datasets = Dataset::load_or_synthetic(None)?;
    let mut cfg = Config::default();
    // The in-flight limit must sit BELOW the client concurrency (16
    // threads) or the 429 admission-rejection path would be unreachable:
    // blocking clients can never hold more requests in flight than there
    // are threads.
    cfg.server.queue_limit = 8;
    let ecfg = EngineConfig { max_new_tokens: 24, ..Default::default() };
    let coordinator = Coordinator::spawn(backend, ecfg, &cfg.server)?;
    let metrics = coordinator.metrics.clone();
    let state = Arc::new(ServerState { coordinator, datasets });

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    {
        let st = state.clone();
        std::thread::spawn(move || {
            let _ = serve(listener, st);
        });
    }
    println!("soak: {total} requests against http://{addr}");

    // Watchdog: a hang anywhere in the serving stack must fail the run,
    // not stall CI until the job-level timeout.
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = done.clone();
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(600);
            while Instant::now() < deadline {
                if done.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(200));
            }
            eprintln!("soak: watchdog deadline exceeded — serving stack hung");
            std::process::exit(2);
        });
    }

    let n_clients = 16;
    let per_client = total.div_ceil(n_clients);
    let ok = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let bad = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let (ok, rejected, bad) = (ok.clone(), rejected.clone(), bad.clone());
        handles.push(std::thread::spawn(move || {
            for r in 0..per_client {
                let ds = ["gsm8k", "wmt", "xsum", "sharegpt"][(c + r) % 4];
                let max_new = [1, 2, 4, 8, 16, 24][(c * per_client + r) % 6];
                let body = json::to_string(&json::obj(vec![
                    ("dataset", json::str_v(ds)),
                    ("max_new_tokens", json::num(max_new as f64)),
                    ("seed", json::num((c * 1000 + r) as f64)),
                ]));
                match client::post_json(&addr, "/v1/generate", &body) {
                    Ok((200, _)) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok((429, _)) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok((status, resp)) => {
                        eprintln!("soak: unexpected status {status}: {resp}");
                        bad.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        eprintln!("soak: transport error: {e:#}");
                        bad.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    done.store(true, Ordering::Release);

    let wall = t0.elapsed().as_secs_f64();
    let (ok, rejected, bad) =
        (ok.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed), bad.load(Ordering::Relaxed));
    let sent = n_clients * per_client;
    println!(
        "soak: {sent} requests in {wall:.1}s — {ok} ok, {rejected} rejected (429), {bad} bad"
    );
    println!(
        "soak: slot occupancy {:.2}, refills {}, tokens {}",
        metrics.slot_occupancy(),
        metrics.slots_refilled.get(),
        metrics.tokens_emitted.get()
    );
    // Batched-admission accounting (DESIGN.md §11.3): every admitted
    // request was part of exactly one batched prefill, so the histogram's
    // value-weighted total must equal the refill count.  (Under 16
    // concurrent clients against B=4 slots the batcher typically packs
    // multi-row admission ticks — the mean printed below is the
    // amortisation win the metric exists to observe; it is
    // timing-dependent, so it is reported rather than gated.)  The
    // watchdog above is the regression test for the narrowed admission
    // critical section: a prefill that blocked the worker per request
    // used to stretch exactly this run.
    let admitted: u64 = metrics
        .prefill_batch_size
        .nonzero()
        .iter()
        .map(|&(rows, count)| rows as u64 * count)
        .sum();
    println!(
        "soak: prefill batches {} (mean rows {:.2}), draft forward mean {:.0}us",
        metrics.prefill_batch_size.total(),
        metrics.prefill_batch_size.mean(),
        metrics.draft_forward_us.mean_us()
    );
    let mut failed = bad != 0 || ok == 0 || ok + rejected != sent;
    if admitted != metrics.slots_refilled.get() {
        eprintln!(
            "soak FAILED: prefill_batch_size accounts for {admitted} admissions but {} slots \
             were refilled",
            metrics.slots_refilled.get()
        );
        failed = true;
    }
    if metrics.draft_forward_us.count() == 0 {
        eprintln!("soak FAILED: draft_forward_us histogram is empty");
        failed = true;
    }
    if failed {
        eprintln!("soak FAILED");
        std::process::exit(1);
    }
    println!("soak passed: all responses 2xx/429, no hangs");
    Ok(())
}
