//! Serving soak (CI gate): boot the full HTTP stack — router, replicas,
//! paged KV pool, prefix cache (DESIGN.md §14) — on the hermetic native
//! backend, fire mixed-length concurrent requests from many client
//! threads, and require every response to be 200 or a well-formed shed
//! (429 **with** a `Retry-After` header) with no hangs.
//!
//! ```sh
//! cargo run --release --example soak                 # 200 requests
//! cargo run --release --example soak -- --requests=50
//! cargo run --release --example soak -- --scale      # ~2000 requests,
//!                                                    # 64 clients, shared-
//!                                                    # prefix-heavy mix;
//!                                                    # writes BENCH_ci.json
//! ```
//!
//! `--scale` sends explicit `prompt_tokens` drawn from a small set of
//! shared 32-token prefixes plus per-request suffixes, so the router's
//! prefix cache must get hits and warm admissions must prefill only the
//! suffix — gated via the `prefill_positions < prompt_positions`
//! accounting (DESIGN.md §14.5).  p50/p99 latency and the shed rate land
//! in BENCH_ci.json for the perf trajectory.
//!
//! Exit codes: 0 pass, 1 bad responses / failed gate, 2 watchdog (hang).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use specd::backend::NativeBackend;
use specd::config::{Config, EngineConfig};
use specd::models::vocab;
use specd::serve::Router;
use specd::server::{client, serve, ServerState};
use specd::util::json;
use specd::verify::Rng;
use specd::workload::Dataset;

/// Shared prompt prefixes for `--scale`: page-aligned 32-token heads
/// (page_size = 16) so `PrefixCache::candidate_len` keys exactly on them.
const SCALE_PREFIXES: usize = 8;
const SCALE_PREFIX_LEN: usize = 32;

fn scale_prompt(prefixes: &[Vec<u32>], c: usize, r: usize) -> Vec<u32> {
    let mut p = prefixes[(c + r) % prefixes.len()].clone();
    // Per-request suffix: 1..=10 content tokens — prompts 33..=42 stay
    // under the engine's `len < L/2 = 48` prefix guard and the ring.
    let mut rng = Rng::new(((c as u64) << 32) | r as u64);
    let span = (vocab::SIZE - vocab::CONTENT_BASE) as usize;
    for _ in 0..1 + (c * 31 + r) % 10 {
        p.push(vocab::CONTENT_BASE + rng.below(span) as u32);
    }
    p
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    sorted_ms[((q * (sorted_ms.len() - 1) as f64).round() as usize).min(sorted_ms.len() - 1)]
}

fn main() -> anyhow::Result<()> {
    let scale = std::env::args().any(|a| a == "--scale");
    let total: usize = std::env::args()
        .find_map(|a| a.strip_prefix("--requests=").and_then(|v| v.parse().ok()))
        .unwrap_or(if scale { 2000 } else { 200 });
    let n_clients = if scale { 64 } else { 16 };

    let backend = Arc::new(NativeBackend::seeded(0x50a4));
    let datasets = Dataset::load_or_synthetic(None)?;
    let mut cfg = Config::default();
    // The per-replica admission token budget must sit BELOW what the
    // blocking clients can hold in flight, or the shed path would be
    // unreachable: budget/cost bounds concurrent admissions per replica,
    // so size it to a handful of requests (cost = prompt + max_new,
    // <= ~60 tokens here) against 16/64 client threads.
    cfg.router.replicas = 2;
    cfg.router.token_budget = if scale { 1024 } else { 256 };
    let max_new_mix: &[usize] = if scale { &[1, 2, 4, 6] } else { &[1, 2, 4, 8, 16, 24] };
    let ecfg = EngineConfig { max_new_tokens: 24, ..Default::default() };
    let router = Router::spawn(backend, ecfg, &cfg.server, &cfg.router)?;
    let state = Arc::new(ServerState { router: router.clone(), datasets });

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    {
        let st = state.clone();
        std::thread::spawn(move || {
            let _ = serve(listener, st);
        });
    }
    println!(
        "soak: {total} requests ({n_clients} clients{}) against http://{addr}",
        if scale { ", --scale shared-prefix mix" } else { "" }
    );

    // Shared 32-token prompt heads for the --scale prefix-cache workload.
    let mut prng = Rng::new(0x5ca1_e5eed);
    let prefixes: Arc<Vec<Vec<u32>>> = Arc::new(
        (0..SCALE_PREFIXES)
            .map(|i| {
                let mut p = vec![vocab::BOS, vocab::marker_for((i % 8) as u32)];
                while p.len() < SCALE_PREFIX_LEN {
                    p.push(
                        vocab::CONTENT_BASE
                            + prng.below((vocab::SIZE - vocab::CONTENT_BASE) as usize) as u32,
                    );
                }
                p
            })
            .collect(),
    );

    // Watchdog: a hang anywhere in the serving stack must fail the run,
    // not stall CI until the job-level timeout.
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = done.clone();
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(600);
            while Instant::now() < deadline {
                if done.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(200));
            }
            eprintln!("soak: watchdog deadline exceeded — serving stack hung");
            std::process::exit(2);
        });
    }

    let per_client = total.div_ceil(n_clients);
    let ok = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));
    let bad = Arc::new(AtomicUsize::new(0));
    let latencies = Arc::new(Mutex::new(Vec::<f64>::new()));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let prefixes = prefixes.clone();
        let (ok, shed, bad) = (ok.clone(), shed.clone(), bad.clone());
        let latencies = latencies.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            for r in 0..per_client {
                let max_new = max_new_mix[(c * per_client + r) % max_new_mix.len()];
                let mut fields = vec![
                    ("max_new_tokens", json::num(max_new as f64)),
                    ("seed", json::num((c * 1000 + r) as f64)),
                    ("tenant", json::num((c % 4) as f64)),
                    ("lane", json::str_v(if (c + r) % 5 == 0 { "batch" } else { "interactive" })),
                ];
                if scale {
                    fields.push(("prompt_tokens", json::arr_u32(&scale_prompt(&prefixes, c, r))));
                } else {
                    let ds = ["gsm8k", "wmt", "xsum", "sharegpt"][(c + r) % 4];
                    fields.push(("dataset", json::str_v(ds)));
                }
                let body = json::to_string(&json::obj(fields));
                let t = Instant::now();
                match client::post_json_full(&addr, "/v1/generate", &body) {
                    Ok((200, _, _)) => {
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    // Load shed: must be a *well-formed* shed — 429 and a
                    // Retry-After hint (the serving-tier overload
                    // contract, DESIGN.md §14.1).
                    Ok((429, headers, resp)) => {
                        let retry_ok = headers.iter().any(|(k, v)| {
                            k == "retry-after" && matches!(v.parse::<u64>(), Ok(s) if s >= 1)
                        });
                        if retry_ok {
                            shed.fetch_add(1, Ordering::Relaxed);
                        } else {
                            eprintln!("soak: 429 without retry-after header: {resp}");
                            bad.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Ok((status, _, resp)) => {
                        eprintln!("soak: unexpected status {status}: {resp}");
                        bad.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        eprintln!("soak: transport error: {e:#}");
                        bad.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            latencies.lock().unwrap().extend(lat);
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    done.store(true, Ordering::Release);

    let wall = t0.elapsed().as_secs_f64();
    let (ok, shed, bad) =
        (ok.load(Ordering::Relaxed), shed.load(Ordering::Relaxed), bad.load(Ordering::Relaxed));
    let sent = n_clients * per_client;
    let mut lat = latencies.lock().unwrap().clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
    let shed_rate = shed as f64 / sent as f64;
    println!(
        "soak: {sent} requests in {wall:.1}s — {ok} ok, {shed} shed (429), {bad} bad; \
         p50 {p50:.0}ms p99 {p99:.0}ms"
    );

    // Sum the engine-side accounting across replicas (each replica owns
    // its own EngineMetrics; the router renders the same sums in
    // /metrics — DESIGN.md §14.5).
    let mut slots_refilled = 0u64;
    let mut admitted = 0u64;
    let mut prefill_batches = 0u64;
    let mut draft_forwards = 0u64;
    let mut tokens_emitted = 0u64;
    let mut prefill_positions = 0u64;
    let mut prompt_positions = 0u64;
    for i in 0..router.replica_count() {
        let m = router.replica_metrics(i);
        slots_refilled += m.slots_refilled.get();
        admitted += m
            .prefill_batch_size
            .nonzero()
            .iter()
            .map(|&(rows, count)| rows as u64 * count)
            .sum::<u64>();
        prefill_batches += m.prefill_batch_size.total();
        draft_forwards += m.draft_forward_us.count();
        tokens_emitted += m.tokens_emitted.get();
        prefill_positions += m.prefill_positions.get();
        prompt_positions += m.prompt_positions.get();
    }
    let stats = router.prefix_stats();
    let (hits, misses) = (stats.hits.get(), stats.misses.get());
    println!(
        "soak: {} replicas — refills {slots_refilled}, tokens {tokens_emitted}, \
         prefix cache {hits} hits / {misses} misses, \
         prefilled {prefill_positions}/{prompt_positions} prompt positions",
        router.replica_count()
    );
    println!(
        "soak: prefill batches {prefill_batches}, kv pages {} used / {} total",
        router.pool().pages_used(),
        router.pool().total_pages()
    );

    // --scale writes the serving-tier trajectory numbers next to the
    // perf-smoke bench's (same schema: flat name -> number).
    if scale {
        let report = json::obj(vec![
            ("soak_requests", json::num(sent as f64)),
            ("soak_ok", json::num(ok as f64)),
            ("soak_shed", json::num(shed as f64)),
            ("soak_shed_rate", json::num(shed_rate)),
            ("soak_p50_ms", json::num(p50)),
            ("soak_p99_ms", json::num(p99)),
            ("soak_wall_s", json::num(wall)),
            ("soak_req_per_s", json::num(ok as f64 / wall.max(1e-9))),
            ("prefix_cache_hits", json::num(hits as f64)),
            ("prefix_cache_misses", json::num(misses as f64)),
            ("prefill_positions", json::num(prefill_positions as f64)),
            ("prompt_positions", json::num(prompt_positions as f64)),
            (
                "prefill_fraction",
                json::num(prefill_positions as f64 / prompt_positions.max(1) as f64),
            ),
        ]);
        specd::bench::merge_section("BENCH_ci.json", "soak", report)?;
        println!("soak: merged section 'soak' into BENCH_ci.json");
    }

    let mut failed = false;
    let mut gate = |cond: bool, msg: &str| {
        if !cond {
            eprintln!("soak FAILED: {msg}");
            failed = true;
        }
    };
    gate(bad == 0, "bad responses (non-200/429, malformed shed, or transport errors)");
    gate(ok > 0, "no request succeeded");
    gate(ok + shed + bad == sent, "response accounting does not cover every request");
    // Every client-visible 429 is one router shed — the counter in
    // /metrics must agree with what clients observed.
    gate(
        shed as u64 == router.metrics.requests_shed_total.get(),
        "client-observed 429s disagree with specd_requests_shed_total",
    );
    // Batched-admission accounting (DESIGN.md §11.3): every admitted row
    // was part of exactly one batched prefill.
    gate(
        admitted == slots_refilled,
        "prefill_batch_size weighted total disagrees with slots_refilled",
    );
    gate(draft_forwards > 0, "draft_forward_us histogram is empty");
    if scale {
        // The shared-prefix mix must actually exercise the cache, and
        // warm admissions must have prefilled strictly fewer positions
        // than the prompts contained (the suffix-only prefill win).
        gate(hits > 0, "prefix cache saw no hits under the shared-prefix mix");
        gate(
            prefill_positions < prompt_positions,
            "warm admissions did not reduce prefilled positions below prompt positions",
        );
        gate(shed_rate < 0.9, "shed rate >= 90% — serving tier is rejecting almost everything");
    }
    if failed {
        eprintln!("soak FAILED");
        std::process::exit(1);
    }
    println!("soak passed: all responses 200 or shed-with-Retry-After, no hangs");
    Ok(())
}
