//! Determinism contracts of the native fast path (DESIGN.md §10).
//!
//! The tentpole perf work — blocked matmul kernel, row-parallel forward
//! on the fixed thread pool, persistent multipath scratch — must not
//! perturb a single output bit:
//!
//! 1. the blocked kernel is bit-identical to the scalar reference on a
//!    zero-filled accumulator (same per-lane summation order);
//! 2. a backend on the reference kernel produces bit-identical scored
//!    distributions to one on the blocked kernel;
//! 3. a threaded forward (`threads = N`) is bit-identical to the
//!    sequential one (`threads = 1`), backend- and engine-level;
//! 4. the persistent-scratch multipath path is bit-identical to the old
//!    allocate-per-iteration path, engine-level, for block, multipath
//!    and tree verification — including across consecutive batches,
//!    where the scratch is reused dirty, and across interleaved
//!    algorithm families sharing one pool (the `(model, rows, ring)`
//!    keying regression).

use std::sync::Arc;

use specd::backend::kernels::{
    matmul_blocked, matmul_q8_i32, matmul_ref, matmul_simd, pack_q8, quantise_row_q8, MatKernel,
    PackedF32, QuantScratch,
};
use specd::backend::{Backend, NativeBackend, Precision};
use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::models::vocab;
use specd::verify::{Algo, Rng};

/// Deterministic mixed-length content prompts.
fn prompts(n: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![vocab::BOS, vocab::marker_for((i % 8) as u32)];
            for j in 0..(4 + (i * 3) % 7) {
                p.push(vocab::CONTENT_BASE + ((i * 37 + j * 11) % 200) as u32);
            }
            p
        })
        .collect()
}

/// Decode every prompt through a fused engine; returns per-row generated
/// tokens per batch (the full engine-level observable).
fn decode(backend: Arc<NativeBackend>, algo: Algo, reqs: &[Vec<u32>], seed: u64) -> Vec<Vec<u32>> {
    let cfg = EngineConfig { algo, gamma: 4, max_new_tokens: 12, ..Default::default() };
    let engine = SpecEngine::new(backend, cfg).unwrap();
    let mut out = Vec::new();
    for rep in engine.run_prompts(reqs, seed).unwrap() {
        for row in rep.rows {
            out.push(row.tokens);
        }
    }
    out
}

/// A deterministic prompt state at the given backend's shapes.
fn prompt_state(be: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
    let info = be.info();
    let (b, l) = (info.batch, info.max_len);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        let p = prompts(b)[bi].clone();
        for (j, &t) in p.iter().enumerate() {
            toks[bi * l + j] = t as i32;
        }
        lens[bi] = p.len() as i32;
    }
    (toks, lens)
}

#[test]
fn blocked_kernel_is_bit_identical_to_scalar_reference() {
    let mut rng = Rng::new(0xfa57);
    // Model shapes plus awkward non-multiple-of-tile remainders.
    for &(t, d_in, d_out) in
        &[(1usize, 32usize, 32usize), (5, 128, 512), (9, 64, 256), (3, 64, 40), (2, 17, 23)]
    {
        let x: Vec<f32> = (0..t * d_in).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let mut a = vec![0.0f32; t * d_out];
        let mut b = vec![0.0f32; t * d_out];
        matmul_ref(&x, &w, &mut a, t, d_in, d_out);
        matmul_blocked(&x, &w, &mut b, t, d_in, d_out);
        assert_eq!(a, b, "kernels diverge at t={t} d_in={d_in} d_out={d_out}");
    }
}

#[test]
fn simd_kernel_is_bit_identical_to_scalar_reference_on_random_shapes() {
    // Property test over random non-lane-multiple shapes (DESIGN.md
    // §12.2): whatever ISA this host resolves, the packed SIMD GEMM must
    // reproduce the scalar reference bit-for-bit, tails included.
    let mut rng = Rng::new(0x51d0);
    for _ in 0..40 {
        let t = 1 + (rng.uniform() * 6.0) as usize;
        let d_in = 1 + (rng.uniform() * 130.0) as usize;
        let d_out = 1 + (rng.uniform() * 130.0) as usize;
        let x: Vec<f32> = (0..t * d_in).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let pk = PackedF32::pack(&w, d_in, d_out);
        let mut a = vec![0.0f32; t * d_out];
        let mut b = vec![0.0f32; t * d_out];
        matmul_ref(&x, &w, &mut a, t, d_in, d_out);
        matmul_simd(&x, &pk, &mut b, t, d_in, d_out);
        assert_eq!(a, b, "simd diverges at t={t} d_in={d_in} d_out={d_out}");
    }
}

#[test]
fn int8_gemm_matches_integer_oracle_on_random_shapes() {
    // Property test: the packed i8×i8→i32 GEMM must *exactly* equal an
    // integer-accumulate oracle — no float enters the accumulation, and
    // the one fp32 rescale per output element is the shared expression
    // `acc as f32 * (sx * sw)` (DESIGN.md §12.3).
    let mut rng = Rng::new(0x18a0);
    for _ in 0..40 {
        let t = 1 + (rng.uniform() * 5.0) as usize;
        let d_in = 1 + (rng.uniform() * 90.0) as usize;
        let d_out = 1 + (rng.uniform() * 90.0) as usize;
        let x: Vec<f32> = (0..t * d_in).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let q: Vec<i8> =
            (0..d_in * d_out).map(|_| (rng.uniform() * 255.0 - 127.0) as i8).collect();
        let scale: Vec<f32> = (0..d_out).map(|_| (rng.uniform() * 0.02) as f32).collect();
        let qt = pack_q8(&q, d_in, d_out);
        let mut scr = QuantScratch::default();
        let mut got = vec![0.0f32; t * d_out];
        matmul_q8_i32(&x, &qt, &scale, &mut got, t, d_in, d_out, &mut scr);
        let mut xq = vec![0i8; d_in];
        for ti in 0..t {
            let sx = quantise_row_q8(&x[ti * d_in..(ti + 1) * d_in], &mut xq);
            for o in 0..d_out {
                let mut acc = 0i32;
                for (i, &xv) in xq.iter().enumerate() {
                    acc += xv as i32 * q[i * d_out + o] as i32;
                }
                assert_eq!(
                    got[ti * d_out + o],
                    acc as f32 * (sx * scale[o]),
                    "oracle mismatch at t={t} d_in={d_in} d_out={d_out} ti={ti} o={o}"
                );
            }
        }
    }
}

#[test]
fn reference_kernel_backend_matches_blocked_backend() {
    let blocked = NativeBackend::seeded_with_shapes(2, 32, 7).with_threads(1);
    let reference =
        NativeBackend::seeded_with_shapes(2, 32, 7).with_threads(1).with_reference_kernel(true);
    let (toks, lens) = prompt_state(&blocked);
    let mut kv_b = blocked.prefill("target", &toks, &lens).unwrap();
    let mut kv_r = reference.prefill("target", &toks, &lens).unwrap();
    let drafts = vec![20i32, 21, 22, 20, 21, 22];
    let ps_b = blocked.target_score(3, &toks, &lens, &mut kv_b, &drafts).unwrap();
    let ps_r = reference.target_score(3, &toks, &lens, &mut kv_r, &drafts).unwrap();
    assert_eq!(ps_b, ps_r, "kernel choice must not perturb scored distributions");
}

#[test]
fn all_kernel_variants_decode_bit_identically() {
    // Backend- and engine-level three-way check: pinning the kernel to
    // ref, blocked, or simd (packed tile-major weights, explicit
    // `std::arch` lanes) changes nothing but wall-clock.
    let reqs = prompts(8);
    let mk = |kernel: MatKernel| {
        NativeBackend::seeded_with_shapes(4, 64, 0x51d).with_threads(1).with_kernel(kernel)
    };
    // Backend-level: scored distributions bitwise equal.
    let reference = mk(MatKernel::Reference);
    let (toks, lens) = prompt_state(&reference);
    let drafts: Vec<i32> = (0..4 * 3).map(|i| 20 + (i % 5)).collect();
    let mut kv_r = reference.prefill("target", &toks, &lens).unwrap();
    let ps_r = reference.target_score(3, &toks, &lens, &mut kv_r, &drafts).unwrap();
    for kernel in [MatKernel::Blocked, MatKernel::Simd] {
        let be = mk(kernel);
        let mut kv = be.prefill("target", &toks, &lens).unwrap();
        let ps = be.target_score(3, &toks, &lens, &mut kv, &drafts).unwrap();
        assert_eq!(ps_r, ps, "{kernel}: scored distributions diverged from reference");
    }
    // Engine-level: every generated token equal across kernels, both
    // fused algos, fp32 and int8 drafters.
    for precision in [Precision::Fp32, Precision::Int8] {
        for algo in [Algo::Block, Algo::MultiPath { k: 2 }] {
            let want = decode(
                Arc::new(mk(MatKernel::Reference).with_draft_precision(precision)),
                algo,
                &reqs,
                17,
            );
            for kernel in [MatKernel::Blocked, MatKernel::Simd] {
                let be = Arc::new(mk(kernel).with_draft_precision(precision));
                let got = decode(be, algo, &reqs, 17);
                assert_eq!(want, got, "{kernel} algo={algo} {precision:?}: tokens diverged");
            }
        }
    }
}

#[test]
fn threaded_forward_is_bit_identical_to_single_thread() {
    let reqs = prompts(8);
    for threads in [2usize, 4] {
        let single = Arc::new(NativeBackend::seeded_with_shapes(4, 64, 0xfa57).with_threads(1));
        let pooled =
            Arc::new(NativeBackend::seeded_with_shapes(4, 64, 0xfa57).with_threads(threads));
        // Backend-level: scored distributions bitwise equal.
        let (toks, lens) = prompt_state(&single);
        let mut kv_s = single.prefill("target", &toks, &lens).unwrap();
        let mut kv_p = pooled.prefill("target", &toks, &lens).unwrap();
        let drafts: Vec<i32> = (0..4 * 3).map(|i| 20 + (i % 5)).collect();
        let ps_s = single.target_score(3, &toks, &lens, &mut kv_s, &drafts).unwrap();
        let ps_p = pooled.target_score(3, &toks, &lens, &mut kv_p, &drafts).unwrap();
        assert_eq!(ps_s, ps_p, "threads={threads}: scored distributions diverged");
        // Engine-level: every generated token equal, single- and
        // multi-path.
        for algo in [Algo::Block, Algo::MultiPath { k: 3 }] {
            let a = decode(single.clone(), algo, &reqs, 11);
            let b = decode(pooled.clone(), algo, &reqs, 11);
            assert_eq!(a, b, "threads={threads} algo={algo}: tokens diverged");
        }
    }
}

#[test]
fn int8_draft_is_deterministic_and_thread_invariant() {
    // The quantised draft path inherits every determinism contract of
    // the fast path (DESIGN.md §11.1): identical backends produce
    // identical streams, and the thread count / fp32-kernel choice (the
    // target's matmuls) perturb nothing.
    let reqs = prompts(8);
    for algo in [Algo::Block, Algo::MultiPath { k: 2 }] {
        let base = Arc::new(
            NativeBackend::seeded_with_shapes(4, 64, 0x18a)
                .with_threads(1)
                .with_draft_precision(Precision::Int8),
        );
        let twin = Arc::new(
            NativeBackend::seeded_with_shapes(4, 64, 0x18a)
                .with_threads(1)
                .with_draft_precision(Precision::Int8),
        );
        let threaded = Arc::new(
            NativeBackend::seeded_with_shapes(4, 64, 0x18a)
                .with_threads(4)
                .with_draft_precision(Precision::Int8),
        );
        let refkernel = Arc::new(
            NativeBackend::seeded_with_shapes(4, 64, 0x18a)
                .with_threads(1)
                .with_reference_kernel(true)
                .with_draft_precision(Precision::Int8),
        );
        let a = decode(base, algo, &reqs, 31);
        assert_eq!(a, decode(twin, algo, &reqs, 31), "algo={algo}: int8 not deterministic");
        assert_eq!(a, decode(threaded, algo, &reqs, 31), "algo={algo}: threads perturb int8");
        assert_eq!(
            a,
            decode(refkernel, algo, &reqs, 31),
            "algo={algo}: fp32 kernel choice perturbs the int8 draft"
        );
    }
}

#[test]
fn target_model_is_never_quantised() {
    // The precision knob must only touch drafter forwards: target-scored
    // distributions are bitwise equal between an int8 and an fp32
    // backend (DESIGN.md §11.2 — the target defines the output law).
    let int8 = NativeBackend::seeded_with_shapes(2, 32, 7)
        .with_threads(1)
        .with_draft_precision(Precision::Int8);
    let fp32 = NativeBackend::seeded_with_shapes(2, 32, 7)
        .with_threads(1)
        .with_draft_precision(Precision::Fp32);
    let (toks, lens) = prompt_state(&int8);
    let mut kv_i = int8.prefill("target", &toks, &lens).unwrap();
    let mut kv_f = fp32.prefill("target", &toks, &lens).unwrap();
    let drafts = vec![20i32, 21, 22, 20, 21, 22];
    let ps_i = int8.target_score(3, &toks, &lens, &mut kv_i, &drafts).unwrap();
    let ps_f = fp32.target_score(3, &toks, &lens, &mut kv_f, &drafts).unwrap();
    assert_eq!(ps_i, ps_f, "draft precision leaked into the target forward");
}

#[test]
fn int8_drafter_engages_and_stays_close_to_fp32() {
    // The knob must actually change the drafter's computation (int8 !=
    // fp32 bits) while the quantisation error stays small: the int8
    // drafter's next-token distributions track the fp32 drafter's far
    // more closely than either tracks the target.
    let int8 = NativeBackend::seeded_with_shapes(2, 32, 7)
        .with_threads(1)
        .with_draft_precision(Precision::Int8);
    let fp32 = NativeBackend::seeded_with_shapes(2, 32, 7)
        .with_threads(1)
        .with_draft_precision(Precision::Fp32);
    let (toks, lens) = prompt_state(&int8);
    let mut kv_i = int8.prefill("xxs", &toks, &lens).unwrap();
    let mut kv_f = fp32.prefill("xxs", &toks, &lens).unwrap();
    let gamma = 4;
    let di = int8.draft_block("xxs", gamma, &toks, &lens, &mut kv_i, &[5, 6]).unwrap();
    let df = fp32.draft_block("xxs", gamma, &toks, &lens, &mut kv_f, &[5, 6]).unwrap();
    assert_ne!(di.qs, df.qs, "int8 knob did not engage the drafter");
    let v = int8.info().vocab_size;
    let mut worst = 0.0f64;
    for (qi, qf) in di.qs.chunks_exact(v).zip(df.qs.chunks_exact(v)) {
        let tv = 0.5
            * qi.iter()
                .zip(qf.iter())
                .map(|(&a, &b)| (a as f64 - b as f64).abs())
                .sum::<f64>();
        worst = worst.max(tv);
    }
    assert!(worst < 0.25, "int8 drafter drifted too far from fp32: worst row TV {worst}");
}

#[test]
fn persistent_scratch_is_bit_identical_to_allocating_path() {
    // Multiple consecutive batches per engine: from the second batch on,
    // the persistent path verifies against a *dirty* reused scratch.
    let reqs = prompts(12);
    for algo in [
        Algo::Block,
        Algo::MultiPath { k: 2 },
        Algo::MultiPath { k: 4 },
        Algo::Tree { k: 2 },
        Algo::Tree { k: 4 },
    ] {
        let persistent = Arc::new(NativeBackend::seeded_with_shapes(4, 64, 0x5c8a));
        let allocating = Arc::new(
            NativeBackend::seeded_with_shapes(4, 64, 0x5c8a).with_persistent_scratch(false),
        );
        let a = decode(persistent.clone(), algo, &reqs, 23);
        let b = decode(allocating.clone(), algo, &reqs, 23);
        assert_eq!(a, b, "algo={algo}: persistent scratch changed decoded tokens");
        // And a second engine run on the same backends (scratch carried
        // over from the previous engine entirely).
        let a2 = decode(persistent, algo, &reqs, 29);
        let b2 = decode(allocating, algo, &reqs, 29);
        assert_eq!(a2, b2, "algo={algo}: dirty scratch reuse changed decoded tokens");
    }
}

#[test]
fn scratch_pool_never_aliases_flat_and_tree_checkouts() {
    // Regression for the pool key: a flat multipath checkout of B*K rows
    // at the model's max_len and a tree checkout of equal row count (but
    // a wider per-row ring) must hit different pool entries.  With the
    // old `(model, rows)` key, `MultiPath { k: 1 }` (4 rows x 64 slots)
    // and `Tree { k }` (4 rows x tree ring) would trade caches and read
    // each other's geometry.  Interleave all three algorithm families on
    // one persistent backend and require every decode to match a
    // fresh-backend run bit for bit.
    let reqs = prompts(8);
    let schedule = [
        Algo::MultiPath { k: 1 },
        Algo::Tree { k: 2 },
        Algo::MultiPath { k: 2 },
        Algo::Tree { k: 4 },
        Algo::Block,
        Algo::MultiPath { k: 1 },
        Algo::Tree { k: 2 },
    ];
    let shared = Arc::new(NativeBackend::seeded_with_shapes(4, 64, 0x5c8a));
    for (i, &algo) in schedule.iter().enumerate() {
        let fresh = Arc::new(NativeBackend::seeded_with_shapes(4, 64, 0x5c8a));
        let seed = 31 + i as u64;
        let got = decode(shared.clone(), algo, &reqs, seed);
        let want = decode(fresh, algo, &reqs, seed);
        assert_eq!(got, want, "step {i} ({algo}): pooled scratch aliased across algorithms");
    }
}
