//! Cross-backend losslessness properties of the native backend.
//!
//! 1. The fused path is the host path: for identical per-row seeds and
//!    prompts, every `spec_iter` call's `(tau, emitted)` must equal
//!    replaying the same state through `draft_block` + `target_score` +
//!    the host-side `verify::verify` dispatch with each row's published
//!    verification uniforms ([`specd::backend::native::verify_uniforms`])
//!    — for both token and block verification, draw for draw.
//! 2. The paper's never-worse guarantee: on aggregate over seeds, prompts
//!    and gammas, block verification's block efficiency is at least token
//!    verification's (small slack for finite-sample noise).

use std::sync::Arc;

use specd::backend::native::verify_uniforms;
use specd::backend::{Backend, NativeBackend};
use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::models::vocab;
use specd::verify::{self, Algo, ProbMatrix};
use specd::workload::Dataset;

/// A deterministic 4-row prompt state on the given backend.
fn prompt_state(be: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
    let info = be.info();
    let (b, l) = (info.batch, info.max_len);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        let mut p = vec![vocab::BOS as i32, vocab::marker_for(bi as u32 % 8) as i32];
        for j in 0..6 {
            p.push((vocab::CONTENT_BASE + ((bi * 31 + j * 7) % 200) as u32) as i32);
        }
        for (j, &t) in p.iter().enumerate() {
            toks[bi * l + j] = t;
        }
        lens[bi] = p.len() as i32;
    }
    (toks, lens)
}

#[test]
fn fused_iterations_match_host_verify_dispatch() {
    let gamma = 4;
    for algo in [Algo::Token, Algo::Block] {
        let be = NativeBackend::seeded_with_shapes(4, 64, 0xc0de);
        let info = be.info().clone();
        let (mut toks, mut lens) = prompt_state(&be);
        let mut kv_t = be.prefill("target", &toks, &lens).unwrap();
        let mut kv_d = be.prefill("xxs", &toks, &lens).unwrap();

        for iter in 0..6 {
            // Distinct seed per row, as the continuous batcher supplies.
            let seeds: Vec<i32> =
                (0..info.batch as i32).map(|bi| iter * 977 + 13 + bi * 131).collect();
            // --- replay path on clones of the exact same state -----------
            let mut kv_t2 = kv_t.clone();
            let mut kv_d2 = kv_d.clone();
            let d = be
                .draft_block("xxs", gamma, &toks, &lens, &mut kv_d2, &seeds)
                .unwrap();
            let ps = be
                .target_score(gamma, &toks, &lens, &mut kv_t2, &d.drafts)
                .unwrap();
            let v = info.vocab_size;
            let expected: Vec<verify::VerifyOutcome> = (0..info.batch)
                .map(|bi| {
                    let (etas, u_res) = verify_uniforms(seeds[bi], gamma);
                    let ps_m = ProbMatrix::from_f32(
                        gamma + 1,
                        v,
                        &ps[bi * (gamma + 1) * v..(bi + 1) * (gamma + 1) * v],
                    );
                    let qs_m = ProbMatrix::from_f32(
                        gamma,
                        v,
                        &d.qs[bi * gamma * v..(bi + 1) * gamma * v],
                    );
                    let drafts: Vec<u32> = d.drafts[bi * gamma..(bi + 1) * gamma]
                        .iter()
                        .map(|&x| x as u32)
                        .collect();
                    verify::verify(algo, &ps_m, &qs_m, &drafts, &etas, u_res)
                })
                .collect();

            // --- fused path ----------------------------------------------
            let out = be
                .spec_iter(
                    algo, "xxs", gamma, &mut toks, &mut lens, &mut kv_t, &mut kv_d, &seeds,
                )
                .unwrap();

            for (bi, want) in expected.iter().enumerate() {
                assert_eq!(
                    out.tau[bi] as usize, want.tau,
                    "{algo} iter {iter} row {bi}: tau"
                );
                let got: Vec<u32> = out.emitted
                    [bi * (gamma + 1)..bi * (gamma + 1) + want.tau + 1]
                    .iter()
                    .map(|&x| x as u32)
                    .collect();
                assert_eq!(got, want.emitted, "{algo} iter {iter} row {bi}: emitted");
            }
        }
    }
}

#[test]
fn block_never_worse_than_token_on_aggregate() {
    let be = Arc::new(NativeBackend::seeded(42));
    let prompts = Dataset::synthetic("gsm8k", 8, 0xabc).unwrap().take(8);
    let mut be_by_algo = Vec::new();
    for algo in [Algo::Token, Algo::Block] {
        let mut emitted = 0usize;
        let mut iters = 0usize;
        for gamma in [4usize, 8] {
            for seed in 0..3u64 {
                let cfg = EngineConfig {
                    gamma,
                    algo,
                    drafter: "xxs".into(),
                    max_new_tokens: 16,
                    host_verify: false,
                    seed,
                    ..Default::default()
                };
                let eng = SpecEngine::new(be.clone(), cfg).unwrap();
                for rep in eng.run_prompts(&prompts, seed).unwrap() {
                    for row in &rep.rows {
                        emitted += row.emitted;
                        iters += row.iterations;
                    }
                }
            }
        }
        be_by_algo.push(emitted as f64 / iters.max(1) as f64);
    }
    let (tok, blk) = (be_by_algo[0], be_by_algo[1]);
    assert!(tok >= 1.0 && blk >= 1.0, "BE is at least 1 by construction");
    // Theorem 2 guarantees E[BE_block] >= E[BE_token]; the 0.1 slack
    // covers finite-sample noise on this aggregate (~1k iterations).
    assert!(
        blk >= tok - 0.1,
        "block verification must not be worse: token {tok:.3} vs block {blk:.3}"
    );
}
