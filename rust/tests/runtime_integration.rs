//! Integration over the execution-backend abstraction: load a hermetic
//! native backend (seeded weights, no artifacts needed) and run every
//! serving path — fused spec engine, host-verify engine, greedy, baseline
//! — end to end.  The manifest-catalogue check at the bottom still runs
//! against a real AOT bundle and skips (with a message) when artifacts are
//! missing.

use std::sync::Arc;

use specd::backend::{Backend, NativeBackend};
use specd::config::EngineConfig;
use specd::engine::baseline::run_baseline_prompts;
use specd::engine::host::HostVerifyEngine;
use specd::engine::spec::SpecEngine;
use specd::engine::FinishReason;
use specd::models::vocab;
use specd::runtime::Manifest;
use specd::verify::Algo;
use specd::workload::Dataset;

fn backend() -> Arc<NativeBackend> {
    Arc::new(NativeBackend::seeded(0xbea7))
}

fn dataset(name: &str) -> Dataset {
    Dataset::synthetic(name, 32, 0x1e57).unwrap()
}

fn cfg(algo: Algo, gamma: usize) -> EngineConfig {
    EngineConfig {
        gamma,
        algo,
        drafter: "xxs".into(),
        max_new_tokens: 16,
        host_verify: !algo.fused(),
        seed: 0,
        ..Default::default()
    }
}

#[test]
fn fused_engine_generates_valid_tokens() {
    let be = backend();
    let ds = dataset("gsm8k");
    let eng = SpecEngine::new(be, cfg(Algo::Block, 8)).unwrap();
    let report = eng.run_batch(&ds.take(3), 7).unwrap();
    assert_eq!(report.rows.len(), 3);
    for row in &report.rows {
        assert!(!row.tokens.is_empty());
        assert!(row.tokens.iter().all(|&t| t < vocab::SIZE && t != vocab::PAD));
        assert!(row.iterations >= 1);
        assert!(
            row.emitted >= row.tokens.len(),
            "emitted counts EOS/overflow tokens too"
        );
        assert!(row.block_efficiency() >= 1.0);
        assert!(matches!(
            row.finish,
            FinishReason::Eos | FinishReason::Length | FinishReason::OutOfRoom
        ));
    }
}

#[test]
fn fused_paths_work_for_all_gammas_and_algos() {
    let be = backend();
    let ds = dataset("lm1b");
    let prompts = ds.take(2);
    for gamma in [4, 6, 8] {
        for algo in [Algo::Token, Algo::Block] {
            let eng = SpecEngine::new(be.clone(), cfg(algo, gamma)).unwrap();
            let rep = eng.run_batch(&prompts, 1).unwrap();
            assert!(rep.rows[0].iterations >= 1, "{algo} g{gamma}");
        }
    }
}

#[test]
fn host_verify_close_to_fused() {
    // Independent implementations of the same algorithm on the same model
    // pair must produce statistically similar block efficiencies.
    let be = backend();
    let ds = dataset("xsum");
    let prompts = ds.take(12);
    let mut be_fused = 0.0;
    let mut be_host = 0.0;
    for seed in 0..2 {
        let f = SpecEngine::new(be.clone(), cfg(Algo::Block, 8)).unwrap();
        let reps = f.run_prompts(&prompts, seed).unwrap();
        be_fused += reps.iter().map(|r| r.block_efficiency()).sum::<f64>()
            / reps.len() as f64;
        let h = HostVerifyEngine::new(be.clone(), cfg(Algo::Block, 8)).unwrap();
        let reps = h.run_prompts(&prompts, seed).unwrap();
        be_host +=
            reps.iter().map(|r| r.block_efficiency()).sum::<f64>() / reps.len() as f64;
    }
    let (f, h) = (be_fused / 2.0, be_host / 2.0);
    assert!((f - h).abs() / f < 0.2, "fused {f} vs host {h}");
}

#[test]
fn greedy_runs_on_host_path() {
    let be = backend();
    let ds = dataset("piqa");
    let eng = HostVerifyEngine::new(be, cfg(Algo::Greedy, 8)).unwrap();
    let rep = eng.run_batch(&ds.take(3), 3).unwrap();
    assert!(rep.rows.iter().all(|r| r.block_efficiency() >= 1.0));
}

#[test]
fn fused_greedy_is_rejected() {
    let be = backend();
    assert!(SpecEngine::new(be, cfg(Algo::Greedy, 8)).is_err());
}

#[test]
fn baseline_emits_one_token_per_call() {
    let be = backend();
    let ds = dataset("webqa");
    let reps = run_baseline_prompts(&*be, &ds.take(3), 12, 0).unwrap();
    for row in reps.iter().flat_map(|r| &r.rows) {
        assert_eq!(row.emitted, row.iterations, "baseline BE is exactly 1");
        assert!(!row.tokens.is_empty());
    }
}

#[test]
fn out_of_range_gammas_rejected() {
    let be = backend();
    // gamma = 0 is invalid everywhere.
    assert!(SpecEngine::new(be.clone(), cfg(Algo::Block, 0)).is_err());
    // Open-gamma backends still cap blocks at L/4 to leave decode room in
    // the ring; an oversized block must fail at engine build time rather
    // than corrupt the KV cache.
    let cap = be.info().max_len / 4;
    assert!(SpecEngine::new(be.clone(), cfg(Algo::Block, cap)).is_ok());
    assert!(SpecEngine::new(be.clone(), cfg(Algo::Block, cap + 1)).is_err());
    // And a direct backend call with a bad gamma errors instead of
    // panicking.
    let ds = dataset("lm1b");
    let prompts = ds.take(1);
    let eng = SpecEngine::new(be.clone(), cfg(Algo::Block, 4)).unwrap();
    let _ = eng.run_batch(&prompts, 0).unwrap();
    let info = be.info();
    let toks = vec![1i32; info.batch * info.max_len];
    let lens = vec![2i32; info.batch];
    let mut kv = be.prefill("xxs", &toks, &lens).unwrap();
    let seeds = vec![0i32; info.batch];
    assert!(be.draft_block("xxs", info.max_len, &toks, &lens, &mut kv, &seeds).is_err());
}

#[test]
fn manifest_catalogue_is_complete() {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = std::path::PathBuf::from(dir);
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let m = Manifest::load(&p).expect("manifest loads");
    assert_eq!(m.batch, 4);
    for g in &m.gammas {
        for d in &m.drafters {
            for a in ["token", "block"] {
                assert!(
                    m.programs.contains_key(&m.spec_iter_name(a, d, *g)),
                    "missing spec_iter_{a}_{d}_g{g}"
                );
            }
            assert!(m.programs.contains_key(&format!("draft_block_{d}_g{g}")));
        }
        assert!(m.programs.contains_key(&format!("target_score_g{g}")));
    }
    assert!(m.programs.contains_key("baseline_step"));
    // weight files exist and sizes match declared entries
    for (name, model) in &m.models {
        let path = p.join(&model.weights_file);
        let n = std::fs::metadata(&path).unwrap().len() as usize / 4;
        let declared: usize = model
            .weights
            .iter()
            .map(|w| w.shape.iter().product::<usize>().max(1))
            .sum();
        assert_eq!(n, declared, "weights file mismatch for {name}");
    }
}
