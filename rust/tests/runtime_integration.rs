//! Integration over the real AOT bundle: load, compile and run every
//! serving path, and cross-check the fused in-HLO verification against the
//! host-verify path.  Skips (with a message) when artifacts are missing.

use std::sync::Arc;

use specd::config::EngineConfig;
use specd::engine::baseline::run_baseline_prompts;
use specd::engine::host::HostVerifyEngine;
use specd::engine::spec::SpecEngine;
use specd::engine::FinishReason;
use specd::models::vocab;
use specd::runtime::Runtime;
use specd::verify::Algo;
use specd::workload::Dataset;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = std::path::PathBuf::from(dir);
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::load(&p).expect("runtime loads")))
}

fn cfg(algo: Algo, gamma: usize) -> EngineConfig {
    EngineConfig {
        gamma,
        algo,
        drafter: "xxs".into(),
        max_new_tokens: 16,
        host_verify: !algo.fused(),
        seed: 0,
    }
}

#[test]
fn fused_engine_generates_valid_tokens() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.artifacts_dir(), "gsm8k").unwrap();
    let eng = SpecEngine::new(rt.clone(), cfg(Algo::Block, 8)).unwrap();
    let report = eng.run_batch(&ds.take(3), 7).unwrap();
    assert_eq!(report.rows.len(), 3);
    for row in &report.rows {
        assert!(!row.tokens.is_empty());
        assert!(row.tokens.iter().all(|&t| t < vocab::SIZE && t != vocab::PAD));
        assert!(row.iterations >= 1);
        assert_eq!(
            row.emitted >= row.tokens.len(),
            true,
            "emitted counts EOS/overflow tokens too"
        );
        assert!(row.block_efficiency() >= 1.0);
        assert!(matches!(
            row.finish,
            FinishReason::Eos | FinishReason::Length | FinishReason::OutOfRoom
        ));
    }
}

#[test]
fn fused_paths_work_for_all_gammas_and_algos() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.artifacts_dir(), "lm1b").unwrap();
    let prompts = ds.take(2);
    for gamma in [4, 6, 8] {
        for algo in [Algo::Token, Algo::Block] {
            let eng = SpecEngine::new(rt.clone(), cfg(algo, gamma)).unwrap();
            let rep = eng.run_batch(&prompts, 1).unwrap();
            assert!(rep.rows[0].iterations >= 1, "{algo} g{gamma}");
        }
    }
}

#[test]
fn host_verify_close_to_fused() {
    // Independent implementations of the same algorithm on the same model
    // pair must produce statistically similar block efficiencies.
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.artifacts_dir(), "xsum").unwrap();
    let prompts = ds.take(12);
    let mut be_fused = 0.0;
    let mut be_host = 0.0;
    for seed in 0..2 {
        let f = SpecEngine::new(rt.clone(), cfg(Algo::Block, 8)).unwrap();
        let reps = f.run_prompts(&prompts, seed).unwrap();
        be_fused += reps.iter().map(|r| r.block_efficiency()).sum::<f64>()
            / reps.len() as f64;
        let h = HostVerifyEngine::new(rt.clone(), cfg(Algo::Block, 8)).unwrap();
        let reps = h.run_prompts(&prompts, seed).unwrap();
        be_host +=
            reps.iter().map(|r| r.block_efficiency()).sum::<f64>() / reps.len() as f64;
    }
    let (f, h) = (be_fused / 2.0, be_host / 2.0);
    assert!((f - h).abs() / f < 0.15, "fused {f} vs host {h}");
}

#[test]
fn greedy_runs_on_host_path() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.artifacts_dir(), "piqa").unwrap();
    let eng = HostVerifyEngine::new(rt.clone(), cfg(Algo::Greedy, 8)).unwrap();
    let rep = eng.run_batch(&ds.take(3), 3).unwrap();
    assert!(rep.rows.iter().all(|r| r.block_efficiency() >= 1.0));
}

#[test]
fn baseline_emits_one_token_per_call() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::load(rt.artifacts_dir(), "webqa").unwrap();
    let reps = run_baseline_prompts(&rt, &ds.take(3), 12, 0).unwrap();
    for row in reps.iter().flat_map(|r| &r.rows) {
        assert_eq!(row.emitted, row.iterations, "baseline BE is exactly 1");
        assert!(!row.tokens.is_empty());
    }
}

#[test]
fn manifest_catalogue_is_complete() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    assert_eq!(m.batch, 4);
    for g in &m.gammas {
        for d in &m.drafters {
            for a in ["token", "block"] {
                assert!(
                    m.programs.contains_key(&m.spec_iter_name(a, d, *g)),
                    "missing spec_iter_{a}_{d}_g{g}"
                );
            }
            assert!(m.programs.contains_key(&format!("draft_block_{d}_g{g}")));
        }
        assert!(m.programs.contains_key(&format!("target_score_g{g}")));
    }
    assert!(m.programs.contains_key("baseline_step"));
    // weight files exist and sizes match declared entries
    for (name, model) in &m.models {
        let path = rt.artifacts_dir().join(&model.weights_file);
        let n = std::fs::metadata(&path).unwrap().len() as usize / 4;
        let declared: usize = model
            .weights
            .iter()
            .map(|w| w.shape.iter().product::<usize>().max(1))
            .sum();
        assert_eq!(n, declared, "weights file mismatch for {name}");
    }
}
