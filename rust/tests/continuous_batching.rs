//! Continuous batching over per-slot KV splice (DESIGN.md §7):
//!
//! 1. Refill losslessness: a prompt admitted into a live mid-decode batch
//!    via `kv_splice` produces token-for-token the same output as the
//!    same prompt run in a fresh batch with the same (row) seed.
//! 2. Slot reuse before batch drain: a short request completes and its
//!    slot is re-admitted while a long request is still decoding.
//! 3. Coordinator end-to-end: under mixed-length concurrent traffic,
//!    every short request completes before the long one — impossible
//!    under the old batch-drain scheduling once the queue overflows the
//!    slot count.

use std::sync::Arc;
use std::time::Instant;

use specd::backend::NativeBackend;
use specd::config::{Config, EngineConfig};
use specd::coordinator::{Coordinator, GenRequest};
use specd::engine::spec::{row_seed, DecodeState, SpecEngine};
use specd::models::vocab;

fn prompt(tail: &[u32]) -> Vec<u32> {
    let mut p = vec![vocab::BOS, vocab::marker_for(1)];
    p.extend_from_slice(tail);
    p
}

/// Step the stream until `slot`'s row finishes, reproducing the
/// coordinator's absorb rules (EOS stops, `max_new` caps, device `done`
/// ends the row), and return the generated tokens.
fn collect_row(
    engine: &SpecEngine<NativeBackend>,
    st: &mut DecodeState<NativeBackend>,
    slot: usize,
    max_new: usize,
) -> Vec<u32> {
    let gamma = engine.cfg.gamma;
    let mut gen: Vec<u32> = Vec::new();
    for _ in 0..(max_new + 200) {
        let out = engine.step_stream(st).unwrap();
        let tau = out.tau[slot] as usize;
        let emitted = &out.emitted[slot * (gamma + 1)..slot * (gamma + 1) + tau + 1];
        for &t in emitted {
            if t as u32 == vocab::EOS {
                return gen;
            }
            gen.push(t as u32);
            if gen.len() >= max_new {
                return gen;
            }
        }
        if out.done[slot] != 0 {
            return gen;
        }
    }
    panic!("row {slot} never finished");
}

#[test]
fn refill_admission_is_lossless() {
    let batch_seed = 0x5eed_cafe;
    let max_new = 12;
    let be = Arc::new(NativeBackend::seeded_with_shapes(2, 64, 7));
    let cfg = EngineConfig { gamma: 4, max_new_tokens: max_new, ..Default::default() };
    let engine = SpecEngine::new(be, cfg).unwrap();
    let p = prompt(&[30, 31, 32, 33]);

    // Reference: the prompt as row 0 of a fresh batch-drain run.
    let reference = engine.run_batch(&[p.clone()], batch_seed).unwrap().rows[0].tokens.clone();

    // Continuous: occupy slot 0 with a decoy, decode a while, then admit
    // the prompt mid-decode into the *other* slot with row 0's seed.
    let mut st = engine.begin_stream().unwrap();
    engine.admit_row(&mut st, 0, &prompt(&[40, 41]), 0xdec0).unwrap();
    for _ in 0..3 {
        engine.step_stream(&mut st).unwrap();
    }
    assert!(st.occupied(0));
    engine.admit_row(&mut st, 1, &p, row_seed(batch_seed, 0)).unwrap();
    let got = collect_row(&engine, &mut st, 1, max_new);

    assert_eq!(
        got, reference,
        "a spliced-in row must reproduce the fresh-batch decode token for token"
    );
}

#[test]
fn slot_reused_before_batch_drain() {
    let be = Arc::new(NativeBackend::seeded_with_shapes(2, 96, 3));
    let cfg = EngineConfig { gamma: 4, max_new_tokens: 40, ..Default::default() };
    let engine = SpecEngine::new(be, cfg).unwrap();
    let mut st = engine.begin_stream().unwrap();

    // Long request in slot 0 (cap 40 ⇒ ≥ 8 iterations at gamma 4); a
    // 1-token request in slot 1 finishes after the first step.
    engine.admit_row(&mut st, 0, &prompt(&[20, 21, 22]), 11).unwrap();
    engine.admit_row(&mut st, 1, &prompt(&[50, 51]), 22).unwrap();
    let long_len_before = st.row_length(0);
    let out = engine.step_stream(&mut st).unwrap();
    // The short row emitted ≥ 1 token: its request (cap 1) is done.
    let tau1 = out.tau[1] as usize;
    assert!(tau1 <= 4);
    // The long row cannot have finished its 40-token budget in one step
    // (≤ gamma + 1 = 5 tokens/iteration; EOS is ~impossible under the
    // seeded control-token bias).
    assert!(st.row_length(0) > long_len_before);
    assert!(st.row_length(0) - long_len_before <= 5);

    // Free the short slot and admit a new request into it mid-decode —
    // the batch never drained.
    engine.release_row(&mut st, 1);
    assert!(!st.occupied(1));
    assert!(st.occupied(0), "long row still live when slot 1 is reused");
    engine.admit_row(&mut st, 1, &prompt(&[60, 61, 62]), 33).unwrap();
    assert_eq!(st.occupied_count(), 2);

    // Both rows run to completion with valid tokens.
    let second = collect_row(&engine, &mut st, 1, 6);
    assert!(second.iter().all(|&t| t < vocab::SIZE && t != vocab::PAD));
    let long = collect_row(&engine, &mut st, 0, 40);
    assert!(!long.is_empty());
    assert!(long.iter().all(|&t| t < vocab::SIZE && t != vocab::PAD));
}

#[test]
fn coordinator_completes_shorts_before_long_under_mixed_load() {
    let backend = Arc::new(NativeBackend::seeded(0x7e57));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 48, ..Default::default() };
    let coordinator = Coordinator::spawn(backend, ecfg, &cfg.server).unwrap();
    let metrics = coordinator.metrics.clone();

    let mk = |tail: Vec<u32>, max_new: usize, seed: u64| GenRequest {
        prompt: prompt(&tail),
        max_new_tokens: Some(max_new),
        seed: Some(seed),
        enqueued: Instant::now(),
    };

    // One long request first, then more shorts than the remaining slots
    // (batch B = 4 ⇒ at least 3 shorts must be admitted into slots freed
    // mid-decode).  The long row needs ≥ 8 engine iterations (64 tokens,
    // ≤ 9 per iteration); every short needs exactly 1 after admission.
    let long_coord = coordinator.clone();
    let long_req = mk(vec![20, 21, 22], 64, 1);
    let long_handle = std::thread::spawn(move || {
        let row = long_coord.generate(long_req).unwrap();
        (Instant::now(), row)
    });
    // Wait until the long request has actually been admitted (its splice
    // bumps the refill counter) before firing the shorts, so it is
    // decoding while they arrive.
    let t0 = Instant::now();
    while metrics.slots_refilled.get() < 1 {
        assert!(t0.elapsed().as_secs() < 10, "long request never admitted");
        std::thread::sleep(std::time::Duration::from_millis(1));
    }

    let mut short_handles = Vec::new();
    for i in 0..6u32 {
        let c = coordinator.clone();
        let req = mk(vec![30 + i, 40 + i], 1, 100 + i as u64);
        short_handles.push(std::thread::spawn(move || {
            let row = c.generate(req).unwrap();
            (Instant::now(), row)
        }));
    }

    let mut latest_short = None::<Instant>;
    for h in short_handles {
        let (done_at, row) = h.join().unwrap();
        assert!(row.tokens.len() <= 1);
        latest_short = Some(match latest_short {
            Some(t) if t > done_at => t,
            _ => done_at,
        });
    }
    let (long_done, long_row) = long_handle.join().unwrap();
    assert!(!long_row.tokens.is_empty());

    // Continuous batching: every short (including the ≥ 3 that overflowed
    // the first admission wave) finishes while the long row is still
    // decoding.  Under batch drain the overflow shorts would have waited
    // for the long row's batch to fully complete.
    assert!(
        latest_short.unwrap() < long_done,
        "shorts must complete before the long request under continuous batching"
    );
    // Every admission goes through the splice path, and all 7 requests
    // completed.
    assert!(metrics.slots_refilled.get() >= 7);
    assert_eq!(metrics.requests_completed.get(), 7);
}

#[test]
fn oversized_prompt_is_rejected_not_hung() {
    let backend = Arc::new(NativeBackend::seeded(0xbad));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 4, ..Default::default() };
    let coordinator = Coordinator::spawn(backend, ecfg, &cfg.server).unwrap();
    // max_len is 96 ⇒ the ring budget is < 48 prompt tokens; the old
    // batch-drain worker would have panicked (and hung every caller) on
    // the layout assert instead of replying with an error.
    let req = GenRequest {
        prompt: prompt(&vec![25u32; 60]),
        max_new_tokens: Some(4),
        seed: Some(0),
        enqueued: Instant::now(),
    };
    let err = coordinator.generate(req).expect_err("oversized prompt must be rejected");
    assert!(format!("{err:#}").contains("ring budget"), "unexpected error: {err:#}");
    // The worker survived: a well-formed request still succeeds.
    let ok = coordinator
        .generate(GenRequest {
            prompt: prompt(&[20, 21]),
            max_new_tokens: Some(2),
            seed: Some(0),
            enqueued: Instant::now(),
        })
        .unwrap();
    assert!(ok.tokens.len() <= 2);
}
