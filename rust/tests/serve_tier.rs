//! Serving-tier invariants (DESIGN.md §14):
//!
//! 1. Warm-prefix losslessness: admitting a prompt over a cached prefix
//!    (`prefill_prefix` + `admit_rows_prefixed`) produces token-for-token
//!    the same output as a cold admission — for the fp32 *and* the int8
//!    drafter.
//! 2. Placement invariance: with per-request seeds, a request's output is
//!    identical whether the router pins every request to replica 0 or
//!    load-balances across replicas under concurrency.
//! 3. Overload sheds: when no replica has admission budget the router
//!    returns `RouteError::Shed` (429 + Retry-After upstream) and counts
//!    it — never a panic, never an unbounded queue.
//! 4. Paged KV pool: exhaustion defers admissions (requests still
//!    complete, unshed); a request that can never fit is rejected with an
//!    explicit error.
//! 5. Router-level prefix serving: warm responses are bit-identical to a
//!    prefix-cache-disabled router's, the cache counts hits/misses, and
//!    warm admissions prefill strictly fewer positions than the prompts
//!    contain.

use std::sync::Arc;
use std::time::{Duration, Instant};

use specd::backend::{NativeBackend, Precision};
use specd::config::{Config, EngineConfig, RouterConfig};
use specd::engine::spec::{Admission, DecodeState, PrefixHandle, SpecEngine};
use specd::models::vocab;
use specd::serve::{RouteError, Router, ServeRequest};

fn prompt(tail: &[u32]) -> Vec<u32> {
    let mut p = vec![vocab::BOS, vocab::marker_for(1)];
    p.extend_from_slice(tail);
    p
}

/// Step the stream until `slot`'s row finishes (the coordinator's absorb
/// rules: EOS stops, `max_new` caps, device `done` ends the row).
fn collect_row(
    engine: &SpecEngine<NativeBackend>,
    st: &mut DecodeState<NativeBackend>,
    slot: usize,
    max_new: usize,
) -> Vec<u32> {
    let gamma = engine.cfg.gamma;
    let mut gen: Vec<u32> = Vec::new();
    for _ in 0..(max_new + 200) {
        let out = engine.step_stream(st).unwrap();
        let tau = out.tau[slot] as usize;
        let emitted = &out.emitted[slot * (gamma + 1)..slot * (gamma + 1) + tau + 1];
        for &t in emitted {
            if t as u32 == vocab::EOS {
                return gen;
            }
            gen.push(t as u32);
            if gen.len() >= max_new {
                return gen;
            }
        }
        if out.done[slot] != 0 {
            return gen;
        }
    }
    panic!("row {slot} never finished");
}

/// Engine-level warm-vs-cold: same prompt, same row seed, once admitted
/// cold and once over a cached 16-token prefix — identical tokens.
fn assert_warm_prefix_lossless(precision: Precision) {
    let max_new = 12;
    let seed = 0x5eed_0001;
    let be = Arc::new(NativeBackend::seeded_with_shapes(2, 96, 7));
    let cfg = EngineConfig {
        gamma: 4,
        max_new_tokens: max_new,
        draft_precision: precision,
        ..Default::default()
    };
    let engine = SpecEngine::new(be, cfg).unwrap();
    // 20-token prompt; its first 16 tokens are the shared prefix.
    let p = prompt(&[30, 31, 32, 33, 34, 35, 36, 37, 38, 39, 40, 41, 42, 43, 44, 45, 46, 47]);
    let plen = 16;

    let mut st = engine.begin_stream().unwrap();
    engine.admit_row(&mut st, 0, &p, seed).unwrap();
    let cold = collect_row(&engine, &mut st, 0, max_new);

    let (kv_t, kv_d) = engine.prefill_prefix(&p[..plen]).unwrap();
    let mut st = engine.begin_stream().unwrap();
    let admissions = [Admission { slot: 0, prompt: &p, row_seed: seed }];
    let prefixes =
        [Some(PrefixHandle::<NativeBackend> { kv_target: &kv_t, kv_drafter: &kv_d, len: plen })];
    let results = engine.admit_rows_prefixed(&mut st, &admissions, &prefixes);
    results.into_iter().next().unwrap().expect("prefixed admission must succeed");
    let warm = collect_row(&engine, &mut st, 0, max_new);

    assert_eq!(
        warm, cold,
        "splicing a cached prefix must reproduce the cold decode token for token \
         ({precision:?} drafter)"
    );
}

#[test]
fn warm_prefix_admission_is_bit_identical_fp32() {
    assert_warm_prefix_lossless(Precision::Fp32);
}

#[test]
fn warm_prefix_admission_is_bit_identical_int8() {
    assert_warm_prefix_lossless(Precision::Int8);
}

#[test]
fn placement_is_invariant_under_load() {
    let backend = Arc::new(NativeBackend::seeded(0x11ad));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 8, ..Default::default() };
    let pinned_cfg =
        RouterConfig { replicas: 2, pinned_replica: Some(0), ..Default::default() };
    let load_cfg = RouterConfig { replicas: 2, ..Default::default() };
    let pinned =
        Router::spawn(backend.clone(), ecfg.clone(), &cfg.server, &pinned_cfg).unwrap();
    let load_aware = Router::spawn(backend, ecfg, &cfg.server, &load_cfg).unwrap();

    let reqs: Vec<(Vec<u32>, usize, u64)> = (0..8u32)
        .map(|i| (prompt(&[20 + i, 30 + i, 40 + i]), [1, 8, 4, 2][i as usize % 4], 100 + i as u64))
        .collect();

    // Reference: everything on replica 0, sequentially.
    let reference: Vec<Vec<u32>> = reqs
        .iter()
        .map(|(p, max_new, seed)| {
            pinned
                .generate(ServeRequest::new(p.clone(), Some(*max_new), Some(*seed)))
                .unwrap()
                .tokens
        })
        .collect();

    // Same requests, concurrent, least-outstanding-tokens placement.
    let handles: Vec<_> = reqs
        .iter()
        .map(|(p, max_new, seed)| {
            let r = load_aware.clone();
            let (p, max_new, seed) = (p.clone(), *max_new, *seed);
            std::thread::spawn(move || {
                r.generate(ServeRequest::new(p, Some(max_new), Some(seed))).unwrap().tokens
            })
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&reference) {
        let got = h.join().unwrap();
        assert_eq!(
            &got, want,
            "a seeded request's output must not depend on replica placement"
        );
    }
}

#[test]
fn overload_sheds_with_retry_after_not_panic() {
    let backend = Arc::new(NativeBackend::seeded(0x0bad));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 48, ..Default::default() };
    // Budget fits exactly one long request (cost = prompt 5 + max_new 80
    // = 85 tokens), so a second request while it decodes must shed.
    let rcfg = RouterConfig {
        replicas: 1,
        token_budget: 86,
        prefix_cache: false,
        ..Default::default()
    };
    let router = Router::spawn(backend, ecfg, &cfg.server, &rcfg).unwrap();

    let long_router = router.clone();
    let long = std::thread::spawn(move || {
        long_router
            .generate(ServeRequest::new(prompt(&[20, 21, 22]), Some(80), Some(1)))
            .unwrap()
    });
    // Wait until the long request is actually admitted and decoding.
    let metrics = router.replica_metrics(0);
    let t0 = Instant::now();
    while metrics.slots_refilled.get() < 1 {
        assert!(t0.elapsed().as_secs() < 10, "long request never admitted");
        std::thread::sleep(Duration::from_millis(1));
    }

    let err = router
        .generate(ServeRequest::new(prompt(&[50, 51]), Some(1), Some(2)))
        .expect_err("an over-budget request must be shed");
    match err {
        RouteError::Shed { retry_after_s } => {
            assert!(retry_after_s >= 1, "shed must carry a usable Retry-After hint")
        }
        other => panic!("expected Shed, got: {other}"),
    }
    assert!(router.metrics.requests_shed_total.get() >= 1);

    let long_row = long.join().unwrap();
    assert!(!long_row.tokens.is_empty());
    // Budget released on completion: the same request now succeeds.
    let ok = router
        .generate(ServeRequest::new(prompt(&[50, 51]), Some(1), Some(2)))
        .unwrap();
    assert!(ok.tokens.len() <= 1);
}

#[test]
fn pool_exhaustion_defers_then_completes() {
    let backend = Arc::new(NativeBackend::seeded_with_shapes(2, 64, 9));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 8, ..Default::default() };
    // Each row's footprint is prompt 5 + max_new 8 + gamma 8 + 2 = 23
    // positions = 2 pages; a 2-page pool serialises admissions — later
    // requests defer (not shed, not fail) until pages free up.
    let rcfg = RouterConfig {
        replicas: 1,
        page_size: 16,
        kv_pages: 2,
        prefix_cache: false,
        ..Default::default()
    };
    let router = Router::spawn(backend, ecfg, &cfg.server, &rcfg).unwrap();

    let handles: Vec<_> = (0..3u32)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                r.generate(ServeRequest::new(
                    prompt(&[30 + i, 40 + i, 50 + i]),
                    Some(8),
                    Some(10 + i as u64),
                ))
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let row = h.join().unwrap();
        assert!(row.tokens.len() <= 8);
    }
    assert_eq!(router.metrics.requests_shed_total.get(), 0, "deferral must not shed");
    assert_eq!(router.replica_metrics(0).requests_completed.get(), 3);
    // Row leases return to the pool with their slots.
    let t0 = Instant::now();
    while router.pool().pages_used() != 0 {
        assert!(t0.elapsed().as_secs() < 10, "row page leases never returned to the pool");
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn request_larger_than_pool_is_rejected_not_hung() {
    let backend = Arc::new(NativeBackend::seeded_with_shapes(2, 64, 9));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 8, ..Default::default() };
    // One 16-position page total; a footprint of 23 positions can never
    // fit — the worker must reply with an explicit error, not defer
    // forever.
    let rcfg = RouterConfig {
        replicas: 1,
        page_size: 16,
        kv_pages: 1,
        prefix_cache: false,
        ..Default::default()
    };
    let router = Router::spawn(backend, ecfg, &cfg.server, &rcfg).unwrap();
    let err = router
        .generate(ServeRequest::new(prompt(&[30, 31, 32]), Some(8), Some(0)))
        .expect_err("a request that cannot ever fit the pool must be rejected");
    match err {
        RouteError::Failed(msg) => {
            assert!(msg.contains("KV pages"), "unexpected rejection: {msg}")
        }
        other => panic!("expected Failed, got: {other}"),
    }
}

#[test]
fn router_warm_prefix_serving_is_bit_identical_and_counted() {
    let backend = Arc::new(NativeBackend::seeded(0x9a9e));
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 8, ..Default::default() };
    let cold_cfg = RouterConfig { replicas: 1, prefix_cache: false, ..Default::default() };
    let warm_cfg = RouterConfig { replicas: 1, prefix_cache: true, ..Default::default() };
    let cold_router =
        Router::spawn(backend.clone(), ecfg.clone(), &cfg.server, &cold_cfg).unwrap();
    let warm_router = Router::spawn(backend, ecfg, &cfg.server, &warm_cfg).unwrap();

    // 36-token prompt: its page-aligned 32-token head is cacheable
    // (page_size 16, L/2 = 48 budget).
    let tail: Vec<u32> = (0..34u32).map(|i| 30 + (i % 60)).collect();
    let p = prompt(&tail);
    let req = || ServeRequest::new(p.clone(), Some(8), Some(7));

    let cold = cold_router.generate(req()).unwrap().tokens;
    // First warm request misses and populates (and already decodes over
    // the spliced prefix); the second hits.
    let warm1 = warm_router.generate(req()).unwrap().tokens;
    let warm2 = warm_router.generate(req()).unwrap().tokens;
    assert_eq!(warm1, cold, "populate-path decode must be bit-identical to cold prefill");
    assert_eq!(warm2, cold, "hit-path decode must be bit-identical to cold prefill");

    let stats = warm_router.prefix_stats();
    assert!(stats.misses.get() >= 1, "first request must count a miss");
    assert!(stats.inserts.get() >= 1, "the miss must populate the cache");
    assert!(stats.hits.get() >= 1, "second request must count a hit");
    assert_eq!(cold_router.prefix_stats().hits.get(), 0);

    // Hit-work accounting (DESIGN.md §14.5): warm admissions forwarded
    // only prompt suffixes, so prefilled positions trail prompt positions.
    let m = warm_router.replica_metrics(0);
    assert!(
        m.prefill_positions.get() < m.prompt_positions.get(),
        "warm admissions must prefill strictly fewer positions than the prompts contain \
         (prefill {} vs prompt {})",
        m.prefill_positions.get(),
        m.prompt_positions.get()
    );
    // The cold router prefilled every prompt position.
    let c = cold_router.replica_metrics(0);
    assert_eq!(c.prefill_positions.get(), c.prompt_positions.get());
}
