//! Scatter-paged KV vs the contiguous oracle (DESIGN.md §16).
//!
//! The paged arena is a pure *layout* change: every kernel, precision,
//! verification algorithm and serving path must produce byte-identical
//! tokens and KV contents against `KvLayout::Contig`, copy-on-write must
//! isolate shared pages from decode writes, recycled (dirty) slabs must
//! never leak stale state into later decodes, and page refcounts must
//! balance when caches drop.
//!
//! Counter *deltas* are asserted only monotonically here — `kvstats` is
//! process-global and tests in this binary run concurrently.  Exact
//! ledger accounting lives in `tests/kv_ledger.rs` (single-test binary,
//! its own process).

use std::sync::Arc;

use specd::backend::{kvstats, Backend, KvLayout, NativeBackend, Precision};
use specd::config::{Config, EngineConfig, RouterConfig};
use specd::engine::spec::SpecEngine;
use specd::models::vocab;
use specd::serve::{Router, ServeRequest};
use specd::verify::Algo;

/// Deterministic prompt: BOS + dataset marker + `len - 2` content tokens
/// derived from `i`.
fn prompt(i: u32, len: usize) -> Vec<u32> {
    let mut p = vec![vocab::BOS, vocab::marker_for(i % 8)];
    while p.len() < len {
        p.push(vocab::CONTENT_BASE + ((i * 37 + p.len() as u32 * 13) % 200));
    }
    p
}

/// Row-major `(B, L)` token state + lengths for direct backend calls.
fn backend_state(b: usize, l: usize) -> (Vec<i32>, Vec<i32>) {
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        let p = prompt(bi as u32, 4 + 2 * bi);
        for (j, &t) in p.iter().enumerate() {
            toks[bi * l + j] = t as i32;
        }
        lens[bi] = p.len() as i32;
    }
    (toks, lens)
}

fn decode_tokens(
    layout: KvLayout,
    algo: Algo,
    precision: Precision,
    reference: bool,
) -> Vec<Vec<u32>> {
    let be = Arc::new(
        NativeBackend::seeded_with_shapes(3, 96, 0x9a6ed)
            .with_kv_layout(layout)
            .with_reference_kernel(reference),
    );
    let cfg = EngineConfig {
        gamma: 4,
        algo,
        draft_precision: precision,
        max_new_tokens: 10,
        kv_layout: layout,
        ..Default::default()
    };
    let eng = SpecEngine::new(be, cfg).unwrap();
    let prompts: Vec<Vec<u32>> = (0..3).map(|i| prompt(i, 5 + 3 * i as usize)).collect();
    let rep = eng.run_batch(&prompts, 0x5eed).unwrap();
    rep.rows.into_iter().map(|r| r.tokens).collect()
}

fn assert_layouts_agree(algo: Algo, precision: Precision, reference: bool) {
    let contig = decode_tokens(KvLayout::Contig, algo, precision, reference);
    let paged = decode_tokens(KvLayout::Paged, algo, precision, reference);
    assert_eq!(
        paged, contig,
        "paged decode diverged from the contiguous oracle \
         ({algo:?}, {precision:?}, reference_kernel={reference})"
    );
}

// ---- full-stream bit-identity: kernel × precision × algorithm --------

#[test]
fn paged_matches_contig_token_int8() {
    assert_layouts_agree(Algo::Token, Precision::Int8, false);
}

#[test]
fn paged_matches_contig_block_int8() {
    assert_layouts_agree(Algo::Block, Precision::Int8, false);
}

#[test]
fn paged_matches_contig_multipath2_int8() {
    assert_layouts_agree(Algo::MultiPath { k: 2 }, Precision::Int8, false);
}

#[test]
fn paged_matches_contig_multipath4_int8() {
    assert_layouts_agree(Algo::MultiPath { k: 4 }, Precision::Int8, false);
}

#[test]
fn paged_matches_contig_tree2_int8() {
    assert_layouts_agree(Algo::Tree { k: 2 }, Precision::Int8, false);
}

#[test]
fn paged_matches_contig_tree4_int8() {
    assert_layouts_agree(Algo::Tree { k: 4 }, Precision::Int8, false);
}

#[test]
fn paged_matches_contig_block_fp32() {
    assert_layouts_agree(Algo::Block, Precision::Fp32, false);
}

#[test]
fn paged_matches_contig_multipath2_fp32() {
    assert_layouts_agree(Algo::MultiPath { k: 2 }, Precision::Fp32, false);
}

#[test]
fn paged_matches_contig_tree2_fp32() {
    assert_layouts_agree(Algo::Tree { k: 2 }, Precision::Fp32, false);
}

#[test]
fn paged_matches_contig_block_reference_kernel() {
    assert_layouts_agree(Algo::Block, Precision::Int8, true);
}

#[test]
fn paged_matches_contig_tree2_reference_kernel() {
    assert_layouts_agree(Algo::Tree { k: 2 }, Precision::Int8, true);
}

// ---- KV-level bit-identity on ragged iterations ----------------------

/// Drive both layouts through identical ragged `spec_iter_rows` streams
/// and compare not just the outputs but the *entire KV rings* after
/// every iteration — the strongest form of the §16 accumulation-order
/// contract (positions never rewritten must match too: the paged zero
/// slab mirrors the contig zero-init).
#[test]
fn ragged_decode_kv_rings_bit_identical() {
    let (b, l) = (4usize, 64usize);
    for algo in [Algo::Block, Algo::MultiPath { k: 2 }, Algo::Tree { k: 2 }] {
        let bc = NativeBackend::seeded_with_shapes(b, l, 0xfeed).with_kv_layout(KvLayout::Contig);
        let bp = NativeBackend::seeded_with_shapes(b, l, 0xfeed).with_kv_layout(KvLayout::Paged);
        bc.prepare(algo, "xxs", Precision::Int8).unwrap();
        bp.prepare(algo, "xxs", Precision::Int8).unwrap();

        let (mut tc, mut lc) = backend_state(b, l);
        let (mut tp, mut lp) = backend_state(b, l);
        let mut kvt_c = bc.prefill("target", &tc, &lc).unwrap();
        let mut kvd_c = bc.prefill("xxs", &tc, &lc).unwrap();
        let mut kvt_p = bp.prefill("target", &tp, &lp).unwrap();
        let mut kvd_p = bp.prefill("xxs", &tp, &lp).unwrap();

        for it in 0..5i32 {
            let gammas: Vec<usize> =
                (0..b).map(|bi| 1 + (it as usize * 7 + bi * 3) % 5).collect();
            let seeds: Vec<i32> = (0..b as i32).map(|bi| it * 977 + 13 + bi * 131).collect();
            let oc = bc
                .spec_iter_rows(algo, "xxs", &gammas, &mut tc, &mut lc, &mut kvt_c, &mut kvd_c, &seeds)
                .unwrap();
            let op = bp
                .spec_iter_rows(algo, "xxs", &gammas, &mut tp, &mut lp, &mut kvt_p, &mut kvd_p, &seeds)
                .unwrap();
            assert_eq!(op.tau, oc.tau, "{algo:?} iter {it}: tau");
            assert_eq!(op.emitted, oc.emitted, "{algo:?} iter {it}: emitted");
            assert_eq!(tp, tc, "{algo:?} iter {it}: token state");
            assert_eq!(lp, lc, "{algo:?} iter {it}: lengths");
            for bi in 0..b {
                assert_eq!(
                    kvt_p.row_snapshot(bi, l),
                    kvt_c.row_snapshot(bi, l),
                    "{algo:?} iter {it}: target KV ring, row {bi}"
                );
                assert_eq!(
                    kvd_p.row_snapshot(bi, l),
                    kvd_c.row_snapshot(bi, l),
                    "{algo:?} iter {it}: drafter KV ring, row {bi}"
                );
            }
        }
    }
}

// ---- splice / extract against the contiguous oracle ------------------

/// `kv_extract` + `kv_splice` at lengths straddling every page-boundary
/// case (page = 16 positions): mid-page, boundary-1, exact boundary,
/// boundary+1, multi-page.  Paged full pages are aliased and only the
/// partial boundary page is copied — the result must still be
/// position-for-position what the contiguous memcpy path produces.
#[test]
fn splice_extract_matches_contig_at_ragged_lengths() {
    let (b, l) = (4usize, 64usize);
    let bc = NativeBackend::seeded_with_shapes(b, l, 0xab1e).with_kv_layout(KvLayout::Contig);
    let bp = NativeBackend::seeded_with_shapes(b, l, 0xab1e).with_kv_layout(KvLayout::Paged);
    let (mut toks, mut lens) = backend_state(b, l);
    // Long source row so extracts read real (non-zero) cache content.
    for (j, t) in (0..40u32).enumerate() {
        toks[l + j] = (vocab::CONTENT_BASE + (t * 7) % 120) as i32;
    }
    toks[l] = vocab::BOS as i32;
    toks[l + 1] = vocab::marker_for(1) as i32;
    lens[1] = 40;
    let kv_c = bc.prefill("target", &toks, &lens).unwrap();
    let kv_p = bp.prefill("target", &toks, &lens).unwrap();

    for len in [1usize, 5, 15, 16, 17, 31, 32, 33, 47] {
        let e_c = bc.kv_extract("target", &kv_c, 1, len).unwrap();
        let e_p = bp.kv_extract("target", &kv_p, 1, len).unwrap();
        assert_eq!(
            e_p.row_snapshot(0, len),
            e_c.row_snapshot(0, len),
            "extract len {len}"
        );

        let mut dst_c = bc.prefill("target", &toks, &lens).unwrap();
        let mut dst_p = bp.prefill("target", &toks, &lens).unwrap();
        bc.kv_splice("target", &mut dst_c, 3, &e_c, 0, len).unwrap();
        bp.kv_splice("target", &mut dst_p, 3, &e_p, 0, len).unwrap();
        for bi in 0..b {
            assert_eq!(
                dst_p.row_snapshot(bi, l),
                dst_c.row_snapshot(bi, l),
                "splice len {len}: full ring of row {bi}"
            );
        }
    }
}

// ---- copy-on-write isolation -----------------------------------------

/// A cloned cache aliases every page of the original; decoding over the
/// original must copy-on-write, never mutate through the shared pages.
#[test]
fn cow_isolates_cloned_caches_from_decode_writes() {
    let (b, l) = (2usize, 64usize);
    let be = NativeBackend::seeded_with_shapes(b, l, 0xc0de).with_kv_layout(KvLayout::Paged);
    be.prepare(Algo::Block, "xxs", Precision::Int8).unwrap();
    let (mut toks, mut lens) = backend_state(b, l);
    let mut kv_t = be.prefill("target", &toks, &lens).unwrap();
    let mut kv_d = be.prefill("xxs", &toks, &lens).unwrap();

    let frozen_t = kv_t.clone();
    let frozen_d = kv_d.clone();
    let snap_t: Vec<_> = (0..b).map(|bi| frozen_t.row_snapshot(bi, l)).collect();
    let snap_d: Vec<_> = (0..b).map(|bi| frozen_d.row_snapshot(bi, l)).collect();
    let cow0 = kvstats::pages_cow();

    let lens0 = lens.clone();
    for it in 0..4i32 {
        let seeds: Vec<i32> = (0..b as i32).map(|bi| it * 31 + bi).collect();
        be.spec_iter(Algo::Block, "xxs", 4, &mut toks, &mut lens, &mut kv_t, &mut kv_d, &seeds)
            .unwrap();
    }
    assert!(
        lens.iter().zip(&lens0).all(|(a, b)| a > b),
        "decode must have advanced every row"
    );
    for bi in 0..b {
        assert_eq!(
            frozen_t.row_snapshot(bi, l),
            snap_t[bi],
            "decode writes leaked into the shared target clone (row {bi})"
        );
        assert_eq!(
            frozen_d.row_snapshot(bi, l),
            snap_d[bi],
            "decode writes leaked into the shared drafter clone (row {bi})"
        );
    }
    assert!(
        kvstats::pages_cow() > cow0,
        "appending into a fully-shared cache must trigger copy-on-write"
    );
}

// ---- dirty-slab recycling --------------------------------------------

/// Slabs recycled through the arena free list carry stale KV from their
/// previous life; alloc-time zeroing must make a decode over recycled
/// pages identical to one on a fresh arena.
#[test]
fn recycled_dirty_slabs_never_leak_into_later_decodes() {
    let mk = || {
        Arc::new(NativeBackend::seeded_with_shapes(2, 96, 0xd127).with_kv_layout(KvLayout::Paged))
    };
    let cfg = EngineConfig {
        gamma: 4,
        max_new_tokens: 8,
        kv_layout: KvLayout::Paged,
        ..Default::default()
    };
    let batch_a: Vec<Vec<u32>> = (0..2).map(|i| prompt(i + 10, 8)).collect();
    let batch_b: Vec<Vec<u32>> = (0..2).map(|i| prompt(i + 20, 12)).collect();

    let warm = SpecEngine::new(mk(), cfg.clone()).unwrap();
    warm.run_batch(&batch_a, 1).unwrap(); // dirty slabs into the free list
    let recycled = warm.run_batch(&batch_b, 2).unwrap();

    let fresh = SpecEngine::new(mk(), cfg).unwrap().run_batch(&batch_b, 2).unwrap();
    let toks = |r: &specd::engine::BatchReport| -> Vec<Vec<u32>> {
        r.rows.iter().map(|x| x.tokens.clone()).collect()
    };
    assert_eq!(
        toks(&recycled),
        toks(&fresh),
        "decode over recycled slabs diverged — stale page state leaked"
    );
}

// ---- page refcount lifecycle -----------------------------------------

#[test]
fn pages_release_when_every_cache_reference_drops() {
    let (b, l) = (2usize, 64usize);
    let be = NativeBackend::seeded_with_shapes(b, l, 0x1ea4).with_kv_layout(KvLayout::Paged);
    assert!(be.is_paged());
    assert!(
        be.kv_arena_stats("target").is_none(),
        "no arena before the model allocates"
    );
    let (toks, lens) = backend_state(b, l);
    let kv = be.prefill("target", &toks, &lens).unwrap();
    let (live1, _) = be.kv_arena_stats("target").unwrap();
    assert!(live1 > 0, "prefill must allocate pages");

    let twin = kv.clone();
    let (live2, _) = be.kv_arena_stats("target").unwrap();
    assert_eq!(live2, live1, "cloning aliases pages, never allocates");

    drop(kv);
    let (live3, _) = be.kv_arena_stats("target").unwrap();
    assert_eq!(live3, live1, "the twin keeps every page live");

    drop(twin);
    let (live4, free4) = be.kv_arena_stats("target").unwrap();
    assert_eq!(live4, 0, "dropping the last reference must release every page");
    assert_eq!(free4, live1, "released slabs recycle through the free list");
}

/// Repeated same-seed decodes must reach a page steady state: whatever
/// persistent scratch the tree path retains, run N+1 may not hold more
/// live pages than run N once warmed up.
#[test]
fn repeated_decodes_reach_page_steady_state() {
    let be =
        Arc::new(NativeBackend::seeded_with_shapes(2, 64, 0x57ab).with_kv_layout(KvLayout::Paged));
    let cfg = EngineConfig {
        gamma: 4,
        algo: Algo::Tree { k: 2 },
        max_new_tokens: 8,
        kv_layout: KvLayout::Paged,
        ..Default::default()
    };
    let eng = SpecEngine::new(be.clone(), cfg).unwrap();
    let prompts: Vec<Vec<u32>> = (0..2).map(|i| prompt(i, 6)).collect();
    eng.run_batch(&prompts, 3).unwrap();
    let (live1, _) = be.kv_arena_stats("target").unwrap();
    eng.run_batch(&prompts, 3).unwrap();
    let (live2, _) = be.kv_arena_stats("target").unwrap();
    eng.run_batch(&prompts, 3).unwrap();
    let (live3, _) = be.kv_arena_stats("target").unwrap();
    assert!(live2 <= live1, "warm run must not grow the live set ({live1} -> {live2})");
    assert_eq!(live3, live2, "same-seed runs must not leak pages ({live2} -> {live3})");
}

// ---- serving tier over both layouts ----------------------------------

/// End-to-end router comparison: one replica, prefix cache on, identical
/// seeded traffic (with a repeated prompt so the second hit takes the
/// warm zero-copy splice path) — paged and contig routers must serve
/// byte-identical streams, and the paged router's `/metrics` must expose
/// the physical-arena gauges the free-list pool cannot.
#[test]
fn router_streams_identical_across_layouts() {
    let spawn = |layout: KvLayout| {
        let be = Arc::new(NativeBackend::seeded(0x707e7).with_kv_layout(layout));
        let cfg = Config::default();
        let ecfg = EngineConfig { max_new_tokens: 8, kv_layout: layout, ..Default::default() };
        let rcfg = RouterConfig { replicas: 1, prefix_cache: true, ..Default::default() };
        Router::spawn(be, ecfg, &cfg.server, &rcfg).unwrap()
    };
    let contig = spawn(KvLayout::Contig);
    let paged = spawn(KvLayout::Paged);

    // 36-token prompt (page-aligned 32-token head is cacheable) issued
    // twice — the second admission splices the cached prefix — plus a
    // distinct short prompt.
    let long = prompt(3, 36);
    let short = prompt(4, 9);
    let reqs =
        vec![(long.clone(), 7u64), (long.clone(), 7u64), (short.clone(), 9u64)];
    for (p, seed) in reqs {
        let c = contig
            .generate(ServeRequest::new(p.clone(), Some(8), Some(seed)))
            .unwrap()
            .tokens;
        let g = paged
            .generate(ServeRequest::new(p, Some(8), Some(seed)))
            .unwrap()
            .tokens;
        assert_eq!(g, c, "router stream diverged between layouts");
    }
    assert!(paged.prefix_stats().hits.get() >= 1, "repeat prompt must hit the cache");

    let pm = paged.render_metrics();
    for line in ["specd_kv_pages_live", "specd_kv_pages_recycled", "specd_kv_bytes_copied_total", "specd_kv_pages_cow_total"] {
        assert!(pm.contains(line), "paged router metrics missing {line}:\n{pm}");
    }
    let cm = contig.render_metrics();
    assert!(
        !cm.contains("specd_kv_pages_live"),
        "free-list pool has no physical pages to report"
    );
}
