//! Multi-draft speculation (DraftSet / `Algo::MultiPath`) properties:
//!
//! 1. `Algo::MultiPath { k: 1 }` reproduces `Algo::Block` token for token
//!    and draw for draw — at the kernel level against the native
//!    backend's published `verify_uniforms` / `multipath_uniforms`
//!    streams, and end to end through the fused engine.
//! 2. Losslessness: the multipath output distribution over the
//!    `sim::chain` Markov pair matches exact target ancestral sampling
//!    within the tolerance `tests/theorems.rs` uses.
//! 3. `sim::exact::expected_tau_multipath(k = 1)` equals
//!    `expected_tau_block`, and more paths never hurt.
//! 4. On the seeded native model, multipath accepts at least as many
//!    draft tokens per target call as block verification on aggregate.
//! 5. The prefix-sharing tree ladder (DESIGN.md §13): `Algo::Tree { k: 1 }`
//!    is `Algo::Block` bit for bit, `Algo::Tree { k }` is
//!    `Algo::MultiPath { k }` bit for bit (sharing and never-share branch
//!    policies alike), tree decoding is lossless, and the tree scores
//!    strictly fewer drafted tokens in expectation.

use std::sync::Arc;

use specd::backend::native::{multipath_uniforms, verify_uniforms};
use specd::backend::NativeBackend;
use specd::config::EngineConfig;
use specd::engine::host::HostVerifyEngine;
use specd::engine::spec::SpecEngine;
use specd::models::vocab;
use specd::sim::{self, MarkovPair};
use specd::stats::empirical::SeqDist;
use specd::util::proptest::{check, rand_instance};
use specd::verify::{self, Algo, Rng};
use specd::workload::Dataset;

/// Satellite property test: `MultiPath { k: 1 }` reproduces `Block`
/// token for token and draw for draw on the native backend's published
/// verification uniforms.
#[test]
fn multipath_k1_reproduces_block_on_published_uniforms() {
    check("multipath k=1 == block (native uniforms)", 300, |rng| {
        let gamma = 1 + rng.below(8);
        let vocab = 2 + rng.below(30);
        let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, 0.8);
        let seed = rng.next_u64() as i32;
        let (etas, u) = verify_uniforms(seed, gamma);
        let (etas_k, u_k) = multipath_uniforms(seed, gamma, 1);
        if etas_k.len() != 1 || etas_k[0] != etas || u_k != u {
            return Err("k=1 multipath uniforms must replay the single-path stream".into());
        }
        let want = verify::verify(Algo::Block, &ps, &qs, &drafts, &etas, u);
        let got = verify::multipath_verify(
            std::slice::from_ref(&ps),
            std::slice::from_ref(&qs),
            std::slice::from_ref(&drafts),
            &etas_k,
            u_k,
        );
        if got.path != 0 || got.tau != want.tau || got.emitted != want.emitted {
            return Err(format!("seed {seed}: {got:?} vs {want:?}"));
        }
        Ok(())
    });
}

/// End-to-end `k = 1` degradation: whole fused-engine decodes agree
/// token for token across seeds and prompts.
#[test]
fn multipath_k1_bit_identical_to_block_end_to_end() {
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| {
            vec![
                vocab::BOS,
                vocab::marker_for(i as u32 % 8),
                vocab::CONTENT_BASE + 5 + i as u32,
                vocab::CONTENT_BASE + 90,
                vocab::CONTENT_BASE + 17 + 3 * i as u32,
            ]
        })
        .collect();
    for seed in [0u64, 7, 0xbeef] {
        let run = |algo: Algo| {
            let be = Arc::new(NativeBackend::seeded_with_shapes(4, 64, 0xcafe));
            let cfg = EngineConfig { algo, gamma: 4, max_new_tokens: 20, ..Default::default() };
            let eng = SpecEngine::new(be, cfg).unwrap();
            eng.run_batch(&prompts, seed).unwrap()
        };
        let a = run(Algo::Block);
        let b = run(Algo::MultiPath { k: 1 });
        assert_eq!(a.device_iterations, b.device_iterations, "seed {seed}: iteration counts");
        for (i, (ra, rb)) in a.rows.iter().zip(&b.rows).enumerate() {
            assert_eq!(ra.tokens, rb.tokens, "seed {seed} row {i}: tokens diverged");
            assert_eq!(ra.accepted, rb.accepted, "seed {seed} row {i}: accepted");
            assert_eq!(ra.iterations, rb.iterations, "seed {seed} row {i}: iterations");
            assert_eq!(ra.finish, rb.finish, "seed {seed} row {i}: finish reason");
        }
    }
}

/// Theorem-1-style losslessness for the joint K-path rule: multipath
/// output prefixes are distributed as target-chain ancestral samples
/// (same tolerance as tests/theorems.rs).
#[test]
fn multipath_lossless_on_markov_pair() {
    let pair = MarkovPair::random(3, 0.5, 11);
    let h = 3;
    let n = 30_000;
    for k in [2usize, 3] {
        let mut spec = SeqDist::default();
        let mut anc = SeqDist::default();
        let mut rng_s = Rng::new(7);
        let mut rng_a = Rng::new(8);
        for _ in 0..n {
            spec.add(&sim::specdec_prefix_multi(&pair, 2, k, h, &mut rng_s));
            anc.add(&sim::sample_target(&pair, h, &mut rng_a));
        }
        let tv = spec.tv(&anc);
        assert!(tv < 0.03, "multipath k={k}: TV {tv}");
    }
}

/// Satellite: the exact multipath expectation at k = 1 equals the
/// Lemma 3 block expectation, on many random pairs.
#[test]
fn expected_tau_multipath_k1_equals_expected_tau_block() {
    check("exact multipath k=1 == block", 30, |rng| {
        let vocab = 2 + rng.below(4);
        let mix = 0.1 + 0.8 * rng.uniform();
        let pair = MarkovPair::random(vocab, mix, rng.next_u64());
        for gamma in 1..=3 {
            let b = sim::exact::expected_tau_block(&pair, gamma);
            let m = sim::exact::expected_tau_multipath(&pair, gamma, 1);
            if (b - m).abs() > 1e-9 {
                return Err(format!("gamma {gamma}: block {b} vs multipath(1) {m}"));
            }
        }
        Ok(())
    });
}

/// The tau-vs-K curve is nondecreasing and dominated by gamma; MC
/// simulation of the full decode agrees with the per-iteration picture
/// qualitatively (block efficiency >= block's within noise).
#[test]
fn multipath_tau_curve_dominates_block() {
    let pair = MarkovPair::random(4, 0.55, 17);
    let gamma = 3;
    let blk = sim::exact::expected_tau_block(&pair, gamma);
    let mut prev = 0.0;
    for k in [1usize, 2, 4, 8] {
        let e = sim::exact::expected_tau_multipath(&pair, gamma, k);
        assert!(e >= prev - 1e-12, "K {k}: {e} < {prev}");
        assert!(e >= blk - 1e-12, "K {k}: {e} < block {blk}");
        assert!(e <= gamma as f64 + 1e-9);
        prev = e;
    }
    let mc_block = sim::simulate(&pair, gamma, Algo::Block, 60_000, 3).mean_tau();
    let mc_mp = sim::simulate_multi(&pair, gamma, 4, 60_000, 3).mean_tau();
    assert!(
        mc_mp >= mc_block - 0.05,
        "full-decode MC: multipath {mc_mp:.3} vs block {mc_block:.3}"
    );
}

/// On the seeded native model, multipath accepts at least as many draft
/// tokens per target call as block on aggregate (finite-sample slack as
/// in tests/native_backend.rs).
#[test]
fn multipath_not_worse_than_block_on_native_aggregate() {
    let be = Arc::new(NativeBackend::seeded(42));
    let prompts = Dataset::synthetic("gsm8k", 6, 0xabc).unwrap().take(6);
    let mut tau_by_algo = Vec::new();
    for algo in [Algo::Block, Algo::MultiPath { k: 2 }] {
        let (mut accepted, mut iters) = (0usize, 0usize);
        for seed in 0..2u64 {
            let cfg = EngineConfig { gamma: 4, algo, max_new_tokens: 16, ..Default::default() };
            let eng = SpecEngine::new(be.clone(), cfg).unwrap();
            for rep in eng.run_prompts(&prompts, seed).unwrap() {
                for row in &rep.rows {
                    accepted += row.accepted;
                    iters += row.iterations;
                }
            }
        }
        tau_by_algo.push(accepted as f64 / iters.max(1) as f64);
    }
    let (blk, mp) = (tau_by_algo[0], tau_by_algo[1]);
    assert!(
        mp >= blk - 0.1,
        "multipath accepted/iter {mp:.3} must not fall below block {blk:.3}"
    );
}

fn ladder_prompts() -> Vec<Vec<u32>> {
    (0..4)
        .map(|i| {
            vec![
                vocab::BOS,
                vocab::marker_for(i as u32 % 8),
                vocab::CONTENT_BASE + 5 + i as u32,
                vocab::CONTENT_BASE + 90,
                vocab::CONTENT_BASE + 17 + 3 * i as u32,
            ]
        })
        .collect()
}

fn run_fused(be: NativeBackend, algo: Algo, seed: u64) -> specd::engine::BatchReport {
    let cfg = EngineConfig { algo, gamma: 4, max_new_tokens: 20, ..Default::default() };
    let eng = SpecEngine::new(Arc::new(be), cfg).unwrap();
    eng.run_batch(&ladder_prompts(), seed).unwrap()
}

fn assert_reports_identical(a: &specd::engine::BatchReport, b: &specd::engine::BatchReport, tag: &str) {
    assert_eq!(a.device_iterations, b.device_iterations, "{tag}: iteration counts");
    for (i, (ra, rb)) in a.rows.iter().zip(&b.rows).enumerate() {
        assert_eq!(ra.tokens, rb.tokens, "{tag} row {i}: tokens diverged");
        assert_eq!(ra.accepted, rb.accepted, "{tag} row {i}: accepted");
        assert_eq!(ra.iterations, rb.iterations, "{tag} row {i}: iterations");
        assert_eq!(ra.finish, rb.finish, "{tag} row {i}: finish reason");
    }
}

/// Bottom rung of the tree ladder: a 1-leaf tree degenerates to block
/// verification, token for token through the fused engine.
#[test]
fn tree_k1_bit_identical_to_block_end_to_end() {
    for seed in [0u64, 7, 0xbeef] {
        let a = run_fused(NativeBackend::seeded_with_shapes(4, 64, 0xcafe), Algo::Block, seed);
        let b =
            run_fused(NativeBackend::seeded_with_shapes(4, 64, 0xcafe), Algo::Tree { k: 1 }, seed);
        assert_reports_identical(&a, &b, &format!("seed {seed} tree:1 vs block"));
    }
}

/// Middle rung: the k-leaf tree reproduces flat multipath bit for bit —
/// with the default share-coincident policy *and* with branching forced
/// off (`with_branch_threshold(inf)`, the degenerate no-sharing tree).
#[test]
fn tree_bit_identical_to_multipath_end_to_end() {
    for k in [2usize, 4] {
        for seed in [0u64, 0xbeef] {
            let m = run_fused(
                NativeBackend::seeded_with_shapes(4, 64, 0xcafe),
                Algo::MultiPath { k },
                seed,
            );
            let t = run_fused(
                NativeBackend::seeded_with_shapes(4, 64, 0xcafe),
                Algo::Tree { k },
                seed,
            );
            assert_reports_identical(&m, &t, &format!("seed {seed} k {k} tree vs multipath"));
            let never = NativeBackend::seeded_with_shapes(4, 64, 0xcafe)
                .with_branch_threshold(f64::INFINITY);
            let t_inf = run_fused(never, Algo::Tree { k }, seed);
            assert_reports_identical(
                &m,
                &t_inf,
                &format!("seed {seed} k {k} never-share tree vs multipath"),
            );
        }
    }
}

/// Theorem-1-style losslessness for tree verification at the
/// distribution level: tree output prefixes match target ancestral
/// samples (same harness and tolerance as the multipath test above).
#[test]
fn tree_lossless_on_markov_pair() {
    let pair = MarkovPair::random(3, 0.5, 11);
    let h = 3;
    let n = 30_000;
    for k in [2usize, 3] {
        let mut spec = SeqDist::default();
        let mut anc = SeqDist::default();
        let mut rng_s = Rng::new(7);
        let mut rng_a = Rng::new(8);
        for _ in 0..n {
            spec.add(&sim::specdec_prefix_tree(&pair, 2, k, h, &mut rng_s));
            anc.add(&sim::sample_target(&pair, h, &mut rng_a));
        }
        let tv = spec.tv(&anc);
        assert!(tv < 0.03, "tree k={k}: TV {tv}");
    }
}

/// Satellite property tests: tree E[tau] never falls below multipath
/// E[tau] (they are equal by dedup-invariance), and the expected scored
/// node count is strictly below the flat `k * gamma` for k >= 2.
#[test]
fn expected_tau_tree_dominates_multipath_and_saves_tokens() {
    check("exact tree tau >= multipath tau; nodes < k*gamma", 30, |rng| {
        let vocab = 2 + rng.below(4);
        let mix = 0.1 + 0.8 * rng.uniform();
        let pair = MarkovPair::random(vocab, mix, rng.next_u64());
        for gamma in 1..=3 {
            for k in [1usize, 2, 4] {
                let t = sim::exact::expected_tau_tree(&pair, gamma, k);
                let m = sim::exact::expected_tau_multipath(&pair, gamma, k);
                if t < m - 1e-12 {
                    return Err(format!("gamma {gamma} k {k}: tree {t} < multipath {m}"));
                }
                let nodes = sim::exact::expected_tree_nodes(&pair, gamma, k);
                if k >= 2 && nodes >= (k * gamma) as f64 - 1e-9 {
                    return Err(format!(
                        "gamma {gamma} k {k}: nodes {nodes} not < {}",
                        k * gamma
                    ));
                }
                if k == 1 && (nodes - gamma as f64).abs() > 1e-9 {
                    return Err(format!("gamma {gamma}: k=1 nodes {nodes} != gamma"));
                }
            }
        }
        Ok(())
    });
}

/// Engine-layer wiring: multipath is fused-only and k must be >= 1.
#[test]
fn multipath_engine_validation() {
    let be = Arc::new(NativeBackend::seeded_with_shapes(2, 32, 5));
    let good = EngineConfig {
        algo: Algo::MultiPath { k: 2 },
        gamma: 4,
        max_new_tokens: 8,
        ..Default::default()
    };
    assert!(SpecEngine::new(be.clone(), good.clone()).is_ok());
    let zero = EngineConfig { algo: Algo::MultiPath { k: 0 }, ..good.clone() };
    assert!(SpecEngine::new(be.clone(), zero).is_err());
    // Same wiring for the tree: fused-only, k >= 1.
    let tree = EngineConfig { algo: Algo::Tree { k: 2 }, ..good.clone() };
    assert!(SpecEngine::new(be.clone(), tree.clone()).is_ok());
    let tree_zero = EngineConfig { algo: Algo::Tree { k: 0 }, ..good.clone() };
    assert!(SpecEngine::new(be.clone(), tree_zero).is_err());
    assert!(HostVerifyEngine::new(be.clone(), tree).is_err());
    // The host-verify engine is single-draft.
    assert!(HostVerifyEngine::new(be, good).is_err());
}
