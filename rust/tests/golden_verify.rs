//! Cross-layer agreement: replay the golden verification vectors produced
//! by the python oracle (artifacts/golden_verify.json) through the rust
//! implementations.  Same explicit uniforms ⇒ identical discrete outcomes
//! and matching acceptance chains.

use specd::util::json;
use specd::verify::{self, GreedyState, ProbMatrix};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = std::path::PathBuf::from(dir);
    p.join("golden_verify.json").exists().then_some(p)
}

#[test]
fn golden_vectors_replay_exactly() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let raw = std::fs::read_to_string(dir.join("golden_verify.json")).unwrap();
    let cases = json::parse(&raw).unwrap();
    let cases = cases.as_arr().unwrap();
    assert!(cases.len() >= 32, "expected a full golden set");
    for (idx, c) in cases.iter().enumerate() {
        let gamma = c.usize_field("gamma").unwrap();
        let vocab = c.usize_field("vocab").unwrap();
        let ps = ProbMatrix::from_flat(gamma + 1, vocab, c.f64_vec("ps").unwrap());
        let qs = ProbMatrix::from_flat(gamma, vocab, c.f64_vec("qs").unwrap());
        let drafts: Vec<u32> =
            c.usize_vec("drafts").unwrap().into_iter().map(|x| x as u32).collect();
        let etas = c.f64_vec("etas").unwrap();
        let u = c.f64_field("u").unwrap();

        // token
        let want = c.field("token").unwrap();
        let got = verify::token_verify(&ps, &qs, &drafts, &etas, u);
        assert_eq!(got.tau, want.usize_field("tau").unwrap(), "case {idx} token tau");
        let want_em: Vec<u32> =
            want.usize_vec("emitted").unwrap().into_iter().map(|x| x as u32).collect();
        assert_eq!(got.emitted, want_em, "case {idx} token emitted");

        // block + chain
        let want = c.field("block").unwrap();
        let got = verify::block_verify(&ps, &qs, &drafts, &etas, u);
        assert_eq!(got.tau, want.usize_field("tau").unwrap(), "case {idx} block tau");
        let want_em: Vec<u32> =
            want.usize_vec("emitted").unwrap().into_iter().map(|x| x as u32).collect();
        assert_eq!(got.emitted, want_em, "case {idx} block emitted");
        let (p, h) = verify::block_chain(&ps, &qs, &drafts);
        for (a, b) in p.iter().zip(want.f64_vec("p").unwrap()) {
            assert!((a - b).abs() < 1e-9, "case {idx} p chain: {a} vs {b}");
        }
        for (a, b) in h.iter().zip(want.f64_vec("h").unwrap()) {
            assert!((a - b).abs() < 1e-9, "case {idx} h chain: {a} vs {b}");
        }

        // greedy with window layers
        let want = c.field("greedy").unwrap();
        let layers_in = want.arr_field("layers_in").unwrap();
        let st = GreedyState {
            layers: layers_in
                .iter()
                .map(|l| {
                    let a = l.as_arr().unwrap();
                    specd::verify::greedy::Layer {
                        remaining: a[0].as_usize().unwrap(),
                        ratio: a[1].as_f64().unwrap(),
                    }
                })
                .collect(),
        };
        let (got, st2) = verify::greedy_verify(&ps, &qs, &drafts, &etas, u, &st);
        assert_eq!(got.tau, want.usize_field("tau").unwrap(), "case {idx} greedy tau");
        let want_em: Vec<u32> =
            want.usize_vec("emitted").unwrap().into_iter().map(|x| x as u32).collect();
        assert_eq!(got.emitted, want_em, "case {idx} greedy emitted");
        let want_layers = want.arr_field("layers_out").unwrap();
        assert_eq!(st2.layers.len(), want_layers.len(), "case {idx} layer count");
        for (gl, wl) in st2.layers.iter().zip(want_layers) {
            let a = wl.as_arr().unwrap();
            assert_eq!(gl.remaining, a[0].as_usize().unwrap(), "case {idx} layer rem");
            assert!(
                (gl.ratio - a[1].as_f64().unwrap()).abs() < 1e-9,
                "case {idx} layer ratio {} vs {}",
                gl.ratio,
                a[1].as_f64().unwrap()
            );
        }
    }
}
