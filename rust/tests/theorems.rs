//! Property tests for the paper's theorems on the distribution-level
//! substrate (no artifacts needed): Theorem 1 (losslessness), Theorem 2
//! (block optimality/dominance), Theorem 3 (greedy per-iteration gain),
//! and the Lemma 8 full-information bound.

use specd::sim::{self, MarkovPair};
use specd::stats::empirical::SeqDist;
use specd::util::proptest::{check, rand_instance};
use specd::verify::{self, Algo, GreedyState, Rng};

/// Theorem 1: SpecDec output prefixes are distributed as target-chain
/// ancestral samples, for all three verification algorithms.
#[test]
fn lossless_all_algorithms() {
    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        let pair = MarkovPair::random(3, 0.5, 11);
        let h = 3;
        let n = 30_000;
        let mut spec = SeqDist::default();
        let mut anc = SeqDist::default();
        let mut rng_s = Rng::new(7);
        let mut rng_a = Rng::new(8);
        for _ in 0..n {
            spec.add(&sim::specdec_prefix(&pair, 2, algo, h, &mut rng_s));
            anc.add(&sim::sample_target(&pair, h, &mut rng_a));
        }
        let tv = spec.tv(&anc);
        assert!(tv < 0.03, "{algo}: TV {tv}");
    }
}

/// Theorem 2 ordering on many random pairs via exact enumeration:
/// E[tau_token] <= E[tau_block] <= full-information bound.
#[test]
fn block_dominates_token_exactly() {
    check("thm2 ordering", 40, |rng| {
        let vocab = 2 + rng.below(4);
        let mix = 0.1 + 0.8 * rng.uniform();
        let pair = MarkovPair::random(vocab, mix, rng.next_u64());
        for gamma in 1..=3 {
            let t = sim::exact::expected_tau_token(&pair, gamma);
            let b = sim::exact::expected_tau_block(&pair, gamma);
            let f = sim::exact::fullinfo_bound(&pair, gamma);
            if b < t - 1e-12 {
                return Err(format!("block {b} < token {t} at gamma {gamma}"));
            }
            if f < b - 1e-12 {
                return Err(format!("bound {f} < block {b} at gamma {gamma}"));
            }
        }
        Ok(())
    });
}

/// The emitted block always has length tau + 1 and stays inside the vocab,
/// for any random instance and any algorithm.
#[test]
fn verify_output_invariants() {
    check("emitted invariants", 300, |rng| {
        let gamma = 1 + rng.below(8);
        let vocab = 2 + rng.below(30);
        let conc = [0.3, 1.0, 3.0][rng.below(3)];
        let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, conc);
        let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
        let u = rng.uniform();
        for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
            let out = verify::verify(algo, &ps, &qs, &drafts, &etas, u);
            if out.emitted.len() != out.tau + 1 {
                return Err(format!("{algo}: len {} tau {}", out.emitted.len(), out.tau));
            }
            if out.emitted.iter().any(|&t| t as usize >= vocab) {
                return Err(format!("{algo}: token out of vocab"));
            }
            if &out.emitted[..out.tau] != &drafts[..out.tau] {
                return Err(format!("{algo}: accepted prefix differs from drafts"));
            }
        }
        Ok(())
    });
}

/// Accepted prefixes must be prefixes of the draft; the block chain is in
/// [0, 1] and h_gamma == p_gamma (Eq. 4 boundary case).
#[test]
fn block_chain_invariants() {
    check("block chain bounds", 300, |rng| {
        let gamma = 1 + rng.below(8);
        let vocab = 2 + rng.below(20);
        let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, 0.8);
        let (p, h) = verify::block_chain(&ps, &qs, &drafts);
        if p[0] != 1.0 {
            return Err("p0 != 1".into());
        }
        for i in 0..=gamma {
            if !(0.0..=1.0 + 1e-12).contains(&p[i]) {
                return Err(format!("p[{i}] = {}", p[i]));
            }
            if !(0.0..=1.0 + 1e-12).contains(&h[i]) {
                return Err(format!("h[{i}] = {}", h[i]));
            }
        }
        if (h[gamma] - p[gamma]).abs() > 1e-12 {
            return Err("h_gamma != p_gamma".into());
        }
        Ok(())
    });
}

/// Theorem 3: from a fresh state, greedy accepts at least as many tokens
/// per iteration as block verification (in expectation).
#[test]
fn greedy_gains_per_iteration() {
    let pair = MarkovPair::random(6, 0.55, 13);
    let gamma = 4;
    let fresh = GreedyState::new(gamma);
    let (mut acc_b, mut acc_g) = (0usize, 0usize);
    let mut rng_b = Rng::new(5);
    let mut rng_g = Rng::new(5);
    for _ in 0..40_000 {
        acc_b += sim::specdec::run_iteration(&pair, None, gamma, Algo::Block, &mut rng_b, &fresh).1;
        acc_g += sim::specdec::run_iteration(&pair, None, gamma, Algo::Greedy, &mut rng_g, &fresh).1;
    }
    assert!(
        acc_g as f64 >= acc_b as f64 * 0.995,
        "greedy {acc_g} < block {acc_b} per fresh iteration"
    );
}

/// The §2 example end-to-end (E0 in DESIGN.md): exact 10/9, 11/9, 12/9.
#[test]
fn motivating_example_numbers() {
    let r = sim::motivating_example(150_000, 3);
    assert!((r.exact_token - 10.0 / 9.0).abs() < 1e-12);
    assert!((r.exact_block - 11.0 / 9.0).abs() < 1e-12);
    assert!((r.exact_ideal - 12.0 / 9.0).abs() < 1e-12);
    assert!((r.mc_token - r.exact_token).abs() < 0.02);
    assert!((r.mc_block - r.exact_block).abs() < 0.02);
}
