//! Property tests for the paper's theorems on the distribution-level
//! substrate (no artifacts needed): Theorem 1 (losslessness), Theorem 2
//! (block optimality/dominance), Theorem 3 (greedy per-iteration gain),
//! and the Lemma 8 full-information bound — plus the engine-level
//! Theorem 1 corollaries of PR 5: the committed-token distribution is
//! unchanged by int8 draft quantisation (DESIGN.md §11.2) and by batching
//! admission prefills (§11.3).

use std::sync::Arc;

use specd::backend::{Backend, NativeBackend, Precision};
use specd::config::EngineConfig;
use specd::engine::spec::{Admission, SpecEngine};
use specd::models::vocab;
use specd::sim::{self, MarkovPair};
use specd::stats::empirical::SeqDist;
use specd::util::proptest::{check, rand_instance};
use specd::verify::{self, dist, Algo, GreedyState, Rng};

/// Theorem 1: SpecDec output prefixes are distributed as target-chain
/// ancestral samples, for all three verification algorithms.
#[test]
fn lossless_all_algorithms() {
    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        let pair = MarkovPair::random(3, 0.5, 11);
        let h = 3;
        let n = 30_000;
        let mut spec = SeqDist::default();
        let mut anc = SeqDist::default();
        let mut rng_s = Rng::new(7);
        let mut rng_a = Rng::new(8);
        for _ in 0..n {
            spec.add(&sim::specdec_prefix(&pair, 2, algo, h, &mut rng_s));
            anc.add(&sim::sample_target(&pair, h, &mut rng_a));
        }
        let tv = spec.tv(&anc);
        assert!(tv < 0.03, "{algo}: TV {tv}");
    }
}

/// Theorem 2 ordering on many random pairs via exact enumeration:
/// E[tau_token] <= E[tau_block] <= full-information bound.
#[test]
fn block_dominates_token_exactly() {
    check("thm2 ordering", 40, |rng| {
        let vocab = 2 + rng.below(4);
        let mix = 0.1 + 0.8 * rng.uniform();
        let pair = MarkovPair::random(vocab, mix, rng.next_u64());
        for gamma in 1..=3 {
            let t = sim::exact::expected_tau_token(&pair, gamma);
            let b = sim::exact::expected_tau_block(&pair, gamma);
            let f = sim::exact::fullinfo_bound(&pair, gamma);
            if b < t - 1e-12 {
                return Err(format!("block {b} < token {t} at gamma {gamma}"));
            }
            if f < b - 1e-12 {
                return Err(format!("bound {f} < block {b} at gamma {gamma}"));
            }
        }
        Ok(())
    });
}

/// The emitted block always has length tau + 1 and stays inside the vocab,
/// for any random instance and any algorithm.
#[test]
fn verify_output_invariants() {
    check("emitted invariants", 300, |rng| {
        let gamma = 1 + rng.below(8);
        let vocab = 2 + rng.below(30);
        let conc = [0.3, 1.0, 3.0][rng.below(3)];
        let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, conc);
        let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
        let u = rng.uniform();
        for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
            let out = verify::verify(algo, &ps, &qs, &drafts, &etas, u);
            if out.emitted.len() != out.tau + 1 {
                return Err(format!("{algo}: len {} tau {}", out.emitted.len(), out.tau));
            }
            if out.emitted.iter().any(|&t| t as usize >= vocab) {
                return Err(format!("{algo}: token out of vocab"));
            }
            if &out.emitted[..out.tau] != &drafts[..out.tau] {
                return Err(format!("{algo}: accepted prefix differs from drafts"));
            }
        }
        Ok(())
    });
}

/// Accepted prefixes must be prefixes of the draft; the block chain is in
/// [0, 1] and h_gamma == p_gamma (Eq. 4 boundary case).
#[test]
fn block_chain_invariants() {
    check("block chain bounds", 300, |rng| {
        let gamma = 1 + rng.below(8);
        let vocab = 2 + rng.below(20);
        let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, 0.8);
        let (p, h) = verify::block_chain(&ps, &qs, &drafts);
        if p[0] != 1.0 {
            return Err("p0 != 1".into());
        }
        for i in 0..=gamma {
            if !(0.0..=1.0 + 1e-12).contains(&p[i]) {
                return Err(format!("p[{i}] = {}", p[i]));
            }
            if !(0.0..=1.0 + 1e-12).contains(&h[i]) {
                return Err(format!("h[{i}] = {}", h[i]));
            }
        }
        if (h[gamma] - p[gamma]).abs() > 1e-12 {
            return Err("h_gamma != p_gamma".into());
        }
        Ok(())
    });
}

/// Theorem 3: from a fresh state, greedy accepts at least as many tokens
/// per iteration as block verification (in expectation).
#[test]
fn greedy_gains_per_iteration() {
    let pair = MarkovPair::random(6, 0.55, 13);
    let gamma = 4;
    let fresh = GreedyState::new(gamma);
    let (mut acc_b, mut acc_g) = (0usize, 0usize);
    let mut rng_b = Rng::new(5);
    let mut rng_g = Rng::new(5);
    for _ in 0..40_000 {
        acc_b += sim::specdec::run_iteration(&pair, None, gamma, Algo::Block, &mut rng_b, &fresh).1;
        acc_g += sim::specdec::run_iteration(&pair, None, gamma, Algo::Greedy, &mut rng_g, &fresh).1;
    }
    assert!(
        acc_g as f64 >= acc_b as f64 * 0.995,
        "greedy {acc_g} < block {acc_b} per fresh iteration"
    );
}

/// Theorem 1 at the engine level under draft quantisation
/// (DESIGN.md §11.2): the committed-token distribution with an **int8**
/// draft matches the target sample distribution, for token, block,
/// multipath (K=2) and prefix-sharing tree (K=2, 4; DESIGN.md §13.4)
/// verification.  Verification corrects any drafter
/// drift, so quantising the drafter must not move the first committed
/// token's law off the target's exact next-token distribution.  An fp32
/// control run with the same sample count calibrates the finite-sample
/// TV noise: the int8 TV must sit inside the control's noise band, not
/// at the drafter-drift scale.
#[test]
fn int8_draft_commits_target_distributed_tokens() {
    const SEED: u64 = 0x7e57;
    const N_RUNS: u64 = 250;
    let prompt: Vec<u32> = vec![vocab::BOS, vocab::marker_for(0), 25, 33, 47];

    // Exact target next-token distribution after the prompt (fp32 target
    // forward — the law every committed first token must follow).
    let be = NativeBackend::seeded_with_shapes(4, 24, SEED);
    let info = be.info().clone();
    let (b, l, v) = (info.batch, info.max_len, info.vocab_size);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        for (j, &t) in prompt.iter().enumerate() {
            toks[bi * l + j] = t as i32;
        }
        lens[bi] = prompt.len() as i32;
    }
    let mut kv = be.prefill("target", &toks, &lens).unwrap();
    let ps = be.target_score(1, &toks, &lens, &mut kv, &vec![20i32; b]).unwrap();
    let mass: f64 = ps[..v].iter().map(|&x| x as f64).sum();
    let exact: Vec<f64> = ps[..v].iter().map(|&x| x as f64 / mass).collect();

    for algo in [
        Algo::Token,
        Algo::Block,
        Algo::MultiPath { k: 2 },
        Algo::Tree { k: 2 },
        Algo::Tree { k: 4 },
    ] {
        let mut tv = [0.0f64; 2];
        for (pi, prec) in [Precision::Int8, Precision::Fp32].into_iter().enumerate() {
            let backend = Arc::new(
                NativeBackend::seeded_with_shapes(4, 24, SEED).with_draft_precision(prec),
            );
            let cfg = EngineConfig {
                algo,
                gamma: 2,
                max_new_tokens: 1,
                draft_precision: prec,
                ..Default::default()
            };
            let engine = SpecEngine::new(backend, cfg).unwrap();
            let mut hist = vec![0u64; v];
            let mut n = 0u64;
            for run in 0..N_RUNS {
                let rep = engine.run_batch(&vec![prompt.clone(); b], 1000 + run).unwrap();
                for row in rep.rows {
                    // EOS truncates `tokens`; fold it back into the
                    // histogram so no probability mass is dropped.
                    let tok = row.tokens.first().copied().unwrap_or(vocab::EOS);
                    hist[(tok as usize).min(v - 1)] += 1;
                    n += 1;
                }
            }
            let emp: Vec<f64> = hist.iter().map(|&c| c as f64 / n as f64).collect();
            tv[pi] = dist::tv_distance(&exact, &emp);
        }
        let (tv_int8, tv_fp32) = (tv[0], tv[1]);
        // The paired bound is the sharp one: both estimators carry the
        // same finite-sample bias (they share batch seeds), so a
        // drafter-biased committed stream would open a gap far above the
        // residual fluctuation.  The absolute bound excludes gross
        // failure even if the control drifts.
        assert!(tv_int8 < 0.25, "{algo}: int8-draft committed TV {tv_int8} vs exact target");
        assert!(
            tv_int8 <= tv_fp32 + 0.05,
            "{algo}: int8 TV {tv_int8} outside the fp32 control's noise band ({tv_fp32})"
        );
    }
}

/// DESIGN.md §11.3: admitting several prompts through one batched
/// `prefill_rows` is bit-identical to admitting them one at a time —
/// same spliced KV rows, same decode stream, token for token.
#[test]
fn batched_prefill_rows_matches_per_row_admissions() {
    let backend = Arc::new(NativeBackend::seeded_with_shapes(4, 48, 0xad31));
    let cfg = EngineConfig { gamma: 4, max_new_tokens: 10, ..Default::default() };
    let engine = SpecEngine::new(backend, cfg).unwrap();
    let prompts: Vec<Vec<u32>> = vec![
        vec![vocab::BOS, vocab::marker_for(0), 21, 35, 44, 50],
        vec![vocab::BOS, vocab::marker_for(1), 60, 61],
        vec![vocab::BOS, vocab::marker_for(2), 77, 78, 79, 80, 81],
    ];
    let admissions: Vec<Admission<'_>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Admission {
            // Non-contiguous slots: 0, 1, 3 (slot 2 stays inert).
            slot: if i == 2 { 3 } else { i },
            prompt: p,
            row_seed: 0x5eed + 13 * i as u64,
        })
        .collect();

    let mut st_batched = engine.begin_stream().unwrap();
    for res in engine.admit_rows(&mut st_batched, &admissions) {
        res.unwrap();
    }
    let mut st_single = engine.begin_stream().unwrap();
    for a in &admissions {
        engine.admit_row(&mut st_single, a.slot, a.prompt, a.row_seed).unwrap();
    }
    for step in 0..6 {
        let x = engine.step_stream(&mut st_batched).unwrap();
        let y = engine.step_stream(&mut st_single).unwrap();
        assert_eq!(x.tau, y.tau, "step {step}: tau diverged");
        assert_eq!(x.emitted, y.emitted, "step {step}: emitted tokens diverged");
        assert_eq!(x.done, y.done, "step {step}: done flags diverged");
    }

    // Per-admission validation rejects bad rows without poisoning the
    // batch: a duplicate slot and an oversized prompt fail, the valid
    // admission in the same batch succeeds.
    let mut st = engine.begin_stream().unwrap();
    let long: Vec<u32> = (0..48).map(|i| vocab::CONTENT_BASE + i).collect();
    let batch = vec![
        Admission { slot: 0, prompt: &prompts[0], row_seed: 1 },
        Admission { slot: 0, prompt: &prompts[1], row_seed: 2 },
        Admission { slot: 1, prompt: &long, row_seed: 3 },
        Admission { slot: 2, prompt: &prompts[2], row_seed: 4 },
    ];
    let res = engine.admit_rows(&mut st, &batch);
    assert!(res[0].is_ok());
    assert!(res[1].is_err(), "duplicate slot must be rejected");
    assert!(res[2].is_err(), "oversized prompt must be rejected");
    assert!(res[3].is_ok(), "valid admission must survive its batch-mates' failures");
    assert!(st.occupied(0) && !st.occupied(1) && st.occupied(2));
}

/// Theorem 1 under *adaptive* speculation (DESIGN.md §15): forcing any
/// per-row gamma / path-count schedule — including adversarial
/// per-iteration switches — through the public forced-schedule hook
/// commits tokens from the same target law as the static configuration.
/// The static and forced arms share row seeds, so the paired TV gap
/// isolates exactly the shape-induced drift (which must be pure
/// finite-sample noise), and both arms must sit on the exact target
/// next-token law.  Runs the fp32 and int8 drafters: quantisation and
/// schedule switches must compose without moving the committed
/// distribution.
#[test]
fn forced_gamma_schedules_commit_target_distributed_tokens() {
    const SEED: u64 = 0x5c4ed;
    const N_RUNS: u64 = 250;
    let prompt: Vec<u32> = vec![vocab::BOS, vocab::marker_for(0), 25, 33, 47];

    // Exact target next-token law after the prompt (fp32 target forward,
    // as in the int8 test above).
    let be = NativeBackend::seeded_with_shapes(4, 24, SEED);
    let info = be.info().clone();
    let (b, l, v) = (info.batch, info.max_len, info.vocab_size);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        for (j, &t) in prompt.iter().enumerate() {
            toks[bi * l + j] = t as i32;
        }
        lens[bi] = prompt.len() as i32;
    }
    let mut kv = be.prefill("target", &toks, &lens).unwrap();
    let ps = be.target_score(1, &toks, &lens, &mut kv, &vec![20i32; b]).unwrap();
    let mass: f64 = ps[..v].iter().map(|&x| x as f64).sum();
    let exact: Vec<f64> = ps[..v].iter().map(|&x| x as f64 / mass).collect();

    // Adversarial per-iteration (gammas, K) schedule: ragged across rows
    // and switching both knobs every iteration (the last entry stays
    // small so three iterations always fit the ring).
    let schedule: [(Vec<usize>, usize); 3] =
        [(vec![2, 5, 3, 6], 2), (vec![6, 2, 5, 3], 1), (vec![1, 2, 1, 2], 2)];

    for algo in [Algo::Block, Algo::MultiPath { k: 2 }, Algo::Tree { k: 2 }] {
        for prec in [Precision::Fp32, Precision::Int8] {
            let backend = Arc::new(
                NativeBackend::seeded_with_shapes(4, 24, SEED).with_draft_precision(prec),
            );
            let cfg = EngineConfig {
                algo,
                gamma: 4,
                max_new_tokens: 8,
                draft_precision: prec,
                ..Default::default()
            };
            let engine = SpecEngine::new(backend, cfg).unwrap();
            // Single-draft algos ignore K; keep the schedule well-typed.
            let ks = |k: usize| if matches!(algo, Algo::Block) { 1 } else { k };
            let mut hist = [vec![0u64; v], vec![0u64; v]]; // [static, forced]
            let mut n = 0u64;
            for run in 0..N_RUNS {
                for (arm, h) in hist.iter_mut().enumerate() {
                    let mut st = engine.begin_stream().unwrap();
                    for slot in 0..b {
                        engine
                            .admit_row(&mut st, slot, &prompt, 0x5eed + run * 31 + slot as u64)
                            .unwrap();
                    }
                    // Same row seeds on both arms: the first iteration's
                    // draws pair exactly, so any TV gap is shape-induced.
                    let out = if arm == 0 {
                        engine.step_stream(&mut st).unwrap()
                    } else {
                        engine
                            .step_stream_rows(&mut st, &schedule[0].0, ks(schedule[0].1))
                            .unwrap()
                    };
                    for i in 0..b {
                        let tok = out.emitted[i * out.stride];
                        h[(tok as usize).min(v - 1)] += 1;
                    }
                    if arm == 1 && run < 8 {
                        // Keep switching shapes: the stream must stay
                        // structurally coherent across the switches.
                        for (gs, k) in schedule[1..].iter() {
                            let o = engine.step_stream_rows(&mut st, gs, ks(*k)).unwrap();
                            for i in 0..b {
                                let tau = o.tau[i] as usize;
                                assert!(tau <= gs[i], "{algo}: tau {tau} > gamma {}", gs[i]);
                                for &t in &o.emitted[i * o.stride..i * o.stride + tau + 1] {
                                    assert!((t as usize) < v, "{algo}: token {t} out of vocab");
                                }
                            }
                        }
                    }
                }
                n += b as u64;
            }
            let tvs: Vec<f64> = hist
                .iter()
                .map(|h| {
                    let emp: Vec<f64> = h.iter().map(|&c| c as f64 / n as f64).collect();
                    dist::tv_distance(&exact, &emp)
                })
                .collect();
            let (tv_static, tv_forced) = (tvs[0], tvs[1]);
            assert!(
                tv_forced < 0.25,
                "{algo}/{prec:?}: forced-schedule committed TV {tv_forced} vs exact target"
            );
            assert!(
                tv_forced <= tv_static + 0.05,
                "{algo}/{prec:?}: forced TV {tv_forced} outside the static arm's noise band \
                 ({tv_static})"
            );
        }
    }
}

/// The adaptive machinery is strictly additive: with `adaptive` disabled
/// (the default), `step_stream` is the pre-existing uniform path, and
/// the forced-schedule hook run at the engine's own (gamma, K)
/// reproduces it bit for bit — same taus, same tokens, same done flags,
/// same stride.
#[test]
fn adaptive_off_is_bit_identical_to_uniform_rows() {
    for algo in [Algo::Block, Algo::MultiPath { k: 2 }] {
        let backend = Arc::new(NativeBackend::seeded_with_shapes(4, 48, 0xb17));
        let cfg = EngineConfig { algo, gamma: 4, max_new_tokens: 12, ..Default::default() };
        let engine = SpecEngine::new(backend, cfg).unwrap();
        assert!(!engine.cfg.adaptive.enabled, "adaptive must default off");
        let prompts: Vec<Vec<u32>> = vec![
            vec![vocab::BOS, vocab::marker_for(0), 21, 35],
            vec![vocab::BOS, vocab::marker_for(1), 60, 61, 62],
            vec![vocab::BOS, vocab::marker_for(2), 77],
            vec![vocab::BOS, vocab::marker_for(3), 80, 81, 82, 83],
        ];
        let mut st_plain = engine.begin_stream().unwrap();
        let mut st_rows = engine.begin_stream().unwrap();
        for (slot, p) in prompts.iter().enumerate() {
            engine.admit_row(&mut st_plain, slot, p, 0xab5 + slot as u64).unwrap();
            engine.admit_row(&mut st_rows, slot, p, 0xab5 + slot as u64).unwrap();
        }
        let uniform = vec![4usize; prompts.len()];
        for step in 0..5 {
            let x = engine.step_stream(&mut st_plain).unwrap();
            let y = engine.step_stream_rows(&mut st_rows, &uniform, algo.paths().max(1)).unwrap();
            assert_eq!(x.stride, 5, "{algo} step {step}: uniform stride is gamma + 1");
            assert_eq!(x.stride, y.stride, "{algo} step {step}: stride diverged");
            assert_eq!(x.tau, y.tau, "{algo} step {step}: tau diverged");
            assert_eq!(x.emitted, y.emitted, "{algo} step {step}: emitted diverged");
            assert_eq!(x.done, y.done, "{algo} step {step}: done flags diverged");
        }
    }
}

/// The §2 example end-to-end (E0 in DESIGN.md): exact 10/9, 11/9, 12/9.
#[test]
fn motivating_example_numbers() {
    let r = sim::motivating_example(150_000, 3);
    assert!((r.exact_token - 10.0 / 9.0).abs() < 1e-12);
    assert!((r.exact_block - 11.0 / 9.0).abs() < 1e-12);
    assert!((r.exact_ideal - 12.0 / 9.0).abs() < 1e-12);
    assert!((r.mc_token - r.exact_token).abs() < 0.02);
    assert!((r.mc_block - r.exact_block).abs() < 0.02);
}
