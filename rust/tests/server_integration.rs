//! End-to-end serving: coordinator + HTTP server + client against the real
//! artifact bundle on a loopback socket.

use std::sync::Arc;

use specd::config::{Config, EngineConfig};
use specd::coordinator::Coordinator;
use specd::runtime::Runtime;
use specd::server::{client, serve, ServerState};
use specd::workload::Dataset;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = std::path::PathBuf::from(dir);
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::load(&p).expect("runtime loads")))
}

#[test]
fn http_generate_roundtrip() {
    let Some(rt) = runtime() else { return };
    let datasets = Dataset::load_all(rt.artifacts_dir()).unwrap();
    let cfg = Config::default();
    let mut ecfg = EngineConfig::default();
    ecfg.max_new_tokens = 12;
    let coordinator = Coordinator::spawn(rt, ecfg, &cfg.server).unwrap();
    let state = Arc::new(ServerState { coordinator, datasets });

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let st = state.clone();
    std::thread::spawn(move || {
        let _ = serve(listener, st);
    });

    // health + metrics before any request
    let (status, body) = client::get(&addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    let (status, _) = client::get(&addr, "/metrics").unwrap();
    assert_eq!(status, 200);

    // three sequential generations (exercises batching with timeouts)
    for seed in 0..3 {
        let resp = client::generate(&addr, "gsm8k", 12, seed).unwrap();
        // n_tokens may be 0 when the model emits EOS immediately; the
        // decode still consumed >= 1 target call and emitted >= 1 token.
        assert_eq!(resp.tokens.len(), resp.n_tokens);
        assert!(resp.block_efficiency >= 1.0);
        assert!(resp.iterations >= 1);
        assert!(resp.latency_ms > 0.0);
    }

    // bad requests are rejected cleanly
    let (status, _) = client::post_json(&addr, "/v1/generate", "{}").unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        client::post_json(&addr, "/v1/generate", r#"{"dataset": "nope"}"#).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client::get(&addr, "/bogus").unwrap();
    assert_eq!(status, 404);

    // metrics reflect the traffic
    let (_, metrics) = client::get(&addr, "/metrics").unwrap();
    assert!(metrics.contains("specd_requests_completed 3"), "{metrics}");
}
