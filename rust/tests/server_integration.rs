//! End-to-end serving: router + HTTP server + client over the hermetic
//! native backend on a loopback socket — the full request path with zero
//! external dependencies and no artifact bundle.

use std::sync::Arc;

use specd::backend::NativeBackend;
use specd::config::{Config, EngineConfig};
use specd::serve::Router;
use specd::server::{client, serve, ServerState};
use specd::workload::Dataset;

#[test]
fn http_generate_roundtrip() {
    let backend = Arc::new(NativeBackend::seeded(0x5e4e));
    let datasets = Dataset::load_or_synthetic(None).unwrap();
    let cfg = Config::default();
    let ecfg = EngineConfig { max_new_tokens: 12, ..Default::default() };
    let router = Router::spawn(backend, ecfg, &cfg.server, &cfg.router).unwrap();
    let state = Arc::new(ServerState { router, datasets });

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let st = state.clone();
    std::thread::spawn(move || {
        let _ = serve(listener, st);
    });

    // health + metrics before any request
    let (status, body) = client::get(&addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    let (status, _) = client::get(&addr, "/metrics").unwrap();
    assert_eq!(status, 200);

    // three sequential generations (exercises batching with timeouts)
    for seed in 0..3 {
        let resp = client::generate(&addr, "gsm8k", 12, seed).unwrap();
        // n_tokens may be 0 when the model emits EOS immediately; the
        // decode still consumed >= 1 target call and emitted >= 1 token.
        assert_eq!(resp.tokens.len(), resp.n_tokens);
        assert!(resp.block_efficiency >= 1.0);
        assert!(resp.iterations >= 1);
        assert!(resp.latency_ms > 0.0);
    }

    // bad requests are rejected cleanly
    let (status, _) = client::post_json(&addr, "/v1/generate", "{}").unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        client::post_json(&addr, "/v1/generate", r#"{"dataset": "nope"}"#).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client::get(&addr, "/bogus").unwrap();
    assert_eq!(status, 404);

    // metrics reflect the traffic: unlabelled aggregates plus the
    // serving-tier exposition (per-replica blocks, shed/pool/prefix
    // counters — DESIGN.md §14.5)
    let (_, metrics) = client::get(&addr, "/metrics").unwrap();
    assert!(metrics.contains("specd_requests_completed 3"), "{metrics}");
    assert!(metrics.contains("specd_slot_occupancy{replica=\"0\"}"), "{metrics}");
    assert!(metrics.contains("specd_requests_shed_total 0"), "{metrics}");
    assert!(metrics.contains("specd_prefix_cache_hits"), "{metrics}");
    assert!(metrics.contains("specd_kv_pages_total"), "{metrics}");
    assert!(metrics.contains("specd_kv_pages_free"), "{metrics}");
}
