//! Exact KV copy/CoW ledger accounting (DESIGN.md §16.3).
//!
//! `kvstats` counters are process-global, so exact *deltas* can only be
//! asserted where nothing else touches the ledger concurrently.  This
//! binary holds a single `#[test]` — cargo gives it its own process and
//! there is no sibling thread to race — which lets it pin the paged
//! layout's central claims as equalities rather than the monotonic
//! lower bounds `tests/paged_kv.rs` has to settle for:
//!
//! * a page-aligned extract/splice moves **zero** KV bytes (pure
//!   page-table aliasing);
//! * an unaligned span copies exactly the boundary positions, nothing
//!   more;
//! * writing through a shared page copies exactly one slab and counts
//!   exactly one CoW;
//! * the contiguous oracle pays the full span for the same operation.

use specd::backend::{kvstats, Backend, KvLayout, NativeBackend};
use specd::models::vocab;

/// 16 positions per page everywhere in the native backend
/// (`DEFAULT_PAGE_POSITIONS` — the router's default page geometry).
const PP: u64 = specd::backend::paged::DEFAULT_PAGE_POSITIONS as u64;

#[test]
fn ledger_counts_exact_bytes_and_cow_pages() {
    let (b, l) = (2usize, 64usize);
    let be = NativeBackend::seeded_with_shapes(b, l, 0x1ed6e).with_kv_layout(KvLayout::Paged);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        toks[bi * l] = vocab::BOS as i32;
        toks[bi * l + 1] = vocab::marker_for(bi as u32) as i32;
        for j in 2..40 {
            toks[bi * l + j] = (vocab::CONTENT_BASE + ((bi * 29 + j * 7) % 150) as u32) as i32;
        }
        lens[bi] = 40;
    }
    let kv = be.prefill("target", &toks, &lens).unwrap();

    // Bytes one cache position occupies across K and V of every layer:
    // the K half of a 1-position snapshot is `n_layers * n_heads *
    // head_dim` floats.
    let (k1, v1) = kv.row_snapshot(0, 1);
    assert_eq!(k1.len(), v1.len());
    let pos_bytes = (k1.len() + v1.len()) as u64 * 4;
    let slab_bytes = PP * pos_bytes;

    // --- page-aligned extract: pure aliasing, zero bytes ---------------
    let b0 = kvstats::bytes_copied();
    let c0 = kvstats::pages_cow();
    let e32 = be.kv_extract("target", &kv, 0, 32).unwrap();
    assert_eq!(
        kvstats::bytes_copied(),
        b0,
        "a page-aligned extract must not copy any KV bytes"
    );
    assert_eq!(kvstats::pages_cow(), c0);
    assert_eq!(e32.row_snapshot(0, 32), kv.row_snapshot(0, 32));

    // --- page-aligned splice into a live cache: still zero -------------
    let mut dst = kv.clone();
    let b1 = kvstats::bytes_copied();
    be.kv_splice("target", &mut dst, 1, &e32, 0, 32).unwrap();
    assert_eq!(
        kvstats::bytes_copied(),
        b1,
        "a page-aligned splice is a page-table clone, not a copy"
    );
    assert_eq!(kvstats::pages_cow(), c0, "retargeting table entries is not a CoW");
    assert_eq!(dst.row_snapshot(1, 32), kv.row_snapshot(0, 32));

    // --- unaligned extract: exactly the boundary positions -------------
    let b2 = kvstats::bytes_copied();
    let e33 = be.kv_extract("target", &kv, 0, 33).unwrap();
    assert_eq!(
        kvstats::bytes_copied(),
        b2 + pos_bytes,
        "extract of 33 = 2 aliased pages + exactly 1 boundary position copied"
    );
    assert_eq!(e33.row_snapshot(0, 33), kv.row_snapshot(0, 33));

    // --- write through a shared page: exactly one slab CoW -------------
    // `dst` row 0 still aliases `kv` row 0's pages (and `e32` aliases
    // page 0 too), so a 1-position splice must first clone that one
    // page, then copy the one position.
    let b3 = kvstats::bytes_copied();
    let c3 = kvstats::pages_cow();
    let twin = dst.clone();
    be.kv_splice("target", &mut dst, 0, &e33, 0, 1).unwrap();
    assert_eq!(kvstats::pages_cow(), c3 + 1, "exactly one page clones on shared-page write");
    assert_eq!(
        kvstats::bytes_copied(),
        b3 + slab_bytes + pos_bytes,
        "one slab clone plus the one spliced position"
    );
    // The twin saw nothing.
    assert_eq!(twin.row_snapshot(0, 40), kv.row_snapshot(0, 40));
    drop(twin);

    // --- contiguous oracle pays the full span --------------------------
    let bc = NativeBackend::seeded_with_shapes(b, l, 0x1ed6e).with_kv_layout(KvLayout::Contig);
    let kv_c = bc.prefill("target", &toks, &lens).unwrap();
    let b4 = kvstats::bytes_copied();
    let e32_c = bc.kv_extract("target", &kv_c, 0, 32).unwrap();
    assert_eq!(
        kvstats::bytes_copied(),
        b4 + 32 * pos_bytes,
        "the contiguous layout physically copies every extracted position"
    );
    // Same content either way — the ledger is the only difference.
    assert_eq!(e32_c.row_snapshot(0, 32), e32.row_snapshot(0, 32));
}
