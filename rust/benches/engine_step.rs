//! End-to-end engine benchmarks over the hermetic native backend:
//! per-iteration latency of the fused spec path vs the baseline step vs
//! the host-verify path.  The paper's wall-clock speedup claims rest on
//! these (EXPERIMENTS.md §Perf).  Set SPECD_ARTIFACTS to bench trained
//! weights instead of the seeded fallback.

use std::sync::Arc;

use specd::backend::{Backend, NativeBackend};
use specd::bench::{fmt_dur, Bench};
use specd::config::EngineConfig;
use specd::engine::baseline::run_baseline_prompts;
use specd::engine::host::HostVerifyEngine;
use specd::engine::spec::SpecEngine;
use specd::verify::Algo;
use specd::workload::Dataset;

fn main() {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend = Arc::new(
        NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0).unwrap(),
    );
    // Canonical bundle prompts when trained weights are in play, synthetic
    // otherwise — keeps the measurement in-distribution either way.
    let datasets =
        Dataset::load_or_synthetic(backend.info().artifacts_dir.as_deref()).unwrap();
    let prompts = datasets.iter().find(|d| d.name == "gsm8k").unwrap().take(4);
    let b = Bench::new(1, 5);

    let mk = |algo: Algo| EngineConfig {
        gamma: 8,
        algo,
        drafter: "xxs".into(),
        max_new_tokens: 32,
        host_verify: !algo.fused(),
        seed: 0,
        ..Default::default()
    };

    // warm up caches/allocators so the timed runs are steady
    let eng = SpecEngine::new(backend.clone(), mk(Algo::Block)).unwrap();
    let _ = eng.run_batch(&prompts, 0).unwrap();

    for algo in [Algo::Token, Algo::Block] {
        let eng = SpecEngine::new(backend.clone(), mk(algo)).unwrap();
        let mut iters = 0usize;
        let mut toks = 0usize;
        let s = b.run(&format!("engine/fused_{algo}_batch4_32tok"), || {
            let rep = eng.run_batch(&prompts, 1).unwrap();
            iters += rep.device_iterations;
            toks += rep.total_tokens();
        });
        let per_iter = s.mean.as_secs_f64() / (iters as f64 / (s.iters + 1) as f64).max(1.0);
        println!(
            "  -> ~{} per fused iteration, {:.1} tok/s",
            fmt_dur(std::time::Duration::from_secs_f64(per_iter)),
            toks as f64 / (s.mean.as_secs_f64() * s.iters as f64).max(1e-9)
        );
    }

    {
        let eng = HostVerifyEngine::new(backend.clone(), mk(Algo::Greedy)).unwrap();
        let _ = eng.run_batch(&prompts, 0).unwrap();
        b.run("engine/host_greedy_batch4_32tok", || {
            let rep = eng.run_batch(&prompts, 1).unwrap();
            std::hint::black_box(rep.total_tokens());
        });
    }

    {
        let _ = run_baseline_prompts(&*backend, &prompts, 32, 0).unwrap();
        b.run("engine/baseline_batch4_32tok", || {
            let rep = run_baseline_prompts(&*backend, &prompts, 32, 1).unwrap();
            std::hint::black_box(rep[0].total_tokens());
        });
    }
}
