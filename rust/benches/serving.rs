//! Serving-path benchmark and CI perf-regression gate.
//!
//! Measures (1) token- vs block-verification throughput/block-efficiency
//! on the fused engine and (2) mixed-length serving throughput under the
//! continuous batcher versus an emulated batch-drain scheduler, then
//! writes `BENCH_ci.json` for CI to archive.  Exit code is non-zero when
//! a perf invariant regresses:
//!
//! * block-verification BE must not drop below token-level BE (the
//!   paper's never-worse guarantee, Theorem 2; 0.05 finite-sample slack);
//! * multipath accepted tokens per target call must not drop below
//!   block's at K in {2, 4} (stage 1 of multipath *is* block
//!   verification, so extra paths can only add; same 0.05 slack);
//! * the prefix-sharing tree must hold acceptance (tau >= flat
//!   multipath's at K in {2, 4} — the two are bit-identical decodes, so
//!   only float-division noise separates them) while scoring no more
//!   drafted tokens per committed token at each K, and strictly fewer on
//!   aggregate — the whole point of sharing (DESIGN.md §13);
//! * the continuous batcher must never need more engine iterations than
//!   batch drain on the mixed-length profile (per-row decodes are
//!   identical under both policies, so earlier admission can only shrink
//!   the makespan; iteration counts are deterministic, so this cannot
//!   flake);
//! * scatter-paged KV (`kv_paging` section, DESIGN.md §16): a prefix-hit
//!   splice of a page-aligned cached prefix must copy **zero** KV bytes
//!   under the paged layout (exact, deterministic — the ledger counters
//!   are read around the op) and be >= 2x faster than the contiguous
//!   span copy; the warm decode streams must match bit-for-bit across
//!   layouts.  Full warm-admission latency is reported per layout but
//!   not wall-gated: the suffix forward dominates it identically in both
//!   layouts, so the speedup lives in the splice component.
//!
//! `--smoke` shrinks the workload for CI; `cargo bench --bench serving --
//! --smoke`.

use std::sync::Arc;
use std::time::Instant;

use specd::backend::{kvstats, Backend, KvLayout, NativeBackend};
use specd::config::{AdaptiveConfig, EngineConfig};
use specd::engine::spec::{Admission, PrefixHandle, SpecEngine};
use specd::models::vocab;
use specd::util::json;
use specd::verify::{Algo, Rng};
use specd::workload::Dataset;

/// One mixed-length request: a prompt plus its own generation cap.
struct Req {
    prompt: Vec<u32>,
    max_new: usize,
}

/// Decode `reqs` through the continuous-stream engine API under one of
/// two scheduling policies, returning (generated tokens, engine
/// iterations).  `drain == true` emulates the retired batch-drain
/// coordinator: admissions only happen when every slot is free.
fn run_policy(engine: &SpecEngine<NativeBackend>, reqs: &[Req], drain: bool) -> (usize, usize) {
    let b = engine.backend().info().batch;
    let mut st = engine.begin_stream().unwrap();
    // Per-slot remaining budget; None = slot free.
    let mut budget: Vec<Option<usize>> = vec![None; b];
    let mut next = 0usize;
    let mut tokens = 0usize;
    let mut iters = 0usize;
    loop {
        let all_free = budget.iter().all(|s| s.is_none());
        if (!drain || all_free) && next < reqs.len() {
            for slot in 0..b {
                if budget[slot].is_none() && next < reqs.len() {
                    let r = &reqs[next];
                    engine.admit_row(&mut st, slot, &r.prompt, 0xbe9c4 + next as u64).unwrap();
                    budget[slot] = Some(r.max_new);
                    next += 1;
                }
            }
        }
        if budget.iter().all(|s| s.is_none()) {
            break;
        }
        let out = engine.step_stream(&mut st).unwrap();
        iters += 1;
        for slot in 0..b {
            let Some(remaining) = budget[slot] else { continue };
            let tau = out.tau[slot] as usize;
            let emitted = &out.emitted[slot * out.stride..slot * out.stride + tau + 1];
            let mut left = remaining;
            let mut finished = out.done[slot] != 0;
            for &t in emitted {
                if t as u32 == vocab::EOS {
                    finished = true;
                    break;
                }
                tokens += 1;
                left -= 1;
                if left == 0 {
                    finished = true;
                    break;
                }
            }
            if finished {
                engine.release_row(&mut st, slot);
                budget[slot] = None;
            } else {
                budget[slot] = Some(left);
            }
        }
    }
    (tokens, iters)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_prompts, max_new, n_seeds) = if smoke { (8, 16, 1u64) } else { (24, 32, 2u64) };
    let backend = Arc::new(NativeBackend::seeded(0xbe9c4));
    let datasets = Dataset::load_or_synthetic(None)?;
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for name in ["gsm8k", "wmt", "xsum"] {
        let ds = datasets.iter().find(|d| d.name == name).expect("dataset");
        prompts.extend(ds.take(n_prompts / 3 + 1));
    }
    prompts.truncate(n_prompts);

    // ---- 1) verification algorithms: BE + accepted/iter + tokens/sec ----
    // (BE, tok/s, mean accepted tau per target call, drafted-per-committed)
    let algos = [
        Algo::Token,
        Algo::Block,
        Algo::MultiPath { k: 2 },
        Algo::MultiPath { k: 4 },
        Algo::Tree { k: 2 },
        Algo::Tree { k: 4 },
    ];
    let mut stats: Vec<(f64, f64, f64, f64)> = Vec::new();
    for algo in algos {
        let cfg = EngineConfig { algo, max_new_tokens: max_new, ..Default::default() };
        let engine = SpecEngine::new(backend.clone(), cfg)?;
        // Warm-up pass, then timed seeds.
        let _ = engine.run_prompts(&prompts[..prompts.len().min(4)], 0)?;
        // Drafted tokens scored (SpecIterOut::drafted) accrue on the
        // engine metrics; delta over the timed region gives the
        // speculation cost of exactly these decodes.
        let drafted0 = engine.metrics.drafts_scored.get();
        let (mut emitted, mut iters, mut toks, mut accepted) = (0usize, 0usize, 0usize, 0usize);
        let t0 = Instant::now();
        for seed in 0..n_seeds {
            for rep in engine.run_prompts(&prompts, seed)? {
                toks += rep.total_tokens();
                for row in &rep.rows {
                    emitted += row.emitted;
                    iters += row.iterations;
                    accepted += row.accepted;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let drafted = (engine.metrics.drafts_scored.get() - drafted0) as f64;
        let be = emitted as f64 / iters.max(1) as f64;
        let tau = accepted as f64 / iters.max(1) as f64;
        let tps = toks as f64 / wall.max(1e-9);
        let dpc = drafted / (emitted as f64).max(1.0);
        let label = algo.to_string();
        println!(
            "verify/{label:<12}  BE {be:>6.3}  tau {tau:>6.3}  drafted/committed {dpc:>6.3}  \
             {tps:>9.1} tok/s"
        );
        stats.push((be, tps, tau, dpc));
    }
    let (token_be, token_tps, _, _) = stats[0];
    let (block_be, block_tps, block_tau, _) = stats[1];
    let (mp2_be, _, mp2_tau, mp2_dpc) = stats[2];
    let (mp4_be, _, mp4_tau, mp4_dpc) = stats[3];
    let (tree2_be, _, tree2_tau, tree2_dpc) = stats[4];
    let (tree4_be, _, tree4_tau, tree4_dpc) = stats[5];

    // ---- 2) mixed-length serving: continuous vs emulated batch drain ----
    // Caps cycle short/medium/long so freed slots matter.
    let caps = [4usize, max_new, 4, 8, 4, max_new / 2];
    let reqs: Vec<Req> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Req { prompt: p.clone(), max_new: caps[i % caps.len()] })
        .collect();
    let cfg = EngineConfig { algo: Algo::Block, max_new_tokens: max_new, ..Default::default() };
    let engine = SpecEngine::new(backend.clone(), cfg)?;
    let _ = run_policy(&engine, &reqs[..reqs.len().min(4)], false); // warm-up
    let t0 = Instant::now();
    let (drain_tokens, drain_iters) = run_policy(&engine, &reqs, true);
    let drain_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let (cont_tokens, cont_iters) = run_policy(&engine, &reqs, false);
    let cont_wall = t0.elapsed().as_secs_f64();
    let drain_tps = drain_tokens as f64 / drain_wall.max(1e-9);
    let cont_tps = cont_tokens as f64 / cont_wall.max(1e-9);
    println!(
        "serving/drain       {drain_tps:>9.1} tok/s  ({drain_tokens} tokens, {drain_iters} iters)"
    );
    println!(
        "serving/continuous  {cont_tps:>9.1} tok/s  ({cont_tokens} tokens, {cont_iters} iters)"
    );
    println!(
        "serving/speedup     {:.2}x wall, {:.2}x fewer iterations",
        cont_tps / drain_tps.max(1e-9),
        drain_iters as f64 / cont_iters.max(1) as f64
    );

    // ---- 3) adaptive controller vs best static gamma (CI gate) ----------
    // Heterogeneous mix: "easy" prompts are a short repeating motif the
    // seeded drafter tracks closely (high acceptance), "hard" prompts are
    // fresh high-entropy token salad (low acceptance).  No single static
    // gamma suits both, which is exactly the regime the per-row controller
    // exists for.  The gate scores committed tokens per unit *work* under
    // the same pinned cost model the controller optimises (work =
    // r * drafted_steps + target row-forwards, r = 0.25, DESIGN.md §15):
    // committed tokens are identical across arms (gamma never changes the
    // output distribution) and drafted/iteration counts are deterministic
    // on the seeded backend, so this gate cannot flake.  Wall-clock tok/s
    // is reported for the trajectory but not gated.
    let span = (vocab::SIZE - vocab::CONTENT_BASE) as usize;
    let mut hard_rng = Rng::new(0xada9717e);
    let n_mix = if smoke { 8 } else { 16 };
    let mix: Vec<Req> = (0..n_mix)
        .map(|i| {
            let prompt: Vec<u32> = if i % 2 == 0 {
                (0..12).map(|j| vocab::CONTENT_BASE + (j % 3) as u32).collect()
            } else {
                (0..12).map(|_| vocab::CONTENT_BASE + hard_rng.below(span) as u32).collect()
            };
            Req { prompt, max_new: if i % 2 == 0 { max_new } else { max_new / 2 } }
        })
        .collect();
    let run_arm = |cfg: EngineConfig| -> anyhow::Result<(f64, f64, usize)> {
        let engine = SpecEngine::new(backend.clone(), cfg)?;
        let drafted0 = engine.metrics.drafts_scored.get();
        let t0 = Instant::now();
        let (tokens, iters) = run_policy(&engine, &mix, false);
        let wall = t0.elapsed().as_secs_f64();
        let drafted = (engine.metrics.drafts_scored.get() - drafted0) as f64;
        let rows = engine.backend().info().batch;
        let work = 0.25 * drafted + (iters * rows) as f64;
        Ok((tokens as f64 / work.max(1e-9), tokens as f64 / wall.max(1e-9), tokens))
    };
    let mut static_cells: Vec<(String, json::Value)> = Vec::new();
    let (mut best_static_tpw, mut best_static_g, mut best_static_tps) = (f64::MIN, 0usize, 0.0);
    let mut static_toks = 0usize;
    for g in [2usize, 4, 8] {
        let cfg = EngineConfig {
            algo: Algo::Block,
            gamma: g,
            max_new_tokens: max_new,
            ..Default::default()
        };
        let (tpw, tps, toks) = run_arm(cfg)?;
        println!("adaptive/static:{g:<2}   tok/work {tpw:>7.4}  {tps:>9.1} tok/s  ({toks} tokens)");
        static_cells.push((format!("static{g}_tok_per_work"), json::num(tpw)));
        static_cells.push((format!("static{g}_tps"), json::num(tps)));
        if tpw > best_static_tpw {
            (best_static_tpw, best_static_g, best_static_tps) = (tpw, g, tps);
        }
        static_toks = toks; // identical across arms: gamma is lossless
    }
    let adaptive_cfg = EngineConfig {
        algo: Algo::Block,
        gamma: 4,
        max_new_tokens: max_new,
        adaptive: AdaptiveConfig {
            enabled: true,
            window: 16,
            min_window: 2,
            gamma_min: 2,
            gamma_max: 8,
            hysteresis: 0.05,
            cost_ratio: Some(0.25),
        },
        ..Default::default()
    };
    let (adaptive_tpw, adaptive_tps, adaptive_toks) = run_arm(adaptive_cfg)?;
    println!(
        "adaptive/controller  tok/work {adaptive_tpw:>7.4}  {adaptive_tps:>9.1} tok/s  \
         ({adaptive_toks} tokens; best static gamma={best_static_g} at {best_static_tpw:.4})"
    );

    // ---- 4) scatter-paged KV: zero-copy prefix sharing (DESIGN.md §16) --
    // Per-layout arm: (a) isolate the prefix-hit splice — the exact op a
    // prefix-cache hit performs per model — and read the global copy
    // ledger around it (this process is single-threaded, so the deltas
    // are exact); (b) run warm prefixed admissions end-to-end and decode
    // the admitted rows, for admission latency and KV bytes copied per
    // committed token.
    struct PagingArm {
        splice_us: f64,
        prefix_bytes_per_hit: u64,
        admission_us: f64,
        bytes_per_token: f64,
        stream: Vec<u32>,
    }
    let page = specd::backend::paged::DEFAULT_PAGE_POSITIONS;
    let prefix_len = 2 * page; // page-aligned: the zero-copy case
    let mut warm_prompt = vec![vocab::BOS, vocab::marker_for(2)];
    while warm_prompt.len() < prefix_len + 4 {
        warm_prompt.push(vocab::CONTENT_BASE + (warm_prompt.len() as u32 * 11) % 180);
    }
    let warm_reps = if smoke { 24usize } else { 96 };
    let run_paging = |layout: KvLayout| -> anyhow::Result<PagingArm> {
        let be = Arc::new(NativeBackend::seeded(0xbe9c4).with_kv_layout(layout));
        let cfg =
            EngineConfig { max_new_tokens: 8, kv_layout: layout, ..Default::default() };
        let engine = SpecEngine::new(be.clone(), cfg)?;
        let (kv_t, kv_d) = engine.prefill_prefix(&warm_prompt[..prefix_len])?;
        let info = be.info();
        let (b, l) = (info.batch, info.max_len);

        // (a) prefix-hit splice, isolated from the suffix forward.
        let mut ptoks = vec![vocab::PAD as i32; b * l];
        let mut plens = vec![0i32; b];
        for bi in 0..b {
            ptoks[bi * l] = vocab::BOS as i32;
            ptoks[bi * l + 1] = vocab::marker_for(0) as i32;
            plens[bi] = 2;
        }
        let mut live_t = be.prefill("target", &ptoks, &plens)?;
        let mut live_d = be.prefill("xxs", &ptoks, &plens)?;
        let b0 = kvstats::bytes_copied();
        let t0 = Instant::now();
        for i in 0..warm_reps {
            let slot = i % b;
            be.kv_splice("target", &mut live_t, slot, &kv_t, 0, prefix_len)?;
            be.kv_splice("xxs", &mut live_d, slot, &kv_d, 0, prefix_len)?;
        }
        let splice_us = t0.elapsed().as_secs_f64() * 1e6 / warm_reps as f64;
        let prefix_bytes_per_hit = (kvstats::bytes_copied() - b0) / warm_reps as u64;

        // (b) warm admissions + decode: latency and bytes per token.
        let bytes0 = kvstats::bytes_copied();
        let mut admit_wall = 0.0f64;
        let mut committed = 0usize;
        let mut stream: Vec<u32> = Vec::new();
        for rep in 0..warm_reps {
            let mut st = engine.begin_stream()?;
            let admissions = [Admission { slot: 0, prompt: &warm_prompt, row_seed: 7 }];
            let prefixes = [Some(PrefixHandle::<NativeBackend> {
                kv_target: &kv_t,
                kv_drafter: &kv_d,
                len: prefix_len,
            })];
            let t0 = Instant::now();
            for r in engine.admit_rows_prefixed(&mut st, &admissions, &prefixes) {
                r?;
            }
            admit_wall += t0.elapsed().as_secs_f64();
            let mut got = 0usize;
            'row: for _ in 0..200 {
                let out = engine.step_stream(&mut st)?;
                let tau = out.tau[0] as usize;
                for &t in &out.emitted[..tau + 1] {
                    if t as u32 == vocab::EOS {
                        break 'row;
                    }
                    if rep == 0 {
                        stream.push(t as u32);
                    }
                    got += 1;
                    if got >= 8 {
                        break 'row;
                    }
                }
                if out.done[0] != 0 {
                    break;
                }
            }
            engine.release_row(&mut st, 0);
            committed += got;
        }
        Ok(PagingArm {
            splice_us,
            prefix_bytes_per_hit,
            admission_us: admit_wall * 1e6 / warm_reps as f64,
            bytes_per_token: (kvstats::bytes_copied() - bytes0) as f64
                / committed.max(1) as f64,
            stream,
        })
    };
    let paged_arm = run_paging(KvLayout::Paged)?;
    let contig_arm = run_paging(KvLayout::Contig)?;
    let splice_speedup = contig_arm.splice_us / paged_arm.splice_us.max(1e-9);
    let admission_speedup = contig_arm.admission_us / paged_arm.admission_us.max(1e-9);
    println!(
        "kv_paging/paged     splice {:>8.2} us/hit  {} prefix bytes/hit  admission \
         {:>8.1} us  {:>8.1} bytes/token",
        paged_arm.splice_us,
        paged_arm.prefix_bytes_per_hit,
        paged_arm.admission_us,
        paged_arm.bytes_per_token
    );
    println!(
        "kv_paging/contig    splice {:>8.2} us/hit  {} prefix bytes/hit  admission \
         {:>8.1} us  {:>8.1} bytes/token",
        contig_arm.splice_us,
        contig_arm.prefix_bytes_per_hit,
        contig_arm.admission_us,
        contig_arm.bytes_per_token
    );
    println!(
        "kv_paging/speedup   {splice_speedup:.1}x prefix-hit splice, \
         {admission_speedup:.2}x warm admission"
    );

    // ---- write BENCH_ci.json --------------------------------------------
    let cells = vec![
        ("smoke", json::Value::Bool(smoke)),
        ("token_be", json::num(token_be)),
        ("block_be", json::num(block_be)),
        ("token_tps", json::num(token_tps)),
        ("block_tps", json::num(block_tps)),
        ("block_tau", json::num(block_tau)),
        ("multipath2_be", json::num(mp2_be)),
        ("multipath2_tau", json::num(mp2_tau)),
        ("multipath2_dpc", json::num(mp2_dpc)),
        ("multipath4_be", json::num(mp4_be)),
        ("multipath4_tau", json::num(mp4_tau)),
        ("multipath4_dpc", json::num(mp4_dpc)),
        ("tree2_be", json::num(tree2_be)),
        ("tree2_tau", json::num(tree2_tau)),
        ("tree2_dpc", json::num(tree2_dpc)),
        ("tree4_be", json::num(tree4_be)),
        ("tree4_tau", json::num(tree4_tau)),
        ("tree4_dpc", json::num(tree4_dpc)),
        ("drain_tps", json::num(drain_tps)),
        ("continuous_tps", json::num(cont_tps)),
        ("drain_iters", json::num(drain_iters as f64)),
        ("continuous_iters", json::num(cont_iters as f64)),
        ("adaptive_tok_per_work", json::num(adaptive_tpw)),
        ("adaptive_tps", json::num(adaptive_tps)),
        ("adaptive_tokens", json::num(adaptive_toks as f64)),
        ("adaptive_best_static_gamma", json::num(best_static_g as f64)),
        ("adaptive_best_static_tok_per_work", json::num(best_static_tpw)),
        ("adaptive_best_static_tps", json::num(best_static_tps)),
        ("adaptive_vs_best_static", json::num(adaptive_tpw / best_static_tpw.max(1e-12))),
    ];
    let mut report = json::obj(cells);
    if let json::Value::Obj(map) = &mut report {
        for (k, v) in static_cells {
            map.insert(k, v);
        }
    }
    specd::bench::merge_section("BENCH_ci.json", "serving", report)?;
    println!("merged section 'serving' into BENCH_ci.json");

    let paging_report = json::obj(vec![
        ("smoke", json::Value::Bool(smoke)),
        ("prefix_len", json::num(prefix_len as f64)),
        ("warm_reps", json::num(warm_reps as f64)),
        ("paged_prefix_splice_us", json::num(paged_arm.splice_us)),
        ("contig_prefix_splice_us", json::num(contig_arm.splice_us)),
        ("prefix_splice_speedup", json::num(splice_speedup)),
        ("paged_prefix_bytes_per_hit", json::num(paged_arm.prefix_bytes_per_hit as f64)),
        ("contig_prefix_bytes_per_hit", json::num(contig_arm.prefix_bytes_per_hit as f64)),
        ("paged_admission_us", json::num(paged_arm.admission_us)),
        ("contig_admission_us", json::num(contig_arm.admission_us)),
        ("admission_speedup", json::num(admission_speedup)),
        ("paged_bytes_per_committed_token", json::num(paged_arm.bytes_per_token)),
        ("contig_bytes_per_committed_token", json::num(contig_arm.bytes_per_token)),
    ]);
    specd::bench::merge_section("BENCH_ci.json", "kv_paging", paging_report)?;
    println!("merged section 'kv_paging' into BENCH_ci.json");

    // ---- CI gates --------------------------------------------------------
    let mut failed = false;
    if block_be < token_be - 0.05 {
        eprintln!(
            "PERF REGRESSION: block-verification BE {block_be:.3} fell below \
             token-level BE {token_be:.3}"
        );
        failed = true;
    }
    for (label, tau) in [("multipath:2", mp2_tau), ("multipath:4", mp4_tau)] {
        if tau < block_tau - 0.05 {
            eprintln!(
                "PERF REGRESSION: {label} accepted/iter {tau:.3} fell below \
                 block's {block_tau:.3} — extra draft paths must never hurt"
            );
            failed = true;
        }
    }
    // Tree gates (DESIGN.md §13): acceptance must match flat multipath
    // (bit-identical decodes; 1e-9 absorbs the float division), and the
    // tree must never score *more* drafted tokens per committed token at
    // either K — with a strict saving on aggregate, since sharing any
    // coincident prefix anywhere in the run scores it once instead of
    // K times.
    for (label, tree_tau, mp_tau, tree_dpc, mp_dpc) in [
        ("tree:2", tree2_tau, mp2_tau, tree2_dpc, mp2_dpc),
        ("tree:4", tree4_tau, mp4_tau, tree4_dpc, mp4_dpc),
    ] {
        if tree_tau < mp_tau - 1e-9 {
            eprintln!(
                "PERF REGRESSION: {label} accepted/iter {tree_tau:.6} fell below flat \
                 multipath's {mp_tau:.6} — sharing must not change acceptance"
            );
            failed = true;
        }
        if tree_dpc > mp_dpc + 1e-9 {
            eprintln!(
                "PERF REGRESSION: {label} drafted/committed {tree_dpc:.4} exceeds flat \
                 multipath's {mp_dpc:.4} — the tree may never score extra tokens"
            );
            failed = true;
        }
    }
    if tree2_dpc + tree4_dpc >= mp2_dpc + mp4_dpc {
        eprintln!(
            "PERF REGRESSION: tree scored as many drafted tokens as flat multipath \
             (tree {:.4} vs flat {:.4} aggregate drafted/committed) — prefix sharing \
             is not engaging",
            tree2_dpc + tree4_dpc,
            mp2_dpc + mp4_dpc
        );
        failed = true;
    }
    if cont_iters > drain_iters {
        eprintln!(
            "PERF REGRESSION: continuous batching used {cont_iters} iterations, \
             batch drain only {drain_iters} — slot refill is hurting"
        );
        failed = true;
    }
    // Adaptive gate: on the easy/hard mix the controller must at least
    // match the best static gamma on tokens-per-unit-work.  2% slack
    // absorbs the controller's warm-up iterations (it starts from the
    // prior until `min_window` observations land); both sides of the
    // ratio are deterministic, so any real regression trips this.
    if adaptive_tpw < best_static_tpw * 0.98 {
        eprintln!(
            "PERF REGRESSION: adaptive controller {adaptive_tpw:.4} tok/work fell below \
             best static gamma={best_static_g} at {best_static_tpw:.4} (>2% gap)"
        );
        failed = true;
    }
    // Losslessness cross-check (cheap, deterministic): the controller may
    // only change *when* tokens commit, never *what* commits.
    if adaptive_toks != static_toks {
        eprintln!(
            "PERF REGRESSION: adaptive run committed {adaptive_toks} tokens but the \
             static arms committed {static_toks} — gamma schedule leaked into the output"
        );
        failed = true;
    }
    // Scatter-paged KV gates (DESIGN.md §16).  The zero-bytes and
    // stream-identity gates are exact and deterministic; the splice
    // speedup gate is wall-clock but the true ratio is a page-table
    // clone vs a multi-KB span memcpy (orders of magnitude), so 2x has
    // enormous margin.
    if paged_arm.prefix_bytes_per_hit != 0 {
        eprintln!(
            "PERF REGRESSION: a paged prefix-hit splice copied {} KV bytes — a \
             page-aligned prefix must be pure page-table aliasing",
            paged_arm.prefix_bytes_per_hit
        );
        failed = true;
    }
    if splice_speedup < 2.0 {
        eprintln!(
            "PERF REGRESSION: paged prefix-hit splice only {splice_speedup:.2}x faster \
             than the contiguous span copy (contig {:.2} us vs paged {:.2} us; >= 2x \
             required)",
            contig_arm.splice_us, paged_arm.splice_us
        );
        failed = true;
    }
    if paged_arm.stream != contig_arm.stream {
        eprintln!(
            "PERF REGRESSION: warm prefixed decode diverged between KV layouts — the \
             paged arena broke bit-identity"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "perf gates passed: block BE >= token BE, multipath tau >= block tau (K=2,4), \
         tree tau >= multipath tau with strictly fewer drafted tokens per committed \
         token (K=2,4), continuous <= drain iterations, adaptive >= best static gamma \
         on tokens-per-work with identical committed tokens, paged prefix hits copy \
         zero prefix KV bytes at >= 2x the contiguous splice speed with bit-identical \
         streams"
    );
    Ok(())
}
