//! Theorem-2 harness benchmarks: exact enumeration vs Monte-Carlo cost of
//! estimating E[tau] for both algorithms (E7 in DESIGN.md), plus the §2
//! motivating example regeneration speed, plus the adaptive controller's
//! oracle-replay regret gate (DESIGN.md §15): replay the controller
//! against a known piecewise-constant acceptance trace and require its
//! cumulative objective to stay within 10% of the best *fixed* (gamma, K)
//! chosen in hindsight.  The replay is fully deterministic (seeded
//! acceptance draws, deterministic controller), so the gate cannot flake.
//!
//! `--smoke` shrinks the replay for CI; the regret gate runs either way
//! and exits non-zero when it trips.

use specd::bench::{self, Bench};
use specd::config::AdaptiveConfig;
use specd::control::{self, Controller};
use specd::sim::{self, MarkovPair};
use specd::util::json;
use specd::verify::{Algo, Rng};

/// True token-acceptance of the replay trace at `step`: alternating
/// "easy" and "hard" phases, the regime shift the controller must chase.
const EASY_ALPHA: f64 = 0.9;
const HARD_ALPHA: f64 = 0.3;

fn replay_alpha(step: usize, phase_len: usize) -> f64 {
    if (step / phase_len) % 2 == 0 {
        EASY_ALPHA
    } else {
        HARD_ALPHA
    }
}

/// Replay the controller against the known trace; return `(regret,
/// ctrl_value, best_fixed_value, best_fixed_gamma, steps)`.  Each step
/// scores the arm the controller picked with [`control::objective`]
/// evaluated at the *true* alpha — the controller only ever sees the
/// noisy tau observations, exactly as in production.
fn oracle_replay(smoke: bool) -> (f64, f64, f64, usize, usize) {
    let (steps, phase_len) = if smoke { (400, 50) } else { (2000, 100) };
    let cfg = AdaptiveConfig {
        enabled: true,
        window: 16,
        min_window: 2,
        gamma_min: 1,
        gamma_max: 8,
        hysteresis: 0.05,
        cost_ratio: Some(0.25),
    };
    let r = 0.25;
    // True per-arm step values, precomputed once per (phase, gamma).
    let value = |alpha: f64, g: usize| control::objective(Algo::Block, alpha, r, g, 1);
    let easy: Vec<f64> = (0..=cfg.gamma_max).map(|g| value(EASY_ALPHA, g.max(1))).collect();
    let hard: Vec<f64> = (0..=cfg.gamma_max).map(|g| value(HARD_ALPHA, g.max(1))).collect();
    let g_hi = cfg.gamma_max;
    let mut ctrl = Controller::new(cfg, 4, Algo::Block);
    let mut rng = Rng::new(0x0eac1e9e9);
    let mut ctrl_value = 0.0;
    for t in 0..steps {
        let alpha = replay_alpha(t, phase_len);
        let d = ctrl.choose(64);
        ctrl_value += if alpha == EASY_ALPHA { easy[d.gamma] } else { hard[d.gamma] };
        // Token-chain acceptance draw: tau consecutive accepts at the
        // true alpha, capped by the gamma the controller actually ran.
        let mut tau = 0usize;
        while tau < d.gamma && rng.uniform() < alpha {
            tau += 1;
        }
        ctrl.observe(tau, d.gamma);
    }
    let easy_steps = (0..steps).filter(|&t| replay_alpha(t, phase_len) == EASY_ALPHA).count();
    let hard_steps = steps - easy_steps;
    let (mut best_fixed, mut best_g) = (f64::MIN, 1usize);
    for g in 1..=g_hi {
        let v = easy_steps as f64 * easy[g] + hard_steps as f64 * hard[g];
        if v > best_fixed {
            (best_fixed, best_g) = (v, g);
        }
    }
    let regret = 1.0 - ctrl_value / best_fixed.max(1e-12);
    (regret, ctrl_value, best_fixed, best_g, steps)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // ---- adaptive controller: oracle-replay regret gate ------------------
    let (regret, ctrl_value, best_fixed, best_g, steps) = oracle_replay(smoke);
    println!(
        "replay/adaptive      regret {:.2}%  (controller {ctrl_value:.1} vs best fixed \
         gamma={best_g} at {best_fixed:.1} over {steps} steps)",
        regret * 100.0
    );
    bench::merge_section(
        "BENCH_ci.json",
        "adaptive_replay",
        json::obj(vec![
            ("replay_smoke", json::Value::Bool(smoke)),
            ("replay_steps", json::num(steps as f64)),
            ("replay_regret", json::num(regret)),
            ("replay_ctrl_value", json::num(ctrl_value)),
            ("replay_best_fixed_value", json::num(best_fixed)),
            ("replay_best_fixed_gamma", json::num(best_g as f64)),
        ]),
    )
    .expect("merge adaptive_replay section into BENCH_ci.json");
    println!("merged section 'adaptive_replay' into BENCH_ci.json");
    if regret > 0.10 {
        eprintln!(
            "PERF REGRESSION: oracle-replay regret {:.2}% exceeds the 10% bound \
             against the best fixed gamma",
            regret * 100.0
        );
        std::process::exit(1);
    }
    if smoke {
        // CI smoke stops at the gate; the enumeration/MC benches below
        // are for the full perf run.
        return;
    }

    let b = Bench::new(2, 8);
    let pair = MarkovPair::random(4, 0.6, 5);

    for gamma in [2, 3, 4] {
        b.run(&format!("exact/enumeration_v4_g{gamma}"), || {
            std::hint::black_box(sim::exact::expected_tau_block(&pair, gamma));
            std::hint::black_box(sim::exact::expected_tau_token(&pair, gamma));
            std::hint::black_box(sim::exact::fullinfo_bound(&pair, gamma));
        });
    }

    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        b.run(&format!("mc/simulate_{algo}_20k_tokens"), || {
            std::hint::black_box(sim::simulate(&pair, 4, algo, 20_000, 1).mean_tau());
        });
    }

    for k in [2usize, 4] {
        b.run(&format!("exact/multipath_v4_g4_k{k}"), || {
            std::hint::black_box(sim::exact::expected_tau_multipath(&pair, 4, k));
        });
        b.run(&format!("mc/simulate_multipath_k{k}_20k_tokens"), || {
            std::hint::black_box(sim::simulate_multi(&pair, 4, k, 20_000, 1).mean_tau());
        });
    }

    b.run("motivating_example_100k", || {
        let r = sim::motivating_example(100_000, 3);
        std::hint::black_box(r.mc_block);
    });

    // Theorem 2 gap across drafter quality (Figure-4-style series on the
    // simulator substrate).
    println!("\nTheorem 2 gap (exact), vocab=4, gamma=4:");
    for mix in [0.2, 0.4, 0.6, 0.8, 0.95] {
        let p = MarkovPair::random(4, mix, 9);
        let t = sim::exact::expected_tau_token(&p, 4);
        let bl = sim::exact::expected_tau_block(&p, 4);
        let f = sim::exact::fullinfo_bound(&p, 4);
        println!(
            "  mix {mix:.2}: token {t:.4}  block {bl:.4}  bound {f:.4}  gain {:+.2}%",
            (bl - t) / t * 100.0
        );
    }

    // Multi-draft dimension: the tau-vs-K curve (exact), K = 1 being
    // plain block verification.  Note K > 1 may exceed the Lemma 8 bound
    // — that bound is per *single* draft.
    println!("\nMulti-draft tau vs K (exact), vocab=4, gamma=4:");
    let blk = sim::exact::expected_tau_block(&pair, 4);
    for k in [1usize, 2, 4, 8] {
        let m = sim::exact::expected_tau_multipath(&pair, 4, k);
        println!(
            "  K {k}: multipath {m:.4}  (block {blk:.4}, gain {:+.2}%)",
            (m - blk) / blk * 100.0
        );
    }

    for k in [2usize, 4] {
        b.run(&format!("exact/tree_nodes_v4_g4_k{k}"), || {
            std::hint::black_box(sim::exact::expected_tree_nodes(&pair, 4, k));
        });
        b.run(&format!("mc/simulate_tree_k{k}_20k_tokens"), || {
            std::hint::black_box(sim::simulate_tree(&pair, 4, k, 20_000, 1).mean_tau());
        });
    }

    // Prefix-sharing tree (DESIGN.md §13): identical tau to multipath at
    // every K (dedup-invariance), but strictly fewer drafted tokens
    // scored — the flat cost is K*gamma, the tree's is the expected
    // distinct-prefix count.
    println!("\nTree vs multipath (exact), vocab=4, gamma=4:");
    for k in [1usize, 2, 4, 8] {
        let mp = sim::exact::expected_tau_multipath(&pair, 4, k);
        let tr = sim::exact::expected_tau_tree(&pair, 4, k);
        let nodes = sim::exact::expected_tree_nodes(&pair, 4, k);
        let flat = (k * 4) as f64;
        println!(
            "  K {k}: tau tree {tr:.4} / multipath {mp:.4}  scored tree {nodes:.3} / flat \
             {flat:.0}  ({:+.1}% tokens)",
            (nodes - flat) / flat * 100.0
        );
    }
}
