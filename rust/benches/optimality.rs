//! Theorem-2 harness benchmarks: exact enumeration vs Monte-Carlo cost of
//! estimating E[tau] for both algorithms (E7 in DESIGN.md), plus the §2
//! motivating example regeneration speed.

use specd::bench::Bench;
use specd::sim::{self, MarkovPair};
use specd::verify::Algo;

fn main() {
    let b = Bench::new(2, 8);
    let pair = MarkovPair::random(4, 0.6, 5);

    for gamma in [2, 3, 4] {
        b.run(&format!("exact/enumeration_v4_g{gamma}"), || {
            std::hint::black_box(sim::exact::expected_tau_block(&pair, gamma));
            std::hint::black_box(sim::exact::expected_tau_token(&pair, gamma));
            std::hint::black_box(sim::exact::fullinfo_bound(&pair, gamma));
        });
    }

    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        b.run(&format!("mc/simulate_{algo}_20k_tokens"), || {
            std::hint::black_box(sim::simulate(&pair, 4, algo, 20_000, 1).mean_tau());
        });
    }

    for k in [2usize, 4] {
        b.run(&format!("exact/multipath_v4_g4_k{k}"), || {
            std::hint::black_box(sim::exact::expected_tau_multipath(&pair, 4, k));
        });
        b.run(&format!("mc/simulate_multipath_k{k}_20k_tokens"), || {
            std::hint::black_box(sim::simulate_multi(&pair, 4, k, 20_000, 1).mean_tau());
        });
    }

    b.run("motivating_example_100k", || {
        let r = sim::motivating_example(100_000, 3);
        std::hint::black_box(r.mc_block);
    });

    // Theorem 2 gap across drafter quality (Figure-4-style series on the
    // simulator substrate).
    println!("\nTheorem 2 gap (exact), vocab=4, gamma=4:");
    for mix in [0.2, 0.4, 0.6, 0.8, 0.95] {
        let p = MarkovPair::random(4, mix, 9);
        let t = sim::exact::expected_tau_token(&p, 4);
        let bl = sim::exact::expected_tau_block(&p, 4);
        let f = sim::exact::fullinfo_bound(&p, 4);
        println!(
            "  mix {mix:.2}: token {t:.4}  block {bl:.4}  bound {f:.4}  gain {:+.2}%",
            (bl - t) / t * 100.0
        );
    }

    // Multi-draft dimension: the tau-vs-K curve (exact), K = 1 being
    // plain block verification.  Note K > 1 may exceed the Lemma 8 bound
    // — that bound is per *single* draft.
    println!("\nMulti-draft tau vs K (exact), vocab=4, gamma=4:");
    let blk = sim::exact::expected_tau_block(&pair, 4);
    for k in [1usize, 2, 4, 8] {
        let m = sim::exact::expected_tau_multipath(&pair, 4, k);
        println!(
            "  K {k}: multipath {m:.4}  (block {blk:.4}, gain {:+.2}%)",
            (m - blk) / blk * 100.0
        );
    }

    for k in [2usize, 4] {
        b.run(&format!("exact/tree_nodes_v4_g4_k{k}"), || {
            std::hint::black_box(sim::exact::expected_tree_nodes(&pair, 4, k));
        });
        b.run(&format!("mc/simulate_tree_k{k}_20k_tokens"), || {
            std::hint::black_box(sim::simulate_tree(&pair, 4, k, 20_000, 1).mean_tau());
        });
    }

    // Prefix-sharing tree (DESIGN.md §13): identical tau to multipath at
    // every K (dedup-invariance), but strictly fewer drafted tokens
    // scored — the flat cost is K*gamma, the tree's is the expected
    // distinct-prefix count.
    println!("\nTree vs multipath (exact), vocab=4, gamma=4:");
    for k in [1usize, 2, 4, 8] {
        let mp = sim::exact::expected_tau_multipath(&pair, 4, k);
        let tr = sim::exact::expected_tau_tree(&pair, 4, k);
        let nodes = sim::exact::expected_tree_nodes(&pair, 4, k);
        let flat = (k * 4) as f64;
        println!(
            "  K {k}: tau tree {tr:.4} / multipath {mp:.4}  scored tree {nodes:.3} / flat \
             {flat:.0}  ({:+.1}% tokens)",
            (nodes - flat) / flat * 100.0
        );
    }
}
