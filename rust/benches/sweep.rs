//! Regenerates paper Figures 3 and 4 (gamma × drafter sweep of average BE
//! and wall-clock speedup + relative-improvement series) at bench scale
//! over the native backend (E2/E3 in DESIGN.md).  Runs hermetically; set
//! SPECD_ARTIFACTS for trained weights.  Knobs: SPECD_BENCH_PROMPTS /
//! SPECD_BENCH_SEEDS.

use std::sync::Arc;

use specd::backend::NativeBackend;
use specd::config::ExperimentConfig;
use specd::experiments::Harness;

fn main() {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend = Arc::new(
        NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0).unwrap(),
    );
    let prompts = std::env::var("SPECD_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let seeds = std::env::var("SPECD_BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(1u64);
    let cfg = ExperimentConfig {
        prompts_per_dataset: prompts,
        seeds: (0..seeds).collect(),
        max_new_tokens: 32,
    };
    let h = Harness::new(backend, cfg).unwrap().quiet();
    let t0 = std::time::Instant::now();
    println!("{}", h.fig3().unwrap());
    println!("{}", h.fig4().unwrap());
    println!("bench sweep: fig3+fig4 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
