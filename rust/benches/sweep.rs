//! Regenerates paper Figures 3 and 4 (gamma × drafter sweep of average BE
//! and wall-clock speedup + relative-improvement series) at bench scale
//! (E2/E3 in DESIGN.md).  Knobs: SPECD_BENCH_PROMPTS / SPECD_BENCH_SEEDS.

use std::sync::Arc;

use specd::config::ExperimentConfig;
use specd::experiments::Harness;
use specd::runtime::Runtime;

fn main() {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = std::path::PathBuf::from(dir);
    if !p.join("manifest.json").exists() {
        eprintln!("skipping sweep bench: artifacts not built");
        return;
    }
    let prompts = std::env::var("SPECD_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let seeds = std::env::var("SPECD_BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(1u64);
    let rt = Arc::new(Runtime::load(&p).unwrap());
    let cfg = ExperimentConfig {
        prompts_per_dataset: prompts,
        seeds: (0..seeds).collect(),
        max_new_tokens: 32,
    };
    let h = Harness::new(rt, cfg).unwrap().quiet();
    let t0 = std::time::Instant::now();
    println!("{}", h.fig3().unwrap());
    println!("{}", h.fig4().unwrap());
    println!("bench sweep: fig3+fig4 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
