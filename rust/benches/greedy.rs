//! Regenerates paper Table 3 (token vs block vs greedy block efficiency,
//! gamma=8, xxs drafter) at bench scale over the native backend (E4 in
//! DESIGN.md), plus the simulator-level comparison across drafter
//! quality.  Runs hermetically; set SPECD_ARTIFACTS for trained weights.

use std::sync::Arc;

use specd::backend::NativeBackend;
use specd::config::ExperimentConfig;
use specd::experiments::Harness;
use specd::sim::{self, MarkovPair};
use specd::verify::Algo;

fn main() {
    // Simulator side first (no model forward passes at all).
    println!("Simulator: per-iteration vs end-to-end greedy behaviour (gamma=4):");
    for mix in [0.4, 0.7, 0.9] {
        let pair = MarkovPair::random(8, mix, 7);
        let t = sim::simulate(&pair, 4, Algo::Token, 60_000, 1).block_efficiency();
        let b = sim::simulate(&pair, 4, Algo::Block, 60_000, 1).block_efficiency();
        let g = sim::simulate(&pair, 4, Algo::Greedy, 60_000, 1).block_efficiency();
        println!("  mix {mix:.2}: token {t:.3}  block {b:.3}  greedy {g:.3}");
    }

    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend = Arc::new(
        NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0).unwrap(),
    );
    let prompts = std::env::var("SPECD_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let cfg = ExperimentConfig {
        prompts_per_dataset: prompts,
        seeds: vec![0],
        max_new_tokens: 32,
    };
    let h = Harness::new(backend, cfg).unwrap().quiet();
    let t0 = std::time::Instant::now();
    println!("{}", h.table3().unwrap());
    println!("bench greedy: table3 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
