//! L3 hot-path microbenchmarks: the three verification algorithms at the
//! production shape (gamma=8, V=256), plus the allocation-free scratch
//! variant used by the host-verify engine (EXPERIMENTS.md §Perf).
//!
//! Runs in the CI `perf-native` job with `--smoke` (fewer reps) and
//! **appends** its per-op nanoseconds to `BENCH_native.json` under a
//! `"verify_hot"` object — merging with whatever `benches/native_fast.rs`
//! already wrote, so the archived perf-trajectory file carries both the
//! wall-clock gates and the verify-kernel microbench in one artifact.

use specd::backend::kernels::{active_isa, matmul_ref, matmul_simd, Isa, PackedF32};
use specd::bench::Bench;
use specd::util::json;
use specd::util::proptest::rand_instance;
use specd::verify::{self, Algo, BlockScratch, GreedyState, Rng};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, samples, n_instances) = if smoke { (1, 5, 24) } else { (3, 15, 64) };
    let mut rng = Rng::new(42);
    let gamma = 8;
    let vocab = 256;
    let instances: Vec<_> =
        (0..n_instances).map(|_| rand_instance(&mut rng, gamma, vocab, 0.8)).collect();
    let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
    let b = Bench::new(warmup, samples);
    let mut results: Vec<(String, f64)> = Vec::new();

    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        let s = b.run_n(&format!("verify/{algo}/g8_v256"), instances.len(), || {
            for (ps, qs, drafts) in &instances {
                let out = verify::verify(algo, ps, qs, drafts, &etas, 0.37);
                std::hint::black_box(out.tau);
            }
        });
        results.push((format!("{algo}_ns"), s.mean.as_nanos() as f64));
    }

    // scratch (allocation-free) block verification
    let mut scratch = BlockScratch::new(gamma, vocab);
    let mut emitted = Vec::with_capacity(gamma + 1);
    let s = b.run_n("verify/block_scratch/g8_v256", instances.len(), || {
        for (ps, qs, drafts) in &instances {
            let tau = scratch.verify(ps, qs, drafts, &etas, 0.37, &mut emitted);
            std::hint::black_box(tau);
        }
    });
    results.push(("block_scratch_ns".into(), s.mean.as_nanos() as f64));

    // greedy with an active window layer (worst-case composite rebuild)
    let st = GreedyState {
        layers: vec![specd::verify::Layer { remaining: 4, ratio: 0.7 }],
    };
    let s = b.run_n("verify/greedy_windowed/g8_v256", instances.len(), || {
        for (ps, qs, drafts) in &instances {
            let (out, _) = verify::greedy_verify(ps, qs, drafts, &etas, 0.37, &st);
            std::hint::black_box(out.tau);
        }
    });
    results.push(("greedy_windowed_ns".into(), s.mean.as_nanos() as f64));

    // ---- kernel microbench: GEMM shape sweep -----------------------------
    // Reference vs SIMD per-call nanoseconds across the model shapes a
    // forward actually runs (qkv/wo at d×d, MLP at d×4d, plus a tail
    // shape), so a kernel regression is attributable separately from the
    // engine cells in `benches/native_fast.rs`.
    let scalar_isa = active_isa() == Isa::Scalar;
    results.push(("kernel_isa_scalar".into(), scalar_isa as u64 as f64));
    for (t, d_in, d_out) in
        [(1usize, 64usize, 64usize), (8, 128, 128), (8, 128, 512), (9, 128, 509)]
    {
        let x: Vec<f32> = (0..t * d_in).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let w: Vec<f32> =
            (0..d_in * d_out).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        let pk = PackedF32::pack(&w, d_in, d_out);
        let mut out = vec![0.0f32; t * d_out];
        let s = b.run_n(&format!("kernel/ref/t{t}_i{d_in}_o{d_out}"), 1, || {
            out.fill(0.0);
            matmul_ref(&x, &w, &mut out, t, d_in, d_out);
            std::hint::black_box(out[0]);
        });
        results.push((format!("gemm_ref_t{t}_i{d_in}_o{d_out}_ns"), s.mean.as_nanos() as f64));
        let s = b.run_n(&format!("kernel/simd/t{t}_i{d_in}_o{d_out}"), 1, || {
            out.fill(0.0);
            matmul_simd(&x, &pk, &mut out, t, d_in, d_out);
            std::hint::black_box(out[0]);
        });
        results.push((format!("gemm_simd_t{t}_i{d_in}_o{d_out}_ns"), s.mean.as_nanos() as f64));
    }

    // ---- append to BENCH_native.json -------------------------------------
    // Merge into the existing report (native_fast writes it first in CI);
    // start a fresh object when the file is absent or unparsable.
    let mut top = std::fs::read_to_string("BENCH_native.json")
        .ok()
        .and_then(|raw| json::parse(&raw).ok())
        .and_then(|v| v.as_obj().cloned())
        .unwrap_or_default();
    let hot = json::obj(
        results.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect::<Vec<_>>(),
    );
    top.insert("verify_hot".into(), hot);
    std::fs::write("BENCH_native.json", json::to_string(&json::Value::Obj(top)))
        .expect("writing BENCH_native.json");
    println!("appended verify_hot numbers to BENCH_native.json");
}
