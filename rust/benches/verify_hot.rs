//! L3 hot-path microbenchmarks: the three verification algorithms at the
//! production shape (gamma=8, V=256), plus the allocation-free scratch
//! variant used by the host-verify engine (EXPERIMENTS.md §Perf).

use specd::bench::Bench;
use specd::util::proptest::rand_instance;
use specd::verify::{self, Algo, BlockScratch, GreedyState, Rng};

fn main() {
    let mut rng = Rng::new(42);
    let gamma = 8;
    let vocab = 256;
    let instances: Vec<_> =
        (0..64).map(|_| rand_instance(&mut rng, gamma, vocab, 0.8)).collect();
    let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
    let b = Bench::new(3, 15);

    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        b.run_n(&format!("verify/{algo}/g8_v256"), instances.len(), || {
            for (ps, qs, drafts) in &instances {
                let out = verify::verify(algo, ps, qs, drafts, &etas, 0.37);
                std::hint::black_box(out.tau);
            }
        });
    }

    // scratch (allocation-free) block verification
    let mut scratch = BlockScratch::new(gamma, vocab);
    let mut emitted = Vec::with_capacity(gamma + 1);
    b.run_n("verify/block_scratch/g8_v256", instances.len(), || {
        for (ps, qs, drafts) in &instances {
            let tau = scratch.verify(ps, qs, drafts, &etas, 0.37, &mut emitted);
            std::hint::black_box(tau);
        }
    });

    // greedy with an active window layer (worst-case composite rebuild)
    let st = GreedyState {
        layers: vec![specd::verify::Layer { remaining: 4, ratio: 0.7 }],
    };
    b.run_n("verify/greedy_windowed/g8_v256", instances.len(), || {
        for (ps, qs, drafts) in &instances {
            let (out, _) = verify::greedy_verify(ps, qs, drafts, &etas, 0.37, &st);
            std::hint::black_box(out.tau);
        }
    });
}
