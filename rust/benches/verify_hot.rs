//! L3 hot-path microbenchmarks: the three verification algorithms at the
//! production shape (gamma=8, V=256), plus the allocation-free scratch
//! variant used by the host-verify engine (EXPERIMENTS.md §Perf).
//!
//! Runs in the CI `perf-native` job with `--smoke` (fewer reps) and
//! **appends** its per-op nanoseconds to `BENCH_native.json` under a
//! `"verify_hot"` object — merging with whatever `benches/native_fast.rs`
//! already wrote, so the archived perf-trajectory file carries both the
//! wall-clock gates and the verify-kernel microbench in one artifact.

use specd::bench::Bench;
use specd::util::json;
use specd::util::proptest::rand_instance;
use specd::verify::{self, Algo, BlockScratch, GreedyState, Rng};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, samples, n_instances) = if smoke { (1, 5, 24) } else { (3, 15, 64) };
    let mut rng = Rng::new(42);
    let gamma = 8;
    let vocab = 256;
    let instances: Vec<_> =
        (0..n_instances).map(|_| rand_instance(&mut rng, gamma, vocab, 0.8)).collect();
    let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
    let b = Bench::new(warmup, samples);
    let mut results: Vec<(String, f64)> = Vec::new();

    for algo in [Algo::Token, Algo::Block, Algo::Greedy] {
        let s = b.run_n(&format!("verify/{algo}/g8_v256"), instances.len(), || {
            for (ps, qs, drafts) in &instances {
                let out = verify::verify(algo, ps, qs, drafts, &etas, 0.37);
                std::hint::black_box(out.tau);
            }
        });
        results.push((format!("{algo}_ns"), s.mean.as_nanos() as f64));
    }

    // scratch (allocation-free) block verification
    let mut scratch = BlockScratch::new(gamma, vocab);
    let mut emitted = Vec::with_capacity(gamma + 1);
    let s = b.run_n("verify/block_scratch/g8_v256", instances.len(), || {
        for (ps, qs, drafts) in &instances {
            let tau = scratch.verify(ps, qs, drafts, &etas, 0.37, &mut emitted);
            std::hint::black_box(tau);
        }
    });
    results.push(("block_scratch_ns".into(), s.mean.as_nanos() as f64));

    // greedy with an active window layer (worst-case composite rebuild)
    let st = GreedyState {
        layers: vec![specd::verify::Layer { remaining: 4, ratio: 0.7 }],
    };
    let s = b.run_n("verify/greedy_windowed/g8_v256", instances.len(), || {
        for (ps, qs, drafts) in &instances {
            let (out, _) = verify::greedy_verify(ps, qs, drafts, &etas, 0.37, &st);
            std::hint::black_box(out.tau);
        }
    });
    results.push(("greedy_windowed_ns".into(), s.mean.as_nanos() as f64));

    // ---- append to BENCH_native.json -------------------------------------
    // Merge into the existing report (native_fast writes it first in CI);
    // start a fresh object when the file is absent or unparsable.
    let mut top = std::fs::read_to_string("BENCH_native.json")
        .ok()
        .and_then(|raw| json::parse(&raw).ok())
        .and_then(|v| v.as_obj().cloned())
        .unwrap_or_default();
    let hot = json::obj(
        results.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect::<Vec<_>>(),
    );
    top.insert("verify_hot".into(), hot);
    std::fs::write("BENCH_native.json", json::to_string(&json::Value::Obj(top)))
        .expect("writing BENCH_native.json");
    println!("appended verify_hot numbers to BENCH_native.json");
}
