//! Regenerates paper Table 1 (gamma=8, xxs drafter, 8 datasets, TokenV vs
//! BlockV, block efficiency + wall-clock speedup) at bench scale over the
//! native backend and reports the wall time of the whole harness (E1 in
//! DESIGN.md).  Runs hermetically; set SPECD_ARTIFACTS for trained
//! weights.
//!
//! Scale knobs: SPECD_BENCH_PROMPTS (default 8), SPECD_BENCH_SEEDS (1).

use std::sync::Arc;

use specd::backend::NativeBackend;
use specd::config::ExperimentConfig;
use specd::experiments::Harness;

fn main() {
    let dir = std::env::var("SPECD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend = Arc::new(
        NativeBackend::from_artifacts_or_seeded(std::path::Path::new(&dir), 0).unwrap(),
    );
    let prompts = std::env::var("SPECD_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let seeds = std::env::var("SPECD_BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(1u64);
    let cfg = ExperimentConfig {
        prompts_per_dataset: prompts,
        seeds: (0..seeds).collect(),
        max_new_tokens: 32,
    };
    let h = Harness::new(backend, cfg).unwrap().quiet();
    let t0 = std::time::Instant::now();
    let table = h.table1().unwrap();
    println!("{table}");
    println!("bench table1: regenerated in {:.1}s ({prompts} prompts x {seeds} seeds)", t0.elapsed().as_secs_f64());
}
