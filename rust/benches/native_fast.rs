//! Native fast-path benchmark and CI wall-clock perf gate (DESIGN.md
//! §10).
//!
//! Measures end-to-end engine tokens/sec on two configurations of the
//! native backend decoding the same prompts with the same seeds:
//!
//! * **scalar reference** — the pre-fast-path configuration: scalar
//!   matmul kernel, single-threaded forward, per-iteration multipath
//!   scratch allocation;
//! * **fast path** — blocked register-tiled matmul, row-parallel forward
//!   on the fixed thread pool, persistent `(B·K)`-row multipath scratch.
//!
//! Both are swept over token/block verification and multipath K in
//! {1, 2, 4}; every cell decodes bit-identical tokens (the two
//! configurations differ only in wall-clock — test-enforced by
//! `tests/native_fast.rs`), so the throughput ratio isolates exactly the
//! kernel + threading + scratch delta.  Results land in
//! `BENCH_native.json` for CI to archive.  Exit code is non-zero when a
//! perf invariant regresses:
//!
//! * fast-path block-verification throughput must be at least 1.5x the
//!   scalar reference (the tentpole's headline gate);
//! * block-verification BE must not drop below token-level BE on the
//!   fast path (the paper's never-worse guarantee; 0.05 finite-sample
//!   slack).
//!
//! `--smoke` shrinks the workload for CI: `cargo bench --bench
//! native_fast -- --smoke`.

use std::sync::Arc;
use std::time::Instant;

use specd::backend::NativeBackend;
use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::util::json;
use specd::verify::Algo;
use specd::workload::Dataset;

/// One measured cell: throughput and block efficiency.
struct Meas {
    tps: f64,
    be: f64,
}

fn measure(
    backend: Arc<NativeBackend>,
    algo: Algo,
    prompts: &[Vec<u32>],
    max_new: usize,
    n_seeds: u64,
) -> anyhow::Result<Meas> {
    let cfg = EngineConfig { algo, max_new_tokens: max_new, ..Default::default() };
    let engine = SpecEngine::new(backend, cfg)?;
    // Warm-up pass (thread pool, scratch, caches), then timed seeds.
    let _ = engine.run_prompts(&prompts[..prompts.len().min(4)], 7)?;
    let (mut toks, mut emitted, mut iters) = (0usize, 0usize, 0usize);
    let t0 = Instant::now();
    for seed in 0..n_seeds {
        for rep in engine.run_prompts(prompts, seed)? {
            toks += rep.total_tokens();
            for row in &rep.rows {
                emitted += row.emitted;
                iters += row.iterations;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(Meas {
        tps: toks as f64 / wall.max(1e-9),
        be: emitted as f64 / iters.max(1) as f64,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_prompts, max_new, n_seeds) = if smoke { (6, 16, 1u64) } else { (18, 32, 2u64) };
    let datasets = Dataset::load_or_synthetic(None)?;
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for name in ["gsm8k", "wmt", "xsum"] {
        let ds = datasets.iter().find(|d| d.name == name).expect("dataset");
        prompts.extend(ds.take(n_prompts / 3 + 1));
    }
    prompts.truncate(n_prompts);

    let seed = 0xfa57;
    let reference = Arc::new(
        NativeBackend::seeded(seed)
            .with_threads(1)
            .with_reference_kernel(true)
            .with_persistent_scratch(false),
    );
    let fast = Arc::new(NativeBackend::seeded(seed));
    let threads = fast.threads();
    println!("native_fast: fast path runs {threads} forward threads");

    let algos = [
        Algo::Token,
        Algo::Block,
        Algo::MultiPath { k: 1 },
        Algo::MultiPath { k: 2 },
        Algo::MultiPath { k: 4 },
    ];
    let mut ref_m: Vec<Meas> = Vec::new();
    let mut fast_m: Vec<Meas> = Vec::new();
    for algo in algos {
        let r = measure(reference.clone(), algo, &prompts, max_new, n_seeds)?;
        let f = measure(fast.clone(), algo, &prompts, max_new, n_seeds)?;
        let label = algo.to_string();
        let speedup = f.tps / r.tps.max(1e-9);
        println!(
            "native/{label:<12}  ref {:>9.1} tok/s   fast {:>9.1} tok/s   {speedup:>5.2}x   \
             BE {:.3}",
            r.tps, f.tps, f.be
        );
        ref_m.push(r);
        fast_m.push(f);
    }
    let block_speedup = fast_m[1].tps / ref_m[1].tps.max(1e-9);

    // ---- write BENCH_native.json ----------------------------------------
    let report = json::obj(vec![
        ("smoke", json::Value::Bool(smoke)),
        ("threads", json::num(threads as f64)),
        ("ref_token_tps", json::num(ref_m[0].tps)),
        ("ref_block_tps", json::num(ref_m[1].tps)),
        ("ref_multipath1_tps", json::num(ref_m[2].tps)),
        ("ref_multipath2_tps", json::num(ref_m[3].tps)),
        ("ref_multipath4_tps", json::num(ref_m[4].tps)),
        ("fast_token_tps", json::num(fast_m[0].tps)),
        ("fast_block_tps", json::num(fast_m[1].tps)),
        ("fast_multipath1_tps", json::num(fast_m[2].tps)),
        ("fast_multipath2_tps", json::num(fast_m[3].tps)),
        ("fast_multipath4_tps", json::num(fast_m[4].tps)),
        ("fast_token_be", json::num(fast_m[0].be)),
        ("fast_block_be", json::num(fast_m[1].be)),
        ("block_speedup", json::num(block_speedup)),
    ]);
    std::fs::write("BENCH_native.json", json::to_string(&report))?;
    println!("wrote BENCH_native.json");

    // ---- CI gates --------------------------------------------------------
    let mut failed = false;
    if block_speedup < 1.5 {
        eprintln!(
            "PERF REGRESSION: fast-path block throughput is only {block_speedup:.2}x the \
             scalar reference (gate: >= 1.5x)"
        );
        failed = true;
    }
    if fast_m[1].be < fast_m[0].be - 0.05 {
        eprintln!(
            "PERF REGRESSION: block-verification BE {:.3} fell below token-level BE {:.3}",
            fast_m[1].be, fast_m[0].be
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "perf gates passed: fast block {block_speedup:.2}x >= 1.5x scalar reference, \
         block BE >= token BE"
    );
    Ok(())
}
