//! Native fast-path benchmark and CI wall-clock perf gate (DESIGN.md
//! §10/§11).
//!
//! Two comparisons on the native backend decoding the same prompts with
//! the same seeds:
//!
//! * **scalar reference vs fast path** (both fp32 drafts) — the PR-4
//!   gate: blocked register-tiled matmul + row-parallel forward +
//!   persistent multipath scratch against the pre-fast-path
//!   configuration (scalar kernel, single thread, per-iteration scratch
//!   allocation).  Every cell decodes bit-identical tokens, so the ratio
//!   isolates exactly the kernel + threading + scratch delta.
//! * **int8 vs fp32 draft** (both on the fast path) — the quantised
//!   draft gate (DESIGN.md §11): drafter-forward throughput, end-to-end
//!   block-mode throughput, and the acceptance-rate (tau) regression
//!   guard.  Int8 drafting changes *which* tokens are drafted (not the
//!   committed-token distribution — verification corrects the drift), so
//!   these cells compare throughput and mean tau, not bits.
//!
//! Results land in `BENCH_native.json` for CI to archive
//! (`benches/verify_hot.rs --smoke` appends its microbench numbers to
//! the same file).  Exit code is non-zero when a perf invariant
//! regresses:
//!
//! * fast-path (SIMD-kernel) block-verification throughput >= 3x the
//!   scalar reference where AVX2/NEON is detected, >= 1.5x on the
//!   packed-scalar fallback (ISA-conditional so runners without AVX2
//!   don't flake) — the PR-6 headline gate, superseding PR-4's flat
//!   1.5x;
//! * isolated f32 and int8 SIMD GEMM GFLOP/s >= the same ISA-conditional
//!   multiple of their scalar references (per-(ISA, dtype) cells in
//!   BENCH_native.json, so kernel regressions are attributable
//!   separately from engine overheads);
//! * block-verification BE >= token-level BE on the fast path (the
//!   paper's never-worse guarantee; 0.05 finite-sample slack);
//! * int8 draft-forward throughput >= 1.3x the fp32 draft;
//! * int8 end-to-end block throughput strictly above the fp32 number;
//! * int8 mean tau >= 0.9x the fp32 mean tau (acceptance-rate guard).
//!
//! `--smoke` shrinks the workload for CI: `cargo bench --bench
//! native_fast -- --smoke`.

use std::sync::Arc;
use std::time::Instant;

use specd::backend::kernels::{
    active_isa, matmul_blocked, matmul_q8_i32, matmul_q8_i32_ref, matmul_ref, matmul_simd,
    pack_q8, Isa, PackedF32, QuantScratch,
};
use specd::backend::{Backend, NativeBackend, Precision};
use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::models::vocab;
use specd::util::json;
use specd::verify::{Algo, Rng};
use specd::workload::Dataset;

/// One measured cell: throughput, block efficiency and mean accepted
/// prefix length.
struct Meas {
    tps: f64,
    be: f64,
    tau: f64,
}

fn measure(
    backend: Arc<NativeBackend>,
    algo: Algo,
    prec: Precision,
    prompts: &[Vec<u32>],
    max_new: usize,
    n_seeds: u64,
) -> anyhow::Result<Meas> {
    let cfg = EngineConfig {
        algo,
        max_new_tokens: max_new,
        draft_precision: prec,
        ..Default::default()
    };
    let engine = SpecEngine::new(backend, cfg)?;
    // Warm-up pass (thread pool, scratch, caches, quantised twins), then
    // timed seeds.
    let _ = engine.run_prompts(&prompts[..prompts.len().min(4)], 7)?;
    let (mut toks, mut emitted, mut iters) = (0usize, 0usize, 0usize);
    let t0 = Instant::now();
    for seed in 0..n_seeds {
        for rep in engine.run_prompts(prompts, seed)? {
            toks += rep.total_tokens();
            for row in &rep.rows {
                emitted += row.emitted;
                iters += row.iterations;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let be = emitted as f64 / iters.max(1) as f64;
    Ok(Meas { tps: toks as f64 / wall.max(1e-9), be, tau: (be - 1.0).max(0.0) })
}

/// Drafter-forward throughput (draft tokens/sec): repeated
/// `draft_block` calls over a fixed prompt state — the isolated cost of
/// the precision knob, with scoring and verification excluded.  The
/// state is not advanced between calls, so every call redrafts the same
/// positions deterministically.
fn measure_draft(backend: &NativeBackend, gamma: usize, reps: usize) -> anyhow::Result<f64> {
    let info = backend.info();
    let (b, l) = (info.batch, info.max_len);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        let p = [vocab::BOS, vocab::marker_for((bi % 8) as u32), 20 + bi as u32, 31, 42];
        for (j, &t) in p.iter().enumerate() {
            toks[bi * l + j] = t as i32;
        }
        lens[bi] = p.len() as i32;
    }
    let seeds: Vec<i32> = (0..b as i32).map(|i| 17 + 5 * i).collect();
    let mut kv = backend.prefill("xxs", &toks, &lens)?;
    // Warm-up (spawns the pool, builds the quantised twin if any).
    let _ = backend.draft_block("xxs", gamma, &toks, &lens, &mut kv, &seeds)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        let out = backend.draft_block("xxs", gamma, &toks, &lens, &mut kv, &seeds)?;
        std::hint::black_box(out.drafts.len());
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((reps * b * gamma) as f64 / wall.max(1e-9))
}

/// Giga-ops/sec of one GEMM closure (`flops` counted per call, f32
/// multiply-adds or i8×i8→i32 ones alike); one untimed warm-up call.
fn gemm_gflops(reps: usize, flops: f64, mut f: impl FnMut()) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    flops * reps as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e9
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_prompts, max_new, n_seeds, draft_reps) =
        if smoke { (6, 16, 1u64, 60) } else { (18, 32, 2u64, 300) };
    let datasets = Dataset::load_or_synthetic(None)?;
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for name in ["gsm8k", "wmt", "xsum"] {
        let ds = datasets.iter().find(|d| d.name == name).expect("dataset");
        prompts.extend(ds.take(n_prompts / 3 + 1));
    }
    prompts.truncate(n_prompts);

    let seed = 0xfa57;
    let reference = Arc::new(
        NativeBackend::seeded(seed)
            .with_threads(1)
            .with_reference_kernel(true)
            .with_persistent_scratch(false)
            .with_draft_precision(Precision::Fp32),
    );
    let fast_fp32 = Arc::new(NativeBackend::seeded(seed).with_draft_precision(Precision::Fp32));
    let fast_int8 = Arc::new(NativeBackend::seeded(seed).with_draft_precision(Precision::Int8));
    let threads = fast_fp32.threads();
    println!("native_fast: fast path runs {threads} forward threads");

    // ---- PR-4 cells: scalar reference vs fast path, both fp32 -----------
    let algos = [
        Algo::Token,
        Algo::Block,
        Algo::MultiPath { k: 1 },
        Algo::MultiPath { k: 2 },
        Algo::MultiPath { k: 4 },
    ];
    let mut ref_m: Vec<Meas> = Vec::new();
    let mut fast_m: Vec<Meas> = Vec::new();
    for algo in algos {
        let r = measure(reference.clone(), algo, Precision::Fp32, &prompts, max_new, n_seeds)?;
        let f = measure(fast_fp32.clone(), algo, Precision::Fp32, &prompts, max_new, n_seeds)?;
        let label = algo.to_string();
        let speedup = f.tps / r.tps.max(1e-9);
        println!(
            "native/{label:<12}  ref {:>9.1} tok/s   fast {:>9.1} tok/s   {speedup:>5.2}x   \
             BE {:.3}",
            r.tps, f.tps, f.be
        );
        ref_m.push(r);
        fast_m.push(f);
    }
    let block_speedup = fast_m[1].tps / ref_m[1].tps.max(1e-9);

    // ---- int8 draft cells: fast path, fp32 vs int8 drafter --------------
    let draft_fp32_tps = measure_draft(&fast_fp32, 8, draft_reps)?;
    let draft_int8_tps = measure_draft(&fast_int8, 8, draft_reps)?;
    let int8_draft_speedup = draft_int8_tps / draft_fp32_tps.max(1e-9);
    println!(
        "native/draft_only    fp32 {draft_fp32_tps:>9.1} tok/s   int8 {draft_int8_tps:>9.1} \
         tok/s   {int8_draft_speedup:>5.2}x"
    );
    let block_fp32 = &fast_m[1];
    let block_int8 =
        measure(fast_int8.clone(), Algo::Block, Precision::Int8, &prompts, max_new, n_seeds)?;
    let int8_block_speedup = block_int8.tps / block_fp32.tps.max(1e-9);
    println!(
        "native/block_int8    fp32 {:>9.1} tok/s   int8 {:>9.1} tok/s   \
         {int8_block_speedup:>5.2}x   tau {:.3} vs {:.3}",
        block_fp32.tps, block_int8.tps, block_int8.tau, block_fp32.tau
    );

    // ---- kernel cells: per-(ISA, dtype) GFLOP/s on one model shape ------
    // Isolated GEMM throughput so kernel regressions are attributable
    // separately from engine overheads (the e2e cells above).  2·t·d_in·
    // d_out ops per call either way — f32 multiply-adds, or exact
    // i8×i8→i32 multiply-accumulates for the int8 cells.
    let isa = active_isa();
    let kreps = if smoke { 200 } else { 1500 };
    let (kt, kdi, kdo) = (8usize, 128usize, 512usize);
    let mut krng = Rng::new(0x6e41);
    let kx: Vec<f32> = (0..kt * kdi).map(|_| (krng.uniform() * 2.0 - 1.0) as f32).collect();
    let kw: Vec<f32> = (0..kdi * kdo).map(|_| (krng.uniform() * 2.0 - 1.0) as f32).collect();
    let kpk = PackedF32::pack(&kw, kdi, kdo);
    let kq: Vec<i8> = (0..kdi * kdo).map(|_| (krng.uniform() * 255.0 - 127.0) as i8).collect();
    let kqt = pack_q8(&kq, kdi, kdo);
    let kscale: Vec<f32> = (0..kdo).map(|_| (krng.uniform() * 0.02) as f32).collect();
    let mut kout = vec![0.0f32; kt * kdo];
    let mut kscr = QuantScratch::default();
    let kflops = 2.0 * (kt * kdi * kdo) as f64;
    let f32_ref_gflops = gemm_gflops(kreps, kflops, || {
        kout.fill(0.0);
        matmul_ref(&kx, &kw, &mut kout, kt, kdi, kdo);
        std::hint::black_box(kout[0]);
    });
    let f32_blocked_gflops = gemm_gflops(kreps, kflops, || {
        kout.fill(0.0);
        matmul_blocked(&kx, &kw, &mut kout, kt, kdi, kdo);
        std::hint::black_box(kout[0]);
    });
    let f32_simd_gflops = gemm_gflops(kreps, kflops, || {
        kout.fill(0.0);
        matmul_simd(&kx, &kpk, &mut kout, kt, kdi, kdo);
        std::hint::black_box(kout[0]);
    });
    let int8_ref_gops = gemm_gflops(kreps, kflops, || {
        kout.fill(0.0);
        matmul_q8_i32_ref(&kx, &kq, &kscale, &mut kout, kt, kdi, kdo, &mut kscr);
        std::hint::black_box(kout[0]);
    });
    let int8_simd_gops = gemm_gflops(kreps, kflops, || {
        kout.fill(0.0);
        matmul_q8_i32(&kx, &kqt, &kscale, &mut kout, kt, kdi, kdo, &mut kscr);
        std::hint::black_box(kout[0]);
    });
    let kernel_f32_speedup = f32_simd_gflops / f32_ref_gflops.max(1e-9);
    let kernel_int8_speedup = int8_simd_gops / int8_ref_gops.max(1e-9);
    println!(
        "native/kernels[{isa}]  f32 ref {f32_ref_gflops:.2} / blocked {f32_blocked_gflops:.2} \
         / simd {f32_simd_gflops:.2} GFLOP/s ({kernel_f32_speedup:.2}x)   int8 ref \
         {int8_ref_gops:.2} / simd {int8_simd_gops:.2} Gop/s ({kernel_int8_speedup:.2}x)"
    );
    // Gate level: 3x over the scalar reference where real SIMD (AVX2 /
    // NEON) was detected, 1.5x on the packed-scalar fallback so runners
    // without AVX2 don't flake.
    let simd_gate = if isa == Isa::Scalar { 1.5 } else { 3.0 };

    // ---- write BENCH_native.json ----------------------------------------
    let report = json::obj(vec![
        ("smoke", json::Value::Bool(smoke)),
        ("threads", json::num(threads as f64)),
        ("ref_token_tps", json::num(ref_m[0].tps)),
        ("ref_block_tps", json::num(ref_m[1].tps)),
        ("ref_multipath1_tps", json::num(ref_m[2].tps)),
        ("ref_multipath2_tps", json::num(ref_m[3].tps)),
        ("ref_multipath4_tps", json::num(ref_m[4].tps)),
        ("fast_token_tps", json::num(fast_m[0].tps)),
        ("fast_block_tps", json::num(fast_m[1].tps)),
        ("fast_multipath1_tps", json::num(fast_m[2].tps)),
        ("fast_multipath2_tps", json::num(fast_m[3].tps)),
        ("fast_multipath4_tps", json::num(fast_m[4].tps)),
        ("fast_token_be", json::num(fast_m[0].be)),
        ("fast_block_be", json::num(fast_m[1].be)),
        ("block_speedup", json::num(block_speedup)),
        ("draft_fp32_tps", json::num(draft_fp32_tps)),
        ("draft_int8_tps", json::num(draft_int8_tps)),
        ("int8_draft_speedup", json::num(int8_draft_speedup)),
        ("int8_block_tps", json::num(block_int8.tps)),
        ("int8_block_speedup", json::num(int8_block_speedup)),
        ("tau_fp32", json::num(block_fp32.tau)),
        ("tau_int8", json::num(block_int8.tau)),
        ("kernel_isa", json::Value::Str(isa.to_string())),
        ("kernel_f32_ref_gflops", json::num(f32_ref_gflops)),
        ("kernel_f32_blocked_gflops", json::num(f32_blocked_gflops)),
        ("kernel_f32_simd_gflops", json::num(f32_simd_gflops)),
        ("kernel_int8_ref_gops", json::num(int8_ref_gops)),
        ("kernel_int8_simd_gops", json::num(int8_simd_gops)),
        ("kernel_f32_simd_speedup", json::num(kernel_f32_speedup)),
        ("kernel_int8_simd_speedup", json::num(kernel_int8_speedup)),
        ("simd_gate", json::num(simd_gate)),
    ]);
    std::fs::write("BENCH_native.json", json::to_string(&report))?;
    println!("wrote BENCH_native.json");

    // ---- CI gates --------------------------------------------------------
    let mut failed = false;
    if block_speedup < simd_gate {
        eprintln!(
            "PERF REGRESSION: fast-path (simd) block throughput is only {block_speedup:.2}x \
             the scalar reference (gate: >= {simd_gate}x on {isa})"
        );
        failed = true;
    }
    if kernel_f32_speedup < simd_gate {
        eprintln!(
            "PERF REGRESSION: f32 simd GEMM is only {kernel_f32_speedup:.2}x the scalar \
             reference kernel (gate: >= {simd_gate}x on {isa})"
        );
        failed = true;
    }
    if kernel_int8_speedup < simd_gate {
        eprintln!(
            "PERF REGRESSION: int8 simd GEMM is only {kernel_int8_speedup:.2}x the scalar \
             integer oracle (gate: >= {simd_gate}x on {isa})"
        );
        failed = true;
    }
    if fast_m[1].be < fast_m[0].be - 0.05 {
        eprintln!(
            "PERF REGRESSION: block-verification BE {:.3} fell below token-level BE {:.3}",
            fast_m[1].be, fast_m[0].be
        );
        failed = true;
    }
    if int8_draft_speedup < 1.3 {
        eprintln!(
            "PERF REGRESSION: int8 draft forward is only {int8_draft_speedup:.2}x the fp32 \
             draft (gate: >= 1.3x)"
        );
        failed = true;
    }
    if int8_block_speedup <= 1.0 {
        eprintln!(
            "PERF REGRESSION: int8-draft end-to-end block throughput {:.1} tok/s is not \
             above the fp32 number {:.1} tok/s",
            block_int8.tps, block_fp32.tps
        );
        failed = true;
    }
    if block_int8.tau < 0.9 * block_fp32.tau {
        eprintln!(
            "ACCEPTANCE REGRESSION: int8 mean tau {:.3} fell below 0.9x the fp32 mean tau \
             {:.3}",
            block_int8.tau, block_fp32.tau
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "perf gates passed [{isa}]: fast block {block_speedup:.2}x >= {simd_gate}x scalar \
         reference, f32 kernel {kernel_f32_speedup:.2}x / int8 kernel \
         {kernel_int8_speedup:.2}x >= {simd_gate}x, block BE >= token BE, int8 draft \
         {int8_draft_speedup:.2}x >= 1.3x fp32, int8 e2e block {int8_block_speedup:.2}x > 1x, \
         int8 tau within 0.9x of fp32"
    );
    Ok(())
}
