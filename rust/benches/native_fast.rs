//! Native fast-path benchmark and CI wall-clock perf gate (DESIGN.md
//! §10/§11).
//!
//! Two comparisons on the native backend decoding the same prompts with
//! the same seeds:
//!
//! * **scalar reference vs fast path** (both fp32 drafts) — the PR-4
//!   gate: blocked register-tiled matmul + row-parallel forward +
//!   persistent multipath scratch against the pre-fast-path
//!   configuration (scalar kernel, single thread, per-iteration scratch
//!   allocation).  Every cell decodes bit-identical tokens, so the ratio
//!   isolates exactly the kernel + threading + scratch delta.
//! * **int8 vs fp32 draft** (both on the fast path) — the quantised
//!   draft gate (DESIGN.md §11): drafter-forward throughput, end-to-end
//!   block-mode throughput, and the acceptance-rate (tau) regression
//!   guard.  Int8 drafting changes *which* tokens are drafted (not the
//!   committed-token distribution — verification corrects the drift), so
//!   these cells compare throughput and mean tau, not bits.
//!
//! Results land in `BENCH_native.json` for CI to archive
//! (`benches/verify_hot.rs --smoke` appends its microbench numbers to
//! the same file).  Exit code is non-zero when a perf invariant
//! regresses:
//!
//! * fast-path block-verification throughput >= 1.5x the scalar
//!   reference (PR-4 headline gate);
//! * block-verification BE >= token-level BE on the fast path (the
//!   paper's never-worse guarantee; 0.05 finite-sample slack);
//! * int8 draft-forward throughput >= 1.3x the fp32 draft;
//! * int8 end-to-end block throughput strictly above the fp32 number;
//! * int8 mean tau >= 0.9x the fp32 mean tau (acceptance-rate guard).
//!
//! `--smoke` shrinks the workload for CI: `cargo bench --bench
//! native_fast -- --smoke`.

use std::sync::Arc;
use std::time::Instant;

use specd::backend::{Backend, NativeBackend, Precision};
use specd::config::EngineConfig;
use specd::engine::spec::SpecEngine;
use specd::models::vocab;
use specd::util::json;
use specd::verify::Algo;
use specd::workload::Dataset;

/// One measured cell: throughput, block efficiency and mean accepted
/// prefix length.
struct Meas {
    tps: f64,
    be: f64,
    tau: f64,
}

fn measure(
    backend: Arc<NativeBackend>,
    algo: Algo,
    prec: Precision,
    prompts: &[Vec<u32>],
    max_new: usize,
    n_seeds: u64,
) -> anyhow::Result<Meas> {
    let cfg = EngineConfig {
        algo,
        max_new_tokens: max_new,
        draft_precision: prec,
        ..Default::default()
    };
    let engine = SpecEngine::new(backend, cfg)?;
    // Warm-up pass (thread pool, scratch, caches, quantised twins), then
    // timed seeds.
    let _ = engine.run_prompts(&prompts[..prompts.len().min(4)], 7)?;
    let (mut toks, mut emitted, mut iters) = (0usize, 0usize, 0usize);
    let t0 = Instant::now();
    for seed in 0..n_seeds {
        for rep in engine.run_prompts(prompts, seed)? {
            toks += rep.total_tokens();
            for row in &rep.rows {
                emitted += row.emitted;
                iters += row.iterations;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let be = emitted as f64 / iters.max(1) as f64;
    Ok(Meas { tps: toks as f64 / wall.max(1e-9), be, tau: (be - 1.0).max(0.0) })
}

/// Drafter-forward throughput (draft tokens/sec): repeated
/// `draft_block` calls over a fixed prompt state — the isolated cost of
/// the precision knob, with scoring and verification excluded.  The
/// state is not advanced between calls, so every call redrafts the same
/// positions deterministically.
fn measure_draft(backend: &NativeBackend, gamma: usize, reps: usize) -> anyhow::Result<f64> {
    let info = backend.info();
    let (b, l) = (info.batch, info.max_len);
    let mut toks = vec![vocab::PAD as i32; b * l];
    let mut lens = vec![0i32; b];
    for bi in 0..b {
        let p = [vocab::BOS, vocab::marker_for((bi % 8) as u32), 20 + bi as u32, 31, 42];
        for (j, &t) in p.iter().enumerate() {
            toks[bi * l + j] = t as i32;
        }
        lens[bi] = p.len() as i32;
    }
    let seeds: Vec<i32> = (0..b as i32).map(|i| 17 + 5 * i).collect();
    let mut kv = backend.prefill("xxs", &toks, &lens)?;
    // Warm-up (spawns the pool, builds the quantised twin if any).
    let _ = backend.draft_block("xxs", gamma, &toks, &lens, &mut kv, &seeds)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        let out = backend.draft_block("xxs", gamma, &toks, &lens, &mut kv, &seeds)?;
        std::hint::black_box(out.drafts.len());
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((reps * b * gamma) as f64 / wall.max(1e-9))
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_prompts, max_new, n_seeds, draft_reps) =
        if smoke { (6, 16, 1u64, 60) } else { (18, 32, 2u64, 300) };
    let datasets = Dataset::load_or_synthetic(None)?;
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for name in ["gsm8k", "wmt", "xsum"] {
        let ds = datasets.iter().find(|d| d.name == name).expect("dataset");
        prompts.extend(ds.take(n_prompts / 3 + 1));
    }
    prompts.truncate(n_prompts);

    let seed = 0xfa57;
    let reference = Arc::new(
        NativeBackend::seeded(seed)
            .with_threads(1)
            .with_reference_kernel(true)
            .with_persistent_scratch(false)
            .with_draft_precision(Precision::Fp32),
    );
    let fast_fp32 = Arc::new(NativeBackend::seeded(seed).with_draft_precision(Precision::Fp32));
    let fast_int8 = Arc::new(NativeBackend::seeded(seed).with_draft_precision(Precision::Int8));
    let threads = fast_fp32.threads();
    println!("native_fast: fast path runs {threads} forward threads");

    // ---- PR-4 cells: scalar reference vs fast path, both fp32 -----------
    let algos = [
        Algo::Token,
        Algo::Block,
        Algo::MultiPath { k: 1 },
        Algo::MultiPath { k: 2 },
        Algo::MultiPath { k: 4 },
    ];
    let mut ref_m: Vec<Meas> = Vec::new();
    let mut fast_m: Vec<Meas> = Vec::new();
    for algo in algos {
        let r = measure(reference.clone(), algo, Precision::Fp32, &prompts, max_new, n_seeds)?;
        let f = measure(fast_fp32.clone(), algo, Precision::Fp32, &prompts, max_new, n_seeds)?;
        let label = algo.to_string();
        let speedup = f.tps / r.tps.max(1e-9);
        println!(
            "native/{label:<12}  ref {:>9.1} tok/s   fast {:>9.1} tok/s   {speedup:>5.2}x   \
             BE {:.3}",
            r.tps, f.tps, f.be
        );
        ref_m.push(r);
        fast_m.push(f);
    }
    let block_speedup = fast_m[1].tps / ref_m[1].tps.max(1e-9);

    // ---- int8 draft cells: fast path, fp32 vs int8 drafter --------------
    let draft_fp32_tps = measure_draft(&fast_fp32, 8, draft_reps)?;
    let draft_int8_tps = measure_draft(&fast_int8, 8, draft_reps)?;
    let int8_draft_speedup = draft_int8_tps / draft_fp32_tps.max(1e-9);
    println!(
        "native/draft_only    fp32 {draft_fp32_tps:>9.1} tok/s   int8 {draft_int8_tps:>9.1} \
         tok/s   {int8_draft_speedup:>5.2}x"
    );
    let block_fp32 = &fast_m[1];
    let block_int8 =
        measure(fast_int8.clone(), Algo::Block, Precision::Int8, &prompts, max_new, n_seeds)?;
    let int8_block_speedup = block_int8.tps / block_fp32.tps.max(1e-9);
    println!(
        "native/block_int8    fp32 {:>9.1} tok/s   int8 {:>9.1} tok/s   \
         {int8_block_speedup:>5.2}x   tau {:.3} vs {:.3}",
        block_fp32.tps, block_int8.tps, block_int8.tau, block_fp32.tau
    );

    // ---- write BENCH_native.json ----------------------------------------
    let report = json::obj(vec![
        ("smoke", json::Value::Bool(smoke)),
        ("threads", json::num(threads as f64)),
        ("ref_token_tps", json::num(ref_m[0].tps)),
        ("ref_block_tps", json::num(ref_m[1].tps)),
        ("ref_multipath1_tps", json::num(ref_m[2].tps)),
        ("ref_multipath2_tps", json::num(ref_m[3].tps)),
        ("ref_multipath4_tps", json::num(ref_m[4].tps)),
        ("fast_token_tps", json::num(fast_m[0].tps)),
        ("fast_block_tps", json::num(fast_m[1].tps)),
        ("fast_multipath1_tps", json::num(fast_m[2].tps)),
        ("fast_multipath2_tps", json::num(fast_m[3].tps)),
        ("fast_multipath4_tps", json::num(fast_m[4].tps)),
        ("fast_token_be", json::num(fast_m[0].be)),
        ("fast_block_be", json::num(fast_m[1].be)),
        ("block_speedup", json::num(block_speedup)),
        ("draft_fp32_tps", json::num(draft_fp32_tps)),
        ("draft_int8_tps", json::num(draft_int8_tps)),
        ("int8_draft_speedup", json::num(int8_draft_speedup)),
        ("int8_block_tps", json::num(block_int8.tps)),
        ("int8_block_speedup", json::num(int8_block_speedup)),
        ("tau_fp32", json::num(block_fp32.tau)),
        ("tau_int8", json::num(block_int8.tau)),
    ]);
    std::fs::write("BENCH_native.json", json::to_string(&report))?;
    println!("wrote BENCH_native.json");

    // ---- CI gates --------------------------------------------------------
    let mut failed = false;
    if block_speedup < 1.5 {
        eprintln!(
            "PERF REGRESSION: fast-path block throughput is only {block_speedup:.2}x the \
             scalar reference (gate: >= 1.5x)"
        );
        failed = true;
    }
    if fast_m[1].be < fast_m[0].be - 0.05 {
        eprintln!(
            "PERF REGRESSION: block-verification BE {:.3} fell below token-level BE {:.3}",
            fast_m[1].be, fast_m[0].be
        );
        failed = true;
    }
    if int8_draft_speedup < 1.3 {
        eprintln!(
            "PERF REGRESSION: int8 draft forward is only {int8_draft_speedup:.2}x the fp32 \
             draft (gate: >= 1.3x)"
        );
        failed = true;
    }
    if int8_block_speedup <= 1.0 {
        eprintln!(
            "PERF REGRESSION: int8-draft end-to-end block throughput {:.1} tok/s is not \
             above the fp32 number {:.1} tok/s",
            block_int8.tps, block_fp32.tps
        );
        failed = true;
    }
    if block_int8.tau < 0.9 * block_fp32.tau {
        eprintln!(
            "ACCEPTANCE REGRESSION: int8 mean tau {:.3} fell below 0.9x the fp32 mean tau \
             {:.3}",
            block_int8.tau, block_fp32.tau
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "perf gates passed: fast block {block_speedup:.2}x >= 1.5x scalar reference, block \
         BE >= token BE, int8 draft {int8_draft_speedup:.2}x >= 1.3x fp32, int8 e2e block \
         {int8_block_speedup:.2}x > 1x, int8 tau within 0.9x of fp32"
    );
    Ok(())
}
