//! Host <-> `xla::Literal` conversion helpers.

use anyhow::anyhow;

/// Build an f32 literal of the given dims from a flat slice.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    if data.len() != n {
        return Err(anyhow!("literal size mismatch: {} vs dims {:?}", data.len(), dims));
    }
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        // rank-0: reshape to scalar
        Ok(lit.reshape(&[])?)
    } else {
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        Ok(lit.reshape(&d)?)
    }
}

/// Build an i32 literal of the given dims from a flat slice.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    if data.len() != n {
        return Err(anyhow!("literal size mismatch: {} vs dims {:?}", data.len(), dims));
    }
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        Ok(lit.reshape(&[])?)
    } else {
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        Ok(lit.reshape(&d)?)
    }
}

/// Scalar i32 literal.
pub fn i32_scalar(v: i32) -> anyhow::Result<xla::Literal> {
    i32_literal(&[v], &[])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let lit = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn roundtrip_i32_scalar() {
        let lit = i32_scalar(7).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(f32_literal(&[1.0], &[2, 2]).is_err());
    }
}
