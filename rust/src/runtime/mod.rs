//! Artifact-bundle runtime layer.
//!
//! [`manifest`] (always compiled) is the typed contract between the python
//! build path (`aot.py`) and every backend: fixed serving shapes, program
//! signatures, weight layouts and dataset metadata.  Both the native
//! backend's artifact loader and the PJRT program catalogue read it.
//!
//! [`pjrt`] and [`literal`] exist only under the `pjrt` cargo feature:
//! they load `artifacts/*.hlo.txt`, compile them on the PJRT CPU client
//! via the `xla` crate and execute them with device-resident state.  The
//! engine layer never touches these types directly — all device
//! interaction goes through [`crate::backend::Backend`], whose PJRT
//! implementation ([`crate::backend::pjrt`]) wraps [`pjrt::Runtime`].
//! With default features the build ships the pure-Rust native backend
//! only and needs neither the `xla` crate nor an artifacts directory.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod literal;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{Manifest, ModelMeta, ProgramMeta};

#[cfg(feature = "pjrt")]
pub use pjrt::{ExecOutput, Program, Runtime, StateHandle};
