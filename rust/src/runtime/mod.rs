//! Runtime layer: loads and executes the AOT-compiled HLO programs via the
//! `xla` crate's PJRT CPU client.  See DESIGN.md §2.1 for the program
//! catalogue and pjrt.rs for the execution model.

pub mod literal;
pub mod manifest;
pub mod pjrt;

pub use manifest::{Manifest, ModelMeta, ProgramMeta};
pub use pjrt::{ExecOutput, Program, Runtime, StateHandle};
