//! PJRT runtime: loads `artifacts/*.hlo.txt`, compiles them on the CPU
//! client, uploads weights once, and executes programs with device-resident
//! state.  This is the only module that touches the `xla` crate FFI.
//!
//! Two output layouts exist across PJRT builds: results may come back as
//! one buffer per output leaf (untupled) or as a single tuple buffer.  The
//! wrapper detects which case it is at first execution and normalises to
//! host literals for small outputs while keeping large state tensors on
//! device when the layout permits (see [`ExecOutput`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context};

use super::manifest::{Manifest, ProgramMeta};

/// A compiled program plus its manifest signature.
pub struct Program {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
    pub meta: ProgramMeta,
    pub compile_ms: u128,
}

/// The runtime: client + manifest + lazily compiled programs + uploaded
/// weights.  `Send`-able behind a mutex; engine keeps it in an `Arc`.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    programs: Mutex<HashMap<String, &'static Program>>,
    weights: Mutex<HashMap<String, &'static Vec<xla::PjRtBuffer>>>,
    /// Host literals pinned until their async host->device copies are known
    /// complete (PJRT's BufferFromHostLiteral copies on a worker thread; the
    /// literal must outlive the copy).  Engines call [`Runtime::clear_pinned`]
    /// at batch boundaries, after output readbacks have forced completion.
    pinned: Mutex<Vec<xla::Literal>>,
}

// The xla crate wrappers are raw pointers without Send/Sync markers; the
// PJRT CPU client is thread-safe for our usage pattern (all mutation goes
// through &self FFI calls which PJRT serialises internally).  The engine
// additionally serialises all execution behind its own lock.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            programs: Mutex::new(HashMap::new()),
            weights: Mutex::new(HashMap::new()),
            pinned: Mutex::new(Vec::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch the cached) program by manifest name.
    ///
    /// Compiled executables are intentionally leaked: they live for the
    /// process lifetime (a serving binary), which sidesteps self-referential
    /// lifetimes without refcounting FFI handles.
    pub fn program(&self, name: &str) -> anyhow::Result<&'static Program> {
        if let Some(p) = self.programs.lock().unwrap().get(name) {
            return Ok(p);
        }
        let meta = self.manifest.program(name)?.clone();
        let path = self.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let prog: &'static Program = Box::leak(Box::new(Program {
            name: name.to_string(),
            exe,
            meta,
            compile_ms: t0.elapsed().as_millis(),
        }));
        self.programs.lock().unwrap().insert(name.to_string(), prog);
        Ok(prog)
    }

    /// Upload (or fetch cached) weight buffers for a model, in the
    /// tree-flatten order shared with every program signature.
    pub fn weights(&self, model: &str) -> anyhow::Result<&'static Vec<xla::PjRtBuffer>> {
        if let Some(w) = self.weights.lock().unwrap().get(model) {
            return Ok(w);
        }
        let meta = self.manifest.model(model)?.clone();
        let path = self.dir.join(&meta.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut bufs = Vec::with_capacity(meta.weights.len());
        for w in &meta.weights {
            let n: usize = w.shape.iter().product::<usize>().max(1);
            let slice = floats
                .get(w.offset..w.offset + n)
                .ok_or_else(|| anyhow!("weights file too short for {}", w.name))?;
            let lit = super::literal::f32_literal(slice, &w.shape)?;
            let buf = self
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("uploading {}: {e}", w.name))?;
            self.pinned.lock().unwrap().push(lit);
            bufs.push(buf);
        }
        let leaked: &'static Vec<xla::PjRtBuffer> = Box::leak(Box::new(bufs));
        self.weights.lock().unwrap().insert(model.to_string(), leaked);
        Ok(leaked)
    }

    /// Upload a host literal to the device, pinning it until
    /// [`Runtime::clear_pinned`] (the copy is asynchronous; see field docs).
    pub fn upload(&self, lit: xla::Literal) -> anyhow::Result<xla::PjRtBuffer> {
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload: {e}"))?;
        self.pinned.lock().unwrap().push(lit);
        Ok(buf)
    }

    /// Drop pinned upload literals.  Callers must have read back at least
    /// one output that depends on every outstanding upload (execution
    /// ordering then guarantees the copies completed).
    pub fn clear_pinned(&self) {
        self.pinned.lock().unwrap().clear();
    }

    /// Execute a program on device buffers, normalising the output layout.
    pub fn execute(
        &self,
        prog: &Program,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<ExecOutput> {
        if args.len() != prog.meta.args.len() {
            return Err(anyhow!(
                "{}: supplied {} args, program expects {}",
                prog.name,
                args.len(),
                prog.meta.args.len()
            ));
        }
        let mut out = prog
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e}", prog.name))?;
        let row = out
            .pop()
            .filter(|r| !r.is_empty())
            .ok_or_else(|| anyhow!("{}: empty execution result", prog.name))?;
        let want = prog.meta.outs.len();
        if row.len() == want {
            Ok(ExecOutput::Untupled(row))
        } else if row.len() == 1 {
            // Single tuple buffer: decompose on the host.
            let lit = row[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{}: readback: {e}", prog.name))?;
            let parts = lit.to_tuple().map_err(|e| anyhow!("{}: untuple: {e}", prog.name))?;
            if parts.len() != want {
                return Err(anyhow!("{}: tuple arity {} != {}", prog.name, parts.len(), want));
            }
            Ok(ExecOutput::Host(parts))
        } else {
            Err(anyhow!("{}: unexpected output count {}", prog.name, row.len()))
        }
    }
}

/// Normalised execution output.
pub enum ExecOutput {
    /// One device buffer per output leaf (state can stay resident).
    Untupled(Vec<xla::PjRtBuffer>),
    /// Host literals (tuple layout forced a readback).
    Host(Vec<xla::Literal>),
}

impl ExecOutput {
    pub fn len(&self) -> usize {
        match self {
            ExecOutput::Untupled(v) => v.len(),
            ExecOutput::Host(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read output `idx` back as an i32 vector.
    pub fn i32s(&self, idx: usize) -> anyhow::Result<Vec<i32>> {
        match self {
            ExecOutput::Untupled(v) => {
                let lit = v[idx].to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
                Ok(lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?)
            }
            ExecOutput::Host(v) => {
                Ok(v[idx].to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?)
            }
        }
    }

    /// Read output `idx` back as an f32 vector.
    pub fn f32s(&self, idx: usize) -> anyhow::Result<Vec<f32>> {
        match self {
            ExecOutput::Untupled(v) => {
                let lit = v[idx].to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
                Ok(lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?)
            }
            ExecOutput::Host(v) => {
                Ok(v[idx].to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?)
            }
        }
    }

    /// Consume into per-output state handles for carrying across calls.
    pub fn into_handles(self) -> Vec<StateHandle> {
        match self {
            ExecOutput::Untupled(v) => v.into_iter().map(StateHandle::Buf).collect(),
            ExecOutput::Host(v) => v.into_iter().map(StateHandle::Lit).collect(),
        }
    }
}

/// A carried state tensor: already on device, or a host literal awaiting
/// (re-)upload — the latter occurs on PJRT builds whose execute returns a
/// single tuple buffer.
pub enum StateHandle {
    Buf(xla::PjRtBuffer),
    Lit(xla::Literal),
}

impl StateHandle {
    /// Materialise as a device buffer (no-op when already resident).
    pub fn ensure_buffer(self, rt: &Runtime) -> anyhow::Result<xla::PjRtBuffer> {
        match self {
            StateHandle::Buf(b) => Ok(b),
            StateHandle::Lit(l) => rt.upload(l),
        }
    }
}
