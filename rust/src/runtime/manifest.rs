//! Typed view of `artifacts/manifest.json` — the contract between the
//! python build path (aot.py) and this runtime.  Every fixed shape baked
//! into the HLO programs is declared here and validated at load time.
//! Decoded with the in-tree JSON parser (util::json).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub batch: usize,
    pub max_len: usize,
    pub vocab_size: usize,
    pub pad_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    pub gammas: Vec<usize>,
    pub algos: Vec<String>,
    pub drafters: Vec<String>,
    pub models: HashMap<String, ModelMeta>,
    pub programs: HashMap<String, ProgramMeta>,
    pub datasets: HashMap<String, crate::workload::DatasetInfo>,
    pub fast_build: bool,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub vocab_size: usize,
    pub max_len: usize,
    pub param_count: u64,
    pub weights_file: String,
    pub weights: Vec<WeightEntry>,
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct ProgramMeta {
    pub file: String,
    pub args: Vec<ArgMeta>,
    pub outs: Vec<OutMeta>,
    pub kind: String,
    pub algo: Option<String>,
    pub drafter: Option<String>,
    pub model: Option<String>,
    pub gamma: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct OutMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

fn decode_model(v: &Value) -> Result<ModelMeta> {
    let weights = v
        .arr_field("weights")?
        .iter()
        .map(|w| {
            Ok(WeightEntry {
                name: w.str_field("name")?,
                shape: w.usize_vec("shape")?,
                offset: w.usize_field("offset")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelMeta {
        n_layers: v.usize_field("n_layers")?,
        d_model: v.usize_field("d_model")?,
        n_heads: v.usize_field("n_heads")?,
        vocab_size: v.usize_field("vocab_size")?,
        max_len: v.usize_field("max_len")?,
        param_count: v.f64_field("param_count")? as u64,
        weights_file: v.str_field("weights_file")?,
        weights,
    })
}

fn decode_program(v: &Value) -> Result<ProgramMeta> {
    let args = v
        .arr_field("args")?
        .iter()
        .map(|a| {
            Ok(ArgMeta {
                name: a.str_field("name")?,
                shape: a.usize_vec("shape")?,
                dtype: a.str_field("dtype")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let outs = v
        .arr_field("outs")?
        .iter()
        .map(|o| Ok(OutMeta { shape: o.usize_vec("shape")?, dtype: o.str_field("dtype")? }))
        .collect::<Result<Vec<_>>>()?;
    Ok(ProgramMeta {
        file: v.str_field("file")?,
        args,
        outs,
        kind: v.str_field("kind")?,
        algo: v.get("algo").and_then(|x| x.as_str()).map(String::from),
        drafter: v.get("drafter").and_then(|x| x.as_str()).map(String::from),
        model: v.get("model").and_then(|x| x.as_str()).map(String::from),
        gamma: v.get("gamma").and_then(|x| x.as_usize()),
    })
}

impl Manifest {
    pub fn parse(raw: &str) -> Result<Self> {
        let v = crate::util::json::parse(raw).context("parsing manifest.json")?;
        let mut models = HashMap::new();
        for (k, mv) in v.field("models")?.as_obj().ok_or_else(|| anyhow!("models: not obj"))? {
            models.insert(k.clone(), decode_model(mv).with_context(|| format!("model {k}"))?);
        }
        let mut programs = HashMap::new();
        for (k, pv) in
            v.field("programs")?.as_obj().ok_or_else(|| anyhow!("programs: not obj"))?
        {
            programs
                .insert(k.clone(), decode_program(pv).with_context(|| format!("program {k}"))?);
        }
        let mut datasets = HashMap::new();
        for (k, dv) in
            v.field("datasets")?.as_obj().ok_or_else(|| anyhow!("datasets: not obj"))?
        {
            datasets.insert(
                k.clone(),
                crate::workload::DatasetInfo {
                    file: dv.str_field("file")?,
                    marker: dv.usize_field("marker")? as u32,
                    count: dv.usize_field("count")?,
                    mean_len: dv.f64_field("mean_len")?,
                },
            );
        }
        let m = Manifest {
            version: v.usize_field("version")? as u32,
            batch: v.usize_field("batch")?,
            max_len: v.usize_field("max_len")?,
            vocab_size: v.usize_field("vocab_size")?,
            pad_id: v.usize_field("pad_id")? as u32,
            bos_id: v.usize_field("bos_id")? as u32,
            eos_id: v.usize_field("eos_id")? as u32,
            gammas: v.usize_vec("gammas")?,
            algos: v
                .arr_field("algos")?
                .iter()
                .map(|x| x.as_str().unwrap_or_default().to_string())
                .collect(),
            drafters: v
                .arr_field("drafters")?
                .iter()
                .map(|x| x.as_str().unwrap_or_default().to_string())
                .collect(),
            models,
            programs,
            datasets,
            fast_build: v.get("fast_build").and_then(|x| x.as_bool()).unwrap_or(false),
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` to build the AOT bundle",
                path.display()
            )
        })?;
        Self::parse(&raw)
    }

    fn validate(&self) -> Result<()> {
        use crate::models::vocab;
        if self.version != 1 {
            return Err(anyhow!("unsupported manifest version {}", self.version));
        }
        if self.vocab_size != vocab::SIZE as usize
            || self.pad_id != vocab::PAD
            || self.eos_id != vocab::EOS
        {
            return Err(anyhow!("manifest vocab layout disagrees with models::vocab"));
        }
        if !self.models.contains_key("target") {
            return Err(anyhow!("manifest missing model 'target'"));
        }
        for d in &self.drafters {
            if !self.models.contains_key(d) {
                return Err(anyhow!("manifest missing drafter '{d}'"));
            }
        }
        for (name, prog) in &self.programs {
            if prog.args.is_empty() || prog.outs.is_empty() {
                return Err(anyhow!("program {name} has empty signature"));
            }
        }
        Ok(())
    }

    pub fn program(&self, name: &str) -> Result<&ProgramMeta> {
        self.programs.get(name).ok_or_else(|| {
            anyhow!(
                "program '{name}' not in manifest (have: {:?})",
                self.programs.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn program_path(&self, dir: &Path, name: &str) -> Result<PathBuf> {
        Ok(dir.join(&self.program(name)?.file))
    }

    /// Name of the fused iteration program for (algo, drafter, gamma).
    pub fn spec_iter_name(&self, algo: &str, drafter: &str, gamma: usize) -> String {
        format!("spec_iter_{algo}_{drafter}_g{gamma}")
    }
}

impl ArgMeta {
    /// Index of the top-level (python-signature) argument this leaf
    /// belongs to: `"[0]['embed']"` -> 0, `"[3]"` -> 3.
    pub fn top_index(&self) -> usize {
        let inner = self.name.trim_start_matches('[');
        inner.split(']').next().and_then(|s| s.parse().ok()).unwrap_or(usize::MAX)
    }
}

impl ProgramMeta {
    /// How many leading top-level args are parameter pytrees.
    pub fn n_param_args(&self) -> usize {
        if self.kind == "spec_iter" {
            2 // (params_target, params_drafter, ...)
        } else {
            1 // (params, ...)
        }
    }

    /// Number of leading flattened args that are weight tensors.
    pub fn weight_arg_count(&self) -> usize {
        let n = self.n_param_args();
        self.args.iter().take_while(|a| a.top_index() < n).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_program_meta() {
        let j = r#"{"file":"x.hlo.txt","args":[{"name":"[0]['embed']","shape":[256,128],"dtype":"float32"},
                    {"name":"[1]","shape":[4,96],"dtype":"int32"}],
                    "outs":[{"shape":[4,96],"dtype":"int32"}],"kind":"prefill","model":"target"}"#;
        let p = decode_program(&crate::util::json::parse(j).unwrap()).unwrap();
        assert_eq!(p.kind, "prefill");
        assert_eq!(p.args[0].shape, vec![256, 128]);
        assert_eq!(p.args[0].top_index(), 0);
        assert_eq!(p.args[1].top_index(), 1);
        assert_eq!(p.weight_arg_count(), 1);
    }

    #[test]
    fn spec_iter_weight_args_span_two_pytrees() {
        let j = r#"{"file":"x","kind":"spec_iter","args":[
            {"name":"[0]['embed']","shape":[2],"dtype":"float32"},
            {"name":"[1]['embed']","shape":[2],"dtype":"float32"},
            {"name":"[2]","shape":[4],"dtype":"int32"}],
            "outs":[{"shape":[4],"dtype":"int32"}]}"#;
        let p = decode_program(&crate::util::json::parse(j).unwrap()).unwrap();
        assert_eq!(p.n_param_args(), 2);
        assert_eq!(p.weight_arg_count(), 2);
    }
}
