//! HTTP/1.1 JSON front-end over std::net (thread-per-connection; the
//! offline image has no tokio, and the engine serialises on one device
//! anyway — see DESIGN.md §3).  Requests route through the serving tier
//! ([`crate::serve::Router`], DESIGN.md §14): multi-replica placement,
//! token-bucket admission and explicit load shedding — over-budget
//! traffic gets `429` with a `Retry-After` header, never a hang.
//!
//! Endpoints:
//! * `POST /v1/generate` — body `{"prompt_tokens": [...], "dataset":
//!   "gsm8k", "max_new_tokens": 48, "seed": 0, "lane": "interactive",
//!   "tenant": 7}`; either explicit tokens or a dataset to sample a
//!   prompt from.  Responds with generated tokens + decode stats.
//! * `GET /metrics`  — plain-text metrics exposition (per-replica blocks
//!   + router aggregates).
//! * `GET /healthz`  — liveness.

pub mod client;
pub mod http;

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Lane;
use crate::serve::{RouteError, Router, ServeRequest};
use crate::util::json::{self, Value};
use crate::workload::Dataset;

/// One routed HTTP response: status, content-type, body, extra headers.
pub type Response = (u16, String, String, Vec<(String, String)>);

/// Parsed generate-request body.
#[derive(Debug, Default)]
pub struct GenerateBody {
    pub prompt_tokens: Option<Vec<u32>>,
    pub dataset: Option<String>,
    pub max_new_tokens: Option<usize>,
    pub seed: Option<u64>,
    /// `"interactive"` (default) or `"batch"` — queue lane.
    pub lane: Option<String>,
    /// Tenant id for intra-lane round-robin fairness.
    pub tenant: Option<u64>,
}

impl GenerateBody {
    pub fn parse(body: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(body)?;
        let v = json::parse(text)?;
        Ok(GenerateBody {
            prompt_tokens: v.get("prompt_tokens").and_then(Value::as_arr).map(|a| {
                a.iter().filter_map(Value::as_u64).map(|x| x as u32).collect()
            }),
            dataset: v.get("dataset").and_then(Value::as_str).map(String::from),
            max_new_tokens: v.get("max_new_tokens").and_then(Value::as_usize),
            seed: v.get("seed").and_then(Value::as_u64),
            lane: v.get("lane").and_then(Value::as_str).map(String::from),
            tenant: v.get("tenant").and_then(Value::as_u64),
        })
    }
}

/// Shared server state.
pub struct ServerState {
    pub router: Router,
    pub datasets: Vec<Dataset>,
}

/// Accept loop: one thread per connection (loopback serving scale).
pub fn serve(listener: TcpListener, state: Arc<ServerState>) -> Result<()> {
    loop {
        let (stream, _) = listener.accept()?;
        let st = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = http::handle_connection(stream, st) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
}

fn plain(status: u16, body: impl Into<String>) -> Response {
    (status, "text/plain".into(), body.into(), Vec::new())
}

/// Route one parsed request.
pub fn route(state: &ServerState, method: &str, path: &str, body: &[u8]) -> Response {
    match (method, path) {
        ("GET", "/healthz") => plain(200, "ok\n"),
        ("GET", "/metrics") => plain(200, state.router.render_metrics()),
        ("POST", "/v1/generate") => generate(state, body),
        _ => plain(404, "not found\n"),
    }
}

fn generate(state: &ServerState, body: &[u8]) -> Response {
    let req = match GenerateBody::parse(body) {
        Ok(r) => r,
        Err(e) => return plain(400, format!("bad request: {e}\n")),
    };
    let prompt = match (&req.prompt_tokens, &req.dataset) {
        (Some(p), _) if p.len() >= 2 => p.clone(),
        (Some(_), _) => return plain(400, "prompt too short\n"),
        (None, Some(ds)) => {
            let seed = req.seed.unwrap_or(0);
            match state.datasets.iter().find(|d| &d.name == ds) {
                Some(d) => d.sample(1, seed).pop().unwrap(),
                None => return plain(400, format!("unknown dataset {ds}\n")),
            }
        }
        (None, None) => return plain(400, "need prompt_tokens or dataset\n"),
    };
    let lane = match req.lane.as_deref() {
        None | Some("interactive") => Lane::Interactive,
        Some("batch") => Lane::Batch,
        Some(other) => return plain(400, format!("unknown lane {other}\n")),
    };
    let t0 = Instant::now();
    let gen = ServeRequest {
        prompt,
        max_new_tokens: req.max_new_tokens,
        // The request seed also pins the row's sampling stream, making
        // generations reproducible under any batching or placement
        // (DESIGN.md §7, §14.1).
        seed: req.seed,
        lane,
        tenant: req.tenant.unwrap_or(0),
        enqueued: t0,
    };
    match state.router.generate(gen) {
        Ok(row) => {
            let resp = json::obj(vec![
                ("tokens", json::arr_u32(&row.tokens)),
                ("n_tokens", json::num(row.tokens.len() as f64)),
                ("iterations", json::num(row.iterations as f64)),
                ("accepted", json::num(row.accepted as f64)),
                ("block_efficiency", json::num(row.block_efficiency())),
                ("finish", json::str_v(&format!("{:?}", row.finish))),
                ("latency_ms", json::num(t0.elapsed().as_secs_f64() * 1e3)),
            ]);
            (200, "application/json".into(), json::to_string(&resp), Vec::new())
        }
        // Load shed: explicit 429 with a Retry-After hint — the
        // serving-tier overload contract (DESIGN.md §14.1).
        Err(RouteError::Shed { retry_after_s }) => (
            429,
            "text/plain".into(),
            "over capacity — request shed\n".into(),
            vec![("retry-after".into(), retry_after_s.to_string())],
        ),
        // Admission rejections (ring budget, bad prompt) and engine
        // failures surface the engine's error chain.
        Err(e @ RouteError::Failed(_)) => plain(400, format!("{e}\n")),
    }
}
