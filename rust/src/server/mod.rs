//! HTTP/1.1 JSON front-end over std::net (thread-per-connection; the
//! offline image has no tokio, and the engine serialises on one device
//! anyway — see DESIGN.md §3).
//!
//! Endpoints:
//! * `POST /v1/generate` — body `{"prompt_tokens": [...], "dataset":
//!   "gsm8k", "max_new_tokens": 48, "seed": 0}`; either explicit tokens or
//!   a dataset to sample a prompt from.  Responds with generated tokens +
//!   decode stats.
//! * `GET /metrics`  — plain-text metrics exposition.
//! * `GET /healthz`  — liveness.

pub mod client;
pub mod http;

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{Coordinator, GenRequest};
use crate::util::json::{self, Value};
use crate::workload::Dataset;

/// Parsed generate-request body.
#[derive(Debug, Default)]
pub struct GenerateBody {
    pub prompt_tokens: Option<Vec<u32>>,
    pub dataset: Option<String>,
    pub max_new_tokens: Option<usize>,
    pub seed: Option<u64>,
}

impl GenerateBody {
    pub fn parse(body: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(body)?;
        let v = json::parse(text)?;
        Ok(GenerateBody {
            prompt_tokens: v.get("prompt_tokens").and_then(Value::as_arr).map(|a| {
                a.iter().filter_map(Value::as_u64).map(|x| x as u32).collect()
            }),
            dataset: v.get("dataset").and_then(Value::as_str).map(String::from),
            max_new_tokens: v.get("max_new_tokens").and_then(Value::as_usize),
            seed: v.get("seed").and_then(Value::as_u64),
        })
    }
}

/// Shared server state.
pub struct ServerState {
    pub coordinator: Coordinator,
    pub datasets: Vec<Dataset>,
}

/// Accept loop: one thread per connection (loopback serving scale).
pub fn serve(listener: TcpListener, state: Arc<ServerState>) -> Result<()> {
    loop {
        let (stream, _) = listener.accept()?;
        let st = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = http::handle_connection(stream, st) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
}

/// Route one parsed request to (status, content-type, body).
pub fn route(state: &ServerState, method: &str, path: &str, body: &[u8]) -> (u16, String, String) {
    match (method, path) {
        ("GET", "/healthz") => (200, "text/plain".into(), "ok\n".into()),
        ("GET", "/metrics") => (200, "text/plain".into(), state.coordinator.metrics.render()),
        ("POST", "/v1/generate") => generate(state, body),
        _ => (404, "text/plain".into(), "not found\n".into()),
    }
}

fn generate(state: &ServerState, body: &[u8]) -> (u16, String, String) {
    let req = match GenerateBody::parse(body) {
        Ok(r) => r,
        Err(e) => return (400, "text/plain".into(), format!("bad request: {e}\n")),
    };
    let prompt = match (&req.prompt_tokens, &req.dataset) {
        (Some(p), _) if p.len() >= 2 => p.clone(),
        (Some(_), _) => return (400, "text/plain".into(), "prompt too short\n".into()),
        (None, Some(ds)) => {
            let seed = req.seed.unwrap_or(0);
            match state.datasets.iter().find(|d| &d.name == ds) {
                Some(d) => d.sample(1, seed).pop().unwrap(),
                None => return (400, "text/plain".into(), format!("unknown dataset {ds}\n")),
            }
        }
        (None, None) => {
            return (400, "text/plain".into(), "need prompt_tokens or dataset\n".into())
        }
    };
    let t0 = Instant::now();
    let gen = GenRequest {
        prompt,
        max_new_tokens: req.max_new_tokens,
        // The request seed also pins the row's sampling stream, making
        // generations reproducible under any batching (DESIGN.md §7).
        seed: req.seed,
        enqueued: t0,
    };
    match state.coordinator.generate(gen) {
        Ok(row) => {
            let resp = json::obj(vec![
                ("tokens", json::arr_u32(&row.tokens)),
                ("n_tokens", json::num(row.tokens.len() as f64)),
                ("iterations", json::num(row.iterations as f64)),
                ("accepted", json::num(row.accepted as f64)),
                ("block_efficiency", json::num(row.block_efficiency())),
                ("finish", json::str_v(&format!("{:?}", row.finish))),
                ("latency_ms", json::num(t0.elapsed().as_secs_f64() * 1e3)),
            ]);
            (200, "application/json".into(), json::to_string(&resp))
        }
        Err(e) => (429, "text/plain".into(), format!("{e:#}\n")),
    }
}
