//! Hand-rolled HTTP/1.1 parsing/serialisation — enough protocol for the
//! JSON API (request line, headers, Content-Length bodies, keep-alive; no
//! chunked encoding).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::ServerState;

/// A parsed request head + body.
#[derive(Debug, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Client sent `Connection: close` — the server must close after
    /// responding (clients using read-to-EOF depend on this).
    pub close: bool,
}

/// Parse one HTTP/1.1 request from a raw byte buffer.
/// Returns `(request, bytes_consumed)` or None if incomplete.
pub fn parse_request(buf: &[u8]) -> Result<Option<(Request, usize)>> {
    let Some(head_end) = find_subsequence(buf, b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| anyhow!("non-utf8 header"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| anyhow!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();
    let mut content_length = 0usize;
    let mut close = false;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().map_err(|_| anyhow!("bad content-length"))?;
            }
            if k.trim().eq_ignore_ascii_case("connection")
                && v.trim().eq_ignore_ascii_case("close")
            {
                close = true;
            }
        }
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let body = buf[body_start..body_start + content_length].to_vec();
    Ok(Some((Request { method, path, body, close }, body_start + content_length)))
}

/// Serialise a response.  `extra_headers` carries per-response headers
/// (e.g. `retry-after` on a shed 429).
pub fn render_response(
    status: u16,
    content_type: &str,
    extra_headers: &[(String, String)],
    body: &str,
) -> Vec<u8> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    let extra: String =
        extra_headers.iter().map(|(k, v)| format!("{k}: {v}\r\n")).collect();
    format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\n{extra}connection: keep-alive\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Serve requests on one connection until EOF (keep-alive loop).
pub fn handle_connection(mut stream: TcpStream, state: Arc<ServerState>) -> Result<()> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    loop {
        match parse_request(&buf)? {
            Some((req, consumed)) => {
                buf.drain(..consumed);
                let (status, ctype, body, headers) =
                    super::route(&state, &req.method, &req.path, &req.body);
                stream.write_all(&render_response(status, &ctype, &headers, &body))?;
                if req.close {
                    return Ok(());
                }
            }
            None => {
                let n = stream.read(&mut chunk)?;
                if n == 0 {
                    return Ok(());
                }
                buf.extend_from_slice(&chunk[..n]);
                if buf.len() > 1 << 20 {
                    return Err(anyhow!("request too large"));
                }
            }
        }
    }
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
        let (req, used) = parse_request(raw).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
        assert_eq!(used, raw.len());
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd";
        let (req, used) = parse_request(raw).unwrap().unwrap();
        assert_eq!(req.body, b"abcd");
        assert_eq!(used, raw.len());
    }

    #[test]
    fn incomplete_returns_none() {
        assert!(parse_request(b"GET / HT").unwrap().is_none());
        assert!(parse_request(b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\nab")
            .unwrap()
            .is_none());
    }

    #[test]
    fn pipelined_requests_consume_correctly() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let (r1, used) = parse_request(raw).unwrap().unwrap();
        assert_eq!(r1.path, "/a");
        let (r2, _) = parse_request(&raw[used..]).unwrap().unwrap();
        assert_eq!(r2.path, "/b");
    }

    #[test]
    fn response_has_content_length() {
        let r = render_response(200, "text/plain", &[], "hello");
        let s = String::from_utf8(r).unwrap();
        assert!(s.contains("content-length: 5"));
        assert!(s.ends_with("hello"));
    }

    #[test]
    fn response_carries_extra_headers() {
        let hdrs = vec![("retry-after".to_string(), "1".to_string())];
        let r = render_response(429, "text/plain", &hdrs, "shed\n");
        let s = String::from_utf8(r).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests"));
        assert!(s.contains("retry-after: 1\r\n"));
        // extra headers stay inside the head, before the blank line
        let head = s.split("\r\n\r\n").next().unwrap();
        assert!(head.contains("retry-after: 1"));
    }
}
