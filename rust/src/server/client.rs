//! Tiny blocking HTTP client for the examples and load tests (avoids an
//! HTTP client dependency for loopback calls).

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

use crate::util::json::{self, Value};

/// POST a JSON body and return (status, body).
pub fn post_json(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let (status, _headers, body) = post_json_full(addr, path, body)?;
    Ok((status, body))
}

/// POST a JSON body and return (status, headers, body) — headers are
/// lower-cased `(name, value)` pairs (e.g. `retry-after` on a shed 429).
pub fn post_json_full(
    addr: &str,
    path: &str,
    body: &str,
) -> Result<(u16, Vec<(String, String)>, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    read_response(stream)
}

/// GET a path and return (status, body).
pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let (status, _headers, body) = read_response(stream)?;
    Ok((status, body))
}

fn read_response(mut stream: TcpStream) -> Result<(u16, Vec<(String, String)>, String)> {
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed response"))?;
    let (head, body) = text.split_once("\r\n\r\n").unwrap_or((&text, ""));
    let headers: Vec<(String, String)> = head
        .split("\r\n")
        .skip(1) // status line
        .filter_map(|line| {
            line.split_once(':')
                .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        })
        .collect();
    Ok((status, headers, body.to_string()))
}

/// Parsed generate response.
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub tokens: Vec<u32>,
    pub n_tokens: usize,
    pub iterations: usize,
    pub accepted: usize,
    pub block_efficiency: f64,
    pub finish: String,
    pub latency_ms: f64,
}

/// Generate via the API and parse the response.
pub fn generate(
    addr: &str,
    dataset: &str,
    max_new_tokens: usize,
    seed: u64,
) -> Result<GenerateResponse> {
    let body = json::to_string(&json::obj(vec![
        ("dataset", json::str_v(dataset)),
        ("max_new_tokens", json::num(max_new_tokens as f64)),
        ("seed", json::num(seed as f64)),
    ]));
    let (status, body) = post_json(addr, "/v1/generate", &body)?;
    if status != 200 {
        return Err(anyhow!("generate failed: {status}: {body}"));
    }
    let v = json::parse(&body)?;
    Ok(GenerateResponse {
        tokens: v
            .get("tokens")
            .and_then(Value::as_arr)
            .map(|a| a.iter().filter_map(Value::as_u64).map(|x| x as u32).collect())
            .unwrap_or_default(),
        n_tokens: v.usize_field("n_tokens")?,
        iterations: v.usize_field("iterations")?,
        accepted: v.usize_field("accepted")?,
        block_efficiency: v.f64_field("block_efficiency")?,
        finish: v.str_field("finish")?,
        latency_ms: v.f64_field("latency_ms")?,
    })
}
