//! Small statistics toolkit for the experiment harness: seed aggregation
//! (mean ± std, as in the paper's tables), histograms and chi-square-ish
//! distribution distance used by the losslessness tests.

/// Mean and sample standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// Relative improvement in percent: `(new - old) / old * 100`.
pub fn improvement_pct(old: f64, new: f64) -> f64 {
    if old == 0.0 {
        return 0.0;
    }
    (new - old) / old * 100.0
}

/// A `mean ± std` cell as the paper prints them.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cell {
    pub mean: f64,
    pub std: f64,
}

impl Cell {
    pub fn from_samples(xs: &[f64]) -> Self {
        let (mean, std) = mean_std(xs);
        Cell { mean, std }
    }
}

impl std::fmt::Display for Cell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2} ± {:.2}", self.mean, self.std)
    }
}

/// Per-seed paired improvement cell: the paper computes improvement per
/// seed and then averages, which is what produces its small stds.
pub fn paired_improvement(old: &[f64], new: &[f64]) -> Cell {
    let imps: Vec<f64> =
        old.iter().zip(new).map(|(o, n)| improvement_pct(*o, *n)).collect();
    Cell::from_samples(&imps)
}

/// Empirical distribution over fixed-length token sequences.
pub mod empirical {
    use std::collections::HashMap;

    #[derive(Default, Clone, Debug)]
    pub struct SeqDist {
        pub counts: HashMap<Vec<u32>, u64>,
        pub total: u64,
    }

    impl SeqDist {
        pub fn add(&mut self, seq: &[u32]) {
            *self.counts.entry(seq.to_vec()).or_insert(0) += 1;
            self.total += 1;
        }

        /// Total-variation distance to another empirical distribution.
        pub fn tv(&self, other: &SeqDist) -> f64 {
            let mut keys: std::collections::HashSet<&Vec<u32>> =
                self.counts.keys().collect();
            keys.extend(other.counts.keys());
            let mut s = 0.0;
            for k in keys {
                let p = *self.counts.get(k).unwrap_or(&0) as f64 / self.total.max(1) as f64;
                let q =
                    *other.counts.get(k).unwrap_or(&0) as f64 / other.total.max(1) as f64;
                s += (p - q).abs();
            }
            0.5 * s
        }

        /// TV distance to an exact distribution given by a probability fn.
        pub fn tv_exact(&self, prob: impl Fn(&[u32]) -> f64, support: &[Vec<u32>]) -> f64 {
            let mut s = 0.0;
            for k in support {
                let p = *self.counts.get(k).unwrap_or(&0) as f64 / self.total.max(1) as f64;
                s += (p - prob(k)).abs();
            }
            0.5 * s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[5.0]).1, 0.0);
    }

    #[test]
    fn improvement() {
        assert!((improvement_pct(2.0, 2.2) - 10.0).abs() < 1e-9);
        let c = paired_improvement(&[2.0, 4.0], &[2.2, 4.4]);
        assert!((c.mean - 10.0).abs() < 1e-9);
        assert!(c.std < 1e-9);
    }

    #[test]
    fn seq_dist_tv() {
        use empirical::SeqDist;
        let mut a = SeqDist::default();
        let mut b = SeqDist::default();
        for _ in 0..50 {
            a.add(&[0]);
            b.add(&[1]);
        }
        assert!((a.tv(&b) - 1.0).abs() < 1e-12);
        assert_eq!(a.tv(&a), 0.0);
    }
}
