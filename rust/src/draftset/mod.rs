//! Multi-draft speculation data layout: a [`DraftSet`] holds `K`
//! independently drafted candidate continuations ("paths") of length
//! `gamma` for every batch row, flattened to a `(B·K)`-row scratch batch
//! so a single batched target pass scores every path at once
//! (DESIGN.md §9).
//!
//! Layout contract (shared with the backends' flattened forwards):
//!
//! * flat scratch row of `(row, path)` is `row * K + path`
//!   ([`DraftSet::flat_row`]) — row-major by engine slot, path minor, so
//!   all of one slot's paths are contiguous;
//! * `drafts` is row-major `(B, K, gamma)` i32, `qs` is
//!   `(B, K, gamma, V)` f32 (drafter next-token distributions along each
//!   path), and `ps` — filled by
//!   [`crate::backend::Backend::target_score_multi`] — is
//!   `(B, K, gamma + 1, V)` f32;
//! * path 0 of every row replays the single-draft stream for the row's
//!   seed, which is what makes `Algo::MultiPath { k: 1 }` bit-identical
//!   to `Algo::Block` (test-enforced).
//!
//! Verification of a set happens per row through
//! [`crate::verify::multipath_verify`]; [`DraftSet::row_views`] produces
//! the per-path matrices that kernel consumes.

use anyhow::anyhow;

use crate::verify::ProbMatrix;

/// `K` candidate draft paths of length `gamma` for each of `B` batch
/// rows, plus their drafter (and, once scored, target) distributions.
#[derive(Clone, Debug)]
pub struct DraftSet {
    /// Engine batch rows `B`.
    pub batch: usize,
    /// Candidate paths per row `K`.
    pub k: usize,
    /// Draft block length per path.
    pub gamma: usize,
    /// Vocabulary size `V`.
    pub vocab: usize,
    /// Draft tokens, row-major `(B, K, gamma)`.
    pub drafts: Vec<i32>,
    /// Drafter next-token distributions along each path,
    /// `(B, K, gamma, V)`: `qs[b, p, j] = M_s(. | c_b, X_p^j)`.
    pub qs: Vec<f32>,
    /// Target next-token distributions along each path,
    /// `(B, K, gamma + 1, V)`; empty until target scoring fills it
    /// ([`DraftSet::set_ps`]).
    pub ps: Vec<f32>,
    /// Per-serving-row draft lengths for ragged variable-gamma sets
    /// (DESIGN.md §15): `row_gammas[b] <= gamma`, with `gamma` staying
    /// the layout stride of `drafts`/`qs`/`ps` (entries past a row's own
    /// length are padding).  `None` = the uniform layout, every row at
    /// `gamma`.
    pub row_gammas: Option<Vec<usize>>,
}

impl DraftSet {
    /// Wrap freshly drafted paths (target scores still pending).
    pub fn new(
        batch: usize,
        k: usize,
        gamma: usize,
        vocab: usize,
        drafts: Vec<i32>,
        qs: Vec<f32>,
    ) -> anyhow::Result<Self> {
        if batch == 0 || k == 0 || gamma == 0 || vocab == 0 {
            return Err(anyhow!(
                "degenerate draft set shape (B {batch}, K {k}, gamma {gamma}, V {vocab})"
            ));
        }
        if drafts.len() != batch * k * gamma {
            return Err(anyhow!(
                "drafts shape {} != B*K*gamma = {}",
                drafts.len(),
                batch * k * gamma
            ));
        }
        if qs.len() != batch * k * gamma * vocab {
            return Err(anyhow!(
                "qs shape {} != B*K*gamma*V = {}",
                qs.len(),
                batch * k * gamma * vocab
            ));
        }
        Ok(DraftSet { batch, k, gamma, vocab, drafts, qs, ps: Vec::new(), row_gammas: None })
    }

    /// Mark the set ragged: row `b`'s paths carry `row_gammas[b]` real
    /// draft tokens (the rest of the `gamma` stride is padding).  Every
    /// per-row accessor ([`DraftSet::row_views_into`] and friends) then
    /// serves that row's own length.
    pub fn set_row_gammas(&mut self, row_gammas: Vec<usize>) -> anyhow::Result<()> {
        if row_gammas.len() != self.batch {
            return Err(anyhow!(
                "row_gammas shape {} != batch {}",
                row_gammas.len(),
                self.batch
            ));
        }
        if let Some(&bad) = row_gammas.iter().find(|&&g| g == 0 || g > self.gamma) {
            return Err(anyhow!("row gamma {bad} outside 1..={}", self.gamma));
        }
        self.row_gammas = Some(row_gammas);
        Ok(())
    }

    /// Draft length of one serving row: its ragged override, else the
    /// uniform `gamma`.
    pub fn row_gamma(&self, row: usize) -> usize {
        self.row_gammas.as_ref().map_or(self.gamma, |v| v[row])
    }

    /// Rows of the flattened scratch batch: `B * K`.
    ///
    /// Crate-internal since the tree API redesign: the flat `(B·K)`
    /// layout is an implementation detail of the deprecated
    /// `draft_multi`/`target_score_multi` shims (DESIGN.md §13.6) and no
    /// longer part of the public surface.
    pub(crate) fn flat_rows(&self) -> usize {
        self.batch * self.k
    }

    /// Flat scratch-batch row index of `(row, path)` (crate-internal;
    /// see [`DraftSet::flat_rows`]).
    #[inline]
    pub(crate) fn flat_row(&self, row: usize, path: usize) -> usize {
        debug_assert!(row < self.batch && path < self.k);
        row * self.k + path
    }

    /// Has [`DraftSet::set_ps`] run yet?
    pub fn scored(&self) -> bool {
        !self.ps.is_empty()
    }

    /// Attach the target scores, `(B, K, gamma + 1, V)` row-major.
    pub fn set_ps(&mut self, ps: Vec<f32>) -> anyhow::Result<()> {
        let want = self.flat_rows() * (self.gamma + 1) * self.vocab;
        if ps.len() != want {
            return Err(anyhow!("ps shape {} != B*K*(gamma+1)*V = {want}", ps.len()));
        }
        self.ps = ps;
        Ok(())
    }

    /// One path's draft tokens.
    pub fn path_drafts(&self, row: usize, path: usize) -> &[i32] {
        let r = self.flat_row(row, path);
        &self.drafts[r * self.gamma..(r + 1) * self.gamma]
    }

    /// One path's draft tokens as the `u32` the verify kernels take.
    pub fn path_drafts_u32(&self, row: usize, path: usize) -> Vec<u32> {
        self.path_drafts(row, path).iter().map(|&x| x as u32).collect()
    }

    /// One path's drafter distributions as a `(gamma, V)` matrix.
    pub fn qs_matrix(&self, row: usize, path: usize) -> ProbMatrix {
        let r = self.flat_row(row, path);
        let n = self.gamma * self.vocab;
        ProbMatrix::from_f32(self.gamma, self.vocab, &self.qs[r * n..(r + 1) * n])
    }

    /// One path's target distributions as a `(gamma + 1, V)` matrix.
    /// Errors if the set has not been target-scored yet.
    pub fn ps_matrix(&self, row: usize, path: usize) -> anyhow::Result<ProbMatrix> {
        if !self.scored() {
            return Err(anyhow!("draft set has not been target-scored"));
        }
        let r = self.flat_row(row, path);
        let n = (self.gamma + 1) * self.vocab;
        Ok(ProbMatrix::from_f32(self.gamma + 1, self.vocab, &self.ps[r * n..(r + 1) * n]))
    }

    /// All `K` per-path views of one row, in the shape
    /// [`crate::verify::multipath_verify`] consumes: `(ps, qs, drafts)`
    /// with one entry per path.
    #[allow(clippy::type_complexity)]
    pub fn row_views(
        &self,
        row: usize,
    ) -> anyhow::Result<(Vec<ProbMatrix>, Vec<ProbMatrix>, Vec<Vec<u32>>)> {
        let mut views = RowViews::default();
        self.row_views_into(row, &mut views)?;
        Ok((views.ps, views.qs, views.drafts))
    }

    /// Fill a reusable [`RowViews`] with row `row`'s per-path view —
    /// the allocation-recycling twin of [`DraftSet::row_views`], used by
    /// the fused multipath iteration so one scratch serves every row of
    /// every iteration (DESIGN.md §10).
    pub fn row_views_into(&self, row: usize, out: &mut RowViews) -> anyhow::Result<()> {
        if !self.scored() {
            return Err(anyhow!("draft set has not been target-scored"));
        }
        out.ps.resize_with(self.k, || ProbMatrix::new(0, 0));
        out.qs.resize_with(self.k, || ProbMatrix::new(0, 0));
        out.drafts.resize_with(self.k, Vec::new);
        // Ragged rows serve their own length: the first `g` (+1) entries
        // of each `gamma`-stride block are the real data, the rest is
        // padding (row-major, so the real prefix is contiguous).
        let g = self.row_gamma(row);
        let np = (self.gamma + 1) * self.vocab;
        let nq = self.gamma * self.vocab;
        for path in 0..self.k {
            let r = self.flat_row(row, path);
            out.ps[path].copy_from_f32(
                g + 1,
                self.vocab,
                &self.ps[r * np..r * np + (g + 1) * self.vocab],
            );
            out.qs[path].copy_from_f32(g, self.vocab, &self.qs[r * nq..r * nq + g * self.vocab]);
            out.drafts[path].clear();
            out.drafts[path]
                .extend(self.path_drafts(row, path)[..g].iter().map(|&x| x as u32));
        }
        Ok(())
    }
}

/// Reusable per-row multipath verify views, in the exact shape
/// [`crate::verify::multipath_verify`] consumes.  Holding one of these
/// across rows and iterations avoids re-allocating `K` f64 matrices per
/// verified row — the verify-side analogue of the backend's persistent
/// `(B·K)`-row KV scratch.
#[derive(Default)]
pub struct RowViews {
    /// Per-path target matrices, `(gamma + 1, V)` each.
    pub ps: Vec<ProbMatrix>,
    /// Per-path drafter matrices, `(gamma, V)` each.
    pub qs: Vec<ProbMatrix>,
    /// Per-path draft tokens, `gamma` each.
    pub drafts: Vec<Vec<u32>>,
}

/// Reusable node-table views of one [`DraftTree`] row, the direct input
/// of [`crate::verify::tree_verify`].  Allocation-recycling analogue of
/// [`RowViews`] for the tree hot path.
pub struct TreeViews {
    /// Target law at the pending token, `(1, V)`.
    pub ps_root: ProbMatrix,
    /// Target law at each node, `(n_nodes, V)`.
    pub node_ps: ProbMatrix,
    /// Drafter law each node was sampled from, `(n_nodes, V)`.
    pub node_qs: ProbMatrix,
    /// Node tokens.
    pub tokens: Vec<u32>,
}

impl Default for TreeViews {
    fn default() -> Self {
        TreeViews {
            ps_root: ProbMatrix::new(0, 0),
            node_ps: ProbMatrix::new(0, 0),
            node_qs: ProbMatrix::new(0, 0),
            tokens: Vec::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Prefix-sharing token trees (DESIGN.md §13)
// ---------------------------------------------------------------------------

/// Where the tree drafter may merge coincident draws into a shared node.
///
/// Every one of the `K` leaves always keeps its own full independent draft
/// stream (the flat multipath streams, verbatim), so the drafted *law* is
/// exactly multipath's regardless of policy — sharing only deduplicates
/// the compute and storage of draws that happen to coincide.  That is
/// what keeps tree speculation lossless and bit-identical to
/// `Algo::MultiPath{k}` (DESIGN.md §13.4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BranchPolicy {
    /// Branch at high-entropy positions: when the drafter's top-2
    /// probability gap at a node is *below* `threshold` the position is
    /// treated as high-entropy and every leaf keeps its own child even
    /// on coincident draws; at or above it, leaves that drew the same
    /// token from the same node share one child.  `threshold = 0.0`
    /// (the default) shares every coincidence; `f64::INFINITY` never
    /// shares.
    EntropyGap { threshold: f64 },
    /// Never share: every leaf gets its own `gamma`-deep chain
    /// (`k * gamma` nodes) — the exact layout twin of the flat multipath
    /// [`DraftSet`], used by the deprecated-API shims and the
    /// bit-identity ladder tests.
    Disjoint,
}

impl Default for BranchPolicy {
    fn default() -> Self {
        BranchPolicy::EntropyGap { threshold: 0.0 }
    }
}

/// One batch row's token tree: a node table (parents strictly before
/// children) plus the `K` leaves, each at depth `gamma - 1`.
///
/// Node `i` holds exactly one drafted token and one KV entry in the
/// backend's tree scratch cache (slot `len + i`).  `qs` row `i` is the
/// drafter law node `i` was *sampled from* (its parent's forward output;
/// root children share the pending token's output), and `ps` row `i` —
/// filled by scoring — is the target law *at* node `i` (the forward
/// output of the node's own token).  `ps_root` is the target law at the
/// pending token, shared by every leaf path as verification row 0.
#[derive(Clone, Debug, Default)]
pub struct TreeRow {
    /// Node tokens.
    pub tokens: Vec<i32>,
    /// Node -> parent table; `-1` = child of the pending root token.
    /// Parents always precede children (`parent[i] < i`).
    pub parent: Vec<i32>,
    /// Node depth, `0..gamma` (root children are depth 0).
    pub depth: Vec<usize>,
    /// Leaf node index per draft path, in path order; path `p`'s drafts
    /// are the root-to-leaf token walk ending at `leaves[p]`.
    pub leaves: Vec<usize>,
    /// Drafter law each node was sampled from, `(n_nodes, V)` row-major.
    pub qs: Vec<f32>,
    /// Target law at each node, `(n_nodes, V)`; empty until scored.
    pub ps: Vec<f32>,
    /// Target law at the pending token, `(V,)`; empty until scored.
    pub ps_root: Vec<f32>,
}

impl TreeRow {
    pub fn n_nodes(&self) -> usize {
        self.tokens.len()
    }

    /// Root-to-leaf node-index chain of one path (length `gamma`).
    pub fn path_nodes(&self, path: usize) -> Vec<usize> {
        let mut chain = Vec::new();
        let mut n = self.leaves[path] as i32;
        while n >= 0 {
            chain.push(n as usize);
            n = self.parent[n as usize];
        }
        chain.reverse();
        chain
    }

    /// One path's draft tokens (the root-to-leaf token walk).
    pub fn path_drafts(&self, path: usize) -> Vec<i32> {
        self.path_nodes(path).iter().map(|&i| self.tokens[i]).collect()
    }
}

/// Prefix-sharing token trees for every batch row — the successor of the
/// flat `(B·K)` [`DraftSet`] layout.  Produced by
/// [`crate::backend::Backend::draft_tree`], scored in place by
/// [`crate::backend::Backend::score_tree`].
#[derive(Clone, Debug)]
pub struct DraftTree {
    /// Engine batch rows `B`.
    pub batch: usize,
    /// Draft paths (leaves) per row `K`.
    pub k: usize,
    /// Draft block length per path.
    pub gamma: usize,
    /// Vocabulary size `V`.
    pub vocab: usize,
    /// One tree per batch row.
    pub rows: Vec<TreeRow>,
}

impl DraftTree {
    /// Wrap freshly drafted trees, validating the per-row invariants:
    /// parents precede children, every leaf sits at depth `gamma - 1`,
    /// and `qs` covers every node.
    pub fn new(
        batch: usize,
        k: usize,
        gamma: usize,
        vocab: usize,
        rows: Vec<TreeRow>,
    ) -> anyhow::Result<Self> {
        if batch == 0 || k == 0 || gamma == 0 || vocab == 0 {
            return Err(anyhow!(
                "degenerate draft tree shape (B {batch}, K {k}, gamma {gamma}, V {vocab})"
            ));
        }
        if rows.len() != batch {
            return Err(anyhow!("tree rows {} != batch {batch}", rows.len()));
        }
        for (bi, row) in rows.iter().enumerate() {
            let n = row.n_nodes();
            if n > k * gamma || row.parent.len() != n || row.depth.len() != n {
                return Err(anyhow!("row {bi}: inconsistent node table ({n} nodes)"));
            }
            if row.leaves.len() != k {
                return Err(anyhow!("row {bi}: {} leaves != K {k}", row.leaves.len()));
            }
            for i in 0..n {
                let p = row.parent[i];
                if p >= i as i32 || (p >= 0 && row.depth[p as usize] + 1 != row.depth[i]) {
                    return Err(anyhow!("row {bi}: node {i} breaks parent/depth order"));
                }
                if p < 0 && row.depth[i] != 0 {
                    return Err(anyhow!("row {bi}: root child {i} at depth {}", row.depth[i]));
                }
            }
            for (p, &leaf) in row.leaves.iter().enumerate() {
                if leaf >= n || row.depth[leaf] + 1 != gamma {
                    return Err(anyhow!("row {bi}: leaf {p} is not at depth gamma-1"));
                }
            }
            if row.qs.len() != n * vocab {
                return Err(anyhow!("row {bi}: qs shape {} != n*V", row.qs.len()));
            }
        }
        Ok(DraftTree { batch, k, gamma, vocab, rows })
    }

    /// Total nodes across every row — the count of drafted tokens the
    /// target actually scores (the prefix-sharing FLOP win: at most
    /// `B * K * gamma`, strictly fewer whenever draws coincided).
    pub fn total_nodes(&self) -> usize {
        self.rows.iter().map(TreeRow::n_nodes).sum()
    }

    /// Has [`DraftTree::set_row_scores`]/backend scoring filled every row?
    pub fn scored(&self) -> bool {
        self.rows.iter().all(|r| !r.ps_root.is_empty() && r.ps.len() == r.qs.len())
    }

    /// Per-leaf verification views of one row, in the exact shape
    /// [`crate::verify::multipath_verify`] consumes — each leaf's
    /// root-to-leaf walk materialised as a flat path.  Shared-prefix
    /// nodes contribute the *same* `ps`/`qs` rows to every leaf that
    /// passes through them.
    pub fn row_views_into(&self, row: usize, out: &mut RowViews) -> anyhow::Result<()> {
        if !self.scored() {
            return Err(anyhow!("draft tree has not been target-scored"));
        }
        let tr = &self.rows[row];
        let v = self.vocab;
        out.ps.resize_with(self.k, || ProbMatrix::new(0, 0));
        out.qs.resize_with(self.k, || ProbMatrix::new(0, 0));
        out.drafts.resize_with(self.k, Vec::new);
        let mut flat_p = vec![0.0f32; (self.gamma + 1) * v];
        let mut flat_q = vec![0.0f32; self.gamma * v];
        for path in 0..self.k {
            let chain = tr.path_nodes(path);
            flat_p[..v].copy_from_slice(&tr.ps_root);
            for (j, &i) in chain.iter().enumerate() {
                flat_p[(j + 1) * v..(j + 2) * v].copy_from_slice(&tr.ps[i * v..(i + 1) * v]);
                flat_q[j * v..(j + 1) * v].copy_from_slice(&tr.qs[i * v..(i + 1) * v]);
            }
            out.ps[path].copy_from_f32(self.gamma + 1, v, &flat_p);
            out.qs[path].copy_from_f32(self.gamma, v, &flat_q);
            out.drafts[path].clear();
            out.drafts[path].extend(chain.iter().map(|&i| tr.tokens[i] as u32));
        }
        Ok(())
    }

    /// Expand the tree into the flat `(B·K)` [`DraftSet`] layout (every
    /// shared node duplicated per path) — the bridge the deprecated
    /// `draft_multi`/`target_score_multi` shims ride on.  Scored trees
    /// yield scored sets.
    pub fn flatten(&self) -> anyhow::Result<DraftSet> {
        let (v, g) = (self.vocab, self.gamma);
        let mut drafts = vec![0i32; self.batch * self.k * g];
        let mut qs = vec![0.0f32; self.batch * self.k * g * v];
        let scored = self.scored();
        let mut ps = if scored { vec![0.0f32; self.batch * self.k * (g + 1) * v] } else { Vec::new() };
        for (bi, tr) in self.rows.iter().enumerate() {
            for path in 0..self.k {
                let r = bi * self.k + path;
                for (j, &i) in tr.path_nodes(path).iter().enumerate() {
                    drafts[r * g + j] = tr.tokens[i];
                    qs[(r * g + j) * v..(r * g + j + 1) * v]
                        .copy_from_slice(&tr.qs[i * v..(i + 1) * v]);
                    if scored {
                        let o = (r * (g + 1) + j + 1) * v;
                        ps[o..o + v].copy_from_slice(&tr.ps[i * v..(i + 1) * v]);
                    }
                }
                if scored {
                    let o = r * (g + 1) * v;
                    ps[o..o + v].copy_from_slice(&tr.ps_root);
                }
            }
        }
        let mut set = DraftSet::new(self.batch, self.k, g, v, drafts, qs)?;
        if scored {
            set.set_ps(ps)?;
        }
        Ok(set)
    }

    /// Degenerate (no-sharing) tree from a flat set: each path becomes
    /// its own chain, node order path-major — the inverse of
    /// [`DraftTree::flatten`] under [`BranchPolicy::Disjoint`].  Used by
    /// the `target_score_multi` shim to score pre-built flat sets
    /// through the tree API.
    pub fn from_flat(set: &DraftSet) -> Self {
        let (v, g) = (set.vocab, set.gamma);
        let mut rows = Vec::with_capacity(set.batch);
        for bi in 0..set.batch {
            let mut tr = TreeRow::default();
            for path in 0..set.k {
                let r = bi * set.k + path;
                for j in 0..g {
                    let i = tr.n_nodes();
                    tr.tokens.push(set.drafts[r * g + j]);
                    tr.parent.push(if j == 0 { -1 } else { i as i32 - 1 });
                    tr.depth.push(j);
                    tr.qs.extend_from_slice(&set.qs[(r * g + j) * v..(r * g + j + 1) * v]);
                    if set.scored() {
                        let o = (r * (g + 1) + j + 1) * v;
                        tr.ps.extend_from_slice(&set.ps[o..o + v]);
                    }
                }
                tr.leaves.push(tr.n_nodes() - 1);
                if set.scored() {
                    tr.ps_root = set.ps[r * (g + 1) * v..r * (g + 1) * v + v].to_vec();
                }
            }
            rows.push(tr);
        }
        DraftTree { batch: set.batch, k: set.k, gamma: g, vocab: v, rows }
    }

    /// Fill reusable node-table views of one row for
    /// [`crate::verify::tree_verify`]: unlike [`DraftTree::row_views_into`]
    /// this never duplicates shared rows — the verifier indexes the node
    /// table directly.
    pub fn tree_views_into(&self, row: usize, out: &mut TreeViews) -> anyhow::Result<()> {
        if !self.scored() {
            return Err(anyhow!("draft tree has not been target-scored"));
        }
        let tr = &self.rows[row];
        let (n, v) = (tr.n_nodes(), self.vocab);
        out.ps_root.copy_from_f32(1, v, &tr.ps_root);
        out.node_ps.copy_from_f32(n, v, &tr.ps);
        out.node_qs.copy_from_f32(n, v, &tr.qs);
        out.tokens.clear();
        out.tokens.extend(tr.tokens.iter().map(|&t| t as u32));
        Ok(())
    }

    /// Write one row's per-node target scores (called by backends from
    /// their tree-scoring forward): `ps_root` is the law at the pending
    /// token, `node_ps` is `(n_nodes, V)` row-major.
    pub fn set_row_scores(
        &mut self,
        row: usize,
        ps_root: Vec<f32>,
        node_ps: Vec<f32>,
    ) -> anyhow::Result<()> {
        let tr = &mut self.rows[row];
        if ps_root.len() != self.vocab || node_ps.len() != tr.n_nodes() * self.vocab {
            return Err(anyhow!(
                "row {row}: score shapes ({}, {}) != (V, n*V)",
                ps_root.len(),
                node_ps.len()
            ));
        }
        tr.ps_root = ps_root;
        tr.ps = node_ps;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_set() -> DraftSet {
        // B = 2, K = 2, gamma = 2, V = 3; drafts count up so every
        // (row, path, j) cell is distinguishable.
        let drafts: Vec<i32> = (0..8).collect();
        let qs: Vec<f32> = (0..2 * 2 * 2 * 3).map(|i| i as f32).collect();
        DraftSet::new(2, 2, 2, 3, drafts, qs).unwrap()
    }

    #[test]
    fn flat_layout_offsets() {
        let set = tiny_set();
        assert_eq!(set.flat_rows(), 4);
        assert_eq!(set.flat_row(0, 0), 0);
        assert_eq!(set.flat_row(0, 1), 1);
        assert_eq!(set.flat_row(1, 0), 2);
        assert_eq!(set.path_drafts(0, 1), &[2, 3]);
        assert_eq!(set.path_drafts(1, 0), &[4, 5]);
        assert_eq!(set.path_drafts_u32(1, 1), vec![6, 7]);
        // qs rows land at the right per-path offsets.
        let m = set.qs_matrix(1, 0);
        assert_eq!(m.rows, 2);
        assert_eq!(m.row(0), &[12.0, 13.0, 14.0]);
    }

    #[test]
    fn scoring_lifecycle_and_shape_checks() {
        let mut set = tiny_set();
        assert!(!set.scored());
        assert!(set.ps_matrix(0, 0).is_err());
        assert!(set.row_views(0).is_err());
        assert!(set.set_ps(vec![0.0; 5]).is_err());
        let ps: Vec<f32> = (0..4 * 3 * 3).map(|i| i as f32).collect();
        set.set_ps(ps).unwrap();
        assert!(set.scored());
        let m = set.ps_matrix(0, 1).unwrap();
        assert_eq!(m.rows, 3);
        assert_eq!(m.row(0), &[9.0, 10.0, 11.0]);
        let (ps_v, qs_v, d_v) = set.row_views(1).unwrap();
        assert_eq!((ps_v.len(), qs_v.len(), d_v.len()), (2, 2, 2));
        assert_eq!(d_v[1], vec![6, 7]);
    }

    #[test]
    fn row_views_into_matches_row_views() {
        let mut set = tiny_set();
        let ps: Vec<f32> = (0..4 * 3 * 3).map(|i| i as f32).collect();
        set.set_ps(ps).unwrap();
        let mut views = RowViews::default();
        for row in 0..2 {
            let (ps_v, qs_v, d_v) = set.row_views(row).unwrap();
            set.row_views_into(row, &mut views).unwrap();
            assert_eq!(views.drafts, d_v, "row {row}");
            for path in 0..2 {
                for i in 0..3 {
                    assert_eq!(views.ps[path].row(i), ps_v[path].row(i));
                }
                for i in 0..2 {
                    assert_eq!(views.qs[path].row(i), qs_v[path].row(i));
                }
            }
        }
        // Unscored sets are rejected.
        let mut fresh = RowViews::default();
        assert!(tiny_set().row_views_into(0, &mut fresh).is_err());
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(DraftSet::new(2, 2, 2, 3, vec![0; 7], vec![0.0; 24]).is_err());
        assert!(DraftSet::new(2, 2, 2, 3, vec![0; 8], vec![0.0; 23]).is_err());
        assert!(DraftSet::new(0, 2, 2, 3, vec![], vec![]).is_err());
        assert!(DraftSet::new(2, 0, 2, 3, vec![], vec![]).is_err());
    }

    /// A 1-row K=2, gamma=2 tree sharing the depth-0 node:
    /// node 0 (tok 5, root child) -> nodes 1 (tok 6, leaf 0) and 2 (tok 7, leaf 1).
    fn shared_tree() -> DraftTree {
        let v = 3;
        let row = TreeRow {
            tokens: vec![5, 6, 7],
            parent: vec![-1, 0, 0],
            depth: vec![0, 1, 1],
            leaves: vec![1, 2],
            qs: (0..3 * v).map(|i| i as f32).collect(),
            ps: Vec::new(),
            ps_root: Vec::new(),
        };
        DraftTree::new(1, 2, 2, v, vec![row]).unwrap()
    }

    #[test]
    fn tree_paths_walk_root_to_leaf() {
        let tree = shared_tree();
        assert_eq!(tree.total_nodes(), 3);
        assert!(!tree.scored());
        assert_eq!(tree.rows[0].path_nodes(0), vec![0, 1]);
        assert_eq!(tree.rows[0].path_nodes(1), vec![0, 2]);
        assert_eq!(tree.rows[0].path_drafts(0), vec![5, 6]);
        assert_eq!(tree.rows[0].path_drafts(1), vec![5, 7]);
    }

    #[test]
    fn tree_flatten_duplicates_shared_prefix_and_roundtrips() {
        let mut tree = shared_tree();
        let v = tree.vocab;
        tree.set_row_scores(
            0,
            vec![0.5, 0.25, 0.25],
            (0..3 * v).map(|i| 100.0 + i as f32).collect(),
        )
        .unwrap();
        assert!(tree.scored());
        let set = tree.flatten().unwrap();
        assert_eq!((set.batch, set.k, set.gamma, set.vocab), (1, 2, 2, v));
        assert_eq!(set.drafts, vec![5, 6, 5, 7]);
        // Both paths carry the shared node's q row at position 0.
        assert_eq!(set.qs[..v], set.qs[2 * v..3 * v]);
        // ps layout: row r = [ps_root, node ps...].
        assert_eq!(&set.ps[..v], &[0.5, 0.25, 0.25]);
        assert_eq!(set.ps[v], 100.0); // path 0 node 0
        assert_eq!(set.ps[3 * v + v], 100.0); // path 1 shares node 0's score

        // Flat -> tree -> flat is the identity (degenerate disjoint tree).
        let back = DraftTree::from_flat(&set);
        assert_eq!(back.total_nodes(), 4); // no sharing in the flat layout
        let set2 = back.flatten().unwrap();
        assert_eq!(set2.drafts, set.drafts);
        assert_eq!(set2.qs, set.qs);
        assert_eq!(set2.ps, set.ps);
    }

    #[test]
    fn tree_row_views_match_flat_row_views() {
        let mut tree = shared_tree();
        let v = tree.vocab;
        tree.set_row_scores(
            0,
            vec![0.5, 0.25, 0.25],
            (0..3 * v).map(|i| 100.0 + i as f32).collect(),
        )
        .unwrap();
        let set = tree.flatten().unwrap();
        let mut tv = RowViews::default();
        let mut fv = RowViews::default();
        tree.row_views_into(0, &mut tv).unwrap();
        set.row_views_into(0, &mut fv).unwrap();
        assert_eq!(tv.drafts, fv.drafts);
        for path in 0..2 {
            for i in 0..3 {
                assert_eq!(tv.ps[path].row(i), fv.ps[path].row(i));
            }
            for i in 0..2 {
                assert_eq!(tv.qs[path].row(i), fv.qs[path].row(i));
            }
        }
        // Unscored trees are rejected.
        let mut fresh = RowViews::default();
        assert!(shared_tree().row_views_into(0, &mut fresh).is_err());
    }

    #[test]
    fn tree_rejects_bad_structure() {
        let v = 3;
        let ok = || TreeRow {
            tokens: vec![5, 6],
            parent: vec![-1, 0],
            depth: vec![0, 1],
            leaves: vec![1],
            qs: vec![0.0; 2 * v],
            ps: Vec::new(),
            ps_root: Vec::new(),
        };
        assert!(DraftTree::new(1, 1, 2, v, vec![ok()]).is_ok());
        // Child before parent.
        let mut bad = ok();
        bad.parent = vec![1, -1];
        bad.depth = vec![1, 0];
        bad.leaves = vec![0];
        assert!(DraftTree::new(1, 1, 2, v, vec![bad]).is_err());
        // Leaf not at depth gamma-1.
        let mut bad = ok();
        bad.leaves = vec![0];
        assert!(DraftTree::new(1, 1, 2, v, vec![bad]).is_err());
        // qs shape mismatch.
        let mut bad = ok();
        bad.qs.pop();
        assert!(DraftTree::new(1, 1, 2, v, vec![bad]).is_err());
        // Wrong leaf count for K.
        assert!(DraftTree::new(1, 2, 2, v, vec![ok()]).is_err());
        // Wrong row count.
        assert!(DraftTree::new(2, 1, 2, v, vec![ok()]).is_err());
    }
}
