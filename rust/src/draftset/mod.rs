//! Multi-draft speculation data layout: a [`DraftSet`] holds `K`
//! independently drafted candidate continuations ("paths") of length
//! `gamma` for every batch row, flattened to a `(B·K)`-row scratch batch
//! so a single batched target pass scores every path at once
//! (DESIGN.md §9).
//!
//! Layout contract (shared with the backends' flattened forwards):
//!
//! * flat scratch row of `(row, path)` is `row * K + path`
//!   ([`DraftSet::flat_row`]) — row-major by engine slot, path minor, so
//!   all of one slot's paths are contiguous;
//! * `drafts` is row-major `(B, K, gamma)` i32, `qs` is
//!   `(B, K, gamma, V)` f32 (drafter next-token distributions along each
//!   path), and `ps` — filled by
//!   [`crate::backend::Backend::target_score_multi`] — is
//!   `(B, K, gamma + 1, V)` f32;
//! * path 0 of every row replays the single-draft stream for the row's
//!   seed, which is what makes `Algo::MultiPath { k: 1 }` bit-identical
//!   to `Algo::Block` (test-enforced).
//!
//! Verification of a set happens per row through
//! [`crate::verify::multipath_verify`]; [`DraftSet::row_views`] produces
//! the per-path matrices that kernel consumes.

use anyhow::anyhow;

use crate::verify::ProbMatrix;

/// `K` candidate draft paths of length `gamma` for each of `B` batch
/// rows, plus their drafter (and, once scored, target) distributions.
#[derive(Clone, Debug)]
pub struct DraftSet {
    /// Engine batch rows `B`.
    pub batch: usize,
    /// Candidate paths per row `K`.
    pub k: usize,
    /// Draft block length per path.
    pub gamma: usize,
    /// Vocabulary size `V`.
    pub vocab: usize,
    /// Draft tokens, row-major `(B, K, gamma)`.
    pub drafts: Vec<i32>,
    /// Drafter next-token distributions along each path,
    /// `(B, K, gamma, V)`: `qs[b, p, j] = M_s(. | c_b, X_p^j)`.
    pub qs: Vec<f32>,
    /// Target next-token distributions along each path,
    /// `(B, K, gamma + 1, V)`; empty until target scoring fills it
    /// ([`DraftSet::set_ps`]).
    pub ps: Vec<f32>,
}

impl DraftSet {
    /// Wrap freshly drafted paths (target scores still pending).
    pub fn new(
        batch: usize,
        k: usize,
        gamma: usize,
        vocab: usize,
        drafts: Vec<i32>,
        qs: Vec<f32>,
    ) -> anyhow::Result<Self> {
        if batch == 0 || k == 0 || gamma == 0 || vocab == 0 {
            return Err(anyhow!(
                "degenerate draft set shape (B {batch}, K {k}, gamma {gamma}, V {vocab})"
            ));
        }
        if drafts.len() != batch * k * gamma {
            return Err(anyhow!(
                "drafts shape {} != B*K*gamma = {}",
                drafts.len(),
                batch * k * gamma
            ));
        }
        if qs.len() != batch * k * gamma * vocab {
            return Err(anyhow!(
                "qs shape {} != B*K*gamma*V = {}",
                qs.len(),
                batch * k * gamma * vocab
            ));
        }
        Ok(DraftSet { batch, k, gamma, vocab, drafts, qs, ps: Vec::new() })
    }

    /// Rows of the flattened scratch batch: `B * K`.
    pub fn flat_rows(&self) -> usize {
        self.batch * self.k
    }

    /// Flat scratch-batch row index of `(row, path)`.
    #[inline]
    pub fn flat_row(&self, row: usize, path: usize) -> usize {
        debug_assert!(row < self.batch && path < self.k);
        row * self.k + path
    }

    /// Has [`DraftSet::set_ps`] run yet?
    pub fn scored(&self) -> bool {
        !self.ps.is_empty()
    }

    /// Attach the target scores, `(B, K, gamma + 1, V)` row-major.
    pub fn set_ps(&mut self, ps: Vec<f32>) -> anyhow::Result<()> {
        let want = self.flat_rows() * (self.gamma + 1) * self.vocab;
        if ps.len() != want {
            return Err(anyhow!("ps shape {} != B*K*(gamma+1)*V = {want}", ps.len()));
        }
        self.ps = ps;
        Ok(())
    }

    /// One path's draft tokens.
    pub fn path_drafts(&self, row: usize, path: usize) -> &[i32] {
        let r = self.flat_row(row, path);
        &self.drafts[r * self.gamma..(r + 1) * self.gamma]
    }

    /// One path's draft tokens as the `u32` the verify kernels take.
    pub fn path_drafts_u32(&self, row: usize, path: usize) -> Vec<u32> {
        self.path_drafts(row, path).iter().map(|&x| x as u32).collect()
    }

    /// One path's drafter distributions as a `(gamma, V)` matrix.
    pub fn qs_matrix(&self, row: usize, path: usize) -> ProbMatrix {
        let r = self.flat_row(row, path);
        let n = self.gamma * self.vocab;
        ProbMatrix::from_f32(self.gamma, self.vocab, &self.qs[r * n..(r + 1) * n])
    }

    /// One path's target distributions as a `(gamma + 1, V)` matrix.
    /// Errors if the set has not been target-scored yet.
    pub fn ps_matrix(&self, row: usize, path: usize) -> anyhow::Result<ProbMatrix> {
        if !self.scored() {
            return Err(anyhow!("draft set has not been target-scored"));
        }
        let r = self.flat_row(row, path);
        let n = (self.gamma + 1) * self.vocab;
        Ok(ProbMatrix::from_f32(self.gamma + 1, self.vocab, &self.ps[r * n..(r + 1) * n]))
    }

    /// All `K` per-path views of one row, in the shape
    /// [`crate::verify::multipath_verify`] consumes: `(ps, qs, drafts)`
    /// with one entry per path.
    #[allow(clippy::type_complexity)]
    pub fn row_views(
        &self,
        row: usize,
    ) -> anyhow::Result<(Vec<ProbMatrix>, Vec<ProbMatrix>, Vec<Vec<u32>>)> {
        let mut views = RowViews::default();
        self.row_views_into(row, &mut views)?;
        Ok((views.ps, views.qs, views.drafts))
    }

    /// Fill a reusable [`RowViews`] with row `row`'s per-path view —
    /// the allocation-recycling twin of [`DraftSet::row_views`], used by
    /// the fused multipath iteration so one scratch serves every row of
    /// every iteration (DESIGN.md §10).
    pub fn row_views_into(&self, row: usize, out: &mut RowViews) -> anyhow::Result<()> {
        if !self.scored() {
            return Err(anyhow!("draft set has not been target-scored"));
        }
        out.ps.resize_with(self.k, || ProbMatrix::new(0, 0));
        out.qs.resize_with(self.k, || ProbMatrix::new(0, 0));
        out.drafts.resize_with(self.k, Vec::new);
        let np = (self.gamma + 1) * self.vocab;
        let nq = self.gamma * self.vocab;
        for path in 0..self.k {
            let r = self.flat_row(row, path);
            out.ps[path].copy_from_f32(self.gamma + 1, self.vocab, &self.ps[r * np..(r + 1) * np]);
            out.qs[path].copy_from_f32(self.gamma, self.vocab, &self.qs[r * nq..(r + 1) * nq]);
            out.drafts[path].clear();
            out.drafts[path].extend(self.path_drafts(row, path).iter().map(|&x| x as u32));
        }
        Ok(())
    }
}

/// Reusable per-row multipath verify views, in the exact shape
/// [`crate::verify::multipath_verify`] consumes.  Holding one of these
/// across rows and iterations avoids re-allocating `K` f64 matrices per
/// verified row — the verify-side analogue of the backend's persistent
/// `(B·K)`-row KV scratch.
#[derive(Default)]
pub struct RowViews {
    /// Per-path target matrices, `(gamma + 1, V)` each.
    pub ps: Vec<ProbMatrix>,
    /// Per-path drafter matrices, `(gamma, V)` each.
    pub qs: Vec<ProbMatrix>,
    /// Per-path draft tokens, `gamma` each.
    pub drafts: Vec<Vec<u32>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_set() -> DraftSet {
        // B = 2, K = 2, gamma = 2, V = 3; drafts count up so every
        // (row, path, j) cell is distinguishable.
        let drafts: Vec<i32> = (0..8).collect();
        let qs: Vec<f32> = (0..2 * 2 * 2 * 3).map(|i| i as f32).collect();
        DraftSet::new(2, 2, 2, 3, drafts, qs).unwrap()
    }

    #[test]
    fn flat_layout_offsets() {
        let set = tiny_set();
        assert_eq!(set.flat_rows(), 4);
        assert_eq!(set.flat_row(0, 0), 0);
        assert_eq!(set.flat_row(0, 1), 1);
        assert_eq!(set.flat_row(1, 0), 2);
        assert_eq!(set.path_drafts(0, 1), &[2, 3]);
        assert_eq!(set.path_drafts(1, 0), &[4, 5]);
        assert_eq!(set.path_drafts_u32(1, 1), vec![6, 7]);
        // qs rows land at the right per-path offsets.
        let m = set.qs_matrix(1, 0);
        assert_eq!(m.rows, 2);
        assert_eq!(m.row(0), &[12.0, 13.0, 14.0]);
    }

    #[test]
    fn scoring_lifecycle_and_shape_checks() {
        let mut set = tiny_set();
        assert!(!set.scored());
        assert!(set.ps_matrix(0, 0).is_err());
        assert!(set.row_views(0).is_err());
        assert!(set.set_ps(vec![0.0; 5]).is_err());
        let ps: Vec<f32> = (0..4 * 3 * 3).map(|i| i as f32).collect();
        set.set_ps(ps).unwrap();
        assert!(set.scored());
        let m = set.ps_matrix(0, 1).unwrap();
        assert_eq!(m.rows, 3);
        assert_eq!(m.row(0), &[9.0, 10.0, 11.0]);
        let (ps_v, qs_v, d_v) = set.row_views(1).unwrap();
        assert_eq!((ps_v.len(), qs_v.len(), d_v.len()), (2, 2, 2));
        assert_eq!(d_v[1], vec![6, 7]);
    }

    #[test]
    fn row_views_into_matches_row_views() {
        let mut set = tiny_set();
        let ps: Vec<f32> = (0..4 * 3 * 3).map(|i| i as f32).collect();
        set.set_ps(ps).unwrap();
        let mut views = RowViews::default();
        for row in 0..2 {
            let (ps_v, qs_v, d_v) = set.row_views(row).unwrap();
            set.row_views_into(row, &mut views).unwrap();
            assert_eq!(views.drafts, d_v, "row {row}");
            for path in 0..2 {
                for i in 0..3 {
                    assert_eq!(views.ps[path].row(i), ps_v[path].row(i));
                }
                for i in 0..2 {
                    assert_eq!(views.qs[path].row(i), qs_v[path].row(i));
                }
            }
        }
        // Unscored sets are rejected.
        let mut fresh = RowViews::default();
        assert!(tiny_set().row_views_into(0, &mut fresh).is_err());
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(DraftSet::new(2, 2, 2, 3, vec![0; 7], vec![0.0; 24]).is_err());
        assert!(DraftSet::new(2, 2, 2, 3, vec![0; 8], vec![0.0; 23]).is_err());
        assert!(DraftSet::new(0, 2, 2, 3, vec![], vec![]).is_err());
        assert!(DraftSet::new(2, 0, 2, 3, vec![], vec![]).is_err());
    }
}
