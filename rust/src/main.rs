//! `specd` — CLI launcher for the block-verification serving stack.
//!
//! Subcommands:
//! * `serve`  — start the HTTP serving front-end (coordinator + engine).
//! * `run`    — one-off batch decode of a dataset, printing stats.
//! * `tables` — regenerate the paper's tables/figures (see DESIGN.md §4).
//! * `sim`    — distribution-level simulator studies (no backend needed).
//!
//! `--backend native` (default) runs the pure-Rust CPU transformer —
//! trained weights when `artifacts/` exists, deterministic seeded weights
//! otherwise, so every subcommand works out of the box.  `--backend pjrt`
//! selects the AOT HLO/PJRT path (requires building with
//! `--features pjrt` and a full artifact bundle).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use specd::backend::{Backend, NativeBackend};
use specd::config::{Config, EngineConfig, ExperimentConfig};
use specd::engine::host::HostVerifyEngine;
use specd::engine::spec::SpecEngine;
use specd::experiments::{motivating_table, Harness};
use specd::serve::Router;
use specd::server::{serve, ServerState};
use specd::sim::{self, MarkovPair};
use specd::util::argparse::Args;
use specd::verify::Algo;
use specd::workload::Dataset;

const USAGE: &str = "\
specd — block-verification speculative decoding server

USAGE: specd <serve|run|tables|sim> [options]
  common:   --config <file.json>  --artifacts <dir>  --backend native|pjrt
  serve:    --addr <ip:port>
  run:      --dataset gsm8k --algo block|token|greedy|multipath:<k>
            --gamma 8 --drafter xxs --prompts 16 --seed 0
  tables:   --table 1|3|4..8|fig3|fig4|motivating|all
            --prompts <n> --seeds <n>
  sim:      --vocab 8 --gamma 4 --tokens 200000
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(std::path::Path::new(p))?,
        None => Config::default(),
    };
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts = Some(PathBuf::from(a));
    }
    match args.subcommand.as_deref() {
        Some(cmd @ ("serve" | "run" | "tables")) => dispatch(cmd, &cfg, &args),
        Some("sim") => cmd_sim(&args),
        _ => {
            eprint!("{USAGE}");
            Ok(())
        }
    }
}

/// Instantiate the selected backend and run the subcommand over it.
fn dispatch(cmd: &str, cfg: &Config, args: &Args) -> Result<()> {
    match args.get_or("backend", "native") {
        "native" => {
            let backend = Arc::new(NativeBackend::from_artifacts_or_seeded(
                &cfg.artifacts_dir(),
                cfg.engine.seed,
            )?);
            if backend.info().artifacts_dir.is_none() {
                eprintln!(
                    "[specd] no artifact bundle at {} — using deterministic seeded weights",
                    cfg.artifacts_dir().display()
                );
            }
            run_cmd(cmd, backend, cfg, args)
        }
        "pjrt" => dispatch_pjrt(cmd, cfg, args),
        other => bail!("unknown backend '{other}' (expected native|pjrt)"),
    }
}

#[cfg(feature = "pjrt")]
fn dispatch_pjrt(cmd: &str, cfg: &Config, args: &Args) -> Result<()> {
    let backend = Arc::new(specd::backend::PjrtBackend::load(&cfg.artifacts_dir())?);
    run_cmd(cmd, backend, cfg, args)
}

#[cfg(not(feature = "pjrt"))]
fn dispatch_pjrt(_cmd: &str, _cfg: &Config, _args: &Args) -> Result<()> {
    bail!("this binary was built without the `pjrt` feature; rebuild with --features pjrt")
}

fn run_cmd<B: Backend>(cmd: &str, backend: Arc<B>, cfg: &Config, args: &Args) -> Result<()> {
    match cmd {
        "serve" => cmd_serve(backend, cfg, args),
        "run" => cmd_run(backend, cfg, args),
        "tables" => cmd_tables(backend, cfg, args),
        _ => unreachable!("dispatch() only routes engine subcommands"),
    }
}

fn cmd_serve<B: Backend>(backend: Arc<B>, cfg: &Config, args: &Args) -> Result<()> {
    let datasets = Dataset::load_or_synthetic(backend.info().artifacts_dir.as_deref())?;
    let addr = args.get_or("addr", &cfg.server.addr).to_string();
    let router = Router::spawn(backend, cfg.engine.clone(), &cfg.server, &cfg.router)?;
    let state = Arc::new(ServerState { router, datasets });
    let listener = std::net::TcpListener::bind(&addr)?;
    println!(
        "specd serving on http://{addr}  (POST /v1/generate, {} replica(s))",
        state.router.replica_count()
    );
    serve(listener, state)
}

fn cmd_run<B: Backend>(backend: Arc<B>, cfg: &Config, args: &Args) -> Result<()> {
    let algo_s = args.get_or("algo", "block");
    let algo = Algo::parse(algo_s).ok_or_else(|| anyhow::anyhow!("unknown algo {algo_s}"))?;
    let gamma = args.usize_or("gamma", 8)?;
    let drafter = args.get_or("drafter", "xxs").to_string();
    let dataset = args.get_or("dataset", "gsm8k");
    let n_prompts = args.usize_or("prompts", 16)?;
    let seed = args.u64_or("seed", 0)?;

    let datasets = Dataset::load_or_synthetic(backend.info().artifacts_dir.as_deref())?;
    let ds = datasets
        .iter()
        .find(|d| d.name == dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    let engine_cfg = EngineConfig {
        gamma,
        algo,
        drafter: drafter.clone(),
        max_new_tokens: cfg.engine.max_new_tokens,
        host_verify: !algo.fused(),
        seed,
        draft_precision: cfg.engine.draft_precision,
    };
    let prompts = ds.take(n_prompts);
    let reports = if algo.fused() {
        SpecEngine::new(backend, engine_cfg)?.run_prompts(&prompts, seed)?
    } else {
        HostVerifyEngine::new(backend, engine_cfg)?.run_prompts(&prompts, seed)?
    };
    let mut iters = 0usize;
    let mut emitted = 0usize;
    let mut out_tokens = 0usize;
    let mut wall = 0.0f64;
    for r in &reports {
        for row in &r.rows {
            iters += row.iterations;
            emitted += row.emitted;
            out_tokens += row.tokens.len();
        }
        wall += r.wall.as_secs_f64();
    }
    println!(
        "dataset={dataset} algo={algo} gamma={gamma} drafter={drafter}\n\
         prompts={} tokens={out_tokens} target_calls={iters}\n\
         block_efficiency={:.3} tokens/sec={:.1} wall={:.2}s",
        prompts.len(),
        emitted as f64 / iters.max(1) as f64,
        out_tokens as f64 / wall.max(1e-9),
        wall
    );
    Ok(())
}

fn cmd_tables<B: Backend>(backend: Arc<B>, cfg: &Config, args: &Args) -> Result<()> {
    let table = args.get_or("table", "1");
    if table == "motivating" {
        println!("{}", motivating_table());
        return Ok(());
    }
    let mut exp_cfg: ExperimentConfig = cfg.experiments.clone();
    if let Some(p) = args.get("prompts") {
        exp_cfg.prompts_per_dataset = p.parse()?;
    }
    if let Some(s) = args.get("seeds") {
        exp_cfg.seeds = (0..s.parse::<u64>()?).collect();
    }
    let h =
        Harness::new(backend, exp_cfg)?.with_draft_precision(cfg.engine.draft_precision);
    let text = match table {
        "1" => h.table1()?,
        "3" => h.table3()?,
        "fig3" => h.fig3()?,
        "fig4" => h.fig4()?,
        "4" | "5" | "6" | "7" | "8" => h.appendix_table(table.parse()?)?,
        "all" => {
            let mut s = String::new();
            s.push_str(&motivating_table());
            s.push('\n');
            s.push_str(&h.table1()?);
            s.push('\n');
            s.push_str(&h.table3()?);
            s.push('\n');
            s.push_str(&h.fig3()?);
            s.push('\n');
            s.push_str(&h.fig4()?);
            for i in 4..=8 {
                s.push('\n');
                s.push_str(&h.appendix_table(i)?);
            }
            s
        }
        other => bail!("unknown table '{other}'"),
    };
    println!("{text}");
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let vocab = args.usize_or("vocab", 8)?;
    let gamma = args.usize_or("gamma", 4)?;
    let tokens = args.usize_or("tokens", 200_000)?;
    println!("{}", motivating_table());
    println!("Simulator: BE vs drafter quality (vocab={vocab}, gamma={gamma})");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>10}",
        "mix", "token BE", "block BE", "greedy BE", "impr.%"
    );
    for mix in [0.2, 0.4, 0.6, 0.8, 0.9, 0.95] {
        let pair = MarkovPair::random(vocab, mix, 7);
        let t = sim::simulate(&pair, gamma, Algo::Token, tokens, 1).block_efficiency();
        let b = sim::simulate(&pair, gamma, Algo::Block, tokens, 1).block_efficiency();
        let g = sim::simulate(&pair, gamma, Algo::Greedy, tokens, 1).block_efficiency();
        println!(
            "{mix:>6.2} {t:>12.3} {b:>12.3} {g:>12.3} {:>9.2}%",
            (b - t) / t * 100.0
        );
    }
    Ok(())
}
