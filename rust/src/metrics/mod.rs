//! Serving metrics: counters, latency histograms and throughput windows.
//! Exposed through the HTTP `/metrics` endpoint and the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Lock-free monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket latency histogram (microseconds, log2 buckets up to ~67 s).
#[derive(Debug)]
pub struct LatencyHist {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist {
            buckets: (0..27).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// `(upper_bucket_edge_us, count)` for every non-empty bucket,
    /// ascending — the exposition-format histogram lines (the
    /// `queue_wait_us` satellite of the serving tier).
    pub fn nonzero(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((1u64 << i, c))
            })
            .collect()
    }

    /// Approximate quantile from the log2 buckets (upper bucket edge).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let want = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= want {
                return 1u64 << i;
            }
        }
        1u64 << (self.buckets.len() - 1)
    }
}

/// Fixed-bucket histogram over small non-negative integers — the
/// accepted-prefix-length (`tau`) distribution per engine, one bucket per
/// length `0..=MAX_VALUE` (larger values clamp into the top bucket).
/// Each engine runs one verification algorithm, so this is the per-algo
/// histogram exported next to the slot-occupancy counters.
#[derive(Debug)]
pub struct ValueHist {
    buckets: Vec<AtomicU64>,
}

impl Default for ValueHist {
    fn default() -> Self {
        Self::new()
    }
}

impl ValueHist {
    /// Largest tracked value (gammas are capped at `L/4 = 24` by the
    /// serving shapes; 32 leaves headroom).
    pub const MAX_VALUE: usize = 32;

    pub fn new() -> Self {
        ValueHist { buckets: (0..=Self::MAX_VALUE).map(|_| AtomicU64::new(0)).collect() }
    }

    pub fn observe(&self, value: usize) {
        self.buckets[value.min(Self::MAX_VALUE)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self, value: usize) -> u64 {
        self.buckets[value.min(Self::MAX_VALUE)].load(Ordering::Relaxed)
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn mean(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            sum += (i as u64 * c) as f64;
            n += c;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// `(value, count)` for every non-empty bucket, ascending.
    pub fn nonzero(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((i, c))
            })
            .collect()
    }
}

/// Engine/coordinator metric bundle.
#[derive(Default, Debug)]
pub struct EngineMetrics {
    pub requests_enqueued: Counter,
    pub requests_completed: Counter,
    pub tokens_emitted: Counter,
    pub drafts_accepted: Counter,
    /// Drafted tokens the target scored, summed over iterations
    /// (`SpecIterOut::drafted`).  The per-committed-token ratio is the
    /// speculation *cost* axis: `Algo::Tree` wins here over flat
    /// multipath at equal tau by scoring shared prefixes once
    /// (DESIGN.md §13; gated in `benches/serving.rs`).
    pub drafts_scored: Counter,
    pub iterations: Counter,
    pub batches: Counter,
    /// Admissions spliced into a live decode stream (continuous batching;
    /// every admission is a per-slot KV refill, DESIGN.md §7).
    pub slots_refilled: Counter,
    /// Slot-iterations spent decoding a real request...
    pub slot_iters_busy: Counter,
    /// ...out of slot-iterations available (`B` per engine step); the
    /// ratio is the batcher's slot occupancy.
    pub slot_iters_total: Counter,
    /// Accepted-prefix-length (`tau`) distribution across row-iterations
    /// — per algorithm, since an engine runs exactly one.
    pub accepted_len_hist: ValueHist,
    /// Rows admitted per batched admission prefill (DESIGN.md §11.3) —
    /// mean > 1 is the amortisation win made observable: that many
    /// admissions shared one prefill forward.
    pub prefill_batch_size: ValueHist,
    /// Wall-clock of the draft forward phase per engine iteration, as
    /// reported by the backend (`SpecIterOut::draft_us`) or measured
    /// around `draft_block` on the host-verify path — where the
    /// quantised-draft speedup shows up in `/metrics`.
    pub draft_forward_us: LatencyHist,
    /// Wall-clock of the target scoring forward per engine iteration
    /// (`SpecIterOut::target_us`, or measured around `target_score` on
    /// the host-verify path) — the denominator of every kernel-substrate
    /// speedup, so SIMD-kernel wins are observable next to the draft
    /// phase they multiply with.
    pub target_forward_us: LatencyHist,
    pub queue_wait: LatencyHist,
    pub iter_latency: LatencyHist,
    pub request_latency: LatencyHist,
    /// Wall-clock of one batched admission — prefill forward plus the KV
    /// splices into the live stream (DESIGN.md §16).  Under the paged
    /// layout a warm-prefix splice is a page-table clone, so this is
    /// where the zero-copy admission win is observable (gated in
    /// `benches/serving.rs`, `kv_paging` section).
    pub admission_us: LatencyHist,
    /// Prompt positions the admission forward actually covered (suffix
    /// lengths under warm-prefix admission, full prompt lengths cold) —
    /// against [`EngineMetrics::prompt_positions`] this is the
    /// prefix-cache work saving made observable (DESIGN.md §14.3).
    pub prefill_positions: Counter,
    /// Total prompt positions admitted (the cold-prefill cost baseline).
    pub prompt_positions: Counter,
    /// Gamma the adaptive controller chose, per slot-iteration
    /// (DESIGN.md §15).  With the controller off this stays at the
    /// configured gamma; its spread under load is the adaptivity made
    /// observable.
    pub gamma_chosen: ValueHist,
    /// Path count K the controller chose per slot-iteration (1 for
    /// single-draft algorithms).
    pub paths_chosen: ValueHist,
    /// Accumulated controller hysteresis regret, in milli-fractions of
    /// the per-step best arm's objective value
    /// ([`crate::control::Controller::take_regret_milli`]).  Growing
    /// fast relative to `iterations` means the hysteresis margin is
    /// holding the schedule on a stale arm.
    pub controller_regret_milli: Counter,
}

impl EngineMetrics {
    /// Running block efficiency = emitted tokens per target call.
    pub fn block_efficiency(&self) -> f64 {
        let it = self.iterations.get();
        if it == 0 {
            return 0.0;
        }
        self.tokens_emitted.get() as f64 / it as f64
    }

    /// Fraction of slot-iterations that decoded a real request (1.0 =
    /// every slot busy on every step the batcher ran).
    pub fn slot_occupancy(&self) -> f64 {
        let total = self.slot_iters_total.get();
        if total == 0 {
            return 0.0;
        }
        self.slot_iters_busy.get() as f64 / total as f64
    }

    /// Render in a Prometheus-ish plain-text exposition format.
    pub fn render(&self) -> String {
        let mut s = self.render_labeled("");
        // Info line: the process-wide native kernel choice and detected
        // ISA (constant per process — `default_kernel` is OnceLock-cached).
        // Unlabelled only: it is process-global, not per-replica.
        s.push_str(&format!(
            "specd_native_kernel{{kernel=\"{}\",isa=\"{}\"}} 1\n",
            crate::backend::kernels::default_kernel(),
            crate::backend::kernels::active_isa(),
        ));
        // Physical-KV movement counters (process-global like the kernel
        // info line: the paged arena's copy/CoW ledger is one ledger per
        // process, shared by every engine and the serving tier's splices
        // — DESIGN.md §16).
        s.push_str(&format!(
            "specd_kv_bytes_copied_total {}\n",
            crate::backend::kvstats::bytes_copied()
        ));
        s.push_str(&format!(
            "specd_kv_pages_cow_total {}\n",
            crate::backend::kvstats::pages_cow()
        ));
        s
    }

    /// [`EngineMetrics::render`]'s body with an extra label set stamped
    /// on every line (e.g. `replica="2"`; empty = no braces — the plain
    /// single-engine exposition).  The serving-tier router renders one
    /// labelled block per replica next to the unlabelled aggregate
    /// (DESIGN.md §14.5).
    pub fn render_labeled(&self, labels: &str) -> String {
        let lb = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let mut s = String::new();
        {
            let mut put = |k: &str, v: f64| s.push_str(&format!("specd_{k}{lb} {v}\n"));
            put("requests_enqueued", self.requests_enqueued.get() as f64);
            put("requests_completed", self.requests_completed.get() as f64);
            put("tokens_emitted", self.tokens_emitted.get() as f64);
            put("drafts_accepted", self.drafts_accepted.get() as f64);
            put("drafts_scored", self.drafts_scored.get() as f64);
            put("iterations", self.iterations.get() as f64);
            put("batches", self.batches.get() as f64);
            put("slots_refilled", self.slots_refilled.get() as f64);
            put("slot_occupancy", self.slot_occupancy());
            put("block_efficiency", self.block_efficiency());
            put("accepted_len_mean", self.accepted_len_hist.mean());
            put("prefill_batch_size_mean", self.prefill_batch_size.mean());
            put("prefill_positions", self.prefill_positions.get() as f64);
            put("prompt_positions", self.prompt_positions.get() as f64);
            put("draft_forward_mean_us", self.draft_forward_us.mean_us());
            put("draft_forward_p99_us", self.draft_forward_us.quantile_us(0.99) as f64);
            put("target_forward_mean_us", self.target_forward_us.mean_us());
            put("target_forward_p99_us", self.target_forward_us.quantile_us(0.99) as f64);
            put("iter_latency_mean_us", self.iter_latency.mean_us());
            put("iter_latency_p99_us", self.iter_latency.quantile_us(0.99) as f64);
            put("request_latency_mean_us", self.request_latency.mean_us());
            put("queue_wait_mean_us", self.queue_wait.mean_us());
            put("admission_mean_us", self.admission_us.mean_us());
            put("admission_p99_us", self.admission_us.quantile_us(0.99) as f64);
            put("gamma_chosen_mean", self.gamma_chosen.mean());
            put("controller_regret_milli", self.controller_regret_milli.get() as f64);
        }
        let sub = |extra: String| {
            if labels.is_empty() {
                format!("{{{extra}}}")
            } else {
                format!("{{{extra},{labels}}}")
            }
        };
        for (len, n) in self.accepted_len_hist.nonzero() {
            s.push_str(&format!("specd_accepted_len_hist{} {n}\n", sub(format!("len=\"{len}\""))));
        }
        for (n_rows, n) in self.prefill_batch_size.nonzero() {
            s.push_str(&format!(
                "specd_prefill_batch_size{} {n}\n",
                sub(format!("rows=\"{n_rows}\""))
            ));
        }
        for (edge, n) in self.queue_wait.nonzero() {
            s.push_str(&format!("specd_queue_wait_us{} {n}\n", sub(format!("le=\"{edge}\""))));
        }
        for (edge, n) in self.admission_us.nonzero() {
            s.push_str(&format!("specd_admission_us{} {n}\n", sub(format!("le=\"{edge}\""))));
        }
        for (g, n) in self.gamma_chosen.nonzero() {
            s.push_str(&format!("specd_gamma_chosen{} {n}\n", sub(format!("gamma=\"{g}\""))));
        }
        for (k, n) in self.paths_chosen.nonzero() {
            s.push_str(&format!("specd_paths_chosen{} {n}\n", sub(format!("k=\"{k}\""))));
        }
        s
    }
}

/// Wall-clock stopwatch accumulating named phase durations (perf pass).
#[derive(Default, Debug)]
pub struct PhaseTimer {
    phases: Mutex<Vec<(String, Duration)>>,
}

impl PhaseTimer {
    pub fn record(&self, name: &str, d: Duration) {
        self.phases.lock().unwrap().push((name.to_string(), d));
    }

    pub fn totals(&self) -> Vec<(String, Duration)> {
        let mut map: std::collections::BTreeMap<String, Duration> = Default::default();
        for (n, d) in self.phases.lock().unwrap().iter() {
            *map.entry(n.clone()).or_default() += *d;
        }
        map.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_be() {
        let m = EngineMetrics::default();
        m.iterations.add(4);
        m.tokens_emitted.add(14);
        assert!((m.block_efficiency() - 3.5).abs() < 1e-12);
        assert!(m.render().contains("specd_block_efficiency 3.5"));
    }

    #[test]
    fn slot_occupancy_ratio() {
        let m = EngineMetrics::default();
        assert_eq!(m.slot_occupancy(), 0.0);
        m.slot_iters_total.add(8);
        m.slot_iters_busy.add(6);
        assert!((m.slot_occupancy() - 0.75).abs() < 1e-12);
        assert!(m.render().contains("specd_slot_occupancy 0.75"));
    }

    #[test]
    fn accepted_len_hist_buckets_and_render() {
        let m = EngineMetrics::default();
        m.accepted_len_hist.observe(0);
        m.accepted_len_hist.observe(3);
        m.accepted_len_hist.observe(3);
        m.accepted_len_hist.observe(999); // clamps into the top bucket
        assert_eq!(m.accepted_len_hist.count(3), 2);
        assert_eq!(m.accepted_len_hist.count(ValueHist::MAX_VALUE), 1);
        assert_eq!(m.accepted_len_hist.total(), 4);
        assert!((m.accepted_len_hist.mean() - (0.0 + 3.0 + 3.0 + 32.0) / 4.0).abs() < 1e-12);
        assert_eq!(
            m.accepted_len_hist.nonzero(),
            vec![(0, 1), (3, 2), (ValueHist::MAX_VALUE, 1)]
        );
        let r = m.render();
        assert!(r.contains("specd_accepted_len_hist{len=\"3\"} 2"));
        assert!(r.contains("specd_accepted_len_mean"));
    }

    #[test]
    fn prefill_and_draft_metrics_render() {
        let m = EngineMetrics::default();
        m.prefill_batch_size.observe(1);
        m.prefill_batch_size.observe(3);
        m.prefill_batch_size.observe(3);
        m.draft_forward_us.observe(Duration::from_micros(800));
        m.target_forward_us.observe(Duration::from_micros(1700));
        let r = m.render();
        assert!(r.contains("specd_prefill_batch_size{rows=\"3\"} 2"));
        assert!(r.contains("specd_prefill_batch_size_mean"));
        assert!(r.contains("specd_draft_forward_mean_us"));
        assert!(r.contains("specd_target_forward_mean_us"));
        assert!(r.contains("specd_native_kernel{kernel=\""));
        assert!((m.prefill_batch_size.mean() - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn controller_metrics_render() {
        let m = EngineMetrics::default();
        m.gamma_chosen.observe(4);
        m.gamma_chosen.observe(8);
        m.paths_chosen.observe(2);
        m.controller_regret_milli.add(37);
        let r = m.render();
        assert!(r.contains("specd_gamma_chosen{gamma=\"4\"} 1"));
        assert!(r.contains("specd_gamma_chosen{gamma=\"8\"} 1"));
        assert!(r.contains("specd_paths_chosen{k=\"2\"} 1"));
        assert!(r.contains("specd_gamma_chosen_mean 6"));
        assert!(r.contains("specd_controller_regret_milli 37"));
        // Labelled rendering stamps the label on hist lines too.
        let r = m.render_labeled("replica=\"1\"");
        assert!(r.contains("specd_gamma_chosen{gamma=\"4\",replica=\"1\"} 1"));
    }

    #[test]
    fn admission_and_kv_counters_render() {
        let m = EngineMetrics::default();
        m.admission_us.observe(Duration::from_micros(250));
        let r = m.render();
        assert!(r.contains("specd_admission_mean_us"));
        assert!(r.contains("specd_admission_p99_us"));
        assert!(r.contains("specd_admission_us{le=\""));
        // The KV movement ledger renders unlabelled (process-global),
        // like the kernel info line.
        assert!(r.contains("specd_kv_bytes_copied_total "));
        assert!(r.contains("specd_kv_pages_cow_total "));
        // ...and only in the global render, not per-replica blocks.
        let r = m.render_labeled("replica=\"0\"");
        assert!(!r.contains("specd_kv_bytes_copied_total"));
    }

    #[test]
    fn hist_quantiles_monotone() {
        let h = LatencyHist::new();
        for us in [10u64, 100, 1000, 10_000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 4);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let t = PhaseTimer::default();
        t.record("draft", Duration::from_millis(2));
        t.record("draft", Duration::from_millis(3));
        let tot = t.totals();
        assert_eq!(tot.len(), 1);
        assert_eq!(tot[0].1, Duration::from_millis(5));
    }
}
