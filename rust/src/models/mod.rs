//! Model-family metadata: vocabulary layout and variant descriptions.
//! Mirrors python/compile/common.py; the authoritative values ship in
//! `artifacts/manifest.json` and are validated against these constants at
//! runtime load.

/// Vocabulary layout of the synthetic byte-level language.
pub mod vocab {
    pub const PAD: u32 = 0;
    pub const BOS: u32 = 1;
    pub const EOS: u32 = 2;
    pub const MARKER_BASE: u32 = 3;
    pub const NUM_DATASETS: u32 = 8;
    pub const CONTENT_BASE: u32 = 16;
    pub const SIZE: u32 = 256;

    /// Is this a control (non-content) token?
    pub fn is_control(tok: u32) -> bool {
        tok < CONTENT_BASE
    }

    pub fn marker_for(dataset_idx: u32) -> u32 {
        assert!(dataset_idx < NUM_DATASETS);
        MARKER_BASE + dataset_idx
    }
}

/// A model variant in the family (the PALM-2 substitution).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variant {
    pub name: &'static str,
    pub role: Role,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Target,
    Drafter,
}

pub const TARGET: Variant = Variant { name: "target", role: Role::Target };
pub const XXS: Variant = Variant { name: "xxs", role: Role::Drafter };
pub const XXXS: Variant = Variant { name: "xxxs", role: Role::Drafter };

pub const DRAFTERS: [&str; 2] = ["xxs", "xxxs"];

/// Architecture dimensions of a family variant — the same values
/// `python/compile/common.py` bakes into the AOT programs.  The native
/// backend builds its transformers from these; the PJRT backend reads them
/// back from `manifest.json` and validates against the vocab constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub vocab_size: usize,
    pub max_len: usize,
}

impl ModelDims {
    pub const fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub const fn d_ff(&self) -> usize {
        4 * self.d_model
    }
}

/// Default sequence ring length (prompt + generation + draft scratch).
pub const MAX_LEN: usize = 96;
/// Default engine slot count per batch.
pub const BATCH: usize = 4;

pub const TARGET_DIMS: ModelDims = ModelDims {
    n_layers: 3,
    d_model: 128,
    n_heads: 4,
    vocab_size: vocab::SIZE as usize,
    max_len: MAX_LEN,
};

pub const XXS_DIMS: ModelDims = ModelDims {
    n_layers: 2,
    d_model: 64,
    n_heads: 4,
    vocab_size: vocab::SIZE as usize,
    max_len: MAX_LEN,
};

pub const XXXS_DIMS: ModelDims = ModelDims {
    n_layers: 1,
    d_model: 32,
    n_heads: 2,
    vocab_size: vocab::SIZE as usize,
    max_len: MAX_LEN,
};

/// Dimensions for a variant by name.
pub fn dims_for(name: &str) -> Option<ModelDims> {
    match name {
        "target" => Some(TARGET_DIMS),
        "xxs" => Some(XXS_DIMS),
        "xxxs" => Some(XXXS_DIMS),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_layout() {
        assert!(vocab::is_control(vocab::PAD));
        assert!(vocab::is_control(vocab::marker_for(7)));
        assert!(!vocab::is_control(vocab::CONTENT_BASE));
        assert_eq!(vocab::marker_for(0), 3);
    }

    #[test]
    #[should_panic]
    fn marker_out_of_range_panics() {
        vocab::marker_for(8);
    }

    #[test]
    fn dims_match_common_py() {
        let t = dims_for("target").unwrap();
        assert_eq!((t.n_layers, t.d_model, t.n_heads), (3, 128, 4));
        assert_eq!(t.head_dim(), 32);
        assert_eq!(t.d_ff(), 512);
        let xxxs = dims_for("xxxs").unwrap();
        assert_eq!(xxxs.head_dim(), 16);
        assert!(dims_for("xl").is_none());
        for d in DRAFTERS {
            assert!(dims_for(d).is_some());
        }
    }
}
