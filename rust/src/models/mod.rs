//! Model-family metadata: vocabulary layout and variant descriptions.
//! Mirrors python/compile/common.py; the authoritative values ship in
//! `artifacts/manifest.json` and are validated against these constants at
//! runtime load.

/// Vocabulary layout of the synthetic byte-level language.
pub mod vocab {
    pub const PAD: u32 = 0;
    pub const BOS: u32 = 1;
    pub const EOS: u32 = 2;
    pub const MARKER_BASE: u32 = 3;
    pub const NUM_DATASETS: u32 = 8;
    pub const CONTENT_BASE: u32 = 16;
    pub const SIZE: u32 = 256;

    /// Is this a control (non-content) token?
    pub fn is_control(tok: u32) -> bool {
        tok < CONTENT_BASE
    }

    pub fn marker_for(dataset_idx: u32) -> u32 {
        assert!(dataset_idx < NUM_DATASETS);
        MARKER_BASE + dataset_idx
    }
}

/// A model variant in the family (the PALM-2 substitution).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variant {
    pub name: &'static str,
    pub role: Role,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Target,
    Drafter,
}

pub const TARGET: Variant = Variant { name: "target", role: Role::Target };
pub const XXS: Variant = Variant { name: "xxs", role: Role::Drafter };
pub const XXXS: Variant = Variant { name: "xxxs", role: Role::Drafter };

pub const DRAFTERS: [&str; 2] = ["xxs", "xxxs"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_layout() {
        assert!(vocab::is_control(vocab::PAD));
        assert!(vocab::is_control(vocab::marker_for(7)));
        assert!(!vocab::is_control(vocab::CONTENT_BASE));
        assert_eq!(vocab::marker_for(0), 3);
    }

    #[test]
    #[should_panic]
    fn marker_out_of_range_panics() {
        vocab::marker_for(8);
    }
}
