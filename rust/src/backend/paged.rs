//! Scatter-paged physical KV storage (DESIGN.md §16).
//!
//! One [`PageArena`] per model holds fixed-size refcounted **pages**; a
//! paged KV cache carries a per-row *page table* instead of one
//! ring-contiguous buffer per row.  A page owns `page_positions`
//! consecutive sequence positions across **all** layers of one row:
//!
//! ```text
//! slab (f32): [ K: layer 0 × P positions × hhd | layer 1 × P × hhd | … ]
//!             [ V: same layout, second half                            ]
//! K block of (layer li, position pos): (li·P + pos%P)·hhd, len hhd
//! V block of (layer li, position pos): half + (li·P + pos%P)·hhd
//! ```
//!
//! with `hhd = n_heads·head_dim` and `half = n_layers·P·hhd`.  Crucially
//! the in-page offset of a position depends only on `pos % P` — *not* on
//! the ring length of the cache holding the table — so a page written
//! under one ring geometry can be aliased into a cache with another
//! (live ring ↔ tree scratch ring), which is what makes `kv_splice`,
//! scratch splats and prefix-cache hits O(pages) refcount bumps instead
//! of O(positions·d_model) memcpys.
//!
//! Sharing rules (the CoW contract, test-enforced in
//! `tests/paged_kv.rs`):
//! * A page referenced by more than one table row is **immutable**.
//! * Writers call [`PageArena::ensure_writable`] before touching a page:
//!   unmapped → fresh zeroed page; refcount 1 → write in place;
//!   refcount > 1 → copy-on-write into a private page (counted in
//!   [`kvstats`]).
//! * Unmapped table slots ([`NO_PAGE`]) read from the arena's immortal
//!   all-zero slab, so a fresh paged cache reads exactly like
//!   `NativeKv::zeros` without allocating anything.
//!
//! Page *contents* are read and written outside the arena lock through
//! addresses captured at allocation time ([`PageRef::addr`]); the lock
//! only serialises allocate/retain/release/CoW bookkeeping.  That is
//! sound because slabs are `Box<[f32]>` (heap addresses stable across
//! arena growth), free slabs are never touched until re-allocated, and
//! the ensure-writable pre-pass gives every parallel forward exclusive
//! ownership of the pages it writes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Page-table sentinel: "no physical page" — reads see zeros (the
/// arena's immortal zero slab), writes must `ensure_writable` first.
pub const NO_PAGE: u32 = u32::MAX;

/// Default positions per page.  16 matches `serve::RouterConfig`'s
/// accounting page size, keeps the boundary-partial-page copy (the only
/// bytes a prefix hit still moves) small, and holds slab size at
/// `2·n_layers·16·hhd` floats.
pub const DEFAULT_PAGE_POSITIONS: usize = 16;

/// One page-table entry: the arena page id plus the slab base address
/// captured when the reference was created.  Carrying the address in
/// the table keeps every block resolution on the forward hot path
/// lock-free (module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageRef {
    /// Arena page id, or [`NO_PAGE`].
    pub id: u32,
    /// Base address of the page's slab (the zero slab for [`NO_PAGE`]),
    /// as a plain integer so tables stay `Send` without carrying borrows.
    pub addr: usize,
}

/// Process-global copy-traffic counters (`specd_kv_bytes_copied_total`
/// / `specd_kv_pages_cow_total` in `/metrics`): every KV byte the
/// substrate still physically moves — contiguous-layout span copies,
/// paged boundary-partial-page copies, and CoW slab clones — lands in
/// `bytes_copied`, so the zero-copy claim of a prefix hit is observable
/// rather than asserted.  Global (not per-arena) because the
/// contiguous oracle layout has no arena to hang them on.
pub mod kvstats {
    use std::sync::atomic::{AtomicU64, Ordering};

    static BYTES_COPIED: AtomicU64 = AtomicU64::new(0);
    static PAGES_COW: AtomicU64 = AtomicU64::new(0);

    pub fn add_bytes_copied(bytes: u64) {
        BYTES_COPIED.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_pages_cow(pages: u64) {
        PAGES_COW.fetch_add(pages, Ordering::Relaxed);
    }

    pub fn bytes_copied() -> u64 {
        BYTES_COPIED.load(Ordering::Relaxed)
    }

    pub fn pages_cow() -> u64 {
        PAGES_COW.load(Ordering::Relaxed)
    }
}

/// Physical KV layout of the native backend (`SPECD_KV_LAYOUT` /
/// `EngineConfig.kv_layout`).  Fixed at backend construction; the
/// contiguous layout survives as the bit-identity oracle the paged
/// layout is tested against.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvLayout {
    /// One ring-contiguous `Vec<f32>` pair per cache — the original
    /// layout; every splice is a physical span copy.
    Contig,
    /// Scatter-paged arena pages behind per-row page tables — splices
    /// alias full pages and copy only the boundary partial page.
    #[default]
    Paged,
}

impl KvLayout {
    pub fn parse(s: &str) -> Option<KvLayout> {
        match s.trim().to_ascii_lowercase().as_str() {
            "contig" | "contiguous" => Some(KvLayout::Contig),
            "paged" | "paging" => Some(KvLayout::Paged),
            _ => None,
        }
    }

    /// Launch-time default: `SPECD_KV_LAYOUT` when set (and valid),
    /// otherwise paged.  An unparsable value falls back *loudly*
    /// (stderr), per the `SPECD_DRAFT_PRECISION` convention: a typo
    /// must not silently flip an operator's intended layout.
    pub fn from_env_or_default() -> KvLayout {
        match std::env::var("SPECD_KV_LAYOUT") {
            Ok(s) => KvLayout::parse(&s).unwrap_or_else(|| {
                eprintln!(
                    "specd: ignoring invalid SPECD_KV_LAYOUT '{s}' (contig | paged); using {}",
                    KvLayout::default()
                );
                KvLayout::default()
            }),
            Err(_) => KvLayout::default(),
        }
    }
}

impl std::fmt::Display for KvLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KvLayout::Contig => "contig",
            KvLayout::Paged => "paged",
        })
    }
}

/// The physical-page admission interface `serve::KvPool` runs on when a
/// backend serves paged KV (DESIGN.md §16.4): the pool's page ledger
/// and the backend's slab allocator become **one object**, so there is
/// no parallel accounting to drift.  Reservations are a logical
/// admission budget denominated in pages of [`PageAllocator::
/// page_positions`] positions; physical slabs are still allocated
/// lazily as rows are written.
pub trait PageAllocator: Send + Sync {
    /// Positions per page.
    fn page_positions(&self) -> usize;

    /// Try to reserve `pages` against the admission budget; false =
    /// budget exhausted (the caller defers, it does not fail).
    fn try_reserve(&self, pages: usize) -> bool;

    /// Return a reservation taken with [`PageAllocator::try_reserve`].
    fn unreserve(&self, pages: usize);

    /// Pages currently reserved.
    fn reserved_pages(&self) -> usize;

    /// Admission budget in pages (`usize::MAX` until
    /// [`PageAllocator::set_page_limit`] is called).
    fn page_limit(&self) -> usize;

    /// Install the admission budget (the serving tier's pool capacity).
    fn set_page_limit(&self, pages: usize);

    /// Physical pages currently referenced by at least one page table.
    fn live_pages(&self) -> usize;

    /// Physical pages allocated once and currently on the free list.
    fn free_pages(&self) -> usize;
}

struct ArenaState {
    /// All slabs ever allocated; freed slabs stay in place (address
    /// stability) and are recycled — and re-zeroed — by `alloc_zeroed`.
    slabs: Vec<Box<[f32]>>,
    /// Per-page reference count; 0 = on the free list.
    refc: Vec<u32>,
    /// Ids of zero-refcount slabs available for recycling.
    free: Vec<u32>,
}

/// Refcounted fixed-size page allocator for one model's KV geometry
/// (module docs for the slab layout and sharing rules).
pub struct PageArena {
    n_layers: usize,
    /// `n_heads · head_dim` — floats per (layer, position) K or V block.
    hhd: usize,
    page_positions: usize,
    /// Floats per slab: `2 · n_layers · page_positions · hhd`.
    slab_floats: usize,
    /// K/V boundary within a slab: `n_layers · page_positions · hhd`.
    half: usize,
    /// Immortal all-zero slab backing `NO_PAGE` reads.  Never written.
    zero: Box<[f32]>,
    state: Mutex<ArenaState>,
    /// Logical admission reservations ([`PageAllocator`]).
    reserved: AtomicUsize,
    /// Reservation budget; `usize::MAX` = unbounded.
    limit: AtomicUsize,
}

impl PageArena {
    pub fn new(n_layers: usize, hhd: usize, page_positions: usize) -> PageArena {
        assert!(n_layers > 0 && hhd > 0 && page_positions > 0, "degenerate page geometry");
        let half = n_layers * page_positions * hhd;
        PageArena {
            n_layers,
            hhd,
            page_positions,
            slab_floats: 2 * half,
            half,
            zero: vec![0.0; 2 * half].into_boxed_slice(),
            state: Mutex::new(ArenaState { slabs: Vec::new(), refc: Vec::new(), free: Vec::new() }),
            reserved: AtomicUsize::new(0),
            limit: AtomicUsize::new(usize::MAX),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Positions per page (inherent twin of the [`PageAllocator`]
    /// accessor, so callers don't need the trait in scope).
    pub fn page_positions(&self) -> usize {
        self.page_positions
    }

    pub fn hhd(&self) -> usize {
        self.hhd
    }

    /// K/V boundary offset within a slab.
    pub fn half(&self) -> usize {
        self.half
    }

    pub fn slab_floats(&self) -> usize {
        self.slab_floats
    }

    /// The `NO_PAGE` table entry for this arena (reads see zeros).
    pub fn zero_ref(&self) -> PageRef {
        PageRef { id: NO_PAGE, addr: self.zero.as_ptr() as usize }
    }

    /// Address of the immortal zero slab (write-path debug assertions).
    pub fn zero_addr(&self) -> usize {
        self.zero.as_ptr() as usize
    }

    /// Allocate a zeroed page at refcount 1.  Recycled slabs are
    /// re-zeroed here — *allocation* is the zeroing point, so dirty
    /// page reuse can never leak stale floats into a fresh cache
    /// (`NativeKv::zeros` parity; dirty-reuse regression in
    /// `tests/paged_kv.rs`).
    pub fn alloc_zeroed(&self) -> PageRef {
        let mut st = self.state.lock().unwrap();
        if let Some(id) = st.free.pop() {
            let slab = &mut st.slabs[id as usize];
            slab.fill(0.0);
            let addr = slab.as_mut_ptr() as usize;
            st.refc[id as usize] = 1;
            return PageRef { id, addr };
        }
        let mut slab = vec![0.0f32; self.slab_floats].into_boxed_slice();
        let addr = slab.as_mut_ptr() as usize;
        let id = st.slabs.len() as u32;
        assert!(id != NO_PAGE, "page arena id space exhausted");
        st.slabs.push(slab);
        st.refc.push(1);
        PageRef { id, addr }
    }

    /// Bump a page's refcount (aliasing a table entry).  `NO_PAGE` is a
    /// no-op: the zero slab is immortal.
    pub fn retain(&self, r: PageRef) {
        if r.id == NO_PAGE {
            return;
        }
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.refc[r.id as usize] > 0, "retain of a freed page");
        st.refc[r.id as usize] += 1;
    }

    /// Drop one reference; the slab returns to the free list at zero.
    pub fn release(&self, r: PageRef) {
        if r.id == NO_PAGE {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let c = &mut st.refc[r.id as usize];
        debug_assert!(*c > 0, "release of a freed page");
        *c -= 1;
        if *c == 0 {
            st.free.push(r.id);
        }
    }

    /// Make the table entry `r` privately writable and return the entry
    /// to store in its place: unmapped → fresh zeroed page; uniquely
    /// owned → unchanged; shared → copy-on-write clone (the old
    /// reference is released, the clone's bytes land in [`kvstats`]).
    pub fn ensure_writable(&self, r: PageRef) -> PageRef {
        if r.id == NO_PAGE {
            return self.alloc_zeroed();
        }
        let mut st = self.state.lock().unwrap();
        let old = r.id as usize;
        debug_assert!(st.refc[old] > 0, "ensure_writable of a freed page");
        if st.refc[old] == 1 {
            return r;
        }
        // Shared: clone the slab into a private page.
        let (id, addr) = if let Some(nid) = st.free.pop() {
            debug_assert_ne!(nid as usize, old, "shared page cannot be on the free list");
            let n = self.slab_floats;
            unsafe {
                let src = st.slabs[old].as_ptr();
                let dst = st.slabs[nid as usize].as_mut_ptr();
                std::ptr::copy_nonoverlapping(src, dst, n);
            }
            st.refc[nid as usize] = 1;
            (nid, st.slabs[nid as usize].as_ptr() as usize)
        } else {
            let mut slab = st.slabs[old].clone();
            let addr = slab.as_mut_ptr() as usize;
            let id = st.slabs.len() as u32;
            assert!(id != NO_PAGE, "page arena id space exhausted");
            st.slabs.push(slab);
            st.refc.push(1);
            (id, addr)
        };
        st.refc[old] -= 1;
        kvstats::add_pages_cow(1);
        kvstats::add_bytes_copied(self.slab_floats as u64 * 4);
        PageRef { id, addr }
    }
}

impl PageAllocator for PageArena {
    fn page_positions(&self) -> usize {
        self.page_positions
    }

    fn try_reserve(&self, pages: usize) -> bool {
        let limit = self.limit.load(Ordering::Relaxed);
        self.reserved
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                let next = cur.checked_add(pages)?;
                (next <= limit).then_some(next)
            })
            .is_ok()
    }

    fn unreserve(&self, pages: usize) {
        let _ = self.reserved.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some(cur.saturating_sub(pages))
        });
    }

    fn reserved_pages(&self) -> usize {
        self.reserved.load(Ordering::Relaxed)
    }

    fn page_limit(&self) -> usize {
        self.limit.load(Ordering::Relaxed)
    }

    fn set_page_limit(&self, pages: usize) {
        self.limit.store(pages, Ordering::Relaxed);
    }

    fn live_pages(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.refc.iter().filter(|&&c| c > 0).count()
    }

    fn free_pages(&self) -> usize {
        self.state.lock().unwrap().free.len()
    }
}

impl std::fmt::Debug for PageArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PageArena")
            .field("n_layers", &self.n_layers)
            .field("hhd", &self.hhd)
            .field("page_positions", &self.page_positions)
            .field("live_pages", &self.live_pages())
            .field("free_pages", &self.free_pages())
            .finish()
    }
}

/// The paged half of a `NativeKv`: the shared arena plus one page
/// table per batch row.  Clone retains every referenced page; Drop
/// releases them — cache lifetime *is* page lifetime, which is how
/// `serve::PrefixCache` entries pin their pages (DESIGN.md §16.4).
pub struct PagedRows {
    pub(crate) arena: Arc<PageArena>,
    /// `tables[row][pos / page_positions]`.
    pub(crate) tables: Vec<Vec<PageRef>>,
}

impl PagedRows {
    /// All-`NO_PAGE` tables for `rows` rows of a `ring`-position cache.
    pub(crate) fn new(arena: Arc<PageArena>, rows: usize, ring: usize) -> PagedRows {
        let per_row = ring.div_ceil(arena.page_positions);
        let zr = arena.zero_ref();
        PagedRows { tables: vec![vec![zr; per_row]; rows], arena }
    }
}

impl Clone for PagedRows {
    fn clone(&self) -> Self {
        for table in &self.tables {
            for &r in table {
                self.arena.retain(r);
            }
        }
        PagedRows { arena: self.arena.clone(), tables: self.tables.clone() }
    }
}

impl Drop for PagedRows {
    fn drop(&mut self) {
        for table in &self.tables {
            for &r in table {
                self.arena.release(r);
            }
        }
    }
}

impl std::fmt::Debug for PagedRows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mapped: usize =
            self.tables.iter().map(|t| t.iter().filter(|r| r.id != NO_PAGE).count()).sum();
        f.debug_struct("PagedRows")
            .field("rows", &self.tables.len())
            .field("mapped_pages", &mapped)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> PageArena {
        PageArena::new(2, 8, 4)
    }

    #[test]
    fn layout_constants() {
        let a = arena();
        assert_eq!(a.half(), 2 * 4 * 8);
        assert_eq!(a.slab_floats(), 2 * a.half());
        assert_eq!(a.zero_ref().id, NO_PAGE);
        assert_eq!(a.zero_ref().addr, a.zero_addr());
    }

    #[test]
    fn alloc_retain_release_recycles() {
        let a = arena();
        let p = a.alloc_zeroed();
        assert_eq!(a.live_pages(), 1);
        a.retain(p);
        a.release(p);
        assert_eq!(a.live_pages(), 1);
        a.release(p);
        assert_eq!(a.live_pages(), 0);
        assert_eq!(a.free_pages(), 1);
        // Recycled slab comes back zeroed at the same address.
        let q = a.alloc_zeroed();
        assert_eq!(q.id, p.id);
        assert_eq!(q.addr, p.addr);
        assert_eq!(a.free_pages(), 0);
        let slab = unsafe { std::slice::from_raw_parts(q.addr as *const f32, a.slab_floats()) };
        assert!(slab.iter().all(|&x| x == 0.0));
        a.release(q);
    }

    #[test]
    fn ensure_writable_cow_and_counters() {
        let a = arena();
        let p = a.alloc_zeroed();
        // Uniquely owned: in-place.
        let w = a.ensure_writable(p);
        assert_eq!(w, p);
        // Write a marker, then share and CoW.
        unsafe { *(p.addr as *mut f32) = 7.0 };
        a.retain(p);
        let cow0 = kvstats::pages_cow();
        let bytes0 = kvstats::bytes_copied();
        let w = a.ensure_writable(p);
        assert_ne!(w.id, p.id);
        // `>=`: the ledger is process-global and other tests in this
        // binary run concurrently.  Exact accounting is asserted in
        // isolation by `tests/kv_ledger.rs`.
        assert!(kvstats::pages_cow() >= cow0 + 1);
        assert!(kvstats::bytes_copied() >= bytes0 + a.slab_floats() as u64 * 4);
        // The clone carries the shared content; the original is intact
        // and back to a single owner.
        let orig = unsafe { *(p.addr as *const f32) };
        let copy = unsafe { *(w.addr as *const f32) };
        assert_eq!(orig, 7.0);
        assert_eq!(copy, 7.0);
        assert_eq!(a.live_pages(), 2);
        a.release(p);
        a.release(w);
        assert_eq!(a.live_pages(), 0);
        // Unmapped → fresh zeroed page.
        let z = a.ensure_writable(a.zero_ref());
        assert_ne!(z.id, NO_PAGE);
        a.release(z);
    }

    #[test]
    fn paged_rows_clone_and_drop_balance_refcounts() {
        let a = Arc::new(arena());
        let mut rows = PagedRows::new(a.clone(), 2, 10);
        assert_eq!(rows.tables[0].len(), 3); // ceil(10 / 4)
        rows.tables[0][0] = a.alloc_zeroed();
        rows.tables[1][2] = a.alloc_zeroed();
        assert_eq!(a.live_pages(), 2);
        let twin = rows.clone();
        drop(rows);
        assert_eq!(a.live_pages(), 2);
        drop(twin);
        assert_eq!(a.live_pages(), 0);
        assert_eq!(a.free_pages(), 2);
    }

    #[test]
    fn reservations_respect_limit() {
        let a = arena();
        a.set_page_limit(4);
        assert!(a.try_reserve(3));
        assert!(!a.try_reserve(2));
        assert!(a.try_reserve(1));
        assert_eq!(a.reserved_pages(), 4);
        a.unreserve(3);
        assert_eq!(a.reserved_pages(), 1);
        a.unreserve(100); // saturates, never underflows
        assert_eq!(a.reserved_pages(), 0);
    }

    #[test]
    fn kv_layout_parses_and_defaults() {
        assert_eq!(KvLayout::parse("contig"), Some(KvLayout::Contig));
        assert_eq!(KvLayout::parse(" PAGED "), Some(KvLayout::Paged));
        assert_eq!(KvLayout::parse("mmap"), None);
        assert_eq!(KvLayout::default(), KvLayout::Paged);
        assert_eq!(format!("{}", KvLayout::Contig), "contig");
        assert_eq!(format!("{}", KvLayout::Paged), "paged");
    }
}
