//! PJRT implementation of [`Backend`]: thin adapter from the host-tensor
//! trait contract onto the AOT HLO programs executed by
//! [`crate::runtime::Runtime`].  Compiled only with the `pjrt` cargo
//! feature (the `xla` dependency).
//!
//! KV caches stay device-resident between calls whenever the PJRT build
//! untuples outputs ([`StateHandle`] hides the tuple-layout fallback);
//! the small `tokens`/`length`/`tau` tensors round-trip through the host
//! every call, which is what lets the engine layer stay backend-agnostic.

use std::path::Path;
use std::sync::Arc;

use anyhow::anyhow;

use super::{Backend, BackendInfo, DraftOut, DraftRequest, SpecIterOut, StepOut};
use crate::draftset::{DraftSet, DraftTree};
use crate::runtime::{literal, Runtime, StateHandle};
use crate::verify::Algo;

/// Device-resident KV cache handles for one model.  The options are only
/// `None` transiently inside a call (or permanently after a failed one, in
/// which case the engine aborts the batch anyway).
pub struct PjrtKv {
    k: Option<StateHandle>,
    v: Option<StateHandle>,
}

impl PjrtKv {
    fn take(&mut self) -> anyhow::Result<(StateHandle, StateHandle)> {
        match (self.k.take(), self.v.take()) {
            (Some(k), Some(v)) => Ok((k, v)),
            _ => Err(anyhow!("KV state consumed by a previously failed call")),
        }
    }

    fn put(&mut self, k: StateHandle, v: StateHandle) {
        self.k = Some(k);
        self.v = Some(v);
    }
}

/// The PJRT backend: compiled HLO programs + uploaded weights.
pub struct PjrtBackend {
    rt: Arc<Runtime>,
    info: BackendInfo,
}

impl PjrtBackend {
    /// Wrap an already-loaded runtime.
    pub fn new(rt: Arc<Runtime>) -> Self {
        let m = &rt.manifest;
        let info = BackendInfo {
            name: "pjrt".into(),
            batch: m.batch,
            max_len: m.max_len,
            vocab_size: m.vocab_size,
            gammas: m.gammas.clone(),
            // Only the exported program grid exists on this backend.
            open_gamma: false,
            drafters: m.drafters.clone(),
            artifacts_dir: Some(rt.artifacts_dir().to_path_buf()),
            // PJRT KV lives in device buffers; paging is native-only.
            paged_kv: false,
        };
        PjrtBackend { rt, info }
    }

    /// Load the artifact bundle and stand up the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        Ok(Self::new(Arc::new(Runtime::load(artifacts_dir)?)))
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Fold per-row seeds into the single scalar the AOT program grid
    /// takes.  The compiled HLO derives its threefry streams from this
    /// scalar with row-index fold-ins, so batch-level determinism is
    /// preserved; *per-row* admission-order determinism (DESIGN.md §7)
    /// additionally needs programs regenerated with a `(B,)` seed input —
    /// tracked in ROADMAP.md, irrelevant until the real `xla` crate is
    /// vendored in.
    fn mix_seeds(&self, seeds: &[i32]) -> anyhow::Result<i32> {
        if seeds.len() != self.info.batch {
            return Err(anyhow!("seeds shape {} != batch {}", seeds.len(), self.info.batch));
        }
        let mut mixed: i64 = 0x5eed;
        for &s in seeds {
            mixed = mixed.wrapping_mul(0x0100_0000_01b3).wrapping_add(s as i64);
        }
        Ok(mixed as i32)
    }

    fn upload_state(
        &self,
        tokens: &[i32],
        length: &[i32],
    ) -> anyhow::Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let (b, l) = (self.info.batch, self.info.max_len);
        if tokens.len() != b * l || length.len() != b {
            return Err(anyhow!(
                "state shape mismatch: tokens {} (want {}), length {} (want {b})",
                tokens.len(),
                b * l,
                length.len()
            ));
        }
        let tok_buf = self.rt.upload(literal::i32_literal(tokens, &[b, l])?)?;
        let len_buf = self.rt.upload(literal::i32_literal(length, &[b])?)?;
        Ok((tok_buf, len_buf))
    }
}

impl Backend for PjrtBackend {
    type Kv = PjrtKv;

    fn info(&self) -> &BackendInfo {
        &self.info
    }

    fn prefill(&self, model: &str, tokens: &[i32], length: &[i32]) -> anyhow::Result<PjrtKv> {
        let rt = &*self.rt;
        let (tok_buf, len_buf) = self.upload_state(tokens, length)?;
        let weights = rt.weights(model)?;
        let prog = rt.program(&format!("prefill_{model}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = weights.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let handles = rt.execute(prog, &args)?.into_handles();
        let [k, v] = <[StateHandle; 2]>::try_from(handles)
            .map_err(|_| anyhow!("prefill_{model}: expected 2 outputs"))?;
        Ok(PjrtKv { k: Some(k), v: Some(v) })
    }

    #[allow(clippy::too_many_arguments)]
    fn spec_iter(
        &self,
        algo: Algo,
        drafter: &str,
        gamma: usize,
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut PjrtKv,
        kv_drafter: &mut PjrtKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        if !algo.fused() {
            return Err(anyhow!("algo {algo} requires the host-verify path"));
        }
        if let Algo::MultiPath { .. } | Algo::Tree { .. } = algo {
            return Err(anyhow!(
                "algo {algo} has no AOT program yet (ROADMAP: device KV-fork multipath / \
                 device tree-KV); run it on the native backend"
            ));
        }
        let rt = &*self.rt;
        let prog = rt.program(&rt.manifest.spec_iter_name(algo.name(), drafter, gamma))?;
        let w_t = rt.weights("target")?;
        let w_d = rt.weights(drafter)?;
        let (tok_buf, len_buf) = self.upload_state(tokens, length)?;
        let seed_buf = rt.upload(literal::i32_scalar(self.mix_seeds(seeds)?)?)?;
        let (kvt_k, kvt_v) = kv_target.take()?;
        let (kvd_k, kvd_v) = kv_drafter.take()?;
        let kvt_k = kvt_k.ensure_buffer(rt)?;
        let kvt_v = kvt_v.ensure_buffer(rt)?;
        let kvd_k = kvd_k.ensure_buffer(rt)?;
        let kvd_v = kvd_v.ensure_buffer(rt)?;

        let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
        args.extend(w_d.iter());
        args.push(&tok_buf);
        args.push(&len_buf);
        args.push(&kvt_k);
        args.push(&kvt_v);
        args.push(&kvd_k);
        args.push(&kvd_v);
        args.push(&seed_buf);
        let out = rt.execute(prog, &args)?;

        // outs: tokens, length, kvt_k, kvt_v, kvd_k, kvd_v, tau, emitted, done
        tokens.copy_from_slice(&out.i32s(0)?);
        length.copy_from_slice(&out.i32s(1)?);
        let tau = out.i32s(6)?;
        let emitted = out.i32s(7)?;
        let done = out.i32s(8)?;
        let mut handles = out.into_handles();
        let _ = handles.split_off(6); // small outputs already on the host
        let h_kvd_v = handles.pop().unwrap();
        let h_kvd_k = handles.pop().unwrap();
        let h_kvt_v = handles.pop().unwrap();
        let h_kvt_k = handles.pop().unwrap();
        kv_target.put(h_kvt_k, h_kvt_v);
        kv_drafter.put(h_kvd_k, h_kvd_v);
        // draft_us / target_us = 0: the fused device program cannot
        // separate its phases (see the SpecIterOut field docs).
        Ok(SpecIterOut {
            tau,
            emitted,
            done,
            stride: gamma + 1,
            draft_us: 0,
            target_us: 0,
            drafted: self.info.batch * gamma,
        })
    }

    fn draft_block(
        &self,
        drafter: &str,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &mut PjrtKv,
        seeds: &[i32],
    ) -> anyhow::Result<DraftOut> {
        let rt = &*self.rt;
        let prog = rt.program(&format!("draft_block_{drafter}_g{gamma}"))?;
        let weights = rt.weights(drafter)?;
        let (tok_buf, len_buf) = self.upload_state(tokens, length)?;
        let seed_buf = rt.upload(literal::i32_scalar(self.mix_seeds(seeds)?)?)?;
        let (kv_k, kv_v) = kv.take()?;
        let kv_k = kv_k.ensure_buffer(rt)?;
        let kv_v = kv_v.ensure_buffer(rt)?;
        let mut args: Vec<&xla::PjRtBuffer> = weights.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        args.push(&kv_k);
        args.push(&kv_v);
        args.push(&seed_buf);
        let out = rt.execute(prog, &args)?;
        // outs: drafts (B, g) i32, qs (B, g, V) f32, kv_k, kv_v
        let drafts = out.i32s(0)?;
        let qs = out.f32s(1)?;
        let mut handles = out.into_handles();
        let h_v = handles.pop().unwrap();
        let h_k = handles.pop().unwrap();
        kv.put(h_k, h_v);
        Ok(DraftOut { drafts, qs })
    }

    fn target_score(
        &self,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &mut PjrtKv,
        drafts: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let rt = &*self.rt;
        let b = self.info.batch;
        let prog = rt.program(&format!("target_score_g{gamma}"))?;
        let weights = rt.weights("target")?;
        let (tok_buf, len_buf) = self.upload_state(tokens, length)?;
        let drafts_buf = rt.upload(literal::i32_literal(drafts, &[b, gamma])?)?;
        let (kv_k, kv_v) = kv.take()?;
        let kv_k = kv_k.ensure_buffer(rt)?;
        let kv_v = kv_v.ensure_buffer(rt)?;
        let mut args: Vec<&xla::PjRtBuffer> = weights.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        args.push(&kv_k);
        args.push(&kv_v);
        args.push(&drafts_buf);
        let out = rt.execute(prog, &args)?;
        // outs: ps (B, g+1, V) f32, kv_k, kv_v
        let ps = out.f32s(0)?;
        let mut handles = out.into_handles();
        let h_v = handles.pop().unwrap();
        let h_k = handles.pop().unwrap();
        kv.put(h_k, h_v);
        Ok(ps)
    }

    /// Host-composed tree-draft fallback: one `draft_block` program run
    /// per leaf path against a host clone of the live cache (the AOT
    /// grid has no tree-attention program yet — ROADMAP: device tree-KV).
    /// Because the paths run separately, nothing is ever merged: the
    /// returned tree is always the disjoint `k * gamma`-node layout
    /// whatever `req.policy` says — a valid (if unshared) tree, since
    /// sharing is a pure compute optimisation, never a semantics change.
    /// `req.precision` is likewise ignored: the AOT programs are fp32
    /// (the PJRT quant path is a ROADMAP follow-up).  The live cache is
    /// left untouched, per the trait contract.
    fn draft_tree(&self, req: &DraftRequest, kv: &PjrtKv) -> anyhow::Result<DraftTree> {
        let (k, gamma) = (req.k, req.gamma);
        if k == 0 {
            return Err(anyhow!("tree draft set needs k >= 1"));
        }
        let (b, v) = (self.info.batch, self.info.vocab_size);
        let mut drafts = vec![0i32; b * k * gamma];
        let mut qs = vec![0.0f32; b * k * gamma * v];
        for path in 0..k {
            let mut scratch = clone_kv_host(kv)?;
            let d = self.draft_block(
                req.drafter,
                gamma,
                req.tokens,
                req.length,
                &mut scratch,
                &path_seeds(req.seeds, path),
            )?;
            for bi in 0..b {
                let r = bi * k + path;
                drafts[r * gamma..(r + 1) * gamma]
                    .copy_from_slice(&d.drafts[bi * gamma..(bi + 1) * gamma]);
                qs[r * gamma * v..(r + 1) * gamma * v]
                    .copy_from_slice(&d.qs[bi * gamma * v..(bi + 1) * gamma * v]);
            }
        }
        let set = DraftSet::new(b, k, gamma, v, drafts, qs)?;
        Ok(DraftTree::from_flat(&set))
    }

    /// Host-composed tree-scoring fallback: one `target_score` program
    /// run per leaf path on a host clone of the live cache (see
    /// [`PjrtBackend::draft_tree`]).  Works for *any* tree shape, not
    /// just the disjoint ones this backend drafts: a node shared by
    /// several paths is scored once per path, but every run produces the
    /// same distribution (row `j + 1` of `target_score` depends only on
    /// the pending token and drafts `0..=j` — the shared prefix), so the
    /// last write is as good as the first.
    fn score_tree(
        &self,
        tree: &mut DraftTree,
        tokens: &[i32],
        length: &[i32],
        kv: &PjrtKv,
    ) -> anyhow::Result<()> {
        let (b, v) = (self.info.batch, self.info.vocab_size);
        if tree.batch != b || tree.vocab != v {
            return Err(anyhow!(
                "draft tree shape mismatch: batch {} (want {b}), vocab {} (want {v})",
                tree.batch,
                tree.vocab
            ));
        }
        let gamma = tree.gamma;
        let n = (gamma + 1) * v;
        let mut ps_root: Vec<Vec<f32>> = vec![Vec::new(); b];
        let mut node_ps: Vec<Vec<f32>> =
            (0..b).map(|bi| vec![0.0f32; tree.rows[bi].n_nodes() * v]).collect();
        for path in 0..tree.k {
            let mut scratch = clone_kv_host(kv)?;
            let drafts_p: Vec<i32> =
                (0..b).flat_map(|bi| tree.rows[bi].path_drafts(path)).collect();
            let ps_p = self.target_score(gamma, tokens, length, &mut scratch, &drafts_p)?;
            for bi in 0..b {
                let base = bi * n;
                if path == 0 {
                    ps_root[bi] = ps_p[base..base + v].to_vec();
                }
                for (j, &node) in tree.rows[bi].path_nodes(path).iter().enumerate() {
                    let src = base + (j + 1) * v;
                    node_ps[bi][node * v..(node + 1) * v].copy_from_slice(&ps_p[src..src + v]);
                }
            }
        }
        for bi in 0..b {
            let root = std::mem::take(&mut ps_root[bi]);
            let nodes = std::mem::take(&mut node_ps[bi]);
            tree.set_row_scores(bi, root, nodes)?;
        }
        Ok(())
    }

    fn baseline_step(
        &self,
        tokens: &mut [i32],
        length: &mut [i32],
        kv: &mut PjrtKv,
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let rt = &*self.rt;
        let prog = rt.program("baseline_step")?;
        let weights = rt.weights("target")?;
        let (tok_buf, len_buf) = self.upload_state(tokens, length)?;
        let seed_buf = rt.upload(literal::i32_scalar(seed)?)?;
        let (kv_k, kv_v) = kv.take()?;
        let kv_k = kv_k.ensure_buffer(rt)?;
        let kv_v = kv_v.ensure_buffer(rt)?;
        let mut args: Vec<&xla::PjRtBuffer> = weights.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        args.push(&kv_k);
        args.push(&kv_v);
        args.push(&seed_buf);
        let out = rt.execute(prog, &args)?;
        // outs: tokens, length, kv_k, kv_v, next, done
        tokens.copy_from_slice(&out.i32s(0)?);
        length.copy_from_slice(&out.i32s(1)?);
        let next = out.i32s(4)?;
        let done = out.i32s(5)?;
        let mut handles = out.into_handles();
        let _ = handles.split_off(4);
        let h_v = handles.pop().unwrap();
        let h_k = handles.pop().unwrap();
        kv.put(h_k, h_v);
        Ok(StepOut { next, done })
    }

    /// Host-roundtrip splice: read both caches back as literals, copy the
    /// row span, re-upload lazily (the rebuilt handles are
    /// [`StateHandle::Lit`]s that `ensure_buffer` uploads on the next
    /// call).  A device-side KV-merge program would avoid the readback;
    /// until the AOT grid grows one (ROADMAP.md), refill admissions on
    /// PJRT pay one KV round-trip each — correct, just not resident.
    fn kv_splice(
        &self,
        model: &str,
        dst: &mut PjrtKv,
        dst_slot: usize,
        src: &PjrtKv,
        src_row: usize,
        len: usize,
    ) -> anyhow::Result<()> {
        let meta = self.rt.manifest.model(model)?;
        let (b, l) = (self.info.batch, self.info.max_len);
        if dst_slot >= b || src_row >= b {
            return Err(anyhow!("kv_splice: row out of range (dst {dst_slot}, src {src_row})"));
        }
        if len > l {
            return Err(anyhow!("kv_splice: len {len} exceeds ring {l}"));
        }
        let row_elems = l * meta.d_model; // L positions x (H, hd) blocks
        let chunk = len * meta.d_model;
        // Everything below reads through shared references and validates
        // before the final `put`, so a failed splice leaves the live
        // destination cache exactly as it was (the per-request admission
        // error must not poison the whole batch).
        let sk = src.k.as_ref().ok_or_else(|| anyhow!("source KV consumed"))?;
        let sv = src.v.as_ref().ok_or_else(|| anyhow!("source KV consumed"))?;
        let (sk, _) = handle_to_host(sk)?;
        let (sv, _) = handle_to_host(sv)?;
        let dk_h = dst.k.as_ref().ok_or_else(|| anyhow!("destination KV consumed"))?;
        let dv_h = dst.v.as_ref().ok_or_else(|| anyhow!("destination KV consumed"))?;
        let (mut dk, dk_dims) = handle_to_host(dk_h)?;
        let (mut dv, dv_dims) = handle_to_host(dv_h)?;
        let want = meta.n_layers * b * row_elems;
        if sk.len() != want || dk.len() != want {
            return Err(anyhow!(
                "kv_splice: cache size mismatch for '{model}' (src {}, dst {}, want {want})",
                sk.len(),
                dk.len()
            ));
        }
        for li in 0..meta.n_layers {
            let d0 = (li * b + dst_slot) * row_elems;
            let s0 = (li * b + src_row) * row_elems;
            dk[d0..d0 + chunk].copy_from_slice(&sk[s0..s0 + chunk]);
            dv[d0..d0 + chunk].copy_from_slice(&sv[s0..s0 + chunk]);
        }
        let k_lit = xla::Literal::vec1(&dk)
            .reshape(&dk_dims)
            .map_err(|e| anyhow!("kv_splice reshape: {e}"))?;
        let v_lit = xla::Literal::vec1(&dv)
            .reshape(&dv_dims)
            .map_err(|e| anyhow!("kv_splice reshape: {e}"))?;
        dst.put(StateHandle::Lit(k_lit), StateHandle::Lit(v_lit));
        Ok(())
    }

    /// Release pinned upload literals: every output of the batch's final
    /// execution has been read back by now, which forces completion of all
    /// outstanding host-to-device copies.
    fn end_batch(&self) {
        self.rt.clear_pinned();
    }
}

/// Host clone of a live KV cache as lazily-uploaded literals
/// ([`StateHandle::Lit`]), leaving the original untouched — the scratch
/// the host-composed multi-draft fallback drafts and scores against.
fn clone_kv_host(kv: &PjrtKv) -> anyhow::Result<PjrtKv> {
    let k = kv.k.as_ref().ok_or_else(|| anyhow!("KV state consumed"))?;
    let v = kv.v.as_ref().ok_or_else(|| anyhow!("KV state consumed"))?;
    let (kd, k_dims) = handle_to_host(k)?;
    let (vd, v_dims) = handle_to_host(v)?;
    let k_lit = xla::Literal::vec1(&kd)
        .reshape(&k_dims)
        .map_err(|e| anyhow!("kv clone reshape: {e}"))?;
    let v_lit = xla::Literal::vec1(&vd)
        .reshape(&v_dims)
        .map_err(|e| anyhow!("kv clone reshape: {e}"))?;
    Ok(PjrtKv { k: Some(StateHandle::Lit(k_lit)), v: Some(StateHandle::Lit(v_lit)) })
}

/// Per-path seed derivation on the scalar-seed program grid: path 0 keeps
/// the row seeds verbatim (the `k == 1` degradation), later paths fold
/// the path index in (best-effort stream separation, same caveat as
/// [`PjrtBackend::mix_seeds`]).
fn path_seeds(seeds: &[i32], path: usize) -> Vec<i32> {
    if path == 0 {
        return seeds.to_vec();
    }
    let mix = (path as i32).wrapping_mul(0x9E37_79B1u32 as i32);
    seeds.iter().map(|&s| s ^ mix).collect()
}

/// Materialise a carried state tensor on the host as `(flat f32 data,
/// dims)` without consuming the handle.
fn handle_to_host(h: &StateHandle) -> anyhow::Result<(Vec<f32>, Vec<i64>)> {
    let lit_owned;
    let lit = match h {
        StateHandle::Buf(buf) => {
            lit_owned = buf.to_literal_sync().map_err(|e| anyhow!("kv readback: {e}"))?;
            &lit_owned
        }
        StateHandle::Lit(l) => l,
    };
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("kv to_vec: {e}"))?;
    Ok((data, lit.dims().to_vec()))
}
