//! Pure-Rust CPU execution backend: a from-scratch decoder-only
//! transformer forward pass (embedding → causal attention with KV cache →
//! GELU MLP → tied LM head) implementing the exact serving contract of
//! `python/compile/model.py`, with verification delegated to the host
//! kernels in [`crate::verify`].  Zero external dependencies: weights are
//! loaded from an artifact bundle when one is present and otherwise
//! initialised deterministically from a seed ([`crate::verify::Rng`]), so
//! every engine path — including the full HTTP serving stack — runs
//! hermetically in tests and benches.
//!
//! Seeded-weight design: the model family must behave like a trained
//! target + distilled drafters (moderate, drafter-quality-ordered
//! acceptance rates), not like three unrelated random LMs.  To get that
//! without training, per-token embedding rows are drawn from a *shared*
//! per-token random stream so a drafter's `(V, d_s)` table is a prefix of
//! the target's `(V, d_b)` table, and layer weights (per-model streams)
//! are damped so the shared embedding signal dominates the tied-head
//! logits.  Smaller drafters share fewer dimensions ⇒ lower acceptance,
//! reproducing the paper's xxs > xxxs quality ordering.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{anyhow, Context};

use super::kernels::{
    default_kernel, dot_f32, dot_q8_i32, matmul_q8_i32, matmul_q8_i32_ref, quantise_row_q8,
    MatKernel, PackedF32, QuantScratch,
};
use super::paged::{
    kvstats, KvLayout, PageAllocator, PageArena, PagedRows, DEFAULT_PAGE_POSITIONS, NO_PAGE,
};
use super::pool::{ScopedJob, ThreadPool};
use super::quant::{Precision, QuantLayer, QuantMatrix, QuantModel, QuantRows};
use super::{
    Backend, BackendInfo, DraftOut, DraftRequest, PrefixSplice, RowSplice, SpecIterOut, StepOut,
};
use crate::draftset::{BranchPolicy, DraftSet, DraftTree, RowViews, TreeRow, TreeViews};
use crate::models::{self, vocab, ModelDims};
use crate::runtime::Manifest;
use crate::verify::{self, dist, Algo, ProbMatrix, Rng};

// Domain separators for the backend's deterministic randomness.
const DOM_DRAFT: u64 = 0xd4af_7b10_c000_0001;
const DOM_ETA: u64 = 0xe7a0_0c0d_e000_0002;
const DOM_RESIDUAL: u64 = 0x4e51_dc0d_e000_0003;
const DOM_BASELINE: u64 = 0xba5e_11fe_e000_0004;
const DOM_EMBED: u64 = 0xe4be_dd00_0000_0005;
const DOM_POS: u64 = 0x9051_7100_0000_0006;
const DOM_LAYER: u64 = 0x1a7e_4000_0000_0007;

/// Layer-norm parameters.
#[derive(Clone, Debug)]
struct LayerNorm {
    g: Vec<f32>,
    b: Vec<f32>,
}

impl LayerNorm {
    fn identity(d: usize) -> Self {
        LayerNorm { g: vec![1.0; d], b: vec![0.0; d] }
    }

    /// Normalise each `d`-sized row of `x` into `out`.
    fn apply(&self, x: &[f32], out: &mut [f32], d: usize) {
        for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            let inv = 1.0 / (var + 1e-6).sqrt();
            for j in 0..d {
                orow[j] = (row[j] - mu) * inv * self.g[j] + self.b[j];
            }
        }
    }
}

/// One transformer block's weights (matrices row-major `(d_in, d_out)`).
#[derive(Clone, Debug)]
struct Layer {
    ln1: LayerNorm,
    ln2: LayerNorm,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w1: Vec<f32>,
    w2: Vec<f32>,
}

/// A complete model: embedding (tied with the LM head), learned positions,
/// transformer blocks and the final layer norm.
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub dims: ModelDims,
    embed: Vec<f32>,
    pos: Vec<f32>,
    layers: Vec<Layer>,
    ln_f: LayerNorm,
    /// Additive logit bias on control tokens (`tok < CONTENT_BASE`).
    /// Trained weights learn to avoid control tokens on their own (bias
    /// 0); the seeded fallback applies a strongly negative bias so
    /// hermetic generations stay in content space, mirroring trained
    /// behaviour.
    control_logit_bias: f32,
}

impl NativeModel {
    /// Build the int8 quantised twin this model's draft forwards run with
    /// under [`Precision::Int8`] (DESIGN.md §11.1): every weight matrix
    /// per-output-column, the tied embedding per token row.  Layer norms,
    /// the position table and the control-token bias stay fp32.
    fn quantise(&self) -> QuantModel {
        let d = self.dims.d_model;
        let f = self.dims.d_ff();
        QuantModel {
            embed: QuantRows::quantise(&self.embed, self.dims.vocab_size, d),
            layers: self
                .layers
                .iter()
                .map(|l| QuantLayer {
                    wq: QuantMatrix::quantise(&l.wq, d, d),
                    wk: QuantMatrix::quantise(&l.wk, d, d),
                    wv: QuantMatrix::quantise(&l.wv, d, d),
                    wo: QuantMatrix::quantise(&l.wo, d, d),
                    w1: QuantMatrix::quantise(&l.w1, d, f),
                    w2: QuantMatrix::quantise(&l.w2, f, d),
                })
                .collect(),
        }
    }
}

/// KV cache for one model over one batch, in one of two physical
/// layouts (DESIGN.md §16):
///
/// * **Contig** (`pages: None`): `(B, n_layers, L, H, hd)` flat in
///   `k`/`v`.  Batch-major, so one serving row's entire cache (all
///   layers) is a single contiguous [`NativeKv::row_stride`]-sized
///   slice — the original layout, kept as the bit-identity oracle.
/// * **Paged** (`pages: Some`): `k`/`v` are empty and every `(layer,
///   position)` block lives in a fixed-size refcounted arena page
///   behind a per-row page table ([`PagedRows`]), so splices alias
///   pages instead of copying spans, with copy-on-write on append.
///
/// All forward and copy paths go through the per-`(layer, position)`
/// block accessors below, which resolve to the same `(H, hd)` float
/// blocks in either layout — paged runs the identical float ops in the
/// identical order, hence bit-identical streams (test-enforced in
/// `tests/paged_kv.rs`).
#[derive(Clone, Debug)]
pub struct NativeKv {
    k: Vec<f32>,
    v: Vec<f32>,
    /// Paged layout state; `None` = ring-contiguous `k`/`v` above.
    pages: Option<PagedRows>,
    n_layers: usize,
    batch: usize,
    max_len: usize,
    n_heads: usize,
    head_dim: usize,
}

impl NativeKv {
    fn zeros(dims: &ModelDims, batch: usize, max_len: usize) -> Self {
        let n = dims.n_layers * batch * max_len * dims.n_heads * dims.head_dim();
        NativeKv {
            k: vec![0.0; n],
            v: vec![0.0; n],
            pages: None,
            n_layers: dims.n_layers,
            batch,
            max_len,
            n_heads: dims.n_heads,
            head_dim: dims.head_dim(),
        }
    }

    /// A paged cache with every page-table entry unmapped — reads see
    /// zeros (the arena's zero slab), so this is `zeros` without the
    /// allocation; pages materialise lazily on first write.
    fn paged(dims: &ModelDims, batch: usize, max_len: usize, arena: &Arc<PageArena>) -> Self {
        debug_assert_eq!(arena.n_layers(), dims.n_layers, "arena geometry mismatch");
        debug_assert_eq!(arena.hhd(), dims.n_heads * dims.head_dim(), "arena geometry mismatch");
        NativeKv {
            k: Vec::new(),
            v: Vec::new(),
            pages: Some(PagedRows::new(arena.clone(), batch, max_len)),
            n_layers: dims.n_layers,
            batch,
            max_len,
            n_heads: dims.n_heads,
            head_dim: dims.head_dim(),
        }
    }

    pub fn is_paged(&self) -> bool {
        self.pages.is_some()
    }

    /// Floats per `(layer, position)` K or V block: `H · hd`.
    #[inline]
    fn hhd(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Flat length of one batch row's cache: `(n_layers, L, H, hd)`.
    /// Contig layout only.
    #[inline]
    fn row_stride(&self) -> usize {
        self.n_layers * self.max_len * self.n_heads * self.head_dim
    }

    /// Flat offset of cache row `(layer, b, pos)` (a `(H, hd)` block).
    /// Contig layout only.
    #[inline]
    fn row(&self, layer: usize, b: usize, pos: usize) -> usize {
        ((b * self.n_layers + layer) * self.max_len + pos) * self.n_heads * self.head_dim
    }

    /// The K block of `(layer, b, pos)` in either layout.  Paged reads
    /// of unmapped pages resolve to the arena's zero slab — exactly
    /// what a contig `zeros` cache reads.
    #[inline]
    fn k_block(&self, layer: usize, b: usize, pos: usize) -> &[f32] {
        let hhd = self.hhd();
        match &self.pages {
            None => {
                let r = self.row(layer, b, pos);
                &self.k[r..r + hhd]
            }
            Some(p) => {
                let pp = p.arena.page_positions();
                let pr = p.tables[b][pos / pp];
                let off = (layer * pp + pos % pp) * hhd;
                unsafe { std::slice::from_raw_parts((pr.addr as *const f32).add(off), hhd) }
            }
        }
    }

    /// The V block of `(layer, b, pos)` in either layout.
    #[inline]
    fn v_block(&self, layer: usize, b: usize, pos: usize) -> &[f32] {
        let hhd = self.hhd();
        match &self.pages {
            None => {
                let r = self.row(layer, b, pos);
                &self.v[r..r + hhd]
            }
            Some(p) => {
                let pp = p.arena.page_positions();
                let pr = p.tables[b][pos / pp];
                let off = p.arena.half() + (layer * pp + pos % pp) * hhd;
                unsafe { std::slice::from_raw_parts((pr.addr as *const f32).add(off), hhd) }
            }
        }
    }

    /// Mutable K block.  Paged callers must have made the position's
    /// page privately writable first ([`NativeKv::ensure_writable_span`]).
    #[inline]
    fn k_block_mut(&mut self, layer: usize, b: usize, pos: usize) -> &mut [f32] {
        let hhd = self.hhd();
        let r = ((b * self.n_layers + layer) * self.max_len + pos) * hhd;
        match &mut self.pages {
            None => &mut self.k[r..r + hhd],
            Some(p) => {
                let pp = p.arena.page_positions();
                let pr = p.tables[b][pos / pp];
                debug_assert!(pr.id != NO_PAGE, "write into an unmapped KV page");
                let off = (layer * pp + pos % pp) * hhd;
                unsafe { std::slice::from_raw_parts_mut((pr.addr as *mut f32).add(off), hhd) }
            }
        }
    }

    /// Mutable V block (same writability contract as `k_block_mut`).
    #[inline]
    fn v_block_mut(&mut self, layer: usize, b: usize, pos: usize) -> &mut [f32] {
        let hhd = self.hhd();
        let r = ((b * self.n_layers + layer) * self.max_len + pos) * hhd;
        match &mut self.pages {
            None => &mut self.v[r..r + hhd],
            Some(p) => {
                let pp = p.arena.page_positions();
                let pr = p.tables[b][pos / pp];
                debug_assert!(pr.id != NO_PAGE, "write into an unmapped KV page");
                let off = p.arena.half() + (layer * pp + pos % pp) * hhd;
                unsafe { std::slice::from_raw_parts_mut((pr.addr as *mut f32).add(off), hhd) }
            }
        }
    }

    /// Make every page covering positions `lo..hi` of row `b` privately
    /// writable (unmapped → fresh zeroed page, shared → copy-on-write).
    /// No-op on the contig layout.  This is the pre-pass every writer
    /// runs *before* handing raw-address row views to the thread pool:
    /// afterwards the written pages are uniquely owned, so parallel row
    /// writes cannot touch a page any other row (or cache) can see.
    fn ensure_writable_span(&mut self, b: usize, lo: usize, hi: usize) {
        let Some(p) = &mut self.pages else { return };
        if hi <= lo {
            return;
        }
        debug_assert!(hi <= self.max_len, "KV write span {lo}..{hi} overruns ring {}", self.max_len);
        let pp = p.arena.page_positions();
        for page in lo / pp..=(hi - 1) / pp {
            let r = p.tables[b][page];
            let w = p.arena.ensure_writable(r);
            p.tables[b][page] = w;
        }
    }

    /// Gather positions `0..len.min(max_len)` of row `b` into contig
    /// `(n_layers, len, H, hd)` K and V buffers — the layout-agnostic
    /// comparison form the bit-identity tests diff (`k`/`v` are empty
    /// in the paged layout, so tests must never peek them directly).
    pub fn row_snapshot(&self, b: usize, len: usize) -> (Vec<f32>, Vec<f32>) {
        let len = len.min(self.max_len);
        let hhd = self.hhd();
        let mut k = Vec::with_capacity(self.n_layers * len * hhd);
        let mut v = Vec::with_capacity(self.n_layers * len * hhd);
        for li in 0..self.n_layers {
            for pos in 0..len {
                k.extend_from_slice(self.k_block(li, b, pos));
                v.extend_from_slice(self.v_block(li, b, pos));
            }
        }
        (k, v)
    }

    /// Ring length (positions) of this cache.
    pub fn ring_len(&self) -> usize {
        self.max_len
    }

    /// Batch rows in this cache.
    pub fn rows(&self) -> usize {
        self.batch
    }
}

/// One row's KV resolved for a forward call: raw base addresses in
/// either layout, so the slot structs stay `Send` for the fork-join
/// pool without borrowing the cache (the paged layout has no
/// per-row contiguous slice for `chunks_mut` to split).  Soundness
/// (DESIGN.md §16.2): rows are disjoint; within a row, the
/// ensure-writable pre-pass ran before views were captured, so written
/// pages are uniquely owned by this cache and shared pages are only
/// ever read.
struct RowKvView {
    hhd: usize,
    mode: RowKvMode,
}

enum RowKvMode {
    /// Base addresses of the row's contiguous K/V slices; `ring` is the
    /// cache ring length the flat `(li·L + pos)` indexing strides by.
    Contig { k: usize, v: usize, ring: usize },
    /// Per-page slab base addresses (one per table entry), page
    /// geometry, and the zero-slab address for write assertions.
    Paged { slabs: Vec<usize>, pp: usize, half: usize, zero: usize },
}

impl RowKvView {
    #[inline]
    fn k_block(&self, li: usize, pos: usize) -> &[f32] {
        match &self.mode {
            RowKvMode::Contig { k, ring, .. } => unsafe {
                std::slice::from_raw_parts(
                    (*k as *const f32).add((li * ring + pos) * self.hhd),
                    self.hhd,
                )
            },
            RowKvMode::Paged { slabs, pp, .. } => unsafe {
                std::slice::from_raw_parts(
                    (slabs[pos / pp] as *const f32).add((li * pp + pos % pp) * self.hhd),
                    self.hhd,
                )
            },
        }
    }

    #[inline]
    fn v_block(&self, li: usize, pos: usize) -> &[f32] {
        match &self.mode {
            RowKvMode::Contig { v, ring, .. } => unsafe {
                std::slice::from_raw_parts(
                    (*v as *const f32).add((li * ring + pos) * self.hhd),
                    self.hhd,
                )
            },
            RowKvMode::Paged { slabs, pp, half, .. } => unsafe {
                std::slice::from_raw_parts(
                    (slabs[pos / pp] as *const f32).add(half + (li * pp + pos % pp) * self.hhd),
                    self.hhd,
                )
            },
        }
    }

    #[inline]
    fn k_block_mut(&mut self, li: usize, pos: usize) -> &mut [f32] {
        match &self.mode {
            RowKvMode::Contig { k, ring, .. } => unsafe {
                std::slice::from_raw_parts_mut(
                    (*k as *mut f32).add((li * ring + pos) * self.hhd),
                    self.hhd,
                )
            },
            RowKvMode::Paged { slabs, pp, zero, .. } => {
                let slab = slabs[pos / pp];
                debug_assert!(slab != *zero, "write into an unmapped KV page");
                unsafe {
                    std::slice::from_raw_parts_mut(
                        (slab as *mut f32).add((li * pp + pos % pp) * self.hhd),
                        self.hhd,
                    )
                }
            }
        }
    }

    #[inline]
    fn v_block_mut(&mut self, li: usize, pos: usize) -> &mut [f32] {
        match &self.mode {
            RowKvMode::Contig { v, ring, .. } => unsafe {
                std::slice::from_raw_parts_mut(
                    (*v as *mut f32).add((li * ring + pos) * self.hhd),
                    self.hhd,
                )
            },
            RowKvMode::Paged { slabs, pp, half, zero } => {
                let slab = slabs[pos / pp];
                debug_assert!(slab != *zero, "write into an unmapped KV page");
                unsafe {
                    std::slice::from_raw_parts_mut(
                        (slab as *mut f32).add(half + (li * pp + pos % pp) * self.hhd),
                        self.hhd,
                    )
                }
            }
        }
    }
}

impl NativeKv {
    /// Capture row `b` as a [`RowKvView`] for a forward call.  Callers
    /// must run [`NativeKv::ensure_writable_span`] over every position
    /// the forward will write *before* capturing views: CoW changes
    /// slab addresses, and the view freezes them.
    fn row_view(&mut self, b: usize) -> RowKvView {
        let hhd = self.hhd();
        match &self.pages {
            None => {
                let base = b * self.row_stride();
                RowKvView {
                    hhd,
                    mode: RowKvMode::Contig {
                        k: unsafe { self.k.as_mut_ptr().add(base) } as usize,
                        v: unsafe { self.v.as_mut_ptr().add(base) } as usize,
                        ring: self.max_len,
                    },
                }
            }
            Some(p) => RowKvView {
                hhd,
                mode: RowKvMode::Paged {
                    slabs: p.tables[b].iter().map(|r| r.addr).collect(),
                    pp: p.arena.page_positions(),
                    half: p.arena.half(),
                    zero: p.arena.zero_addr(),
                },
            },
        }
    }
}

/// Copy cache positions `0..len` of `src` row `src_row` over `dst` row
/// `dst_row`, for every layer.  The raw copy behind
/// [`Backend::kv_splice`] and the multipath scratch/commit paths
/// (geometries must already be validated by the caller).  Same-ring
/// twin of [`copy_kv_span`] — the extra ring assert is the difference.
fn copy_kv_rows(dst: &mut NativeKv, dst_row: usize, src: &NativeKv, src_row: usize, len: usize) {
    debug_assert_eq!(dst.max_len, src.max_len, "KV ring mismatch");
    copy_kv_span(dst, dst_row, src, src_row, len)
}

/// Physically copy positions `lo..hi` of `src` row `src_row` over the
/// same positions of `dst` row `dst_row`, for every layer, through the
/// layout-agnostic block accessors — the generic path shared by the
/// boundary-partial-page copy, mixed-layout splices and
/// [`copy_kv_pos`].  Counts the moved bytes in [`kvstats`].
fn copy_kv_blocks(
    dst: &mut NativeKv,
    dst_row: usize,
    src: &NativeKv,
    src_row: usize,
    lo: usize,
    hi: usize,
) {
    if hi <= lo {
        return;
    }
    dst.ensure_writable_span(dst_row, lo, hi);
    for li in 0..src.n_layers {
        for pos in lo..hi {
            dst.k_block_mut(li, dst_row, pos).copy_from_slice(src.k_block(li, src_row, pos));
            dst.v_block_mut(li, dst_row, pos).copy_from_slice(src.v_block(li, src_row, pos));
        }
    }
    let moved = 2 * src.n_layers * (hi - lo) * src.n_heads * src.head_dim;
    kvstats::add_bytes_copied(moved as u64 * 4);
}

/// Copy cache positions `0..len` of `src` row `src_row` over `dst` row
/// `dst_row`, for every layer, tolerating caches with *different ring
/// lengths* — the cross-ring twin of [`copy_kv_rows`] the tree paths
/// need (tree scratch rings are [`NativeBackend::tree_scratch_len`]
/// long, the live ring `L`).  Ring tolerance is bounded, not silent:
/// the span must fit both rings (debug-asserted below), so a bad page
/// table or splice length fails loudly in tests instead of truncating.
///
/// Layout behaviour (observably identical, DESIGN.md §16.3):
/// * contig → contig: one chunk memcpy per layer (positions within a
///   layer are contiguous in both rings);
/// * paged → paged on the same arena: every **full** page in `0..len`
///   is aliased with a refcount bump — zero bytes moved — and only the
///   boundary partial page is physically copied, preserving the
///   destination page's `len..` tail exactly as the contig copy leaves
///   `dst` positions `len..` untouched (the in-page offset of a
///   position depends only on `pos % P`, so aliasing is ring-length
///   agnostic);
/// * mixed layouts / different arenas: generic per-block copy.
fn copy_kv_span(dst: &mut NativeKv, dst_row: usize, src: &NativeKv, src_row: usize, len: usize) {
    debug_assert_eq!(
        (dst.n_layers, dst.n_heads, dst.head_dim),
        (src.n_layers, src.n_heads, src.head_dim),
        "KV geometry mismatch"
    );
    debug_assert!(
        dst_row < dst.batch && src_row < src.batch,
        "KV row out of range (dst {dst_row}/{}, src {src_row}/{})",
        dst.batch,
        src.batch
    );
    debug_assert!(
        len <= src.max_len && len <= dst.max_len,
        "KV span {len} overruns a ring (dst ring {}, src ring {})",
        dst.max_len,
        src.max_len
    );
    if len == 0 {
        return;
    }
    let same_arena = match (&dst.pages, &src.pages) {
        (Some(d), Some(s)) => Arc::ptr_eq(&d.arena, &s.arena),
        _ => false,
    };
    if same_arena {
        let pp = src.pages.as_ref().unwrap().arena.page_positions();
        let full = len / pp;
        {
            let dp = dst.pages.as_mut().unwrap();
            let sp = src.pages.as_ref().unwrap();
            for pg in 0..full {
                let s = sp.tables[src_row][pg];
                let old = dp.tables[dst_row][pg];
                if s.id == old.id {
                    continue;
                }
                sp.arena.retain(s);
                sp.arena.release(old);
                dp.tables[dst_row][pg] = s;
            }
        }
        // Boundary partial page: physical copy of the in-span slots
        // only, keeping the destination's tail beyond `len` intact.
        copy_kv_blocks(dst, dst_row, src, src_row, full * pp, len);
        return;
    }
    if dst.pages.is_none() && src.pages.is_none() {
        let chunk = len * src.n_heads * src.head_dim;
        for li in 0..src.n_layers {
            let d0 = dst.row(li, dst_row, 0);
            let s0 = src.row(li, src_row, 0);
            dst.k[d0..d0 + chunk].copy_from_slice(&src.k[s0..s0 + chunk]);
            dst.v[d0..d0 + chunk].copy_from_slice(&src.v[s0..s0 + chunk]);
        }
        kvstats::add_bytes_copied((2 * src.n_layers * chunk) as u64 * 4);
        return;
    }
    copy_kv_blocks(dst, dst_row, src, src_row, 0, len);
}

/// Copy one cache position across rows (and possibly rings), for every
/// layer — the winner-commit gather of the tree path, where a leaf's
/// node slots are scattered through the scratch ring instead of
/// contiguous.
fn copy_kv_pos(
    dst: &mut NativeKv,
    dst_row: usize,
    dst_pos: usize,
    src: &NativeKv,
    src_row: usize,
    src_pos: usize,
) {
    debug_assert_eq!(
        (dst.n_layers, dst.n_heads, dst.head_dim),
        (src.n_layers, src.n_heads, src.head_dim),
        "KV geometry mismatch"
    );
    debug_assert!(
        dst_row < dst.batch && src_row < src.batch,
        "KV row out of range (dst {dst_row}/{}, src {src_row}/{})",
        dst.batch,
        src.batch
    );
    debug_assert!(
        dst_pos < dst.max_len && src_pos < src.max_len,
        "KV position out of range (dst {dst_pos}/{}, src {src_pos}/{})",
        dst.max_len,
        src.max_len
    );
    dst.ensure_writable_span(dst_row, dst_pos, dst_pos + 1);
    for li in 0..src.n_layers {
        dst.k_block_mut(li, dst_row, dst_pos).copy_from_slice(src.k_block(li, src_row, src_pos));
        dst.v_block_mut(li, dst_row, dst_pos).copy_from_slice(src.v_block(li, src_row, src_pos));
    }
    kvstats::add_bytes_copied((2 * src.n_layers * src.n_heads * src.head_dim) as u64 * 4);
}

// ---------------------------------------------------------------------------
// Math helpers (the matmul/dot kernels live in `super::kernels`)
// ---------------------------------------------------------------------------

/// tanh-approximated GELU (`jax.nn.gelu`'s default).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

/// In-place softmax over a logit row.
fn softmax_row(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Standard normal via Box–Muller on the deterministic stream.
fn normal(rng: &mut Rng) -> f64 {
    let u1 = rng.uniform().max(1e-12);
    let u2 = rng.uniform();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// Treat the i32 device seed as an unsigned 64-bit stream seed.
#[inline]
fn seed64(seed: i32) -> u64 {
    seed as u32 as u64
}

/// Categorical sample via the shared inverse-CDF convention
/// (`model.py::_sample_rows` / `dist::inv_cdf`).
fn sample_row(probs: &[f32], u: f64) -> usize {
    let w: Vec<f64> = probs.iter().map(|&p| p.max(0.0) as f64).collect();
    dist::inv_cdf(&w, u)
}

// ---------------------------------------------------------------------------
// Row-parallel forward pass (DESIGN.md §10)
// ---------------------------------------------------------------------------

/// Per-thread forward scratch: every intermediate buffer one row of
/// `forward_block` needs.  Allocated once per worker chunk per call, not
/// per row.
struct RowScratch {
    x: Vec<f32>,
    y: Vec<f32>,
    q: Vec<f32>,
    kx: Vec<f32>,
    vx: Vec<f32>,
    o: Vec<f32>,
    ff: Vec<f32>,
    att: Vec<f32>,
    /// Activation-quantisation scratch for the int8 integer GEMMs
    /// (`kernels::matmul_q8_i32`); unused on fp32 forwards.
    qscr: QuantScratch,
    /// Quantised normed row for the int8 unembedding dot.
    xq: Vec<i8>,
}

impl RowScratch {
    fn new(dims: &ModelDims, t: usize, l: usize) -> Self {
        let d = dims.d_model;
        RowScratch {
            x: vec![0.0; t * d],
            y: vec![0.0; t * d],
            q: vec![0.0; t * d],
            kx: vec![0.0; t * d],
            vx: vec![0.0; t * d],
            o: vec![0.0; t * d],
            ff: vec![0.0; t * dims.d_ff()],
            att: vec![0.0; l],
            qscr: QuantScratch::default(),
            xq: vec![0; d],
        }
    }
}

/// One batch row's inputs and disjoint mutable outputs — the unit of
/// work handed to the thread pool.  `kv` is the row's resolved KV view
/// ([`RowKvView`]): rows never alias in either layout (batch-major
/// contig rows are disjoint slices; paged rows write only pages the
/// ensure-writable pre-pass made uniquely owned).
struct RowSlot<'a> {
    kv: RowKvView,
    probs: Option<&'a mut [f32]>,
    toks: &'a [i32],
    start: i32,
}

/// Tile-major packed fp32 twin of one transformer block — the SIMD
/// kernel's weight layout ([`PackedF32`]).
pub(crate) struct PackedLayer {
    wq: PackedF32,
    wk: PackedF32,
    wv: PackedF32,
    wo: PackedF32,
    w1: PackedF32,
    w2: PackedF32,
}

/// Tile-major packed fp32 model twin, built once per model at
/// [`Backend::prepare`] time (or lazily on the first `Simd` forward) and
/// cached on the backend keyed by model name — the same keyed-pool idiom
/// as the int8 twins.  Only the six GEMM matrices per layer pack; the
/// embedding is consumed row-wise through `dot_f32` (already contiguous)
/// and the norms are vectors.
pub(crate) struct PackedModel {
    layers: Vec<PackedLayer>,
}

impl PackedModel {
    fn pack(m: &NativeModel) -> PackedModel {
        let d = m.dims.d_model;
        let f = m.dims.d_ff();
        PackedModel {
            layers: m
                .layers
                .iter()
                .map(|l| PackedLayer {
                    wq: PackedF32::pack(&l.wq, d, d),
                    wk: PackedF32::pack(&l.wk, d, d),
                    wv: PackedF32::pack(&l.wv, d, d),
                    wo: PackedF32::pack(&l.wo, d, d),
                    w1: PackedF32::pack(&l.w1, d, f),
                    w2: PackedF32::pack(&l.w2, f, d),
                })
                .collect(),
        }
    }
}

/// `out += x @ w`, routed through the exact i8×i8→i32 integer GEMM when
/// the layer runs quantised and the configured fp32 kernel otherwise —
/// the single dispatch point of the draft-precision knob inside a
/// forward.  The int8 route ignores the fp32 kernel choice except to
/// pick the (bit-identical) layout walked: `Reference` runs the scalar
/// row-major oracle, everything else the SIMD-dispatched tile-major
/// twin; integer accumulation makes both exact, so the quantised stream
/// is kernel- and ISA-invariant (DESIGN.md §12.3).
#[inline]
#[allow(clippy::too_many_arguments)]
fn matmul_any(
    kernel: MatKernel,
    qm: Option<&QuantMatrix>,
    pm: Option<&PackedF32>,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
    scr: &mut QuantScratch,
) {
    match qm {
        Some(qm) => match kernel {
            MatKernel::Reference => {
                matmul_q8_i32_ref(x, &qm.q, &qm.scale, out, t, d_in, d_out, scr)
            }
            _ => matmul_q8_i32(x, &qm.qt, &qm.scale, out, t, d_in, d_out, scr),
        },
        None => kernel.matmul_acc(x, w, pm, out, t, d_in, d_out),
    }
}

/// Forward `t` tokens of one row through `model`, mirroring the per-row
/// body of `model.py::forward_block`: embeds, runs every transformer
/// layer (rewriting the row's cache positions `ws..ws+t`), and — when
/// the slot carries a probs slice — applies the final norm + tied
/// unembedding + softmax.  With `quant` set, every weight matrix and the
/// tied embedding (lookup *and* unembedding — the same int8 table both
/// ways, so the row runs one well-defined int8 model, DESIGN.md §11)
/// come from the quantised twin; layer norms and positions stay fp32
/// while GEMM activations quantise per token row inside the integer
/// kernels.  Pure function of `(model, quant, packed, slot, t, l)`; the
/// scratch is write-before-read throughout, so results are independent
/// of which thread runs the row and of whatever a previous row left in
/// the buffers (the threading determinism contract).  `packed` is the
/// tile-major fp32 twin the `Simd` kernel streams; `None` falls back to
/// the bit-identical blocked kernel.
#[allow(clippy::too_many_arguments)]
fn forward_row(
    model: &NativeModel,
    quant: Option<&QuantModel>,
    packed: Option<&PackedModel>,
    kernel: MatKernel,
    slot: RowSlot<'_>,
    t: usize,
    l: usize,
    s: &mut RowScratch,
) {
    let dims = &model.dims;
    let (d, h, hd, vcb) = (dims.d_model, dims.n_heads, dims.head_dim(), dims.vocab_size);
    let scale = (hd as f32).powf(-0.5);
    let start = slot.start.max(0) as usize;
    // Clamped write origin, as jax.lax.dynamic_update_slice does.
    let ws = start.min(l.saturating_sub(t));
    let RowSlot { mut kv, probs, toks, .. } = slot;
    // Embed + positions (positions clamped for lookup only).
    for j in 0..t {
        let tok = (toks[j].max(0) as usize).min(vcb - 1);
        let p = (start + j).min(l - 1);
        match quant {
            None => {
                for di in 0..d {
                    s.x[j * d + di] = model.embed[tok * d + di] + model.pos[p * d + di];
                }
            }
            Some(qm) => {
                let (qrow, qs) = qm.embed.row(tok);
                for di in 0..d {
                    s.x[j * d + di] = qrow[di] as f32 * qs + model.pos[p * d + di];
                }
            }
        }
    }
    for (li, layer) in model.layers.iter().enumerate() {
        let ql = quant.map(|qm| &qm.layers[li]);
        let pl = packed.map(|pm| &pm.layers[li]);
        layer.ln1.apply(&s.x, &mut s.y, d);
        s.q.iter_mut().for_each(|z| *z = 0.0);
        s.kx.iter_mut().for_each(|z| *z = 0.0);
        s.vx.iter_mut().for_each(|z| *z = 0.0);
        let (wq, wk, wv) = (ql.map(|q| &q.wq), ql.map(|q| &q.wk), ql.map(|q| &q.wv));
        let (pq, pk, pv) = (pl.map(|p| &p.wq), pl.map(|p| &p.wk), pl.map(|p| &p.wv));
        matmul_any(kernel, wq, pq, &s.y, &layer.wq, &mut s.q, t, d, d, &mut s.qscr);
        matmul_any(kernel, wk, pk, &s.y, &layer.wk, &mut s.kx, t, d, d, &mut s.qscr);
        matmul_any(kernel, wv, pv, &s.y, &layer.wv, &mut s.vx, t, d, d, &mut s.qscr);
        // Write the new K/V rows into the cache at ws..ws+t.
        for j in 0..t {
            kv.k_block_mut(li, ws + j).copy_from_slice(&s.kx[j * d..(j + 1) * d]);
            kv.v_block_mut(li, ws + j).copy_from_slice(&s.vx[j * d..(j + 1) * d]);
        }
        // Causal attention over the cache: key_pos <= query_pos.
        s.o.iter_mut().for_each(|z| *z = 0.0);
        for j in 0..t {
            let qpos = start + j;
            let hi = qpos.min(l - 1); // attend keys 0..=hi
            for hh in 0..h {
                let qv = &s.q[j * d + hh * hd..j * d + (hh + 1) * hd];
                let mut mx = f32::NEG_INFINITY;
                for (sp, a) in s.att[..=hi].iter_mut().enumerate() {
                    let kb = &kv.k_block(li, sp)[hh * hd..hh * hd + hd];
                    *a = dot_f32(qv, kb) * scale;
                    mx = mx.max(*a);
                }
                let mut sum = 0.0f32;
                for a in s.att[..=hi].iter_mut() {
                    *a = (*a - mx).exp();
                    sum += *a;
                }
                let inv = 1.0 / sum.max(1e-30);
                let orow = &mut s.o[j * d + hh * hd..j * d + (hh + 1) * hd];
                for (sp, &a) in s.att[..=hi].iter().enumerate() {
                    let w = a * inv;
                    let vr = &kv.v_block(li, sp)[hh * hd..hh * hd + hd];
                    for (ov, &vv) in orow.iter_mut().zip(vr.iter()) {
                        *ov += w * vv;
                    }
                }
            }
        }
        // x += o @ wo
        s.y.iter_mut().for_each(|z| *z = 0.0);
        let (wo, po) = (ql.map(|q| &q.wo), pl.map(|p| &p.wo));
        matmul_any(kernel, wo, po, &s.o, &layer.wo, &mut s.y, t, d, d, &mut s.qscr);
        for (xv, yv) in s.x.iter_mut().zip(s.y.iter()) {
            *xv += *yv;
        }
        // MLP: x += gelu(ln2(x) @ w1) @ w2
        layer.ln2.apply(&s.x, &mut s.y, d);
        s.ff.iter_mut().for_each(|z| *z = 0.0);
        let (w1, p1) = (ql.map(|q| &q.w1), pl.map(|p| &p.w1));
        let ff = dims.d_ff();
        matmul_any(kernel, w1, p1, &s.y, &layer.w1, &mut s.ff, t, d, ff, &mut s.qscr);
        s.ff.iter_mut().for_each(|z| *z = gelu(*z));
        s.y.iter_mut().for_each(|z| *z = 0.0);
        let (w2, p2) = (ql.map(|q| &q.w2), pl.map(|p| &p.w2));
        matmul_any(kernel, w2, p2, &s.ff, &layer.w2, &mut s.y, t, ff, d, &mut s.qscr);
        for (xv, yv) in s.x.iter_mut().zip(s.y.iter()) {
            *xv += *yv;
        }
    }
    let Some(probs) = probs else { return };
    // Final norm + tied unembedding + softmax.
    model.ln_f.apply(&s.x, &mut s.y, d);
    for j in 0..t {
        let xrow = &s.y[j * d..(j + 1) * d];
        // Int8 unembedding: quantise the normed row once, then one exact
        // i8×i8→i32 dot per vocab row, rescaled by the product of the
        // activation and embedding-row scales (DESIGN.md §12.3).
        let sx = match quant {
            Some(_) => quantise_row_q8(xrow, &mut s.xq),
            None => 0.0,
        };
        let prow = &mut probs[j * vcb..(j + 1) * vcb];
        for (tok, pv) in prow.iter_mut().enumerate() {
            let mut dot = match quant {
                None => dot_f32(xrow, &model.embed[tok * d..(tok + 1) * d]),
                Some(qm) => {
                    let (qrow, qs) = qm.embed.row(tok);
                    dot_q8_i32(&s.xq, qrow) as f32 * (sx * qs)
                }
            };
            if (tok as u32) < vocab::CONTENT_BASE {
                dot += model.control_logit_bias;
            }
            *pv = dot;
        }
        softmax_row(prow);
    }
}

/// One batch row's token-tree forward inputs (DESIGN.md §13.2).  Unlike
/// the flat [`RowSlot`] — where a call's tokens occupy contiguous
/// positions and attend a contiguous prefix — every tree token carries
/// its own flat sequence position, KV write slot and explicit ascending
/// visible-slot list (shared prefix, then ancestors by node index, then
/// self: the tree attention mask over the node→parent table).
struct TreeSlot<'a> {
    kv: RowKvView,
    probs: &'a mut [f32],
    toks: &'a [i32],
    /// Flat sequence position per token (`len + depth` — what the token's
    /// position would be on its own path), indexing the position table
    /// (clamped into the model ring exactly like [`forward_row`]).
    pos: &'a [usize],
    /// KV write slot per token within the scratch ring (`len + node`).
    slot: &'a [usize],
    /// Visible scratch slots per token, strictly ascending, self last.
    vis: &'a [Vec<usize>],
}

/// Per-row token batch for one tree forward call (the owning twin of
/// [`TreeSlot`], built level-by-level by the tree drafter scan and in
/// one piece by the tree scorer).
#[derive(Default)]
struct TreeTokens {
    toks: Vec<i32>,
    pos: Vec<usize>,
    slot: Vec<usize>,
    vis: Vec<Vec<usize>>,
}

impl TreeTokens {
    fn push(&mut self, tok: i32, pos: usize, slot: usize, vis: Vec<usize>) {
        self.toks.push(tok);
        self.pos.push(pos);
        self.slot.push(slot);
        self.vis.push(vis);
    }
}

/// The ascending visible-slot list of node `node` in a row whose shared
/// prefix (prompt + pending token) occupies scratch slots `0..len`:
/// prefix slots, then the node's ancestors (parents precede children, so
/// ascending node index == ascending depth == the flat path's position
/// order), then the node itself.  Walking this list accumulates the
/// attention softmax in exactly the order [`forward_row`] walks slots
/// `0..=hi` on the equivalent flat path — the bit-identity contract.
fn visible_slots(len: usize, parent: &[i32], node: usize) -> Vec<usize> {
    let mut anc = Vec::new();
    let mut n = node as i32;
    while n >= 0 {
        anc.push(len + n as usize);
        n = parent[n as usize];
    }
    anc.reverse();
    let mut vis: Vec<usize> = (0..len).collect();
    vis.extend(anc);
    vis
}

/// Forward one row's tree tokens, replicating [`forward_row`]'s float
/// arithmetic operation for operation — same kernels, same per-layer
/// write-KV-then-attend order, same streaming softmax accumulation —
/// with the contiguous position/slot/visibility arithmetic replaced by
/// [`TreeSlot`]'s explicit per-token lists.  A token's outputs therefore
/// match the flat forward of its root-to-leaf path bit for bit
/// (test-enforced via the `Algo::Tree`/`Algo::MultiPath` ladder).
/// `lm` is the model ring (position-table) length; the scratch ring the
/// slots index is carried by the slot's [`RowKvView`].
#[allow(clippy::too_many_arguments)]
fn forward_tree_row(
    model: &NativeModel,
    quant: Option<&QuantModel>,
    packed: Option<&PackedModel>,
    kernel: MatKernel,
    slot: TreeSlot<'_>,
    lm: usize,
    s: &mut RowScratch,
) {
    let dims = &model.dims;
    let (d, h, hd, vcb) = (dims.d_model, dims.n_heads, dims.head_dim(), dims.vocab_size);
    let scale = (hd as f32).powf(-0.5);
    let t = slot.toks.len();
    let TreeSlot { mut kv, probs, toks, pos, slot: wslot, vis } = slot;
    // Embed + positions (position lookup clamped like forward_row).
    for j in 0..t {
        let tok = (toks[j].max(0) as usize).min(vcb - 1);
        let p = pos[j].min(lm - 1);
        match quant {
            None => {
                for di in 0..d {
                    s.x[j * d + di] = model.embed[tok * d + di] + model.pos[p * d + di];
                }
            }
            Some(qm) => {
                let (qrow, qs) = qm.embed.row(tok);
                for di in 0..d {
                    s.x[j * d + di] = qrow[di] as f32 * qs + model.pos[p * d + di];
                }
            }
        }
    }
    for (li, layer) in model.layers.iter().enumerate() {
        let ql = quant.map(|qm| &qm.layers[li]);
        let pl = packed.map(|pm| &pm.layers[li]);
        layer.ln1.apply(&s.x, &mut s.y, d);
        s.q.iter_mut().for_each(|z| *z = 0.0);
        s.kx.iter_mut().for_each(|z| *z = 0.0);
        s.vx.iter_mut().for_each(|z| *z = 0.0);
        let (wq, wk, wv) = (ql.map(|q| &q.wq), ql.map(|q| &q.wk), ql.map(|q| &q.wv));
        let (pq, pk, pv) = (pl.map(|p| &p.wq), pl.map(|p| &p.wk), pl.map(|p| &p.wv));
        matmul_any(kernel, wq, pq, &s.y, &layer.wq, &mut s.q, t, d, d, &mut s.qscr);
        matmul_any(kernel, wk, pk, &s.y, &layer.wk, &mut s.kx, t, d, d, &mut s.qscr);
        matmul_any(kernel, wv, pv, &s.y, &layer.wv, &mut s.vx, t, d, d, &mut s.qscr);
        // Write every token's K/V rows at its own slot before attention
        // (the flat forward's write-then-attend order; tokens of one call
        // are never each other's ancestors, so visibility is unaffected).
        for j in 0..t {
            kv.k_block_mut(li, wslot[j]).copy_from_slice(&s.kx[j * d..(j + 1) * d]);
            kv.v_block_mut(li, wslot[j]).copy_from_slice(&s.vx[j * d..(j + 1) * d]);
        }
        // Tree attention: each token attends exactly its visible slots.
        s.o.iter_mut().for_each(|z| *z = 0.0);
        for j in 0..t {
            let nv = vis[j].len();
            for hh in 0..h {
                let qv = &s.q[j * d + hh * hd..j * d + (hh + 1) * hd];
                let mut mx = f32::NEG_INFINITY;
                for (a, &sp) in s.att[..nv].iter_mut().zip(vis[j].iter()) {
                    let kb = &kv.k_block(li, sp)[hh * hd..hh * hd + hd];
                    *a = dot_f32(qv, kb) * scale;
                    mx = mx.max(*a);
                }
                let mut sum = 0.0f32;
                for a in s.att[..nv].iter_mut() {
                    *a = (*a - mx).exp();
                    sum += *a;
                }
                let inv = 1.0 / sum.max(1e-30);
                let orow = &mut s.o[j * d + hh * hd..j * d + (hh + 1) * hd];
                for (&a, &sp) in s.att[..nv].iter().zip(vis[j].iter()) {
                    let w = a * inv;
                    let vr = &kv.v_block(li, sp)[hh * hd..hh * hd + hd];
                    for (ov, &vv) in orow.iter_mut().zip(vr.iter()) {
                        *ov += w * vv;
                    }
                }
            }
        }
        // x += o @ wo
        s.y.iter_mut().for_each(|z| *z = 0.0);
        let (wo, po) = (ql.map(|q| &q.wo), pl.map(|p| &p.wo));
        matmul_any(kernel, wo, po, &s.o, &layer.wo, &mut s.y, t, d, d, &mut s.qscr);
        for (xv, yv) in s.x.iter_mut().zip(s.y.iter()) {
            *xv += *yv;
        }
        // MLP: x += gelu(ln2(x) @ w1) @ w2
        layer.ln2.apply(&s.x, &mut s.y, d);
        s.ff.iter_mut().for_each(|z| *z = 0.0);
        let (w1, p1) = (ql.map(|q| &q.w1), pl.map(|p| &p.w1));
        let ff = dims.d_ff();
        matmul_any(kernel, w1, p1, &s.y, &layer.w1, &mut s.ff, t, d, ff, &mut s.qscr);
        s.ff.iter_mut().for_each(|z| *z = gelu(*z));
        s.y.iter_mut().for_each(|z| *z = 0.0);
        let (w2, p2) = (ql.map(|q| &q.w2), pl.map(|p| &p.w2));
        matmul_any(kernel, w2, p2, &s.ff, &layer.w2, &mut s.y, t, ff, d, &mut s.qscr);
        for (xv, yv) in s.x.iter_mut().zip(s.y.iter()) {
            *xv += *yv;
        }
    }
    // Final norm + tied unembedding + softmax (tree forwards always want
    // probs — every node's distribution feeds sampling or verification).
    model.ln_f.apply(&s.x, &mut s.y, d);
    for j in 0..t {
        let xrow = &s.y[j * d..(j + 1) * d];
        let sx = match quant {
            Some(_) => quantise_row_q8(xrow, &mut s.xq),
            None => 0.0,
        };
        let prow = &mut probs[j * vcb..(j + 1) * vcb];
        for (tok, pv) in prow.iter_mut().enumerate() {
            let mut dot = match quant {
                None => dot_f32(xrow, &model.embed[tok * d..(tok + 1) * d]),
                Some(qm) => {
                    let (qrow, qs) = qm.embed.row(tok);
                    dot_q8_i32(&s.xq, qrow) as f32 * (sx * qs)
                }
            };
            if (tok as u32) < vocab::CONTENT_BASE {
                dot += model.control_logit_bias;
            }
            *pv = dot;
        }
        softmax_row(prow);
    }
}

/// The verification uniforms one row draws from its per-row seed: `etas
/// (gamma,)` and the residual-sampling uniform `u`.  A pure function of
/// `(seed, gamma)` — no batch or slot index enters, which is what makes
/// a row's verification stream slot-independent (the continuous-batching
/// losslessness contract, DESIGN.md §7).  Public so the cross-backend
/// losslessness tests can replay the fused path's randomness through the
/// host `verify::verify` dispatch draw-for-draw.
pub fn verify_uniforms(seed: i32, gamma: usize) -> (Vec<f64>, f64) {
    let mut eta_rng = Rng::new(seed64(seed) ^ DOM_ETA);
    let etas: Vec<f64> = (0..gamma).map(|_| eta_rng.uniform()).collect();
    let mut u_rng = Rng::new(seed64(seed) ^ DOM_RESIDUAL);
    (etas, u_rng.uniform())
}

/// Per-path stream under a domain separator: path 0 is the plain
/// single-draft stream for the seed (so `k = 1` multipath replays
/// single-path behaviour draw for draw), and each later path folds its
/// index into an independent stream.
fn path_rng(seed: i32, dom: u64, path: usize) -> Rng {
    let base = Rng::new(seed64(seed) ^ dom);
    if path == 0 {
        base
    } else {
        base.fold_in(path as u64)
    }
}

/// The verification uniforms one row draws for a `k`-path draft set:
/// `gamma` acceptance etas per path plus the shared residual uniform
/// (only the winning stage consumes it — see
/// [`crate::verify::multipath_verify`]).  Path 0's etas and the residual
/// uniform replay [`verify_uniforms`] exactly, which is what makes
/// `Algo::MultiPath { k: 1 }` bit-identical to `Algo::Block`
/// (test-enforced).  Public for the same draw-for-draw replay tests.
pub fn multipath_uniforms(seed: i32, gamma: usize, k: usize) -> (Vec<Vec<f64>>, f64) {
    let etas: Vec<Vec<f64>> = (0..k)
        .map(|path| {
            let mut rng = path_rng(seed, DOM_ETA, path);
            (0..gamma).map(|_| rng.uniform()).collect()
        })
        .collect();
    let mut u_rng = Rng::new(seed64(seed) ^ DOM_RESIDUAL);
    (etas, u_rng.uniform())
}

// ---------------------------------------------------------------------------
// Seeded initialisation
// ---------------------------------------------------------------------------

/// Damping applied to layer weights in seeded mode so the shared
/// embedding/position signal dominates the logits (see module docs).
const LAYER_DAMP: f64 = 0.5;
/// Position-table scale in seeded mode (larger than the trained 0.02 so
/// next-token distributions vary along the sequence without training).
const POS_SCALE: f64 = 0.3;

fn seeded_matrix(rng: &mut Rng, d_in: usize, d_out: usize, scale: f64) -> Vec<f32> {
    (0..d_in * d_out).map(|_| (normal(rng) * scale) as f32).collect()
}

fn seeded_model(name: &str, dims: ModelDims, max_len: usize, seed: u64) -> NativeModel {
    let dims = ModelDims { max_len, ..dims };
    let d = dims.d_model;
    let emb_scale = (d as f64).powf(-0.5);
    // Per-token shared streams: a drafter's row is a prefix of the
    // target's, making the tied-head logits of the family correlated.
    let mut embed = Vec::with_capacity(dims.vocab_size * d);
    let base = Rng::new(seed ^ DOM_EMBED);
    for tok in 0..dims.vocab_size {
        let mut s = base.fold_in(tok as u64);
        for _ in 0..d {
            embed.push((normal(&mut s) * emb_scale) as f32);
        }
    }
    let mut pos = Vec::with_capacity(max_len * d);
    let base = Rng::new(seed ^ DOM_POS);
    for p in 0..max_len {
        let mut s = base.fold_in(p as u64);
        for _ in 0..d {
            pos.push((normal(&mut s) * POS_SCALE) as f32);
        }
    }
    // Layer weights are per-model (damped) streams.
    let mut name_mix = 0u64;
    for b in name.bytes() {
        name_mix = name_mix.wrapping_mul(0x100_0000_01b3).wrapping_add(b as u64);
    }
    let mut layers = Vec::with_capacity(dims.n_layers);
    let f = dims.d_ff();
    for li in 0..dims.n_layers {
        let mut s = Rng::new(seed ^ DOM_LAYER ^ name_mix).fold_in(li as u64);
        let att_scale = LAYER_DAMP * (d as f64).powf(-0.5);
        let ff_scale = LAYER_DAMP * (f as f64).powf(-0.5);
        layers.push(Layer {
            ln1: LayerNorm::identity(d),
            ln2: LayerNorm::identity(d),
            wq: seeded_matrix(&mut s, d, d, att_scale),
            wk: seeded_matrix(&mut s, d, d, att_scale),
            wv: seeded_matrix(&mut s, d, d, att_scale),
            wo: seeded_matrix(&mut s, d, d, att_scale),
            w1: seeded_matrix(&mut s, d, f, att_scale),
            w2: seeded_matrix(&mut s, f, d, ff_scale),
        });
    }
    NativeModel {
        dims,
        embed,
        pos,
        layers,
        ln_f: LayerNorm::identity(d),
        control_logit_bias: -12.0,
    }
}

// ---------------------------------------------------------------------------
// Artifact loading
// ---------------------------------------------------------------------------

/// All weight tensors of one model, keyed by their pytree keystr name
/// (e.g. `['layer_0']['wq']`), as exported by `aot.write_weights`.
struct WeightMap {
    tensors: HashMap<String, (Vec<usize>, Vec<f32>)>,
}

impl WeightMap {
    fn load(dir: &Path, meta: &crate::runtime::ModelMeta) -> anyhow::Result<Self> {
        let path = dir.join(&meta.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = HashMap::new();
        for w in &meta.weights {
            let n: usize = w.shape.iter().product::<usize>().max(1);
            let slice = floats
                .get(w.offset..w.offset + n)
                .ok_or_else(|| anyhow!("weights file too short for {}", w.name))?;
            tensors.insert(w.name.clone(), (w.shape.clone(), slice.to_vec()));
        }
        Ok(WeightMap { tensors })
    }

    /// Remove and return a tensor (each is consumed exactly once, so no
    /// second copy of the weights is ever held).
    fn take(&mut self, name: &str, shape: &[usize]) -> anyhow::Result<Vec<f32>> {
        let (got_shape, data) = self
            .tensors
            .remove(name)
            .ok_or_else(|| anyhow!("weight tensor '{name}' missing from bundle"))?;
        if got_shape != shape {
            return Err(anyhow!("weight '{name}': shape {got_shape:?}, expected {shape:?}"));
        }
        Ok(data)
    }
}

fn take_ln(w: &mut WeightMap, prefix: &str, d: usize) -> anyhow::Result<LayerNorm> {
    Ok(LayerNorm {
        g: w.take(&format!("{prefix}['g']"), &[d])?,
        b: w.take(&format!("{prefix}['b']"), &[d])?,
    })
}

fn model_from_artifacts(
    dir: &Path,
    meta: &crate::runtime::ModelMeta,
) -> anyhow::Result<NativeModel> {
    let dims = ModelDims {
        n_layers: meta.n_layers,
        d_model: meta.d_model,
        n_heads: meta.n_heads,
        vocab_size: meta.vocab_size,
        max_len: meta.max_len,
    };
    let d = dims.d_model;
    let f = dims.d_ff();
    let mut w = WeightMap::load(dir, meta)?;
    let mut layers = Vec::with_capacity(dims.n_layers);
    for li in 0..dims.n_layers {
        let p = format!("['layer_{li}']");
        layers.push(Layer {
            ln1: take_ln(&mut w, &format!("{p}['ln1']"), d)?,
            ln2: take_ln(&mut w, &format!("{p}['ln2']"), d)?,
            wq: w.take(&format!("{p}['wq']"), &[d, d])?,
            wk: w.take(&format!("{p}['wk']"), &[d, d])?,
            wv: w.take(&format!("{p}['wv']"), &[d, d])?,
            wo: w.take(&format!("{p}['wo']"), &[d, d])?,
            w1: w.take(&format!("{p}['w1']"), &[d, f])?,
            w2: w.take(&format!("{p}['w2']"), &[f, d])?,
        });
    }
    Ok(NativeModel {
        dims,
        embed: w.take("['embed']", &[dims.vocab_size, d])?,
        pos: w.take("['pos']", &[dims.max_len, d])?,
        layers,
        ln_f: take_ln(&mut w, "['ln_f']", d)?,
        control_logit_bias: 0.0,
    })
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// The pure-Rust CPU backend.
pub struct NativeBackend {
    info: BackendInfo,
    models: HashMap<String, NativeModel>,
    /// Forward-pass thread count (callers + pool workers); see
    /// [`NativeBackend::with_threads`].
    threads: usize,
    /// Persistent workers for the batch-parallel forward, spawned on the
    /// first parallel `forward_block` (a `threads = 1` backend never
    /// spawns any).
    pool: OnceLock<ThreadPool>,
    /// The fp32 matmul kernel the forwards run with (reference, blocked,
    /// or SIMD; bit-identical outputs either way — DESIGN.md §12.2).
    /// Defaults to the process-wide [`default_kernel`] choice
    /// (`SPECD_NATIVE_KERNEL`).
    kernel: MatKernel,
    /// Reuse the `(B·K)`-row multipath scratch caches across iterations
    /// instead of allocating fresh ones per call.
    persistent_scratch: bool,
    /// The persistent scratch caches, keyed by `(model name, rows,
    /// ring length)`.  Entries are taken out for the duration of a
    /// multipath/tree call (so concurrent engines never alias one) and
    /// returned afterwards; the per-key stack holds one cache per
    /// concurrently-active engine.  Batched admission prefills
    /// ([`Backend::prefill_rows`]) draw their `(B,)`-row forward scratch
    /// from the same pool.  The ring length is part of the key because
    /// tree scratches run an extended ring
    /// ([`NativeBackend::tree_scratch_len`]): a flat `B·K`-row checkout
    /// must never alias a tree checkout that happens to hold the same
    /// row count (regression-tested in `tests/native_fast.rs`).
    scratch: Mutex<HashMap<(String, usize, usize), Vec<NativeKv>>>,
    /// Entropy-gap branch threshold for `Algo::Tree` drafting
    /// ([`BranchPolicy::EntropyGap`]): coincident draws at a node share
    /// one child only when the parent distribution's top-2 probability
    /// gap is at least this value.  `0.0` (the default) always shares;
    /// `f64::INFINITY` never does (the multipath layout twin).  Sharing
    /// never changes emitted bits — only how many drafted tokens are
    /// scored (DESIGN.md §13.3).
    branch_threshold: f64,
    /// Draft-model inference precision ([`Precision`] as u8): fp32, or
    /// the int8 quantised-weight path (DESIGN.md §11).  Backend-wide —
    /// set at construction (env `SPECD_DRAFT_PRECISION`, default int8),
    /// overridden by [`NativeBackend::with_draft_precision`] or the
    /// engine's `draft_precision` config via [`Backend::prepare`].  The
    /// target model always runs fp32.
    draft_precision: AtomicU8,
    /// Quantise-once cache of int8 model twins, keyed by model name —
    /// the same keyed-pool idiom as `scratch`.
    quant: Mutex<HashMap<String, Arc<QuantModel>>>,
    /// Pack-once cache of tile-major fp32 model twins for the SIMD
    /// kernel, keyed by model name (same idiom as `quant`).
    packed: Mutex<HashMap<String, Arc<PackedModel>>>,
    /// Physical KV layout every cache this backend allocates uses:
    /// scatter-paged (the default) or ring-contiguous (the bit-identity
    /// oracle).  Set at construction (`SPECD_KV_LAYOUT`), overridden by
    /// [`NativeBackend::with_kv_layout`] or the engine's `kv_layout`
    /// config via [`Backend::prepare`]-time construction.
    kv_layout: KvLayout,
    /// One [`PageArena`] per model (keyed by name, same idiom as
    /// `quant`/`packed`): every paged cache of a model — live rings,
    /// scratch checkouts, extracted prefixes — draws pages from the same
    /// arena, which is what lets splices alias pages instead of copying.
    /// Empty under the contiguous layout.
    arenas: Mutex<HashMap<String, Arc<PageArena>>>,
}

/// Forward-pass thread count default: `SPECD_NATIVE_THREADS` when set
/// (and valid), else the machine's parallelism capped at 4 (the serving
/// batch is small; more threads than rows just idle).  An unparsable
/// value falls back *loudly* (stderr), matching `SPECD_DRAFT_PRECISION`
/// and `SPECD_NATIVE_KERNEL`: a typo must not silently change an
/// operator's intended parallelism.
fn default_threads() -> usize {
    if let Ok(s) = std::env::var("SPECD_NATIVE_THREADS") {
        match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n.min(64),
            _ => eprintln!(
                "specd: ignoring invalid SPECD_NATIVE_THREADS '{s}' (want 1..=64); using auto"
            ),
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
}

/// Tree branch-threshold default: `SPECD_TREE_THRESHOLD` when set (and a
/// valid non-negative float), else 0.0 (always share coincident draws).
/// An unparsable value falls back *loudly* (stderr), matching the other
/// `SPECD_*` knobs.
fn default_branch_threshold() -> f64 {
    if let Ok(s) = std::env::var("SPECD_TREE_THRESHOLD") {
        match s.trim().parse::<f64>() {
            Ok(t) if t >= 0.0 => return t,
            _ => eprintln!(
                "specd: ignoring invalid SPECD_TREE_THRESHOLD '{s}' (want >= 0); using 0"
            ),
        }
    }
    0.0
}

impl NativeBackend {
    fn with_models(mut info: BackendInfo, models: HashMap<String, NativeModel>) -> Self {
        let kv_layout = KvLayout::from_env_or_default();
        info.paged_kv = kv_layout == KvLayout::Paged;
        NativeBackend {
            info,
            models,
            threads: default_threads(),
            pool: OnceLock::new(),
            kernel: default_kernel(),
            persistent_scratch: true,
            scratch: Mutex::new(HashMap::new()),
            branch_threshold: default_branch_threshold(),
            draft_precision: AtomicU8::new(Precision::from_env_or_default() as u8),
            quant: Mutex::new(HashMap::new()),
            packed: Mutex::new(HashMap::new()),
            kv_layout,
            arenas: Mutex::new(HashMap::new()),
        }
    }

    /// Hermetic backend at the standard serving shapes (`B=4`, `L=96`,
    /// target + xxs + xxxs) with deterministic seeded weights.
    pub fn seeded(seed: u64) -> Self {
        Self::seeded_with_shapes(models::BATCH, models::MAX_LEN, seed)
    }

    /// Hermetic backend with custom batch/ring shapes (smaller rings make
    /// property tests markedly faster).
    pub fn seeded_with_shapes(batch: usize, max_len: usize, seed: u64) -> Self {
        assert!(batch >= 1 && max_len >= 16, "degenerate serving shapes");
        let mut models_map = HashMap::new();
        for name in ["target", "xxs", "xxxs"] {
            let dims = models::dims_for(name).expect("family variant");
            models_map.insert(name.to_string(), seeded_model(name, dims, max_len, seed));
        }
        Self::with_models(
            BackendInfo {
                name: "native".into(),
                batch,
                max_len,
                vocab_size: vocab::SIZE as usize,
                gammas: vec![4, 6, 8],
                open_gamma: true,
                drafters: models::DRAFTERS.iter().map(|s| s.to_string()).collect(),
                artifacts_dir: None,
                // Overwritten by `with_models` from the layout knob.
                paged_kv: false,
            },
            models_map,
        )
    }

    /// Load trained weights from an artifact bundle (`manifest.json` +
    /// `weights_*.bin`), sharing shapes with the PJRT programs.
    pub fn from_artifacts(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let mut models_map = HashMap::new();
        for (name, meta) in &manifest.models {
            models_map.insert(
                name.clone(),
                model_from_artifacts(dir, meta)
                    .with_context(|| format!("loading model {name}"))?,
            );
        }
        Ok(Self::with_models(
            BackendInfo {
                name: "native".into(),
                batch: manifest.batch,
                max_len: manifest.max_len,
                vocab_size: manifest.vocab_size,
                gammas: manifest.gammas.clone(),
                open_gamma: true,
                drafters: manifest.drafters.clone(),
                artifacts_dir: Some(dir.to_path_buf()),
                // Overwritten by `with_models` from the layout knob.
                paged_kv: false,
            },
            models_map,
        ))
    }

    /// Override the forward-pass thread count (1 = fully sequential, the
    /// reference for the bit-identical-under-threading contract).  Rows
    /// are split into contiguous chunks across the pool; every row's
    /// arithmetic is independent of the split, so any `threads` value
    /// produces identical bits (test-enforced).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self.pool = OnceLock::new();
        self
    }

    /// Switch the forward pass to the scalar reference matmul kernel
    /// (`benches/native_fast.rs`'s baseline).  Outputs are bit-identical
    /// to the blocked and SIMD kernels; only wall-clock changes.  `false`
    /// restores the process-wide default choice.
    pub fn with_reference_kernel(self, on: bool) -> Self {
        self.with_kernel(if on { MatKernel::Reference } else { default_kernel() })
    }

    /// Pin the fp32 matmul kernel explicitly (A/B benchmarking; outputs
    /// are bit-identical across all variants, DESIGN.md §12.2).
    pub fn with_kernel(mut self, kernel: MatKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Toggle the persistent multipath scratch (on by default).  Off
    /// reproduces the old allocate-per-iteration behaviour — outputs are
    /// bit-identical either way (test-enforced); only allocation traffic
    /// changes.
    pub fn with_persistent_scratch(mut self, on: bool) -> Self {
        self.persistent_scratch = on;
        self
    }

    /// Set the entropy-gap branch threshold for `Algo::Tree` drafting
    /// (default 0.0 = always share coincident draws, or the
    /// `SPECD_TREE_THRESHOLD` env override; `f64::INFINITY` = never
    /// share, the exact multipath layout twin).  Any value yields the
    /// same emitted bits — the threshold only trades drafted-token work
    /// against tree width (DESIGN.md §13.3, test-enforced).
    pub fn with_branch_threshold(mut self, threshold: f64) -> Self {
        assert!(threshold >= 0.0, "branch threshold must be >= 0");
        self.branch_threshold = threshold;
        self
    }

    /// Current entropy-gap branch threshold.
    pub fn branch_threshold(&self) -> f64 {
        self.branch_threshold
    }

    /// Pin the physical KV layout explicitly (A/B benchmarking and the
    /// bit-identity tests; decode streams are bitwise identical either
    /// way, DESIGN.md §16).  Overrides the `SPECD_KV_LAYOUT` env choice.
    /// Must be called before any KV cache is allocated — already-paged
    /// caches keep their layout.
    pub fn with_kv_layout(mut self, layout: KvLayout) -> Self {
        self.kv_layout = layout;
        self.info.paged_kv = layout == KvLayout::Paged;
        self
    }

    /// Physical layout of the KV caches this backend allocates.
    pub fn kv_layout(&self) -> KvLayout {
        self.kv_layout
    }

    /// The page arena of `name` (created on first use).  Every paged
    /// cache of a model shares one arena — aliasing across caches is only
    /// sound within a single allocator.
    fn arena_for(&self, name: &str, dims: &ModelDims) -> Arc<PageArena> {
        let mut arenas = self.arenas.lock().unwrap();
        arenas
            .entry(name.to_string())
            .or_insert_with(|| {
                Arc::new(PageArena::new(
                    dims.n_layers,
                    dims.n_heads * dims.head_dim(),
                    DEFAULT_PAGE_POSITIONS,
                ))
            })
            .clone()
    }

    /// Allocate a zeroed `(rows,)`-row KV cache of ring length `max_len`
    /// for `name` in the backend's configured layout.
    fn alloc_kv(&self, name: &str, dims: &ModelDims, rows: usize, max_len: usize) -> NativeKv {
        match self.kv_layout {
            KvLayout::Contig => NativeKv::zeros(dims, rows, max_len),
            KvLayout::Paged => {
                NativeKv::paged(dims, rows, max_len, &self.arena_for(name, dims))
            }
        }
    }

    /// `(live, free)` page counts of `model`'s arena (`None` under the
    /// contiguous layout, or before the model allocated anything).  The
    /// refcount-leak tests pin `live` back to baseline after rows are
    /// released.
    pub fn kv_arena_stats(&self, model: &str) -> Option<(usize, usize)> {
        let arenas = self.arenas.lock().unwrap();
        arenas.get(model).map(|a| (a.live_pages(), a.free_pages()))
    }

    /// Set the draft-model inference precision (fp32, or the int8
    /// quantised-weight path — the default).  Builder form of the knob
    /// [`Backend::prepare`] threads through from the engine config.
    pub fn with_draft_precision(self, p: Precision) -> Self {
        self.set_draft_precision(p);
        self
    }

    /// Current draft-model precision.
    pub fn draft_precision(&self) -> Precision {
        match self.draft_precision.load(Ordering::Relaxed) {
            0 => Precision::Fp32,
            _ => Precision::Int8,
        }
    }

    fn set_draft_precision(&self, p: Precision) {
        self.draft_precision.store(p as u8, Ordering::Relaxed);
    }

    /// The quantised twin a *drafter* forward runs with, or `None` when
    /// the model is the target (never quantised — its distributions
    /// define the output law) or the backend runs fp32 drafts.  Twins are
    /// built once per model and cached (`quant`, keyed by name).
    fn draft_quant(&self, name: &str) -> Option<Arc<QuantModel>> {
        self.quant_for(name, None)
    }

    /// [`NativeBackend::draft_quant`] with an optional per-request
    /// precision override ([`DraftRequest::precision`]): `None` follows
    /// the backend-wide knob, `Some(p)` forces it for this call.  The
    /// target is never quantised regardless.
    fn quant_for(&self, name: &str, precision: Option<Precision>) -> Option<Arc<QuantModel>> {
        let p = precision.unwrap_or_else(|| self.draft_precision());
        if name == "target" || p == Precision::Fp32 {
            return None;
        }
        let model = self.models.get(name)?;
        let mut cache = self.quant.lock().unwrap();
        Some(cache.entry(name.to_string()).or_insert_with(|| Arc::new(model.quantise())).clone())
    }

    /// Configured forward-pass thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The worker pool, spawned on first parallel use.
    fn pool(&self) -> &ThreadPool {
        self.pool.get_or_init(|| ThreadPool::new(self.threads))
    }

    /// The matmul kernel this backend's forwards run with.
    pub fn kernel(&self) -> MatKernel {
        self.kernel
    }

    /// The tile-major packed fp32 twin of `model` when the active kernel
    /// wants one (SIMD only), built once per model and cached (`packed`,
    /// keyed by name — `Backend::prepare` pre-builds the twins so steady
    /// state never packs).
    fn packed_model(&self, name: &str, model: &NativeModel) -> Option<Arc<PackedModel>> {
        if self.kernel != MatKernel::Simd {
            return None;
        }
        let mut cache = self.packed.lock().unwrap();
        Some(
            cache
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(PackedModel::pack(model)))
                .clone(),
        )
    }

    /// Check out a `(rows,)`-row scratch cache of ring length `max_len`
    /// for `model` (persistent pool hit, or a fresh zeroed cache).  Stale
    /// contents are fine: the multipath/tree forwards splice every
    /// attended prefix slot and rewrite every in-flight slot before it is
    /// read (DESIGN.md §10 scratch lifetime), so reuse is bit-identical
    /// to a fresh cache.  The ring length is part of the pool key: flat
    /// multipath checkouts (`max_len == info.max_len`) and tree checkouts
    /// (extended ring, [`NativeBackend::tree_scratch_len`]) never alias
    /// even at equal row counts.
    fn take_scratch(&self, model: &NativeModel, name: &str, rows: usize, max_len: usize) -> NativeKv {
        if self.persistent_scratch {
            let mut cache = self.scratch.lock().unwrap();
            if let Some(kv) =
                cache.get_mut(&(name.to_string(), rows, max_len)).and_then(Vec::pop)
            {
                return kv;
            }
        }
        self.alloc_kv(name, &model.dims, rows, max_len)
    }

    /// Return a scratch cache to the persistent pool (dropped when the
    /// backend runs with `persistent_scratch` off).
    fn put_scratch(&self, name: &str, kv: NativeKv) {
        if self.persistent_scratch {
            let mut cache = self.scratch.lock().unwrap();
            cache.entry((name.to_string(), kv.batch, kv.max_len)).or_default().push(kv);
        }
    }

    /// Ring length of a `k`-leaf tree scratch row: the serving ring plus
    /// `k` per-leaf extension slots per supported draft depth
    /// (`gamma <= max_len / 4`, [`BackendInfo::supports_gamma`]), so the
    /// slot of node `i` — `len + i` with `len <= max_len` and
    /// `i < k * gamma` — always fits, for any admissible `len`/`gamma`.
    /// Gamma-independent on purpose: [`Backend::prepare`] pre-sizes the
    /// pool without knowing the engine's gamma.  Slots past the model
    /// ring are pure KV storage (position embeddings clamp, exactly like
    /// the flat forward's ring-end clamp).
    fn tree_scratch_len(&self, k: usize) -> usize {
        self.info.max_len + k * (self.info.max_len / 4).max(1)
    }

    /// Artifact bundle when present, hermetic seeded weights otherwise —
    /// the launcher/examples default.
    pub fn from_artifacts_or_seeded(dir: &Path, seed: u64) -> anyhow::Result<Self> {
        if dir.join("manifest.json").exists() {
            Self::from_artifacts(dir)
        } else {
            Ok(Self::seeded(seed))
        }
    }

    fn model(&self, name: &str) -> anyhow::Result<&NativeModel> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not served by the native backend"))
    }

    fn check_shapes(&self, tokens: &[i32], length: &[i32]) -> anyhow::Result<()> {
        let (b, l) = (self.info.batch, self.info.max_len);
        if tokens.len() != b * l || length.len() != b {
            return Err(anyhow!(
                "state shape mismatch: tokens {} (want {}), length {} (want {b})",
                tokens.len(),
                b * l,
                length.len()
            ));
        }
        Ok(())
    }

    /// Defensive gamma validation for direct backend calls (engines check
    /// via [`BackendInfo::supports_gamma`] at construction; a block that
    /// does not fit the ring would otherwise corrupt or overrun the KV
    /// cache).
    fn check_gamma(&self, gamma: usize) -> anyhow::Result<()> {
        if !self.info.supports_gamma(gamma) {
            return Err(anyhow!(
                "gamma {gamma} outside the supported range 1..={} for ring length {}",
                self.info.max_len / 4,
                self.info.max_len
            ));
        }
        Ok(())
    }

    /// Forward `t` tokens per row starting at per-row cache positions
    /// `start_pos`, mirroring `model.py::forward_block`: returns probs
    /// row-major `(B, t, V)` and rewrites cache rows
    /// `start..start+t` (start clamped into the ring like
    /// `dynamic_update_slice`).  With `want_probs == false` the tied-head
    /// unembedding is skipped and the returned vector is empty — prefill
    /// only needs the KV rows (XLA dead-code-eliminates the same work on
    /// the PJRT path).
    ///
    /// Rows come from the cache, not the serving batch: the multipath
    /// scratch caches run this very forward over `B * K` flattened path
    /// rows (DESIGN.md §9), everything else over the `B` serving rows.
    /// Rows are independent, so they are split into contiguous chunks
    /// across the backend's thread pool ([`NativeBackend::with_threads`])
    /// — bit-identical to the sequential order for any thread count.
    #[allow(clippy::too_many_arguments)]
    fn forward_block(
        &self,
        model: &NativeModel,
        name: &str,
        quant: Option<&QuantModel>,
        kv: &mut NativeKv,
        tokens_t: &[i32],
        t: usize,
        start_pos: &[i32],
        want_probs: bool,
    ) -> Vec<f32> {
        self.forward_block_masked(model, name, quant, kv, tokens_t, t, start_pos, want_probs, None)
    }

    /// Masked variant of [`NativeBackend::forward_block`]: rows with
    /// `active[bi] == false` are skipped outright — no model evaluation,
    /// no KV write, their `probs` slice stays zero.  Because every row
    /// is processed independently (`forward_row` is a pure function of
    /// one row's slot), masking neighbours cannot change an active
    /// row's bits, which is what lets the ragged variable-gamma paths
    /// (DESIGN.md §15) advance only the rows whose draft length reaches
    /// the current level while staying bit-identical per row to a
    /// uniform run.  `active == None` runs every row (the plain
    /// [`NativeBackend::forward_block`]).
    #[allow(clippy::too_many_arguments)]
    fn forward_block_masked(
        &self,
        model: &NativeModel,
        name: &str,
        quant: Option<&QuantModel>,
        kv: &mut NativeKv,
        tokens_t: &[i32],
        t: usize,
        start_pos: &[i32],
        want_probs: bool,
        active: Option<&[bool]>,
    ) -> Vec<f32> {
        let dims = &model.dims;
        let (rows, l) = (kv.batch, kv.max_len);
        let vcb = dims.vocab_size;
        debug_assert_eq!(tokens_t.len(), rows * t);
        debug_assert_eq!(start_pos.len(), rows);
        debug_assert_eq!(l, self.info.max_len);
        debug_assert_eq!(
            (kv.n_layers, kv.n_heads, kv.head_dim),
            (dims.n_layers, dims.n_heads, dims.head_dim()),
            "KV cache belongs to a different model"
        );

        let mut probs = if want_probs { vec![0.0f32; rows * t * vcb] } else { Vec::new() };
        let kernel = self.kernel();
        let packed_arc = self.packed_model(name, model);
        let packed = packed_arc.as_deref();
        // CoW pre-pass: materialise every page an active row will write
        // this call (shared pages cloned, holes allocated) *before* the
        // per-row views are captured — CoW replaces slab addresses, so it
        // must never run inside the parallel scope.
        for bi in 0..rows {
            if active.is_some_and(|a| !a[bi]) {
                continue;
            }
            let start = start_pos[bi].max(0) as usize;
            let ws = start.min(l.saturating_sub(t));
            kv.ensure_writable_span(bi, ws, ws + t);
        }
        // Disjoint per-row views: each slot resolves its own row's pages
        // (or contiguous chunk), and probs splits row-major the same way.
        let mut pit = probs.chunks_mut(t * vcb);
        let mut slots = Vec::with_capacity(rows);
        for bi in 0..rows {
            // Advance the probs iterator in lockstep so row `bi` always
            // maps to chunk `bi`, then drop the slot for masked-out rows.
            let p = if want_probs { Some(pit.next().expect("probs row chunk")) } else { None };
            if active.is_some_and(|a| !a[bi]) {
                continue;
            }
            slots.push(RowSlot {
                kv: kv.row_view(bi),
                probs: p,
                toks: &tokens_t[bi * t..(bi + 1) * t],
                start: start_pos[bi],
            });
        }

        let n_threads = self.threads.min(slots.len()).max(1);
        if n_threads == 1 {
            let mut scratch = RowScratch::new(dims, t, l);
            for slot in slots {
                forward_row(model, quant, packed, kernel, slot, t, l, &mut scratch);
            }
        } else {
            let chunk = slots.len().div_ceil(n_threads);
            let mut it = slots.into_iter();
            let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(n_threads);
            loop {
                let group: Vec<RowSlot<'_>> = it.by_ref().take(chunk).collect();
                if group.is_empty() {
                    break;
                }
                jobs.push(Box::new(move || {
                    let mut scratch = RowScratch::new(dims, t, l);
                    for slot in group {
                        forward_row(model, quant, packed, kernel, slot, t, l, &mut scratch);
                    }
                }));
            }
            self.pool().scope(jobs);
        }
        probs
    }

    /// Shared prefill forward: ingest a padded `(B, L)` prompt batch into
    /// `kv` (a fresh cache for [`Backend::prefill`], a pooled scratch for
    /// [`Backend::prefill_rows`]), at the drafter's configured precision
    /// when `name` is a drafter.  Only positions `0..len-2` of a row are
    /// ever attended before the decode loop rewrites the rest, so
    /// forwarding the longest prompt is enough (the PJRT programs forward
    /// the whole fixed-shape ring; here we can spare the quadratic
    /// attention over PAD).
    fn prefill_into(
        &self,
        m: &NativeModel,
        name: &str,
        kv: &mut NativeKv,
        tokens: &[i32],
        length: &[i32],
    ) {
        let (b, l) = (self.info.batch, self.info.max_len);
        let t = length
            .iter()
            .map(|&x| x.max(1) as usize)
            .max()
            .unwrap_or(1)
            .min(l);
        let mut tok_t = vec![vocab::PAD as i32; b * t];
        for bi in 0..b {
            tok_t[bi * t..(bi + 1) * t].copy_from_slice(&tokens[bi * l..bi * l + t]);
        }
        let start = vec![0i32; b];
        let quant = self.draft_quant(name);
        let _ = self.forward_block(m, name, quant.as_deref(), kv, &tok_t, t, &start, false);
    }

    /// Suffix-only prefill forward (DESIGN.md §14.3): like
    /// [`NativeBackend::prefill_into`], but row `bi` starts at cache
    /// position `start[bi]` — its positions `0..start[bi]` must already
    /// hold that row's prefix KV (spliced from the prefix cache).
    /// Because cache row `i` depends only on tokens `0..=i` (per-row
    /// causal attention, positions processed against the same cache
    /// contents a cold prefill would hold), the suffix rows come out
    /// bit-identical to a cold full-prompt prefill — the warm-admission
    /// losslessness argument, test-enforced in `tests/serve_tier.rs`.
    fn prefill_suffix_into(
        &self,
        m: &NativeModel,
        name: &str,
        kv: &mut NativeKv,
        tokens: &[i32],
        length: &[i32],
        start: &[i32],
    ) {
        let (b, l) = (self.info.batch, self.info.max_len);
        let t = length
            .iter()
            .zip(start.iter())
            .map(|(&len, &s)| (len.max(1) - s.max(0)).max(1) as usize)
            .max()
            .unwrap_or(1)
            .min(l);
        let mut tok_t = vec![vocab::PAD as i32; b * t];
        for bi in 0..b {
            let s = (start[bi].max(0) as usize).min(l);
            // Prompts are < L/2 (admission guard) and starts are below a
            // prompt length, so the window never clips against the ring
            // and the write origin is never clamp-shifted.
            debug_assert!(s + t <= l, "suffix window {s}+{t} overruns ring {l}");
            let hi = (s + t).min(l);
            tok_t[bi * t..bi * t + (hi - s)].copy_from_slice(&tokens[bi * l + s..bi * l + hi]);
        }
        let quant = self.draft_quant(name);
        let _ = self.forward_block(m, name, quant.as_deref(), kv, &tok_t, t, start, false);
    }

    /// Pending token per row: `tokens[b][length[b] - 1]` (clamped).
    fn gather_pending(&self, tokens: &[i32], length: &[i32]) -> Vec<i32> {
        let l = self.info.max_len;
        length
            .iter()
            .enumerate()
            .map(|(b, &len)| tokens[b * l + ((len - 1).max(0) as usize).min(l - 1)])
            .collect()
    }

    /// Allocation core of the draft scan, over however many rows `kv`
    /// carries (`B` serving rows, or `B * K` flattened path rows on the
    /// multipath scratch): `gamma` autoregressive steps from the per-row
    /// pending token `cur`, each row sampling from its own `rngs` stream.
    #[allow(clippy::too_many_arguments)]
    fn draft_scan_flat(
        &self,
        model: &NativeModel,
        name: &str,
        quant: Option<&QuantModel>,
        kv: &mut NativeKv,
        mut cur: Vec<i32>,
        start0: &[i32],
        gamma: usize,
        rngs: &mut [Rng],
    ) -> (Vec<i32>, Vec<f32>) {
        let (rows, vcb) = (kv.batch, self.info.vocab_size);
        debug_assert_eq!(cur.len(), rows);
        debug_assert_eq!(start0.len(), rows);
        debug_assert_eq!(rngs.len(), rows);
        let mut drafts = vec![0i32; rows * gamma];
        let mut qs = vec![0.0f32; rows * gamma * vcb];
        for j in 0..gamma {
            let start: Vec<i32> = start0.iter().map(|&s| s + j as i32).collect();
            let probs = self.forward_block(model, name, quant, kv, &cur, 1, &start, true);
            for r in 0..rows {
                let prow = &probs[r * vcb..(r + 1) * vcb];
                qs[(r * gamma + j) * vcb..(r * gamma + j + 1) * vcb].copy_from_slice(prow);
                let u = rngs[r].uniform();
                let next = sample_row(prow, u) as i32;
                drafts[r * gamma + j] = next;
                cur[r] = next;
            }
        }
        (drafts, qs)
    }

    /// `gamma` autoregressive draft steps (`model.py::draft_scan`).  Row
    /// `b` samples from its own stream keyed on `seeds[b]` alone, so a
    /// row's draft trajectory is independent of its slot and neighbours.
    /// Runs the drafter at the backend's configured draft precision.
    #[allow(clippy::too_many_arguments)]
    fn draft_scan(
        &self,
        model: &NativeModel,
        name: &str,
        quant: Option<&QuantModel>,
        kv: &mut NativeKv,
        tokens: &[i32],
        length: &[i32],
        gamma: usize,
        seeds: &[i32],
    ) -> (Vec<i32>, Vec<f32>) {
        let mut rngs: Vec<Rng> =
            seeds.iter().map(|&s| Rng::new(seed64(s) ^ DOM_DRAFT)).collect();
        let cur = self.gather_pending(tokens, length);
        let start0: Vec<i32> = length.iter().map(|&len| len - 1).collect();
        self.draft_scan_flat(model, name, quant, kv, cur, &start0, gamma, &mut rngs)
    }

    /// Per-row seed count must match the serving batch.
    fn check_seeds(&self, seeds: &[i32]) -> anyhow::Result<()> {
        if seeds.len() != self.info.batch {
            return Err(anyhow!(
                "seeds shape {} != batch {}",
                seeds.len(),
                self.info.batch
            ));
        }
        Ok(())
    }

    /// Parallel scoring of the `gamma + 1` prefixes
    /// (`model.py::target_score`).
    fn score(
        &self,
        model: &NativeModel,
        kv: &mut NativeKv,
        tokens: &[i32],
        length: &[i32],
        drafts: &[i32],
        gamma: usize,
    ) -> Vec<f32> {
        let b = self.info.batch;
        let pending = self.gather_pending(tokens, length);
        let mut inp = vec![0i32; b * (gamma + 1)];
        for bi in 0..b {
            inp[bi * (gamma + 1)] = pending[bi];
            inp[bi * (gamma + 1) + 1..(bi + 1) * (gamma + 1)]
                .copy_from_slice(&drafts[bi * gamma..(bi + 1) * gamma]);
        }
        let start: Vec<i32> = length.iter().map(|&len| len - 1).collect();
        self.forward_block(model, "target", None, kv, &inp, gamma + 1, &start, true)
    }

    // ------------------------------------------------------------------
    // Ragged (variable-gamma) speculation (DESIGN.md §15)
    // ------------------------------------------------------------------

    /// Ragged counterpart of [`NativeBackend::draft_scan_flat`]: row `r`
    /// takes `gammas[r]` autoregressive steps; levels past a row's own
    /// gamma mask that row out of the forward and consume nothing from
    /// its RNG stream.  Drafts and per-step distributions are laid out at
    /// the uniform `gmax = max(gammas)` stride with zero padding, so
    /// downstream slicing matches the uniform path.  Each surviving level
    /// is bit-identical to the same level of a uniform `gammas[r]` run —
    /// the per-row losslessness invariant the adaptive controller relies
    /// on (test: `ragged_rows_match_uniform_runs`).
    #[allow(clippy::too_many_arguments)]
    fn draft_scan_ragged(
        &self,
        model: &NativeModel,
        name: &str,
        quant: Option<&QuantModel>,
        kv: &mut NativeKv,
        mut cur: Vec<i32>,
        start0: &[i32],
        gammas: &[usize],
        rngs: &mut [Rng],
    ) -> (Vec<i32>, Vec<f32>) {
        let (rows, vcb) = (kv.batch, self.info.vocab_size);
        debug_assert_eq!(cur.len(), rows);
        debug_assert_eq!(start0.len(), rows);
        debug_assert_eq!(rngs.len(), rows);
        debug_assert_eq!(gammas.len(), rows);
        let gmax = gammas.iter().copied().max().unwrap_or(0);
        let mut drafts = vec![0i32; rows * gmax];
        let mut qs = vec![0.0f32; rows * gmax * vcb];
        let mut active = vec![true; rows];
        for j in 0..gmax {
            for r in 0..rows {
                active[r] = gammas[r] > j;
            }
            let start: Vec<i32> = start0.iter().map(|&s| s + j as i32).collect();
            let probs = self
                .forward_block_masked(model, name, quant, kv, &cur, 1, &start, true, Some(&active));
            for r in 0..rows {
                if !active[r] {
                    continue;
                }
                let prow = &probs[r * vcb..(r + 1) * vcb];
                qs[(r * gmax + j) * vcb..(r * gmax + j + 1) * vcb].copy_from_slice(prow);
                let u = rngs[r].uniform();
                let next = sample_row(prow, u) as i32;
                drafts[r * gmax + j] = next;
                cur[r] = next;
            }
        }
        (drafts, qs)
    }

    /// Ragged counterpart of [`NativeBackend::score`] over an
    /// already-flattened row set: row `r` scores its `gammas[r] + 1`
    /// prefixes in one forward.  Rows are grouped by their gamma so each
    /// forward keeps the uniform `(rows, g + 1)` block shape the kernels
    /// want, masking out the other groups; distinct gammas in flight are
    /// bounded by the controller's [gamma_min, gamma_max] band, so the
    /// group count stays small.  Output keeps the uniform
    /// `(gmax + 1) * vocab` row stride with zero padding past a row's own
    /// `gammas[r] + 1` distributions.
    #[allow(clippy::too_many_arguments)]
    fn score_ragged_flat(
        &self,
        model: &NativeModel,
        kv: &mut NativeKv,
        pending: &[i32],
        start0: &[i32],
        drafts: &[i32],
        gammas: &[usize],
        gmax: usize,
    ) -> Vec<f32> {
        let (rows, vcb) = (kv.batch, self.info.vocab_size);
        debug_assert_eq!(pending.len(), rows);
        debug_assert_eq!(start0.len(), rows);
        debug_assert_eq!(gammas.len(), rows);
        debug_assert_eq!(drafts.len(), rows * gmax);
        let mut ps = vec![0.0f32; rows * (gmax + 1) * vcb];
        let mut distinct: Vec<usize> = gammas.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        let mut active = vec![false; rows];
        for &g in &distinct {
            for r in 0..rows {
                active[r] = gammas[r] == g;
            }
            let mut inp = vec![0i32; rows * (g + 1)];
            for r in 0..rows {
                if !active[r] {
                    continue;
                }
                inp[r * (g + 1)] = pending[r];
                inp[r * (g + 1) + 1..(r + 1) * (g + 1)]
                    .copy_from_slice(&drafts[r * gmax..r * gmax + g]);
            }
            let probs = self.forward_block_masked(
                model,
                "target",
                None,
                kv,
                &inp,
                g + 1,
                start0,
                true,
                Some(&active),
            );
            for r in 0..rows {
                if !active[r] {
                    continue;
                }
                ps[r * (gmax + 1) * vcb..(r * (gmax + 1) + g + 1) * vcb]
                    .copy_from_slice(&probs[r * (g + 1) * vcb..(r + 1) * (g + 1) * vcb]);
            }
        }
        ps
    }

    // ------------------------------------------------------------------
    // Multi-draft speculation (DESIGN.md §9)
    // ------------------------------------------------------------------

    /// Build the flattened `(B·K)`-row scratch cache for one model,
    /// splicing each serving row's shared prefix (its `length - 1` valid
    /// cache rows) into all `k` of that row's path rows.  The cache is
    /// checked out of the persistent scratch pool
    /// ([`NativeBackend::take_scratch`]); callers return it via
    /// [`NativeBackend::put_scratch`] when the iteration is done.
    fn multi_prefix_scratch(
        &self,
        model: &NativeModel,
        name: &str,
        k: usize,
        length: &[i32],
        kv: &NativeKv,
    ) -> NativeKv {
        let (b, l) = (self.info.batch, self.info.max_len);
        let mut scratch = self.take_scratch(model, name, b * k, l);
        for bi in 0..b {
            let prefix = (length[bi].max(1) as usize - 1).min(l);
            for path in 0..k {
                copy_kv_rows(&mut scratch, bi * k + path, kv, bi, prefix);
            }
        }
        scratch
    }

    /// [`Backend::draft_multi`] plus the drafter scratch cache, which the
    /// fused multipath iteration keeps so it can commit the winning
    /// path's rows after verification.
    #[allow(clippy::too_many_arguments)]
    fn draft_multi_scratch(
        &self,
        drafter: &str,
        k: usize,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<(DraftSet, NativeKv)> {
        self.check_shapes(tokens, length)?;
        self.check_gamma(gamma)?;
        self.check_seeds(seeds)?;
        if k == 0 {
            return Err(anyhow!("multipath draft set needs k >= 1"));
        }
        let m = self.model(drafter)?;
        let b = self.info.batch;
        let mut scratch = self.multi_prefix_scratch(m, drafter, k, length, kv);
        let pending = self.gather_pending(tokens, length);
        // Flat layout: path rows of serving row `bi` are `bi*k..bi*k+k`
        // (the DraftSet::flat_row contract); every path starts from the
        // row's pending token, with its own draft stream.
        let mut cur = Vec::with_capacity(b * k);
        let mut start0 = Vec::with_capacity(b * k);
        let mut rngs = Vec::with_capacity(b * k);
        for bi in 0..b {
            for path in 0..k {
                cur.push(pending[bi]);
                start0.push(length[bi] - 1);
                rngs.push(path_rng(seeds[bi], DOM_DRAFT, path));
            }
        }
        let quant = self.draft_quant(drafter);
        let (drafts, qs) = self.draft_scan_flat(
            m,
            drafter,
            quant.as_deref(),
            &mut scratch,
            cur,
            &start0,
            gamma,
            &mut rngs,
        );
        let set = DraftSet::new(b, k, gamma, self.info.vocab_size, drafts, qs)?;
        Ok((set, scratch))
    }

    /// [`Backend::target_score_multi`] plus the target scratch cache (the
    /// winner-commit twin of [`NativeBackend::draft_multi_scratch`]).
    fn target_score_multi_scratch(
        &self,
        set: &mut DraftSet,
        tokens: &[i32],
        length: &[i32],
        kv: &NativeKv,
    ) -> anyhow::Result<NativeKv> {
        self.check_shapes(tokens, length)?;
        let (b, gamma) = (self.info.batch, set.gamma);
        if set.batch != b || set.vocab != self.info.vocab_size {
            return Err(anyhow!(
                "draft set shape mismatch: batch {} (want {b}), vocab {} (want {})",
                set.batch,
                set.vocab,
                self.info.vocab_size
            ));
        }
        self.check_gamma(gamma)?;
        let m = self.model("target")?;
        let mut scratch = self.multi_prefix_scratch(m, "target", set.k, length, kv);
        let pending = self.gather_pending(tokens, length);
        let rows = set.flat_rows();
        let mut inp = vec![0i32; rows * (gamma + 1)];
        let mut start = Vec::with_capacity(rows);
        for bi in 0..b {
            for path in 0..set.k {
                let r = set.flat_row(bi, path);
                inp[r * (gamma + 1)] = pending[bi];
                inp[r * (gamma + 1) + 1..(r + 1) * (gamma + 1)]
                    .copy_from_slice(set.path_drafts(bi, path));
                start.push(length[bi] - 1);
            }
        }
        let ps = self.forward_block(m, "target", None, &mut scratch, &inp, gamma + 1, &start, true);
        set.set_ps(ps)?;
        Ok(scratch)
    }

    /// One fused multipath iteration: draft `k` paths per row against
    /// scratch prefix copies, score them all in one batched target pass,
    /// verify jointly ([`verify::multipath_verify`]) and commit only the
    /// winning path's cache rows back into the live caches.
    #[allow(clippy::too_many_arguments)]
    fn spec_iter_multipath(
        &self,
        k: usize,
        drafter: &str,
        gamma: usize,
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut NativeKv,
        kv_drafter: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        let (b, l) = (self.info.batch, self.info.max_len);
        let t_draft = Instant::now();
        let (mut set, d_scratch) =
            self.draft_multi_scratch(drafter, k, gamma, tokens, length, kv_drafter, seeds)?;
        let draft_us = t_draft.elapsed().as_micros() as u64;
        let t_target = Instant::now();
        let t_scratch = self.target_score_multi_scratch(&mut set, tokens, length, kv_target)?;
        let target_us = t_target.elapsed().as_micros() as u64;

        let mut tau = vec![0i32; b];
        let mut emitted = vec![vocab::PAD as i32; b * (gamma + 1)];
        let mut done = vec![0i32; b];
        // One reusable verify-view scratch serves every row (the per-row
        // `(K, gamma + 1, V)` f64 conversions dominate verify-side
        // allocation otherwise).
        let mut views = RowViews::default();
        for bi in 0..b {
            let (etas, u_res) = multipath_uniforms(seeds[bi], gamma, k);
            set.row_views_into(bi, &mut views)?;
            let outcome =
                verify::multipath_verify(&views.ps, &views.qs, &views.drafts, &etas, u_res);
            // Commit the winner: during this iteration the drafter wrote
            // scratch rows `len-1 .. len+gamma-2` and the target rows
            // `len-1 .. len+gamma-1`; copying from position 0 also
            // rewrites the shared prefix with identical values, so the
            // live caches end up exactly as a single-path iteration of
            // the winning path would have left them.
            let len = length[bi].max(0) as usize;
            let w = set.flat_row(bi, outcome.path);
            copy_kv_rows(kv_drafter, bi, &d_scratch, w, (len + gamma).saturating_sub(1).min(l));
            copy_kv_rows(kv_target, bi, &t_scratch, w, (len + gamma).min(l));
            for (j, &t) in outcome.emitted.iter().enumerate() {
                if len + j < l {
                    tokens[bi * l + len + j] = t as i32;
                }
                emitted[bi * (gamma + 1) + j] = t as i32;
            }
            let eos_hit = outcome.emitted.iter().any(|&t| t == vocab::EOS);
            let new_len = length[bi] + outcome.tau as i32 + 1;
            let out_of_room = new_len > (l as i32) - (gamma as i32 + 2);
            tau[bi] = outcome.tau as i32;
            done[bi] = (eos_hit || out_of_room) as i32;
            length[bi] = new_len.min(l as i32 - 1);
        }
        self.put_scratch(drafter, d_scratch);
        self.put_scratch("target", t_scratch);
        Ok(SpecIterOut {
            tau,
            emitted,
            done,
            stride: gamma + 1,
            draft_us,
            target_us,
            drafted: b * k * gamma,
        })
    }

    /// Ragged multi-draft iteration (DESIGN.md §15): like
    /// [`NativeBackend::spec_iter_multipath`], but serving row `bi` drafts
    /// and verifies `gammas[bi]` tokens on each of its `k` paths.  Tree
    /// iterations also land here when rows disagree on gamma — the flat
    /// multipath path commits the same bits (the tree layout is a pure
    /// FLOP optimisation, test-enforced equal to multipath), it only
    /// forgoes prefix-sharing on the transient ragged iterations.
    #[allow(clippy::too_many_arguments)]
    fn spec_iter_rows_multi(
        &self,
        k: usize,
        drafter: &str,
        gammas: &[usize],
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut NativeKv,
        kv_drafter: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        if k == 0 {
            return Err(anyhow!("multipath draft set needs k >= 1"));
        }
        let (b, l, vcb) = (self.info.batch, self.info.max_len, self.info.vocab_size);
        let gmax = gammas.iter().copied().max().unwrap_or(1);
        let m_d = self.model(drafter)?;
        let m_t = self.model("target")?;

        // Draft: K path rows per serving row against prefix-spliced
        // scratch, every path row running its serving row's own gamma.
        let t_draft = Instant::now();
        let mut d_scratch = self.multi_prefix_scratch(m_d, drafter, k, length, kv_drafter);
        let pending = self.gather_pending(tokens, length);
        let mut cur = Vec::with_capacity(b * k);
        let mut pend_flat = Vec::with_capacity(b * k);
        let mut start0 = Vec::with_capacity(b * k);
        let mut rngs = Vec::with_capacity(b * k);
        let mut flat_gammas = Vec::with_capacity(b * k);
        for bi in 0..b {
            for path in 0..k {
                cur.push(pending[bi]);
                pend_flat.push(pending[bi]);
                start0.push(length[bi] - 1);
                rngs.push(path_rng(seeds[bi], DOM_DRAFT, path));
                flat_gammas.push(gammas[bi]);
            }
        }
        let quant = self.draft_quant(drafter);
        let (drafts, qs) = self.draft_scan_ragged(
            m_d,
            drafter,
            quant.as_deref(),
            &mut d_scratch,
            cur,
            &start0,
            &flat_gammas,
            &mut rngs,
        );
        let draft_us = t_draft.elapsed().as_micros() as u64;

        // Score each path row's own gamma + 1 prefixes in grouped
        // forwards, then hand the gmax-stride buffers to the draft set.
        let t_target = Instant::now();
        let mut t_scratch = self.multi_prefix_scratch(m_t, "target", k, length, kv_target);
        let ps = self.score_ragged_flat(
            m_t,
            &mut t_scratch,
            &pend_flat,
            &start0,
            &drafts,
            &flat_gammas,
            gmax,
        );
        let target_us = t_target.elapsed().as_micros() as u64;
        let mut set = DraftSet::new(b, k, gmax, vcb, drafts, qs)?;
        set.set_row_gammas(gammas.to_vec())?;
        set.set_ps(ps)?;

        let mut tau = vec![0i32; b];
        let mut emitted = vec![vocab::PAD as i32; b * (gmax + 1)];
        let mut done = vec![0i32; b];
        let mut views = RowViews::default();
        for bi in 0..b {
            let g = gammas[bi];
            let (etas, u_res) = multipath_uniforms(seeds[bi], g, k);
            set.row_views_into(bi, &mut views)?;
            let outcome =
                verify::multipath_verify(&views.ps, &views.qs, &views.drafts, &etas, u_res);
            let len = length[bi].max(0) as usize;
            let w = set.flat_row(bi, outcome.path);
            copy_kv_rows(kv_drafter, bi, &d_scratch, w, (len + g).saturating_sub(1).min(l));
            copy_kv_rows(kv_target, bi, &t_scratch, w, (len + g).min(l));
            for (j, &t) in outcome.emitted.iter().enumerate() {
                if len + j < l {
                    tokens[bi * l + len + j] = t as i32;
                }
                emitted[bi * (gmax + 1) + j] = t as i32;
            }
            let eos_hit = outcome.emitted.iter().any(|&t| t == vocab::EOS);
            let new_len = length[bi] + outcome.tau as i32 + 1;
            let out_of_room = new_len > (l as i32) - (g as i32 + 2);
            tau[bi] = outcome.tau as i32;
            done[bi] = (eos_hit || out_of_room) as i32;
            length[bi] = new_len.min(l as i32 - 1);
        }
        self.put_scratch(drafter, d_scratch);
        self.put_scratch("target", t_scratch);
        Ok(SpecIterOut {
            tau,
            emitted,
            done,
            stride: gmax + 1,
            draft_us,
            target_us,
            drafted: k * gammas.iter().sum::<usize>(),
        })
    }

    /// Ragged single-draft iteration (Token/Block/Greedy): row `bi`
    /// drafts, scores and verifies `gammas[bi]` tokens.  Per-row bits
    /// match a uniform iteration at that row's gamma exactly — drafting
    /// consumes `gammas[bi]` RNG draws, verification reseeds per row from
    /// `seeds[bi]` alone, and the forward masking never touches a
    /// neighbour's rows (test: `ragged_rows_match_uniform_runs`).
    #[allow(clippy::too_many_arguments)]
    fn spec_iter_rows_block(
        &self,
        algo: Algo,
        drafter: &str,
        gammas: &[usize],
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut NativeKv,
        kv_drafter: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        let (b, l, vcb) = (self.info.batch, self.info.max_len, self.info.vocab_size);
        let gmax = gammas.iter().copied().max().unwrap_or(1);
        let m_d = self.model(drafter)?;
        let m_t = self.model("target")?;
        let quant = self.draft_quant(drafter);

        let t_draft = Instant::now();
        let mut rngs: Vec<Rng> =
            seeds.iter().map(|&s| Rng::new(seed64(s) ^ DOM_DRAFT)).collect();
        let pending = self.gather_pending(tokens, length);
        let start0: Vec<i32> = length.iter().map(|&len| len - 1).collect();
        let (drafts, qs) = self.draft_scan_ragged(
            m_d,
            drafter,
            quant.as_deref(),
            kv_drafter,
            pending.clone(),
            &start0,
            gammas,
            &mut rngs,
        );
        let draft_us = t_draft.elapsed().as_micros() as u64;

        let t_target = Instant::now();
        let ps =
            self.score_ragged_flat(m_t, kv_target, &pending, &start0, &drafts, gammas, gmax);
        let target_us = t_target.elapsed().as_micros() as u64;

        let mut tau = vec![0i32; b];
        let mut emitted = vec![vocab::PAD as i32; b * (gmax + 1)];
        let mut done = vec![0i32; b];
        for bi in 0..b {
            let g = gammas[bi];
            let (etas, u_res) = verify_uniforms(seeds[bi], g);
            let ps_m = ProbMatrix::from_f32(
                g + 1,
                vcb,
                &ps[bi * (gmax + 1) * vcb..(bi * (gmax + 1) + g + 1) * vcb],
            );
            let qs_m =
                ProbMatrix::from_f32(g, vcb, &qs[bi * gmax * vcb..(bi * gmax + g) * vcb]);
            let row_drafts: Vec<u32> =
                drafts[bi * gmax..bi * gmax + g].iter().map(|&x| x as u32).collect();
            let outcome = verify::verify(algo, &ps_m, &qs_m, &row_drafts, &etas, u_res);
            let len = length[bi].max(0) as usize;
            for (j, &t) in outcome.emitted.iter().enumerate() {
                if len + j < l {
                    tokens[bi * l + len + j] = t as i32;
                }
                emitted[bi * (gmax + 1) + j] = t as i32;
            }
            let eos_hit = outcome.emitted.iter().any(|&t| t == vocab::EOS);
            let new_len = length[bi] + outcome.tau as i32 + 1;
            let out_of_room = new_len > (l as i32) - (g as i32 + 2);
            tau[bi] = outcome.tau as i32;
            done[bi] = (eos_hit || out_of_room) as i32;
            length[bi] = new_len.min(l as i32 - 1);
        }
        Ok(SpecIterOut {
            tau,
            emitted,
            done,
            stride: gmax + 1,
            draft_us,
            target_us,
            drafted: gammas.iter().sum(),
        })
    }

    // ------------------------------------------------------------------
    // Prefix-sharing token-tree speculation (DESIGN.md §13)
    // ------------------------------------------------------------------

    /// Forward each row's tree-token batch ([`TreeTokens`]) against its
    /// scratch ring in one call — the tree twin of
    /// [`NativeBackend::forward_block`], with explicit per-token
    /// position/slot/visibility instead of the contiguous block layout.
    /// Rows may carry different token counts (sharing collapses levels
    /// unevenly), so probs come back per row.  Rows are independent and
    /// split across the thread pool exactly like the flat forward —
    /// bit-identical for any thread count.
    fn forward_tree(
        &self,
        model: &NativeModel,
        name: &str,
        quant: Option<&QuantModel>,
        kv: &mut NativeKv,
        batch_tokens: &[TreeTokens],
    ) -> Vec<Vec<f32>> {
        let dims = &model.dims;
        let (rows, lt) = (kv.batch, kv.max_len);
        let lm = self.info.max_len;
        let vcb = dims.vocab_size;
        debug_assert_eq!(batch_tokens.len(), rows);
        debug_assert_eq!(
            (kv.n_layers, kv.n_heads, kv.head_dim),
            (dims.n_layers, dims.n_heads, dims.head_dim()),
            "KV cache belongs to a different model"
        );
        let kernel = self.kernel();
        let packed_arc = self.packed_model(name, model);
        let packed = packed_arc.as_deref();
        let mut probs: Vec<Vec<f32>> =
            batch_tokens.iter().map(|tt| vec![0.0f32; tt.toks.len() * vcb]).collect();
        // CoW pre-pass: materialise every scratch slot this call writes
        // (the trees write scattered single slots, not one dense span)
        // before the per-row views are captured — CoW replaces slab
        // addresses, so it must never run inside the parallel scope.
        for (bi, tt) in batch_tokens.iter().enumerate() {
            for &sl in &tt.slot {
                kv.ensure_writable_span(bi, sl, sl + 1);
            }
        }
        let mut slots = Vec::with_capacity(rows);
        for (bi, (tt, prow)) in batch_tokens.iter().zip(probs.iter_mut()).enumerate() {
            slots.push(TreeSlot {
                kv: kv.row_view(bi),
                probs: prow,
                toks: &tt.toks,
                pos: &tt.pos,
                slot: &tt.slot,
                vis: &tt.vis,
            });
        }
        let n_threads = self.threads.min(rows).max(1);
        if n_threads == 1 {
            for slot in slots {
                if slot.toks.is_empty() {
                    continue;
                }
                let mut scratch = RowScratch::new(dims, slot.toks.len(), lt);
                forward_tree_row(model, quant, packed, kernel, slot, lm, &mut scratch);
            }
        } else {
            let chunk = rows.div_ceil(n_threads);
            let mut it = slots.into_iter();
            let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(n_threads);
            loop {
                let group: Vec<TreeSlot<'_>> = it.by_ref().take(chunk).collect();
                if group.is_empty() {
                    break;
                }
                jobs.push(Box::new(move || {
                    for slot in group {
                        if slot.toks.is_empty() {
                            continue;
                        }
                        let mut scratch = RowScratch::new(dims, slot.toks.len(), lt);
                        forward_tree_row(model, quant, packed, kernel, slot, lm, &mut scratch);
                    }
                }));
            }
            self.pool().scope(jobs);
        }
        probs
    }

    /// [`Backend::draft_tree`] plus the drafter's tree scratch cache
    /// (kept by the fused tree iteration for the winner-chain commit).
    ///
    /// Every leaf runs the *same* independent draft stream as a flat
    /// multipath path (`path_rng(seed, DOM_DRAFT, p)`, one uniform per
    /// depth); leaves whose freshly drawn tokens coincide at the same
    /// node share one child — drafted, stored and scored once — when the
    /// branch policy's confidence gate allows (DESIGN.md §13.3).  Sharing
    /// never changes any draw or any distribution (a shared node's q-row
    /// is bit-identical to what each leaf would compute on its own flat
    /// row), so emitted tokens match `Algo::MultiPath` exactly; only the
    /// drafted-token count shrinks.
    fn draft_tree_scratch(
        &self,
        req: &DraftRequest<'_>,
        kv: &NativeKv,
    ) -> anyhow::Result<(DraftTree, NativeKv)> {
        let (tokens, length, seeds) = (req.tokens, req.length, req.seeds);
        let (k, gamma) = (req.k, req.gamma);
        self.check_shapes(tokens, length)?;
        self.check_gamma(gamma)?;
        self.check_seeds(seeds)?;
        if k == 0 {
            return Err(anyhow!("tree draft set needs k >= 1"));
        }
        let m = self.model(req.drafter)?;
        let (b, lm, vcb) = (self.info.batch, self.info.max_len, self.info.vocab_size);
        let lt = self.tree_scratch_len(k);
        let mut scratch = self.take_scratch(m, req.drafter, b, lt);
        // Shared prefix: each serving row's committed slots, copied once
        // — the tree's whole point (multipath copies the prefix into all
        // `k` path rows and attends it `k` times over).
        for bi in 0..b {
            let prefix = (length[bi].max(1) as usize - 1).min(lm);
            copy_kv_span(&mut scratch, bi, kv, bi, prefix);
        }
        let pending = self.gather_pending(tokens, length);
        let quant = self.quant_for(req.drafter, req.precision);

        let mut rows: Vec<TreeRow> = (0..b).map(|_| TreeRow::default()).collect();
        // cur[bi][p]: node index leaf stream `p` currently sits on
        // (-1 = root, i.e. the pending token).
        let mut cur: Vec<Vec<i32>> = vec![vec![-1i32; k]; b];
        let mut rngs: Vec<Vec<Rng>> = seeds
            .iter()
            .map(|&s| (0..k).map(|p| path_rng(s, DOM_DRAFT, p)).collect())
            .collect();
        // Nodes the previous forward call scored, per row (call 0 scores
        // the pending token, whose q-row seeds depth 0).
        let mut prev_level: Vec<Vec<i32>> = vec![vec![-1i32]; b];

        for dj in 0..gamma {
            // Forward this level in one batched call: call 0 forwards
            // [pending]; call `dj` forwards every depth-(dj-1) node.
            let mut batch_toks: Vec<TreeTokens> = Vec::with_capacity(b);
            for bi in 0..b {
                let p0 = (length[bi] - 1).max(0) as usize;
                let mut tt = TreeTokens::default();
                for &n in &prev_level[bi] {
                    if n < 0 {
                        tt.push(pending[bi], p0, p0, (0..p0 + 1).collect());
                    } else {
                        let (ni, row) = (n as usize, &rows[bi]);
                        tt.push(
                            row.tokens[ni],
                            (p0 + 1 + row.depth[ni]).min(lm - 1),
                            p0 + 1 + ni,
                            visible_slots(p0 + 1, &row.parent, ni),
                        );
                    }
                }
                batch_toks.push(tt);
            }
            let probs =
                self.forward_tree(m, req.drafter, quant.as_deref(), &mut scratch, &batch_toks);
            // Sample each leaf stream's next token from its current
            // node's distribution (its own uniform at every depth — the
            // multipath streams verbatim), then group coincident
            // `(parent, token)` draws into shared children where the
            // confidence gate allows.
            for bi in 0..b {
                let mut next_level: Vec<i32> = Vec::new();
                let mut share: HashMap<(i32, i32), i32> = HashMap::new();
                let mut next_cur = vec![-1i32; k];
                for p in 0..k {
                    let parent = cur[bi][p];
                    let qi = prev_level[bi]
                        .iter()
                        .position(|&x| x == parent)
                        .expect("leaf parent was forwarded this level");
                    let qrow = &probs[bi][qi * vcb..(qi + 1) * vcb];
                    let u = rngs[bi][p].uniform();
                    let tok = sample_row(qrow, u) as i32;
                    let shareable = match req.policy {
                        BranchPolicy::Disjoint => false,
                        BranchPolicy::EntropyGap { threshold } => top2_gap(qrow) >= threshold,
                    };
                    let hit =
                        if shareable { share.get(&(parent, tok)).copied() } else { None };
                    let node = match hit {
                        Some(n) => n,
                        None => {
                            let row = &mut rows[bi];
                            let n = row.tokens.len() as i32;
                            row.tokens.push(tok);
                            row.parent.push(parent);
                            row.depth.push(dj);
                            row.qs.extend_from_slice(qrow);
                            next_level.push(n);
                            if shareable {
                                share.insert((parent, tok), n);
                            }
                            n
                        }
                    };
                    next_cur[p] = node;
                }
                cur[bi] = next_cur;
                prev_level[bi] = next_level;
            }
        }
        for bi in 0..b {
            rows[bi].leaves = cur[bi].iter().map(|&n| n as usize).collect();
        }
        let tree = DraftTree::new(b, k, gamma, vcb, rows)?;
        Ok((tree, scratch))
    }

    /// [`Backend::score_tree`] plus the target's tree scratch cache (the
    /// winner-commit twin of [`NativeBackend::draft_tree_scratch`]): one
    /// target forward per row over `[pending] ++ all tree nodes` under
    /// the tree attention mask — every root-to-leaf chain gets exactly
    /// the distributions a flat per-path scoring pass would produce,
    /// with shared prefixes scored once.
    fn score_tree_scratch(
        &self,
        tree: &mut DraftTree,
        tokens: &[i32],
        length: &[i32],
        kv: &NativeKv,
    ) -> anyhow::Result<NativeKv> {
        self.check_shapes(tokens, length)?;
        let (b, lm, vcb) = (self.info.batch, self.info.max_len, self.info.vocab_size);
        if tree.batch != b || tree.vocab != vcb {
            return Err(anyhow!(
                "draft tree shape mismatch: batch {} (want {b}), vocab {} (want {vcb})",
                tree.batch,
                tree.vocab
            ));
        }
        self.check_gamma(tree.gamma)?;
        let m = self.model("target")?;
        let lt = self.tree_scratch_len(tree.k);
        let mut scratch = self.take_scratch(m, "target", b, lt);
        for bi in 0..b {
            let prefix = (length[bi].max(1) as usize - 1).min(lm);
            copy_kv_span(&mut scratch, bi, kv, bi, prefix);
        }
        let pending = self.gather_pending(tokens, length);
        let mut batch_toks: Vec<TreeTokens> = Vec::with_capacity(b);
        for bi in 0..b {
            let p0 = (length[bi] - 1).max(0) as usize;
            let row = &tree.rows[bi];
            let mut tt = TreeTokens::default();
            tt.push(pending[bi], p0, p0, (0..p0 + 1).collect());
            for ni in 0..row.n_nodes() {
                tt.push(
                    row.tokens[ni],
                    (p0 + 1 + row.depth[ni]).min(lm - 1),
                    p0 + 1 + ni,
                    visible_slots(p0 + 1, &row.parent, ni),
                );
            }
            batch_toks.push(tt);
        }
        let probs = self.forward_tree(m, "target", None, &mut scratch, &batch_toks);
        for bi in 0..b {
            let n = tree.rows[bi].n_nodes();
            let ps_root = probs[bi][..vcb].to_vec();
            let node_ps = probs[bi][vcb..(n + 1) * vcb].to_vec();
            tree.set_row_scores(bi, ps_root, node_ps)?;
        }
        Ok(scratch)
    }

    /// One fused tree iteration: draft the prefix-sharing token tree,
    /// score all its tokens in one batched target pass per row, verify
    /// every root-to-leaf chain jointly ([`verify::tree_verify`]) and
    /// commit only the winning chain's KV back into the live caches —
    /// leaving token/length/cache state bit-identical to
    /// [`NativeBackend::spec_iter_multipath`] at the same `k` (the
    /// ladder contract, test-enforced), with `drafted` counting actual
    /// tree nodes (strictly fewer than `B·K·gamma` whenever draws
    /// coincide).
    #[allow(clippy::too_many_arguments)]
    fn spec_iter_tree(
        &self,
        k: usize,
        drafter: &str,
        gamma: usize,
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut NativeKv,
        kv_drafter: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        let (b, l) = (self.info.batch, self.info.max_len);
        let t_draft = Instant::now();
        let req = DraftRequest {
            drafter,
            gamma,
            k,
            policy: BranchPolicy::EntropyGap { threshold: self.branch_threshold },
            tokens,
            length,
            seeds,
            precision: None,
            row_gammas: None,
        };
        let (mut tree, d_scratch) = self.draft_tree_scratch(&req, kv_drafter)?;
        let draft_us = t_draft.elapsed().as_micros() as u64;
        let t_target = Instant::now();
        let t_scratch = self.score_tree_scratch(&mut tree, tokens, length, kv_target)?;
        let target_us = t_target.elapsed().as_micros() as u64;
        let drafted = tree.total_nodes();

        let mut tau = vec![0i32; b];
        let mut emitted = vec![vocab::PAD as i32; b * (gamma + 1)];
        let mut done = vec![0i32; b];
        let mut views = TreeViews::default();
        for bi in 0..b {
            let (etas, u_res) = multipath_uniforms(seeds[bi], gamma, k);
            tree.tree_views_into(bi, &mut views)?;
            let row = &tree.rows[bi];
            let outcome = verify::tree_verify(
                &views.ps_root,
                &views.node_ps,
                &views.node_qs,
                &views.tokens,
                &row.parent,
                &row.leaves,
                &etas,
                u_res,
            );
            // Commit the winning chain: one span copy for the shared
            // prefix (+ pending), then each chain node's slot to its
            // flat cache position — covering exactly the slots the flat
            // multipath commit rewrites (drafter wrote pending + depths
            // 0..gamma-2; the target all gamma depths), with identical
            // values (DESIGN.md §13.5).
            let len = length[bi].max(0) as usize;
            let p0 = (length[bi] - 1).max(0) as usize;
            let chain = row.path_nodes(outcome.path);
            let lim_d = (len + gamma).saturating_sub(1).min(l);
            let lim_t = (len + gamma).min(l);
            copy_kv_span(kv_drafter, bi, &d_scratch, bi, (p0 + 1).min(lim_d));
            copy_kv_span(kv_target, bi, &t_scratch, bi, (p0 + 1).min(lim_t));
            for (dj, &node) in chain.iter().enumerate() {
                let src_pos = p0 + 1 + node;
                let dst_pos = p0 + 1 + dj;
                if dj < gamma.saturating_sub(1) && dst_pos < lim_d {
                    copy_kv_pos(kv_drafter, bi, dst_pos, &d_scratch, bi, src_pos);
                }
                if dst_pos < lim_t {
                    copy_kv_pos(kv_target, bi, dst_pos, &t_scratch, bi, src_pos);
                }
            }
            for (j, &t) in outcome.emitted.iter().enumerate() {
                if len + j < l {
                    tokens[bi * l + len + j] = t as i32;
                }
                emitted[bi * (gamma + 1) + j] = t as i32;
            }
            let eos_hit = outcome.emitted.iter().any(|&t| t == vocab::EOS);
            let new_len = length[bi] + outcome.tau as i32 + 1;
            let out_of_room = new_len > (l as i32) - (gamma as i32 + 2);
            tau[bi] = outcome.tau as i32;
            done[bi] = (eos_hit || out_of_room) as i32;
            length[bi] = new_len.min(l as i32 - 1);
        }
        self.put_scratch(drafter, d_scratch);
        self.put_scratch("target", t_scratch);
        Ok(SpecIterOut { tau, emitted, done, stride: gamma + 1, draft_us, target_us, drafted })
    }
}

/// Top-2 probability gap of a distribution row — the
/// [`BranchPolicy::EntropyGap`] confidence signal: a large gap means the
/// distribution is concentrated (low entropy), so coincident draws are
/// expected and sharing them loses no exploration (DESIGN.md §13.3).
fn top2_gap(q: &[f32]) -> f64 {
    let (mut a, mut b) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for &p in q {
        if p > a {
            b = a;
            a = p;
        } else if p > b {
            b = p;
        }
    }
    (a - b) as f64
}

impl Backend for NativeBackend {
    type Kv = NativeKv;

    fn info(&self) -> &BackendInfo {
        &self.info
    }

    /// The target model's page arena, when the backend runs the paged
    /// layout: [`crate::serve::KvPool`] accounts its leases directly
    /// against this allocator, so the serving pool and the physical
    /// arena agree by construction (one allocator, no parallel ledger).
    fn page_allocator(&self) -> Option<Arc<dyn PageAllocator>> {
        if self.kv_layout != KvLayout::Paged {
            return None;
        }
        let m = self.models.get("target")?;
        Some(self.arena_for("target", &m.dims))
    }

    /// Pre-size the persistent multipath scratch for the engine's
    /// configured path count, so the first iteration never pays the
    /// `(B·K)`-row allocations (they would otherwise be taken lazily on
    /// first use) — and adopt the engine's draft precision, pre-building
    /// the drafter's int8 twin so the first iteration never pays the
    /// quantisation pass (DESIGN.md §11.1).  The precision knob is
    /// backend-wide: engines sharing one backend must agree on it (the
    /// last `prepare` wins).
    fn prepare(&self, algo: Algo, drafter: &str, draft_precision: Precision) -> anyhow::Result<()> {
        self.set_draft_precision(draft_precision);
        if draft_precision == Precision::Int8 && self.info.has_drafter(drafter) {
            let _ = self.draft_quant(drafter);
        }
        // Pre-pack the tile-major fp32 twins the SIMD kernel streams, so
        // the first forward never pays the packing pass (DESIGN.md §12.1).
        if self.kernel == MatKernel::Simd {
            for name in [drafter, "target"] {
                if let Ok(m) = self.model(name) {
                    let _ = self.packed_model(name, m);
                }
            }
        }
        // Pre-size the persistent scratch for the multi-draft algorithms:
        // multipath runs `B·K` flat rows at the serving ring; tree runs
        // `B` rows at the extended tree ring (a distinct pool key —
        // never aliased, see `take_scratch`).
        let plan: Option<(usize, usize)> = match algo {
            Algo::MultiPath { k } => {
                if k == 0 {
                    return Err(anyhow!("multipath draft set needs k >= 1"));
                }
                Some((self.info.batch * k, self.info.max_len))
            }
            Algo::Tree { k } => {
                if k == 0 {
                    return Err(anyhow!("tree draft set needs k >= 1"));
                }
                Some((self.info.batch, self.tree_scratch_len(k)))
            }
            _ => None,
        };
        if let Some((rows, ring)) = plan {
            if !self.persistent_scratch {
                return Ok(());
            }
            for name in [drafter, "target"] {
                let m = self.model(name)?;
                let mut cache = self.scratch.lock().unwrap();
                let entry = cache.entry((name.to_string(), rows, ring)).or_default();
                if entry.is_empty() {
                    let kv = self.alloc_kv(name, &m.dims, rows, ring);
                    entry.push(kv);
                }
            }
        }
        Ok(())
    }

    fn prefill(&self, model: &str, tokens: &[i32], length: &[i32]) -> anyhow::Result<NativeKv> {
        self.check_shapes(tokens, length)?;
        let m = self.model(model)?;
        let mut kv = self.alloc_kv(model, &m.dims, self.info.batch, self.info.max_len);
        self.prefill_into(m, model, &mut kv, tokens, length);
        Ok(kv)
    }

    /// Batched admission prefill over the persistent scratch pool
    /// (DESIGN.md §11.3): one forward over the whole padded prompt batch,
    /// then one [`copy_kv_rows`] splice per admitted row — no per-call KV
    /// allocation, and the forward cost is shared by every admission in
    /// the scheduler tick.  Bit-identical to per-row `prefill` +
    /// `kv_splice` because batch rows are causally independent
    /// (test-enforced, `tests/theorems.rs`).
    fn prefill_rows(
        &self,
        model: &str,
        tokens: &[i32],
        length: &[i32],
        dst: &mut NativeKv,
        splices: &[RowSplice],
    ) -> anyhow::Result<()> {
        self.check_shapes(tokens, length)?;
        let m = self.model(model)?;
        let geom = (m.dims.n_layers, m.dims.n_heads, m.dims.head_dim());
        if (dst.n_layers, dst.n_heads, dst.head_dim) != geom || dst.max_len != self.info.max_len
        {
            return Err(anyhow!("prefill_rows: dst cache does not belong to '{model}'"));
        }
        for s in splices {
            if s.src_row >= self.info.batch || s.dst_slot >= dst.batch {
                return Err(anyhow!(
                    "prefill_rows: row out of range (src {}/{}, dst {}/{})",
                    s.src_row,
                    self.info.batch,
                    s.dst_slot,
                    dst.batch
                ));
            }
            if s.len > length[s.src_row].max(1) as usize {
                return Err(anyhow!(
                    "prefill_rows: splice len {} exceeds prefilled length {} of row {}",
                    s.len,
                    length[s.src_row].max(1),
                    s.src_row
                ));
            }
        }
        let mut scratch = self.take_scratch(m, model, self.info.batch, self.info.max_len);
        self.prefill_into(m, model, &mut scratch, tokens, length);
        for s in splices {
            copy_kv_rows(dst, s.dst_slot, &scratch, s.src_row, s.len);
        }
        self.put_scratch(model, scratch);
        Ok(())
    }

    /// Prefix-warm batched admission prefill (DESIGN.md §14.3): splice
    /// each cached prefix's positions into the scratch batch, forward
    /// **only the suffixes** ([`NativeBackend::prefill_suffix_into`]),
    /// then splice the completed rows over the live cache exactly like
    /// [`Backend::prefill_rows`].  Bit-identical to the cold path because
    /// cache row `i` depends only on tokens `0..=i` and the cached prefix
    /// rows are exactly what the cold forward would have written
    /// (test-enforced, `tests/serve_tier.rs`).
    fn prefill_rows_prefixed(
        &self,
        model: &str,
        tokens: &[i32],
        length: &[i32],
        dst: &mut NativeKv,
        splices: &[PrefixSplice<'_, NativeKv>],
    ) -> anyhow::Result<()> {
        if splices.iter().all(|s| s.prefix.is_none()) {
            let plain: Vec<RowSplice> = splices.iter().map(|s| s.splice).collect();
            return self.prefill_rows(model, tokens, length, dst, &plain);
        }
        self.check_shapes(tokens, length)?;
        let m = self.model(model)?;
        let geom = (m.dims.n_layers, m.dims.n_heads, m.dims.head_dim());
        if (dst.n_layers, dst.n_heads, dst.head_dim) != geom || dst.max_len != self.info.max_len
        {
            return Err(anyhow!("prefill_rows_prefixed: dst cache does not belong to '{model}'"));
        }
        let (b, l) = (self.info.batch, self.info.max_len);
        let mut start = vec![0i32; b];
        for s in splices {
            if s.splice.src_row >= b || s.splice.dst_slot >= dst.batch {
                return Err(anyhow!(
                    "prefill_rows_prefixed: row out of range (src {}/{b}, dst {}/{})",
                    s.splice.src_row,
                    s.splice.dst_slot,
                    dst.batch
                ));
            }
            if s.splice.len > length[s.splice.src_row].max(1) as usize {
                return Err(anyhow!(
                    "prefill_rows_prefixed: splice len {} exceeds prefilled length {} of row {}",
                    s.splice.len,
                    length[s.splice.src_row].max(1),
                    s.splice.src_row
                ));
            }
            if let Some((pkv, plen)) = s.prefix {
                if (pkv.n_layers, pkv.n_heads, pkv.head_dim) != geom {
                    return Err(anyhow!(
                        "prefill_rows_prefixed: prefix cache does not belong to '{model}'"
                    ));
                }
                if plen == 0 || plen > pkv.max_len || plen >= s.splice.len {
                    return Err(anyhow!(
                        "prefill_rows_prefixed: prefix len {plen} invalid for prompt len {}",
                        s.splice.len
                    ));
                }
                start[s.splice.src_row] = plen as i32;
            }
        }
        let mut scratch = self.take_scratch(m, model, b, l);
        for s in splices {
            if let Some((pkv, plen)) = s.prefix {
                copy_kv_span(&mut scratch, s.splice.src_row, pkv, 0, plen);
            }
        }
        self.prefill_suffix_into(m, model, &mut scratch, tokens, length, &start);
        for s in splices {
            copy_kv_rows(dst, s.splice.dst_slot, &scratch, s.splice.src_row, s.splice.len);
        }
        self.put_scratch(model, scratch);
        Ok(())
    }

    /// Compact single-row extract: the returned cache's ring is exactly
    /// `len`, so a prefix cache holds `len` positions instead of a full
    /// `(B, L)` batch — the memory footprint the page accounting in
    /// [`crate::serve::KvPool`] charges for it.  Only ever a splice
    /// source (ring mismatches are legal for splices; the span bounds
    /// are still debug-asserted against both rings); it is never
    /// forwarded.  The single-row checkout comes from the scratch pool
    /// (sized to `len`, not `max_len`), and under the paged layout the
    /// extract aliases the source row's full pages instead of copying
    /// them — only the boundary partial page moves.  The row is handed
    /// off to the caller (prefix caches own their extracts), so it is
    /// never returned to the pool.
    fn kv_extract(
        &self,
        model: &str,
        src: &NativeKv,
        src_row: usize,
        len: usize,
    ) -> anyhow::Result<NativeKv> {
        let m = self.model(model)?;
        let geom = (m.dims.n_layers, m.dims.n_heads, m.dims.head_dim());
        if (src.n_layers, src.n_heads, src.head_dim) != geom {
            return Err(anyhow!("kv_extract: src cache does not belong to '{model}'"));
        }
        if src_row >= src.batch {
            return Err(anyhow!("kv_extract: row {src_row} out of range ({} rows)", src.batch));
        }
        if len > src.max_len {
            return Err(anyhow!("kv_extract: len {len} exceeds ring {}", src.max_len));
        }
        if len == 0 {
            // Degenerate extract: a zeroed 1-position ring (stale pool
            // contents would leak unwritten floats — nothing covers them).
            return Ok(self.alloc_kv(model, &m.dims, 1, 1));
        }
        let mut out = self.take_scratch(m, model, 1, len);
        copy_kv_span(&mut out, 0, src, src_row, len);
        Ok(out)
    }

    #[allow(clippy::too_many_arguments)]
    fn spec_iter(
        &self,
        algo: Algo,
        drafter: &str,
        gamma: usize,
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut NativeKv,
        kv_drafter: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        if !algo.fused() {
            return Err(anyhow!("algo {algo} requires the host-verify engine"));
        }
        if let Algo::MultiPath { k } = algo {
            return self.spec_iter_multipath(
                k, drafter, gamma, tokens, length, kv_target, kv_drafter, seeds,
            );
        }
        if let Algo::Tree { k } = algo {
            return self.spec_iter_tree(
                k, drafter, gamma, tokens, length, kv_target, kv_drafter, seeds,
            );
        }
        self.check_shapes(tokens, length)?;
        self.check_gamma(gamma)?;
        self.check_seeds(seeds)?;
        let (b, l, vcb) = (self.info.batch, self.info.max_len, self.info.vocab_size);
        let m_d = self.model(drafter)?;
        let m_t = self.model("target")?;

        let quant = self.draft_quant(drafter);
        let t_draft = Instant::now();
        let (drafts, qs) = self.draft_scan(
            m_d,
            drafter,
            quant.as_deref(),
            kv_drafter,
            tokens,
            length,
            gamma,
            seeds,
        );
        let draft_us = t_draft.elapsed().as_micros() as u64;
        let t_target = Instant::now();
        let ps = self.score(m_t, kv_target, tokens, length, &drafts, gamma);
        let target_us = t_target.elapsed().as_micros() as u64;

        let mut tau = vec![0i32; b];
        let mut emitted = vec![vocab::PAD as i32; b * (gamma + 1)];
        let mut done = vec![0i32; b];
        for bi in 0..b {
            let (etas, u_res) = verify_uniforms(seeds[bi], gamma);
            let ps_m = ProbMatrix::from_f32(
                gamma + 1,
                vcb,
                &ps[bi * (gamma + 1) * vcb..(bi + 1) * (gamma + 1) * vcb],
            );
            let qs_m =
                ProbMatrix::from_f32(gamma, vcb, &qs[bi * gamma * vcb..(bi + 1) * gamma * vcb]);
            let row_drafts: Vec<u32> =
                drafts[bi * gamma..(bi + 1) * gamma].iter().map(|&x| x as u32).collect();
            let outcome = verify::verify(algo, &ps_m, &qs_m, &row_drafts, &etas, u_res);
            let len = length[bi].max(0) as usize;
            for (j, &t) in outcome.emitted.iter().enumerate() {
                if len + j < l {
                    tokens[bi * l + len + j] = t as i32;
                }
                emitted[bi * (gamma + 1) + j] = t as i32;
            }
            let eos_hit = outcome.emitted.iter().any(|&t| t == vocab::EOS);
            let new_len = length[bi] + outcome.tau as i32 + 1;
            let out_of_room = new_len > (l as i32) - (gamma as i32 + 2);
            tau[bi] = outcome.tau as i32;
            done[bi] = (eos_hit || out_of_room) as i32;
            length[bi] = new_len.min(l as i32 - 1);
        }
        Ok(SpecIterOut {
            tau,
            emitted,
            done,
            stride: gamma + 1,
            draft_us,
            target_us,
            drafted: b * gamma,
        })
    }

    /// True ragged implementation of [`Backend::spec_iter_rows`]: each
    /// row runs at its own gamma via the masked forwards (no default-impl
    /// clamp to `min(gammas)`).  Uniform calls fall through to the plain
    /// fused [`Backend::spec_iter`] so the adaptive-off and steady-state
    /// paths stay byte-for-byte the pre-existing code.
    fn spec_iter_rows(
        &self,
        algo: Algo,
        drafter: &str,
        gammas: &[usize],
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut NativeKv,
        kv_drafter: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        if !algo.fused() {
            return Err(anyhow!("algo {algo} requires the host-verify engine"));
        }
        self.check_shapes(tokens, length)?;
        self.check_seeds(seeds)?;
        if gammas.len() != self.info.batch {
            return Err(anyhow!(
                "gammas shape {} != batch {}",
                gammas.len(),
                self.info.batch
            ));
        }
        for &g in gammas {
            self.check_gamma(g)?;
        }
        let gmax = gammas.iter().copied().max().unwrap_or(1);
        if gammas.iter().all(|&g| g == gmax) {
            return self
                .spec_iter(algo, drafter, gmax, tokens, length, kv_target, kv_drafter, seeds);
        }
        match algo {
            Algo::MultiPath { k } | Algo::Tree { k } => self.spec_iter_rows_multi(
                k, drafter, gammas, tokens, length, kv_target, kv_drafter, seeds,
            ),
            _ => self.spec_iter_rows_block(
                algo, drafter, gammas, tokens, length, kv_target, kv_drafter, seeds,
            ),
        }
    }

    fn draft_block(
        &self,
        drafter: &str,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &mut NativeKv,
        seeds: &[i32],
    ) -> anyhow::Result<DraftOut> {
        self.check_shapes(tokens, length)?;
        self.check_gamma(gamma)?;
        self.check_seeds(seeds)?;
        let m = self.model(drafter)?;
        let quant = self.draft_quant(drafter);
        let (drafts, qs) =
            self.draft_scan(m, drafter, quant.as_deref(), kv, tokens, length, gamma, seeds);
        Ok(DraftOut { drafts, qs })
    }

    /// Host-memory splice: copy `len` leading cache rows of `src` row
    /// `src_row` over `dst` row `dst_slot`, for every layer of `model`'s
    /// cache.  O(len · layers · d_model) copies, no model evaluation.
    fn kv_splice(
        &self,
        model: &str,
        dst: &mut NativeKv,
        dst_slot: usize,
        src: &NativeKv,
        src_row: usize,
        len: usize,
    ) -> anyhow::Result<()> {
        let m = self.model(model)?;
        let geom = (m.dims.n_layers, m.dims.n_heads, m.dims.head_dim());
        for (who, kv) in [("dst", &*dst), ("src", src)] {
            if (kv.n_layers, kv.n_heads, kv.head_dim) != geom {
                return Err(anyhow!("kv_splice: {who} cache does not belong to '{model}'"));
            }
        }
        if dst_slot >= dst.batch || src_row >= src.batch {
            return Err(anyhow!(
                "kv_splice: row out of range (dst {dst_slot}/{}, src {src_row}/{})",
                dst.batch,
                src.batch
            ));
        }
        // Rings may differ: extracted prefix caches are compact (ring =
        // prefix length, [`Backend::kv_extract`]) and only ever splice
        // *sources*.  The copy just needs `len` positions on both sides.
        if len > dst.max_len || len > src.max_len {
            return Err(anyhow!(
                "kv_splice: len {len} exceeds ring (dst {}, src {})",
                dst.max_len,
                src.max_len
            ));
        }
        copy_kv_span(dst, dst_slot, src, src_row, len);
        Ok(())
    }

    fn target_score(
        &self,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &mut NativeKv,
        drafts: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        self.check_shapes(tokens, length)?;
        self.check_gamma(gamma)?;
        if drafts.len() != self.info.batch * gamma {
            return Err(anyhow!("drafts shape {} != B*gamma", drafts.len()));
        }
        let m = self.model("target")?;
        Ok(self.score(m, kv, tokens, length, drafts, gamma))
    }

    fn draft_tree(&self, req: &DraftRequest<'_>, kv: &NativeKv) -> anyhow::Result<DraftTree> {
        let (tree, scratch) = self.draft_tree_scratch(req, kv)?;
        self.put_scratch(req.drafter, scratch);
        Ok(tree)
    }

    fn score_tree(
        &self,
        tree: &mut DraftTree,
        tokens: &[i32],
        length: &[i32],
        kv: &NativeKv,
    ) -> anyhow::Result<()> {
        let scratch = self.score_tree_scratch(tree, tokens, length, kv)?;
        self.put_scratch("target", scratch);
        Ok(())
    }

    fn baseline_step(
        &self,
        tokens: &mut [i32],
        length: &mut [i32],
        kv: &mut NativeKv,
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        self.check_shapes(tokens, length)?;
        let (b, l, vcb) = (self.info.batch, self.info.max_len, self.info.vocab_size);
        let m = self.model("target")?;
        let pending = self.gather_pending(tokens, length);
        let start: Vec<i32> = length.iter().map(|&len| len - 1).collect();
        let probs = self.forward_block(m, "target", None, kv, &pending, 1, &start, true);
        let mut rng = Rng::new(seed64(seed) ^ DOM_BASELINE);
        let mut next = vec![0i32; b];
        let mut done = vec![0i32; b];
        for bi in 0..b {
            let u = rng.uniform();
            let nx = sample_row(&probs[bi * vcb..(bi + 1) * vcb], u) as i32;
            let len = length[bi].max(0) as usize;
            if len < l {
                tokens[bi * l + len] = nx;
            }
            let new_len = length[bi] + 1;
            next[bi] = nx;
            done[bi] = (nx == vocab::EOS as i32 || new_len > l as i32 - 2) as i32;
            length[bi] = new_len.min(l as i32 - 1);
        }
        Ok(StepOut { next, done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeBackend {
        NativeBackend::seeded_with_shapes(2, 32, 7)
    }

    /// Layout-agnostic full-ring KV equality (gathers through the page
    /// table under the paged layout, straight from the ring otherwise).
    fn assert_kv_eq(a: &NativeKv, b: &NativeKv, msg: &str) {
        assert_eq!(a.batch, b.batch, "{msg}: row counts differ");
        assert_eq!(a.max_len, b.max_len, "{msg}: ring lengths differ");
        for bi in 0..a.batch {
            assert_eq!(
                a.row_snapshot(bi, a.max_len),
                b.row_snapshot(bi, b.max_len),
                "{msg}: row {bi} diverged"
            );
        }
    }

    fn prompt_state(be: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
        let info = be.info();
        let mut toks = vec![vocab::PAD as i32; info.batch * info.max_len];
        let mut lens = vec![0i32; info.batch];
        for b in 0..info.batch {
            let p = [vocab::BOS, vocab::marker_for(0), 20 + b as u32, 21, 22];
            for (j, &t) in p.iter().enumerate() {
                toks[b * info.max_len + j] = t as i32;
            }
            lens[b] = p.len() as i32;
        }
        (toks, lens)
    }

    #[test]
    fn forward_produces_normalised_distributions() {
        let be = tiny();
        let (toks, lens) = prompt_state(&be);
        let mut kv = be.prefill("xxs", &toks, &lens).unwrap();
        let out = be.draft_block("xxs", 3, &toks, &lens, &mut kv, &[5, 6]).unwrap();
        let v = be.info().vocab_size;
        assert_eq!(out.drafts.len(), 2 * 3);
        assert_eq!(out.qs.len(), 2 * 3 * v);
        for row in out.qs.chunks_exact(v) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "row sums to {s}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        assert!(out.drafts.iter().all(|&t| (0..v as i32).contains(&t)));
    }

    #[test]
    fn seeded_backend_is_deterministic() {
        let (a, b) = (tiny(), tiny());
        let (toks, lens) = prompt_state(&a);
        let mut kva = a.prefill("target", &toks, &lens).unwrap();
        let mut kvb = b.prefill("target", &toks, &lens).unwrap();
        assert_kv_eq(&kva, &kvb, "prefill");
        let pa = a.target_score(2, &toks, &lens, &mut kva, &[20, 21, 20, 21]).unwrap();
        let pb = b.target_score(2, &toks, &lens, &mut kvb, &[20, 21, 20, 21]).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn spec_iter_advances_state_and_respects_contract() {
        let be = tiny();
        let (mut toks, mut lens) = prompt_state(&be);
        let mut kvt = be.prefill("target", &toks, &lens).unwrap();
        let mut kvd = be.prefill("xxs", &toks, &lens).unwrap();
        let len0 = lens.clone();
        let out = be
            .spec_iter(Algo::Block, "xxs", 4, &mut toks, &mut lens, &mut kvt, &mut kvd, &[3, 4])
            .unwrap();
        for b in 0..be.info().batch {
            let t = out.tau[b] as usize;
            assert!(t <= 4);
            assert_eq!(lens[b], len0[b] + t as i32 + 1);
            // emitted tokens landed in the ring at the old length.
            for j in 0..=t {
                assert_eq!(
                    toks[b * be.info().max_len + len0[b] as usize + j],
                    out.emitted[b * 5 + j]
                );
            }
        }
    }

    #[test]
    fn spec_iter_rows_uniform_delegates_bit_identically() {
        let be = tiny();
        let (toks0, lens0) = prompt_state(&be);
        let seeds = [3, 4];
        let mut ta = toks0.clone();
        let mut la = lens0.clone();
        let mut kvt_a = be.prefill("target", &toks0, &lens0).unwrap();
        let mut kvd_a = be.prefill("xxs", &toks0, &lens0).unwrap();
        let a = be
            .spec_iter(Algo::Block, "xxs", 4, &mut ta, &mut la, &mut kvt_a, &mut kvd_a, &seeds)
            .unwrap();
        let mut tb = toks0.clone();
        let mut lb = lens0.clone();
        let mut kvt_b = be.prefill("target", &toks0, &lens0).unwrap();
        let mut kvd_b = be.prefill("xxs", &toks0, &lens0).unwrap();
        let b = be
            .spec_iter_rows(
                Algo::Block,
                "xxs",
                &[4, 4],
                &mut tb,
                &mut lb,
                &mut kvt_b,
                &mut kvd_b,
                &seeds,
            )
            .unwrap();
        assert_eq!(a.tau, b.tau);
        assert_eq!(a.emitted, b.emitted);
        assert_eq!(a.stride, b.stride);
        assert_eq!(a.done, b.done);
        assert_eq!(ta, tb);
        assert_eq!(la, lb);
        assert_kv_eq(&kvt_a, &kvt_b, "target cache");
        assert_kv_eq(&kvd_a, &kvd_b, "drafter cache");
    }

    fn run_uniform(
        be: &NativeBackend,
        algo: Algo,
        g: usize,
        toks0: &[i32],
        lens0: &[i32],
        seeds: &[i32],
    ) -> (Vec<i32>, Vec<i32>, SpecIterOut, NativeKv, NativeKv) {
        let mut toks = toks0.to_vec();
        let mut lens = lens0.to_vec();
        let mut kvt = be.prefill("target", &toks, &lens).unwrap();
        let mut kvd = be.prefill("xxs", &toks, &lens).unwrap();
        let out = be
            .spec_iter(algo, "xxs", g, &mut toks, &mut lens, &mut kvt, &mut kvd, seeds)
            .unwrap();
        (toks, lens, out, kvt, kvd)
    }

    /// The per-row losslessness invariant behind the adaptive controller:
    /// in a ragged iteration every row commits exactly the bits a uniform
    /// iteration at that row's gamma would (tokens, lengths, emitted,
    /// done, and — where the cache layout is shared — KV bytes).
    #[test]
    fn ragged_rows_match_uniform_runs() {
        for algo in [Algo::Block, Algo::Token, Algo::MultiPath { k: 2 }, Algo::Tree { k: 2 }] {
            let be = NativeBackend::seeded_with_shapes(4, 32, 7);
            let (toks0, lens0) = prompt_state(&be);
            let seeds = [3, 4, 5, 6];
            let gammas = [3usize, 5, 3, 5];
            let mut toks = toks0.clone();
            let mut lens = lens0.clone();
            let mut kvt = be.prefill("target", &toks0, &lens0).unwrap();
            let mut kvd = be.prefill("xxs", &toks0, &lens0).unwrap();
            let out = be
                .spec_iter_rows(
                    algo, "xxs", &gammas, &mut toks, &mut lens, &mut kvt, &mut kvd, &seeds,
                )
                .unwrap();
            assert_eq!(out.stride, 6, "{algo}: stride is max(gammas) + 1");
            assert_eq!(out.drafted, algo.paths() * (3 + 5 + 3 + 5), "{algo}: drafted");
            let l = be.info().max_len;
            for g in [3usize, 5] {
                let (ut, ul, uo, ukvt, ukvd) = run_uniform(&be, algo, g, &toks0, &lens0, &seeds);
                for bi in 0..4 {
                    if gammas[bi] != g {
                        continue;
                    }
                    assert_eq!(out.tau[bi], uo.tau[bi], "{algo}: tau row {bi} at gamma {g}");
                    assert_eq!(out.done[bi], uo.done[bi], "{algo}: done row {bi}");
                    assert_eq!(lens[bi], ul[bi], "{algo}: length row {bi}");
                    assert_eq!(
                        &toks[bi * l..(bi + 1) * l],
                        &ut[bi * l..(bi + 1) * l],
                        "{algo}: token ring row {bi}"
                    );
                    let t = out.tau[bi] as usize;
                    assert_eq!(
                        &out.emitted[bi * out.stride..bi * out.stride + t + 1],
                        &uo.emitted[bi * uo.stride..bi * uo.stride + t + 1],
                        "{algo}: emitted row {bi}"
                    );
                    // The tree layout commits equivalent-but-differently
                    // padded scratch rows; byte-compare KV only where the
                    // uniform run uses the same flat layout.
                    if !matches!(algo, Algo::Tree { .. }) {
                        assert_eq!(
                            kvt.row_snapshot(bi, l),
                            ukvt.row_snapshot(bi, l),
                            "{algo}: target KV row {bi}"
                        );
                        assert_eq!(
                            kvd.row_snapshot(bi, l),
                            ukvd.row_snapshot(bi, l),
                            "{algo}: drafter KV row {bi}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn greedy_rejected_on_fused_path() {
        let be = tiny();
        let (mut toks, mut lens) = prompt_state(&be);
        let mut kvt = be.prefill("target", &toks, &lens).unwrap();
        let mut kvd = be.prefill("xxs", &toks, &lens).unwrap();
        assert!(be
            .spec_iter(Algo::Greedy, "xxs", 4, &mut toks, &mut lens, &mut kvt, &mut kvd, &[0, 0])
            .is_err());
    }

    #[test]
    fn verify_uniforms_are_stable_and_in_range() {
        let (e1, u1) = verify_uniforms(42, 8);
        let (e2, u2) = verify_uniforms(42, 8);
        assert_eq!(e1, e2);
        assert_eq!(u1, u2);
        assert_eq!(e1.len(), 8);
        assert!(e1.iter().all(|&x| (0.0..1.0).contains(&x)));
        assert!((0.0..1.0).contains(&u1));
        let (e3, _) = verify_uniforms(43, 8);
        assert_ne!(e1, e3);
    }

    #[test]
    fn kv_splice_copies_exactly_one_row() {
        let be = tiny();
        let (toks, lens) = prompt_state(&be);
        let src = be.prefill("target", &toks, &lens).unwrap();
        // A differently prefilled destination cache.
        let mut toks2 = toks.clone();
        toks2[2] = 60;
        let mut dst = be.prefill("target", &toks2, &lens).unwrap();
        let before_row0 = dst.row_snapshot(0, dst.max_len);
        let len = lens[0] as usize;
        be.kv_splice("target", &mut dst, 1, &src, 0, len).unwrap();
        // Destination row 1 now equals source row 0 on the spliced span...
        assert_eq!(dst.row_snapshot(1, len), src.row_snapshot(0, len));
        // ...and row 0 was left untouched.
        assert_eq!(before_row0, dst.row_snapshot(0, dst.max_len));
        // Bad geometry / bounds are rejected.
        assert!(be.kv_splice("target", &mut dst, 9, &src, 0, len).is_err());
        let xxs = be.prefill("xxs", &toks, &lens).unwrap();
        assert!(be.kv_splice("target", &mut dst, 1, &xxs, 0, len).is_err());
    }

    #[test]
    fn multipath_uniforms_replay_single_path_at_path_zero() {
        let (etas1, u1) = verify_uniforms(42, 6);
        let (etas_k, u_k) = multipath_uniforms(42, 6, 3);
        assert_eq!(etas_k.len(), 3);
        assert_eq!(etas_k[0], etas1, "path 0 must replay the single-path eta stream");
        assert_eq!(u_k, u1, "the residual uniform is shared");
        assert_ne!(etas_k[1], etas_k[0], "paths draw from distinct streams");
        assert_ne!(etas_k[2], etas_k[1]);
        for path in &etas_k {
            assert!(path.iter().all(|&x| (0.0..1.0).contains(&x)));
        }
    }

    #[test]
    fn draft_multi_path0_replays_single_path() {
        let be = tiny();
        let (toks, lens) = prompt_state(&be);
        let mut kv_single = be.prefill("xxs", &toks, &lens).unwrap();
        let kv_multi = kv_single.clone();
        let seeds = [5, 6];
        let d = be.draft_block("xxs", 3, &toks, &lens, &mut kv_single, &seeds).unwrap();
        let set = be.draft_multi("xxs", 2, 3, &toks, &lens, &kv_multi, &seeds).unwrap();
        let v = be.info().vocab_size;
        let n = 3 * v;
        for bi in 0..2 {
            assert_eq!(set.path_drafts(bi, 0), &d.drafts[bi * 3..(bi + 1) * 3]);
            let r = set.flat_row(bi, 0);
            assert_eq!(&set.qs[r * n..(r + 1) * n], &d.qs[bi * n..(bi + 1) * n]);
        }
        assert!(be.draft_multi("xxs", 0, 3, &toks, &lens, &kv_multi, &seeds).is_err());
    }

    #[test]
    fn target_score_multi_agrees_with_single_path_scoring() {
        let be = tiny();
        let (toks, lens) = prompt_state(&be);
        let kv_d = be.prefill("xxs", &toks, &lens).unwrap();
        let mut kv_t = be.prefill("target", &toks, &lens).unwrap();
        let kv_t2 = kv_t.clone();
        let seeds = [3, 9];
        let mut set = be.draft_multi("xxs", 2, 3, &toks, &lens, &kv_d, &seeds).unwrap();
        be.target_score_multi(&mut set, &toks, &lens, &kv_t2).unwrap();
        // Path 0 drafts are the single-path drafts, so single-path target
        // scoring of them must reproduce the path-0 ps slice bit for bit.
        let drafts0: Vec<i32> =
            (0..2).flat_map(|bi| set.path_drafts(bi, 0).to_vec()).collect();
        let ps = be.target_score(3, &toks, &lens, &mut kv_t, &drafts0).unwrap();
        let v = be.info().vocab_size;
        let n = 4 * v;
        for bi in 0..2 {
            let r = set.flat_row(bi, 0);
            assert_eq!(&set.ps[r * n..(r + 1) * n], &ps[bi * n..(bi + 1) * n]);
        }
        for row in set.ps.chunks_exact(v) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "scored row sums to {s}");
        }
    }

    #[test]
    fn multipath_k1_spec_iter_is_bit_identical_to_block() {
        let be = tiny();
        let (mut t1, mut l1) = prompt_state(&be);
        let (mut t2, mut l2) = (t1.clone(), l1.clone());
        let mut kt1 = be.prefill("target", &t1, &l1).unwrap();
        let mut kd1 = be.prefill("xxs", &t1, &l1).unwrap();
        let mut kt2 = kt1.clone();
        let mut kd2 = kd1.clone();
        for iter in 0..4i32 {
            let seeds = [11 + iter, 23 + 7 * iter];
            let a = be
                .spec_iter(Algo::Block, "xxs", 4, &mut t1, &mut l1, &mut kt1, &mut kd1, &seeds)
                .unwrap();
            let b = be
                .spec_iter(
                    Algo::MultiPath { k: 1 },
                    "xxs",
                    4,
                    &mut t2,
                    &mut l2,
                    &mut kt2,
                    &mut kd2,
                    &seeds,
                )
                .unwrap();
            assert_eq!(a.tau, b.tau, "iter {iter}");
            assert_eq!(a.emitted, b.emitted, "iter {iter}");
            assert_eq!(a.done, b.done, "iter {iter}");
            assert_eq!(t1, t2, "iter {iter}: token rings diverged");
            assert_eq!(l1, l2, "iter {iter}: lengths diverged");
            assert_kv_eq(&kt1, &kt2, "target cache");
            assert_kv_eq(&kd1, &kd2, "drafter cache");
        }
    }

    /// Drive two algos side by side on two (identically seeded) backends
    /// and require bit-identical emitted tokens, rings, lengths and all
    /// four KV caches after every iteration.
    fn spec_ladder_bit_identical(be_a: &NativeBackend, a: Algo, be_b: &NativeBackend, b: Algo) {
        let (mut t1, mut l1) = prompt_state(be_a);
        let (mut t2, mut l2) = (t1.clone(), l1.clone());
        let mut kt1 = be_a.prefill("target", &t1, &l1).unwrap();
        let mut kd1 = be_a.prefill("xxs", &t1, &l1).unwrap();
        let mut kt2 = be_b.prefill("target", &t2, &l2).unwrap();
        let mut kd2 = be_b.prefill("xxs", &t2, &l2).unwrap();
        for iter in 0..4i32 {
            let seeds = [11 + iter, 23 + 7 * iter];
            let oa = be_a
                .spec_iter(a, "xxs", 4, &mut t1, &mut l1, &mut kt1, &mut kd1, &seeds)
                .unwrap();
            let ob = be_b
                .spec_iter(b, "xxs", 4, &mut t2, &mut l2, &mut kt2, &mut kd2, &seeds)
                .unwrap();
            assert_eq!(oa.tau, ob.tau, "{a} vs {b} iter {iter}");
            assert_eq!(oa.emitted, ob.emitted, "{a} vs {b} iter {iter}");
            assert_eq!(oa.done, ob.done, "{a} vs {b} iter {iter}");
            assert_eq!(t1, t2, "{a} vs {b} iter {iter}: token rings diverged");
            assert_eq!(l1, l2, "{a} vs {b} iter {iter}: lengths diverged");
            assert_kv_eq(&kt1, &kt2, "target cache");
            assert_kv_eq(&kd1, &kd2, "drafter cache");
        }
    }

    /// Bottom rung of the ladder: a 1-leaf tree is block verification.
    #[test]
    fn tree_k1_spec_iter_is_bit_identical_to_block() {
        spec_ladder_bit_identical(&tiny(), Algo::Block, &tiny(), Algo::Tree { k: 1 });
    }

    /// Middle rung: the tree is flat multipath with shared storage — at
    /// the default threshold (share coincident draws) *and* at threshold
    /// infinity (never share; exact layout twin), the k-leaf tree must be
    /// bit-identical to `MultiPath { k }` end to end.
    #[test]
    fn tree_spec_iter_is_bit_identical_to_multipath() {
        for k in [2usize, 3] {
            spec_ladder_bit_identical(
                &tiny(),
                Algo::MultiPath { k },
                &tiny(),
                Algo::Tree { k },
            );
            let never_share = tiny().with_branch_threshold(f64::INFINITY);
            spec_ladder_bit_identical(
                &tiny(),
                Algo::MultiPath { k },
                &never_share,
                Algo::Tree { k },
            );
        }
    }

    /// The tree never drafts more than flat multipath (`b * k * gamma`
    /// scored tokens) and never less than a single path per row.
    #[test]
    fn tree_drafted_count_is_bounded() {
        let be = tiny();
        let (mut toks, mut lens) = prompt_state(&be);
        let mut kvt = be.prefill("target", &toks, &lens).unwrap();
        let mut kvd = be.prefill("xxs", &toks, &lens).unwrap();
        let (b, k, gamma) = (be.info().batch, 3usize, 4usize);
        for iter in 0..4i32 {
            let out = be
                .spec_iter(
                    Algo::Tree { k },
                    "xxs",
                    gamma,
                    &mut toks,
                    &mut lens,
                    &mut kvt,
                    &mut kvd,
                    &[3 + iter, 4 + iter],
                )
                .unwrap();
            assert!(out.drafted <= b * k * gamma, "iter {iter}: {}", out.drafted);
            assert!(out.drafted >= b * gamma, "iter {iter}: {}", out.drafted);
        }
    }

    /// Dedup-invariance at the draft level: the sharing tree flattens to
    /// exactly the per-leaf streams the disjoint (multipath-layout) tree
    /// produces, while storing at most as many nodes.
    #[test]
    fn draft_tree_sharing_matches_disjoint_flat() {
        let be = tiny();
        let (toks, lens) = prompt_state(&be);
        let kv = be.prefill("xxs", &toks, &lens).unwrap();
        let req_d = DraftRequest {
            drafter: "xxs",
            gamma: 3,
            k: 4,
            policy: BranchPolicy::Disjoint,
            tokens: &toks,
            length: &lens,
            seeds: &[5, 6],
            precision: None,
            row_gammas: None,
        };
        let req_s = DraftRequest { policy: BranchPolicy::EntropyGap { threshold: 0.0 }, ..req_d };
        let t_d = be.draft_tree(&req_d, &kv).unwrap();
        let t_s = be.draft_tree(&req_s, &kv).unwrap();
        assert_eq!(t_d.total_nodes(), 2 * 4 * 3, "disjoint tree is the flat layout");
        assert!(t_s.total_nodes() <= t_d.total_nodes());
        let f_d = t_d.flatten().unwrap();
        let f_s = t_s.flatten().unwrap();
        assert_eq!(f_d.drafts, f_s.drafts, "per-leaf streams must not depend on sharing");
        assert_eq!(f_d.qs, f_s.qs, "shared nodes must carry bit-identical q rows");
    }

    #[test]
    fn multipath_spec_iter_advances_state_and_respects_contract() {
        let be = tiny();
        let (mut toks, mut lens) = prompt_state(&be);
        let mut kvt = be.prefill("target", &toks, &lens).unwrap();
        let mut kvd = be.prefill("xxs", &toks, &lens).unwrap();
        let len0 = lens.clone();
        let gamma = 4;
        let out = be
            .spec_iter(
                Algo::MultiPath { k: 3 },
                "xxs",
                gamma,
                &mut toks,
                &mut lens,
                &mut kvt,
                &mut kvd,
                &[3, 4],
            )
            .unwrap();
        for b in 0..be.info().batch {
            let t = out.tau[b] as usize;
            assert!(t <= gamma);
            assert_eq!(lens[b], len0[b] + t as i32 + 1);
            for j in 0..=t {
                assert_eq!(
                    toks[b * be.info().max_len + len0[b] as usize + j],
                    out.emitted[b * (gamma + 1) + j]
                );
            }
        }
    }

    #[test]
    fn drafter_family_is_quality_ordered() {
        // The shared-embedding construction must make xxs a better
        // approximation of the target than xxxs (paper ordering).  Compare
        // mean TV distance between drafter and target next-token
        // distributions along a short decode path.
        let be = NativeBackend::seeded(11);
        let info = be.info().clone();
        let mut toks = vec![vocab::PAD as i32; info.batch * info.max_len];
        let mut lens = vec![0i32; info.batch];
        for b in 0..info.batch {
            let p = [1i32, 3, 20 + b as i32, 30, 40, 21];
            for (j, &t) in p.iter().enumerate() {
                toks[b * info.max_len + j] = t;
            }
            lens[b] = p.len() as i32;
        }
        let gamma = 8;
        let mut tv = HashMap::new();
        for name in ["xxs", "xxxs"] {
            let mut kv_d = be.prefill(name, &toks, &lens).unwrap();
            let mut kv_t = be.prefill("target", &toks, &lens).unwrap();
            let seeds: Vec<i32> = (0..info.batch as i32).map(|b| 9 + 7 * b).collect();
            let d = be.draft_block(name, gamma, &toks, &lens, &mut kv_d, &seeds).unwrap();
            let ps = be.target_score(gamma, &toks, &lens, &mut kv_t, &d.drafts).unwrap();
            let v = info.vocab_size;
            let mut sum = 0.0;
            let mut n = 0usize;
            for b in 0..info.batch {
                for j in 0..gamma {
                    let q: Vec<f64> = d.qs[(b * gamma + j) * v..(b * gamma + j + 1) * v]
                        .iter()
                        .map(|&x| x as f64)
                        .collect();
                    let p: Vec<f64> = ps
                        [(b * (gamma + 1) + j) * v..(b * (gamma + 1) + j + 1) * v]
                        .iter()
                        .map(|&x| x as f64)
                        .collect();
                    sum += dist::tv_distance(&p, &q);
                    n += 1;
                }
            }
            tv.insert(name, sum / n as f64);
        }
        // Structural ordering from the shared-prefix embeddings; allow a
        // hair of slack since it is measured on a finite sample.
        assert!(
            tv["xxs"] <= tv["xxxs"] + 0.02,
            "xxs should track the target at least as well as xxxs: {tv:?}"
        );
    }
}
