//! Execution backends: the device abstraction the engine layer runs on.
//!
//! The [`Backend`] trait is the contract extracted from the original
//! PJRT-only runtime (DESIGN.md §5): `prefill`, `spec_iter`,
//! `draft_block`, `target_score`, `baseline_step`, `kv_splice`, plus the
//! multi-draft tree pair `draft_tree` / `score_tree` (DESIGN.md §13; the
//! flat `draft_multi` / `target_score_multi` of §9 survive as deprecated
//! default-impl shims over it, §13.6)
//! — expressed over *plain host tensors* (`tokens (B, L) i32`,
//! `length (B,) i32`, flat `f32`/`i32` readbacks) plus an opaque per-model
//! KV-cache handle ([`Backend::Kv`]) that each backend represents however
//! it likes (device-resident buffers on PJRT, flat `Vec<f32>` on the
//! native CPU backend).  Engines ([`crate::engine`]), the coordinator, the
//! experiment harness and the benches are generic over `B: Backend` and
//! never name a concrete runtime type.
//!
//! Implementations:
//! * [`NativeBackend`] — pure-Rust CPU transformer forward pass mirroring
//!   `python/compile/model.py`; hermetic (seeded weights) or loaded from an
//!   artifact bundle.  Always available.
//! * `PjrtBackend` (behind the `pjrt` cargo feature) — the AOT HLO / PJRT
//!   path over [`crate::runtime::Runtime`].

pub mod kernels;
pub mod native;
pub mod paged;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod pool;
pub mod quant;

use std::path::PathBuf;
use std::sync::Arc;

use crate::draftset::{BranchPolicy, DraftSet, DraftTree};
use crate::verify::Algo;

pub use native::{NativeBackend, NativeKv};
pub use paged::{kvstats, KvLayout, PageAllocator};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use quant::Precision;

/// Everything one multi-draft speculation call needs (DESIGN.md §13.2):
/// the unified request the tree API takes in place of the deprecated
/// `draft_multi` positional-argument pile.  Borrowed views keep the hot
/// path allocation-free.
#[derive(Clone, Copy, Debug)]
pub struct DraftRequest<'a> {
    /// Drafter model name.
    pub drafter: &'a str,
    /// Draft block length per leaf path.
    pub gamma: usize,
    /// Path budget: the tree is capped at `k` leaves.
    pub k: usize,
    /// Where the drafter may merge coincident draws into shared nodes.
    pub policy: BranchPolicy,
    /// Sequence ring, row-major `(B, L)`.
    pub tokens: &'a [i32],
    /// Current per-row sequence lengths, `(B,)`.
    pub length: &'a [i32],
    /// Per-row sampling seeds, `(B,)` (trait-level determinism contract).
    pub seeds: &'a [i32],
    /// Draft-forward precision override; `None` = the backend's prepared
    /// default (what [`Backend::prepare`] installed).
    pub precision: Option<Precision>,
    /// Per-row draft-length override for ragged iterations
    /// ([`Backend::spec_iter_rows`], DESIGN.md §15): row `b` drafts
    /// `row_gammas[b] <= gamma` levels, with `gamma` staying the layout
    /// stride.  `None` = every row drafts `gamma` (the uniform case).
    pub row_gammas: Option<&'a [usize]>,
}

/// Static facts about a backend instance: the fixed serving shapes the
/// engine lays batches out against (what the PJRT path reads from
/// `manifest.json` and the native path takes from [`crate::models`]).
#[derive(Clone, Debug)]
pub struct BackendInfo {
    /// Backend family name ("native" | "pjrt") for logs and reports.
    pub name: String,
    /// Engine slot count `B` per batch.
    pub batch: usize,
    /// Sequence ring length `L` (prompt + generation + draft scratch).
    pub max_len: usize,
    /// Vocabulary size `V`.
    pub vocab_size: usize,
    /// Advertised draft lengths (the paper's sweep grid).
    pub gammas: Vec<usize>,
    /// Whether gammas outside [`BackendInfo::gammas`] also work (true for
    /// the native backend; PJRT only has programs for the exported grid).
    pub open_gamma: bool,
    /// Drafter model names servable next to the target.
    pub drafters: Vec<String>,
    /// Artifact bundle directory, when the backend was loaded from one
    /// (used to locate the canonical prompt sets; `None` ⇒ synthetic
    /// prompts, see [`crate::workload::Dataset::load_or_synthetic`]).
    pub artifacts_dir: Option<PathBuf>,
    /// Whether this backend serves scatter-paged physical KV
    /// ([`KvLayout::Paged`], DESIGN.md §16): splices alias refcounted
    /// pages instead of copying spans, and
    /// [`Backend::page_allocator`] returns the physical allocator the
    /// serving tier's `KvPool` should account against.  False for
    /// ring-contiguous layouts (the bit-identity oracle, and PJRT).
    pub paged_kv: bool,
}

impl BackendInfo {
    /// Can this backend run draft blocks of length `gamma`?  Even on
    /// open-gamma backends the block must leave decode room in the
    /// sequence ring: a prompt may occupy up to `L/2` positions
    /// ([`crate::engine`]'s layout guard), so gammas are capped at `L/4`.
    pub fn supports_gamma(&self, gamma: usize) -> bool {
        gamma >= 1
            && gamma <= self.max_len / 4
            && (self.open_gamma || self.gammas.contains(&gamma))
    }

    /// Does this backend serve the named drafter?
    pub fn has_drafter(&self, drafter: &str) -> bool {
        self.drafters.iter().any(|d| d == drafter)
    }
}

/// Output of one fused SpecDec iteration over the whole batch.
#[derive(Clone, Debug)]
pub struct SpecIterOut {
    /// Accepted draft tokens per row, `(B,)`.
    pub tau: Vec<i32>,
    /// Emitted tokens per row, row-major `(B, stride)`; entries past
    /// `tau[i]` are padding.
    pub emitted: Vec<i32>,
    /// Row stride of `emitted`: `gamma + 1` for a uniform iteration,
    /// `max(row gammas) + 1` for a ragged one
    /// ([`Backend::spec_iter_rows`]).  Consumers must slice
    /// `emitted[i*stride .. i*stride + tau[i] + 1]` rather than assume
    /// `cfg.gamma + 1`.
    pub stride: usize,
    /// Per-row done flag (EOS emitted within the accepted prefix, or the
    /// sequence ring is out of room), `(B,)`.
    pub done: Vec<i32>,
    /// Wall-clock microseconds the iteration spent in the draft forward
    /// pass (all paths), for the `draft_forward_us` metric — how the
    /// quantised-draft win shows up in `/metrics`.  0 = not instrumented
    /// (a fully fused device program cannot separate its draft phase).
    pub draft_us: u64,
    /// Wall-clock microseconds the iteration spent in the target scoring
    /// forward, for the `target_forward_us` metric — the denominator of
    /// every kernel-substrate win.  0 = not instrumented, as above.
    pub target_us: u64,
    /// Drafted tokens the target scored this iteration, summed over the
    /// batch (`B·gamma` single-path, `B·K·gamma` flat multipath, total
    /// tree nodes for `Algo::Tree` — the prefix-sharing FLOP win shows
    /// up as this number dropping at equal tau; `drafts_scored` metric).
    pub drafted: usize,
}

/// One row mapping of a batched admission prefill
/// ([`Backend::prefill_rows`]): splice the `len` leading cache positions
/// of scratch-batch row `src_row` over live-cache row `dst_slot`.
#[derive(Clone, Copy, Debug)]
pub struct RowSplice {
    /// Row of the prefilled scratch batch holding the new prompt.
    pub src_row: usize,
    /// Live-cache slot the prompt is being admitted into.
    pub dst_slot: usize,
    /// Prompt length: cache positions `0..len` are copied.
    pub len: usize,
}

/// One row mapping of a prefix-warm admission prefill
/// ([`Backend::prefill_rows_prefixed`], DESIGN.md §14.3): like
/// [`RowSplice`], plus an optional cached prompt-prefix KV whose first
/// `prefix.1` positions are already exactly what a cold prefill of this
/// row would write.  `tokens` still carries the **full** prompt for the
/// row, so a backend that cannot exploit the prefix may ignore it and
/// stay lossless by construction.
///
/// Not `derive`d `Clone`/`Copy` because a derive would bound `K` —
/// manual impls below keep the borrow copyable for any cache type.
#[derive(Debug)]
pub struct PrefixSplice<'a, K> {
    /// The plain splice mapping (scratch row → live slot, full length).
    pub splice: RowSplice,
    /// Cached prefix cache and its position count, when this admission
    /// longest-prefix-matched the shared-prefix cache; row 0 of the
    /// handed cache holds the prefix.  `None` = cold admission.
    pub prefix: Option<(&'a K, usize)>,
}

impl<K> Clone for PrefixSplice<'_, K> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<K> Copy for PrefixSplice<'_, K> {}

/// Output of one drafting call on the host-verify path.
#[derive(Clone, Debug)]
pub struct DraftOut {
    /// Draft tokens, row-major `(B, gamma)`.
    pub drafts: Vec<i32>,
    /// Drafter next-token distributions along the draft path, row-major
    /// `(B, gamma, V)`: `qs[b, j] = M_s(. | c, X^j)`.
    pub qs: Vec<f32>,
}

/// Output of one autoregressive baseline step.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Sampled next token per row, `(B,)`.
    pub next: Vec<i32>,
    /// Per-row done flag, `(B,)`.
    pub done: Vec<i32>,
}

/// An execution backend: everything the engine layer needs from a device.
///
/// Tensor layout contract (shared with `python/compile/model.py`):
/// * `tokens` is a row-major `(B, L)` i32 ring of the full sequence;
///   `length` holds the current per-row sequence length.  The *pending*
///   token `tokens[b][length[b] - 1]` has not been fed through the models.
/// * KV caches cover positions `0..length-2` plus junk above; every
///   operation consumes a contiguous run of positions starting at
///   `length - 1` and rewrites exactly those cache rows.
/// * Sampling randomness is seeded **per row**: `seeds (B,)` feeds one
///   independent stream per batch row, and row `b`'s outputs must be a
///   pure function of `(row b state, seeds[b])` — independent of the slot
///   index and of every other row.  That slot-independence is what makes
///   continuous batching lossless: a row admitted mid-decode via
///   [`Backend::kv_splice`] replays exactly the tokens it would have
///   produced in a fresh batch (DESIGN.md §7).  Identical seeds on
///   identical state must reproduce identical outputs.
pub trait Backend: Send + Sync + 'static {
    /// Opaque per-model KV-cache state carried across calls.  Only ever
    /// handed back to the backend that produced it.
    type Kv;

    /// Fixed shapes and capabilities of this backend instance.
    fn info(&self) -> &BackendInfo;

    /// The physical page allocator behind this backend's KV caches,
    /// when it serves scatter-paged KV ([`BackendInfo::paged_kv`],
    /// DESIGN.md §16.4).  The serving tier's `KvPool` accounts its
    /// admission budget directly against this object — one allocator,
    /// no parallel ledger.  `None` (the default, and every
    /// ring-contiguous layout) keeps the pool on its own identity
    /// free-list accounting.
    fn page_allocator(&self) -> Option<Arc<dyn PageAllocator>> {
        None
    }

    /// Warm-up hook, called by engine constructors with the configured
    /// algorithm, drafter and draft precision so a backend can pre-size
    /// internal scratch before the first iteration (the native backend
    /// pre-allocates its persistent `(B·K)`-row multipath KV scratch and
    /// pre-quantises the drafter's int8 twin here, DESIGN.md §10/§11).
    /// Must be cheap after the first call and idempotent.  Backends
    /// without a quantised path ignore `draft_precision` and serve the
    /// draft in fp32 — equally lossless, just slower (the PJRT quant path
    /// is a ROADMAP follow-up).  Default: no-op.
    fn prepare(&self, algo: Algo, drafter: &str, draft_precision: Precision) -> anyhow::Result<()> {
        let _ = (algo, drafter, draft_precision);
        Ok(())
    }

    /// Ingest a padded prompt batch through `model` ("target" or a drafter
    /// name), returning its KV cache with rows `0..L-1` written.
    fn prefill(&self, model: &str, tokens: &[i32], length: &[i32]) -> anyhow::Result<Self::Kv>;

    /// Batched admission prefill (DESIGN.md §11.3): ingest a padded
    /// prompt batch (same `(B, L)` shapes as [`Backend::prefill`]) and
    /// splice each mapping's `len` leading cache positions from scratch
    /// row `src_row` directly over live-cache slot `dst_slot`.  This is
    /// how the continuous batcher amortises admission cost — every
    /// admission available in one scheduler tick rides a **single**
    /// forward pass instead of one prefill per row.  Because batch rows
    /// are independent (per-row causal attention), the spliced rows are
    /// bit-identical to what a per-row `prefill` + [`Backend::kv_splice`]
    /// would produce (test-enforced).  The default implementation is
    /// exactly that fallback; the native backend overrides it to run the
    /// forward in a pooled scratch cache, so no KV allocation happens per
    /// admission.
    fn prefill_rows(
        &self,
        model: &str,
        tokens: &[i32],
        length: &[i32],
        dst: &mut Self::Kv,
        splices: &[RowSplice],
    ) -> anyhow::Result<()> {
        let kv = self.prefill(model, tokens, length)?;
        for s in splices {
            self.kv_splice(model, dst, s.dst_slot, &kv, s.src_row, s.len)?;
        }
        Ok(())
    }

    /// Prefix-warm batched admission prefill (DESIGN.md §14.3): like
    /// [`Backend::prefill_rows`], but each mapping may carry a cached
    /// prompt-prefix KV ([`PrefixSplice::prefix`]) whose positions are
    /// bit-identical to what a cold prefill of that row would write.  A
    /// backend that understands prefixes splices the cached positions in
    /// and forwards **only the suffix** (per-row causal attention means
    /// cache row `i` depends only on tokens `0..=i`, so the suffix rows
    /// come out bit-identical — test-enforced in `tests/serve_tier.rs`).
    /// The default implementation simply drops the prefixes and runs the
    /// full cold prefill — lossless by construction, since `tokens`
    /// always carries the complete prompt.
    fn prefill_rows_prefixed(
        &self,
        model: &str,
        tokens: &[i32],
        length: &[i32],
        dst: &mut Self::Kv,
        splices: &[PrefixSplice<'_, Self::Kv>],
    ) -> anyhow::Result<()> {
        let plain: Vec<RowSplice> = splices.iter().map(|s| s.splice).collect();
        self.prefill_rows(model, tokens, length, dst, &plain)
    }

    /// Extract one row's leading `len` cache positions into a standalone
    /// single-row cache — the prefix-cache ingest primitive (DESIGN.md
    /// §14.3): the serving tier prefills a shared prompt prefix once,
    /// extracts it, and `kv_splice`s it under every admission that
    /// longest-prefix-matches.  Backends may return a *compact* cache
    /// (ring = `len`), which is only ever a splice source, never
    /// forwarded.  The default implementation prefills an inert batch
    /// and splices the row over row 0 — full-ring, but correct.
    fn kv_extract(
        &self,
        model: &str,
        src: &Self::Kv,
        src_row: usize,
        len: usize,
    ) -> anyhow::Result<Self::Kv> {
        let info = self.info();
        let (b, l) = (info.batch, info.max_len);
        let tokens = vec![crate::models::vocab::PAD as i32; b * l];
        let length = vec![1i32; b];
        let mut kv = self.prefill(model, &tokens, &length)?;
        self.kv_splice(model, &mut kv, 0, src, src_row, len)?;
        Ok(kv)
    }

    /// One fused SpecDec iteration (paper Algorithm 3): draft `gamma`
    /// tokens with `drafter`, score with the target, verify with `algo`,
    /// and apply the accepted block — updating `tokens`/`length` in place
    /// and both KV caches.  `seeds (B,)` carries one sampling seed per
    /// row (see the trait docs' per-row determinism contract).  Only
    /// stateless algorithms (`algo.fused()`) are accepted; greedy
    /// verification needs the host-verify path.
    #[allow(clippy::too_many_arguments)]
    fn spec_iter(
        &self,
        algo: Algo,
        drafter: &str,
        gamma: usize,
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut Self::Kv,
        kv_drafter: &mut Self::Kv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut>;

    /// One fused SpecDec iteration with a **per-row** draft length
    /// (variable-gamma batching, DESIGN.md §15): row `i` drafts and
    /// verifies `gammas[i]` tokens, everything else exactly as
    /// [`Backend::spec_iter`].  Row `i`'s outputs must be bit-identical
    /// to what a uniform iteration at `gammas[i]` would produce for that
    /// row (rows are independent, so the per-row determinism contract
    /// carries over unchanged) — which is why the adaptive controller
    /// can never affect the committed distribution: each row runs the
    /// plain lossless iteration at its own depth.
    ///
    /// The default implementation runs the whole batch at
    /// `min(gammas)`: lossless (speculation depth never changes the
    /// committed distribution) but without per-row depth.  Backends
    /// with a ragged layout override it (the native backend runs true
    /// ragged rows).
    #[allow(clippy::too_many_arguments)]
    fn spec_iter_rows(
        &self,
        algo: Algo,
        drafter: &str,
        gammas: &[usize],
        tokens: &mut [i32],
        length: &mut [i32],
        kv_target: &mut Self::Kv,
        kv_drafter: &mut Self::Kv,
        seeds: &[i32],
    ) -> anyhow::Result<SpecIterOut> {
        let g = gammas.iter().copied().min().unwrap_or(1).max(1);
        self.spec_iter(algo, drafter, g, tokens, length, kv_target, kv_drafter, seeds)
    }

    /// `gamma` autoregressive draft steps from the pending token
    /// (host-verify path), drawing row `b`'s samples from `seeds[b]`.
    /// Advances `kv` by `gamma` cache rows; does not touch
    /// `tokens`/`length` (the host engine owns sequence state).
    #[allow(clippy::too_many_arguments)]
    fn draft_block(
        &self,
        drafter: &str,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &mut Self::Kv,
        seeds: &[i32],
    ) -> anyhow::Result<DraftOut>;

    /// Parallel target scoring of the `gamma + 1` draft prefixes
    /// (host-verify path).  Returns `ps` row-major `(B, gamma + 1, V)`
    /// with `ps[b, i] = M_b(. | c, X^i)`; advances `kv`.
    fn target_score(
        &self,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &mut Self::Kv,
        drafts: &[i32],
    ) -> anyhow::Result<Vec<f32>>;

    /// Draft a prefix-sharing token tree per batch row (DESIGN.md §13):
    /// `req.k` independent candidate streams of length `req.gamma`,
    /// with coincident draws merged into shared nodes wherever
    /// `req.policy` allows.  Path `p` of every row replays exactly the
    /// flat multipath stream for fold-in `p` of the row's seed (path 0
    /// = the single-path stream — the `k == 1` degradation), so a tree
    /// drafted under [`BranchPolicy::Disjoint`] flattens to precisely
    /// what the deprecated `draft_multi` returned.  The live cache is
    /// **not** advanced: drafting runs against a scratch copy of each
    /// row's shared prefix, and only the winning path's cache rows are
    /// committed by the fused `spec_iter`.
    fn draft_tree(&self, req: &DraftRequest, kv: &Self::Kv) -> anyhow::Result<DraftTree>;

    /// Target-score every node of a draft tree in one batched pass under
    /// the tree attention mask (each node attends to the shared prefix,
    /// its ancestors, and itself — DESIGN.md §13.2), filling each row's
    /// per-node `ps` and `ps_root`.  Shared nodes are scored **once**;
    /// that is the prefix-sharing FLOP win over the flat `(B·K)` layout.
    /// Leaves the live cache untouched.
    fn score_tree(
        &self,
        tree: &mut DraftTree,
        tokens: &[i32],
        length: &[i32],
        kv: &Self::Kv,
    ) -> anyhow::Result<()>;

    /// Deprecated flat multi-draft API (DESIGN.md §13.6), kept for one
    /// release as a shim over [`Backend::draft_tree`]: drafts a
    /// [`BranchPolicy::Disjoint`] tree at the backend's prepared
    /// precision and flattens it to the `(B·K)` layout — bit-identical
    /// to the pre-tree implementation (test-enforced).
    #[allow(clippy::too_many_arguments)]
    fn draft_multi(
        &self,
        drafter: &str,
        k: usize,
        gamma: usize,
        tokens: &[i32],
        length: &[i32],
        kv: &Self::Kv,
        seeds: &[i32],
    ) -> anyhow::Result<DraftSet> {
        let req = DraftRequest {
            drafter,
            gamma,
            k,
            policy: BranchPolicy::Disjoint,
            tokens,
            length,
            seeds,
            precision: None,
            row_gammas: None,
        };
        self.draft_tree(&req, kv)?.flatten()
    }

    /// Deprecated flat multi-draft scoring (DESIGN.md §13.6), kept for
    /// one release as a shim over [`Backend::score_tree`]: lifts the set
    /// into a degenerate disjoint tree, scores it, and copies the
    /// per-path `(B, K, gamma + 1, V)` distributions back — bit-identical
    /// to the pre-tree implementation (test-enforced).
    fn target_score_multi(
        &self,
        set: &mut DraftSet,
        tokens: &[i32],
        length: &[i32],
        kv: &Self::Kv,
    ) -> anyhow::Result<()> {
        let mut tree = DraftTree::from_flat(set);
        self.score_tree(&mut tree, tokens, length, kv)?;
        let scored = tree.flatten()?;
        set.set_ps(scored.ps)
    }

    /// One autoregressive target step (the paper's 1x wall-clock
    /// baseline): sample the next token per row and apply it, updating
    /// `tokens`/`length` in place and the target KV cache.
    fn baseline_step(
        &self,
        tokens: &mut [i32],
        length: &mut [i32],
        kv: &mut Self::Kv,
        seed: i32,
    ) -> anyhow::Result<StepOut>;

    /// Splice one prefilled row's KV cache into a live batch: copy cache
    /// positions `0..len` of `src`'s model-`model` cache row `src_row`
    /// over row `dst_slot` of `dst`.  This is the continuous batcher's
    /// refill primitive (DESIGN.md §7): a freshly prefilled prompt enters
    /// a freed slot of a mid-decode batch without disturbing any other
    /// row.  Both caches must belong to `model` and share serving shapes;
    /// positions `len..` of the destination row are left as-is (they are
    /// rewritten before ever being attended, per the layout contract
    /// above).
    ///
    /// Paged-KV backends ([`BackendInfo::paged_kv`]) implement this as
    /// a page-table operation: full pages inside `0..len` are aliased
    /// with a refcount bump (zero bytes moved), only the boundary
    /// partial page is physically copied, and a later append into a
    /// still-shared page copies-on-write (DESIGN.md §16.3).  The
    /// observable outcome must stay bit-identical to the contiguous
    /// span copy — including the destination's preserved `len..` tail.
    fn kv_splice(
        &self,
        model: &str,
        dst: &mut Self::Kv,
        dst_slot: usize,
        src: &Self::Kv,
        src_row: usize,
        len: usize,
    ) -> anyhow::Result<()>;

    /// Drain-boundary hook: called after a batch fully drains, and by the
    /// continuous batcher after any step in which a row completed (the
    /// step's outputs have been read back by then, so all outstanding
    /// uploads are complete).  The PJRT backend releases pinned host
    /// literals here; the native backend has nothing to do.
    fn end_batch(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_gamma_and_drafter_queries() {
        let info = BackendInfo {
            name: "test".into(),
            batch: 4,
            max_len: 96,
            vocab_size: 256,
            gammas: vec![4, 6, 8],
            open_gamma: false,
            drafters: vec!["xxs".into()],
            artifacts_dir: None,
            paged_kv: false,
        };
        assert!(info.supports_gamma(6));
        assert!(!info.supports_gamma(5));
        assert!(!info.supports_gamma(0));
        let mut open = info.clone();
        open.open_gamma = true;
        assert!(open.supports_gamma(5));
        assert!(!open.supports_gamma(0));
        // Even open-gamma backends cap at L/4 to leave decode room.
        assert!(open.supports_gamma(24));
        assert!(!open.supports_gamma(25));
        assert!(info.has_drafter("xxs"));
        assert!(!info.has_drafter("xl"));
    }
}
