//! CPU matmul/dot kernels for the native backend (DESIGN.md §10).
//!
//! Two implementations of the same `out (t, d_out) += x (t, d_in) @
//! w (d_in, d_out)` contract:
//!
//! * [`matmul_ref`] — the scalar reference: the plain broadcast-row
//!   triple loop, with **no** skip-zero branch (the old kernel skipped
//!   `x == 0.0` rows, which silently changed the FLOP count between
//!   weight initialisations and made scalar-vs-blocked comparisons
//!   apples-to-oranges).  This is the baseline the `native_fast` bench
//!   gate measures against.
//! * [`matmul_blocked`] — the fast path: tiled over `d_out` in
//!   [`TILE`]-wide register blocks so each output lane accumulates in a
//!   register across the whole `d_in` loop (the reference re-loads and
//!   re-stores the output row once per input element), with an
//!   `f32x8`-style unrolled inner loop the autovectorizer maps onto SIMD
//!   lanes.  Independent output lanes need no reduction reordering, so
//!   vectorisation requires no fast-math relaxation.
//!
//! Bit-identity contract: for a zero-filled `out`, both kernels add each
//! output element's partial products in the same (input-index) order, so
//! their results are bit-identical — `tests/native_fast.rs` enforces it.
//! That is what lets the backend switch kernels per
//! [`super::NativeBackend::with_reference_kernel`] without perturbing a
//! single sampled token.

/// Register-tile width of the blocked kernel: 16 f32 lanes (two AVX or
/// four SSE registers) held live across the `d_in` loop.
pub const TILE: usize = 16;

/// Scalar reference kernel: `out (t, d_out) += x (t, d_in) @ w (d_in,
/// d_out)`.  Loop order keeps `w` and `out` accesses sequential; every
/// input element contributes exactly one multiply-add per output lane
/// (no skip-zero branch).
pub fn matmul_ref(x: &[f32], w: &[f32], out: &mut [f32], t: usize, d_in: usize, d_out: usize) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        for (i, &xv) in xrow.iter().enumerate() {
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
}

/// Cache-blocked register-tiled kernel; bit-identical to [`matmul_ref`]
/// on a zero-filled `out` (see module docs).
pub fn matmul_blocked(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        let mut o0 = 0;
        while o0 + TILE <= d_out {
            let mut acc = [0.0f32; TILE];
            for (i, &xv) in xrow.iter().enumerate() {
                let wtile = &w[i * d_out + o0..i * d_out + o0 + TILE];
                for (a, &wv) in acc.iter_mut().zip(wtile.iter()) {
                    *a += xv * wv;
                }
            }
            for (o, &a) in orow[o0..o0 + TILE].iter_mut().zip(acc.iter()) {
                *o += a;
            }
            o0 += TILE;
        }
        if o0 < d_out {
            // Remainder lanes (d_out not a multiple of TILE): reference
            // order, still branch-free.
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &w[i * d_out + o0..(i + 1) * d_out];
                for (o, &wv) in orow[o0..].iter_mut().zip(wrow.iter()) {
                    *o += xv * wv;
                }
            }
        }
    }
}

/// Dot product with an 8-lane unrolled partial-sum accumulator.  Strict
/// IEEE reductions defeat the autovectorizer (reassociation changes
/// rounding), so the lanes are split manually; the final combine order is
/// fixed (tail, then lanes 0..8), keeping the result deterministic and
/// platform-independent for a given input.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for ((l, &va), &vb) in acc.iter_mut().zip(xa.iter()).zip(xb.iter()) {
            *l += va * vb;
        }
    }
    let mut sum = 0.0f32;
    for (&va, &vb) in ca.remainder().iter().zip(cb.remainder().iter()) {
        sum += va * vb;
    }
    for &l in &acc {
        sum += l;
    }
    sum
}

/// Int8-weight GEMM: `out (t, d_out) += (x (t, d_in) @ dequant(q) (d_in,
/// d_out))` where `dequant(q)[i][o] = q[i*d_out+o] as f32 * scale[o]`
/// (the per-output-column symmetric layout of
/// [`super::quant::QuantMatrix`]).  Mirrors [`matmul_blocked`]'s
/// register-tile structure — [`TILE`] output lanes accumulate the raw
/// `x · q` partial sums in registers across the whole `d_in` loop, and
/// the per-column scale is applied **once** per output element at the
/// end (factoring `scale[o]` out of the reduction), so the fp32 work per
/// element is one convert + one fma while the weight traffic is a
/// quarter of the fp32 kernel's.  Runs on the same `backend::pool`
/// row-parallel forwards as the fp32 kernels; like them it is a pure
/// function of its inputs, so results are independent of threading.
pub fn matmul_q8_acc(
    x: &[f32],
    q: &[i8],
    scale: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(q.len(), d_in * d_out);
    debug_assert_eq!(scale.len(), d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        let mut o0 = 0;
        while o0 + TILE <= d_out {
            let mut acc = [0.0f32; TILE];
            for (i, &xv) in xrow.iter().enumerate() {
                let qtile = &q[i * d_out + o0..i * d_out + o0 + TILE];
                for (a, &qv) in acc.iter_mut().zip(qtile.iter()) {
                    *a += xv * qv as f32;
                }
            }
            let stile = &scale[o0..o0 + TILE];
            for ((o, &a), &s) in orow[o0..o0 + TILE].iter_mut().zip(acc.iter()).zip(stile) {
                *o += a * s;
            }
            o0 += TILE;
        }
        if o0 < d_out {
            // Remainder lanes: same accumulate-then-scale order.
            let mut acc = [0.0f32; TILE];
            let rem = d_out - o0;
            for (i, &xv) in xrow.iter().enumerate() {
                let qrow = &q[i * d_out + o0..(i + 1) * d_out];
                for (a, &qv) in acc[..rem].iter_mut().zip(qrow.iter()) {
                    *a += xv * qv as f32;
                }
            }
            for ((o, &a), &s) in
                orow[o0..].iter_mut().zip(acc[..rem].iter()).zip(scale[o0..].iter())
            {
                *o += a * s;
            }
        }
    }
}

/// Int8 dot product against an fp32 vector, mirroring [`dot_f32`]'s
/// 8-lane unrolled structure (tail then lanes 0..8 combine order — same
/// determinism contract).  The caller multiplies the result by the row's
/// dequantisation scale (factored out of the reduction).
#[inline]
pub fn dot_q8(a: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cq = q.chunks_exact(8);
    for (xa, xq) in ca.by_ref().zip(cq.by_ref()) {
        for ((l, &va), &vq) in acc.iter_mut().zip(xa.iter()).zip(xq.iter()) {
            *l += va * vq as f32;
        }
    }
    let mut sum = 0.0f32;
    for (&va, &vq) in ca.remainder().iter().zip(cq.remainder().iter()) {
        sum += va * vq as f32;
    }
    for &l in &acc {
        sum += l;
    }
    sum
}

/// Which matmul kernel a forward pass runs with — the only thing the
/// backend's `reference_kernel` benchmarking switch toggles (everything
/// else in the forward is shared, so the `native_fast` bench isolates
/// exactly the kernel + threading + scratch delta).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatKernel {
    /// [`matmul_ref`] — scalar baseline for perf comparisons.
    Reference,
    /// [`matmul_blocked`] — the production fast path.
    Blocked,
}

impl MatKernel {
    /// `out (t, d_out) += x (t, d_in) @ w (d_in, d_out)`.
    #[inline]
    pub fn matmul_acc(
        self,
        x: &[f32],
        w: &[f32],
        out: &mut [f32],
        t: usize,
        d_in: usize,
        d_out: usize,
    ) {
        match self {
            MatKernel::Reference => matmul_ref(x, w, out, t, d_in, d_out),
            MatKernel::Blocked => matmul_blocked(x, w, out, t, d_in, d_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn blocked_matches_reference_bitwise() {
        let mut rng = Rng::new(0xb10c);
        for &(t, d_in, d_out) in
            &[(1usize, 32usize, 32usize), (5, 128, 512), (3, 64, 40), (2, 17, 23), (4, 96, 16)]
        {
            let x = rand_vec(&mut rng, t * d_in);
            let w = rand_vec(&mut rng, d_in * d_out);
            let mut a = vec![0.0f32; t * d_out];
            let mut b = vec![0.0f32; t * d_out];
            matmul_ref(&x, &w, &mut a, t, d_in, d_out);
            matmul_blocked(&x, &w, &mut b, t, d_in, d_out);
            assert_eq!(a, b, "kernels diverge at t={t} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn zero_inputs_contribute_nothing() {
        // The bugfixed contract: x == 0.0 rows multiply through instead of
        // branching, and the result is unchanged.
        let x = [0.0f32, 2.0, 0.0];
        let w = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0];
        let mut out = vec![0.0f32; 2];
        matmul_ref(&x, &w, &mut out, 1, 3, 2);
        assert_eq!(out, vec![4.0, 40.0]);
        let mut out_b = vec![0.0f32; 2];
        matmul_blocked(&x, &w, &mut out_b, 1, 3, 2);
        assert_eq!(out_b, vec![4.0, 40.0]);
    }

    #[test]
    fn q8_matmul_matches_scalar_dequantised_reference() {
        let mut rng = Rng::new(0x0b8);
        for &(t, d_in, d_out) in
            &[(1usize, 32usize, 32usize), (5, 64, 256), (3, 64, 40), (2, 17, 23)]
        {
            let x = rand_vec(&mut rng, t * d_in);
            let q: Vec<i8> =
                (0..d_in * d_out).map(|_| (rng.uniform() * 255.0 - 127.0) as i8).collect();
            let scale: Vec<f32> =
                (0..d_out).map(|_| (rng.uniform() * 0.02) as f32).collect();
            let mut got = vec![0.0f32; t * d_out];
            matmul_q8_acc(&x, &q, &scale, &mut got, t, d_in, d_out);
            // Scalar reference with identical accumulate-then-scale order.
            let mut want = vec![0.0f32; t * d_out];
            for ti in 0..t {
                for o in 0..d_out {
                    let mut acc = 0.0f32;
                    for i in 0..d_in {
                        acc += x[ti * d_in + i] * q[i * d_out + o] as f32;
                    }
                    want[ti * d_out + o] += acc * scale[o];
                }
            }
            for (g, w) in got.iter().zip(want.iter()) {
                assert!(
                    (g - w).abs() <= w.abs().max(1.0) * 1e-5,
                    "t={t} d_in={d_in} d_out={d_out}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn dot_q8_matches_naive_sum() {
        let mut rng = Rng::new(0x0d8);
        for n in [1usize, 7, 8, 9, 31, 64, 100] {
            let a = rand_vec(&mut rng, n);
            let q: Vec<i8> = (0..n).map(|_| (rng.uniform() * 255.0 - 127.0) as i8).collect();
            let got = dot_q8(&a, &q) as f64;
            let want: f64 = a.iter().zip(q.iter()).map(|(&x, &v)| (x as f64) * v as f64).sum();
            assert!((got - want).abs() < 1e-2, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_matches_naive_order_free_sum() {
        let mut rng = Rng::new(7);
        for n in [1usize, 7, 8, 9, 16, 31, 64, 100] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let got = dot_f32(&a, &b) as f64;
            let want: f64 = a.iter().zip(b.iter()).map(|(&x, &y)| (x * y) as f64).sum();
            assert!((got - want).abs() < 1e-4, "n={n}: {got} vs {want}");
        }
    }
}
