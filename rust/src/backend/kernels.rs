//! CPU matmul/dot kernels for the native backend (DESIGN.md §10).
//!
//! Two implementations of the same `out (t, d_out) += x (t, d_in) @
//! w (d_in, d_out)` contract:
//!
//! * [`matmul_ref`] — the scalar reference: the plain broadcast-row
//!   triple loop, with **no** skip-zero branch (the old kernel skipped
//!   `x == 0.0` rows, which silently changed the FLOP count between
//!   weight initialisations and made scalar-vs-blocked comparisons
//!   apples-to-oranges).  This is the baseline the `native_fast` bench
//!   gate measures against.
//! * [`matmul_blocked`] — the fast path: tiled over `d_out` in
//!   [`TILE`]-wide register blocks so each output lane accumulates in a
//!   register across the whole `d_in` loop (the reference re-loads and
//!   re-stores the output row once per input element), with an
//!   `f32x8`-style unrolled inner loop the autovectorizer maps onto SIMD
//!   lanes.  Independent output lanes need no reduction reordering, so
//!   vectorisation requires no fast-math relaxation.
//!
//! Bit-identity contract: for a zero-filled `out`, both kernels add each
//! output element's partial products in the same (input-index) order, so
//! their results are bit-identical — `tests/native_fast.rs` enforces it.
//! That is what lets the backend switch kernels per
//! [`super::NativeBackend::with_reference_kernel`] without perturbing a
//! single sampled token.

/// Register-tile width of the blocked kernel: 16 f32 lanes (two AVX or
/// four SSE registers) held live across the `d_in` loop.
pub const TILE: usize = 16;

/// Scalar reference kernel: `out (t, d_out) += x (t, d_in) @ w (d_in,
/// d_out)`.  Loop order keeps `w` and `out` accesses sequential; every
/// input element contributes exactly one multiply-add per output lane
/// (no skip-zero branch).
pub fn matmul_ref(x: &[f32], w: &[f32], out: &mut [f32], t: usize, d_in: usize, d_out: usize) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        for (i, &xv) in xrow.iter().enumerate() {
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
}

/// Cache-blocked register-tiled kernel; bit-identical to [`matmul_ref`]
/// on a zero-filled `out` (see module docs).
pub fn matmul_blocked(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        let mut o0 = 0;
        while o0 + TILE <= d_out {
            let mut acc = [0.0f32; TILE];
            for (i, &xv) in xrow.iter().enumerate() {
                let wtile = &w[i * d_out + o0..i * d_out + o0 + TILE];
                for (a, &wv) in acc.iter_mut().zip(wtile.iter()) {
                    *a += xv * wv;
                }
            }
            for (o, &a) in orow[o0..o0 + TILE].iter_mut().zip(acc.iter()) {
                *o += a;
            }
            o0 += TILE;
        }
        if o0 < d_out {
            // Remainder lanes (d_out not a multiple of TILE): reference
            // order, still branch-free.
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &w[i * d_out + o0..(i + 1) * d_out];
                for (o, &wv) in orow[o0..].iter_mut().zip(wrow.iter()) {
                    *o += xv * wv;
                }
            }
        }
    }
}

/// Dot product with an 8-lane unrolled partial-sum accumulator.  Strict
/// IEEE reductions defeat the autovectorizer (reassociation changes
/// rounding), so the lanes are split manually; the final combine order is
/// fixed (tail, then lanes 0..8), keeping the result deterministic and
/// platform-independent for a given input.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for ((l, &va), &vb) in acc.iter_mut().zip(xa.iter()).zip(xb.iter()) {
            *l += va * vb;
        }
    }
    let mut sum = 0.0f32;
    for (&va, &vb) in ca.remainder().iter().zip(cb.remainder().iter()) {
        sum += va * vb;
    }
    for &l in &acc {
        sum += l;
    }
    sum
}

/// Which matmul kernel a forward pass runs with — the only thing the
/// backend's `reference_kernel` benchmarking switch toggles (everything
/// else in the forward is shared, so the `native_fast` bench isolates
/// exactly the kernel + threading + scratch delta).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatKernel {
    /// [`matmul_ref`] — scalar baseline for perf comparisons.
    Reference,
    /// [`matmul_blocked`] — the production fast path.
    Blocked,
}

impl MatKernel {
    /// `out (t, d_out) += x (t, d_in) @ w (d_in, d_out)`.
    #[inline]
    pub fn matmul_acc(
        self,
        x: &[f32],
        w: &[f32],
        out: &mut [f32],
        t: usize,
        d_in: usize,
        d_out: usize,
    ) {
        match self {
            MatKernel::Reference => matmul_ref(x, w, out, t, d_in, d_out),
            MatKernel::Blocked => matmul_blocked(x, w, out, t, d_in, d_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn blocked_matches_reference_bitwise() {
        let mut rng = Rng::new(0xb10c);
        for &(t, d_in, d_out) in
            &[(1usize, 32usize, 32usize), (5, 128, 512), (3, 64, 40), (2, 17, 23), (4, 96, 16)]
        {
            let x = rand_vec(&mut rng, t * d_in);
            let w = rand_vec(&mut rng, d_in * d_out);
            let mut a = vec![0.0f32; t * d_out];
            let mut b = vec![0.0f32; t * d_out];
            matmul_ref(&x, &w, &mut a, t, d_in, d_out);
            matmul_blocked(&x, &w, &mut b, t, d_in, d_out);
            assert_eq!(a, b, "kernels diverge at t={t} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn zero_inputs_contribute_nothing() {
        // The bugfixed contract: x == 0.0 rows multiply through instead of
        // branching, and the result is unchanged.
        let x = [0.0f32, 2.0, 0.0];
        let w = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0];
        let mut out = vec![0.0f32; 2];
        matmul_ref(&x, &w, &mut out, 1, 3, 2);
        assert_eq!(out, vec![4.0, 40.0]);
        let mut out_b = vec![0.0f32; 2];
        matmul_blocked(&x, &w, &mut out_b, 1, 3, 2);
        assert_eq!(out_b, vec![4.0, 40.0]);
    }

    #[test]
    fn dot_matches_naive_order_free_sum() {
        let mut rng = Rng::new(7);
        for n in [1usize, 7, 8, 9, 16, 31, 64, 100] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let got = dot_f32(&a, &b) as f64;
            let want: f64 = a.iter().zip(b.iter()).map(|(&x, &y)| (x * y) as f64).sum();
            assert!((got - want).abs() < 1e-4, "n={n}: {got} vs {want}");
        }
    }
}
