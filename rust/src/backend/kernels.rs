//! CPU matmul/dot kernels for the native backend (DESIGN.md §10, §12).
//!
//! Three implementations of the same `out (t, d_out) += x (t, d_in) @
//! w (d_in, d_out)` contract:
//!
//! * [`matmul_ref`] — the scalar reference: the plain broadcast-row
//!   triple loop, with **no** skip-zero branch (the old kernel skipped
//!   `x == 0.0` rows, which silently changed the FLOP count between
//!   weight initialisations and made scalar-vs-blocked comparisons
//!   apples-to-oranges).  This is the baseline the `native_fast` bench
//!   gate measures against.
//! * [`matmul_blocked`] — tiled over `d_out` in [`TILE`]-wide register
//!   blocks so each output lane accumulates in a register across the
//!   whole `d_in` loop, with an `f32x8`-style unrolled inner loop the
//!   autovectorizer maps onto SIMD lanes.
//! * [`matmul_simd`] — explicit `std::arch` SIMD (AVX2 on x86_64, NEON
//!   on aarch64, a packed-scalar fallback elsewhere) over a tile-major
//!   [`PackedF32`] weight layout built once per model at
//!   `Backend::prepare` time, so the inner loop streams contiguous
//!   cache lines.
//!
//! Bit-identity contract: for a zero-filled `out`, all three kernels add
//! each output element's partial products in the same (input-index)
//! order, so their results are bit-identical — `tests/native_fast.rs`
//! enforces it, including on non-lane-multiple tail shapes.  The SIMD
//! kernels keep the contract by parallelising over *output lanes*: lane
//! `o` of an accumulator register replays exactly the scalar sequence
//! `acc += x[i] * w[i][o]` (separate IEEE multiply and add per element —
//! **never** FMA, whose single rounding would diverge), and the final
//! `out[o] += acc` is one add in both worlds.  That is what lets the
//! backend switch kernels per [`MatKernel`] without perturbing a single
//! sampled token.
//!
//! The int8 drafter path is different: [`matmul_q8_i32`] is a true
//! i8×i8→i32 integer GEMM (per-token-row activation quantisation, exact
//! integer accumulation, one fp32 rescale per output element at the
//! end).  Integer accumulation is associative, so the scalar reference
//! [`matmul_q8_i32_ref`] and every SIMD variant are bit-identical *by
//! construction* — the determinism contract for the quantised drafter
//! holds across ISAs and kernel choices (DESIGN.md §12.3).
//!
//! Kernel selection is resolved once per process: [`default_kernel`]
//! reads `SPECD_NATIVE_KERNEL` (`ref | blocked | simd`, default `simd`)
//! and [`active_isa`] probes the CPU, both `OnceLock`-cached.

use std::fmt;
use std::sync::OnceLock;

/// Register-tile width of the blocked and SIMD kernels: 16 f32 lanes
/// (two AVX2 registers, four NEON registers) held live across the
/// `d_in` loop.  Also the lane granularity of the tile-major packed
/// weight layouts ([`PackedF32`], [`pack_q8`]).
pub const TILE: usize = 16;

/// Scalar reference kernel: `out (t, d_out) += x (t, d_in) @ w (d_in,
/// d_out)`.  Loop order keeps `w` and `out` accesses sequential; every
/// input element contributes exactly one multiply-add per output lane
/// (no skip-zero branch).
pub fn matmul_ref(x: &[f32], w: &[f32], out: &mut [f32], t: usize, d_in: usize, d_out: usize) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        for (i, &xv) in xrow.iter().enumerate() {
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
}

/// Cache-blocked register-tiled kernel; bit-identical to [`matmul_ref`]
/// on a zero-filled `out` (see module docs).
pub fn matmul_blocked(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), t * d_out);
    for ti in 0..t {
        let xrow = &x[ti * d_in..(ti + 1) * d_in];
        let orow = &mut out[ti * d_out..(ti + 1) * d_out];
        let mut o0 = 0;
        while o0 + TILE <= d_out {
            let mut acc = [0.0f32; TILE];
            for (i, &xv) in xrow.iter().enumerate() {
                let wtile = &w[i * d_out + o0..i * d_out + o0 + TILE];
                for (a, &wv) in acc.iter_mut().zip(wtile.iter()) {
                    *a += xv * wv;
                }
            }
            for (o, &a) in orow[o0..o0 + TILE].iter_mut().zip(acc.iter()) {
                *o += a;
            }
            o0 += TILE;
        }
        if o0 < d_out {
            // Remainder lanes (d_out not a multiple of TILE): reference
            // order, still branch-free.
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &w[i * d_out + o0..(i + 1) * d_out];
                for (o, &wv) in orow[o0..].iter_mut().zip(wrow.iter()) {
                    *o += xv * wv;
                }
            }
        }
    }
}

/// Dot product with an 8-lane unrolled partial-sum accumulator.  Strict
/// IEEE reductions defeat the autovectorizer (reassociation changes
/// rounding), so the lanes are split manually; the final combine order is
/// fixed (tail, then lanes 0..8), keeping the result deterministic and
/// platform-independent for a given input.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for ((l, &va), &vb) in acc.iter_mut().zip(xa.iter()).zip(xb.iter()) {
            *l += va * vb;
        }
    }
    let mut sum = 0.0f32;
    for (&va, &vb) in ca.remainder().iter().zip(cb.remainder().iter()) {
        sum += va * vb;
    }
    for &l in &acc {
        sum += l;
    }
    sum
}

// ---------------------------------------------------------------------------
// Tile-major weight packing
// ---------------------------------------------------------------------------

/// A weight matrix repacked tile-major for the SIMD kernel: for each
/// [`TILE`]-wide output tile, all `d_in` input rows' tile slices are
/// stored contiguously — `data[(tile * d_in + i) * TILE + lane] =
/// w[i * d_out + tile * TILE + lane]` — so the inner `d_in` loop streams
/// one contiguous cache line per step instead of striding by `d_out`.
/// The tail tile's missing lanes are zero-padded; `x * 0.0` contributes
/// `+0.0` to a lane that is never written back, so padding cannot
/// perturb results.
#[derive(Clone, Debug)]
pub struct PackedF32 {
    pub d_in: usize,
    pub d_out: usize,
    /// `(d_out.div_ceil(TILE), d_in, TILE)` tile-major data.
    pub data: Vec<f32>,
}

impl PackedF32 {
    /// Pack a row-major `(d_in, d_out)` matrix (done once per model at
    /// `Backend::prepare` time, cached on the backend).
    pub fn pack(w: &[f32], d_in: usize, d_out: usize) -> PackedF32 {
        assert_eq!(w.len(), d_in * d_out, "weight shape mismatch");
        let ntiles = d_out.div_ceil(TILE);
        let mut data = vec![0.0f32; ntiles * d_in * TILE];
        for (i, row) in w.chunks_exact(d_out).enumerate() {
            for (o, &v) in row.iter().enumerate() {
                data[((o / TILE) * d_in + i) * TILE + o % TILE] = v;
            }
        }
        PackedF32 { d_in, d_out, data }
    }
}

/// Tile-major repack of a row-major `(d_in, d_out)` int8 matrix — the
/// integer twin of [`PackedF32::pack`], with the same layout and
/// zero-padded tail tile (`xq * 0` adds nothing to padded lanes).
pub fn pack_q8(q: &[i8], d_in: usize, d_out: usize) -> Vec<i8> {
    assert_eq!(q.len(), d_in * d_out, "weight shape mismatch");
    let ntiles = d_out.div_ceil(TILE);
    let mut data = vec![0i8; ntiles * d_in * TILE];
    for (i, row) in q.chunks_exact(d_out).enumerate() {
        for (o, &v) in row.iter().enumerate() {
            data[((o / TILE) * d_in + i) * TILE + o % TILE] = v;
        }
    }
    data
}

// ---------------------------------------------------------------------------
// f32 SIMD GEMM over the packed layout
// ---------------------------------------------------------------------------

/// Explicit-SIMD f32 GEMM over a [`PackedF32`] weight: `out (t, d_out)
/// += x (t, d_in) @ w (d_in, d_out)`.  Dispatches on [`active_isa`];
/// every variant (AVX2, NEON, packed-scalar) is bit-identical to
/// [`matmul_ref`] on a zero-filled `out` — see the module docs for the
/// output-lane argument.
pub fn matmul_simd(
    x: &[f32],
    pk: &PackedF32,
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(pk.d_in, d_in);
    debug_assert_eq!(pk.d_out, d_out);
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(out.len(), t * d_out);
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::matmul_f32_avx2(x, &pk.data, out, d_in, d_out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::matmul_f32_neon(x, &pk.data, out, d_in, d_out) },
        _ => matmul_f32_packed_scalar(x, &pk.data, out, d_in, d_out),
    }
}

/// Scalar walk of the packed layout — the [`matmul_simd`] fallback on
/// CPUs without AVX2.  Identical accumulation structure to
/// [`matmul_blocked`] (per-lane partial sums in input order, one final
/// add into `out`), hence bit-identical to [`matmul_ref`].
fn matmul_f32_packed_scalar(x: &[f32], data: &[f32], out: &mut [f32], d_in: usize, d_out: usize) {
    let ntiles = d_out.div_ceil(TILE);
    for (xrow, orow) in x.chunks_exact(d_in).zip(out.chunks_exact_mut(d_out)) {
        for tile in 0..ntiles {
            let base = tile * d_in * TILE;
            let mut acc = [0.0f32; TILE];
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &data[base + i * TILE..base + (i + 1) * TILE];
                for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
                    *a += xv * wv;
                }
            }
            let o0 = tile * TILE;
            let n = TILE.min(d_out - o0);
            for (o, &a) in orow[o0..o0 + n].iter_mut().zip(acc.iter()) {
                *o += a;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 integer GEMM (i8 x i8 -> i32, fp32 rescale at the end)
// ---------------------------------------------------------------------------

/// Reusable activation-quantisation scratch for the int8 GEMMs: the
/// quantised activation rows and their per-row scales.  Owned by the
/// caller (one per forward scratch) so the hot loop never allocates.
#[derive(Default, Debug)]
pub struct QuantScratch {
    pub xq: Vec<i8>,
    pub xs: Vec<f32>,
}

/// Symmetric per-row activation quantisation: writes `round(x / s)`
/// codes into `xq` and returns the scale `s = absmax / 127` (0 for an
/// all-zero row, with all-zero codes).  Deliberately scalar everywhere:
/// `f32::round` ties away from zero while SIMD rounding modes tie to
/// even, so a vectorised variant would break the cross-ISA bit-identity
/// of the integer GEMM at exact-half codes.
#[inline]
pub fn quantise_row_q8(x: &[f32], xq: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), xq.len());
    let m = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = m / 127.0;
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    for (q, &v) in xq.iter_mut().zip(x.iter()) {
        *q = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

fn quantise_rows(x: &[f32], t: usize, d_in: usize, scr: &mut QuantScratch) {
    scr.xq.resize(t * d_in, 0);
    scr.xs.resize(t, 0.0);
    for ((xrow, qrow), s) in
        x.chunks_exact(d_in).zip(scr.xq.chunks_exact_mut(d_in)).zip(scr.xs.iter_mut())
    {
        *s = quantise_row_q8(xrow, qrow);
    }
}

/// Integer-accumulate scalar reference for the int8 GEMM, over the
/// row-major [`super::quant::QuantMatrix`] layout: `out (t, d_out) +=
/// dequant(quantise_rows(x) @ q)`.  Each output element is an exact
/// i8×i8→i32 sum rescaled once by `sx * scale[o]`; no float enters the
/// accumulation, so every other implementation (packed scalar, AVX2,
/// NEON) is bit-identical to this one by construction.
#[allow(clippy::too_many_arguments)]
pub fn matmul_q8_i32_ref(
    x: &[f32],
    q: &[i8],
    scale: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
    scr: &mut QuantScratch,
) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(q.len(), d_in * d_out);
    debug_assert_eq!(scale.len(), d_out);
    debug_assert_eq!(out.len(), t * d_out);
    quantise_rows(x, t, d_in, scr);
    for ((xq, &sx), orow) in
        scr.xq.chunks_exact(d_in).zip(scr.xs.iter()).zip(out.chunks_exact_mut(d_out))
    {
        for (o, (ov, &sw)) in orow.iter_mut().zip(scale.iter()).enumerate() {
            let mut acc = 0i32;
            for (i, &xv) in xq.iter().enumerate() {
                acc += xv as i32 * q[i * d_out + o] as i32;
            }
            *ov += acc as f32 * (sx * sw);
        }
    }
}

/// True i8×i8→i32 integer GEMM over the tile-major packed layout of
/// [`pack_q8`]: quantises `x` per token row (shared scalar helper),
/// accumulates exact integer dot products, and rescales each output
/// element once (`acc as f32 * (sx * scale[o])`).  Dispatches on
/// [`active_isa`]; all variants are bit-identical to
/// [`matmul_q8_i32_ref`] because integer accumulation is order-free and
/// the rescale expression is shared.
#[allow(clippy::too_many_arguments)]
pub fn matmul_q8_i32(
    x: &[f32],
    qt: &[i8],
    scale: &[f32],
    out: &mut [f32],
    t: usize,
    d_in: usize,
    d_out: usize,
    scr: &mut QuantScratch,
) {
    debug_assert_eq!(x.len(), t * d_in);
    debug_assert_eq!(qt.len(), d_out.div_ceil(TILE) * d_in * TILE);
    debug_assert_eq!(scale.len(), d_out);
    debug_assert_eq!(out.len(), t * d_out);
    quantise_rows(x, t, d_in, scr);
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            x86::matmul_q8_avx2(&scr.xq, &scr.xs, qt, scale, out, d_in, d_out)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            arm::matmul_q8_neon(&scr.xq, &scr.xs, qt, scale, out, d_in, d_out)
        },
        _ => matmul_q8_packed_scalar(&scr.xq, &scr.xs, qt, scale, out, d_in, d_out),
    }
}

fn matmul_q8_packed_scalar(
    xq: &[i8],
    xs: &[f32],
    qt: &[i8],
    scale: &[f32],
    out: &mut [f32],
    d_in: usize,
    d_out: usize,
) {
    let ntiles = d_out.div_ceil(TILE);
    for ((xrow, &sx), orow) in
        xq.chunks_exact(d_in).zip(xs.iter()).zip(out.chunks_exact_mut(d_out))
    {
        for tile in 0..ntiles {
            let base = tile * d_in * TILE;
            let mut acc = [0i32; TILE];
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &qt[base + i * TILE..base + (i + 1) * TILE];
                for (a, &qv) in acc.iter_mut().zip(wrow.iter()) {
                    *a += xv as i32 * qv as i32;
                }
            }
            let o0 = tile * TILE;
            let n = TILE.min(d_out - o0);
            for ((ov, &a), &sw) in
                orow[o0..o0 + n].iter_mut().zip(acc.iter()).zip(scale[o0..o0 + n].iter())
            {
                *ov += a as f32 * (sx * sw);
            }
        }
    }
}

/// Exact i8×i8→i32 dot product, ISA-dispatched.  Integer accumulation
/// is order-free, so every variant returns the same integer regardless
/// of ISA or chunking — the unembedding path uses this unconditionally
/// (no kernel switch needed for determinism).
#[inline]
pub fn dot_q8_i32(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_q8_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::dot_q8_neon(a, b) },
        _ => dot_q8_i32_scalar(a, b),
    }
}

/// Scalar oracle for [`dot_q8_i32`] (also the non-SIMD fallback).
#[inline]
pub fn dot_q8_i32_scalar(a: &[i8], b: &[i8]) -> i32 {
    a.iter().zip(b.iter()).map(|(&x, &y)| x as i32 * y as i32).sum()
}

// ---------------------------------------------------------------------------
// AVX2 (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::TILE;
    use std::arch::x86_64::*;

    /// AVX2 f32 GEMM over the tile-major layout.  Two 8-lane registers
    /// cover one [`TILE`]; each lane replays the scalar `acc += x[i] *
    /// w[i][o]` sequence with separate multiply and add (no FMA), so the
    /// result is bit-identical to the scalar reference.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_f32_avx2(
        x: &[f32],
        data: &[f32],
        out: &mut [f32],
        d_in: usize,
        d_out: usize,
    ) {
        let ntiles = d_out.div_ceil(TILE);
        for (xrow, orow) in x.chunks_exact(d_in).zip(out.chunks_exact_mut(d_out)) {
            for tile in 0..ntiles {
                let base = tile * d_in * TILE;
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                let mut p = data.as_ptr().add(base);
                for &xv in xrow {
                    let xv8 = _mm256_set1_ps(xv);
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv8, _mm256_loadu_ps(p)));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv8, _mm256_loadu_ps(p.add(8))));
                    p = p.add(TILE);
                }
                let mut buf = [0.0f32; TILE];
                _mm256_storeu_ps(buf.as_mut_ptr(), acc0);
                _mm256_storeu_ps(buf.as_mut_ptr().add(8), acc1);
                let o0 = tile * TILE;
                let n = TILE.min(d_out - o0);
                for (o, &a) in orow[o0..o0 + n].iter_mut().zip(buf.iter()) {
                    *o += a;
                }
            }
        }
    }

    /// AVX2 i8×i8→i32 GEMM over the tile-major layout.  Weights widen
    /// i8→i16, multiply against the broadcast activation code with
    /// `mullo_epi16` (exact: |product| ≤ 127² = 16129 < 2¹⁵), widen to
    /// i32 and accumulate; the fp32 rescale per output element matches
    /// the scalar reference's expression exactly.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_q8_avx2(
        xq: &[i8],
        xs: &[f32],
        qt: &[i8],
        scale: &[f32],
        out: &mut [f32],
        d_in: usize,
        d_out: usize,
    ) {
        let ntiles = d_out.div_ceil(TILE);
        for ((xrow, &sx), orow) in
            xq.chunks_exact(d_in).zip(xs.iter()).zip(out.chunks_exact_mut(d_out))
        {
            for tile in 0..ntiles {
                let base = tile * d_in * TILE;
                let mut acc0 = _mm256_setzero_si256();
                let mut acc1 = _mm256_setzero_si256();
                let mut p = qt.as_ptr().add(base);
                for &xv in xrow {
                    let xv16 = _mm256_set1_epi16(xv as i16);
                    let w16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(p as *const __m128i));
                    let prod = _mm256_mullo_epi16(w16, xv16);
                    let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
                    let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
                    acc0 = _mm256_add_epi32(acc0, lo);
                    acc1 = _mm256_add_epi32(acc1, hi);
                    p = p.add(TILE);
                }
                let mut buf = [0i32; TILE];
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc0);
                _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc1);
                let o0 = tile * TILE;
                let n = TILE.min(d_out - o0);
                for ((ov, &a), &sw) in
                    orow[o0..o0 + n].iter_mut().zip(buf.iter()).zip(scale[o0..o0 + n].iter())
                {
                    *ov += a as f32 * (sx * sw);
                }
            }
        }
    }

    /// AVX2 i8×i8→i32 dot: widen both operands to i16 and `madd` (pairs
    /// of exact i16 products summed into i32 lanes).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_q8_avx2(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = _mm256_setzero_si256();
        let mut ca = a.chunks_exact(16);
        let mut cb = b.chunks_exact(16);
        for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
            let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(xa.as_ptr() as *const __m128i));
            let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(xb.as_ptr() as *const __m128i));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        }
        let mut buf = [0i32; 8];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc);
        let mut sum: i32 = buf.iter().sum();
        for (&va, &vb) in ca.remainder().iter().zip(cb.remainder().iter()) {
            sum += va as i32 * vb as i32;
        }
        sum
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::TILE;
    use std::arch::aarch64::*;

    /// NEON f32 GEMM over the tile-major layout.  Four 4-lane registers
    /// cover one [`TILE`]; `vmulq` + `vaddq` with separate roundings
    /// (never `vfmaq`) keeps each lane bit-identical to the scalar
    /// reference sequence.
    pub(super) unsafe fn matmul_f32_neon(
        x: &[f32],
        data: &[f32],
        out: &mut [f32],
        d_in: usize,
        d_out: usize,
    ) {
        let ntiles = d_out.div_ceil(TILE);
        for (xrow, orow) in x.chunks_exact(d_in).zip(out.chunks_exact_mut(d_out)) {
            for tile in 0..ntiles {
                let base = tile * d_in * TILE;
                let mut acc0 = vdupq_n_f32(0.0);
                let mut acc1 = vdupq_n_f32(0.0);
                let mut acc2 = vdupq_n_f32(0.0);
                let mut acc3 = vdupq_n_f32(0.0);
                let mut p = data.as_ptr().add(base);
                for &xv in xrow {
                    let xv4 = vdupq_n_f32(xv);
                    acc0 = vaddq_f32(acc0, vmulq_f32(xv4, vld1q_f32(p)));
                    acc1 = vaddq_f32(acc1, vmulq_f32(xv4, vld1q_f32(p.add(4))));
                    acc2 = vaddq_f32(acc2, vmulq_f32(xv4, vld1q_f32(p.add(8))));
                    acc3 = vaddq_f32(acc3, vmulq_f32(xv4, vld1q_f32(p.add(12))));
                    p = p.add(TILE);
                }
                let mut buf = [0.0f32; TILE];
                vst1q_f32(buf.as_mut_ptr(), acc0);
                vst1q_f32(buf.as_mut_ptr().add(4), acc1);
                vst1q_f32(buf.as_mut_ptr().add(8), acc2);
                vst1q_f32(buf.as_mut_ptr().add(12), acc3);
                let o0 = tile * TILE;
                let n = TILE.min(d_out - o0);
                for (o, &a) in orow[o0..o0 + n].iter_mut().zip(buf.iter()) {
                    *o += a;
                }
            }
        }
    }

    /// NEON i8×i8→i32 GEMM over the tile-major layout: widen weights
    /// i8→i16 and `vmlal` against the broadcast activation code into
    /// four i32x4 accumulators (exact).
    pub(super) unsafe fn matmul_q8_neon(
        xq: &[i8],
        xs: &[f32],
        qt: &[i8],
        scale: &[f32],
        out: &mut [f32],
        d_in: usize,
        d_out: usize,
    ) {
        let ntiles = d_out.div_ceil(TILE);
        for ((xrow, &sx), orow) in
            xq.chunks_exact(d_in).zip(xs.iter()).zip(out.chunks_exact_mut(d_out))
        {
            for tile in 0..ntiles {
                let base = tile * d_in * TILE;
                let mut acc = [vdupq_n_s32(0); 4];
                let mut p = qt.as_ptr().add(base);
                for &xv in xrow {
                    let xv4 = vdup_n_s16(xv as i16);
                    let w = vld1q_s8(p);
                    let wlo = vmovl_s8(vget_low_s8(w));
                    let whi = vmovl_s8(vget_high_s8(w));
                    acc[0] = vmlal_s16(acc[0], vget_low_s16(wlo), xv4);
                    acc[1] = vmlal_s16(acc[1], vget_high_s16(wlo), xv4);
                    acc[2] = vmlal_s16(acc[2], vget_low_s16(whi), xv4);
                    acc[3] = vmlal_s16(acc[3], vget_high_s16(whi), xv4);
                    p = p.add(TILE);
                }
                let mut buf = [0i32; TILE];
                for (k, &a) in acc.iter().enumerate() {
                    vst1q_s32(buf.as_mut_ptr().add(4 * k), a);
                }
                let o0 = tile * TILE;
                let n = TILE.min(d_out - o0);
                for ((ov, &a), &sw) in
                    orow[o0..o0 + n].iter_mut().zip(buf.iter()).zip(scale[o0..o0 + n].iter())
                {
                    *ov += a as f32 * (sx * sw);
                }
            }
        }
    }

    /// NEON i8×i8→i32 dot: `vmull_s8` to exact i16 products, pairwise
    /// add-accumulate into i32 lanes.
    pub(super) unsafe fn dot_q8_neon(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = vdupq_n_s32(0);
        let mut ca = a.chunks_exact(16);
        let mut cb = b.chunks_exact(16);
        for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
            let va = vld1q_s8(xa.as_ptr());
            let vb = vld1q_s8(xb.as_ptr());
            acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(va), vget_low_s8(vb)));
            acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(va), vget_high_s8(vb)));
        }
        let mut sum = vaddvq_s32(acc);
        for (&va, &vb) in ca.remainder().iter().zip(cb.remainder().iter()) {
            sum += va as i32 * vb as i32;
        }
        sum
    }
}

// ---------------------------------------------------------------------------
// Runtime ISA detection and kernel dispatch
// ---------------------------------------------------------------------------

/// The SIMD instruction set the process resolved at startup
/// ([`active_isa`]).  `Scalar` means [`matmul_simd`] runs the
/// packed-scalar fallback (still bit-identical, still cache-friendly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    Avx2,
    Neon,
    Scalar,
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        })
    }
}

/// CPU feature probe, resolved once per process (`OnceLock`).
pub fn active_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(detect_isa)
}

#[cfg(target_arch = "x86_64")]
fn detect_isa() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_isa() -> Isa {
    // NEON is baseline on aarch64 targets; no runtime probe needed.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_isa() -> Isa {
    Isa::Scalar
}

/// Which matmul kernel a forward pass runs with.  All three produce
/// bit-identical f32 results (module docs), so the choice is purely a
/// performance A/B — and all three route int8 drafts through the same
/// exact integer GEMM, so the quantised stream is kernel-invariant too.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatKernel {
    /// [`matmul_ref`] — scalar baseline for perf comparisons.
    Reference,
    /// [`matmul_blocked`] — register-tiled, autovectorized.
    Blocked,
    /// [`matmul_simd`] — explicit `std::arch` SIMD over packed tiles;
    /// the production default.
    Simd,
}

impl MatKernel {
    pub fn parse(s: &str) -> Option<MatKernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "ref" | "reference" | "scalar" => Some(MatKernel::Reference),
            "blocked" => Some(MatKernel::Blocked),
            "simd" => Some(MatKernel::Simd),
            _ => None,
        }
    }

    /// `out (t, d_out) += x (t, d_in) @ w (d_in, d_out)`.  `packed` is
    /// the tile-major twin of `w` when the caller has one; `Simd`
    /// without it falls back to the (bit-identical) blocked kernel
    /// rather than packing per call.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn matmul_acc(
        self,
        x: &[f32],
        w: &[f32],
        packed: Option<&PackedF32>,
        out: &mut [f32],
        t: usize,
        d_in: usize,
        d_out: usize,
    ) {
        match self {
            MatKernel::Reference => matmul_ref(x, w, out, t, d_in, d_out),
            MatKernel::Blocked => matmul_blocked(x, w, out, t, d_in, d_out),
            MatKernel::Simd => match packed {
                Some(pk) => matmul_simd(x, pk, out, t, d_in, d_out),
                None => matmul_blocked(x, w, out, t, d_in, d_out),
            },
        }
    }
}

impl fmt::Display for MatKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            MatKernel::Reference => "ref",
            MatKernel::Blocked => "blocked",
            MatKernel::Simd => "simd",
        })
    }
}

/// Process-wide default kernel: `SPECD_NATIVE_KERNEL` when set (and
/// valid), otherwise [`MatKernel::Simd`].  Resolved once (`OnceLock`);
/// an unparsable value falls back *loudly* (stderr) — a typo must not
/// silently flip an operator's intended A/B arm.
pub fn default_kernel() -> MatKernel {
    static KERNEL: OnceLock<MatKernel> = OnceLock::new();
    *KERNEL.get_or_init(|| match std::env::var("SPECD_NATIVE_KERNEL") {
        Ok(s) => MatKernel::parse(&s).unwrap_or_else(|| {
            eprintln!(
                "specd: ignoring invalid SPECD_NATIVE_KERNEL '{s}' (ref | blocked | simd); \
                 using simd"
            );
            MatKernel::Simd
        }),
        Err(_) => MatKernel::Simd,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect()
    }

    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 32, 32),
        (5, 128, 512),
        (3, 64, 40),
        (2, 17, 23),
        (4, 96, 16),
        (1, 1, 1),
        (2, 3, 15),
        (6, 9, 17),
        (2, 16, 31),
        (5, 7, 33),
    ];

    #[test]
    fn blocked_matches_reference_bitwise() {
        let mut rng = Rng::new(0xb10c);
        for &(t, d_in, d_out) in SHAPES {
            let x = rand_vec(&mut rng, t * d_in);
            let w = rand_vec(&mut rng, d_in * d_out);
            let mut a = vec![0.0f32; t * d_out];
            let mut b = vec![0.0f32; t * d_out];
            matmul_ref(&x, &w, &mut a, t, d_in, d_out);
            matmul_blocked(&x, &w, &mut b, t, d_in, d_out);
            assert_eq!(a, b, "kernels diverge at t={t} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn simd_matches_reference_bitwise_on_packed_tiles() {
        let mut rng = Rng::new(0x51d);
        for &(t, d_in, d_out) in SHAPES {
            let x = rand_vec(&mut rng, t * d_in);
            let w = rand_vec(&mut rng, d_in * d_out);
            let pk = PackedF32::pack(&w, d_in, d_out);
            let mut a = vec![0.0f32; t * d_out];
            let mut b = vec![0.0f32; t * d_out];
            matmul_ref(&x, &w, &mut a, t, d_in, d_out);
            matmul_simd(&x, &pk, &mut b, t, d_in, d_out);
            assert_eq!(
                a, b,
                "simd ({}) diverges at t={t} d_in={d_in} d_out={d_out}",
                active_isa()
            );
        }
    }

    #[test]
    fn packed_layout_roundtrips() {
        let mut rng = Rng::new(0x9ac);
        let (d_in, d_out) = (7, 37); // tail tile of 5 lanes
        let w = rand_vec(&mut rng, d_in * d_out);
        let pk = PackedF32::pack(&w, d_in, d_out);
        assert_eq!(pk.data.len(), d_out.div_ceil(TILE) * d_in * TILE);
        for i in 0..d_in {
            for o in 0..d_out {
                let v = pk.data[((o / TILE) * d_in + i) * TILE + o % TILE];
                assert_eq!(v, w[i * d_out + o], "({i},{o}) mispacked");
            }
        }
        // Padded tail lanes are zero.
        for i in 0..d_in {
            for lane in d_out % TILE..TILE {
                let v = pk.data[((d_out / TILE) * d_in + i) * TILE + lane];
                assert_eq!(v, 0.0, "pad lane ({i},{lane}) not zero");
            }
        }
    }

    #[test]
    fn q8_gemm_variants_are_bit_identical_and_match_integer_oracle() {
        let mut rng = Rng::new(0x0b8);
        for &(t, d_in, d_out) in SHAPES {
            let x = rand_vec(&mut rng, t * d_in);
            let q: Vec<i8> =
                (0..d_in * d_out).map(|_| (rng.uniform() * 255.0 - 127.0) as i8).collect();
            let scale: Vec<f32> = (0..d_out).map(|_| (rng.uniform() * 0.02) as f32).collect();
            let qt = pack_q8(&q, d_in, d_out);
            let mut scr = QuantScratch::default();
            let mut got_ref = vec![0.0f32; t * d_out];
            matmul_q8_i32_ref(&x, &q, &scale, &mut got_ref, t, d_in, d_out, &mut scr);
            let mut got_simd = vec![0.0f32; t * d_out];
            matmul_q8_i32(&x, &qt, &scale, &mut got_simd, t, d_in, d_out, &mut scr);
            assert_eq!(
                got_ref, got_simd,
                "int8 GEMM diverges ({}) at t={t} d_in={d_in} d_out={d_out}",
                active_isa()
            );
            // Independent integer-accumulate oracle: no float enters the
            // accumulation, the rescale expression is shared.
            let mut xq = vec![0i8; d_in];
            for ti in 0..t {
                let sx = quantise_row_q8(&x[ti * d_in..(ti + 1) * d_in], &mut xq);
                for o in 0..d_out {
                    let mut acc = 0i32;
                    for (i, &xv) in xq.iter().enumerate() {
                        acc += xv as i32 * q[i * d_out + o] as i32;
                    }
                    let want = acc as f32 * (sx * scale[o]);
                    assert_eq!(
                        got_ref[ti * d_out + o],
                        want,
                        "oracle mismatch at ti={ti} o={o}"
                    );
                }
            }
        }
    }

    #[test]
    fn dot_q8_i32_matches_scalar_oracle_exactly() {
        let mut rng = Rng::new(0x0d8);
        for n in [1usize, 7, 8, 15, 16, 17, 31, 64, 100] {
            let a: Vec<i8> = (0..n).map(|_| (rng.uniform() * 255.0 - 127.0) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.uniform() * 255.0 - 127.0) as i8).collect();
            assert_eq!(dot_q8_i32(&a, &b), dot_q8_i32_scalar(&a, &b), "n={n}");
        }
    }

    #[test]
    fn quantise_row_uses_full_code_range() {
        let mut rng = Rng::new(0x11e);
        let x = rand_vec(&mut rng, 33);
        let mut xq = vec![0i8; 33];
        let s = quantise_row_q8(&x, &mut xq);
        assert!(s > 0.0);
        assert_eq!(xq.iter().map(|q| q.unsigned_abs()).max().unwrap(), 127);
        // Roundtrip error bounded by half a step.
        for (&q, &v) in xq.iter().zip(x.iter()) {
            assert!((q as f32 * s - v).abs() <= s * 0.5 + 1e-7);
        }
        // All-zero rows quantise to scale 0, all-zero codes.
        let z = vec![0.0f32; 8];
        let mut zq = vec![1i8; 8];
        assert_eq!(quantise_row_q8(&z, &mut zq), 0.0);
        assert!(zq.iter().all(|&q| q == 0));
    }

    #[test]
    fn zero_inputs_contribute_nothing() {
        // The bugfixed contract: x == 0.0 rows multiply through instead of
        // branching, and the result is unchanged.
        let x = [0.0f32, 2.0, 0.0];
        let w = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0];
        let mut out = vec![0.0f32; 2];
        matmul_ref(&x, &w, &mut out, 1, 3, 2);
        assert_eq!(out, vec![4.0, 40.0]);
        let mut out_b = vec![0.0f32; 2];
        matmul_blocked(&x, &w, &mut out_b, 1, 3, 2);
        assert_eq!(out_b, vec![4.0, 40.0]);
        let pk = PackedF32::pack(&w, 3, 2);
        let mut out_s = vec![0.0f32; 2];
        matmul_simd(&x, &pk, &mut out_s, 1, 3, 2);
        assert_eq!(out_s, vec![4.0, 40.0]);
    }

    #[test]
    fn dot_matches_naive_order_free_sum() {
        let mut rng = Rng::new(7);
        for n in [1usize, 7, 8, 9, 16, 31, 64, 100] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let got = dot_f32(&a, &b) as f64;
            let want: f64 = a.iter().zip(b.iter()).map(|(&x, &y)| (x * y) as f64).sum();
            assert!((got - want).abs() < 1e-4, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn kernel_parse_and_display() {
        assert_eq!(MatKernel::parse("ref"), Some(MatKernel::Reference));
        assert_eq!(MatKernel::parse(" Reference "), Some(MatKernel::Reference));
        assert_eq!(MatKernel::parse("blocked"), Some(MatKernel::Blocked));
        assert_eq!(MatKernel::parse("SIMD"), Some(MatKernel::Simd));
        assert_eq!(MatKernel::parse("avx512"), None);
        assert_eq!(MatKernel::Reference.to_string(), "ref");
        assert_eq!(MatKernel::Blocked.to_string(), "blocked");
        assert_eq!(MatKernel::Simd.to_string(), "simd");
        // The ISA label renders (whatever this host resolves to).
        assert!(["avx2", "neon", "scalar"].contains(&active_isa().to_string().as_str()));
    }

    #[test]
    fn matkernel_dispatch_is_bit_identical_across_variants() {
        let mut rng = Rng::new(0xd15);
        let (t, d_in, d_out) = (3, 48, 50);
        let x = rand_vec(&mut rng, t * d_in);
        let w = rand_vec(&mut rng, d_in * d_out);
        let pk = PackedF32::pack(&w, d_in, d_out);
        let mut want = vec![0.0f32; t * d_out];
        matmul_ref(&x, &w, &mut want, t, d_in, d_out);
        for kernel in [MatKernel::Reference, MatKernel::Blocked, MatKernel::Simd] {
            let mut got = vec![0.0f32; t * d_out];
            kernel.matmul_acc(&x, &w, Some(&pk), &mut got, t, d_in, d_out);
            assert_eq!(got, want, "{kernel} diverges from reference");
            // Simd without packed tiles falls back, still bit-identical.
            let mut got2 = vec![0.0f32; t * d_out];
            kernel.matmul_acc(&x, &w, None, &mut got2, t, d_in, d_out);
            assert_eq!(got2, want, "{kernel} (unpacked) diverges from reference");
        }
    }
}
