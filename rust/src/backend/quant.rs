//! Int8 quantised-weight inference containers for the native backend
//! (DESIGN.md §11).
//!
//! Speculative decoding's lossless guarantee holds *regardless of draft
//! quality*: verification corrects any drift between drafter and target,
//! so the draft forward pass is the one place precision can be traded for
//! raw speed with zero change to the output distribution — provided the
//! drafter reports the distributions it actually sampled from.  The
//! quantised path therefore replaces the *whole* drafter (weights and the
//! tied embedding used for both lookup and unembedding) with one
//! well-defined int8 model: drafts are sampled from the int8 model's
//! softmax outputs and those same outputs are handed to verification as
//! `qs`, so the committed stream remains an exact target sample
//! (test-enforced, `tests/theorems.rs`).  The target model is **never**
//! quantised — its distributions define the output law, so any precision
//! loss there would change what "lossless" means (DESIGN.md §11.2).
//!
//! Scheme: per-output-row symmetric int8.  A weight matrix `w (d_in,
//! d_out)` stores `q[i][o] = round(w[i][o] / scale[o])` with one fp32
//! scale per *output unit* `o` (`scale[o] = max_i |w[i][o]| / 127`), so
//! each output lane's quantisation error is bounded by half a step of its
//! own dynamic range and the GEMM dequantises with a single multiply per
//! output element.  The tied embedding table quantises per *token row*
//! (the output unit of the unembedding dot).  Quantisation happens once
//! per model at first use and is cached on the backend, keyed by model
//! name — the same keyed-pool idiom as the persistent multipath scratch
//! (DESIGN.md §10.3).

use std::fmt;

use super::kernels;

/// Inference precision of the draft model's forward pass.  The knob is
/// threaded from `EngineConfig` ("draft_precision" / env
/// `SPECD_DRAFT_PRECISION`) through [`crate::backend::Backend::prepare`]
/// to the backend; backends without a quantised path (PJRT — ROADMAP
/// follow-up) serve the draft in fp32 either way, which is equally
/// lossless.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full fp32 drafter — bit-identical to the pre-quantisation stream.
    Fp32,
    /// Int8 quantised drafter weights with per-token-row activation
    /// quantisation and exact i8×i8→i32 accumulation (DESIGN.md §12.3)
    /// — the default fast path on the native backend.
    #[default]
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "float32" => Some(Precision::Fp32),
            "int8" | "i8" | "q8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Launch-time default: `SPECD_DRAFT_PRECISION` when set (and valid),
    /// otherwise int8 — the quantised draft path is the default because
    /// it cannot change the committed-token distribution (module docs).
    /// An unparsable value falls back to the default *loudly* (stderr):
    /// this is a `Default` impl's data source, so it cannot error like
    /// the config-file path does, but a typo must not silently flip an
    /// operator's intended precision.
    pub fn from_env_or_default() -> Precision {
        match std::env::var("SPECD_DRAFT_PRECISION") {
            Ok(s) => Precision::parse(&s).unwrap_or_else(|| {
                eprintln!(
                    "specd: ignoring invalid SPECD_DRAFT_PRECISION '{s}' (int8 | fp32); \
                     using {}",
                    Precision::default()
                );
                Precision::default()
            }),
            Err(_) => Precision::default(),
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
        })
    }
}

/// An int8 weight matrix `(d_in, d_out)` row-major with one fp32 scale
/// per output column: `w[i][o] ~= q[i*d_out + o] as f32 * scale[o]`.
///
/// Carries two layouts of the same codes: `q` row-major (the
/// reference-kernel GEMM and the tests index it directly) and `qt`
/// tile-major ([`kernels::pack_q8`]) for the SIMD integer GEMM.  Both
/// are built once at quantisation time, so `Backend::prepare`'s twin
/// pre-build covers the packing too.
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    pub d_in: usize,
    pub d_out: usize,
    /// Row-major `(d_in, d_out)` quantised weights.
    pub q: Vec<i8>,
    /// Tile-major twin of `q` (see [`kernels::pack_q8`]), zero-padded to
    /// a whole number of [`kernels::TILE`]-wide output tiles.
    pub qt: Vec<i8>,
    /// Per-output-column dequantisation scales, `(d_out,)`.
    pub scale: Vec<f32>,
}

impl QuantMatrix {
    /// Symmetric per-output-column quantisation of a row-major `(d_in,
    /// d_out)` fp32 matrix.  An all-zero column gets scale 0 (and all-zero
    /// codes), so dequantisation reproduces it exactly.
    pub fn quantise(w: &[f32], d_in: usize, d_out: usize) -> QuantMatrix {
        assert_eq!(w.len(), d_in * d_out, "weight shape mismatch");
        let mut absmax = vec![0.0f32; d_out];
        for row in w.chunks_exact(d_out) {
            for (m, &v) in absmax.iter_mut().zip(row.iter()) {
                *m = m.max(v.abs());
            }
        }
        let scale: Vec<f32> = absmax.iter().map(|&m| m / 127.0).collect();
        let inv: Vec<f32> =
            scale.iter().map(|&s| if s > 0.0 { 1.0 / s } else { 0.0 }).collect();
        let mut q = Vec::with_capacity(d_in * d_out);
        for row in w.chunks_exact(d_out) {
            for (o, &v) in row.iter().enumerate() {
                q.push((v * inv[o]).round().clamp(-127.0, 127.0) as i8);
            }
        }
        let qt = kernels::pack_q8(&q, d_in, d_out);
        QuantMatrix { d_in, d_out, q, qt, scale }
    }

    /// Dequantised element (tests / error analysis).
    pub fn dequant(&self, i: usize, o: usize) -> f32 {
        self.q[i * self.d_out + o] as f32 * self.scale[o]
    }

    /// Worst-case absolute dequantisation error of column `o`: half a
    /// quantisation step.
    pub fn step(&self, o: usize) -> f32 {
        self.scale[o] * 0.5
    }
}

/// An int8 table of `rows` vectors of width `d` with one fp32 scale per
/// *row* — the tied embedding layout, where a token row is both a lookup
/// vector and an unembedding output unit.
#[derive(Clone, Debug)]
pub struct QuantRows {
    pub rows: usize,
    pub d: usize,
    /// Row-major `(rows, d)` quantised table.
    pub q: Vec<i8>,
    /// Per-row dequantisation scales, `(rows,)`.
    pub scale: Vec<f32>,
}

impl QuantRows {
    /// Symmetric per-row quantisation of a row-major `(rows, d)` table.
    pub fn quantise(w: &[f32], rows: usize, d: usize) -> QuantRows {
        assert_eq!(w.len(), rows * d, "table shape mismatch");
        let mut q = Vec::with_capacity(rows * d);
        let mut scale = Vec::with_capacity(rows);
        for row in w.chunks_exact(d) {
            let m = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let s = m / 127.0;
            let inv = if s > 0.0 { 1.0 / s } else { 0.0 };
            scale.push(s);
            q.extend(row.iter().map(|&v| (v * inv).round().clamp(-127.0, 127.0) as i8));
        }
        QuantRows { rows, d, q, scale }
    }

    /// Quantised row `r` and its scale.
    #[inline]
    pub fn row(&self, r: usize) -> (&[i8], f32) {
        (&self.q[r * self.d..(r + 1) * self.d], self.scale[r])
    }
}

/// One transformer block's quantised weights.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    pub wq: QuantMatrix,
    pub wk: QuantMatrix,
    pub wv: QuantMatrix,
    pub wo: QuantMatrix,
    pub w1: QuantMatrix,
    pub w2: QuantMatrix,
}

/// A complete quantised model twin: the int8 weights the drafter forward
/// runs with under [`Precision::Int8`].  Layer norms, the position table
/// and all activations stay fp32 (they are tiny or per-token); see the
/// module docs for why this is still one well-defined model.
#[derive(Clone, Debug)]
pub struct QuantModel {
    pub embed: QuantRows,
    pub layers: Vec<QuantLayer>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Rng;

    fn rand_mat(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| ((rng.uniform() * 2.0 - 1.0) * scale) as f32).collect()
    }

    #[test]
    fn precision_parse_and_display() {
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("FP32"), Some(Precision::Fp32));
        assert_eq!(Precision::parse(" f32 "), Some(Precision::Fp32));
        assert_eq!(Precision::parse("bf16"), None);
        assert_eq!(Precision::Int8.to_string(), "int8");
        assert_eq!(Precision::Fp32.to_string(), "fp32");
        assert_eq!(Precision::default(), Precision::Int8);
    }

    #[test]
    fn matrix_roundtrip_error_is_bounded_per_column() {
        let mut rng = Rng::new(0x9a7);
        let (d_in, d_out) = (37, 23);
        let w = rand_mat(&mut rng, d_in * d_out, 0.8);
        let qm = QuantMatrix::quantise(&w, d_in, d_out);
        for i in 0..d_in {
            for o in 0..d_out {
                let err = (qm.dequant(i, o) - w[i * d_out + o]).abs();
                assert!(
                    err <= qm.step(o) + 1e-7,
                    "({i},{o}): err {err} > step {}",
                    qm.step(o)
                );
            }
        }
        // Codes use the full range: every column's absmax maps to ±127.
        for o in 0..d_out {
            let m = (0..d_in).map(|i| qm.q[i * d_out + o].unsigned_abs()).max().unwrap();
            assert_eq!(m, 127, "column {o} does not reach full code range");
        }
    }

    #[test]
    fn packed_twin_matches_row_major_codes() {
        let mut rng = Rng::new(0x7e1);
        let (d_in, d_out) = (9, 21); // tail tile of 5 lanes
        let w = rand_mat(&mut rng, d_in * d_out, 0.6);
        let qm = QuantMatrix::quantise(&w, d_in, d_out);
        assert_eq!(qm.qt, kernels::pack_q8(&qm.q, d_in, d_out));
        assert_eq!(qm.qt.len(), d_out.div_ceil(kernels::TILE) * d_in * kernels::TILE);
    }

    #[test]
    fn zero_column_survives_quantisation() {
        let w = vec![0.0f32, 1.0, 0.0, -2.0]; // (2, 2): column 0 all-zero
        let qm = QuantMatrix::quantise(&w, 2, 2);
        assert_eq!(qm.scale[0], 0.0);
        assert_eq!(qm.dequant(0, 0), 0.0);
        assert_eq!(qm.dequant(1, 0), 0.0);
        assert!((qm.dequant(1, 1) - -2.0).abs() < 0.02);
    }

    #[test]
    fn rows_roundtrip_error_is_bounded_per_row() {
        let mut rng = Rng::new(0x10e);
        let (rows, d) = (19, 31);
        let w = rand_mat(&mut rng, rows * d, 0.5);
        let qr = QuantRows::quantise(&w, rows, d);
        for r in 0..rows {
            let (q, s) = qr.row(r);
            for j in 0..d {
                let err = (q[j] as f32 * s - w[r * d + j]).abs();
                assert!(err <= s * 0.5 + 1e-7, "row {r} col {j}: err {err}");
            }
        }
    }
}
