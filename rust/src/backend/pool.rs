//! A small fixed fork-join thread pool for the native backend's
//! batch-parallel forward (DESIGN.md §10).
//!
//! Workers are spawned once (lazily, on the backend's first parallel
//! forward) and live for the backend's lifetime, so the per-forward cost
//! is one queue push + one condvar wake per job instead of a thread
//! spawn (`forward_block` runs
//! `gamma + 2` times per SpecDec iteration — spawn latency would rival
//! the compute at these model sizes).  [`ThreadPool::scope`] provides the
//! fork-join shape: the caller submits one job per worker chunk, runs the
//! first job on its own thread, and blocks until a completion latch
//! counts every submitted job done — which is also what makes handing
//! the pool borrowed (non-`'static`) closures sound, see the safety
//! comment in `scope`.
//!
//! Determinism contract: the pool only ever carries *row-disjoint* jobs
//! (each job owns mutable slices of distinct batch rows), every job's
//! arithmetic is a pure function of its inputs, and no job draws
//! randomness.  Scheduling order therefore cannot affect any output bit:
//! `threads = N` is bit-identical to `threads = 1` (test-enforced by
//! `tests/native_fast.rs`).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A borrowed fork-join job: may capture references to the caller's
/// stack, which [`ThreadPool::scope`]'s latch keeps alive until the job
/// has finished.
pub type ScopedJob<'a> = Box<dyn FnOnce() + Send + 'a>;

/// Type-erased job as stored on the shared queue.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Queue state shared between the submitting thread and the workers.
struct Shared {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Completion latch for one `scope` call.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    /// Set when any worker-run job panicked; `scope` re-raises it on the
    /// calling thread so a failure is never silently swallowed.
    panicked: AtomicBool,
}

/// Decrements the latch when dropped, so a panicking job still releases
/// the waiting caller instead of deadlocking it.
struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        let mut rem = self.0.remaining.lock().unwrap_or_else(|e| e.into_inner());
        *rem -= 1;
        if *rem == 0 {
            self.0.done.notify_all();
        }
    }
}

/// Blocks until the latch reaches zero — **in `Drop`**, so the wait also
/// happens while the calling thread is unwinding from a panic in its own
/// job.  That wait is what makes handing the workers stack-borrowing
/// (`'a`-erased) closures sound: `scope`'s frame cannot be torn down, on
/// any path, before every queued job has finished with its borrows.
struct WaitLatch<'a>(&'a Latch);

impl Drop for WaitLatch<'_> {
    fn drop(&mut self) {
        let mut rem = self.0.remaining.lock().unwrap_or_else(|e| e.into_inner());
        while *rem > 0 {
            rem = self.0.done.wait(rem).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// The pool: `threads - 1` persistent workers plus the calling thread.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// A pool that runs `scope` jobs across `threads` threads in total
    /// (the caller participates, so `threads - 1` workers are spawned;
    /// `threads <= 1` spawns none and `scope` degenerates to a plain
    /// sequential loop).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Total thread count (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run every job to completion, farming all but the first out to the
    /// workers while the caller runs the first itself.  Returns (or
    /// unwinds) only once every job has finished, which is what lets
    /// jobs borrow from the caller's stack; a panic in any job is
    /// re-raised on the calling thread after the whole scope has
    /// drained, never swallowed.
    pub fn scope<'a>(&self, mut jobs: Vec<ScopedJob<'a>>) {
        if jobs.is_empty() {
            return;
        }
        let mine = jobs.remove(0);
        if self.workers.is_empty() || jobs.is_empty() {
            mine();
            for job in jobs {
                job();
            }
            return;
        }
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            for job in jobs {
                // SAFETY: the `WaitLatch` guard below blocks — in Drop,
                // so on the panic path too — until the latch has counted
                // this job complete (its own guard decrements even on
                // unwind), so the erased borrow never outlives `'a`.
                let job: Job = unsafe {
                    std::mem::transmute::<ScopedJob<'a>, Box<dyn FnOnce() + Send + 'static>>(job)
                };
                let latch = latch.clone();
                st.jobs.push_back(Box::new(move || {
                    let _guard = LatchGuard(latch.clone());
                    // Keep the worker alive and the failure visible: the
                    // panic is recorded and re-raised by the caller.
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        latch.panicked.store(true, Ordering::Release);
                    }
                }));
            }
            self.shared.ready.notify_all();
        }
        let wait = WaitLatch(&latch);
        mine();
        drop(wait);
        if latch.panicked.load(Ordering::Acquire) {
            panic!("native thread-pool job panicked (re-raised on the calling thread)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.closed = true;
        }
        self.shared.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    break Some(job);
                }
                if st.closed {
                    break None;
                }
                st = shared.ready.wait(st).unwrap();
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_every_job_and_supports_borrows() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.threads(), 4);
        let mut data = vec![0usize; 16];
        {
            let jobs: Vec<ScopedJob> = data
                .chunks_mut(4)
                .enumerate()
                .map(|(i, chunk)| {
                    let job: ScopedJob = Box::new(move || {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = i * 4 + j + 1;
                        }
                    });
                    job
                })
                .collect();
            pool.scope(jobs);
        }
        let want: Vec<usize> = (1..=16).collect();
        assert_eq!(data, want);
    }

    #[test]
    fn single_threaded_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob> = (0..3)
            .map(|_| {
                let job: ScopedJob = Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn worker_panic_is_reraised_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let boom = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<ScopedJob> = (0..2)
                .map(|i| {
                    let job: ScopedJob = Box::new(move || {
                        if i == 1 {
                            panic!("boom");
                        }
                    });
                    job
                })
                .collect();
            pool.scope(jobs);
        }));
        assert!(boom.is_err(), "worker panic must re-raise on the caller");
        // The worker caught the unwind and still serves later scopes.
        let hits = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob> = (0..2)
            .map(|_| {
                let hits = &hits;
                let job: ScopedJob = Box::new(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn repeated_scopes_reuse_the_workers() {
        let pool = ThreadPool::new(3);
        for round in 0..50usize {
            let counter = AtomicUsize::new(0);
            let jobs: Vec<ScopedJob> = (0..5)
                .map(|i| {
                    let counter = &counter;
                    let job: ScopedJob = Box::new(move || {
                        counter.fetch_add(i + 1, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            pool.scope(jobs);
            assert_eq!(counter.load(Ordering::Relaxed), 15, "round {round}");
        }
    }
}
