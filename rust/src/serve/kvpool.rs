//! Paged KV accounting for the serving tier (DESIGN.md §14.2).
//!
//! [`KvPool`] is a fixed-size page arena with a free-list allocator:
//! every admitted row leases the pages covering its worst-case position
//! footprint (prompt + generation budget + draft scratch) before it may
//! enter a replica's slot table, and every cached prompt prefix
//! ([`crate::serve::PrefixCache`]) leases the pages covering its
//! positions.  Slot capacity is therefore bounded by *memory pages*, not
//! only by the compile-time batch shape: when the pool is sized below
//! `replicas · B` full rows, replicas admit until pages run out and
//! defer the rest (never panic, never queue unboundedly).
//!
//! A [`PageLease`]'s page-id vector is the row's page chain.  The
//! physical `NativeKv` storage stays ring-contiguous per row (one
//! `chunks_mut` slice per row is what makes the forward pass's safe row
//! parallelism work, DESIGN.md §10), so the chain is an identity-mapped
//! accounting view — the compact per-prefix caches
//! ([`crate::backend::Backend::kv_extract`]) are where paging actually
//! shrinks resident KV memory.

use std::sync::{Arc, Mutex};

/// Shared page arena: cheap-to-clone handle over the free list.
#[derive(Debug, Clone)]
pub struct KvPool {
    inner: Arc<PoolInner>,
}

#[derive(Debug)]
struct PoolInner {
    page_size: usize,
    total: usize,
    free: Mutex<Vec<u32>>,
}

impl KvPool {
    /// A pool of `total_pages` pages, each covering `page_size` KV
    /// positions (both models' caches for those positions count as one
    /// page — the pool meters *positions*, the unit admission and prefix
    /// caching both deal in).
    pub fn new(total_pages: usize, page_size: usize) -> Self {
        let total = total_pages.max(1);
        KvPool {
            inner: Arc::new(PoolInner {
                page_size: page_size.max(1),
                total,
                free: Mutex::new((0..total as u32).rev().collect()),
            }),
        }
    }

    pub fn page_size(&self) -> usize {
        self.inner.page_size
    }

    pub fn total_pages(&self) -> usize {
        self.inner.total
    }

    pub fn pages_free(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }

    pub fn pages_used(&self) -> usize {
        self.inner.total - self.pages_free()
    }

    /// Pages needed to cover `positions` KV positions (ceiling; at least
    /// one page — a row always occupies storage).
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.inner.page_size)
    }

    /// Try to lease `pages` pages; `None` when the free list is short —
    /// the caller's cue to evict idle prefixes, defer the admission, or
    /// shed.  Never blocks and never over-allocates.
    pub fn try_lease(&self, pages: usize) -> Option<PageLease> {
        let mut free = self.inner.free.lock().unwrap();
        if free.len() < pages {
            return None;
        }
        let at = free.len() - pages;
        let taken = free.split_off(at);
        Some(PageLease { inner: Arc::clone(&self.inner), pages: taken })
    }
}

/// An owned run of pages: the page chain of one admitted row or one
/// cached prefix.  Pages return to the free list on drop, so page
/// lifetime is exactly the lifetime of whatever holds the lease (the
/// slot's bookkeeping entry, or the cache entry's `Arc`).
#[derive(Debug)]
pub struct PageLease {
    inner: Arc<PoolInner>,
    pages: Vec<u32>,
}

impl PageLease {
    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    /// The leased page ids — the row's page chain.
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }
}

impl Drop for PageLease {
    fn drop(&mut self) {
        self.inner.free.lock().unwrap().append(&mut self.pages);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        let pool = KvPool::new(8, 16);
        assert_eq!(pool.pages_for(0), 1);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(16), 1);
        assert_eq!(pool.pages_for(17), 2);
        assert_eq!(pool.pages_for(96), 6);
    }

    #[test]
    fn lease_exhaustion_and_return_on_drop() {
        let pool = KvPool::new(4, 16);
        assert_eq!((pool.total_pages(), pool.pages_free(), pool.pages_used()), (4, 4, 0));
        let a = pool.try_lease(3).expect("3 of 4 pages");
        assert_eq!((pool.pages_free(), pool.pages_used()), (1, 3));
        assert!(pool.try_lease(2).is_none(), "only 1 page left");
        let b = pool.try_lease(1).expect("last page");
        assert_eq!(pool.pages_free(), 0);
        drop(a);
        assert_eq!(pool.pages_free(), 3);
        drop(b);
        assert_eq!((pool.pages_free(), pool.pages_used()), (4, 0));
    }

    #[test]
    fn leased_chains_are_disjoint() {
        let pool = KvPool::new(6, 16);
        let a = pool.try_lease(2).unwrap();
        let b = pool.try_lease(3).unwrap();
        assert_eq!(a.page_count(), 2);
        assert_eq!(b.page_count(), 3);
        for p in a.pages() {
            assert!(!b.pages().contains(p), "page {p} double-leased");
        }
    }
}
