//! Paged KV accounting for the serving tier (DESIGN.md §14.2, §16).
//!
//! [`KvPool`] meters KV *positions* in pages: every admitted row leases
//! the pages covering its worst-case position footprint (prompt +
//! generation budget + draft scratch) before it may enter a replica's
//! slot table, and every cached prompt prefix
//! ([`crate::serve::PrefixCache`]) leases the pages covering its
//! positions.  Slot capacity is therefore bounded by *memory pages*, not
//! only by the compile-time batch shape: when the pool is sized below
//! `replicas · B` full rows, replicas admit until pages run out and
//! defer the rest (never panic, never queue unboundedly).
//!
//! Two backings share the [`PageLease`] interface:
//!
//! * **Arena-backed** ([`KvPool::with_allocator`]) — the normal shape
//!   under the paged native KV layout.  The pool's budget is installed
//!   directly on the backend's [`PageAllocator`] (the per-model
//!   [`crate::backend::paged`] arena), so the admission ledger and the
//!   physical page allocator are **one object**: a lease reserves real
//!   page capacity in the same arena the forward pass allocates from,
//!   and the arena's `live_pages`/`free_pages` are the physical truth the
//!   router's `/metrics` renders.
//! * **Free-list** ([`KvPool::new`]) — the standalone accounting arena
//!   used when the backend has no page allocator (contig layout, PJRT).
//!   Page ids are an identity-mapped accounting view; the physical KV
//!   stays ring-contiguous per row.
use std::sync::{Arc, Mutex};

use crate::backend::PageAllocator;

/// Shared page arena: cheap-to-clone handle over the backing.
#[derive(Debug, Clone)]
pub struct KvPool {
    inner: Arc<PoolInner>,
}

struct PoolInner {
    page_size: usize,
    backing: Backing,
}

enum Backing {
    /// Standalone accounting free list (ids are synthetic).
    FreeList { total: usize, free: Mutex<Vec<u32>> },
    /// Budget installed on the backend's physical page arena.
    Arena(Arc<dyn PageAllocator>),
}

impl std::fmt::Debug for PoolInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.backing {
            Backing::FreeList { total, free } => f
                .debug_struct("KvPool")
                .field("page_size", &self.page_size)
                .field("total", total)
                .field("free", &free.lock().unwrap().len())
                .finish(),
            Backing::Arena(a) => f
                .debug_struct("KvPool")
                .field("page_size", &self.page_size)
                .field("limit", &a.page_limit())
                .field("reserved", &a.reserved_pages())
                .field("live", &a.live_pages())
                .finish(),
        }
    }
}

impl KvPool {
    /// A standalone pool of `total_pages` pages, each covering
    /// `page_size` KV positions (both models' caches for those positions
    /// count as one page — the pool meters *positions*, the unit
    /// admission and prefix caching both deal in).
    pub fn new(total_pages: usize, page_size: usize) -> Self {
        let total = total_pages.max(1);
        KvPool {
            inner: Arc::new(PoolInner {
                page_size: page_size.max(1),
                backing: Backing::FreeList {
                    total,
                    free: Mutex::new((0..total as u32).rev().collect()),
                },
            }),
        }
    }

    /// A pool whose budget lives on the backend's own page allocator
    /// (DESIGN.md §16): installs `total_pages` as the arena's admission
    /// limit and takes the arena's page geometry.  Leases reserve and
    /// release capacity on that same arena — one allocator, no parallel
    /// ledger.
    pub fn with_allocator(total_pages: usize, alloc: Arc<dyn PageAllocator>) -> Self {
        alloc.set_page_limit(total_pages.max(1));
        KvPool {
            inner: Arc::new(PoolInner {
                page_size: alloc.page_positions(),
                backing: Backing::Arena(alloc),
            }),
        }
    }

    /// Is the budget installed on a backend arena (vs a standalone
    /// free list)?
    pub fn is_arena_backed(&self) -> bool {
        matches!(self.inner.backing, Backing::Arena(_))
    }

    /// Physical `(live, free)` slab counts of the backing arena; `None`
    /// for a free-list pool (it has no physical pages).
    pub fn physical_pages(&self) -> Option<(usize, usize)> {
        match &self.inner.backing {
            Backing::FreeList { .. } => None,
            Backing::Arena(a) => Some((a.live_pages(), a.free_pages())),
        }
    }

    pub fn page_size(&self) -> usize {
        self.inner.page_size
    }

    pub fn total_pages(&self) -> usize {
        match &self.inner.backing {
            Backing::FreeList { total, .. } => *total,
            Backing::Arena(a) => a.page_limit(),
        }
    }

    pub fn pages_free(&self) -> usize {
        match &self.inner.backing {
            Backing::FreeList { free, .. } => free.lock().unwrap().len(),
            Backing::Arena(a) => a.page_limit().saturating_sub(a.reserved_pages()),
        }
    }

    pub fn pages_used(&self) -> usize {
        match &self.inner.backing {
            Backing::FreeList { total, .. } => *total - self.pages_free(),
            Backing::Arena(a) => a.reserved_pages(),
        }
    }

    /// Pages needed to cover `positions` KV positions (ceiling; at least
    /// one page — a row always occupies storage).
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.inner.page_size)
    }

    /// Try to lease `pages` pages; `None` when the budget is short —
    /// the caller's cue to evict idle prefixes, defer the admission, or
    /// shed.  Never blocks and never over-allocates.
    pub fn try_lease(&self, pages: usize) -> Option<PageLease> {
        let taken = match &self.inner.backing {
            Backing::FreeList { free, .. } => {
                let mut free = free.lock().unwrap();
                if free.len() < pages {
                    return None;
                }
                let at = free.len() - pages;
                free.split_off(at)
            }
            Backing::Arena(a) => {
                if !a.try_reserve(pages) {
                    return None;
                }
                // No synthetic ids: the physical chain lives in the
                // row's `NativeKv` page table, allocated lazily on
                // write.
                Vec::new()
            }
        };
        Some(PageLease { inner: Arc::clone(&self.inner), count: pages, pages: taken })
    }
}

/// An owned page reservation: the budget of one admitted row or one
/// cached prefix.  Capacity returns to the pool on drop, so page
/// lifetime is exactly the lifetime of whatever holds the lease (the
/// slot's bookkeeping entry, or the cache entry's `Arc`).
#[derive(Debug)]
pub struct PageLease {
    inner: Arc<PoolInner>,
    count: usize,
    /// Free-list backing only: the synthetic page-id chain.  Empty under
    /// an arena backing, where physical pages live in the row's page
    /// table instead.
    pages: Vec<u32>,
}

impl PageLease {
    pub fn page_count(&self) -> usize {
        self.count
    }

    /// The leased page ids — the row's page chain under the free-list
    /// backing; empty under an arena backing (see [`PageLease::pages`]
    /// field docs).
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }
}

impl Drop for PageLease {
    fn drop(&mut self) {
        match &self.inner.backing {
            Backing::FreeList { free, .. } => {
                free.lock().unwrap().append(&mut self.pages);
            }
            Backing::Arena(a) => a.unreserve(self.count),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::paged::PageArena;

    #[test]
    fn pages_for_rounds_up() {
        let pool = KvPool::new(8, 16);
        assert_eq!(pool.pages_for(0), 1);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(16), 1);
        assert_eq!(pool.pages_for(17), 2);
        assert_eq!(pool.pages_for(96), 6);
    }

    #[test]
    fn lease_exhaustion_and_return_on_drop() {
        let pool = KvPool::new(4, 16);
        assert_eq!((pool.total_pages(), pool.pages_free(), pool.pages_used()), (4, 4, 0));
        let a = pool.try_lease(3).expect("3 of 4 pages");
        assert_eq!((pool.pages_free(), pool.pages_used()), (1, 3));
        assert!(pool.try_lease(2).is_none(), "only 1 page left");
        let b = pool.try_lease(1).expect("last page");
        assert_eq!(pool.pages_free(), 0);
        drop(a);
        assert_eq!(pool.pages_free(), 3);
        drop(b);
        assert_eq!((pool.pages_free(), pool.pages_used()), (4, 0));
    }

    #[test]
    fn leased_chains_are_disjoint() {
        let pool = KvPool::new(6, 16);
        let a = pool.try_lease(2).unwrap();
        let b = pool.try_lease(3).unwrap();
        assert_eq!(a.page_count(), 2);
        assert_eq!(b.page_count(), 3);
        for p in a.pages() {
            assert!(!b.pages().contains(p), "page {p} double-leased");
        }
    }

    #[test]
    fn arena_backing_reserves_on_the_arena_itself() {
        let arena = Arc::new(PageArena::new(2, 8, 16));
        let pool = KvPool::with_allocator(4, arena.clone());
        assert!(pool.is_arena_backed());
        assert_eq!(pool.page_size(), 16);
        assert_eq!(pool.total_pages(), 4);
        // The budget lives on the arena — no parallel ledger.
        assert_eq!(arena.page_limit(), 4);
        let a = pool.try_lease(3).expect("3 of 4");
        assert_eq!(arena.reserved_pages(), 3);
        assert_eq!((pool.pages_free(), pool.pages_used()), (1, 3));
        assert!(pool.try_lease(2).is_none(), "budget exhausted defers");
        assert!(a.pages().is_empty(), "arena leases carry no synthetic ids");
        assert_eq!(a.page_count(), 3);
        drop(a);
        assert_eq!(arena.reserved_pages(), 0);
        assert_eq!(pool.pages_free(), 4);
        // No physical slabs were ever allocated by accounting alone.
        assert_eq!(pool.physical_pages(), Some((0, 0)));
    }
}
