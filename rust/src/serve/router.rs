//! L4 serving tier: a multi-replica router over a paged, prefix-shared
//! KV pool (DESIGN.md §14).
//!
//! [`Router::spawn`] stands up `N` [`SpecEngine`] replicas, each on its
//! own worker thread with its own KV slot table and two-lane request
//! queue ([`RequestQueue`]).  The router handle places each request on
//! the replica with the fewest outstanding tokens whose admission
//! [`TokenBucket`] still has budget; when no replica can take it, the
//! request is **shed** — an explicit [`RouteError::Shed`] (HTTP 429 +
//! `Retry-After`), never a panic and never an unbounded queue.
//!
//! Replica workers mirror the coordinator's continuous batcher, with two
//! serving-tier additions at admission time: every row first leases the
//! [`KvPool`] pages covering its worst-case footprint (deferring — not
//! failing — when the pool is momentarily exhausted), and prompts are
//! longest-prefix-matched against the shared [`PrefixCache`] so warm
//! admissions splice the cached prefix KV and forward only the suffix
//! ([`SpecEngine::admit_rows_prefixed`]) — bit-identical to cold
//! prefill, test-enforced in `tests/serve_tier.rs`.
//!
//! Placement never changes what a request generates: with a per-request
//! seed, a row's output is a pure function of `(prompt, seed)` on every
//! replica (DESIGN.md §7), so least-outstanding-tokens routing is a pure
//! latency policy (also test-enforced).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{kvstats, Backend};
use crate::config::{EngineConfig, RouterConfig, ServerConfig};
use crate::coordinator::queue::{Lane, RequestQueue, SlotTable, TokenBucket};
use crate::engine::spec::{Admission, DecodeState, PrefixHandle, SpecEngine};
use crate::engine::{RowResult, RowTracker};
use crate::metrics::{Counter, EngineMetrics, LatencyHist};
use crate::verify::Rng;

use super::kvpool::{KvPool, PageLease};
use super::prefix::{CachedPrefix, PrefixCache, PrefixStats};

/// A generation request as accepted by the router.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: Option<usize>,
    /// Per-request sampling seed (same semantics as
    /// [`crate::coordinator::GenRequest::seed`]): when set, the output is
    /// a pure function of `(prompt, seed)` — independent of placement.
    pub seed: Option<u64>,
    pub lane: Lane,
    /// Tenant id for intra-lane round-robin fairness.
    pub tenant: u64,
    pub enqueued: Instant,
}

impl ServeRequest {
    /// An interactive, single-tenant request — the common case.
    pub fn new(prompt: Vec<u32>, max_new_tokens: Option<usize>, seed: Option<u64>) -> Self {
        ServeRequest {
            prompt,
            max_new_tokens,
            seed,
            lane: Lane::Interactive,
            tenant: 0,
            enqueued: Instant::now(),
        }
    }
}

/// Why the router did not return a result.
#[derive(Debug)]
pub enum RouteError {
    /// Load shed: every replica's admission budget (or channel) was
    /// full.  Maps to HTTP 429 with a `Retry-After` hint.
    Shed { retry_after_s: u64 },
    /// The placed request failed (admission rejection or device error);
    /// the message preserves the engine's error chain.
    Failed(String),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Shed { retry_after_s } => {
                write!(f, "over capacity — request shed (retry after {retry_after_s}s)")
            }
            RouteError::Failed(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for RouteError {}

/// Router-level counters, rendered next to the per-replica engine
/// metrics in `/metrics`.
#[derive(Default, Debug)]
pub struct RouterMetrics {
    /// Requests refused with 429 because no replica had admission budget.
    pub requests_shed_total: Counter,
    /// Enqueue-to-admission wait across all replicas.
    pub queue_wait_us: LatencyHist,
}

type Reply = SyncSender<Result<RowResult>>;

struct ReplicaHandle {
    tx: SyncSender<(ServeRequest, Reply)>,
    /// Admission budget in tokens (prompt + generation); sized so a
    /// replica's backlog stays a few batches deep.
    bucket: TokenBucket,
    /// Outstanding token cost — the placement key.
    outstanding: AtomicUsize,
    metrics: Arc<EngineMetrics>,
}

/// The cloneable router handle held by server handlers.  Type-erased:
/// worker threads own the engines, so the HTTP layer needs no backend
/// generic.
#[derive(Clone)]
pub struct Router {
    replicas: Arc<Vec<ReplicaHandle>>,
    pool: KvPool,
    stats: Arc<PrefixStats>,
    pub metrics: Arc<RouterMetrics>,
    default_max_new: usize,
    pinned: Option<usize>,
}

impl Router {
    /// Spawn `cfg.replicas` engine replicas over a shared backend, KV
    /// pool and prefix cache.  Replicas share the backend `Arc` (its
    /// scratch pool is keyed and locked per shape, and `prepare` is
    /// idempotent), so weights are resident once.
    pub fn spawn<B: Backend>(
        backend: Arc<B>,
        engine_cfg: EngineConfig,
        server_cfg: &ServerConfig,
        router_cfg: &RouterConfig,
    ) -> Result<Router> {
        let info = backend.info();
        let (b, l) = (info.batch, info.max_len);
        let n = router_cfg.replicas.max(1);
        // Under the paged native layout the pool's budget is installed on
        // the backend's own page arena (DESIGN.md §16) — the arena's page
        // geometry then *is* the pool geometry, overriding the config
        // knob (warn when they disagree so the operator learns why).
        let alloc = backend.page_allocator();
        let page_size = match &alloc {
            Some(a) => {
                let pp = a.page_positions();
                if router_cfg.page_size.max(1) != pp {
                    eprintln!(
                        "specd: router page_size {} overridden by the backend \
                         arena's {pp} positions/page",
                        router_cfg.page_size.max(1)
                    );
                }
                pp
            }
            None => router_cfg.page_size.max(1),
        };
        let pages_per_row = l.div_ceil(page_size);
        // Auto pool: fund every replica's full slot table plus headroom
        // for a handful of cached prefixes.  Sizing it *below*
        // `n * b * pages_per_row` turns the pool into the admission
        // bound: replicas defer rows until pages free up.
        let total_pages = if router_cfg.kv_pages > 0 {
            router_cfg.kv_pages
        } else {
            (n * b + 8) * pages_per_row
        };
        let pool = match alloc {
            Some(a) => KvPool::with_allocator(total_pages, a),
            None => KvPool::new(total_pages, page_size),
        };
        let min_prefix = if router_cfg.min_prefix_len > 0 {
            router_cfg.min_prefix_len
        } else {
            page_size
        };
        // Prefixes share the prompt budget: strictly below L/2.
        let cache = Arc::new(PrefixCache::<B>::new(page_size, min_prefix, l / 2 - 1));
        let stats = cache.stats.clone();
        let token_budget = if router_cfg.token_budget > 0 {
            router_cfg.token_budget
        } else {
            4 * b * l
        };
        let batch_wait = Duration::from_millis(server_cfg.batch_wait_ms);
        let depth = server_cfg.queue_limit.max(1);
        let metrics = Arc::new(RouterMetrics::default());
        let default_max_new = engine_cfg.max_new_tokens;
        let mut replicas = Vec::with_capacity(n);
        for i in 0..n {
            let engine = SpecEngine::new(backend.clone(), engine_cfg.clone())?;
            let engine_metrics = engine.metrics.clone();
            let (tx, rx) = sync_channel(depth);
            let worker_pool = pool.clone();
            let worker_cache = cache.clone();
            let worker_metrics = metrics.clone();
            let prefix_on = router_cfg.prefix_cache;
            std::thread::Builder::new()
                .name(format!("specd-replica-{i}"))
                .spawn(move || {
                    replica_worker(
                        engine,
                        rx,
                        batch_wait,
                        worker_pool,
                        worker_cache,
                        prefix_on,
                        worker_metrics,
                    )
                })
                .map_err(|e| anyhow!("spawning replica {i}: {e}"))?;
            replicas.push(ReplicaHandle {
                tx,
                bucket: TokenBucket::new(token_budget),
                outstanding: AtomicUsize::new(0),
                metrics: engine_metrics,
            });
        }
        Ok(Router {
            replicas: Arc::new(replicas),
            pool,
            stats,
            metrics,
            default_max_new,
            pinned: router_cfg.pinned_replica,
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// A replica's engine metrics (tests and the coordinator shim).
    pub fn replica_metrics(&self, i: usize) -> Arc<EngineMetrics> {
        self.replicas[i].metrics.clone()
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn prefix_stats(&self) -> &Arc<PrefixStats> {
        &self.stats
    }

    /// Place a request and block until its row completes.
    ///
    /// Placement: replicas ordered by outstanding token cost (fewest
    /// first; or the pinned replica when configured), first one whose
    /// token bucket accepts the request's cost AND whose channel has
    /// room wins.  If none does, the request is shed — the charge is
    /// rolled back, nothing queues.
    pub fn generate(&self, req: ServeRequest) -> Result<RowResult, RouteError> {
        let cost = req
            .prompt
            .len()
            .saturating_add(req.max_new_tokens.unwrap_or(self.default_max_new).max(1))
            .max(1);
        let order: Vec<usize> = match self.pinned {
            Some(i) => vec![i.min(self.replicas.len() - 1)],
            None => {
                let mut idx: Vec<usize> = (0..self.replicas.len()).collect();
                idx.sort_by_key(|&i| self.replicas[i].outstanding.load(Ordering::Acquire));
                idx
            }
        };
        let (otx, orx) = sync_channel(1);
        let mut msg = (req, otx);
        let mut placed: Option<usize> = None;
        for &i in &order {
            let r = &self.replicas[i];
            if !r.bucket.try_acquire(cost) {
                continue;
            }
            match r.tx.try_send(msg) {
                Ok(()) => {
                    placed = Some(i);
                    break;
                }
                Err(e) => {
                    // Channel full (or replica gone): roll back the
                    // charge, recover the message, try the next replica.
                    r.bucket.release(cost);
                    msg = match e {
                        TrySendError::Full(m) | TrySendError::Disconnected(m) => m,
                    };
                }
            }
        }
        let Some(i) = placed else {
            self.metrics.requests_shed_total.inc();
            return Err(RouteError::Shed { retry_after_s: 1 });
        };
        let r = &self.replicas[i];
        r.outstanding.fetch_add(cost, Ordering::AcqRel);
        r.metrics.requests_enqueued.inc();
        let res = orx.recv();
        r.outstanding.fetch_sub(cost, Ordering::AcqRel);
        r.bucket.release(cost);
        match res {
            Ok(Ok(row)) => Ok(row),
            Ok(Err(e)) => Err(RouteError::Failed(format!("{e:#}"))),
            Err(_) => Err(RouteError::Failed("replica dropped request".into())),
        }
    }

    /// `/metrics` exposition: unlabelled aggregates over all replicas
    /// (so single-engine dashboards and tests keep reading the same
    /// lines), one `replica="i"`-labelled block per replica, then the
    /// router-level serving metrics (DESIGN.md §14.5).
    pub fn render_metrics(&self) -> String {
        let mut s = String::new();
        let total = |g: &dyn Fn(&EngineMetrics) -> u64| -> u64 {
            self.replicas.iter().map(|r| g(&r.metrics)).sum()
        };
        {
            let mut put = |k: &str, v: f64| s.push_str(&format!("specd_{k} {v}\n"));
            put("requests_enqueued", total(&|m| m.requests_enqueued.get()) as f64);
            put("requests_completed", total(&|m| m.requests_completed.get()) as f64);
            put("tokens_emitted", total(&|m| m.tokens_emitted.get()) as f64);
            put("drafts_accepted", total(&|m| m.drafts_accepted.get()) as f64);
            put("drafts_scored", total(&|m| m.drafts_scored.get()) as f64);
            put("iterations", total(&|m| m.iterations.get()) as f64);
            put("batches", total(&|m| m.batches.get()) as f64);
            put("slots_refilled", total(&|m| m.slots_refilled.get()) as f64);
            let busy = total(&|m| m.slot_iters_busy.get());
            let avail = total(&|m| m.slot_iters_total.get());
            put("slot_occupancy", if avail == 0 { 0.0 } else { busy as f64 / avail as f64 });
            let toks = total(&|m| m.tokens_emitted.get());
            let iters = total(&|m| m.iterations.get());
            put("block_efficiency", if iters == 0 { 0.0 } else { toks as f64 / iters as f64 });
            put("prefill_positions", total(&|m| m.prefill_positions.get()) as f64);
            put("prompt_positions", total(&|m| m.prompt_positions.get()) as f64);
        }
        for (i, r) in self.replicas.iter().enumerate() {
            s.push_str(&r.metrics.render_labeled(&format!("replica=\"{i}\"")));
            s.push_str(&format!(
                "specd_replica_outstanding_tokens{{replica=\"{i}\"}} {}\n",
                r.outstanding.load(Ordering::Relaxed)
            ));
        }
        s.push_str(&format!("specd_router_replicas {}\n", self.replicas.len()));
        s.push_str(&format!(
            "specd_requests_shed_total {}\n",
            self.metrics.requests_shed_total.get()
        ));
        s.push_str(&format!("specd_prefix_cache_hits {}\n", self.stats.hits.get()));
        s.push_str(&format!("specd_prefix_cache_misses {}\n", self.stats.misses.get()));
        s.push_str(&format!("specd_prefix_cache_evictions {}\n", self.stats.evictions.get()));
        s.push_str(&format!("specd_prefix_cache_inserts {}\n", self.stats.inserts.get()));
        s.push_str(&format!("specd_kv_pages_total {}\n", self.pool.total_pages()));
        s.push_str(&format!("specd_kv_pages_used {}\n", self.pool.pages_used()));
        s.push_str(&format!("specd_kv_pages_free {}\n", self.pool.pages_free()));
        // Physical truth of the arena backing (paged layout only): slabs
        // referenced by live page tables vs recycled on the free list.
        if let Some((live, free)) = self.pool.physical_pages() {
            s.push_str(&format!("specd_kv_pages_live {live}\n"));
            s.push_str(&format!("specd_kv_pages_recycled {free}\n"));
        }
        // Process-global KV movement ledger (DESIGN.md §16): bytes the
        // splice/CoW paths physically copied, next to the admission
        // traffic that avoided copying.
        s.push_str(&format!("specd_kv_bytes_copied_total {}\n", kvstats::bytes_copied()));
        s.push_str(&format!("specd_kv_pages_cow_total {}\n", kvstats::pages_cow()));
        s.push_str(&format!(
            "specd_router_queue_wait_mean_us {}\n",
            self.metrics.queue_wait_us.mean_us()
        ));
        for (edge, n) in self.metrics.queue_wait_us.nonzero() {
            s.push_str(&format!("specd_router_queue_wait_us{{le=\"{edge}\"}} {n}\n"));
        }
        // Process-global kernel info line, same as the single-engine
        // exposition.
        s.push_str(&format!(
            "specd_native_kernel{{kernel=\"{}\",isa=\"{}\"}} 1\n",
            crate::backend::kernels::default_kernel(),
            crate::backend::kernels::active_isa(),
        ));
        s
    }
}

/// Per-slot request bookkeeping held by a replica worker.  Holds the
/// row's page lease: pages return to the pool exactly when the slot is
/// released.
struct SlotReq {
    tracker: RowTracker,
    reply: Reply,
    enqueued: Instant,
    _lease: PageLease,
}

/// A queued request after dequeue validation (prompt travels separately
/// as the [`RequestQueue`] key).
struct Pending {
    max_new: usize,
    seed: Option<u64>,
    lane: Lane,
    tenant: u64,
    enqueued: Instant,
    reply: Reply,
}

fn enqueue(
    queue: &mut RequestQueue<Pending>,
    req: ServeRequest,
    reply: Reply,
    default_max_new: usize,
) {
    // Too-short prompts cannot even key the queue; reject inline.  All
    // other validation (ring budget) happens at engine admission so the
    // error chain matches the single-engine path.
    if req.prompt.len() < 2 {
        let _ = reply.send(Err(anyhow!("prompts need >= 2 tokens (BOS + marker)")));
        return;
    }
    let pend = Pending {
        max_new: req.max_new_tokens.unwrap_or(default_max_new).max(1),
        seed: req.seed,
        lane: req.lane,
        tenant: req.tenant,
        enqueued: req.enqueued,
        reply,
    };
    let _ = queue.push_with(req.prompt, pend.lane, pend.tenant, pend);
}

/// Longest-prefix-match the prompt against the shared cache; on a miss,
/// populate the cache (prefill the page-aligned prefix once, extract
/// compact caches) so this and every later admission sharing the prefix
/// go warm.  Any failure degrades to a cold admission — losslessness
/// never depends on this function succeeding.
fn lookup_or_populate<B: Backend>(
    engine: &SpecEngine<B>,
    cache: &PrefixCache<B>,
    pool: &KvPool,
    prompt: &[u32],
) -> Option<Arc<CachedPrefix<B>>> {
    let plen = cache.candidate_len(prompt.len())?;
    if let Some(hit) = cache.lookup(prompt) {
        return Some(hit);
    }
    let need = pool.pages_for(plen);
    let lease = pool.try_lease(need).or_else(|| {
        cache.evict_idle(need);
        pool.try_lease(need)
    })?;
    let (kv_t, kv_d) = engine.prefill_prefix(&prompt[..plen]).ok()?;
    Some(cache.insert(prompt[..plen].to_vec(), kv_t, kv_d, lease))
}

/// An admission candidate that secured a slot, pages and (maybe) a
/// cached prefix.  The `prefix` `Arc` is held across the batched
/// prefill so eviction cannot free the spliced pages mid-admission.
struct Ready<B: Backend> {
    slot: usize,
    prompt: Vec<u32>,
    pend: Pending,
    row_seed: u64,
    lease: PageLease,
    prefix: Option<Arc<CachedPrefix<B>>>,
}

/// Continuous batching loop for one replica: the coordinator's batcher
/// (admit into free slots mid-decode, one fused step, reply per row)
/// plus the serving-tier admission ladder — two-lane tenant-fair queue,
/// page leasing with defer-on-exhaustion, prefix-cache splicing.
fn replica_worker<B: Backend>(
    engine: SpecEngine<B>,
    rx: Receiver<(ServeRequest, Reply)>,
    batch_wait: Duration,
    pool: KvPool,
    cache: Arc<PrefixCache<B>>,
    prefix_on: bool,
    router_metrics: Arc<RouterMetrics>,
) {
    let metrics = engine.metrics.clone();
    let info = engine.backend().info();
    let (b, l) = (info.batch, info.max_len);
    // Footprint reservations must cover the largest gamma the adaptive
    // controller may pick, not just the configured static one.
    let gamma = if engine.cfg.adaptive.enabled {
        engine.cfg.gamma.max(engine.cfg.adaptive.gamma_max)
    } else {
        engine.cfg.gamma
    };
    let default_max_new = engine.cfg.max_new_tokens;
    let mut seed_rng = Rng::new(0xc0ffee0 ^ 0x9E3779B97F4A7C15);
    let mut state: Option<DecodeState<B>> = None;
    let mut slots: SlotTable<SlotReq> = SlotTable::new(b);
    // Local queue: validation is the engine's job (limits unbounded here;
    // the router's token buckets bound what can reach this queue).
    let mut queue: RequestQueue<Pending> = RequestQueue::new(usize::MAX, usize::MAX);
    'serve: loop {
        // --- gather incoming requests ------------------------------------
        if slots.is_empty() && queue.is_empty() {
            // Idle: block for the next request, then give stragglers
            // `batch_wait` to land so bursts start as one batch.
            match rx.recv() {
                Ok((req, reply)) => enqueue(&mut queue, req, reply, default_max_new),
                Err(_) => return, // router dropped: shut down
            }
            let deadline = Instant::now() + batch_wait;
            while queue.len() < b {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok((req, reply)) => enqueue(&mut queue, req, reply, default_max_new),
                    Err(_) => break,
                }
            }
        } else {
            if slots.is_empty() {
                // Deferred admissions with no live rows (pool
                // exhausted): wait one straggler window for pages to
                // come back instead of spinning.
                if let Ok((req, reply)) = rx.recv_timeout(batch_wait.max(Duration::from_millis(1)))
                {
                    enqueue(&mut queue, req, reply, default_max_new);
                }
            }
            // Mid-decode: non-blocking drain — live rows must not wait
            // on the queue.
            while let Ok((req, reply)) = rx.try_recv() {
                enqueue(&mut queue, req, reply, default_max_new);
            }
        }

        // --- admit into free slots (one batched prefill per tick) ---------
        let free = slots.free_slots();
        let cands = if free.is_empty() { Vec::new() } else { queue.take_batch(free.len()) };
        if !cands.is_empty() {
            match ensure_stream(&engine, &mut state) {
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (_, pend) in cands {
                        let _ = pend.reply.send(Err(anyhow!("{msg}")));
                    }
                }
                Ok(st) => {
                    let mut ready: Vec<Ready<B>> = Vec::new();
                    let mut deferred: Vec<(Vec<u32>, Pending)> = Vec::new();
                    let mut free_iter = free.into_iter();
                    for (prompt, pend) in cands {
                        // Page lease first: a row may only occupy a slot
                        // if the pool can cover its worst-case footprint
                        // (prompt + generation budget + draft scratch).
                        let footprint = (prompt.len() + pend.max_new + gamma + 2).min(l);
                        let need = pool.pages_for(footprint);
                        let lease = pool.try_lease(need).or_else(|| {
                            cache.evict_idle(need);
                            pool.try_lease(need)
                        });
                        let Some(lease) = lease else {
                            if need > pool.total_pages() {
                                // Can never fit: reject, don't spin.
                                let _ = pend.reply.send(Err(anyhow!(
                                    "request needs {need} KV pages but the pool holds {}",
                                    pool.total_pages()
                                )));
                            } else {
                                // Momentary exhaustion: defer (back to
                                // the front of its lane after this
                                // tick), keep serving.
                                deferred.push((prompt, pend));
                            }
                            continue;
                        };
                        let prefix = if prefix_on {
                            lookup_or_populate(&engine, &cache, &pool, &prompt)
                        } else {
                            None
                        };
                        let row_seed = pend.seed.unwrap_or_else(|| seed_rng.next_u64());
                        let slot = free_iter.next().expect("candidates bounded by free slots");
                        ready.push(Ready { slot, prompt, pend, row_seed, lease, prefix });
                    }
                    // Reverse so repeated push-fronts restore arrival
                    // order at the head of each lane.
                    for (prompt, pend) in deferred.into_iter().rev() {
                        queue.requeue(prompt, pend.lane, pend.tenant, pend);
                    }
                    let results = {
                        let admissions: Vec<Admission<'_>> = ready
                            .iter()
                            .map(|r| Admission {
                                slot: r.slot,
                                prompt: &r.prompt,
                                row_seed: r.row_seed,
                            })
                            .collect();
                        let prefixes: Vec<Option<PrefixHandle<'_, B>>> = ready
                            .iter()
                            .map(|r| {
                                r.prefix.as_ref().map(|c| PrefixHandle {
                                    kv_target: &c.kv_target,
                                    kv_drafter: &c.kv_drafter,
                                    len: c.len(),
                                })
                            })
                            .collect();
                        engine.admit_rows_prefixed(st, &admissions, &prefixes)
                    };
                    for (r, res) in ready.into_iter().zip(results) {
                        match res {
                            Ok(()) => {
                                metrics.queue_wait.observe(r.pend.enqueued.elapsed());
                                router_metrics.queue_wait_us.observe(r.pend.enqueued.elapsed());
                                slots.occupy(
                                    r.slot,
                                    SlotReq {
                                        tracker: RowTracker::new(true, r.pend.max_new),
                                        reply: r.pend.reply,
                                        enqueued: r.pend.enqueued,
                                        _lease: r.lease,
                                    },
                                );
                            }
                            // Admission errors (over-long prompt, bad
                            // state) reject just this request; the live
                            // batch and the tick's other admissions are
                            // untouched.  The lease drops with `r`.
                            Err(e) => {
                                let _ = r.pend.reply.send(Err(e));
                            }
                        }
                    }
                }
            }
        }
        if slots.is_empty() {
            continue 'serve;
        }

        // --- one fused engine step over the live batch --------------------
        let st = state.as_mut().expect("occupied slots imply a live stream");
        let out = match engine.step_stream(st) {
            Ok(out) => out,
            Err(e) => {
                // Device-level failure: fail every in-flight request and
                // rebuild the stream on the next admission.  Dropping the
                // slot entries returns their page leases.
                let msg = format!("{e:#}");
                for (_, sr) in slots.drain() {
                    let _ = sr.reply.send(Err(anyhow!("{msg}")));
                }
                state = None;
                continue 'serve;
            }
        };

        // --- absorb per-row outcomes; reply and free rows as they finish --
        metrics.slot_iters_total.add(b as u64);
        metrics.slot_iters_busy.add(slots.occupied() as u64);
        let mut finished: Vec<usize> = Vec::new();
        for (i, sr) in slots.iter_occupied_mut() {
            let tau = out.tau[i] as usize;
            let row: Vec<u32> = out.emitted[i * out.stride..i * out.stride + tau + 1]
                .iter()
                .map(|&x| x as u32)
                .collect();
            sr.tracker.absorb(&row, tau, out.done[i] != 0);
            metrics.tokens_emitted.add(row.len() as u64);
            metrics.drafts_accepted.add(tau as u64);
            metrics.accepted_len_hist.observe(tau);
            metrics.iterations.inc();
            if !sr.tracker.active() {
                finished.push(i);
            }
        }
        let any_finished = !finished.is_empty();
        for i in finished {
            let sr = slots.release(i).expect("finished slot was occupied");
            metrics.requests_completed.inc();
            metrics.request_latency.observe(sr.enqueued.elapsed());
            let result = sr.tracker.into_result();
            let _ = sr.reply.send(Ok(result));
            engine.release_row(st, i);
        }
        if slots.is_empty() {
            metrics.batches.inc();
        }
        if any_finished {
            // Per-row drain boundary (see coordinator::batch_worker): all
            // of this step's outputs were read back, so the backend can
            // release per-batch resources.
            engine.backend().end_batch();
        }
    }
}

/// Lazily build (or rebuild after failure) a worker's decode stream.
fn ensure_stream<'a, B: Backend>(
    engine: &SpecEngine<B>,
    state: &'a mut Option<DecodeState<B>>,
) -> Result<&'a mut DecodeState<B>> {
    if state.is_none() {
        *state = Some(engine.begin_stream()?);
    }
    Ok(state.as_mut().expect("just ensured"))
}
