//! Ref-counted prompt-prefix KV cache for the serving tier
//! (DESIGN.md §14.3).
//!
//! Keys are page-aligned token prefixes (exact `Vec<u32>` match — two
//! prompts share a cache entry iff they share those tokens verbatim).
//! Values are compact per-model KV caches produced by
//! [`crate::engine::spec::SpecEngine::prefill_prefix`] /
//! [`crate::backend::Backend::kv_extract`]: one row, ring length =
//! prefix length, for *both* the target and the drafter (warm admission
//! must splice both or the drafter would re-derive the prefix and the
//! stream would diverge from cold prefill).
//!
//! Lifecycle is `Arc`-refcounted: `lookup` hands out a clone that the
//! admission path holds across `admit_rows_prefixed` (the splice reads
//! `&B::Kv` borrowed from it), so eviction can never free pages under a
//! live splice — [`PrefixCache::evict_idle`] only removes entries whose
//! sole owner is the cache itself (`Arc::strong_count == 1`), oldest
//! `last_used` first.  Each entry owns the [`PageLease`] covering its
//! positions; dropping the entry returns the pages.
//!
//! Under the paged native layout (DESIGN.md §16) the cached `B::Kv`
//! values are page tables into the backend arena, so an entry **pins its
//! physical pages directly**: keys are page-aligned
//! ([`PrefixCache::candidate_len`]), every page of a cached prefix is
//! full, and a warm admission splice is therefore a pure page-table
//! clone — refcount bumps, zero prefix KV bytes copied (gated in
//! `benches/serving.rs`).  Copy-on-write keeps the pinned pages
//! immutable while admitted rows extend past them.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::backend::Backend;
use crate::metrics::Counter;

use super::kvpool::PageLease;

/// Hit/miss/eviction counters, shared with the router's `/metrics`
/// rendering (non-generic so the HTTP layer needs no backend type).
#[derive(Default, Debug)]
pub struct PrefixStats {
    pub hits: Counter,
    pub misses: Counter,
    pub evictions: Counter,
    pub inserts: Counter,
}

/// One cached prefix: the exact tokens it covers plus both models'
/// compact KV for those positions, pinned to its page lease.
pub struct CachedPrefix<B: Backend> {
    pub tokens: Vec<u32>,
    pub kv_target: B::Kv,
    pub kv_drafter: B::Kv,
    /// Held, not read: pages return to the pool when the entry drops.
    _lease: PageLease,
}

impl<B: Backend> CachedPrefix<B> {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

struct Entry<B: Backend> {
    data: Arc<CachedPrefix<B>>,
    last_used: u64,
}

/// Hash-keyed prefix cache shared by every replica of a router.
pub struct PrefixCache<B: Backend> {
    map: Mutex<HashMap<Vec<u32>, Entry<B>>>,
    /// Logical LRU clock (bumped per lookup/insert — wall time would
    /// break determinism for no benefit).
    clock: AtomicU64,
    page_size: usize,
    min_len: usize,
    /// Longest cacheable prefix (the engine's prompt budget `L/2 - 1`;
    /// prefixes must stay strictly shorter than any admissible prompt).
    max_len: usize,
    pub stats: Arc<PrefixStats>,
}

impl<B: Backend> PrefixCache<B> {
    pub fn new(page_size: usize, min_len: usize, max_len: usize) -> Self {
        PrefixCache {
            map: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            page_size: page_size.max(1),
            // An engine prefix needs >= 2 tokens (BOS + content).
            min_len: min_len.max(2),
            max_len,
            stats: Arc::new(PrefixStats::default()),
        }
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Longest page-aligned *strict* prefix of a `prompt_len`-token
    /// prompt this cache would key on; `None` when the prompt is too
    /// short to leave a cacheable prefix.  Page alignment keeps the key
    /// space coarse (at most `L / page_size` probe lengths) and matches
    /// the pool's allocation granularity.
    pub fn candidate_len(&self, prompt_len: usize) -> Option<usize> {
        let cap = prompt_len.saturating_sub(1).min(self.max_len);
        let len = (cap / self.page_size) * self.page_size;
        (len >= self.min_len).then_some(len)
    }

    /// Longest-prefix match: probe page-aligned prefix lengths of
    /// `prompt`, longest first.  A hit bumps the entry's LRU stamp and
    /// returns a refcounted handle the caller holds across the splice.
    pub fn lookup(&self, prompt: &[u32]) -> Option<Arc<CachedPrefix<B>>> {
        let longest = self.candidate_len(prompt.len())?;
        let stamp = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        let mut map = self.map.lock().unwrap();
        let mut len = longest;
        while len >= self.min_len {
            if let Some(e) = map.get_mut(&prompt[..len]) {
                e.last_used = stamp;
                self.stats.hits.inc();
                return Some(e.data.clone());
            }
            if len < self.page_size {
                break;
            }
            len -= self.page_size;
        }
        self.stats.misses.inc();
        None
    }

    /// Insert a freshly prefilled prefix and return the shared handle
    /// (so the populating admission warms itself).  Re-inserting an
    /// existing key replaces it — harmless: both values are bit-identical
    /// by construction and in-flight holders keep their `Arc` alive.
    pub fn insert(
        &self,
        tokens: Vec<u32>,
        kv_target: B::Kv,
        kv_drafter: B::Kv,
        lease: PageLease,
    ) -> Arc<CachedPrefix<B>> {
        let data = Arc::new(CachedPrefix {
            tokens: tokens.clone(),
            kv_target,
            kv_drafter,
            _lease: lease,
        });
        let stamp = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        self.map
            .lock()
            .unwrap()
            .insert(tokens, Entry { data: data.clone(), last_used: stamp });
        self.stats.inserts.inc();
        data
    }

    /// Evict idle entries (cache is the sole `Arc` owner), least
    /// recently used first, until roughly `want_pages` pages have been
    /// returned to the pool or no idle entry remains.  Entries pinned by
    /// an in-flight admission are never touched.
    pub fn evict_idle(&self, want_pages: usize) {
        let mut map = self.map.lock().unwrap();
        let mut idle: Vec<(u64, Vec<u32>, usize)> = map
            .iter()
            .filter(|(_, e)| Arc::strong_count(&e.data) == 1)
            .map(|(k, e)| (e.last_used, k.clone(), e.data._lease.page_count()))
            .collect();
        idle.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
        let mut freed = 0usize;
        for (_, key, pages) in idle {
            if freed >= want_pages {
                break;
            }
            // Dropping the entry drops its Arc (sole owner) and with it
            // the page lease — the pages are back in the pool before
            // this returns.
            map.remove(&key);
            self.stats.evictions.inc();
            freed += pages;
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::backend::NativeBackend;

    use super::*;

    // Key/alignment logic is backend-independent — instantiate the cache
    // at a concrete backend type without ever touching a model.  Entry
    // lifecycle (insert/lookup/evict with real KV) is covered by
    // `tests/serve_tier.rs`.
    fn cache() -> PrefixCache<NativeBackend> {
        // page 16, min prefix 16, prompt budget 47 (L=96 ring).
        PrefixCache::new(16, 16, 47)
    }

    #[test]
    fn candidate_len_is_page_aligned_and_strict() {
        let c = cache();
        assert_eq!(c.candidate_len(5), None, "too short to leave a 16-token prefix");
        assert_eq!(c.candidate_len(16), None, "prefix must be strictly shorter");
        assert_eq!(c.candidate_len(17), Some(16));
        assert_eq!(c.candidate_len(33), Some(32));
        assert_eq!(c.candidate_len(40), Some(32));
        // Capped by the prompt budget: never a prefix the engine couldn't
        // have admitted as a prompt itself.
        assert_eq!(c.candidate_len(400), Some(32));
    }

    #[test]
    fn lookup_miss_counts_and_returns_none() {
        let c = cache();
        let prompt: Vec<u32> = (0..20).map(|i| 16 + i).collect();
        assert!(c.lookup(&prompt).is_none());
        assert_eq!(c.stats.misses.get(), 1);
        assert_eq!(c.stats.hits.get(), 0);
        // Un-cacheable prompts are not misses — there was nothing to probe.
        assert!(c.lookup(&prompt[..4]).is_none());
        assert_eq!(c.stats.misses.get(), 1);
    }
}
