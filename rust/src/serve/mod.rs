//! L4 serving tier (DESIGN.md §14): the traffic-facing layer above the
//! per-replica continuous batchers.
//!
//! * [`router`] — [`Router`]: N engine replicas on worker threads,
//!   least-outstanding-tokens placement, per-replica token-bucket
//!   admission with explicit load shedding (429 + `Retry-After`).
//! * [`kvpool`] — [`KvPool`]: fixed-size page budget; rows and cached
//!   prefixes lease their pages, so admission is bounded by memory, not
//!   only by the batch shape.  Under the paged native KV layout the
//!   budget is installed directly on the backend's physical page arena
//!   ([`crate::backend::Backend::page_allocator`], DESIGN.md §16); a
//!   standalone free-list backing covers contig/PJRT backends.
//! * [`prefix`] — [`PrefixCache`]: ref-counted, hash-keyed cache of
//!   prefilled prompt-prefix KV; warm admissions splice cached pages and
//!   prefill only the suffix, bit-identically to cold prefill
//!   (test-enforced in `tests/serve_tier.rs`).
//!
//! The single-engine [`crate::coordinator::Coordinator`] is a thin shim
//! over a one-replica router, so both entry points share one batcher
//! implementation.

pub mod kvpool;
pub mod prefix;
pub mod router;

pub use kvpool::{KvPool, PageLease};
pub use prefix::{CachedPrefix, PrefixCache, PrefixStats};
pub use router::{RouteError, Router, RouterMetrics, ServeRequest};
