//! In-tree utility substrates (the build image is offline; DESIGN.md §3).

pub mod argparse;
pub mod json;
pub mod proptest;
