//! Minimal `--flag value` CLI parser for the launcher and examples.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: a subcommand, `--key value` options and bare
/// positional args.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // flag followed by value, or boolean flag
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(name.to_string(), v);
                        }
                        _ => {
                            out.options.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("tables --table 1 --prompts 64");
        assert_eq!(a.subcommand.as_deref(), Some("tables"));
        assert_eq!(a.get("table"), Some("1"));
        assert_eq!(a.usize_or("prompts", 0).unwrap(), 64);
    }

    #[test]
    fn equals_form_and_bool_flags() {
        let a = parse("run --gamma=8 --verbose --seed 3");
        assert_eq!(a.get("gamma"), Some("8"));
        assert!(a.flag("verbose"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 3);
        assert_eq!(a.u64_or("missing", 9).unwrap(), 9);
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse("run --gamma x");
        assert!(a.usize_or("gamma", 0).is_err());
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("serve --quiet");
        assert!(a.flag("quiet"));
    }
}
