//! Tiny property-testing helper (proptest is unavailable offline): runs a
//! property over many seeded random cases and reports the first failing
//! seed so failures are reproducible.

use crate::verify::dist::normalize;
use crate::verify::Rng;

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(seed));
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Random probability vector of length `v` with concentration knob:
/// smaller `conc` ⇒ peakier distributions (more interesting residuals).
pub fn rand_dist(rng: &mut Rng, v: usize, conc: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (0..v)
        .map(|_| {
            let u = rng.uniform().max(1e-12);
            // inverse-CDF of a rough gamma-ish shape
            u.powf(1.0 / conc.max(1e-3))
        })
        .collect();
    if !normalize(&mut w) {
        w = vec![1.0 / v as f64; v];
    }
    w
}

/// Random (ps, qs, drafts) verification instance with drafts sampled from
/// qs (as the real system does).
pub fn rand_instance(
    rng: &mut Rng,
    gamma: usize,
    v: usize,
    conc: f64,
) -> (crate::verify::ProbMatrix, crate::verify::ProbMatrix, Vec<u32>) {
    use crate::verify::dist::inv_cdf;
    let ps_rows: Vec<Vec<f64>> = (0..=gamma).map(|_| rand_dist(rng, v, conc)).collect();
    let qs_rows: Vec<Vec<f64>> = (0..gamma).map(|_| rand_dist(rng, v, conc)).collect();
    let drafts: Vec<u32> =
        (0..gamma).map(|i| inv_cdf(&qs_rows[i], rng.uniform()) as u32).collect();
    (
        crate::verify::ProbMatrix::from_rows(ps_rows),
        crate::verify::ProbMatrix::from_rows(qs_rows),
        drafts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rand_dist_is_normalised() {
        check("rand_dist normalised", 50, |rng| {
            let d = rand_dist(rng, 16, 0.5);
            let s: f64 = d.iter().sum();
            if (s - 1.0).abs() > 1e-9 {
                return Err(format!("sum {s}"));
            }
            if d.iter().any(|&x| x < 0.0) {
                return Err("negative prob".into());
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed at seed 0")]
    fn reports_failing_seed() {
        check("always-fails", 3, |_| Err("boom".into()));
    }

    #[test]
    fn rand_instance_shapes() {
        check("instance shapes", 20, |rng| {
            let (ps, qs, d) = rand_instance(rng, 4, 8, 1.0);
            if ps.rows != 5 || qs.rows != 4 || d.len() != 4 {
                return Err("bad shapes".into());
            }
            if d.iter().any(|&x| x >= 8) {
                return Err("draft out of vocab".into());
            }
            Ok(())
        });
    }
}
