//! In-tree JSON parser/serialiser (RFC 8259 subset sufficient for the
//! artifact bundle and the HTTP API).
//!
//! The build image is offline with only the `xla` crate closure cached, so
//! serde/serde_json are unavailable; this module is the substrate instead
//! (DESIGN.md §3).  Supports the full JSON data model with f64 numbers,
//! `\uXXXX` escapes (BMP + surrogate pairs) and nesting-depth limits.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ------------------------------------------------------------------
    // Typed accessors (used pervasively by manifest/workload/server code).
    // ------------------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj.field` access with a descriptive error.
    pub fn field(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON field '{key}'"))
    }

    pub fn str_field(&self, key: &str) -> Result<String> {
        Ok(self
            .field(key)?
            .as_str()
            .ok_or_else(|| anyhow!("field '{key}' is not a string"))?
            .to_string())
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.field(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("field '{key}' is not a number"))
    }

    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("field '{key}' is not a number"))
    }

    pub fn arr_field(&self, key: &str) -> Result<&[Value]> {
        self.field(key)?
            .as_arr()
            .ok_or_else(|| anyhow!("field '{key}' is not an array"))
    }

    /// Vec<usize> from a numeric array field.
    pub fn usize_vec(&self, key: &str) -> Result<Vec<usize>> {
        self.arr_field(key)?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("'{key}': non-numeric entry")))
            .collect()
    }

    pub fn f64_vec(&self, key: &str) -> Result<Vec<f64>> {
        self.arr_field(key)?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("'{key}': non-numeric entry")))
            .collect()
    }
}

// ----------------------------------------------------------------------
// Parsing
// ----------------------------------------------------------------------

const MAX_DEPTH: usize = 128;

pub fn parse(input: &str) -> Result<Value> {
    let b = input.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value(0)?;
    p.ws();
    if p.i != b.len() {
        bail!("trailing bytes at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            bail!("JSON nesting exceeds {MAX_DEPTH}");
        }
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(depth),
            b'{' => self.object(depth),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte '{}' at offset {}", c as char, self.i),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value(depth + 1)?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value(depth + 1)?;
            out.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => bail!("expected ',' or '}}' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate");
                                }
                                let c =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?
                            } else {
                                char::from_u32(hi).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => bail!("invalid escape at {}", self.i),
                    }
                }
                _ => {
                    // Re-sync on UTF-8 boundaries: push raw bytes until valid.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len()
                        && std::str::from_utf8(&self.b[start..end]).is_err()
                    {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| anyhow!("invalid utf8 in string"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        Ok(u32::from_str_radix(hex, 16)?)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }
}

// ----------------------------------------------------------------------
// Serialisation
// ----------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, x);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(out, k);
                out.push(':');
                write_value(out, x);
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// Convenience constructors for response building.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn str_v(s: &str) -> Value {
    Value::Str(s.to_string())
}

pub fn arr_u32(xs: &[u32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(s).unwrap();
            let v2 = parse(&to_string(&v)).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_field("c").unwrap(), "x\ny");
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        // raw utf8 passthrough
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("[1] trailing").is_err());
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 3, "xs": [1, 2.5], "s": "a", "b": true}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 3);
        assert_eq!(v.f64_vec("xs").unwrap(), vec![1.0, 2.5]);
        assert!(v.usize_field("s").is_err());
        assert_eq!(v.field("b").unwrap().as_bool(), Some(true));
        assert!(v.field("missing").is_err());
    }

    #[test]
    fn serialises_integers_cleanly() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
        assert_eq!(to_string(&obj(vec![("k", str_v("v"))])), r#"{"k":"v"}"#);
    }

    #[test]
    fn large_numeric_array_roundtrip() {
        let xs: Vec<Value> = (0..1000).map(|i| Value::Num(i as f64 * 0.25)).collect();
        let s = to_string(&Value::Arr(xs.clone()));
        assert_eq!(parse(&s).unwrap(), Value::Arr(xs));
    }
}
