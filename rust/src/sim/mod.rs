//! Distribution-level simulation substrate (no NN, no device).
//!
//! Everything the paper proves is a statement about pairs of conditional
//! distributions; this module lets us check those statements exactly
//! ([`exact`]) and by Monte Carlo ([`specdec`]) in microseconds, and
//! regenerates the §2 motivating example.  The NN serving path (engine/)
//! produces the paper's *measured* numbers; this module produces its
//! *theoretical* ones.

pub mod chain;
pub mod exact;
pub mod specdec;

pub use chain::{bernoulli_example, MarkovPair};
pub use specdec::{
    run_iteration_multi, run_iteration_tree, sample_target, simulate, simulate_multi,
    simulate_tree, specdec_prefix, specdec_prefix_multi, specdec_prefix_tree, SimStats,
};

/// The §2 motivating-example report (E0 in DESIGN.md): exact values for
/// token / block / full-information at gamma = 2 plus MC confirmation.
pub struct MotivatingExample {
    pub exact_token: f64,
    pub exact_block: f64,
    pub exact_ideal: f64,
    pub mc_token: f64,
    pub mc_block: f64,
}

pub fn motivating_example(mc_tokens: usize, seed: u64) -> MotivatingExample {
    let pair = bernoulli_example();
    MotivatingExample {
        exact_token: exact::expected_tau_token(&pair, 2),
        exact_block: exact::expected_tau_block(&pair, 2),
        exact_ideal: exact::fullinfo_bound(&pair, 2),
        mc_token: simulate(&pair, 2, crate::verify::Algo::Token, mc_tokens, seed).mean_tau(),
        mc_block: simulate(&pair, 2, crate::verify::Algo::Block, mc_tokens, seed).mean_tau(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn motivating_example_report() {
        let r = motivating_example(100_000, 1);
        assert!((r.exact_token - 10.0 / 9.0).abs() < 1e-12);
        assert!((r.exact_block - 11.0 / 9.0).abs() < 1e-12);
        assert!((r.exact_ideal - 12.0 / 9.0).abs() < 1e-12);
        assert!((r.mc_token - r.exact_token).abs() < 0.02);
        assert!((r.mc_block - r.exact_block).abs() < 0.02);
    }
}
