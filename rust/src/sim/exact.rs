//! Exact (enumeration-based) expected-acceptance computations for small
//! model pairs — the analytic side of Theorem 2 and the §2 example.
//!
//! All quantities are per-iteration expectations over draft blocks
//! `X^gamma ~ M_s^gamma`:
//!
//! * [`expected_tau_token`] — `E[tau]` under Algorithm 1:
//!   `sum_l sum_{x^l} prod_i min(M_b(x_i|x^{i-1}), M_s(x_i|x^{i-1}))`.
//! * [`expected_tau_block`] — `E[tau]` under Algorithm 2 (Lemma 3):
//!   `sum_l sum_{x^l} M_s(x^l) * p_l(x^l)`.
//! * [`fullinfo_bound`] — the Lemma 8 / full-information upper bound:
//!   `sum_l sum_{x^l} min(M_s(x^l), M_b(x^l))` over *joint* probabilities.
//!
//! Complexity is `O(V^gamma)` — intended for `V <= 8`, `gamma <= 6`.

use super::chain::MarkovPair;

fn recurse<F: FnMut(usize, f64, f64, f64, f64)>(
    pair: &MarkovPair,
    depth: usize,
    max_depth: usize,
    last: Option<u32>,
    qs_joint: f64,
    ps_joint: f64,
    min_prod: f64,
    p_chain: f64,
    visit: &mut F,
) {
    if depth == max_depth {
        return;
    }
    let trow = pair.target_row(last);
    let drow = pair.draft_row(last);
    for x in 0..pair.vocab {
        let q = drow[x];
        let p = trow[x];
        if q <= 0.0 && p <= 0.0 {
            continue;
        }
        let qs2 = qs_joint * q;
        let ps2 = ps_joint * p;
        let min2 = min_prod * p.min(q);
        // Eq. 8 chain with zero-draft guard (q = 0 ⇒ path has zero draft
        // probability; contributes nothing).
        let pch2 = if q > 0.0 { (p_chain * p / q).min(1.0) } else { 0.0 };
        visit(depth + 1, qs2, ps2, min2, pch2);
        recurse(pair, depth + 1, max_depth, Some(x as u32), qs2, ps2, min2, pch2, visit);
    }
}

/// `E[tau]` for token verification (Algorithm 1), exact.
pub fn expected_tau_token(pair: &MarkovPair, gamma: usize) -> f64 {
    let mut total = 0.0;
    recurse(pair, 0, gamma, None, 1.0, 1.0, 1.0, 1.0, &mut |_, _, _, min2, _| {
        total += min2;
    });
    total
}

/// `E[tau]` for block verification (Algorithm 2 / Lemma 3), exact.
pub fn expected_tau_block(pair: &MarkovPair, gamma: usize) -> f64 {
    let mut total = 0.0;
    recurse(pair, 0, gamma, None, 1.0, 1.0, 1.0, 1.0, &mut |_, qs, _, _, pch| {
        total += qs * pch;
    });
    total
}

/// The full-information optimal-transport upper bound (Lemma 8).
pub fn fullinfo_bound(pair: &MarkovPair, gamma: usize) -> f64 {
    let mut total = 0.0;
    recurse(pair, 0, gamma, None, 1.0, 1.0, 1.0, 1.0, &mut |_, qs, ps, _, _| {
        total += qs.min(ps);
    });
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::chain::bernoulli_example;

    /// The paper's §2 numbers: E[accepted] = 10/9 (token), 11/9 (block),
    /// 12/9 (full-information ideal) at gamma = 2.
    #[test]
    fn motivating_example_exact() {
        let pair = bernoulli_example();
        let tok = expected_tau_token(&pair, 2);
        let blk = expected_tau_block(&pair, 2);
        let ideal = fullinfo_bound(&pair, 2);
        assert!((tok - 10.0 / 9.0).abs() < 1e-12, "token {tok}");
        assert!((blk - 11.0 / 9.0).abs() < 1e-12, "block {blk}");
        assert!((ideal - 12.0 / 9.0).abs() < 1e-12, "ideal {ideal}");
    }

    /// Theorem 2 ordering on random pairs: token <= block <= full-info.
    #[test]
    fn ordering_holds_on_random_pairs() {
        for seed in 0..30 {
            let mix = 0.2 + 0.6 * (seed as f64 / 30.0);
            let pair = MarkovPair::random(4, mix, seed);
            for gamma in 1..=4 {
                let t = expected_tau_token(&pair, gamma);
                let b = expected_tau_block(&pair, gamma);
                let f = fullinfo_bound(&pair, gamma);
                assert!(b >= t - 1e-12, "seed {seed} gamma {gamma}: {b} < {t}");
                assert!(f >= b - 1e-12, "seed {seed} gamma {gamma}: {f} < {b}");
            }
        }
    }

    /// At gamma = 1 the three quantities coincide (1 - TV distance).
    #[test]
    fn gamma1_all_equal() {
        let pair = MarkovPair::random(5, 0.5, 7);
        let t = expected_tau_token(&pair, 1);
        let b = expected_tau_block(&pair, 1);
        let f = fullinfo_bound(&pair, 1);
        assert!((t - b).abs() < 1e-12 && (b - f).abs() < 1e-12);
    }

    /// Perfect drafter: everything is accepted, E[tau] = gamma.
    #[test]
    fn perfect_drafter_accepts_everything() {
        let pair = MarkovPair::random(4, 1.0, 11);
        for gamma in 1..=4 {
            assert!((expected_tau_block(&pair, gamma) - gamma as f64).abs() < 1e-9);
            assert!((expected_tau_token(&pair, gamma) - gamma as f64).abs() < 1e-9);
        }
    }
}
