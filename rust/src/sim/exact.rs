//! Exact (enumeration-based) expected-acceptance computations for small
//! model pairs — the analytic side of Theorem 2 and the §2 example.
//!
//! All quantities are per-iteration expectations over draft blocks
//! `X^gamma ~ M_s^gamma`:
//!
//! * [`expected_tau_token`] — `E[tau]` under Algorithm 1:
//!   `sum_l sum_{x^l} prod_i min(M_b(x_i|x^{i-1}), M_s(x_i|x^{i-1}))`.
//! * [`expected_tau_block`] — `E[tau]` under Algorithm 2 (Lemma 3):
//!   `sum_l sum_{x^l} M_s(x^l) * p_l(x^l)`.
//! * [`fullinfo_bound`] — the Lemma 8 / full-information upper bound:
//!   `sum_l sum_{x^l} min(M_s(x^l), M_b(x^l))` over *joint* probabilities.
//! * [`expected_tau_multipath`] — `E[tau]` for sequential multi-draft
//!   block verification over `K` i.i.d. draft paths
//!   ([`crate::verify::multipath`]); note `K > 1` can exceed
//!   [`fullinfo_bound`], which bounds *single-draft* schemes only.
//!
//! Complexity is `O(V^gamma)` — intended for `V <= 8`, `gamma <= 6`.

use super::chain::MarkovPair;
use crate::verify::dist::{normalize, pos_diff_sum, EPS};

fn recurse<F: FnMut(usize, f64, f64, f64, f64)>(
    pair: &MarkovPair,
    depth: usize,
    max_depth: usize,
    last: Option<u32>,
    qs_joint: f64,
    ps_joint: f64,
    min_prod: f64,
    p_chain: f64,
    visit: &mut F,
) {
    if depth == max_depth {
        return;
    }
    let trow = pair.target_row(last);
    let drow = pair.draft_row(last);
    for x in 0..pair.vocab {
        let q = drow[x];
        let p = trow[x];
        if q <= 0.0 && p <= 0.0 {
            continue;
        }
        let qs2 = qs_joint * q;
        let ps2 = ps_joint * p;
        let min2 = min_prod * p.min(q);
        // Eq. 8 chain with zero-draft guard (q = 0 ⇒ path has zero draft
        // probability; contributes nothing).
        let pch2 = if q > 0.0 { (p_chain * p / q).min(1.0) } else { 0.0 };
        visit(depth + 1, qs2, ps2, min2, pch2);
        recurse(pair, depth + 1, max_depth, Some(x as u32), qs2, ps2, min2, pch2, visit);
    }
}

/// `E[tau]` for token verification (Algorithm 1), exact.
pub fn expected_tau_token(pair: &MarkovPair, gamma: usize) -> f64 {
    let mut total = 0.0;
    recurse(pair, 0, gamma, None, 1.0, 1.0, 1.0, 1.0, &mut |_, _, _, min2, _| {
        total += min2;
    });
    total
}

/// `E[tau]` for block verification (Algorithm 2 / Lemma 3), exact.
pub fn expected_tau_block(pair: &MarkovPair, gamma: usize) -> f64 {
    let mut total = 0.0;
    recurse(pair, 0, gamma, None, 1.0, 1.0, 1.0, 1.0, &mut |_, qs, _, _, pch| {
        total += qs * pch;
    });
    total
}

/// The full-information optimal-transport upper bound (Lemma 8).
pub fn fullinfo_bound(pair: &MarkovPair, gamma: usize) -> f64 {
    let mut total = 0.0;
    recurse(pair, 0, gamma, None, 1.0, 1.0, 1.0, 1.0, &mut |_, qs, ps, _, _| {
        total += qs.min(ps);
    });
    total
}

/// One multipath stage, exactly: `(E[tau], P(tau = 0))` for block
/// verification of a single draft path whose position-0 target row is
/// `d` (positions `>= 1` use the pair's target conditionals), with the
/// path drawn from the pair's draft chain.  Works off the per-path
/// acceptance probabilities: conditioned on the path, `tau = max{i :
/// eta_i <= h_i}` over independent uniforms, so `P(tau >= l) = 1 -
/// prod_{i>=l}(1 - h_i)` and `E[tau] = sum_l P(tau >= l)`.
fn stage_stats(pair: &MarkovPair, gamma: usize, d: &[f64]) -> (f64, f64) {
    let mut hs = vec![0.0; gamma + 1];
    let mut m = 0.0;
    let mut z = 0.0;
    stage_rec(pair, 0, gamma, None, 1.0, 1.0, d, &mut hs, &mut m, &mut z);
    (m, z)
}

#[allow(clippy::too_many_arguments)]
fn stage_rec(
    pair: &MarkovPair,
    depth: usize,
    gamma: usize,
    last: Option<u32>,
    q_joint: f64,
    p_chain: f64,
    d: &[f64],
    hs: &mut [f64],
    m: &mut f64,
    z: &mut f64,
) {
    if depth >= gamma {
        return;
    }
    let drow = pair.draft_row(last);
    for x in 0..pair.vocab {
        let q = drow[x];
        if q <= 0.0 {
            // Zero draft probability: the path never occurs.
            continue;
        }
        let t = if depth == 0 { d[x] } else { pair.target_row(last)[x] };
        let pch = (p_chain * t / q).min(1.0);
        let i = depth + 1;
        hs[i] = if i == gamma {
            pch
        } else {
            // Eq. 4 with the *next* position's rows, as in block_chain.
            let nxt = Some(x as u32);
            let s = pos_diff_sum(pch, pair.target_row(nxt), pair.draft_row(nxt));
            let denom = s + 1.0 - pch;
            if denom <= EPS {
                1.0
            } else {
                s / denom
            }
        };
        if i == gamma {
            let mut prod = 1.0;
            let mut etau = 0.0;
            for l in (1..=gamma).rev() {
                prod *= 1.0 - hs[l];
                etau += 1.0 - prod;
            }
            let w = q_joint * q;
            *m += w * etau;
            *z += w * prod;
        } else {
            stage_rec(pair, i, gamma, Some(x as u32), q_joint * q, pch, d, hs, m, z);
        }
    }
}

/// `E[tau]` for sequential multi-draft block verification over `k`
/// i.i.d. draft paths ([`crate::verify::multipath_verify`]), exact.
/// Stage `i` block-verifies one path against the remaining position-0
/// target `d_i` (`d_1 = M_b(.|c)`); with probability `P(tau = 0)` it
/// defers to stage `i + 1` with `d_{i+1} = norm(max(d_i - M_s(.|c), 0))`
/// (the Eq. 3 residual at `tau = 0`).  At `k = 1` this equals
/// [`expected_tau_block`] (test-enforced, to 1e-9: the two formulas walk
/// the same chain by different routes).
pub fn expected_tau_multipath(pair: &MarkovPair, gamma: usize, k: usize) -> f64 {
    assert!(k >= 1, "multipath needs k >= 1");
    let q0 = pair.draft_row(None);
    let mut d = pair.target_row(None).to_vec();
    let mut total = 0.0;
    let mut reach = 1.0;
    for stage in 0..k {
        let (m, z) = stage_stats(pair, gamma, &d);
        total += reach * m;
        reach *= z;
        if reach <= 0.0 {
            break;
        }
        if stage + 1 < k {
            let mut res: Vec<f64> = d.iter().zip(q0).map(|(a, b)| (a - b).max(0.0)).collect();
            if !normalize(&mut res) {
                // Remaining target equals the drafter row: later stages
                // cannot reject at position 0, so nothing more accrues.
                break;
            }
            d = res;
        }
    }
    total
}

/// `E[tau]` for prefix-sharing tree verification (DESIGN.md §13), exact.
///
/// Equal to [`expected_tau_multipath`] by **dedup-invariance**: the tree
/// drafts the same `k` i.i.d. token streams as flat multipath (each leaf
/// keeps its own draw sequence), and merely stores/scores coincident
/// prefixes once.  Because the tree forward pass returns bit-identical
/// rows for a shared node and for the separate flat rows it replaces
/// (test-enforced in `tests/multipath.rs`), the verification walk sees
/// exactly the flat multipath inputs, so the acceptance law — and hence
/// `E[tau]` — is unchanged.  What *does* change is the number of drafted
/// tokens scored per iteration: see [`expected_tree_nodes`].
pub fn expected_tau_tree(pair: &MarkovPair, gamma: usize, k: usize) -> f64 {
    expected_tau_multipath(pair, gamma, k)
}

fn nodes_rec(
    pair: &MarkovPair,
    depth: usize,
    gamma: usize,
    last: Option<u32>,
    q_joint: f64,
    k: usize,
    total: &mut f64,
) {
    if depth >= gamma {
        return;
    }
    let drow = pair.draft_row(last);
    for x in 0..pair.vocab {
        let q = drow[x];
        if q <= 0.0 {
            continue;
        }
        let qw = q_joint * q;
        // The prefix `w` materialises one tree node iff at least one of
        // the k i.i.d. draft streams walks it.
        *total += 1.0 - (1.0 - qw).powi(k as i32);
        nodes_rec(pair, depth + 1, gamma, Some(x as u32), qw, k, total);
    }
}

/// Expected number of tree nodes drafted *and* target-scored per
/// iteration under the always-share branch policy (threshold 0,
/// DESIGN.md §13.3):
///
/// `sum_{j=1..gamma} sum_{|w|=j} (1 - (1 - q(w))^k)`
///
/// where `q(w)` is the draft-chain probability of prefix `w` from the
/// root context.  Flat multipath always scores `k * gamma`; the tree
/// scores strictly fewer whenever any prefix probability lies in (0, 1)
/// and `k >= 2`, and exactly `gamma` at `k = 1`.  This is the
/// denominator of the drafted-tokens-per-committed-token CI gate
/// (`benches/serving.rs`).
pub fn expected_tree_nodes(pair: &MarkovPair, gamma: usize, k: usize) -> f64 {
    assert!(k >= 1, "tree needs k >= 1");
    let mut total = 0.0;
    nodes_rec(pair, 0, gamma, None, 1.0, k, &mut total);
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::chain::bernoulli_example;

    /// The paper's §2 numbers: E[accepted] = 10/9 (token), 11/9 (block),
    /// 12/9 (full-information ideal) at gamma = 2.
    #[test]
    fn motivating_example_exact() {
        let pair = bernoulli_example();
        let tok = expected_tau_token(&pair, 2);
        let blk = expected_tau_block(&pair, 2);
        let ideal = fullinfo_bound(&pair, 2);
        assert!((tok - 10.0 / 9.0).abs() < 1e-12, "token {tok}");
        assert!((blk - 11.0 / 9.0).abs() < 1e-12, "block {blk}");
        assert!((ideal - 12.0 / 9.0).abs() < 1e-12, "ideal {ideal}");
    }

    /// Theorem 2 ordering on random pairs: token <= block <= full-info.
    #[test]
    fn ordering_holds_on_random_pairs() {
        for seed in 0..30 {
            let mix = 0.2 + 0.6 * (seed as f64 / 30.0);
            let pair = MarkovPair::random(4, mix, seed);
            for gamma in 1..=4 {
                let t = expected_tau_token(&pair, gamma);
                let b = expected_tau_block(&pair, gamma);
                let f = fullinfo_bound(&pair, gamma);
                assert!(b >= t - 1e-12, "seed {seed} gamma {gamma}: {b} < {t}");
                assert!(f >= b - 1e-12, "seed {seed} gamma {gamma}: {f} < {b}");
            }
        }
    }

    /// At gamma = 1 the three quantities coincide (1 - TV distance).
    #[test]
    fn gamma1_all_equal() {
        let pair = MarkovPair::random(5, 0.5, 7);
        let t = expected_tau_token(&pair, 1);
        let b = expected_tau_block(&pair, 1);
        let f = fullinfo_bound(&pair, 1);
        assert!((t - b).abs() < 1e-12 && (b - f).abs() < 1e-12);
    }

    /// Perfect drafter: everything is accepted, E[tau] = gamma.
    #[test]
    fn perfect_drafter_accepts_everything() {
        let pair = MarkovPair::random(4, 1.0, 11);
        for gamma in 1..=4 {
            assert!((expected_tau_block(&pair, gamma) - gamma as f64).abs() < 1e-9);
            assert!((expected_tau_token(&pair, gamma) - gamma as f64).abs() < 1e-9);
        }
    }

    /// The multipath recursion at K = 1 is block verification computed by
    /// a different route (per-path h-products vs the Lemma 3 sum); the
    /// two must agree to float precision.
    #[test]
    fn multipath_k1_equals_block() {
        let b = bernoulli_example();
        assert!((expected_tau_multipath(&b, 2, 1) - 11.0 / 9.0).abs() < 1e-12);
        for seed in 0..10 {
            let mix = 0.15 + 0.07 * seed as f64;
            let pair = MarkovPair::random(4, mix, seed);
            for gamma in 1..=3 {
                let blk = expected_tau_block(&pair, gamma);
                let mp = expected_tau_multipath(&pair, gamma, 1);
                assert!(
                    (blk - mp).abs() < 1e-9,
                    "seed {seed} gamma {gamma}: block {blk} vs multipath(1) {mp}"
                );
            }
        }
    }

    /// More paths never hurt: E[tau] is nondecreasing in K, always at
    /// least the single-draft block value, and capped by gamma.
    #[test]
    fn multipath_monotone_in_k() {
        for seed in 0..8 {
            let mix = 0.2 + 0.08 * seed as f64;
            let pair = MarkovPair::random(4, mix, seed + 100);
            let gamma = 3;
            let blk = expected_tau_block(&pair, gamma);
            let mut prev = 0.0;
            for k in [1usize, 2, 4, 8] {
                let e = expected_tau_multipath(&pair, gamma, k);
                assert!(e >= prev - 1e-12, "seed {seed} K {k}: {e} < {prev}");
                assert!(e >= blk - 1e-12, "seed {seed} K {k}: {e} < block {blk}");
                assert!(e <= gamma as f64 + 1e-9);
                prev = e;
            }
        }
    }

    /// An imperfect drafter leaves P(tau = 0) > 0, so a second path must
    /// strictly help on the Bernoulli example.
    #[test]
    fn second_path_strictly_helps_on_bernoulli() {
        let pair = bernoulli_example();
        let one = expected_tau_multipath(&pair, 2, 1);
        let two = expected_tau_multipath(&pair, 2, 2);
        assert!(two > one + 1e-6, "K=2 {two} should beat K=1 {one}");
    }

    /// Dedup-invariance: tree E[tau] is multipath E[tau] for every pair
    /// (same acceptance law, fewer scored tokens).
    #[test]
    fn tree_tau_equals_multipath_tau() {
        for seed in 0..6 {
            let pair = MarkovPair::random(4, 0.25 + 0.1 * seed as f64, seed + 40);
            for gamma in 1..=3 {
                for k in [1usize, 2, 4] {
                    let t = expected_tau_tree(&pair, gamma, k);
                    let m = expected_tau_multipath(&pair, gamma, k);
                    assert!((t - m).abs() < 1e-15, "seed {seed} g {gamma} k {k}: {t} vs {m}");
                }
            }
        }
    }

    /// Node-count accounting: exactly gamma at k = 1 (a chain), between
    /// gamma and k*gamma in general, strictly below k*gamma for k >= 2 on
    /// stochastic drafters, and nondecreasing in k.
    #[test]
    fn tree_nodes_bounds_and_strict_saving() {
        for seed in 0..6 {
            let pair = MarkovPair::random(4, 0.25 + 0.1 * seed as f64, seed + 70);
            for gamma in 1..=3 {
                let g = gamma as f64;
                assert!((expected_tree_nodes(&pair, gamma, 1) - g).abs() < 1e-12);
                let mut prev = g;
                for k in [2usize, 4, 8] {
                    let n = expected_tree_nodes(&pair, gamma, k);
                    assert!(n >= prev - 1e-12, "nodes must grow with k");
                    assert!(n >= g - 1e-12);
                    // Strict: some depth-1 prefix has q in (0,1), so the
                    // union bound loses mass vs k disjoint copies.
                    assert!(
                        n < (k * gamma) as f64 - 1e-9,
                        "seed {seed} g {gamma} k {k}: {n} !< {}",
                        k * gamma
                    );
                    prev = n;
                }
            }
        }
    }
}
