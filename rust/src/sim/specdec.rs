//! Monte-Carlo speculative decoding at the distribution level (no NN):
//! drafts are sampled from the pair's draft chain, verified with any of the
//! three algorithms, and per-iteration acceptance statistics collected.
//!
//! This is the fast harness behind the optimality/losslessness tests and
//! the `simulate` example; the real serving numbers come from the engine.

use crate::verify::dist::inv_cdf;
use crate::verify::{self, Algo, GreedyState, MultipathOutcome, ProbMatrix, Rng};

use super::chain::MarkovPair;

/// Statistics from a simulated decode.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub iterations: usize,
    pub tokens_emitted: usize,
    pub accepted_total: usize,
    /// histogram of tau values, length gamma + 1
    pub tau_hist: Vec<usize>,
    /// Drafted tokens scored by the target, summed over iterations.
    /// Filled by [`simulate_tree`] (tree nodes) — the speculation-cost
    /// axis of DESIGN.md §13; zero for the paths that don't track it.
    pub drafted_total: usize,
}

impl SimStats {
    /// Paper "block efficiency": mean decoded tokens per target call.
    pub fn block_efficiency(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.tokens_emitted as f64 / self.iterations as f64
    }

    pub fn mean_tau(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.accepted_total as f64 / self.iterations as f64
    }

    /// Drafted tokens scored per committed token (speculation cost);
    /// meaningful only where [`Self::drafted_total`] is tracked.
    pub fn drafts_per_token(&self) -> f64 {
        if self.tokens_emitted == 0 {
            return 0.0;
        }
        self.drafted_total as f64 / self.tokens_emitted as f64
    }
}

/// One verification iteration over the pair: draft `gamma` tokens from the
/// draft chain, score both chains along the path, verify.
/// Returns (emitted tokens, tau, updated greedy state).
pub fn run_iteration(
    pair: &MarkovPair,
    last: Option<u32>,
    gamma: usize,
    algo: Algo,
    rng: &mut Rng,
    greedy_state: &GreedyState,
) -> (Vec<u32>, usize, GreedyState) {
    let v = pair.vocab;
    let mut ps_rows: Vec<Vec<f64>> = Vec::with_capacity(gamma + 1);
    let mut qs_rows: Vec<Vec<f64>> = Vec::with_capacity(gamma);
    let mut drafts: Vec<u32> = Vec::with_capacity(gamma);
    let mut cur = last;
    for _ in 0..gamma {
        let q = pair.draft_row(cur).to_vec();
        let p = pair.target_row(cur).to_vec();
        let x = inv_cdf(&q, rng.uniform()) as u32;
        drafts.push(x);
        qs_rows.push(q);
        ps_rows.push(p);
        cur = Some(x);
    }
    ps_rows.push(pair.target_row(cur).to_vec());
    let ps = ProbMatrix::from_rows(ps_rows);
    let qs = ProbMatrix::from_rows(qs_rows);
    let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
    let u = rng.uniform();
    debug_assert_eq!(ps.vocab, v);

    match algo {
        Algo::Greedy => {
            let (out, st) = verify::greedy_verify(&ps, &qs, &drafts, &etas, u, greedy_state);
            (out.emitted, out.tau, st)
        }
        _ => {
            let out = verify::verify(algo, &ps, &qs, &drafts, &etas, u);
            (out.emitted, out.tau, greedy_state.clone())
        }
    }
}

/// Decode `n_tokens` tokens via speculative decoding over the pair.
pub fn simulate(
    pair: &MarkovPair,
    gamma: usize,
    algo: Algo,
    n_tokens: usize,
    seed: u64,
) -> SimStats {
    let mut rng = Rng::new(seed);
    let mut stats = SimStats { tau_hist: vec![0; gamma + 1], ..Default::default() };
    let mut last: Option<u32> = None;
    let mut greedy = GreedyState::new(gamma);
    while stats.tokens_emitted < n_tokens {
        let (emitted, tau, st) = run_iteration(pair, last, gamma, algo, &mut rng, &greedy);
        greedy = st;
        stats.iterations += 1;
        stats.tokens_emitted += emitted.len();
        stats.accepted_total += tau;
        stats.tau_hist[tau] += 1;
        last = emitted.last().copied().or(last);
    }
    stats
}

/// One multipath iteration at the distribution level: draft `k` i.i.d.
/// candidate paths from the draft chain, score both chains along every
/// path, verify jointly ([`verify::multipath_verify`]).  Draw order is
/// fixed (path-major: each path's `gamma` draft uniforms, then each
/// path's `gamma` etas, then the shared residual uniform) so runs are
/// replayable draw for draw.
pub fn run_iteration_multi(
    pair: &MarkovPair,
    last: Option<u32>,
    gamma: usize,
    k: usize,
    rng: &mut Rng,
) -> MultipathOutcome {
    let mut ps_l = Vec::with_capacity(k);
    let mut qs_l = Vec::with_capacity(k);
    let mut drafts_l = Vec::with_capacity(k);
    for _ in 0..k {
        let mut ps_rows: Vec<Vec<f64>> = Vec::with_capacity(gamma + 1);
        let mut qs_rows: Vec<Vec<f64>> = Vec::with_capacity(gamma);
        let mut drafts: Vec<u32> = Vec::with_capacity(gamma);
        let mut cur = last;
        for _ in 0..gamma {
            let q = pair.draft_row(cur).to_vec();
            let p = pair.target_row(cur).to_vec();
            let x = inv_cdf(&q, rng.uniform()) as u32;
            drafts.push(x);
            qs_rows.push(q);
            ps_rows.push(p);
            cur = Some(x);
        }
        ps_rows.push(pair.target_row(cur).to_vec());
        ps_l.push(ProbMatrix::from_rows(ps_rows));
        qs_l.push(ProbMatrix::from_rows(qs_rows));
        drafts_l.push(drafts);
    }
    let etas: Vec<Vec<f64>> =
        (0..k).map(|_| (0..gamma).map(|_| rng.uniform()).collect()).collect();
    let u = rng.uniform();
    verify::multipath_verify(&ps_l, &qs_l, &drafts_l, &etas, u)
}

/// One prefix-sharing tree iteration at the distribution level
/// (DESIGN.md §13): draws and verification are *exactly* those of
/// [`run_iteration_multi`] — same path-major draw order, same
/// [`verify::multipath_verify`] acceptance law — because the tree is a
/// storage/scoring optimisation, not a sampling change.  The second
/// return value is what the tree would actually score: the number of
/// distinct draft prefixes across the `k` streams (always-share policy),
/// versus flat multipath's `k * gamma`.  Its expectation is
/// [`crate::sim::exact::expected_tree_nodes`] (test-enforced).
pub fn run_iteration_tree(
    pair: &MarkovPair,
    last: Option<u32>,
    gamma: usize,
    k: usize,
    rng: &mut Rng,
) -> (MultipathOutcome, usize) {
    let mut ps_l = Vec::with_capacity(k);
    let mut qs_l = Vec::with_capacity(k);
    let mut drafts_l: Vec<Vec<u32>> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut ps_rows: Vec<Vec<f64>> = Vec::with_capacity(gamma + 1);
        let mut qs_rows: Vec<Vec<f64>> = Vec::with_capacity(gamma);
        let mut drafts: Vec<u32> = Vec::with_capacity(gamma);
        let mut cur = last;
        for _ in 0..gamma {
            let q = pair.draft_row(cur).to_vec();
            let p = pair.target_row(cur).to_vec();
            let x = inv_cdf(&q, rng.uniform()) as u32;
            drafts.push(x);
            qs_rows.push(q);
            ps_rows.push(p);
            cur = Some(x);
        }
        ps_rows.push(pair.target_row(cur).to_vec());
        ps_l.push(ProbMatrix::from_rows(ps_rows));
        qs_l.push(ProbMatrix::from_rows(qs_rows));
        drafts_l.push(drafts);
    }
    let mut nodes = 0usize;
    for j in 1..=gamma {
        let mut prefixes: Vec<&[u32]> = drafts_l.iter().map(|d| &d[..j]).collect();
        prefixes.sort_unstable();
        prefixes.dedup();
        nodes += prefixes.len();
    }
    let etas: Vec<Vec<f64>> =
        (0..k).map(|_| (0..gamma).map(|_| rng.uniform()).collect()).collect();
    let u = rng.uniform();
    (verify::multipath_verify(&ps_l, &qs_l, &drafts_l, &etas, u), nodes)
}

/// Decode `n_tokens` tokens via `k`-leaf tree speculative decoding,
/// tracking scored nodes in [`SimStats::drafted_total`].
pub fn simulate_tree(
    pair: &MarkovPair,
    gamma: usize,
    k: usize,
    n_tokens: usize,
    seed: u64,
) -> SimStats {
    let mut rng = Rng::new(seed);
    let mut stats = SimStats { tau_hist: vec![0; gamma + 1], ..Default::default() };
    let mut last: Option<u32> = None;
    while stats.tokens_emitted < n_tokens {
        let (out, nodes) = run_iteration_tree(pair, last, gamma, k, &mut rng);
        stats.iterations += 1;
        stats.tokens_emitted += out.emitted.len();
        stats.accepted_total += out.tau;
        stats.tau_hist[out.tau] += 1;
        stats.drafted_total += nodes;
        last = out.emitted.last().copied().or(last);
    }
    stats
}

/// Decode a fixed-length prefix with tree speculative decoding (the
/// losslessness harness twin of [`specdec_prefix_multi`]).
pub fn specdec_prefix_tree(
    pair: &MarkovPair,
    gamma: usize,
    k: usize,
    n_tokens: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let mut out: Vec<u32> = Vec::with_capacity(n_tokens + gamma + 1);
    while out.len() < n_tokens {
        let (res, _nodes) = run_iteration_tree(pair, out.last().copied(), gamma, k, rng);
        out.extend_from_slice(&res.emitted);
    }
    out.truncate(n_tokens);
    out
}

/// Decode `n_tokens` tokens via `k`-path multipath speculative decoding.
pub fn simulate_multi(
    pair: &MarkovPair,
    gamma: usize,
    k: usize,
    n_tokens: usize,
    seed: u64,
) -> SimStats {
    let mut rng = Rng::new(seed);
    let mut stats = SimStats { tau_hist: vec![0; gamma + 1], ..Default::default() };
    let mut last: Option<u32> = None;
    while stats.tokens_emitted < n_tokens {
        let out = run_iteration_multi(pair, last, gamma, k, &mut rng);
        stats.iterations += 1;
        stats.tokens_emitted += out.emitted.len();
        stats.accepted_total += out.tau;
        stats.tau_hist[out.tau] += 1;
        last = out.emitted.last().copied().or(last);
    }
    stats
}

/// Decode a fixed-length prefix with multipath speculative decoding (for
/// empirical distribution comparison against [`sample_target`] — the
/// losslessness check).
pub fn specdec_prefix_multi(
    pair: &MarkovPair,
    gamma: usize,
    k: usize,
    n_tokens: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let mut out: Vec<u32> = Vec::with_capacity(n_tokens + gamma + 1);
    while out.len() < n_tokens {
        let res = run_iteration_multi(pair, out.last().copied(), gamma, k, rng);
        out.extend_from_slice(&res.emitted);
    }
    out.truncate(n_tokens);
    out
}

/// Ancestral sampling from the *target* chain only — ground truth for
/// losslessness checks.
pub fn sample_target(pair: &MarkovPair, n_tokens: usize, rng: &mut Rng) -> Vec<u32> {
    let mut out = Vec::with_capacity(n_tokens);
    let mut last = None;
    for _ in 0..n_tokens {
        let x = inv_cdf(pair.target_row(last), rng.uniform()) as u32;
        out.push(x);
        last = Some(x);
    }
    out
}

/// Decode a fixed-length prefix with speculative decoding (for empirical
/// distribution comparison against [`sample_target`]).
pub fn specdec_prefix(
    pair: &MarkovPair,
    gamma: usize,
    algo: Algo,
    n_tokens: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let mut out: Vec<u32> = Vec::with_capacity(n_tokens + gamma + 1);
    let mut greedy = GreedyState::new(gamma);
    while out.len() < n_tokens {
        let (emitted, _tau, st) =
            run_iteration(pair, out.last().copied(), gamma, algo, rng, &greedy);
        greedy = st;
        out.extend_from_slice(&emitted);
    }
    out.truncate(n_tokens);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::chain::bernoulli_example;
    use crate::sim::exact;

    /// MC block efficiency matches the exact enumeration within tolerance.
    #[test]
    fn mc_matches_exact_bernoulli() {
        let pair = bernoulli_example();
        let gamma = 2;
        for (algo, want) in [(Algo::Token, 10.0 / 9.0), (Algo::Block, 11.0 / 9.0)] {
            let stats = simulate(&pair, gamma, algo, 200_000, 17);
            let got = stats.mean_tau();
            assert!((got - want).abs() < 0.01, "{algo}: {got} vs {want}");
        }
    }

    /// Per-iteration E[tau] from a fresh context matches the exact
    /// enumeration (simulate() mixes contexts across iterations, so this
    /// test drives single iterations from the empty context).
    #[test]
    fn mc_matches_exact_markov() {
        let pair = MarkovPair::random(4, 0.6, 5);
        let gamma = 3;
        let want_t = exact::expected_tau_token(&pair, gamma);
        let want_b = exact::expected_tau_block(&pair, gamma);
        let fresh = GreedyState::new(gamma);
        let n = 60_000;
        let (mut tot_t, mut tot_b) = (0usize, 0usize);
        let mut rng_t = Rng::new(3);
        let mut rng_b = Rng::new(3);
        for _ in 0..n {
            tot_t += run_iteration(&pair, None, gamma, Algo::Token, &mut rng_t, &fresh).1;
            tot_b += run_iteration(&pair, None, gamma, Algo::Block, &mut rng_b, &fresh).1;
        }
        let got_t = tot_t as f64 / n as f64;
        let got_b = tot_b as f64 / n as f64;
        assert!((got_t - want_t).abs() < 0.02, "token {got_t} vs {want_t}");
        assert!((got_b - want_b).abs() < 0.02, "block {got_b} vs {want_b}");
    }

    /// Per-iteration multipath E[tau] from a fresh context matches the
    /// exact stage recursion, and stage-1 of multipath is block (k = 1).
    #[test]
    fn mc_multipath_matches_exact() {
        let pair = MarkovPair::random(4, 0.6, 5);
        let gamma = 3;
        for k in [1usize, 2, 4] {
            let want = exact::expected_tau_multipath(&pair, gamma, k);
            let n = 60_000;
            let mut rng = Rng::new(33);
            let mut tot = 0usize;
            for _ in 0..n {
                tot += run_iteration_multi(&pair, None, gamma, k, &mut rng).tau;
            }
            let got = tot as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "k={k}: mc {got} vs exact {want}");
        }
    }

    /// Tree iterations replay multipath draw for draw: identical
    /// outcomes from identical rng streams, and the mean scored-node
    /// count matches the exact union-probability enumeration.
    #[test]
    fn mc_tree_matches_multipath_and_exact_nodes() {
        let pair = MarkovPair::random(4, 0.6, 5);
        let gamma = 3;
        for k in [1usize, 2, 4] {
            let want_nodes = exact::expected_tree_nodes(&pair, gamma, k);
            let n = 60_000;
            let mut rng_t = Rng::new(33);
            let mut rng_m = Rng::new(33);
            let (mut tot_tau, mut tot_nodes) = (0usize, 0usize);
            for _ in 0..n {
                let (out, nodes) = run_iteration_tree(&pair, None, gamma, k, &mut rng_t);
                let out_m = run_iteration_multi(&pair, None, gamma, k, &mut rng_m);
                assert_eq!(out.emitted, out_m.emitted);
                assert_eq!(out.tau, out_m.tau);
                assert!(nodes <= k * gamma);
                tot_tau += out.tau;
                tot_nodes += nodes;
            }
            let got_tau = tot_tau as f64 / n as f64;
            let got_nodes = tot_nodes as f64 / n as f64;
            let want_tau = exact::expected_tau_tree(&pair, gamma, k);
            assert!((got_tau - want_tau).abs() < 0.02, "k={k}: tau {got_tau} vs {want_tau}");
            assert!(
                (got_nodes - want_nodes).abs() < 0.02,
                "k={k}: nodes {got_nodes} vs {want_nodes}"
            );
        }
    }

    /// Multipath outcome invariants on the simulator substrate.
    #[test]
    fn multipath_iteration_invariants() {
        let pair = MarkovPair::random(5, 0.4, 21);
        let mut rng = Rng::new(9);
        for _ in 0..2000 {
            let out = run_iteration_multi(&pair, None, 3, 3, &mut rng);
            assert!(out.path < 3);
            assert_eq!(out.emitted.len(), out.tau + 1);
            assert!(out.emitted.iter().all(|&t| (t as usize) < pair.vocab));
        }
    }

    /// Greedy accepts at least as much as block *per iteration* from a
    /// fresh state (Theorem 3) — checked in expectation.
    #[test]
    fn greedy_beats_block_single_iteration() {
        let pair = MarkovPair::random(6, 0.5, 9);
        let gamma = 4;
        let mut rng_b = Rng::new(123);
        let mut rng_g = Rng::new(123);
        let fresh = GreedyState::new(gamma);
        let (mut accb, mut accg) = (0usize, 0usize);
        for _ in 0..30_000 {
            let (_, tb, _) = run_iteration(&pair, None, gamma, Algo::Block, &mut rng_b, &fresh);
            let (_, tg, _) = run_iteration(&pair, None, gamma, Algo::Greedy, &mut rng_g, &fresh);
            accb += tb;
            accg += tg;
        }
        assert!(accg as f64 >= accb as f64 * 0.995, "greedy {accg} < block {accb}");
    }
}
