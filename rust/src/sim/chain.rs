//! Synthetic (target, draft) model pairs for distribution-level studies.
//!
//! The paper's claims (Theorems 1/2, the §2 example) are statements about
//! *pairs of conditional distributions* — no transformer needed.  This
//! module provides cheap model pairs over which block efficiency, the
//! optimality bound, and losslessness can be measured exactly (small cases)
//! or by Monte Carlo, independent of the NN serving substrate.

use crate::verify::dist::normalize;
use crate::verify::Rng;

/// A pair of order-1 Markov language models over a small vocabulary: the
/// next-token distribution depends only on the previous token.
#[derive(Clone, Debug)]
pub struct MarkovPair {
    pub vocab: usize,
    /// target rows: `vocab` distributions of length `vocab` (row = prev tok)
    target: Vec<Vec<f64>>,
    draft: Vec<Vec<f64>>,
    /// initial distributions (empty-context row)
    target0: Vec<f64>,
    draft0: Vec<f64>,
}

impl MarkovPair {
    /// A random pair whose draft is a `mix`-interpolation between the
    /// target and an independent random model: `mix = 1` ⇒ draft == target
    /// (perfect drafter), `mix = 0` ⇒ unrelated drafter.
    pub fn random(vocab: usize, mix: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let row = |rng: &mut Rng| {
            let mut w: Vec<f64> = (0..vocab).map(|_| rng.uniform().powi(2) + 1e-3).collect();
            normalize(&mut w);
            w
        };
        let target: Vec<Vec<f64>> = (0..vocab).map(|_| row(&mut rng)).collect();
        let noise: Vec<Vec<f64>> = (0..vocab).map(|_| row(&mut rng)).collect();
        let draft: Vec<Vec<f64>> = target
            .iter()
            .zip(&noise)
            .map(|(t, n)| {
                let mut d: Vec<f64> =
                    t.iter().zip(n).map(|(a, b)| mix * a + (1.0 - mix) * b).collect();
                normalize(&mut d);
                d
            })
            .collect();
        let target0 = row(&mut rng);
        let mut draft0: Vec<f64> = target0
            .iter()
            .zip(row(&mut rng).iter())
            .map(|(a, b)| mix * a + (1.0 - mix) * b)
            .collect();
        normalize(&mut draft0);
        Self { vocab, target, draft, target0, draft0 }
    }

    /// Context-independent pair (every row identical) — the paper's §2
    /// Bernoulli setting generalised to any vocab.
    pub fn iid(target: Vec<f64>, draft: Vec<f64>) -> Self {
        let vocab = target.len();
        assert_eq!(vocab, draft.len());
        Self {
            vocab,
            target: vec![target.clone(); vocab],
            draft: vec![draft.clone(); vocab],
            target0: target,
            draft0: draft,
        }
    }

    #[inline]
    pub fn target_row(&self, ctx_last: Option<u32>) -> &[f64] {
        match ctx_last {
            Some(t) => &self.target[t as usize],
            None => &self.target0,
        }
    }

    #[inline]
    pub fn draft_row(&self, ctx_last: Option<u32>) -> &[f64] {
        match ctx_last {
            Some(t) => &self.draft[t as usize],
            None => &self.draft0,
        }
    }

    /// Expected per-token acceptance `1 - TV` averaged over target rows —
    /// a quick drafter-quality diagnostic.
    pub fn mean_overlap(&self) -> f64 {
        let overlap = |p: &[f64], q: &[f64]| -> f64 {
            p.iter().zip(q).map(|(a, b)| a.min(*b)).sum()
        };
        let s: f64 = self
            .target
            .iter()
            .zip(&self.draft)
            .map(|(t, d)| overlap(t, d))
            .sum::<f64>()
            + overlap(&self.target0, &self.draft0);
        s / (self.vocab + 1) as f64
    }
}

/// The §2 motivating example: vocab {A=0, B=1}, `M_b = (1/3, 2/3)`,
/// `M_s = (2/3, 1/3)`, context-independent.
pub fn bernoulli_example() -> MarkovPair {
    MarkovPair::iid(vec![1.0 / 3.0, 2.0 / 3.0], vec![2.0 / 3.0, 1.0 / 3.0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_distributions() {
        let p = MarkovPair::random(8, 0.7, 3);
        for t in 0..8 {
            let s: f64 = p.target_row(Some(t as u32)).iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            let s: f64 = p.draft_row(Some(t as u32)).iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn mix_controls_overlap() {
        let hi = MarkovPair::random(8, 0.95, 3).mean_overlap();
        let lo = MarkovPair::random(8, 0.2, 3).mean_overlap();
        assert!(hi > lo, "{hi} vs {lo}");
        assert!(MarkovPair::random(8, 1.0, 3).mean_overlap() > 0.999);
    }

    #[test]
    fn bernoulli_overlap_is_two_thirds() {
        let p = bernoulli_example();
        assert!((p.mean_overlap() - 2.0 / 3.0).abs() < 1e-12);
    }
}
