//! `specd` — a speculative-decoding serving stack reproducing
//! *Block Verification Accelerates Speculative Decoding* (ICLR 2025).
//!
//! Three-layer architecture:
//! * L3 (this crate): request routing, continuous batching, KV-slot
//!   management, spec-dec scheduling, metrics, CLI.
//! * L2 (python/compile/model.py): JAX transformer LMs, AOT-lowered to HLO
//!   text programs loaded by [`runtime`].
//! * L1 (python/compile/kernels/): Pallas verification + attention kernels,
//!   lowered into the same HLO programs.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` plus weights, and the rust binary is self-contained
//! afterwards.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod util;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod stats;
pub mod verify;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
