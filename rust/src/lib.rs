//! `specd` — a speculative-decoding serving stack reproducing
//! *Block Verification Accelerates Speculative Decoding* (ICLR 2025).
//!
//! Three-layer architecture (DESIGN.md):
//! * L3 (this crate): request routing, continuous batching, KV-slot
//!   management, spec-dec scheduling, metrics, CLI.
//! * L2: the model forward passes, behind the [`backend::Backend`] trait —
//!   either the pure-Rust CPU transformer ([`backend::NativeBackend`],
//!   always available, hermetic) or AOT-lowered HLO programs from
//!   `python/compile/model.py` executed via PJRT
//!   (`backend::PjrtBackend`, behind the `pjrt` cargo feature).
//! * L1: the verification + attention kernels — host implementations in
//!   [`verify`] (used directly by the native backend and the host-verify
//!   engine), Pallas-lowered twins inside the HLO programs on PJRT.
//!
//! Feature flags:
//! * default — no external dependencies, no artifacts required: the
//!   native backend initialises deterministic seeded weights
//!   ([`verify::Rng`]) and the whole stack (engines, HTTP serving,
//!   benches, paper tables) runs hermetically.  When an `artifacts/`
//!   bundle exists (`make artifacts`), the native backend loads its
//!   trained weights instead.
//! * `pjrt` — additionally compiles [`runtime::pjrt`] and
//!   `backend::pjrt` against the `xla` crate (vendored as an API stub;
//!   swap in the real crate to execute HLO).
//!
//! Python never runs on the request path: it only produces artifacts.

pub mod backend;
pub mod bench;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod draftset;
pub mod engine;
pub mod experiments;
pub mod util;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod sim;
pub mod stats;
pub mod verify;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
