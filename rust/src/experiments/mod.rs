//! The paper-reproduction harness: every table and figure in the paper's
//! evaluation maps to a function here (experiment index in DESIGN.md §4).
//!
//! * Table 1  — [`Harness::table1`] (γ=8, xxs, 8 datasets, BE + wall-clock)
//! * Figure 3 — [`Harness::fig3`]  (avg BE/WS grid over γ × drafter)
//! * Figure 4 — [`Harness::fig4`]  (relative improvement series)
//! * Table 3  — [`Harness::table3`] (token vs block vs greedy BE)
//! * Tables 4–8 — [`Harness::appendix_table`] (per-dataset grids)
//! * §2 example — [`motivating_table`] (exact + MC, no artifacts needed)
//!
//! Each cell is averaged over the configured seeds with mean ± std, exactly
//! as the paper reports.  Wall-clock speedup is measured against the
//! autoregressive baseline on the same substrate (see DESIGN.md §8.3).

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::Mutex;

use anyhow::Result;

use crate::backend::{Backend, Precision};
use crate::config::ExperimentConfig;
use crate::engine::baseline::run_baseline_prompts;
use crate::engine::host::HostVerifyEngine;
use crate::engine::spec::SpecEngine;
use crate::engine::BatchReport;
use crate::sim;
use crate::stats::{paired_improvement, Cell};
use crate::verify::Algo;
use crate::workload::{paper_name, Dataset, DATASET_NAMES};

/// One measured cell: per-seed block efficiencies and throughputs.
#[derive(Clone, Debug, Default)]
pub struct Measurement {
    pub be: Vec<f64>,
    pub tokens_per_sec: Vec<f64>,
}

impl Measurement {
    pub fn be_cell(&self) -> Cell {
        Cell::from_samples(&self.be)
    }
}

/// Experiment driver, generic over the execution backend; caches baseline
/// throughputs per (dataset, seed).
pub struct Harness<B: Backend> {
    pub backend: Arc<B>,
    pub cfg: ExperimentConfig,
    pub datasets: Vec<Dataset>,
    baseline_cache: Mutex<HashMap<(String, u64), f64>>,
    quiet: bool,
    /// Draft precision every cell's engine runs with (DESIGN.md §11);
    /// defaults to the env/int8 default, overridden from the config
    /// file's `engine.draft_precision` via
    /// [`Harness::with_draft_precision`].
    draft_precision: Precision,
}

impl<B: Backend> Harness<B> {
    pub fn new(backend: Arc<B>, cfg: ExperimentConfig) -> Result<Self> {
        let datasets =
            Dataset::load_or_synthetic(backend.info().artifacts_dir.as_deref())?;
        Ok(Harness {
            backend,
            cfg,
            datasets,
            baseline_cache: Mutex::new(HashMap::new()),
            quiet: false,
            draft_precision: Precision::from_env_or_default(),
        })
    }

    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Run every cell's drafter at the given precision (threads the
    /// config file's `engine.draft_precision` into the harness — the
    /// tables must honour the same knob `run`/`serve` do).
    pub fn with_draft_precision(mut self, p: Precision) -> Self {
        self.draft_precision = p;
        self
    }

    fn log(&self, msg: &str) {
        if !self.quiet {
            eprintln!("[harness] {msg}");
        }
    }

    fn dataset(&self, name: &str) -> &Dataset {
        self.datasets.iter().find(|d| d.name == name).expect("dataset loaded")
    }

    fn agg(reports: &[BatchReport]) -> (f64, f64) {
        let iters: usize = reports.iter().flat_map(|r| &r.rows).map(|x| x.iterations).sum();
        let toks: usize = reports.iter().flat_map(|r| &r.rows).map(|x| x.emitted).sum();
        let out_toks: usize =
            reports.iter().flat_map(|r| &r.rows).map(|x| x.tokens.len()).sum();
        let wall: f64 = reports.iter().map(|r| r.wall.as_secs_f64()).sum();
        let be = if iters == 0 { 0.0 } else { toks as f64 / iters as f64 };
        let tps = if wall == 0.0 { 0.0 } else { out_toks as f64 / wall };
        (be, tps)
    }

    /// Tokens/sec of the autoregressive baseline (cached per dataset/seed).
    pub fn baseline_tps(&self, ds_name: &str, seed: u64) -> Result<f64> {
        if let Some(v) = self.baseline_cache.lock().unwrap().get(&(ds_name.into(), seed)) {
            return Ok(*v);
        }
        let prompts = self.dataset(ds_name).take(self.cfg.prompts_per_dataset);
        let reports =
            run_baseline_prompts(&*self.backend, &prompts, self.cfg.max_new_tokens, seed)?;
        let (_, tps) = Self::agg(&reports);
        self.baseline_cache.lock().unwrap().insert((ds_name.into(), seed), tps);
        Ok(tps)
    }

    /// Measure one (dataset, algo, drafter, gamma) cell across seeds.
    pub fn run_cell(
        &self,
        ds_name: &str,
        algo: Algo,
        drafter: &str,
        gamma: usize,
    ) -> Result<Measurement> {
        let prompts = self.dataset(ds_name).take(self.cfg.prompts_per_dataset);
        let mut m = Measurement::default();
        for &seed in &self.cfg.seeds {
            let cfg = crate::config::EngineConfig {
                gamma,
                algo,
                drafter: drafter.to_string(),
                max_new_tokens: self.cfg.max_new_tokens,
                host_verify: !algo.fused(),
                seed,
                draft_precision: self.draft_precision,
            };
            let reports = if algo.fused() {
                SpecEngine::new(self.backend.clone(), cfg)?.run_prompts(&prompts, seed)?
            } else {
                HostVerifyEngine::new(self.backend.clone(), cfg)?.run_prompts(&prompts, seed)?
            };
            let (be, tps) = Self::agg(&reports);
            m.be.push(be);
            m.tokens_per_sec.push(tps);
        }
        self.log(&format!(
            "{ds_name} {algo} {drafter} g{gamma}: BE {:.3} tps {:.1}",
            m.be_cell().mean,
            m.tokens_per_sec.iter().sum::<f64>() / m.tokens_per_sec.len().max(1) as f64
        ));
        Ok(m)
    }

    /// Wall-clock speedups per seed for a measurement on a dataset.
    pub fn speedups(&self, ds_name: &str, m: &Measurement) -> Result<Vec<f64>> {
        self.cfg
            .seeds
            .iter()
            .zip(&m.tokens_per_sec)
            .map(|(&seed, &tps)| Ok(tps / self.baseline_tps(ds_name, seed)?.max(1e-9)))
            .collect()
    }

    // ---------------------------------------------------------------------
    // Table generators
    // ---------------------------------------------------------------------

    /// Paper Table 1 (and Tables 4–8 via `drafter`/`gamma`): per-dataset
    /// TokenV vs BlockV, block efficiency + wall-clock speedup.
    pub fn speedup_table(&self, drafter: &str, gamma: usize) -> Result<String> {
        let mut out = String::new();
        out.push_str(&format!(
            "Speedup comparison: TokenV vs BlockV, gamma={gamma}, drafter={drafter}\n"
        ));
        out.push_str(&format!(
            "{:<12} {:>13} {:>13} {:>9} | {:>13} {:>13} {:>9}\n",
            "Dataset", "TokenV BE", "BlockV BE", "Impr.%", "TokenV WS", "BlockV WS", "Impr.%"
        ));
        let (mut sum_bt, mut sum_bb, mut sum_ib) = (0.0, 0.0, 0.0);
        let (mut sum_wt, mut sum_wb, mut sum_iw) = (0.0, 0.0, 0.0);
        for ds in DATASET_NAMES {
            let mt = self.run_cell(ds, Algo::Token, drafter, gamma)?;
            let mb = self.run_cell(ds, Algo::Block, drafter, gamma)?;
            let wt = self.speedups(ds, &mt)?;
            let wb = self.speedups(ds, &mb)?;
            let be_t = mt.be_cell();
            let be_b = mb.be_cell();
            let imp_be = paired_improvement(&mt.be, &mb.be);
            let ws_t = Cell::from_samples(&wt);
            let ws_b = Cell::from_samples(&wb);
            let imp_ws = paired_improvement(&wt, &wb);
            out.push_str(&format!(
                "{:<12} {:>13} {:>13} {:>9} | {:>13} {:>13} {:>9}\n",
                paper_name(ds),
                be_t.to_string(),
                be_b.to_string(),
                format!("{:+.2}", imp_be.mean),
                ws_t.to_string(),
                ws_b.to_string(),
                format!("{:+.2}", imp_ws.mean),
            ));
            sum_bt += be_t.mean;
            sum_bb += be_b.mean;
            sum_ib += imp_be.mean;
            sum_wt += ws_t.mean;
            sum_wb += ws_b.mean;
            sum_iw += imp_ws.mean;
        }
        let n = DATASET_NAMES.len() as f64;
        out.push_str(&format!(
            "{:<12} {:>13.2} {:>13.2} {:>9} | {:>13.2} {:>13.2} {:>9}\n",
            "Average",
            sum_bt / n,
            sum_bb / n,
            format!("{:+.2}", sum_ib / n),
            sum_wt / n,
            sum_wb / n,
            format!("{:+.2}", sum_iw / n),
        ));
        Ok(out)
    }

    pub fn table1(&self) -> Result<String> {
        self.speedup_table("xxs", 8)
    }

    /// Averages across datasets for one (drafter, gamma, algo).
    fn averages(&self, drafter: &str, gamma: usize, algo: Algo) -> Result<(f64, f64)> {
        let (mut be_sum, mut ws_sum) = (0.0, 0.0);
        for ds in DATASET_NAMES {
            let m = self.run_cell(ds, algo, drafter, gamma)?;
            let ws = self.speedups(ds, &m)?;
            be_sum += m.be_cell().mean;
            ws_sum += ws.iter().sum::<f64>() / ws.len() as f64;
        }
        let n = DATASET_NAMES.len() as f64;
        Ok((be_sum / n, ws_sum / n))
    }

    /// Paper Figure 3: avg BE and wall-clock speedup per γ × drafter.
    pub fn fig3(&self) -> Result<String> {
        let mut out = String::from(
            "Figure 3: average BE / WS across datasets\n  γ  drafter |  TokenV BE  TokenV WS |  BlockV BE  BlockV WS\n",
        );
        for &gamma in &self.backend.info().gammas.clone() {
            for drafter in ["xxs", "xxxs"] {
                let (bt, wt) = self.averages(drafter, gamma, Algo::Token)?;
                let (bb, wb) = self.averages(drafter, gamma, Algo::Block)?;
                out.push_str(&format!(
                    "  {gamma}  {drafter:<7} | {bt:>10.2} {wt:>10.2} | {bb:>10.2} {wb:>10.2}\n"
                ));
            }
        }
        Ok(out)
    }

    /// Paper Figure 4: relative improvement (%) of BlockV over TokenV in BE
    /// and WS per γ × drafter, rendered as an ASCII series.
    pub fn fig4(&self) -> Result<String> {
        let mut out =
            String::from("Figure 4: relative improvement of BlockV over TokenV (%)\n");
        for drafter in ["xxs", "xxxs"] {
            out.push_str(&format!("  drafter {drafter}:\n"));
            for &gamma in &self.backend.info().gammas.clone() {
                let (bt, wt) = self.averages(drafter, gamma, Algo::Token)?;
                let (bb, wb) = self.averages(drafter, gamma, Algo::Block)?;
                let ibe = (bb - bt) / bt * 100.0;
                let iws = (wb - wt) / wt * 100.0;
                let bar = |v: f64| "#".repeat((v.max(0.0) * 2.0).round() as usize);
                out.push_str(&format!(
                    "    γ={gamma}: BE {ibe:+6.2}% {:<24} WS {iws:+6.2}% {}\n",
                    bar(ibe),
                    bar(iws)
                ));
            }
        }
        Ok(out)
    }

    /// Paper Table 3: token vs block vs greedy block efficiency (γ=8, xxs).
    pub fn table3(&self) -> Result<String> {
        let mut out = String::from(
            "Table 3: block efficiency, gamma=8, drafter=xxs\nDataset      TokenV   BlockV   GreedyBlockV\n",
        );
        for ds in DATASET_NAMES {
            let t = self.run_cell(ds, Algo::Token, "xxs", 8)?.be_cell();
            let b = self.run_cell(ds, Algo::Block, "xxs", 8)?.be_cell();
            let g = self.run_cell(ds, Algo::Greedy, "xxs", 8)?.be_cell();
            out.push_str(&format!(
                "{:<12} {:>7.2} {:>8.2} {:>13.2}\n",
                paper_name(ds),
                t.mean,
                b.mean,
                g.mean
            ));
        }
        Ok(out)
    }

    /// Appendix Tables 4–8.
    pub fn appendix_table(&self, idx: usize) -> Result<String> {
        let (drafter, gamma) = match idx {
            4 => ("xxs", 4),
            5 => ("xxs", 6),
            6 => ("xxxs", 4),
            7 => ("xxxs", 6),
            8 => ("xxxs", 8),
            _ => anyhow::bail!("appendix tables are 4..=8"),
        };
        Ok(format!("Table {idx}:\n{}", self.speedup_table(drafter, gamma)?))
    }
}

/// §2 motivating example (E0) — pure simulator, no artifacts required.
pub fn motivating_table() -> String {
    let r = sim::motivating_example(400_000, 42);
    format!(
        "Motivating example (paper §2): E[accepted tokens], gamma=2\n\
         {:<28} {:>8} {:>12}\n\
         {:<28} {:>8.4} {:>12.4}\n\
         {:<28} {:>8.4} {:>12.4}\n\
         {:<28} {:>8.4} {:>12}\n",
        "algorithm", "exact", "monte-carlo",
        "token verification (10/9)", r.exact_token, r.mc_token,
        "block verification (11/9)", r.exact_block, r.mc_block,
        "full-info ideal (12/9)", r.exact_ideal, "-",
    )
}
