//! Evaluation workloads: the eight synthetic "datasets" (paper Table 1
//! rows).  Canonical prompts are generated at artifact-build time by
//! python/compile/corpus.py and shipped as `artifacts/prompts_<ds>.json`
//! so the serving workload is guaranteed in-distribution for the trained
//! models; this module loads them and hands out deterministic slices.

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::util::json;
use crate::verify::Rng;

/// Dataset order matches paper Table 1 (and corpus.PROFILES).
pub const DATASET_NAMES: [&str; 8] =
    ["lm1b", "gptprompt", "webqa", "piqa", "sharegpt", "xsum", "gsm8k", "wmt"];

/// Human-readable mapping to the paper's datasets (the substitution).
pub fn paper_name(ds: &str) -> &'static str {
    match ds {
        "lm1b" => "LM1B",
        "gptprompt" => "GPT Prompt",
        "webqa" => "WebQA",
        "piqa" => "PIQA",
        "sharegpt" => "ShareGPT",
        "xsum" => "XSum",
        "gsm8k" => "GSM8K",
        "wmt" => "WMT-DeEn",
        _ => "?",
    }
}

/// A loaded prompt set.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub prompts: Vec<Vec<u32>>,
}

impl Dataset {
    pub fn load(artifacts_dir: &Path, name: &str) -> anyhow::Result<Self> {
        let path = artifacts_dir.join(format!("prompts_{name}.json"));
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&raw).with_context(|| format!("parsing {}", path.display()))?;
        let prompts: Vec<Vec<u32>> = v
            .as_arr()
            .ok_or_else(|| anyhow!("prompts file is not an array"))?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| anyhow!("prompt is not an array"))
                    .map(|toks| {
                        toks.iter().map(|t| t.as_u64().unwrap_or(0) as u32).collect()
                    })
            })
            .collect::<anyhow::Result<_>>()?;
        if prompts.is_empty() {
            return Err(anyhow!("dataset {name} has no prompts"));
        }
        Ok(Dataset { name: name.to_string(), prompts })
    }

    pub fn load_all(artifacts_dir: &Path) -> anyhow::Result<Vec<Dataset>> {
        DATASET_NAMES.iter().map(|n| Dataset::load(artifacts_dir, n)).collect()
    }

    /// First `n` prompts (the paper decodes "the first 1000 prompts").
    pub fn take(&self, n: usize) -> Vec<Vec<u32>> {
        self.prompts.iter().take(n).cloned().collect()
    }

    /// A seeded shuffle-sample for load tests / the HTTP demo.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed ^ 0x5eed_da7a);
        (0..n).map(|_| self.prompts[rng.below(self.prompts.len())].clone()).collect()
    }

    pub fn mean_prompt_len(&self) -> f64 {
        self.prompts.iter().map(|p| p.len() as f64).sum::<f64>() / self.prompts.len() as f64
    }
}

/// Manifest-declared dataset info (for validation).
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub file: String,
    pub marker: u32,
    pub count: usize,
    pub mean_len: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_names_cover_all() {
        for ds in DATASET_NAMES {
            assert_ne!(paper_name(ds), "?");
        }
        assert_eq!(paper_name("nope"), "?");
    }

    #[test]
    fn take_and_sample() {
        let ds = Dataset {
            name: "t".into(),
            prompts: vec![vec![1, 3, 20], vec![1, 3, 21], vec![1, 3, 22]],
        };
        assert_eq!(ds.take(2).len(), 2);
        let s1 = ds.sample(5, 9);
        let s2 = ds.sample(5, 9);
        assert_eq!(s1, s2, "sampling must be deterministic per seed");
        assert!((ds.mean_prompt_len() - 3.0).abs() < 1e-12);
    }
}
