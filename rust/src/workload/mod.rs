//! Evaluation workloads: the eight synthetic "datasets" (paper Table 1
//! rows).  Canonical prompts are generated at artifact-build time by
//! python/compile/corpus.py and shipped as `artifacts/prompts_<ds>.json`
//! so the serving workload is guaranteed in-distribution for the trained
//! models; this module loads them and hands out deterministic slices.
//!
//! When no artifact bundle exists (the hermetic native-backend mode),
//! [`Dataset::synthetic`] generates deterministic in-layout prompts —
//! `[BOS, domain marker, content...]` with per-dataset length profiles
//! mirroring `corpus.PROFILES` — so every engine path and the HTTP demo
//! run without python having ever executed.

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::models::vocab;
use crate::util::json;
use crate::verify::Rng;

/// Dataset order matches paper Table 1 (and corpus.PROFILES).
pub const DATASET_NAMES: [&str; 8] =
    ["lm1b", "gptprompt", "webqa", "piqa", "sharegpt", "xsum", "gsm8k", "wmt"];

/// Human-readable mapping to the paper's datasets (the substitution).
pub fn paper_name(ds: &str) -> &'static str {
    match ds {
        "lm1b" => "LM1B",
        "gptprompt" => "GPT Prompt",
        "webqa" => "WebQA",
        "piqa" => "PIQA",
        "sharegpt" => "ShareGPT",
        "xsum" => "XSum",
        "gsm8k" => "GSM8K",
        "wmt" => "WMT-DeEn",
        _ => "?",
    }
}

/// A loaded prompt set.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub prompts: Vec<Vec<u32>>,
}

impl Dataset {
    pub fn load(artifacts_dir: &Path, name: &str) -> anyhow::Result<Self> {
        let path = artifacts_dir.join(format!("prompts_{name}.json"));
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&raw).with_context(|| format!("parsing {}", path.display()))?;
        let prompts: Vec<Vec<u32>> = v
            .as_arr()
            .ok_or_else(|| anyhow!("prompts file is not an array"))?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| anyhow!("prompt is not an array"))
                    .map(|toks| {
                        toks.iter().map(|t| t.as_u64().unwrap_or(0) as u32).collect()
                    })
            })
            .collect::<anyhow::Result<_>>()?;
        if prompts.is_empty() {
            return Err(anyhow!("dataset {name} has no prompts"));
        }
        Ok(Dataset { name: name.to_string(), prompts })
    }

    pub fn load_all(artifacts_dir: &Path) -> anyhow::Result<Vec<Dataset>> {
        DATASET_NAMES.iter().map(|n| Dataset::load(artifacts_dir, n)).collect()
    }

    /// Deterministic synthetic prompt set for one dataset: `[BOS, marker,
    /// content...]` rows with the dataset's corpus length profile.
    ///
    /// Lengths target the standard serving ring (`L = 96`): the longest
    /// prompt is 34 tokens, comfortably under the engine's `len < L/2`
    /// layout guard.  Tests running on smaller custom rings build their
    /// own prompts instead.
    pub fn synthetic(name: &str, count: usize, seed: u64) -> anyhow::Result<Dataset> {
        let idx = DATASET_NAMES
            .iter()
            .position(|&n| n == name)
            .ok_or_else(|| anyhow!("unknown dataset '{name}'"))? as u32;
        // (min, max) content-token counts, mirroring corpus.PROFILES.
        let (lo, hi) = [(8, 28), (10, 30), (6, 20), (8, 24), (12, 32), (14, 32), (10, 26), (10, 28)]
            [idx as usize];
        let mut rng = Rng::new(seed ^ 0x5f17_7e71c ^ ((idx as u64) << 32));
        let span = (vocab::SIZE - vocab::CONTENT_BASE) as usize;
        let prompts = (0..count.max(1))
            .map(|_| {
                let n = lo + rng.below(hi - lo + 1);
                let mut p = vec![vocab::BOS, vocab::marker_for(idx)];
                for _ in 0..n {
                    p.push(vocab::CONTENT_BASE + rng.below(span) as u32);
                }
                p
            })
            .collect();
        Ok(Dataset { name: name.to_string(), prompts })
    }

    /// Canonical prompt sets from the artifact bundle when one is present,
    /// synthetic prompts otherwise (the hermetic native-backend mode).
    pub fn load_or_synthetic(artifacts_dir: Option<&Path>) -> anyhow::Result<Vec<Dataset>> {
        match artifacts_dir {
            Some(dir) if dir.join(format!("prompts_{}.json", DATASET_NAMES[0])).exists() => {
                Self::load_all(dir)
            }
            _ => DATASET_NAMES
                .iter()
                .map(|n| Dataset::synthetic(n, 256, 0x5eed))
                .collect(),
        }
    }

    /// First `n` prompts (the paper decodes "the first 1000 prompts").
    pub fn take(&self, n: usize) -> Vec<Vec<u32>> {
        self.prompts.iter().take(n).cloned().collect()
    }

    /// A seeded shuffle-sample for load tests / the HTTP demo.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed ^ 0x5eed_da7a);
        (0..n).map(|_| self.prompts[rng.below(self.prompts.len())].clone()).collect()
    }

    pub fn mean_prompt_len(&self) -> f64 {
        self.prompts.iter().map(|p| p.len() as f64).sum::<f64>() / self.prompts.len() as f64
    }
}

/// Manifest-declared dataset info (for validation).
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub file: String,
    pub marker: u32,
    pub count: usize,
    pub mean_len: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_names_cover_all() {
        for ds in DATASET_NAMES {
            assert_ne!(paper_name(ds), "?");
        }
        assert_eq!(paper_name("nope"), "?");
    }

    #[test]
    fn synthetic_prompts_are_well_formed_and_deterministic() {
        for name in DATASET_NAMES {
            let a = Dataset::synthetic(name, 32, 1).unwrap();
            let b = Dataset::synthetic(name, 32, 1).unwrap();
            assert_eq!(a.prompts, b.prompts, "{name} must be seed-deterministic");
            let c = Dataset::synthetic(name, 32, 2).unwrap();
            assert_ne!(a.prompts, c.prompts, "{name} must vary with the seed");
            for p in &a.prompts {
                // 2 control tokens + the profile's (lo, hi) content range;
                // must stay under the L/2 = 48 layout guard.
                assert!(p.len() >= 8 && p.len() <= 34);
                assert_eq!(p[0], vocab::BOS);
                assert!(vocab::is_control(p[1]) && p[1] >= vocab::MARKER_BASE);
                assert!(p[2..].iter().all(|&t| t >= vocab::CONTENT_BASE && t < vocab::SIZE));
            }
        }
        assert!(Dataset::synthetic("nope", 4, 0).is_err());
        let all = Dataset::load_or_synthetic(None).unwrap();
        assert_eq!(all.len(), DATASET_NAMES.len());
    }

    #[test]
    fn take_and_sample() {
        let ds = Dataset {
            name: "t".into(),
            prompts: vec![vec![1, 3, 20], vec![1, 3, 21], vec![1, 3, 22]],
        };
        assert_eq!(ds.take(2).len(), 2);
        let s1 = ds.sample(5, 9);
        let s2 = ds.sample(5, 9);
        assert_eq!(s1, s2, "sampling must be deterministic per seed");
        assert!((ds.mean_prompt_len() - 3.0).abs() < 1e-12);
    }
}
