//! Fused-path engine: one PJRT call per SpecDec iteration.
//!
//! State layout (see python/compile/model.py docstring for the contract):
//! `tokens (B, L) i32`, `length (B,) i32`, KV caches for target + drafter.
//! All five state tensors stay device-resident between iterations when the
//! PJRT build unтuples outputs; otherwise they round-trip as literals
//! (handled transparently by [`StateHandle`]).

use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use crate::config::EngineConfig;
use crate::metrics::EngineMetrics;
use crate::models::vocab;
use crate::runtime::{literal, Runtime, StateHandle};
use crate::verify::Rng;

use super::{pad_prompts, BatchReport, RowTracker};

/// The fused speculative-decoding engine.
pub struct SpecEngine {
    rt: Arc<Runtime>,
    pub cfg: EngineConfig,
    pub metrics: Arc<EngineMetrics>,
}

impl SpecEngine {
    pub fn new(rt: Arc<Runtime>, cfg: EngineConfig) -> anyhow::Result<Self> {
        if !cfg.algo.fused() {
            return Err(anyhow!(
                "algo {} requires the host-verify engine (engine::host)",
                cfg.algo
            ));
        }
        if !rt.manifest.gammas.contains(&cfg.gamma) {
            return Err(anyhow!(
                "gamma {} not exported (available: {:?}) — re-run make artifacts",
                cfg.gamma,
                rt.manifest.gammas
            ));
        }
        Ok(SpecEngine { rt, cfg, metrics: Arc::new(EngineMetrics::default()) })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Build the (tokens, length) literals for a padded prompt batch.
    pub(crate) fn prompt_literals(
        rt: &Runtime,
        prompts: &[Vec<u32>],
    ) -> anyhow::Result<(xla::Literal, xla::Literal, Vec<usize>)> {
        let b = rt.manifest.batch;
        let l = rt.manifest.max_len;
        let mut toks = vec![vocab::PAD as i32; b * l];
        let mut lens = vec![0i32; b];
        let mut prompt_lens = Vec::with_capacity(b);
        for (i, p) in prompts.iter().enumerate() {
            assert!(p.len() >= 2, "prompts need >= 2 tokens (BOS + marker)");
            assert!(p.len() < l / 2, "prompt too long for max_len {l}");
            for (j, &t) in p.iter().enumerate() {
                toks[i * l + j] = t as i32;
            }
            lens[i] = p.len() as i32;
            prompt_lens.push(p.len());
        }
        Ok((
            literal::i32_literal(&toks, &[b, l])?,
            literal::i32_literal(&lens, &[b])?,
            prompt_lens,
        ))
    }

    /// Run one padded batch of prompts to completion (batch drain).
    pub fn run_batch(&self, prompts: &[Vec<u32>], seed: u64) -> anyhow::Result<BatchReport> {
        let rt = &*self.rt;
        let b = rt.manifest.batch;
        let gamma = self.cfg.gamma;
        let t_start = Instant::now();

        let n_real = prompts.len();
        let padded = pad_prompts(prompts, b);
        let (tok_lit, len_lit, _) = Self::prompt_literals(rt, &padded)?;

        // --- prefill both models -------------------------------------------------
        let w_t = rt.weights("target")?;
        let w_d = rt.weights(&self.cfg.drafter)?;
        let tok_buf = rt.upload(tok_lit)?;
        let len_buf = rt.upload(len_lit)?;

        let prefill_t = rt.program("prefill_target")?;
        let prefill_d = rt.program(&format!("prefill_{}", self.cfg.drafter))?;
        let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let kv_t = rt.execute(prefill_t, &args)?.into_handles();
        let mut args: Vec<&xla::PjRtBuffer> = w_d.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let kv_d = rt.execute(prefill_d, &args)?.into_handles();
        let [kvt_k, kvt_v] = <[StateHandle; 2]>::try_from(kv_t)
            .map_err(|_| anyhow!("prefill target: expected 2 outputs"))?;
        let [kvd_k, kvd_v] = <[StateHandle; 2]>::try_from(kv_d)
            .map_err(|_| anyhow!("prefill drafter: expected 2 outputs"))?;

        // --- iterate --------------------------------------------------------------
        let iter_prog = rt.program(&rt.manifest.spec_iter_name(
            self.cfg.algo.name(),
            &self.cfg.drafter,
            gamma,
        ))?;

        let mut trackers: Vec<RowTracker> = (0..b)
            .map(|i| RowTracker::new(i < n_real, self.cfg.max_new_tokens))
            .collect();
        let mut state = SpecState {
            tokens: StateHandle::Buf(tok_buf),
            length: StateHandle::Buf(len_buf),
            kvt_k,
            kvt_v,
            kvd_k,
            kvd_v,
        };
        let mut seed_rng = Rng::new(seed ^ SEED_DOMAIN);
        let mut device_iterations = 0usize;
        // Hard cap: every row emits >= 1 token per iteration.
        let max_iters = self.cfg.max_new_tokens + rt.manifest.max_len;

        while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
            let t_iter = Instant::now();
            let seed_lit = literal::i32_scalar(seed_rng.next_u64() as i32)?;
            let seed_buf = rt.upload(seed_lit)?;

            // Materialise state buffers (no-op on the untupled layout).
            let bufs = state.into_buffers(rt)?;
            let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
            args.extend(w_d.iter());
            args.push(&bufs.tokens);
            args.push(&bufs.length);
            args.push(&bufs.kvt_k);
            args.push(&bufs.kvt_v);
            args.push(&bufs.kvd_k);
            args.push(&bufs.kvd_v);
            args.push(&seed_buf);
            let out = rt.execute(iter_prog, &args)?;

            // outs: tokens, length, kvt_k, kvt_v, kvd_k, kvd_v, tau, emitted, done
            let tau = out.i32s(6)?;
            let emitted = out.i32s(7)?;
            let done = out.i32s(8)?;
            let mut handles = out.into_handles();
            // drain order: reverse-pop to move out without clones
            let _ = handles.split_off(6); // small outputs already read
            let kvd_v = handles.pop().unwrap();
            let kvd_k = handles.pop().unwrap();
            let kvt_v = handles.pop().unwrap();
            let kvt_k = handles.pop().unwrap();
            let length = handles.pop().unwrap();
            let tokens = handles.pop().unwrap();
            state = SpecState { tokens, length, kvt_k, kvt_v, kvd_k, kvd_v };

            for (i, tr) in trackers.iter_mut().enumerate() {
                if !tr.active() {
                    continue;
                }
                let t_i = tau[i] as usize;
                let row: Vec<u32> = emitted[i * (gamma + 1)..i * (gamma + 1) + t_i + 1]
                    .iter()
                    .map(|&x| x as u32)
                    .collect();
                tr.absorb(&row, t_i, done[i] != 0);
                self.metrics.tokens_emitted.add(row.len() as u64);
                self.metrics.drafts_accepted.add(t_i as u64);
                self.metrics.iterations.inc();
            }
            device_iterations += 1;
            self.metrics.iter_latency.observe(t_iter.elapsed());
        }

        self.metrics.batches.inc();
        // All outputs of the final iteration were read back above, so every
        // outstanding upload copy has completed — safe to release the pins.
        rt.clear_pinned();
        let rows = trackers
            .into_iter()
            .take(n_real)
            .map(|t| t.into_result())
            .collect();
        Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
    }

    /// Convenience: run many prompts in consecutive batches of `B`.
    pub fn run_prompts(
        &self,
        prompts: &[Vec<u32>],
        seed: u64,
    ) -> anyhow::Result<Vec<BatchReport>> {
        let b = self.rt.manifest.batch;
        prompts
            .chunks(b)
            .enumerate()
            .map(|(i, chunk)| self.run_batch(chunk, seed.wrapping_add(i as u64 * 7919)))
            .collect()
    }
}

struct SpecState {
    tokens: StateHandle,
    length: StateHandle,
    kvt_k: StateHandle,
    kvt_v: StateHandle,
    kvd_k: StateHandle,
    kvd_v: StateHandle,
}

struct SpecBuffers {
    tokens: xla::PjRtBuffer,
    length: xla::PjRtBuffer,
    kvt_k: xla::PjRtBuffer,
    kvt_v: xla::PjRtBuffer,
    kvd_k: xla::PjRtBuffer,
    kvd_v: xla::PjRtBuffer,
}

impl SpecState {
    fn into_buffers(self, rt: &Runtime) -> anyhow::Result<SpecBuffers> {
        Ok(SpecBuffers {
            tokens: self.tokens.ensure_buffer(rt)?,
            length: self.length.ensure_buffer(rt)?,
            kvt_k: self.kvt_k.ensure_buffer(rt)?,
            kvt_v: self.kvt_v.ensure_buffer(rt)?,
            kvd_k: self.kvd_k.ensure_buffer(rt)?,
            kvd_v: self.kvd_v.ensure_buffer(rt)?,
        })
    }
}

/// Domain separator for the per-iteration device seeds.
const SEED_DOMAIN: u64 = 0x5bec_dec0de;
