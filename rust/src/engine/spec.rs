//! Fused-path engine: one [`Backend::spec_iter`] call per SpecDec
//! iteration.
//!
//! State layout (see python/compile/model.py docstring for the contract):
//! `tokens (B, L) i32`, `length (B,) i32`, plus the two opaque per-model
//! KV caches the backend carries between iterations.  On PJRT the KV
//! tensors stay device-resident whenever the build untuples outputs; on
//! the native backend everything lives in host memory.  The engine only
//! ever sees host tensors and the backend trait.

use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use crate::backend::Backend;
use crate::config::EngineConfig;
use crate::metrics::EngineMetrics;
use crate::verify::Rng;

use super::{layout_prompts, pad_prompts, BatchReport, RowTracker};

/// The fused speculative-decoding engine, generic over the execution
/// backend.
pub struct SpecEngine<B: Backend> {
    backend: Arc<B>,
    pub cfg: EngineConfig,
    pub metrics: Arc<EngineMetrics>,
}

impl<B: Backend> SpecEngine<B> {
    pub fn new(backend: Arc<B>, cfg: EngineConfig) -> anyhow::Result<Self> {
        if !cfg.algo.fused() {
            return Err(anyhow!(
                "algo {} requires the host-verify engine (engine::host)",
                cfg.algo
            ));
        }
        let info = backend.info();
        if !info.supports_gamma(cfg.gamma) {
            return Err(anyhow!(
                "gamma {} not supported by the {} backend (available: {:?})",
                cfg.gamma,
                info.name,
                info.gammas
            ));
        }
        if !info.has_drafter(&cfg.drafter) {
            return Err(anyhow!(
                "drafter '{}' not served (available: {:?})",
                cfg.drafter,
                info.drafters
            ));
        }
        Ok(SpecEngine { backend, cfg, metrics: Arc::new(EngineMetrics::default()) })
    }

    pub fn backend(&self) -> &Arc<B> {
        &self.backend
    }

    /// Run one padded batch of prompts to completion (batch drain).
    pub fn run_batch(&self, prompts: &[Vec<u32>], seed: u64) -> anyhow::Result<BatchReport> {
        let backend = &*self.backend;
        let info = backend.info();
        let b = info.batch;
        let gamma = self.cfg.gamma;
        let t_start = Instant::now();

        let n_real = prompts.len();
        let padded = pad_prompts(prompts, b);
        let (mut tokens, mut length) = layout_prompts(info, &padded);

        // --- prefill both models ---------------------------------------------
        let mut kv_t = backend.prefill("target", &tokens, &length)?;
        let mut kv_d = backend.prefill(&self.cfg.drafter, &tokens, &length)?;

        // --- iterate ----------------------------------------------------------
        let mut trackers: Vec<RowTracker> = (0..b)
            .map(|i| RowTracker::new(i < n_real, self.cfg.max_new_tokens))
            .collect();
        let mut seed_rng = Rng::new(seed ^ SEED_DOMAIN);
        let mut device_iterations = 0usize;
        // Hard cap: every row emits >= 1 token per iteration.
        let max_iters = self.cfg.max_new_tokens + info.max_len;

        while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
            let t_iter = Instant::now();
            let iter_seed = seed_rng.next_u64() as i32;
            let out = backend.spec_iter(
                self.cfg.algo,
                &self.cfg.drafter,
                gamma,
                &mut tokens,
                &mut length,
                &mut kv_t,
                &mut kv_d,
                iter_seed,
            )?;

            for (i, tr) in trackers.iter_mut().enumerate() {
                if !tr.active() {
                    continue;
                }
                let t_i = out.tau[i] as usize;
                let row: Vec<u32> = out.emitted[i * (gamma + 1)..i * (gamma + 1) + t_i + 1]
                    .iter()
                    .map(|&x| x as u32)
                    .collect();
                tr.absorb(&row, t_i, out.done[i] != 0);
                self.metrics.tokens_emitted.add(row.len() as u64);
                self.metrics.drafts_accepted.add(t_i as u64);
                self.metrics.iterations.inc();
            }
            device_iterations += 1;
            self.metrics.iter_latency.observe(t_iter.elapsed());
        }

        self.metrics.batches.inc();
        backend.end_batch();
        let rows = trackers
            .into_iter()
            .take(n_real)
            .map(|t| t.into_result())
            .collect();
        Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
    }

    /// Convenience: run many prompts in consecutive batches of `B`.
    pub fn run_prompts(
        &self,
        prompts: &[Vec<u32>],
        seed: u64,
    ) -> anyhow::Result<Vec<BatchReport>> {
        let b = self.backend.info().batch;
        prompts
            .chunks(b)
            .enumerate()
            .map(|(i, chunk)| self.run_batch(chunk, seed.wrapping_add(i as u64 * 7919)))
            .collect()
    }
}

/// Domain separator for the per-iteration device seeds.
const SEED_DOMAIN: u64 = 0x5bec_dec0de;
