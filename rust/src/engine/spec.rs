//! Fused-path engine: one [`Backend::spec_iter`] call per SpecDec
//! iteration.
//!
//! State layout (see python/compile/model.py docstring for the contract):
//! `tokens (B, L) i32`, `length (B,) i32`, plus the two opaque per-model
//! KV caches the backend carries between iterations.  On PJRT the KV
//! tensors stay device-resident whenever the build untuples outputs; on
//! the native backend everything lives in host memory.  The engine only
//! ever sees host tensors and the backend trait.
//!
//! Two execution modes share the same state layout:
//! * [`SpecEngine::run_batch`] — batch drain: lay out a prompt batch,
//!   iterate until every real row finishes (the experiment harness path).
//! * the continuous stream — [`SpecEngine::begin_stream`] /
//!   [`SpecEngine::admit_row`] / [`SpecEngine::step_stream`] /
//!   [`SpecEngine::release_row`]: slots are admitted and released
//!   individually while decoding proceeds, with each admission splicing a
//!   freshly prefilled prompt into the live KV caches
//!   ([`Backend::kv_splice`]).  Per-row seeding ([`row_seed`]) makes the
//!   two modes produce identical tokens for identical row seeds
//!   (DESIGN.md §7).

use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use crate::backend::{Backend, KvLayout, PrefixSplice, RowSplice, SpecIterOut};
use crate::config::EngineConfig;
use crate::control::Controller;
use crate::metrics::EngineMetrics;
use crate::models::vocab;
use crate::verify::{Algo, Rng};

use super::{layout_prompts, pad_prompts, BatchReport, RowTracker};

/// The fused speculative-decoding engine, generic over the execution
/// backend.
pub struct SpecEngine<B: Backend> {
    backend: Arc<B>,
    pub cfg: EngineConfig,
    pub metrics: Arc<EngineMetrics>,
}

impl<B: Backend> SpecEngine<B> {
    pub fn new(backend: Arc<B>, cfg: EngineConfig) -> anyhow::Result<Self> {
        if !cfg.algo.fused() {
            return Err(anyhow!(
                "algo {} requires the host-verify engine (engine::host)",
                cfg.algo
            ));
        }
        if cfg.algo.paths() == 0 {
            return Err(anyhow!("multipath needs at least one draft path (k >= 1)"));
        }
        let info = backend.info();
        if !info.supports_gamma(cfg.gamma) {
            return Err(anyhow!(
                "gamma {} not supported by the {} backend (available: {:?})",
                cfg.gamma,
                info.name,
                info.gammas
            ));
        }
        if !info.has_drafter(&cfg.drafter) {
            return Err(anyhow!(
                "drafter '{}' not served (available: {:?})",
                cfg.drafter,
                info.drafters
            ));
        }
        // The KV layout lives with the backend (it owns the physical
        // caches); the config knob is advisory at engine level.  A
        // mismatch is harmless — both layouts are bit-identical — but it
        // means the operator's intent did not reach the backend
        // constructor, so surface it (warn-on-stderr convention).
        // Backends that cannot page at all (PJRT owns device-resident KV)
        // stay silent under the default paged config.
        let mismatch = (cfg.kv_layout == KvLayout::Paged) != info.paged_kv;
        if mismatch && (info.paged_kv || info.name == "native") {
            eprintln!(
                "specd: engine config wants kv_layout {} but backend '{}' serves {}; \
                 the backend's layout wins (construct it with the matching layout \
                 or set SPECD_KV_LAYOUT)",
                cfg.kv_layout,
                info.name,
                if info.paged_kv { KvLayout::Paged } else { KvLayout::Contig },
            );
        }
        // Let the backend size internal scratch for this configuration up
        // front (the native backend pre-allocates its persistent
        // `(B·K)`-row multipath KV scratch and, under int8 draft
        // precision, the drafter's quantised twin here, DESIGN.md
        // §10/§11).
        backend.prepare(cfg.algo, &cfg.drafter, cfg.draft_precision)?;
        let mut cfg = cfg;
        if cfg.adaptive.enabled && !info.open_gamma {
            eprintln!(
                "specd: adaptive controller needs an open-gamma backend; \
                 disabling on '{}' (exported gammas {:?})",
                info.name, info.gammas
            );
            cfg.adaptive.enabled = false;
        }
        if cfg.adaptive.enabled {
            let cap = (info.max_len / 4).max(1);
            if cfg.adaptive.gamma_max > cap {
                eprintln!(
                    "specd: adaptive.gamma_max {} clamped to backend cap {cap}",
                    cfg.adaptive.gamma_max
                );
                cfg.adaptive.gamma_max = cap;
                cfg.adaptive.gamma_min = cfg.adaptive.gamma_min.min(cap);
            }
            // Pre-size scratch for every path count the controller may
            // pick, so mid-stream K switches never allocate.  Ragged
            // tree iterations run on the flat multipath rows
            // (DESIGN.md §15), so prepare those shapes too.
            for k in 1..=cfg.algo.paths() {
                match cfg.algo {
                    Algo::MultiPath { .. } => {
                        backend.prepare(Algo::MultiPath { k }, &cfg.drafter, cfg.draft_precision)?
                    }
                    Algo::Tree { .. } => {
                        backend.prepare(Algo::Tree { k }, &cfg.drafter, cfg.draft_precision)?;
                        backend.prepare(Algo::MultiPath { k }, &cfg.drafter, cfg.draft_precision)?
                    }
                    _ => {}
                }
            }
        }
        Ok(SpecEngine { backend, cfg, metrics: Arc::new(EngineMetrics::default()) })
    }

    pub fn backend(&self) -> &Arc<B> {
        &self.backend
    }

    /// Run one padded batch of prompts to completion (batch drain).
    pub fn run_batch(&self, prompts: &[Vec<u32>], seed: u64) -> anyhow::Result<BatchReport> {
        let backend = &*self.backend;
        let info = backend.info();
        let b = info.batch;
        let gamma = self.cfg.gamma;
        let t_start = Instant::now();

        let n_real = prompts.len();
        let padded = pad_prompts(prompts, b);
        let (mut tokens, mut length) = layout_prompts(info, &padded);

        // --- prefill both models ---------------------------------------------
        let mut kv_t = backend.prefill("target", &tokens, &length)?;
        let mut kv_d = backend.prefill(&self.cfg.drafter, &tokens, &length)?;
        self.metrics.prefill_batch_size.observe(n_real);

        // --- iterate ----------------------------------------------------------
        let mut trackers: Vec<RowTracker> = (0..b)
            .map(|i| RowTracker::new(i < n_real, self.cfg.max_new_tokens))
            .collect();
        // One iteration-seed stream per row, keyed on (batch seed, row):
        // row i's k-th iteration draws the k-th value of its own stream,
        // exactly as a continuous-batching admission with
        // `row_seed(seed, i)` would (the losslessness contract).
        let mut row_rngs: Vec<Rng> =
            (0..b).map(|i| Rng::new(row_seed(seed, i) ^ SEED_DOMAIN)).collect();
        let mut device_iterations = 0usize;
        // Hard cap: every row emits >= 1 token per iteration.
        let max_iters = self.cfg.max_new_tokens + info.max_len;
        // Per-row tuners when the adaptive controller is on; the off path
        // below runs the exact pre-controller iteration (bit-identity).
        let adaptive = self.cfg.adaptive.enabled;
        let mut controllers: Vec<Controller> = if adaptive {
            (0..b)
                .map(|_| Controller::new(self.cfg.adaptive.clone(), gamma, self.cfg.algo))
                .collect()
        } else {
            Vec::new()
        };

        while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
            let t_iter = Instant::now();
            let seeds: Vec<i32> =
                row_rngs.iter_mut().map(|r| r.next_u64() as i32).collect();
            let out = if adaptive {
                let mut gammas = vec![1usize; b];
                let mut votes = Vec::new();
                for (i, tr) in trackers.iter().enumerate() {
                    if tr.active() {
                        let room =
                            info.max_len.saturating_sub(length[i].max(0) as usize + 2).max(1);
                        let d = controllers[i].choose(room);
                        gammas[i] = d.gamma;
                        votes.push(d.k);
                    }
                }
                let k = modal(&votes).unwrap_or_else(|| self.cfg.algo.paths().max(1));
                let out = backend.spec_iter_rows(
                    with_paths(self.cfg.algo, k),
                    &self.cfg.drafter,
                    &gammas,
                    &mut tokens,
                    &mut length,
                    &mut kv_t,
                    &mut kv_d,
                    &seeds,
                )?;
                for (i, tr) in trackers.iter().enumerate() {
                    if tr.active() {
                        controllers[i].observe(out.tau[i].max(0) as usize, gammas[i]);
                        let (d_us, t_us) = (out.draft_us, out.target_us);
                        controllers[i].observe_costs(d_us, out.drafted, t_us, b * k);
                        self.metrics.gamma_chosen.observe(gammas[i]);
                        self.metrics.paths_chosen.observe(k);
                        let regret = controllers[i].take_regret_milli();
                        self.metrics.controller_regret_milli.add(regret);
                    }
                }
                out
            } else {
                backend.spec_iter(
                    self.cfg.algo,
                    &self.cfg.drafter,
                    gamma,
                    &mut tokens,
                    &mut length,
                    &mut kv_t,
                    &mut kv_d,
                    &seeds,
                )?
            };

            for (i, tr) in trackers.iter_mut().enumerate() {
                if !tr.active() {
                    continue;
                }
                let t_i = out.tau[i] as usize;
                let row: Vec<u32> = out.emitted[i * out.stride..i * out.stride + t_i + 1]
                    .iter()
                    .map(|&x| x as u32)
                    .collect();
                tr.absorb(&row, t_i, out.done[i] != 0);
                self.metrics.tokens_emitted.add(row.len() as u64);
                self.metrics.drafts_accepted.add(t_i as u64);
                self.metrics.accepted_len_hist.observe(t_i);
                self.metrics.iterations.inc();
            }
            self.metrics.drafts_scored.add(out.drafted as u64);
            device_iterations += 1;
            if out.draft_us > 0 {
                self.metrics
                    .draft_forward_us
                    .observe(std::time::Duration::from_micros(out.draft_us));
            }
            if out.target_us > 0 {
                self.metrics
                    .target_forward_us
                    .observe(std::time::Duration::from_micros(out.target_us));
            }
            self.metrics.iter_latency.observe(t_iter.elapsed());
        }

        self.metrics.batches.inc();
        backend.end_batch();
        let rows = trackers
            .into_iter()
            .take(n_real)
            .map(|t| t.into_result())
            .collect();
        Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
    }

    /// Convenience: run many prompts in consecutive batches of `B`.
    pub fn run_prompts(
        &self,
        prompts: &[Vec<u32>],
        seed: u64,
    ) -> anyhow::Result<Vec<BatchReport>> {
        let b = self.backend.info().batch;
        prompts
            .chunks(b)
            .enumerate()
            .map(|(i, chunk)| self.run_batch(chunk, seed.wrapping_add(i as u64 * 7919)))
            .collect()
    }

    // ------------------------------------------------------------------
    // Continuous batching (DESIGN.md §7)
    // ------------------------------------------------------------------

    /// Start an empty continuous-batching stream: every slot holds the
    /// inert padding prompt and both KV caches are prefilled once.  Real
    /// requests enter via [`SpecEngine::admit_row`].
    pub fn begin_stream(&self) -> anyhow::Result<DecodeState<B>> {
        let info = self.backend.info();
        let padded = pad_prompts(&[], info.batch);
        let (tokens, length) = layout_prompts(info, &padded);
        let kv_target = self.backend.prefill("target", &tokens, &length)?;
        let kv_drafter = self.backend.prefill(&self.cfg.drafter, &tokens, &length)?;
        Ok(DecodeState {
            tokens,
            length,
            kv_target,
            kv_drafter,
            row_rngs: vec![None; info.batch],
            controllers: vec![None; info.batch],
        })
    }

    /// Admit one request into a free slot of a live stream — the
    /// single-row form of [`SpecEngine::admit_rows`] (an admission batch
    /// of one).
    ///
    /// `row_seed` fully determines the row's randomness: the same prompt
    /// admitted with the same seed produces the same tokens regardless of
    /// slot index, admission time, or what the other slots are decoding —
    /// in particular, identical to batch-drain row `i` of
    /// [`SpecEngine::run_batch`] when seeded with [`row_seed`]`(batch_seed,
    /// i)` (the refill-losslessness contract, DESIGN.md §7).
    pub fn admit_row(
        &self,
        st: &mut DecodeState<B>,
        slot: usize,
        prompt: &[u32],
        row_seed: u64,
    ) -> anyhow::Result<()> {
        self.admit_rows(st, &[Admission { slot, prompt, row_seed }])
            .pop()
            .expect("one admission yields one result")
    }

    /// Admit a whole scheduler tick's worth of requests in one batched
    /// prefill (DESIGN.md §11.3): every valid admission's prompt is laid
    /// out in one scratch batch, each model runs a **single** forward
    /// over it ([`Backend::prefill_rows`], drawing its KV from the
    /// persistent scratch pool on the native backend), and each row is
    /// spliced into its slot — so `m` admissions cost one prefill instead
    /// of `m`.  Rows are causally independent in every backend, making
    /// this bit-identical to `m` sequential [`SpecEngine::admit_row`]
    /// calls (test-enforced, `tests/theorems.rs`).
    ///
    /// Returns one result per admission, in order.  Per-row validation
    /// failures (bad slot, oversized prompt, duplicate slot) reject only
    /// that admission; the rest proceed.  Admission order is preserved:
    /// row `i` of the scratch batch is the `i`-th *valid* admission, and
    /// each row's randomness is keyed on its own `row_seed`, so FIFO
    /// semantics and per-row determinism are unaffected by the batching.
    pub fn admit_rows(
        &self,
        st: &mut DecodeState<B>,
        admissions: &[Admission<'_>],
    ) -> Vec<anyhow::Result<()>> {
        let cold: Vec<Option<PrefixHandle<'_, B>>> = admissions.iter().map(|_| None).collect();
        self.admit_rows_prefixed(st, admissions, &cold)
    }

    /// Prefill a shared prompt prefix once and extract it as a pair of
    /// standalone single-row caches (target, drafter) — the prefix-cache
    /// ingest path (DESIGN.md §14.3).  The returned caches hold exactly
    /// the KV a cold prefill of any prompt starting with `prefix` would
    /// write at positions `0..prefix.len()` (per-row causal attention:
    /// cache row `i` depends only on tokens `0..=i`), which is what makes
    /// splicing them under a later admission lossless.  `prefix` must
    /// satisfy the same bounds as a prompt (`2 <= len < L/2`).
    pub fn prefill_prefix(&self, prefix: &[u32]) -> anyhow::Result<(B::Kv, B::Kv)> {
        let info = self.backend.info();
        let (b, l) = (info.batch, info.max_len);
        if prefix.len() < 2 || prefix.len() >= l / 2 {
            return Err(anyhow!(
                "prefix length {} outside the cacheable range 2..{} (max_len {l})",
                prefix.len(),
                l / 2
            ));
        }
        let padded = pad_prompts(&[prefix.to_vec()], b);
        let (tokens, length) = layout_prompts(info, &padded);
        let kv_t = self.backend.prefill("target", &tokens, &length)?;
        let kv_d = self.backend.prefill(&self.cfg.drafter, &tokens, &length)?;
        let out_t = self.backend.kv_extract("target", &kv_t, 0, prefix.len())?;
        let out_d = self.backend.kv_extract(&self.cfg.drafter, &kv_d, 0, prefix.len())?;
        Ok((out_t, out_d))
    }

    /// [`SpecEngine::admit_rows`] with an optional cached prompt-prefix
    /// per admission (DESIGN.md §14.3): admissions carrying a
    /// [`PrefixHandle`] get the cached positions spliced into the scratch
    /// batch and only their suffix forwarded
    /// ([`Backend::prefill_rows_prefixed`]) — bit-identical to the cold
    /// path (test-enforced, `tests/serve_tier.rs`), so callers may attach
    /// prefixes opportunistically.  The caller is responsible for the
    /// *match*: `prefixes[i]`, when present, must hold the KV of the
    /// first `len` tokens of `admissions[i].prompt` (the serving tier
    /// guarantees this by keying its cache on the exact token prefix).
    pub fn admit_rows_prefixed(
        &self,
        st: &mut DecodeState<B>,
        admissions: &[Admission<'_>],
        prefixes: &[Option<PrefixHandle<'_, B>>],
    ) -> Vec<anyhow::Result<()>> {
        assert_eq!(admissions.len(), prefixes.len(), "one prefix slot per admission");
        let info = self.backend.info();
        let (b, l) = (info.batch, info.max_len);
        let mut results: Vec<Option<anyhow::Result<()>>> =
            admissions.iter().map(|_| None).collect();
        // Per-admission validation; valid rows join the batched prefill.
        let mut claimed = vec![false; b];
        let mut valid: Vec<usize> = Vec::with_capacity(admissions.len().min(b));
        for (i, a) in admissions.iter().enumerate() {
            let err = if a.slot >= b {
                Some(anyhow!("slot {} out of range (batch {b})", a.slot))
            } else if st.row_rngs[a.slot].is_some() {
                Some(anyhow!("slot {} is still occupied", a.slot))
            } else if claimed[a.slot] {
                Some(anyhow!("slot {} claimed twice in one admission batch", a.slot))
            } else if a.prompt.len() < 2 {
                Some(anyhow!("prompts need >= 2 tokens (BOS + marker)"))
            } else if a.prompt.len() >= l / 2 {
                Some(anyhow!(
                    "prompt length {} exceeds the ring budget {} (max_len {l})",
                    a.prompt.len(),
                    l / 2 - 1
                ))
            } else if prefixes[i]
                .as_ref()
                .is_some_and(|p| p.len == 0 || p.len >= a.prompt.len())
            {
                Some(anyhow!(
                    "prefix length {} invalid for prompt length {}",
                    prefixes[i].as_ref().map_or(0, |p| p.len),
                    a.prompt.len()
                ))
            } else {
                None
            };
            match err {
                Some(e) => results[i] = Some(Err(e)),
                None => {
                    claimed[a.slot] = true;
                    valid.push(i);
                }
            }
        }
        if !valid.is_empty() {
            // One padded scratch batch carrying every admitted prompt
            // (valid admissions are bounded by free slots <= B).  Rows
            // are independent in every backend (per-row causal
            // attention), so splicing row i out of the scratch caches
            // yields exactly the rows a full-batch prefill would have
            // produced for that prompt.
            let prompts: Vec<Vec<u32>> =
                valid.iter().map(|&i| admissions[i].prompt.to_vec()).collect();
            let padded = pad_prompts(&prompts, b);
            let (scratch_toks, scratch_lens) = layout_prompts(info, &padded);
            // Per-model splice maps: same row layout, each model spliced
            // from its own cached prefix (target and drafter caches are
            // separate models with separate KV).
            let splice_for = |r: usize, i: usize| RowSplice {
                src_row: r,
                dst_slot: admissions[i].slot,
                len: admissions[i].prompt.len(),
            };
            let splices_t: Vec<PrefixSplice<'_, B::Kv>> = valid
                .iter()
                .enumerate()
                .map(|(r, &i)| PrefixSplice {
                    splice: splice_for(r, i),
                    prefix: prefixes[i].as_ref().map(|p| (p.kv_target, p.len)),
                })
                .collect();
            let splices_d: Vec<PrefixSplice<'_, B::Kv>> = valid
                .iter()
                .enumerate()
                .map(|(r, &i)| PrefixSplice {
                    splice: splice_for(r, i),
                    prefix: prefixes[i].as_ref().map(|p| (p.kv_drafter, p.len)),
                })
                .collect();
            let t_admit = Instant::now();
            let prefilled = self
                .backend
                .prefill_rows_prefixed(
                    "target",
                    &scratch_toks,
                    &scratch_lens,
                    &mut st.kv_target,
                    &splices_t,
                )
                .and_then(|()| {
                    self.backend.prefill_rows_prefixed(
                        &self.cfg.drafter,
                        &scratch_toks,
                        &scratch_lens,
                        &mut st.kv_drafter,
                        &splices_d,
                    )
                });
            match prefilled {
                Err(e) => {
                    // Device-level failure: every admission in the batch
                    // fails; no slot bookkeeping was touched, and any
                    // partially spliced cache rows are rewritten by the
                    // next successful admission before being attended.
                    let msg = format!("{e:#}");
                    for &i in &valid {
                        results[i] = Some(Err(anyhow!("batched prefill failed: {msg}")));
                    }
                }
                Ok(()) => {
                    // Admission latency: the batched prefill forward plus
                    // every per-row KV splice — the serving-path cost the
                    // paged layout's zero-copy prefix sharing attacks
                    // (DESIGN.md §16; gated in benches/serving.rs).
                    self.metrics.admission_us.observe(t_admit.elapsed());
                    self.metrics.prefill_batch_size.observe(valid.len());
                    for &i in &valid {
                        let a = &admissions[i];
                        for j in 0..l {
                            st.tokens[a.slot * l + j] = vocab::PAD as i32;
                        }
                        for (j, &t) in a.prompt.iter().enumerate() {
                            st.tokens[a.slot * l + j] = t as i32;
                        }
                        st.length[a.slot] = a.prompt.len() as i32;
                        st.row_rngs[a.slot] = Some(Rng::new(a.row_seed ^ SEED_DOMAIN));
                        // Controller state lives with the slot: a fresh
                        // request starts from the configured arm and its
                        // own empty acceptance window.
                        st.controllers[a.slot] = self.cfg.adaptive.enabled.then(|| {
                            let adaptive = self.cfg.adaptive.clone();
                            Controller::new(adaptive, self.cfg.gamma, self.cfg.algo)
                        });
                        self.metrics.slots_refilled.inc();
                        // Prefill-work accounting: positions the forward
                        // actually covered vs. the whole prompt — the
                        // prefix-cache win is the gap between the two.
                        let plen = prefixes[i].as_ref().map_or(0, |p| p.len);
                        self.metrics.prompt_positions.add(a.prompt.len() as u64);
                        self.metrics.prefill_positions.add((a.prompt.len() - plen) as u64);
                        results[i] = Some(Ok(()));
                    }
                }
            }
        }
        results.into_iter().map(|r| r.expect("every admission resolved")).collect()
    }

    /// One fused iteration over the live stream.  Every slot advances
    /// (free slots decode the inert prompt; their outputs are discarded by
    /// the caller); per-slot `tau`/`emitted`/`done` come back in the
    /// returned [`SpecIterOut`] at stride [`SpecIterOut::stride`]
    /// (`cfg.gamma + 1` with the adaptive controller off, `max(row
    /// gammas) + 1` when it varies the rows).
    ///
    /// With [`crate::config::AdaptiveConfig::enabled`] each occupied
    /// slot's [`Controller`] picks the next (gamma, K); since gamma and K
    /// are losslessness-invariant and each row's randomness is a pure
    /// function of its own seed stream (one seed per iteration,
    /// regardless of shape), the committed distribution is unchanged —
    /// adaptive-off streams are bit-identical to pre-controller builds.
    pub fn step_stream(&self, st: &mut DecodeState<B>) -> anyhow::Result<SpecIterOut> {
        if !self.cfg.adaptive.enabled {
            let t_iter = Instant::now();
            let seeds: Vec<i32> = st
                .row_rngs
                .iter_mut()
                .map(|r| r.as_mut().map_or(0, |rng| rng.next_u64() as i32))
                .collect();
            let out = self.backend.spec_iter(
                self.cfg.algo,
                &self.cfg.drafter,
                self.cfg.gamma,
                &mut st.tokens,
                &mut st.length,
                &mut st.kv_target,
                &mut st.kv_drafter,
                &seeds,
            )?;
            if out.draft_us > 0 {
                self.metrics
                    .draft_forward_us
                    .observe(std::time::Duration::from_micros(out.draft_us));
            }
            if out.target_us > 0 {
                self.metrics
                    .target_forward_us
                    .observe(std::time::Duration::from_micros(out.target_us));
            }
            self.metrics.drafts_scored.add(out.drafted as u64);
            self.metrics.iter_latency.observe(t_iter.elapsed());
            return Ok(out);
        }
        let info = self.backend.info();
        let l = info.max_len;
        let mut gammas = vec![1usize; info.batch];
        let mut votes = Vec::new();
        for slot in 0..info.batch {
            if let Some(c) = st.controllers[slot].as_mut() {
                let room = l.saturating_sub(st.length[slot].max(0) as usize + 2).max(1);
                let d = c.choose(room);
                gammas[slot] = d.gamma;
                votes.push(d.k);
            }
        }
        // One iteration shape per step: gamma is per-row (ragged), K is
        // batch-wide, so the controllers vote and the mode wins.
        let k = modal(&votes).unwrap_or_else(|| self.cfg.algo.paths().max(1));
        let out = self.step_stream_rows(st, &gammas, k)?;
        for slot in 0..info.batch {
            if let Some(c) = st.controllers[slot].as_mut() {
                c.observe(out.tau[slot].max(0) as usize, gammas[slot]);
                c.observe_costs(out.draft_us, out.drafted, out.target_us, info.batch * k);
                self.metrics.gamma_chosen.observe(gammas[slot]);
                self.metrics.paths_chosen.observe(k);
                self.metrics.controller_regret_milli.add(c.take_regret_milli());
            }
        }
        Ok(out)
    }

    /// One fused iteration with an explicit per-slot gamma schedule and
    /// path-count override — the adaptive step's engine.  Public so
    /// tests and the oracle-replay harness can force arbitrary (even
    /// adversarial per-iteration) schedules and check the committed
    /// distribution never moves (tests/theorems.rs).  Consumes exactly
    /// one seed per occupied slot, like [`SpecEngine::step_stream`], so
    /// any schedule replays the same per-row randomness.
    pub fn step_stream_rows(
        &self,
        st: &mut DecodeState<B>,
        gammas: &[usize],
        k: usize,
    ) -> anyhow::Result<SpecIterOut> {
        let t_iter = Instant::now();
        let seeds: Vec<i32> = st
            .row_rngs
            .iter_mut()
            .map(|r| r.as_mut().map_or(0, |rng| rng.next_u64() as i32))
            .collect();
        let out = self.backend.spec_iter_rows(
            with_paths(self.cfg.algo, k.max(1)),
            &self.cfg.drafter,
            gammas,
            &mut st.tokens,
            &mut st.length,
            &mut st.kv_target,
            &mut st.kv_drafter,
            &seeds,
        )?;
        if out.draft_us > 0 {
            self.metrics
                .draft_forward_us
                .observe(std::time::Duration::from_micros(out.draft_us));
        }
        if out.target_us > 0 {
            self.metrics
                .target_forward_us
                .observe(std::time::Duration::from_micros(out.target_us));
        }
        self.metrics.drafts_scored.add(out.drafted as u64);
        self.metrics.iter_latency.observe(t_iter.elapsed());
        Ok(out)
    }

    /// Release a finished slot: clear its seed stream and rewind the row
    /// to the inert prompt.  The stale KV rows above the inert prompt are
    /// never attended (queries only look at positions below their own),
    /// and the next admission splices fresh rows in.
    pub fn release_row(&self, st: &mut DecodeState<B>, slot: usize) {
        let l = self.backend.info().max_len;
        let inert = pad_prompts(&[], 1);
        for j in 0..l {
            st.tokens[slot * l + j] = vocab::PAD as i32;
        }
        for (j, &t) in inert[0].iter().enumerate() {
            st.tokens[slot * l + j] = t as i32;
        }
        st.length[slot] = inert[0].len() as i32;
        st.row_rngs[slot] = None;
        st.controllers[slot] = None;
    }
}

/// Rebuild a multi-draft algo with path count `k` (no-op for
/// single-draft algorithms, whose controllers only vote k = 1).
fn with_paths(algo: Algo, k: usize) -> Algo {
    match algo {
        Algo::MultiPath { .. } => Algo::MultiPath { k },
        Algo::Tree { .. } => Algo::Tree { k },
        a => a,
    }
}

/// Most-voted value, smallest winner on ties (deterministic across
/// iteration orders); `None` for an empty vote.
fn modal(votes: &[usize]) -> Option<usize> {
    let mut counts = std::collections::BTreeMap::new();
    for &v in votes {
        *counts.entry(v).or_insert(0usize) += 1;
    }
    counts
        .into_iter()
        .max_by_key(|&(v, c)| (c, std::cmp::Reverse(v)))
        .map(|(v, _)| v)
}

/// One pending admission for [`SpecEngine::admit_rows`]: which free slot
/// the prompt enters, and the seed that fully determines the row's
/// randomness (see [`SpecEngine::admit_row`]).
#[derive(Clone, Copy, Debug)]
pub struct Admission<'a> {
    pub slot: usize,
    pub prompt: &'a [u32],
    pub row_seed: u64,
}

/// A cached prompt prefix attached to one admission
/// ([`SpecEngine::admit_rows_prefixed`]): the pair of single-row caches
/// [`SpecEngine::prefill_prefix`] produced for the first `len` tokens of
/// the prompt.  Manual `Clone`/`Copy` impls — a derive would wrongly
/// bound `B` itself.
pub struct PrefixHandle<'a, B: Backend> {
    /// Target-model KV of the prefix (row 0 holds it).
    pub kv_target: &'a B::Kv,
    /// Drafter-model KV of the prefix (row 0 holds it).
    pub kv_drafter: &'a B::Kv,
    /// Prefix length in tokens; must be `1..prompt.len()`.
    pub len: usize,
}

impl<B: Backend> Clone for PrefixHandle<'_, B> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<B: Backend> Copy for PrefixHandle<'_, B> {}

/// Live state of a continuously batched decode stream: the host
/// token/length rings, both KV caches, and one iteration-seed stream per
/// occupied slot.  Created by [`SpecEngine::begin_stream`]; owned by the
/// serving worker ([`crate::coordinator`]) which tracks per-slot request
/// bookkeeping separately.
pub struct DecodeState<B: Backend> {
    tokens: Vec<i32>,
    length: Vec<i32>,
    kv_target: B::Kv,
    kv_drafter: B::Kv,
    /// `Some` while a request owns the slot; drives that row's seeds.
    row_rngs: Vec<Option<Rng>>,
    /// Per-slot adaptive tuner (`Some` only while the slot is occupied
    /// *and* [`crate::config::AdaptiveConfig::enabled`]); lives and dies
    /// with the request, so its acceptance window never mixes streams.
    controllers: Vec<Option<Controller>>,
}

impl<B: Backend> DecodeState<B> {
    /// Is this slot currently owned by an admitted request?
    pub fn occupied(&self, slot: usize) -> bool {
        self.row_rngs[slot].is_some()
    }

    /// Number of slots currently owned by requests.
    pub fn occupied_count(&self) -> usize {
        self.row_rngs.iter().filter(|r| r.is_some()).count()
    }

    /// Current ring length (prompt + generated + pending) of a slot.
    pub fn row_length(&self, slot: usize) -> usize {
        self.length[slot].max(0) as usize
    }
}

/// The per-row seed [`SpecEngine::run_batch`] derives for batch row `row`
/// from its batch seed.  Passing the same value to
/// [`SpecEngine::admit_row`] reproduces that row's decode token for token
/// in a continuous stream, whatever slot it lands in.
pub fn row_seed(batch_seed: u64, row: usize) -> u64 {
    let mut r = Rng::new(batch_seed ^ ROW_SEED_DOMAIN).fold_in(row as u64);
    r.next_u64()
}

/// Domain separator for the per-iteration device seeds.
const SEED_DOMAIN: u64 = 0x5bec_dec0de;
/// Domain separator for deriving per-row seeds from a batch seed.
const ROW_SEED_DOMAIN: u64 = 0x510_75eed;
