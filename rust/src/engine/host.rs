//! Host-verify engine: draft and score through the backend
//! ([`Backend::draft_block`] / [`Backend::target_score`]), verify in rust.
//!
//! This path exists because greedy block verification (Appendix C) threads
//! the distribution-modification state across iterations (Algorithm 6),
//! which cannot live inside a stateless fused call.  It also serves as the
//! cross-check harness for the fused verification kernels: identical math,
//! independent implementation.

use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use crate::backend::Backend;
use crate::config::EngineConfig;
use crate::metrics::EngineMetrics;
use crate::verify::{self, Algo, GreedyState, ProbMatrix, Rng};

use super::{layout_prompts, pad_prompts, BatchReport, RowTracker};

pub struct HostVerifyEngine<B: Backend> {
    backend: Arc<B>,
    pub cfg: EngineConfig,
    pub metrics: Arc<EngineMetrics>,
}

impl<B: Backend> HostVerifyEngine<B> {
    pub fn new(backend: Arc<B>, cfg: EngineConfig) -> anyhow::Result<Self> {
        if matches!(cfg.algo, Algo::MultiPath { .. } | Algo::Tree { .. }) {
            return Err(anyhow!(
                "multi-draft verification ({}) runs on the fused engine (engine::spec); \
                 the host-verify path is single-draft",
                cfg.algo
            ));
        }
        let info = backend.info();
        if !info.supports_gamma(cfg.gamma) {
            return Err(anyhow!("gamma {} not supported", cfg.gamma));
        }
        if !info.has_drafter(&cfg.drafter) {
            return Err(anyhow!("drafter '{}' not served", cfg.drafter));
        }
        // Same warm-up hook as the fused engine: adopt the configured
        // draft precision (and pre-build the drafter's int8 twin on the
        // native backend, DESIGN.md §11).
        backend.prepare(cfg.algo, &cfg.drafter, cfg.draft_precision)?;
        Ok(HostVerifyEngine { backend, cfg, metrics: Arc::new(EngineMetrics::default()) })
    }

    pub fn backend(&self) -> &Arc<B> {
        &self.backend
    }

    pub fn run_batch(&self, prompts: &[Vec<u32>], seed: u64) -> anyhow::Result<BatchReport> {
        let backend = &*self.backend;
        let info = backend.info();
        let b = info.batch;
        let l = info.max_len;
        let v = info.vocab_size;
        let gamma = self.cfg.gamma;
        let t_start = Instant::now();

        let n_real = prompts.len();
        let padded = pad_prompts(prompts, b);
        // Host-owned token/length state.
        let (mut toks, mut lens) = layout_prompts(info, &padded);

        let mut kv_t = backend.prefill("target", &toks, &lens)?;
        let mut kv_d = backend.prefill(&self.cfg.drafter, &toks, &lens)?;
        self.metrics.prefill_batch_size.observe(n_real);

        let mut trackers: Vec<RowTracker> =
            (0..b).map(|i| RowTracker::new(i < n_real, self.cfg.max_new_tokens)).collect();
        let mut greedy: Vec<GreedyState> = (0..b).map(|_| GreedyState::new(gamma)).collect();
        let mut rng = Rng::new(seed ^ 0x705f_3eed);
        let mut seed_rng = Rng::new(seed ^ 0xd3af_7000);
        let mut device_iterations = 0usize;
        let max_iters = self.cfg.max_new_tokens + l;

        while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
            // --- draft + score through the backend ---------------------------
            // One draft seed per row (the backend contract keys sampling
            // streams per row; see DESIGN.md §5.1).
            let iter_seeds: Vec<i32> = (0..b).map(|_| seed_rng.next_u64() as i32).collect();
            let t_draft = Instant::now();
            let draft = backend
                .draft_block(&self.cfg.drafter, gamma, &toks, &lens, &mut kv_d, &iter_seeds)?;
            self.metrics.draft_forward_us.observe(t_draft.elapsed());
            let t_target = Instant::now();
            let ps_flat =
                backend.target_score(gamma, &toks, &lens, &mut kv_t, &draft.drafts)?;
            self.metrics.target_forward_us.observe(t_target.elapsed());
            let qs_flat = &draft.qs;
            let drafts = &draft.drafts;

            // --- verify on host ----------------------------------------------
            for (i, tr) in trackers.iter_mut().enumerate() {
                if !tr.active() {
                    continue;
                }
                let ps = ProbMatrix::from_f32(
                    gamma + 1,
                    v,
                    &ps_flat[i * (gamma + 1) * v..(i + 1) * (gamma + 1) * v],
                );
                let qs =
                    ProbMatrix::from_f32(gamma, v, &qs_flat[i * gamma * v..(i + 1) * gamma * v]);
                let row_drafts: Vec<u32> =
                    drafts[i * gamma..(i + 1) * gamma].iter().map(|&x| x as u32).collect();
                let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
                let u = rng.uniform();
                let outcome = match self.cfg.algo {
                    Algo::Greedy => {
                        let (o, st) = verify::greedy_verify(
                            &ps, &qs, &row_drafts, &etas, u, &greedy[i],
                        );
                        greedy[i] = st;
                        o
                    }
                    a => verify::verify(a, &ps, &qs, &row_drafts, &etas, u),
                };
                // Write emitted into host tokens; advance length.
                let start = lens[i] as usize;
                for (j, &t) in outcome.emitted.iter().enumerate() {
                    if start + j < l {
                        toks[i * l + start + j] = t as i32;
                    }
                }
                lens[i] = (lens[i] + outcome.tau as i32 + 1).min(l as i32 - 1);
                let out_of_room = lens[i] as usize > l - (gamma + 2);
                tr.absorb(&outcome.emitted, outcome.tau, out_of_room);
                self.metrics.tokens_emitted.add(outcome.emitted.len() as u64);
                self.metrics.drafts_accepted.add(outcome.tau as u64);
                self.metrics.accepted_len_hist.observe(outcome.tau);
                self.metrics.iterations.inc();
            }
            device_iterations += 1;
        }

        self.metrics.batches.inc();
        backend.end_batch();
        let rows = trackers.into_iter().take(n_real).map(|t| t.into_result()).collect();
        Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
    }

    pub fn run_prompts(
        &self,
        prompts: &[Vec<u32>],
        seed: u64,
    ) -> anyhow::Result<Vec<BatchReport>> {
        let b = self.backend.info().batch;
        prompts
            .chunks(b)
            .enumerate()
            .map(|(i, c)| self.run_batch(c, seed.wrapping_add(i as u64 * 7919)))
            .collect()
    }
}
