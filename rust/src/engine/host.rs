//! Host-verify engine: draft and score on device (`draft_block_*`,
//! `target_score_*` programs), verify in rust.
//!
//! This path exists because greedy block verification (Appendix C) threads
//! the distribution-modification state across iterations (Algorithm 6),
//! which cannot live inside a stateless fused program.  It also serves as
//! the cross-check harness for the in-HLO Pallas verify kernels: identical
//! math, independent implementation.

use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use crate::config::EngineConfig;
use crate::metrics::EngineMetrics;
use crate::models::vocab;
use crate::runtime::{literal, Runtime, StateHandle};
use crate::verify::{self, Algo, GreedyState, ProbMatrix, Rng};

use super::{pad_prompts, BatchReport, RowTracker};

pub struct HostVerifyEngine {
    rt: Arc<Runtime>,
    pub cfg: EngineConfig,
    pub metrics: Arc<EngineMetrics>,
}

impl HostVerifyEngine {
    pub fn new(rt: Arc<Runtime>, cfg: EngineConfig) -> anyhow::Result<Self> {
        if !rt.manifest.gammas.contains(&cfg.gamma) {
            return Err(anyhow!("gamma {} not exported", cfg.gamma));
        }
        Ok(HostVerifyEngine { rt, cfg, metrics: Arc::new(EngineMetrics::default()) })
    }

    pub fn run_batch(&self, prompts: &[Vec<u32>], seed: u64) -> anyhow::Result<BatchReport> {
        let rt = &*self.rt;
        let b = rt.manifest.batch;
        let l = rt.manifest.max_len;
        let v = rt.manifest.vocab_size;
        let gamma = self.cfg.gamma;
        let t_start = Instant::now();

        let n_real = prompts.len();
        let padded = pad_prompts(prompts, b);

        // Host-owned token/length state.
        let mut toks = vec![vocab::PAD as i32; b * l];
        let mut lens = vec![0i32; b];
        for (i, p) in padded.iter().enumerate() {
            for (j, &t) in p.iter().enumerate() {
                toks[i * l + j] = t as i32;
            }
            lens[i] = p.len() as i32;
        }

        let w_t = rt.weights("target")?;
        let w_d = rt.weights(&self.cfg.drafter)?;
        let tok_lit = literal::i32_literal(&toks, &[b, l])?;
        let len_lit = literal::i32_literal(&lens, &[b])?;
        let tok_buf = rt.upload(tok_lit)?;
        let len_buf = rt.upload(len_lit)?;

        let prefill_t = rt.program("prefill_target")?;
        let prefill_d = rt.program(&format!("prefill_{}", self.cfg.drafter))?;
        let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let kvt = rt.execute(prefill_t, &args)?.into_handles();
        let mut args: Vec<&xla::PjRtBuffer> = w_d.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let kvd = rt.execute(prefill_d, &args)?.into_handles();
        let [mut kvt_k, mut kvt_v] =
            <[StateHandle; 2]>::try_from(kvt).map_err(|_| anyhow!("prefill: 2 outs"))?;
        let [mut kvd_k, mut kvd_v] =
            <[StateHandle; 2]>::try_from(kvd).map_err(|_| anyhow!("prefill: 2 outs"))?;

        let draft_prog =
            rt.program(&format!("draft_block_{}_g{gamma}", self.cfg.drafter))?;
        let score_prog = rt.program(&format!("target_score_g{gamma}"))?;

        let mut trackers: Vec<RowTracker> =
            (0..b).map(|i| RowTracker::new(i < n_real, self.cfg.max_new_tokens)).collect();
        let mut greedy: Vec<GreedyState> = (0..b).map(|_| GreedyState::new(gamma)).collect();
        let mut rng = Rng::new(seed ^ 0x705f_3eed);
        let mut seed_rng = Rng::new(seed ^ 0xd3af_7000);
        let mut device_iterations = 0usize;
        let max_iters = self.cfg.max_new_tokens + l;

        while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
            // --- draft on device --------------------------------------------------
            let tok_lit = literal::i32_literal(&toks, &[b, l])?;
            let len_lit = literal::i32_literal(&lens, &[b])?;
            let tok_buf = rt.upload(tok_lit)?;
            let len_buf = rt.upload(len_lit)?;
            let seed_lit = literal::i32_scalar(seed_rng.next_u64() as i32)?;
            let seed_buf = rt.upload(seed_lit)?;
            let kvd_k_b = kvd_k.ensure_buffer(rt)?;
            let kvd_v_b = kvd_v.ensure_buffer(rt)?;
            let mut args: Vec<&xla::PjRtBuffer> = w_d.iter().collect();
            args.push(&tok_buf);
            args.push(&len_buf);
            args.push(&kvd_k_b);
            args.push(&kvd_v_b);
            args.push(&seed_buf);
            let out = rt.execute(draft_prog, &args)?;
            // outs: drafts (B,g) i32, qs (B,g,V) f32, kvd_k, kvd_v
            let drafts = out.i32s(0)?;
            let qs_flat = out.f32s(1)?;
            let mut handles = out.into_handles();
            kvd_v = handles.pop().unwrap();
            kvd_k = handles.pop().unwrap();

            // --- score on device --------------------------------------------------
            let drafts_lit = literal::i32_literal(&drafts, &[b, gamma])?;
            let drafts_buf = rt.upload(drafts_lit)?;
            let kvt_k_b = kvt_k.ensure_buffer(rt)?;
            let kvt_v_b = kvt_v.ensure_buffer(rt)?;
            let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
            args.push(&tok_buf);
            args.push(&len_buf);
            args.push(&kvt_k_b);
            args.push(&kvt_v_b);
            args.push(&drafts_buf);
            let out = rt.execute(score_prog, &args)?;
            // outs: ps (B,g+1,V) f32, kvt_k, kvt_v
            let ps_flat = out.f32s(0)?;
            let mut handles = out.into_handles();
            kvt_v = handles.pop().unwrap();
            kvt_k = handles.pop().unwrap();

            // --- verify on host ---------------------------------------------------
            for (i, tr) in trackers.iter_mut().enumerate() {
                if !tr.active() {
                    continue;
                }
                let ps = ProbMatrix::from_f32(
                    gamma + 1,
                    v,
                    &ps_flat[i * (gamma + 1) * v..(i + 1) * (gamma + 1) * v],
                );
                let qs =
                    ProbMatrix::from_f32(gamma, v, &qs_flat[i * gamma * v..(i + 1) * gamma * v]);
                let row_drafts: Vec<u32> =
                    drafts[i * gamma..(i + 1) * gamma].iter().map(|&x| x as u32).collect();
                let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
                let u = rng.uniform();
                let outcome = match self.cfg.algo {
                    Algo::Greedy => {
                        let (o, st) = verify::greedy_verify(
                            &ps, &qs, &row_drafts, &etas, u, &greedy[i],
                        );
                        greedy[i] = st;
                        o
                    }
                    a => verify::verify(a, &ps, &qs, &row_drafts, &etas, u),
                };
                // Write emitted into host tokens; advance length.
                let start = lens[i] as usize;
                for (j, &t) in outcome.emitted.iter().enumerate() {
                    if start + j < l {
                        toks[i * l + start + j] = t as i32;
                    }
                }
                lens[i] = (lens[i] + outcome.tau as i32 + 1).min(l as i32 - 1);
                let out_of_room = lens[i] as usize > l - (gamma + 2);
                tr.absorb(&outcome.emitted, outcome.tau, out_of_room);
                self.metrics.tokens_emitted.add(outcome.emitted.len() as u64);
                self.metrics.drafts_accepted.add(outcome.tau as u64);
                self.metrics.iterations.inc();
            }
            device_iterations += 1;
        }

        self.metrics.batches.inc();
        rt.clear_pinned();
        let rows = trackers.into_iter().take(n_real).map(|t| t.into_result()).collect();
        Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
    }

    pub fn run_prompts(
        &self,
        prompts: &[Vec<u32>],
        seed: u64,
    ) -> anyhow::Result<Vec<BatchReport>> {
        let b = self.rt.manifest.batch;
        prompts
            .chunks(b)
            .enumerate()
            .map(|(i, c)| self.run_batch(c, seed.wrapping_add(i as u64 * 7919)))
            .collect()
    }
}
