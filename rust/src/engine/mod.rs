//! The speculative-decoding engine: drives whole request batches through
//! an execution backend.  Every engine is generic over
//! [`crate::backend::Backend`] and works identically on the pure-Rust
//! native backend and (with the `pjrt` feature) the AOT HLO/PJRT backend.
//!
//! Three execution paths:
//! * [`spec::SpecEngine::run_batch`] — fused path: one
//!   [`crate::backend::Backend::spec_iter`] call per iteration (draft
//!   block + target score + verification all inside the backend).  Used
//!   for token/block verification.
//! * [`host::HostVerifyEngine`] — host-verify path:
//!   [`crate::backend::Backend::draft_block`] +
//!   [`crate::backend::Backend::target_score`] plus rust-side
//!   verification.  Required for greedy verification (Appendix C threads
//!   state across iterations) and used to cross-check the fused kernels.
//! * [`baseline::run_baseline`] — plain autoregressive target decoding, the
//!   1x reference for wall-clock speedups.

pub mod baseline;
pub mod host;
pub mod spec;

use crate::backend::BackendInfo;
use crate::models::vocab;

/// Why a row stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Model emitted EOS.
    Eos,
    /// Hit the per-request `max_new_tokens` cap.
    Length,
    /// Ran out of sequence buffer (device `done` flag).
    OutOfRoom,
}

/// Per-request decode result.
#[derive(Clone, Debug)]
pub struct RowResult {
    /// Generated tokens (prompt excluded), truncated at EOS if present.
    pub tokens: Vec<u32>,
    /// Target-model calls consumed while this row was active.
    pub iterations: usize,
    /// Draft tokens accepted across those iterations (sum of tau).
    pub accepted: usize,
    /// Tokens emitted across those iterations (sum of tau + 1) — the
    /// numerator of block efficiency, which counts EOS/overflow tokens too.
    pub emitted: usize,
    pub finish: FinishReason,
}

impl RowResult {
    pub fn block_efficiency(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.emitted as f64 / self.iterations as f64
    }
}

/// Batch-level report.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub rows: Vec<RowResult>,
    /// Device iterations executed (the batch runs until every row is done).
    pub device_iterations: usize,
    pub wall: std::time::Duration,
}

impl BatchReport {
    /// Aggregate block efficiency: total emitted / total per-row active
    /// iterations (the paper's "decoded tokens per serial target call").
    pub fn block_efficiency(&self) -> f64 {
        let iters: usize = self.rows.iter().map(|r| r.iterations).sum();
        let toks: usize = self.rows.iter().map(|r| r.emitted).sum();
        if iters == 0 {
            0.0
        } else {
            toks as f64 / iters as f64
        }
    }

    pub fn total_tokens(&self) -> usize {
        self.rows.iter().map(|r| r.tokens.len()).sum()
    }
}

/// Tracks one batch row across iterations, independent of the verify path.
#[derive(Clone, Debug)]
pub(crate) struct RowTracker {
    pub real: bool,
    pub max_new_tokens: usize,
    pub generated: Vec<u32>,
    pub iterations: usize,
    pub accepted: usize,
    pub emitted: usize,
    pub finish: Option<FinishReason>,
}

impl RowTracker {
    pub fn new(real: bool, max_new_tokens: usize) -> Self {
        RowTracker {
            real,
            max_new_tokens,
            generated: Vec::new(),
            iterations: 0,
            accepted: 0,
            emitted: 0,
            finish: None,
        }
    }

    pub fn active(&self) -> bool {
        self.real && self.finish.is_none()
    }

    /// Record one iteration's outcome for this row.
    pub fn absorb(&mut self, emitted: &[u32], tau: usize, device_done: bool) {
        debug_assert_eq!(emitted.len(), tau + 1);
        self.iterations += 1;
        self.accepted += tau;
        self.emitted += emitted.len();
        for &t in emitted {
            if t == vocab::EOS {
                self.finish = Some(FinishReason::Eos);
                return;
            }
            self.generated.push(t);
            if self.generated.len() >= self.max_new_tokens {
                self.finish = Some(FinishReason::Length);
                return;
            }
        }
        if device_done {
            self.finish = Some(FinishReason::OutOfRoom);
        }
    }

    pub fn into_result(self) -> RowResult {
        RowResult {
            tokens: self.generated,
            iterations: self.iterations,
            accepted: self.accepted,
            emitted: self.emitted,
            finish: self.finish.unwrap_or(FinishReason::Length),
        }
    }
}

/// Pad a prompt batch to exactly `batch` rows; extra rows are inert
/// (BOS-only) and their outputs are discarded.
pub(crate) fn pad_prompts(prompts: &[Vec<u32>], batch: usize) -> Vec<Vec<u32>> {
    assert!(prompts.len() <= batch, "batch overflow: {} > {batch}", prompts.len());
    let mut out = prompts.to_vec();
    while out.len() < batch {
        out.push(vec![vocab::BOS, vocab::marker_for(0), vocab::CONTENT_BASE]);
    }
    out
}

/// Lay a padded prompt batch out as the backend's host state tensors:
/// `tokens` row-major `(B, L)` (PAD-filled) and `length (B,)`.
pub(crate) fn layout_prompts(info: &BackendInfo, prompts: &[Vec<u32>]) -> (Vec<i32>, Vec<i32>) {
    let (b, l) = (info.batch, info.max_len);
    assert_eq!(prompts.len(), b, "layout_prompts expects a padded batch");
    let mut tokens = vec![vocab::PAD as i32; b * l];
    let mut length = vec![0i32; b];
    for (i, p) in prompts.iter().enumerate() {
        assert!(p.len() >= 2, "prompts need >= 2 tokens (BOS + marker)");
        assert!(p.len() < l / 2, "prompt too long for max_len {l}");
        for (j, &t) in p.iter().enumerate() {
            tokens[i * l + j] = t as i32;
        }
        length[i] = p.len() as i32;
    }
    (tokens, length)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_stops_at_eos_and_truncates() {
        let mut t = RowTracker::new(true, 10);
        t.absorb(&[20, 21, vocab::EOS], 2, false);
        assert_eq!(t.finish, Some(FinishReason::Eos));
        assert_eq!(t.generated, vec![20, 21]);
        assert_eq!(t.emitted, 3);
        assert_eq!(t.accepted, 2);
    }

    #[test]
    fn tracker_caps_length() {
        let mut t = RowTracker::new(true, 3);
        t.absorb(&[20, 21], 1, false);
        assert!(t.active());
        t.absorb(&[22, 23], 1, false);
        assert_eq!(t.finish, Some(FinishReason::Length));
        assert_eq!(t.generated.len(), 3);
    }

    #[test]
    fn tracker_device_done() {
        let mut t = RowTracker::new(true, 100);
        t.absorb(&[20], 0, true);
        assert_eq!(t.finish, Some(FinishReason::OutOfRoom));
    }

    #[test]
    fn pad_prompts_fills_batch() {
        let p = pad_prompts(&[vec![1, 3, 20]], 4);
        assert_eq!(p.len(), 4);
        assert_eq!(p[3][0], vocab::BOS);
    }

    #[test]
    #[should_panic]
    fn pad_prompts_rejects_overflow() {
        let five: Vec<Vec<u32>> = (0..5).map(|_| vec![1u32]).collect();
        pad_prompts(&five, 4);
    }

    #[test]
    fn layout_fills_tokens_and_lengths() {
        let info = BackendInfo {
            name: "test".into(),
            batch: 2,
            max_len: 16,
            vocab_size: 256,
            gammas: vec![4],
            open_gamma: true,
            drafters: vec!["xxs".into()],
            artifacts_dir: None,
            paged_kv: false,
        };
        let padded = pad_prompts(&[vec![1, 3, 20, 21]], 2);
        let (toks, lens) = layout_prompts(&info, &padded);
        assert_eq!(toks.len(), 32);
        assert_eq!(&toks[..5], &[1, 3, 20, 21, vocab::PAD as i32]);
        assert_eq!(lens, vec![4, 3]);
    }
}
