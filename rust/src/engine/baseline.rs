//! Autoregressive baseline decoding — the "1x" reference the paper's
//! wall-clock speedups are measured against (one target call per token).
//! Backend-generic like the spec engines: no device types appear here.

use std::time::Instant;

use crate::backend::Backend;
use crate::verify::Rng;

use super::{layout_prompts, pad_prompts, BatchReport, RowTracker};

/// Decode a padded batch autoregressively with the target model only.
pub fn run_baseline<B: Backend>(
    backend: &B,
    prompts: &[Vec<u32>],
    max_new_tokens: usize,
    seed: u64,
) -> anyhow::Result<BatchReport> {
    let info = backend.info();
    let b = info.batch;
    let t_start = Instant::now();
    let n_real = prompts.len();
    let padded = pad_prompts(prompts, b);
    let (mut tokens, mut length) = layout_prompts(info, &padded);

    let mut kv = backend.prefill("target", &tokens, &length)?;
    let mut trackers: Vec<RowTracker> =
        (0..b).map(|i| RowTracker::new(i < n_real, max_new_tokens)).collect();
    let mut seed_rng = Rng::new(seed ^ 0xba5e11e);
    let mut device_iterations = 0usize;
    let max_iters = max_new_tokens + 4;

    while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
        let iter_seed = seed_rng.next_u64() as i32;
        let out = backend.baseline_step(&mut tokens, &mut length, &mut kv, iter_seed)?;
        for (i, tr) in trackers.iter_mut().enumerate() {
            if !tr.active() {
                continue;
            }
            tr.absorb(&[out.next[i] as u32], 0, out.done[i] != 0);
        }
        device_iterations += 1;
    }

    backend.end_batch();
    let rows = trackers.into_iter().take(n_real).map(|t| t.into_result()).collect();
    Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
}

/// Run many prompts through the baseline in batches of `B`.
pub fn run_baseline_prompts<B: Backend>(
    backend: &B,
    prompts: &[Vec<u32>],
    max_new_tokens: usize,
    seed: u64,
) -> anyhow::Result<Vec<BatchReport>> {
    let b = backend.info().batch;
    prompts
        .chunks(b)
        .enumerate()
        .map(|(i, c)| {
            run_baseline(backend, c, max_new_tokens, seed.wrapping_add(i as u64 * 104729))
        })
        .collect()
}
