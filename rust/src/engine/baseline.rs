//! Autoregressive baseline decoding — the "1x" reference the paper's
//! wall-clock speedups are measured against (one target call per token).

use std::time::Instant;

use anyhow::anyhow;

use crate::runtime::{literal, Runtime, StateHandle};
use crate::verify::Rng;

use super::{pad_prompts, BatchReport, RowTracker};

/// Decode a padded batch autoregressive with the target model only.
pub fn run_baseline(
    rt: &Runtime,
    prompts: &[Vec<u32>],
    max_new_tokens: usize,
    seed: u64,
) -> anyhow::Result<BatchReport> {
    let b = rt.manifest.batch;
    let t_start = Instant::now();
    let n_real = prompts.len();
    let padded = pad_prompts(prompts, b);
    let (tok_lit, len_lit, _) =
        super::spec::SpecEngine::prompt_literals(rt, &padded)?;

    let w_t = rt.weights("target")?;
    let tok_buf = rt.upload(tok_lit)?;
    let len_buf = rt.upload(len_lit)?;
    let prefill = rt.program("prefill_target")?;
    let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
    args.push(&tok_buf);
    args.push(&len_buf);
    let kv = rt.execute(prefill, &args)?.into_handles();
    let [mut kv_k, mut kv_v] =
        <[StateHandle; 2]>::try_from(kv).map_err(|_| anyhow!("prefill: expected 2 outputs"))?;

    let step = rt.program("baseline_step")?;
    let mut trackers: Vec<RowTracker> =
        (0..b).map(|i| RowTracker::new(i < n_real, max_new_tokens)).collect();
    let mut tokens = StateHandle::Buf(tok_buf);
    let mut length = StateHandle::Buf(len_buf);
    let mut seed_rng = Rng::new(seed ^ 0xba5e11e);
    let mut device_iterations = 0usize;
    let max_iters = max_new_tokens + 4;

    while trackers.iter().any(|t| t.active()) && device_iterations < max_iters {
        let seed_lit = literal::i32_scalar(seed_rng.next_u64() as i32)?;
        let seed_buf = rt.upload(seed_lit)?;
        let tok_b = tokens.ensure_buffer(rt)?;
        let len_b = length.ensure_buffer(rt)?;
        let kv_k_b = kv_k.ensure_buffer(rt)?;
        let kv_v_b = kv_v.ensure_buffer(rt)?;
        let mut args: Vec<&xla::PjRtBuffer> = w_t.iter().collect();
        args.push(&tok_b);
        args.push(&len_b);
        args.push(&kv_k_b);
        args.push(&kv_v_b);
        args.push(&seed_buf);
        let out = rt.execute(step, &args)?;
        // outs: tokens, length, kv_k, kv_v, next, done
        let next = out.i32s(4)?;
        let done = out.i32s(5)?;
        let mut handles = out.into_handles();
        let _ = handles.split_off(4);
        kv_v = handles.pop().unwrap();
        kv_k = handles.pop().unwrap();
        length = handles.pop().unwrap();
        tokens = handles.pop().unwrap();

        for (i, tr) in trackers.iter_mut().enumerate() {
            if !tr.active() {
                continue;
            }
            tr.absorb(&[next[i] as u32], 0, done[i] != 0);
        }
        device_iterations += 1;
    }

    rt.clear_pinned();
    let rows = trackers.into_iter().take(n_real).map(|t| t.into_result()).collect();
    Ok(BatchReport { rows, device_iterations, wall: t_start.elapsed() })
}

/// Run many prompts through the baseline in batches of `B`.
pub fn run_baseline_prompts(
    rt: &Runtime,
    prompts: &[Vec<u32>],
    max_new_tokens: usize,
    seed: u64,
) -> anyhow::Result<Vec<BatchReport>> {
    let b = rt.manifest.batch;
    prompts
        .chunks(b)
        .enumerate()
        .map(|(i, c)| run_baseline(rt, c, max_new_tokens, seed.wrapping_add(i as u64 * 104729)))
        .collect()
}
