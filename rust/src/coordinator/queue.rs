//! Bounded request queue with admission control — a standalone, testable
//! model of the coordinator's backpressure policy (the async path in
//! `coordinator::mod` uses tokio's bounded mpsc with the same semantics).

use std::collections::VecDeque;

/// Admission failures surfaced to clients as HTTP 429 / 400.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    QueueFull { limit: usize },
    PromptTooLong { len: usize, max: usize },
    PromptTooShort,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { limit } => write!(f, "queue full (limit {limit})"),
            AdmissionError::PromptTooLong { len, max } => {
                write!(f, "prompt length {len} exceeds {max}")
            }
            AdmissionError::PromptTooShort => write!(f, "prompt needs >= 2 tokens"),
        }
    }
}

/// FIFO queue with a hard limit and prompt validation.
#[derive(Debug)]
pub struct RequestQueue<T> {
    items: VecDeque<(Vec<u32>, T)>,
    pub limit: usize,
    pub max_prompt_len: usize,
}

impl<T> RequestQueue<T> {
    pub fn new(limit: usize, max_prompt_len: usize) -> Self {
        RequestQueue { items: VecDeque::new(), limit, max_prompt_len }
    }

    pub fn push(&mut self, prompt: Vec<u32>, payload: T) -> Result<(), AdmissionError> {
        if prompt.len() < 2 {
            return Err(AdmissionError::PromptTooShort);
        }
        if prompt.len() > self.max_prompt_len {
            return Err(AdmissionError::PromptTooLong {
                len: prompt.len(),
                max: self.max_prompt_len,
            });
        }
        if self.items.len() >= self.limit {
            return Err(AdmissionError::QueueFull { limit: self.limit });
        }
        self.items.push_back((prompt, payload));
        Ok(())
    }

    /// Drain up to `n` requests in FIFO order.
    pub fn take_batch(&mut self, n: usize) -> Vec<(Vec<u32>, T)> {
        let k = n.min(self.items.len());
        self.items.drain(..k).collect()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut q = RequestQueue::new(10, 32);
        for i in 0..5u32 {
            q.push(vec![1, 3, 20 + i], i).unwrap();
        }
        let batch = q.take_batch(3);
        assert_eq!(batch.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn admission_limits() {
        let mut q: RequestQueue<()> = RequestQueue::new(1, 4);
        assert_eq!(q.push(vec![1], ()), Err(AdmissionError::PromptTooShort));
        assert_eq!(
            q.push(vec![1; 5], ()),
            Err(AdmissionError::PromptTooLong { len: 5, max: 4 })
        );
        q.push(vec![1, 3], ()).unwrap();
        assert_eq!(q.push(vec![1, 3], ()), Err(AdmissionError::QueueFull { limit: 1 }));
    }

    #[test]
    fn take_more_than_available() {
        let mut q = RequestQueue::new(10, 32);
        q.push(vec![1, 3], 0u32).unwrap();
        assert_eq!(q.take_batch(8).len(), 1);
        assert!(q.is_empty());
    }
}
