//! Admission control and slot bookkeeping for the serving tier:
//!
//! * [`RequestQueue`] — bounded two-lane queue (interactive / batch) with
//!   per-tenant round-robin inside each lane and prompt validation; the
//!   standalone, testable model of the replica worker's scheduling policy
//!   (DESIGN.md §14.4 — strict FIFO retired).
//! * [`TokenBucket`] — the atomic budget limiter the router charges
//!   per-replica admission costs against (DESIGN.md §14.2).
//! * [`AdmissionGate`] — the in-flight limiter guarding
//!   [`crate::coordinator::Coordinator::generate`]; a unit-cost
//!   [`TokenBucket`].
//! * [`SlotTable`] — which engine slots the continuous batcher has
//!   occupied, and with what (DESIGN.md §7).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Admission failures surfaced to clients as HTTP 429 / 400.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    QueueFull { limit: usize },
    PromptTooLong { len: usize, max: usize },
    PromptTooShort,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { limit } => write!(f, "queue full (limit {limit})"),
            AdmissionError::PromptTooLong { len, max } => {
                write!(f, "prompt length {len} exceeds {max}")
            }
            AdmissionError::PromptTooShort => write!(f, "prompt needs >= 2 tokens"),
        }
    }
}

/// Scheduling lane of a queued request: [`Lane::Interactive`] is always
/// served before [`Lane::Batch`] (strict lane priority); *within* a lane
/// tenants are served round-robin, so no tenant can starve another by
/// flooding (DESIGN.md §14.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Lane {
    #[default]
    Interactive,
    Batch,
}

/// One lane of the two-lane queue: per-tenant FIFO sub-queues drained
/// round-robin.  `cursor` remembers which tenant is served next, so the
/// rotation survives across `take_batch` calls.
#[derive(Debug)]
struct LaneQueue<T> {
    tenants: Vec<(u64, VecDeque<(Vec<u32>, T)>)>,
    cursor: usize,
    len: usize,
}

impl<T> Default for LaneQueue<T> {
    fn default() -> Self {
        LaneQueue { tenants: Vec::new(), cursor: 0, len: 0 }
    }
}

impl<T> LaneQueue<T> {
    fn sub(&mut self, tenant: u64) -> &mut VecDeque<(Vec<u32>, T)> {
        if let Some(i) = self.tenants.iter().position(|(t, _)| *t == tenant) {
            return &mut self.tenants[i].1;
        }
        // New tenants join *behind* the current rotation point, so they
        // wait at most one full rotation before being served.
        self.tenants.push((tenant, VecDeque::new()));
        &mut self.tenants.last_mut().expect("just pushed").1
    }

    fn push_back(&mut self, tenant: u64, prompt: Vec<u32>, payload: T) {
        self.sub(tenant).push_back((prompt, payload));
        self.len += 1;
    }

    fn push_front(&mut self, tenant: u64, prompt: Vec<u32>, payload: T) {
        self.sub(tenant).push_front((prompt, payload));
        self.len += 1;
    }

    /// Pop one request from the tenant at the rotation cursor, advancing
    /// the rotation.  Tenants whose sub-queue drains are removed (their
    /// next request re-enters behind the rotation).
    fn pop(&mut self) -> Option<(Vec<u32>, T)> {
        if self.len == 0 {
            return None;
        }
        self.cursor %= self.tenants.len();
        let q = &mut self.tenants[self.cursor].1;
        let item = q.pop_front().expect("non-empty lane has non-empty tenant queues");
        self.len -= 1;
        if q.is_empty() {
            // Removing at the cursor makes the cursor point at the next
            // tenant already — no advance needed.
            self.tenants.remove(self.cursor);
        } else {
            self.cursor += 1;
        }
        if !self.tenants.is_empty() {
            self.cursor %= self.tenants.len();
        } else {
            self.cursor = 0;
        }
        Some(item)
    }
}

/// Bounded two-lane request queue with per-tenant fairness: interactive
/// requests are always served before batch requests, and within a lane
/// tenants are drained round-robin (one request per tenant per turn).
/// [`RequestQueue::push`] is the single-tenant interactive shorthand the
/// pre-§14 FIFO callers keep using — with one lane and one tenant, the
/// round-robin degenerates to exactly the old FIFO order.
#[derive(Debug)]
pub struct RequestQueue<T> {
    interactive: LaneQueue<T>,
    batch: LaneQueue<T>,
    pub limit: usize,
    pub max_prompt_len: usize,
}

impl<T> RequestQueue<T> {
    pub fn new(limit: usize, max_prompt_len: usize) -> Self {
        RequestQueue {
            interactive: LaneQueue::default(),
            batch: LaneQueue::default(),
            limit,
            max_prompt_len,
        }
    }

    /// Enqueue as tenant 0 on the interactive lane (the single-tenant
    /// FIFO shorthand).
    pub fn push(&mut self, prompt: Vec<u32>, payload: T) -> Result<(), AdmissionError> {
        self.push_with(prompt, Lane::Interactive, 0, payload)
    }

    /// Enqueue on `lane` as `tenant`, validating the prompt and the
    /// queue bound.
    pub fn push_with(
        &mut self,
        prompt: Vec<u32>,
        lane: Lane,
        tenant: u64,
        payload: T,
    ) -> Result<(), AdmissionError> {
        if prompt.len() < 2 {
            return Err(AdmissionError::PromptTooShort);
        }
        if prompt.len() > self.max_prompt_len {
            return Err(AdmissionError::PromptTooLong {
                len: prompt.len(),
                max: self.max_prompt_len,
            });
        }
        if self.len() >= self.limit {
            return Err(AdmissionError::QueueFull { limit: self.limit });
        }
        match lane {
            Lane::Interactive => self.interactive.push_back(tenant, prompt, payload),
            Lane::Batch => self.batch.push_back(tenant, prompt, payload),
        }
        Ok(())
    }

    /// Put a request back at the *front* of its tenant's sub-queue,
    /// bypassing validation and the queue bound — the replica worker's
    /// deferral path for admissions that stalled on a KV-page lease
    /// (DESIGN.md §14.2): the request already passed admission once and
    /// must not lose its position or be shed for re-entering.
    pub fn requeue(&mut self, prompt: Vec<u32>, lane: Lane, tenant: u64, payload: T) {
        match lane {
            Lane::Interactive => self.interactive.push_front(tenant, prompt, payload),
            Lane::Batch => self.batch.push_front(tenant, prompt, payload),
        }
    }

    /// Drain up to `n` requests: the interactive lane first, tenants
    /// round-robin within each lane.
    pub fn take_batch(&mut self, n: usize) -> Vec<(Vec<u32>, T)> {
        let mut out = Vec::with_capacity(n.min(self.len()));
        while out.len() < n {
            match self.interactive.pop().or_else(|| self.batch.pop()) {
                Some(item) => out.push(item),
                None => break,
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.interactive.len + self.batch.len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Atomic token-budget limiter: at most `capacity` tokens outstanding,
/// acquired in variable-size chunks.  The router charges each request's
/// worst-case token footprint (prompt + generation budget) against its
/// replica's bucket, so per-replica queueing is bounded by *work*, not
/// request count — the [`AdmissionGate`] generalisation the serving tier
/// sheds load with (DESIGN.md §14.2).  Check and decrement are one
/// atomic `fetch_update`, so concurrent callers can never overshoot.
#[derive(Debug)]
pub struct TokenBucket {
    available: AtomicUsize,
    capacity: usize,
}

impl TokenBucket {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        TokenBucket { available: AtomicUsize::new(capacity), capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn available(&self) -> usize {
        self.available.load(Ordering::Acquire)
    }

    /// Try to take `n` tokens; pair every success with exactly one
    /// `release(n)`.  `n > capacity` never succeeds.
    pub fn try_acquire(&self, n: usize) -> bool {
        self.available
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |avail| avail.checked_sub(n))
            .is_ok()
    }

    pub fn release(&self, n: usize) {
        let prev = self.available.fetch_add(n, Ordering::AcqRel);
        debug_assert!(prev + n <= self.capacity, "token bucket over-released");
    }
}

/// Atomic in-flight limiter: at most `limit` concurrent holders — a
/// unit-cost [`TokenBucket`], kept as the coordinator-facing API.
#[derive(Debug)]
pub struct AdmissionGate {
    bucket: TokenBucket,
}

impl AdmissionGate {
    pub fn new(limit: usize) -> Self {
        AdmissionGate { bucket: TokenBucket::new(limit) }
    }

    pub fn limit(&self) -> usize {
        self.bucket.capacity()
    }

    /// Try to take a slot; pair every success with exactly one
    /// [`AdmissionGate::release`].
    pub fn try_acquire(&self) -> bool {
        self.bucket.try_acquire(1)
    }

    pub fn release(&self) {
        self.bucket.release(1);
    }

    pub fn inflight(&self) -> usize {
        self.bucket.capacity() - self.bucket.available()
    }
}

/// Fixed-capacity slot table for the continuous batcher: tracks which
/// engine slots are owned by an in-flight request and the per-slot
/// payload (tracker + reply channel in the coordinator; anything in
/// tests).
#[derive(Debug)]
pub struct SlotTable<T> {
    slots: Vec<Option<T>>,
}

impl<T> SlotTable<T> {
    pub fn new(capacity: usize) -> Self {
        SlotTable { slots: (0..capacity).map(|_| None).collect() }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free(&self) -> usize {
        self.capacity() - self.occupied()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Lowest-index free slot, if any.
    pub fn first_free(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Every free slot, ascending — the batched-admission path assigns
    /// one scheduler tick's queued requests to these in FIFO order.
    pub fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_none().then_some(i))
            .collect()
    }

    pub fn occupy(&mut self, slot: usize, item: T) {
        debug_assert!(self.slots[slot].is_none(), "slot {slot} already occupied");
        self.slots[slot] = Some(item);
    }

    pub fn release(&mut self, slot: usize) -> Option<T> {
        self.slots[slot].take()
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut T> {
        self.slots[slot].as_mut()
    }

    /// Iterate occupied slots as `(slot index, payload)`.
    pub fn iter_occupied_mut(&mut self) -> impl Iterator<Item = (usize, &mut T)> {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| s.as_mut().map(|t| (i, t)))
    }

    /// Take every occupied slot (worker teardown / device failure).
    pub fn drain(&mut self) -> Vec<(usize, T)> {
        self.slots
            .iter_mut()
            .enumerate()
            .filter_map(|(i, s)| s.take().map(|t| (i, t)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut q = RequestQueue::new(10, 32);
        for i in 0..5u32 {
            q.push(vec![1, 3, 20 + i], i).unwrap();
        }
        let batch = q.take_batch(3);
        assert_eq!(batch.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn admission_limits() {
        let mut q: RequestQueue<()> = RequestQueue::new(1, 4);
        assert_eq!(q.push(vec![1], ()), Err(AdmissionError::PromptTooShort));
        assert_eq!(
            q.push(vec![1; 5], ()),
            Err(AdmissionError::PromptTooLong { len: 5, max: 4 })
        );
        q.push(vec![1, 3], ()).unwrap();
        assert_eq!(q.push(vec![1, 3], ()), Err(AdmissionError::QueueFull { limit: 1 }));
    }

    #[test]
    fn take_more_than_available() {
        let mut q = RequestQueue::new(10, 32);
        q.push(vec![1, 3], 0u32).unwrap();
        assert_eq!(q.take_batch(8).len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn interactive_lane_served_before_batch() {
        let mut q = RequestQueue::new(16, 32);
        q.push_with(vec![1, 3], Lane::Batch, 0, "b0").unwrap();
        q.push_with(vec![1, 3], Lane::Batch, 0, "b1").unwrap();
        q.push_with(vec![1, 3], Lane::Interactive, 0, "i0").unwrap();
        let got: Vec<_> = q.take_batch(3).into_iter().map(|(_, p)| p).collect();
        assert_eq!(got, vec!["i0", "b0", "b1"]);
    }

    /// The starvation regression strict FIFO had: a tenant flooding the
    /// queue ahead of everyone else used to monopolise every scheduler
    /// tick.  Under per-tenant round-robin, a late light tenant is served
    /// on the very next rotation regardless of how deep the heavy
    /// tenant's backlog is.
    #[test]
    fn heavy_tenant_cannot_starve_light_tenant() {
        let mut q = RequestQueue::new(64, 32);
        for i in 0..40u32 {
            q.push_with(vec![1, 3, 20 + i], Lane::Interactive, 7, ("heavy", i)).unwrap();
        }
        q.push_with(vec![1, 3], Lane::Interactive, 8, ("light", 0)).unwrap();
        // One rotation: the light tenant's request is in the first pair
        // drained, not behind 40 heavy requests.
        let got: Vec<_> = q.take_batch(2).into_iter().map(|(_, p)| p).collect();
        assert!(got.contains(&("light", 0)), "light tenant starved: {got:?}");
        // Heavy requests still drain in their own FIFO order.
        let rest: Vec<_> = q.take_batch(3).into_iter().map(|(_, p)| p.1).collect();
        assert_eq!(rest, vec![1, 2, 3]);
    }

    #[test]
    fn requeue_keeps_position_and_bypasses_limit() {
        let mut q = RequestQueue::new(2, 32);
        q.push(vec![1, 3, 20], "a").unwrap();
        q.push(vec![1, 3, 21], "b").unwrap();
        assert_eq!(q.push(vec![1, 3, 22], "c"), Err(AdmissionError::QueueFull { limit: 2 }));
        let (prompt, payload) = q.take_batch(1).pop().unwrap();
        assert_eq!(payload, "a");
        // Deferred admission goes back to the *front*, even at the limit.
        q.requeue(prompt, Lane::Interactive, 0, "a");
        let got: Vec<_> = q.take_batch(2).into_iter().map(|(_, p)| p).collect();
        assert_eq!(got, vec!["a", "b"]);
    }

    #[test]
    fn token_bucket_charges_and_releases() {
        let b = TokenBucket::new(100);
        assert_eq!(b.capacity(), 100);
        assert!(b.try_acquire(60));
        assert!(!b.try_acquire(50), "only 40 tokens left");
        assert!(b.try_acquire(40));
        assert_eq!(b.available(), 0);
        b.release(60);
        b.release(40);
        assert_eq!(b.available(), 100);
        assert!(!b.try_acquire(101), "cost above capacity never admits");
    }

    /// Regression test for the racy admission check: the old coordinator
    /// loaded `inflight` and incremented it in two steps, so concurrent
    /// callers could exceed `queue_limit`.  With the gate's single
    /// `fetch_update`, the observed concurrency can never overshoot.
    #[test]
    fn admission_gate_never_exceeds_limit_under_contention() {
        use std::sync::Arc;

        let limit = 4;
        let gate = Arc::new(AdmissionGate::new(limit));
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..16 {
            let (gate, live, peak) = (gate.clone(), live.clone(), peak.clone());
            handles.push(std::thread::spawn(move || {
                let mut admitted = 0usize;
                for _ in 0..2000 {
                    if gate.try_acquire() {
                        let now = live.fetch_add(1, Ordering::AcqRel) + 1;
                        peak.fetch_max(now, Ordering::AcqRel);
                        std::thread::yield_now();
                        live.fetch_sub(1, Ordering::AcqRel);
                        gate.release();
                        admitted += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                admitted
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0, "some admissions must succeed");
        let peak = peak.load(Ordering::Acquire);
        assert!(peak <= limit, "admission exceeded the limit: peak {peak} > {limit}");
        assert_eq!(gate.inflight(), 0, "acquire/release must balance");
    }

    #[test]
    fn slot_table_lifecycle() {
        let mut t: SlotTable<&'static str> = SlotTable::new(3);
        assert!(t.is_empty());
        assert_eq!((t.capacity(), t.free()), (3, 3));
        assert_eq!(t.first_free(), Some(0));
        t.occupy(0, "a");
        t.occupy(2, "c");
        assert_eq!(t.occupied(), 2);
        assert_eq!(t.first_free(), Some(1));
        assert_eq!(
            t.iter_occupied_mut().map(|(i, s)| (i, *s)).collect::<Vec<_>>(),
            vec![(0, "a"), (2, "c")]
        );
        assert_eq!(t.release(0), Some("a"));
        assert_eq!(t.release(0), None);
        assert_eq!(t.first_free(), Some(0));
        *t.get_mut(2).unwrap() = "c2";
        assert_eq!(t.drain(), vec![(2, "c2")]);
        assert!(t.is_empty());
    }
}
