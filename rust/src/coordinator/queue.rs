//! Admission control and slot bookkeeping for the coordinator:
//!
//! * [`RequestQueue`] — bounded FIFO with prompt validation, a
//!   standalone, testable model of the channel-level backpressure policy.
//! * [`AdmissionGate`] — the atomic in-flight limiter guarding
//!   [`crate::coordinator::Coordinator::generate`].
//! * [`SlotTable`] — which engine slots the continuous batcher has
//!   occupied, and with what (DESIGN.md §7).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Admission failures surfaced to clients as HTTP 429 / 400.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    QueueFull { limit: usize },
    PromptTooLong { len: usize, max: usize },
    PromptTooShort,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { limit } => write!(f, "queue full (limit {limit})"),
            AdmissionError::PromptTooLong { len, max } => {
                write!(f, "prompt length {len} exceeds {max}")
            }
            AdmissionError::PromptTooShort => write!(f, "prompt needs >= 2 tokens"),
        }
    }
}

/// FIFO queue with a hard limit and prompt validation.
#[derive(Debug)]
pub struct RequestQueue<T> {
    items: VecDeque<(Vec<u32>, T)>,
    pub limit: usize,
    pub max_prompt_len: usize,
}

impl<T> RequestQueue<T> {
    pub fn new(limit: usize, max_prompt_len: usize) -> Self {
        RequestQueue { items: VecDeque::new(), limit, max_prompt_len }
    }

    pub fn push(&mut self, prompt: Vec<u32>, payload: T) -> Result<(), AdmissionError> {
        if prompt.len() < 2 {
            return Err(AdmissionError::PromptTooShort);
        }
        if prompt.len() > self.max_prompt_len {
            return Err(AdmissionError::PromptTooLong {
                len: prompt.len(),
                max: self.max_prompt_len,
            });
        }
        if self.items.len() >= self.limit {
            return Err(AdmissionError::QueueFull { limit: self.limit });
        }
        self.items.push_back((prompt, payload));
        Ok(())
    }

    /// Drain up to `n` requests in FIFO order.
    pub fn take_batch(&mut self, n: usize) -> Vec<(Vec<u32>, T)> {
        let k = n.min(self.items.len());
        self.items.drain(..k).collect()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Atomic in-flight limiter: at most `limit` concurrent holders.  The
/// check and the increment are one atomic `fetch_update`, so concurrent
/// callers can never overshoot — unlike the load-then-increment pattern
/// it replaced, where two threads could both observe `limit - 1` and both
/// enter.
#[derive(Debug)]
pub struct AdmissionGate {
    inflight: AtomicUsize,
    limit: usize,
}

impl AdmissionGate {
    pub fn new(limit: usize) -> Self {
        AdmissionGate { inflight: AtomicUsize::new(0), limit: limit.max(1) }
    }

    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Try to take a slot; pair every success with exactly one
    /// [`AdmissionGate::release`].
    pub fn try_acquire(&self) -> bool {
        self.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                if n < self.limit {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    pub fn release(&self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }
}

/// Fixed-capacity slot table for the continuous batcher: tracks which
/// engine slots are owned by an in-flight request and the per-slot
/// payload (tracker + reply channel in the coordinator; anything in
/// tests).
#[derive(Debug)]
pub struct SlotTable<T> {
    slots: Vec<Option<T>>,
}

impl<T> SlotTable<T> {
    pub fn new(capacity: usize) -> Self {
        SlotTable { slots: (0..capacity).map(|_| None).collect() }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free(&self) -> usize {
        self.capacity() - self.occupied()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Lowest-index free slot, if any.
    pub fn first_free(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Every free slot, ascending — the batched-admission path assigns
    /// one scheduler tick's queued requests to these in FIFO order.
    pub fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_none().then_some(i))
            .collect()
    }

    pub fn occupy(&mut self, slot: usize, item: T) {
        debug_assert!(self.slots[slot].is_none(), "slot {slot} already occupied");
        self.slots[slot] = Some(item);
    }

    pub fn release(&mut self, slot: usize) -> Option<T> {
        self.slots[slot].take()
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut T> {
        self.slots[slot].as_mut()
    }

    /// Iterate occupied slots as `(slot index, payload)`.
    pub fn iter_occupied_mut(&mut self) -> impl Iterator<Item = (usize, &mut T)> {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| s.as_mut().map(|t| (i, t)))
    }

    /// Take every occupied slot (worker teardown / device failure).
    pub fn drain(&mut self) -> Vec<(usize, T)> {
        self.slots
            .iter_mut()
            .enumerate()
            .filter_map(|(i, s)| s.take().map(|t| (i, t)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut q = RequestQueue::new(10, 32);
        for i in 0..5u32 {
            q.push(vec![1, 3, 20 + i], i).unwrap();
        }
        let batch = q.take_batch(3);
        assert_eq!(batch.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn admission_limits() {
        let mut q: RequestQueue<()> = RequestQueue::new(1, 4);
        assert_eq!(q.push(vec![1], ()), Err(AdmissionError::PromptTooShort));
        assert_eq!(
            q.push(vec![1; 5], ()),
            Err(AdmissionError::PromptTooLong { len: 5, max: 4 })
        );
        q.push(vec![1, 3], ()).unwrap();
        assert_eq!(q.push(vec![1, 3], ()), Err(AdmissionError::QueueFull { limit: 1 }));
    }

    #[test]
    fn take_more_than_available() {
        let mut q = RequestQueue::new(10, 32);
        q.push(vec![1, 3], 0u32).unwrap();
        assert_eq!(q.take_batch(8).len(), 1);
        assert!(q.is_empty());
    }

    /// Regression test for the racy admission check: the old coordinator
    /// loaded `inflight` and incremented it in two steps, so concurrent
    /// callers could exceed `queue_limit`.  With the gate's single
    /// `fetch_update`, the observed concurrency can never overshoot.
    #[test]
    fn admission_gate_never_exceeds_limit_under_contention() {
        use std::sync::Arc;

        let limit = 4;
        let gate = Arc::new(AdmissionGate::new(limit));
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..16 {
            let (gate, live, peak) = (gate.clone(), live.clone(), peak.clone());
            handles.push(std::thread::spawn(move || {
                let mut admitted = 0usize;
                for _ in 0..2000 {
                    if gate.try_acquire() {
                        let now = live.fetch_add(1, Ordering::AcqRel) + 1;
                        peak.fetch_max(now, Ordering::AcqRel);
                        std::thread::yield_now();
                        live.fetch_sub(1, Ordering::AcqRel);
                        gate.release();
                        admitted += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                admitted
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0, "some admissions must succeed");
        let peak = peak.load(Ordering::Acquire);
        assert!(peak <= limit, "admission exceeded the limit: peak {peak} > {limit}");
        assert_eq!(gate.inflight(), 0, "acquire/release must balance");
    }

    #[test]
    fn slot_table_lifecycle() {
        let mut t: SlotTable<&'static str> = SlotTable::new(3);
        assert!(t.is_empty());
        assert_eq!((t.capacity(), t.free()), (3, 3));
        assert_eq!(t.first_free(), Some(0));
        t.occupy(0, "a");
        t.occupy(2, "c");
        assert_eq!(t.occupied(), 2);
        assert_eq!(t.first_free(), Some(1));
        assert_eq!(
            t.iter_occupied_mut().map(|(i, s)| (i, *s)).collect::<Vec<_>>(),
            vec![(0, "a"), (2, "c")]
        );
        assert_eq!(t.release(0), Some("a"));
        assert_eq!(t.release(0), None);
        assert_eq!(t.first_free(), Some(0));
        *t.get_mut(2).unwrap() = "c2";
        assert_eq!(t.drain(), vec![(2, "c2")]);
        assert!(t.is_empty());
    }
}
