//! L3 coordinator: request queue, admission control and the continuous
//! batcher that feeds the engine.
//!
//! Architecture (vLLM-router-like, scaled to a single-process CPU
//! backend): front-end threads enqueue [`GenRequest`]s into a bounded
//! channel guarded by an atomic [`AdmissionGate`]; a dedicated worker
//! thread runs a **continuous batcher** over the engine's `B` slots
//! (DESIGN.md §7) — queued requests are spliced into freed slots
//! mid-decode via [`crate::backend::Backend::kv_splice`], every slot
//! replies the moment its own row finishes, and mixed-length traffic no
//! longer decodes at the speed of the slowest row in a batch.  Responses
//! flow back through per-request oneshot channels.  Everything is
//! std-only: the offline image has no tokio.
//!
//! [`Coordinator::spawn`] is generic over [`Backend`]; the handle itself
//! is type-erased (the worker thread owns the engine), so the HTTP server
//! layer stays backend-agnostic without generics.

pub mod queue;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::config::{EngineConfig, ServerConfig};
use crate::engine::spec::{Admission, DecodeState, SpecEngine};
use crate::engine::{RowResult, RowTracker};
use crate::metrics::EngineMetrics;
use crate::verify::Rng;

pub use queue::{AdmissionError, AdmissionGate, RequestQueue, SlotTable};

/// A generation request as accepted by the coordinator.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: Option<usize>,
    /// Per-request sampling seed.  When set, the row's draft and
    /// verification randomness is a pure function of this value — the
    /// generation reproduces exactly regardless of which slot it lands in
    /// or what else is being served (DESIGN.md §7).  `None` draws a fresh
    /// seed from the worker's admission stream.
    pub seed: Option<u64>,
    pub enqueued: Instant,
}

type Reply = std::sync::mpsc::SyncSender<Result<RowResult>>;

/// The coordinator handle cloned into server handlers.
#[derive(Clone)]
pub struct Coordinator {
    tx: SyncSender<(GenRequest, Reply)>,
    pub metrics: Arc<EngineMetrics>,
    gate: Arc<AdmissionGate>,
}

impl Coordinator {
    /// Spawn the coordinator worker thread over any execution backend.
    pub fn spawn<B: Backend>(
        backend: Arc<B>,
        engine_cfg: EngineConfig,
        server_cfg: &ServerConfig,
    ) -> Result<Coordinator> {
        let engine = SpecEngine::new(backend, engine_cfg)?;
        let metrics = engine.metrics.clone();
        let limit = server_cfg.queue_limit.max(1);
        let (tx, rx) = sync_channel(limit);
        let batch_wait = Duration::from_millis(server_cfg.batch_wait_ms);
        let m2 = metrics.clone();
        std::thread::Builder::new()
            .name("specd-batcher".into())
            .spawn(move || batch_worker(engine, rx, batch_wait, m2))
            .map_err(|e| anyhow!("spawning batcher: {e}"))?;
        Ok(Coordinator { tx, metrics, gate: Arc::new(AdmissionGate::new(limit)) })
    }

    /// Enqueue a request and block until its row completes.
    pub fn generate(&self, req: GenRequest) -> Result<RowResult> {
        // Single atomic check-and-increment: concurrent callers can never
        // exceed `queue_limit` (see AdmissionGate).
        if !self.gate.try_acquire() {
            return Err(anyhow!("queue full — admission rejected"));
        }
        let (otx, orx) = sync_channel(1);
        self.metrics.requests_enqueued.inc();
        let res = (|| {
            self.tx
                .try_send((req, otx))
                .map_err(|_| anyhow!("queue full — admission rejected"))?;
            orx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
        })();
        self.gate.release();
        res
    }
}

/// Per-slot request bookkeeping held by the worker.
struct SlotReq {
    tracker: RowTracker,
    reply: Reply,
    enqueued: Instant,
}

/// Continuous batching loop: admit queued requests into free engine slots
/// the moment they open (including mid-decode), step the fused engine over
/// the live batch, and reply per row as it finishes.
fn batch_worker<B: Backend>(
    engine: SpecEngine<B>,
    rx: Receiver<(GenRequest, Reply)>,
    batch_wait: Duration,
    metrics: Arc<EngineMetrics>,
) {
    let b = engine.backend().info().batch;
    let gamma = engine.cfg.gamma;
    let default_max_new = engine.cfg.max_new_tokens;
    // Admission seeds for requests that do not pin their own; requests
    // that need reproducibility set `GenRequest::seed`.
    let mut seed_rng = Rng::new(0xc0ffee0 ^ 0x9E3779B97F4A7C15);
    // The decode stream is built lazily (first admission) and rebuilt
    // after a device-level failure.
    let mut state: Option<DecodeState<B>> = None;
    let mut slots: SlotTable<SlotReq> = SlotTable::new(b);
    'serve: loop {
        // --- gather incoming requests, bounded by free slots --------------
        let mut incoming: Vec<(GenRequest, Reply)> = Vec::new();
        if slots.is_empty() {
            // Idle: block for the next request, then give stragglers
            // `batch_wait` to land so bursts start as one batch.
            match rx.recv() {
                Ok(x) => incoming.push(x),
                Err(_) => return, // all senders dropped: shut down
            }
            let deadline = Instant::now() + batch_wait;
            while incoming.len() < b {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(x) => incoming.push(x),
                    Err(_) => break,
                }
            }
        } else {
            // Mid-decode: non-blocking refill of freed slots only — the
            // live rows must not wait on the queue.
            while incoming.len() < slots.free() {
                match rx.try_recv() {
                    Ok(x) => incoming.push(x),
                    Err(_) => break,
                }
            }
        }

        // --- admit into free slots (one batched prefill per tick) ---------
        // All of this tick's admissions share a single batched prefill
        // ([`SpecEngine::admit_rows`] → `Backend::prefill_rows`): m
        // admissions cost one forward pass instead of m, and the slot
        // table is only touched before and after that forward — never
        // held across it — so the admission critical section no longer
        // scales with prompt length (the old loop ran one full prefill
        // per request between bookkeeping steps).  FIFO is preserved:
        // requests arrive in queue order and are assigned ascending free
        // slots in that order, with per-request seeds drawn in the same
        // order as the old per-row loop.
        if !incoming.is_empty() {
            match ensure_stream(&engine, &mut state) {
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (_, reply) in incoming {
                        let _ = reply.send(Err(anyhow!("{msg}")));
                    }
                }
                Ok(st) => {
                    let free = slots.free_slots();
                    debug_assert!(incoming.len() <= free.len(), "admissions exceed free slots");
                    let pending: Vec<(usize, GenRequest, Reply, u64)> = incoming
                        .into_iter()
                        .zip(free)
                        .map(|((req, reply), slot)| {
                            let row_seed = req.seed.unwrap_or_else(|| seed_rng.next_u64());
                            metrics.queue_wait.observe(req.enqueued.elapsed());
                            (slot, req, reply, row_seed)
                        })
                        .collect();
                    let results = {
                        let admissions: Vec<Admission<'_>> = pending
                            .iter()
                            .map(|(slot, req, _, row_seed)| Admission {
                                slot: *slot,
                                prompt: &req.prompt,
                                row_seed: *row_seed,
                            })
                            .collect();
                        engine.admit_rows(st, &admissions)
                    };
                    for ((slot, req, reply, _), res) in pending.into_iter().zip(results) {
                        match res {
                            Ok(()) => {
                                let max_new =
                                    req.max_new_tokens.unwrap_or(default_max_new).max(1);
                                slots.occupy(
                                    slot,
                                    SlotReq {
                                        tracker: RowTracker::new(true, max_new),
                                        reply,
                                        enqueued: req.enqueued,
                                    },
                                );
                            }
                            // Admission errors (over-long prompt, bad
                            // state) reject just this request; the live
                            // batch and the tick's other admissions are
                            // untouched.
                            Err(e) => {
                                let _ = reply.send(Err(e));
                            }
                        }
                    }
                }
            }
        }
        if slots.is_empty() {
            continue 'serve;
        }

        // --- one fused engine step over the live batch --------------------
        let st = state.as_mut().expect("occupied slots imply a live stream");
        let out = match engine.step_stream(st) {
            Ok(out) => out,
            Err(e) => {
                // Device-level failure: fail every in-flight request and
                // rebuild the stream on the next admission.
                let msg = format!("{e:#}");
                for (_, sr) in slots.drain() {
                    let _ = sr.reply.send(Err(anyhow!("{msg}")));
                }
                state = None;
                continue 'serve;
            }
        };

        // --- absorb per-row outcomes; reply and free rows as they finish --
        metrics.slot_iters_total.add(b as u64);
        metrics.slot_iters_busy.add(slots.occupied() as u64);
        let mut finished: Vec<usize> = Vec::new();
        for (i, sr) in slots.iter_occupied_mut() {
            let tau = out.tau[i] as usize;
            let row: Vec<u32> = out.emitted[i * (gamma + 1)..i * (gamma + 1) + tau + 1]
                .iter()
                .map(|&x| x as u32)
                .collect();
            sr.tracker.absorb(&row, tau, out.done[i] != 0);
            metrics.tokens_emitted.add(row.len() as u64);
            metrics.drafts_accepted.add(tau as u64);
            metrics.accepted_len_hist.observe(tau);
            metrics.iterations.inc();
            if !sr.tracker.active() {
                finished.push(i);
            }
        }
        let any_finished = !finished.is_empty();
        for i in finished {
            let sr = slots.release(i).expect("finished slot was occupied");
            metrics.requests_completed.inc();
            metrics.request_latency.observe(sr.enqueued.elapsed());
            let result = sr.tracker.into_result();
            let _ = sr.reply.send(Ok(result));
            engine.release_row(st, i);
        }
        if slots.is_empty() {
            metrics.batches.inc();
        }
        if any_finished {
            // Per-row drain boundary: the step's outputs were read back
            // above, so every outstanding upload is complete and the
            // backend can release per-batch resources (pinned literals on
            // PJRT).  Keyed on row completion — not on the batch emptying
            // — so sustained traffic that never idles the batcher cannot
            // grow the pinned set without bound.  (Deliberately skipped on
            // the step-error path above: a failed execution may not have
            // read its uploads back.)
            engine.backend().end_batch();
        }
    }
}

/// Lazily build (or rebuild after failure) the worker's decode stream.
fn ensure_stream<'a, B: Backend>(
    engine: &SpecEngine<B>,
    state: &'a mut Option<DecodeState<B>>,
) -> Result<&'a mut DecodeState<B>> {
    if state.is_none() {
        *state = Some(engine.begin_stream()?);
    }
    Ok(state.as_mut().expect("just ensured"))
}
