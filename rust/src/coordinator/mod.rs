//! L3 coordinator: request queue, admission control and the continuous
//! batcher that feeds the engine.
//!
//! Architecture (vLLM-router-like, scaled to a single-process CPU
//! backend): front-end threads enqueue [`GenRequest`]s into a bounded
//! channel; a dedicated worker thread drains the queue into batches of the
//! engine's slot count `B` and runs each batch to completion ("batch
//! drain" — per-slot refill requires a KV-merge operation on the backend,
//! listed as future work in DESIGN.md §7).  Responses flow back through
//! per-request oneshot channels.  Everything is std-only: the offline
//! image has no tokio.
//!
//! [`Coordinator::spawn`] is generic over [`Backend`]; the handle itself
//! is type-erased (the worker thread owns the engine), so the HTTP server
//! layer stays backend-agnostic without generics.

pub mod queue;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::config::{EngineConfig, ServerConfig};
use crate::engine::spec::SpecEngine;
use crate::engine::RowResult;
use crate::metrics::EngineMetrics;

pub use queue::{AdmissionError, RequestQueue};

/// A generation request as accepted by the coordinator.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: Option<usize>,
    pub enqueued: Instant,
}

type Reply = std::sync::mpsc::SyncSender<Result<RowResult>>;

/// The coordinator handle cloned into server handlers.
#[derive(Clone)]
pub struct Coordinator {
    tx: SyncSender<(GenRequest, Reply)>,
    pub metrics: Arc<EngineMetrics>,
    inflight: Arc<AtomicUsize>,
    queue_limit: usize,
}

impl Coordinator {
    /// Spawn the coordinator worker thread over any execution backend.
    pub fn spawn<B: Backend>(
        backend: Arc<B>,
        engine_cfg: EngineConfig,
        server_cfg: &ServerConfig,
    ) -> Result<Coordinator> {
        let engine = SpecEngine::new(backend, engine_cfg)?;
        let metrics = engine.metrics.clone();
        let limit = server_cfg.queue_limit.max(1);
        let (tx, rx) = sync_channel(limit);
        let batch_wait = Duration::from_millis(server_cfg.batch_wait_ms);
        let m2 = metrics.clone();
        std::thread::Builder::new()
            .name("specd-batcher".into())
            .spawn(move || batch_worker(engine, rx, batch_wait, m2))
            .map_err(|e| anyhow!("spawning batcher: {e}"))?;
        Ok(Coordinator {
            tx,
            metrics,
            inflight: Arc::new(AtomicUsize::new(0)),
            queue_limit: limit,
        })
    }

    /// Enqueue a request and block until its batch completes.
    pub fn generate(&self, req: GenRequest) -> Result<RowResult> {
        if self.inflight.load(Ordering::Relaxed) >= self.queue_limit {
            return Err(anyhow!("queue full — admission rejected"));
        }
        let (otx, orx) = sync_channel(1);
        self.metrics.requests_enqueued.inc();
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let res = (|| {
            self.tx
                .try_send((req, otx))
                .map_err(|_| anyhow!("queue full — admission rejected"))?;
            orx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
        })();
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        res
    }
}

/// Batch formation loop: greedily drain up to `B` requests, waiting at most
/// `batch_wait` for stragglers after the first arrival.
fn batch_worker<B: Backend>(
    engine: SpecEngine<B>,
    rx: Receiver<(GenRequest, Reply)>,
    batch_wait: Duration,
    metrics: Arc<EngineMetrics>,
) {
    let b = engine.backend().info().batch;
    let mut seed: u64 = 0xc0ffee0;
    loop {
        let first = match rx.recv() {
            Ok(x) => x,
            Err(_) => return, // all senders dropped: shut down
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + batch_wait;
        while batch.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(x) => batch.push(x),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        for (req, _) in &batch {
            metrics.queue_wait.observe(req.enqueued.elapsed());
        }
        seed = seed.wrapping_add(0x9E3779B97F4A7C15);
        let prompts: Vec<Vec<u32>> = batch.iter().map(|(r, _)| r.prompt.clone()).collect();
        match engine.run_batch(&prompts, seed) {
            Ok(rep) => {
                for ((req, otx), row) in batch.into_iter().zip(rep.rows.into_iter()) {
                    metrics.requests_completed.inc();
                    metrics.request_latency.observe(req.enqueued.elapsed());
                    let _ = otx.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (_, otx) in batch {
                    let _ = otx.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}
