//! L3 coordinator: the single-engine serving entry point.
//!
//! Historically this module owned the continuous batcher directly; the
//! batcher now lives in the serving tier ([`crate::serve::Router`],
//! DESIGN.md §14) and [`Coordinator`] is a thin shim over a one-replica
//! router with the prefix cache off and a pool that always funds the
//! full slot table ([`crate::config::RouterConfig::single_engine`]) —
//! exactly the old semantics, one batcher implementation.  The queue
//! primitives (two-lane tenant-fair [`RequestQueue`], [`TokenBucket`],
//! [`AdmissionGate`], [`SlotTable`]) live in [`queue`] and are shared
//! with the router's replica workers.
//!
//! [`Coordinator::spawn`] is generic over [`Backend`]; the handle itself
//! is type-erased (the worker thread owns the engine), so the HTTP server
//! layer stays backend-agnostic without generics.

pub mod queue;

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::config::{EngineConfig, RouterConfig, ServerConfig};
use crate::engine::RowResult;
use crate::metrics::EngineMetrics;
use crate::serve::{Router, ServeRequest};

pub use queue::{AdmissionError, AdmissionGate, Lane, RequestQueue, SlotTable, TokenBucket};

/// A generation request as accepted by the coordinator.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: Option<usize>,
    /// Per-request sampling seed.  When set, the row's draft and
    /// verification randomness is a pure function of this value — the
    /// generation reproduces exactly regardless of which slot it lands in
    /// or what else is being served (DESIGN.md §7).  `None` draws a fresh
    /// seed from the worker's admission stream.
    pub seed: Option<u64>,
    pub enqueued: Instant,
}

/// The coordinator handle cloned into server handlers.
#[derive(Clone)]
pub struct Coordinator {
    router: Router,
    pub metrics: Arc<EngineMetrics>,
    gate: Arc<AdmissionGate>,
}

impl Coordinator {
    /// Spawn the coordinator worker thread over any execution backend.
    pub fn spawn<B: Backend>(
        backend: Arc<B>,
        engine_cfg: EngineConfig,
        server_cfg: &ServerConfig,
    ) -> Result<Coordinator> {
        let limit = server_cfg.queue_limit.max(1);
        let router =
            Router::spawn(backend, engine_cfg, server_cfg, &RouterConfig::single_engine())?;
        let metrics = router.replica_metrics(0);
        Ok(Coordinator { router, metrics, gate: Arc::new(AdmissionGate::new(limit)) })
    }

    /// Enqueue a request and block until its row completes.
    pub fn generate(&self, req: GenRequest) -> Result<RowResult> {
        // Single atomic check-and-increment: concurrent callers can never
        // exceed `queue_limit` (see AdmissionGate).  With the gate bounding
        // in-flight requests to the replica's channel depth, the
        // single-engine router never sheds.
        if !self.gate.try_acquire() {
            return Err(anyhow!("queue full — admission rejected"));
        }
        let res = self
            .router
            .generate(ServeRequest {
                prompt: req.prompt,
                max_new_tokens: req.max_new_tokens,
                seed: req.seed,
                lane: Lane::Interactive,
                tenant: 0,
                enqueued: req.enqueued,
            })
            .map_err(|e| anyhow!("{e}"));
        self.gate.release();
        res
    }
}
