//! Adaptive speculation controller (DESIGN.md §15).
//!
//! Gamma (the draft block length) and K (the path count of the
//! multi-draft algorithms) are *losslessness-invariant*: any value, on
//! any iteration, commits tokens from the same target distribution
//! (tests/theorems.rs enforces this).  Tuning them online is therefore a
//! pure throughput knob — the only question is which (gamma, K) buys the
//! most committed tokens per unit of forward work for the acceptance
//! rate the stream is *currently* showing.
//!
//! One [`Controller`] lives with each decode slot (engine/spec.rs keeps
//! them in `DecodeState`, so in the serving tier the state automatically
//! stays with the replica that owns the slot).  Per iteration it:
//!
//! 1. **Estimates acceptance** from a sliding window of observed
//!    `tau` values.  The window feeds from the same observations the
//!    engine already pushes into `accepted_len_hist`.  Naively
//!    `sum(tau) / sum(gamma)` is biased low — an iteration that accepts
//!    all `gamma` drafts never *observes* a rejection, it is truncated.
//!    The geometric-MLE correction counts `tau + 1` Bernoulli trials for
//!    a rejected iteration (`tau < gamma`: tau successes then one
//!    failure) and `tau` trials for a fully-accepted one, making
//!    `successes / trials` exactly the acceptance MLE under the
//!    token-chain model.
//! 2. **Measures cost** as the forward-time ratio `r` of one sequential
//!    draft step to one target row-forward (or uses the pinned
//!    [`AdaptiveConfig::cost_ratio`] — CI does, for determinism).
//! 3. **Scores each arm** `(gamma, k)` in the configured band with the
//!    exact expected-tau oracles from [`crate::sim::exact`] evaluated on
//!    the two-symbol i.i.d. pair whose overlap equals the estimated
//!    acceptance: committed tokens per unit work,
//!    `(E[tau] + 1) / (r * draft_tokens + scored_tokens)`.
//! 4. **Switches with hysteresis**: the incumbent arm is kept unless a
//!    challenger beats it by a relative margin, so estimate noise near
//!    an objective plateau cannot make the schedule flap.
//!
//! The controller never touches probabilities, seeds or the verify
//! kernels; it only picks which *already-lossless* iteration shape to
//! run next.  Expected regret against the best fixed arm is bounded in
//! `benches/optimality.rs` (oracle replay, CI-gated).

use std::collections::HashMap;
use std::collections::VecDeque;

use crate::config::AdaptiveConfig;
use crate::sim::exact;
use crate::sim::MarkovPair;
use crate::verify::Algo;

/// Acceptance estimate the controller assumes until `min_window`
/// observations have arrived (a mid-range prior: speculation is worth
/// running, but not worth maxing gamma for).
pub const PRIOR_ALPHA: f64 = 0.75;

/// Fallback draft/target per-token cost ratio when nothing has been
/// measured and none is pinned (the xxs drafter runs at roughly a
/// quarter of the target's per-token cost on the native backend).
pub const DEFAULT_COST_RATIO: f64 = 0.25;

/// Acceptance clamp: the exact oracles are defined on (0, 1) and the
/// extreme bins carry no ranking information anyway.
const ALPHA_MIN: f64 = 0.02;
const ALPHA_MAX: f64 = 0.98;

/// Quantisation bins for the acceptance estimate: stabilises decisions
/// and keys the expected-tau cache.
const ALPHA_BINS: usize = 64;

/// One (gamma, path-count) choice for the next speculation iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub gamma: usize,
    /// Path count; 1 for single-draft algorithms.
    pub k: usize,
}

/// Committed-tokens-per-unit-work objective for one arm, from first
/// principles (no controller state): `alpha` is the true/estimated
/// token acceptance, `cost_ratio` (`r`) the cost of one sequential
/// draft step relative to one target row-forward.  Work is counted in
/// *target row-forward equivalents* — the latency model that makes
/// speculation pay at all: one target forward scores all `gamma + 1`
/// positions in parallel for ~the cost of one sequential step, while
/// drafting is `gamma` genuinely sequential steps at `r` each.
///
/// * Token/Block/Greedy: `r·gamma + 1` per iteration.
/// * MultiPath(k): `k` independent path rows — `r·k·gamma` draft steps
///   and `k` target row-forwards.
/// * Tree(k): prefix sharing drafts only the expected unique node count
///   and scores the whole tree in one tree-attention row-forward.
///
/// Public because the oracle-replay harness scores arms against the
/// *true* alpha with exactly this function.
pub fn objective(algo: Algo, alpha: f64, cost_ratio: f64, gamma: usize, k: usize) -> f64 {
    let a = alpha.clamp(ALPHA_MIN, ALPHA_MAX);
    let pair = alpha_pair(a);
    let (e_tau, draft_steps, target_fwds) = match algo {
        Algo::Token => (exact::expected_tau_token(&pair, gamma), gamma as f64, 1.0),
        Algo::Greedy | Algo::Block => (exact::expected_tau_block(&pair, gamma), gamma as f64, 1.0),
        Algo::MultiPath { .. } => (
            exact::expected_tau_multipath(&pair, gamma, k),
            (k * gamma) as f64,
            k as f64,
        ),
        Algo::Tree { .. } => {
            let nodes = exact::expected_tree_nodes(&pair, gamma, k);
            (exact::expected_tau_tree(&pair, gamma, k), nodes, 1.0)
        }
    };
    (e_tau + 1.0) / (cost_ratio * draft_steps + target_fwds)
}

/// Two-symbol i.i.d. pair with token overlap exactly `alpha`:
/// `t = [a, 1-a]`, `d = [1-a, a]` with `a = 1 - alpha/2` gives
/// `sum_i min(t_i, d_i) = alpha`.  The exact oracles only see the
/// distributions through their overlap structure, so this is the
/// cheapest pair realising a given acceptance.
fn alpha_pair(alpha: f64) -> MarkovPair {
    let a = 1.0 - alpha / 2.0;
    MarkovPair::iid(vec![a, 1.0 - a], vec![1.0 - a, a])
}

/// Per-slot online tuner for (gamma, K).  See the module docs for the
/// policy; all state is a few hundred bytes per slot.
#[derive(Clone, Debug)]
pub struct Controller {
    cfg: AdaptiveConfig,
    algo: Algo,
    /// Incumbent arm (starts at the engine's configured shape).
    current: Decision,
    /// Sliding `(successes, trials)` window of acceptance observations.
    window: VecDeque<(u32, u32)>,
    /// Accumulated forward timings for the measured cost ratio.
    draft_us: u64,
    drafted: u64,
    target_us: u64,
    scored: u64,
    /// Memoised `objective` numerators/denominators don't cache well
    /// (the ratio moves with `r`), but `objective` itself is cheap and
    /// deterministic per `(alpha_bin, gamma, k, r_bin)`; we cache on the
    /// full quantised key.
    cache: HashMap<(usize, usize, usize, u64), f64>,
    /// Cumulative opportunity cost of hysteresis/laziness, in
    /// milli-fractions of the per-step best arm's value, drained by
    /// [`Controller::take_regret_milli`] into the metrics counter.
    regret_milli: u64,
}

impl Controller {
    /// `gamma0` / `algo` are the engine's configured shape: the arm the
    /// controller runs (and reports) until it has seen enough to move.
    pub fn new(cfg: AdaptiveConfig, gamma0: usize, algo: Algo) -> Self {
        let gamma0 = gamma0.clamp(cfg.gamma_min, cfg.gamma_max);
        Controller {
            cfg,
            algo,
            current: Decision { gamma: gamma0, k: algo.paths() },
            window: VecDeque::new(),
            draft_us: 0,
            drafted: 0,
            target_us: 0,
            scored: 0,
            cache: HashMap::new(),
            regret_milli: 0,
        }
    }

    /// Record one iteration's outcome: `tau` drafts accepted out of the
    /// `gamma` this slot actually ran (which the controller may have
    /// varied — the estimator is per-observation, not per-config).
    pub fn observe(&mut self, tau: usize, gamma: usize) {
        let tau = tau.min(gamma) as u32;
        // Truncation correction: a full acceptance is tau censored
        // trials; a rejection adds the failed trial.
        let trials = tau + u32::from((tau as usize) < gamma);
        self.window.push_back((tau, trials));
        while self.window.len() > self.cfg.window {
            self.window.pop_front();
        }
    }

    /// Accumulate forward timings for the measured cost ratio (ignored
    /// while [`AdaptiveConfig::cost_ratio`] pins it).  `drafted` counts
    /// sequential draft steps × rows; `scored` counts target
    /// row-forwards (rows × forwards, *not* scored positions — one
    /// row-forward scores gamma + 1 positions in parallel).
    pub fn observe_costs(&mut self, draft_us: u64, drafted: usize, target_us: u64, scored: usize) {
        self.draft_us += draft_us;
        self.drafted += drafted as u64;
        self.target_us += target_us;
        self.scored += scored as u64;
    }

    /// Windowed acceptance MLE, or the prior while the window is short.
    pub fn alpha(&self) -> f64 {
        if self.window.len() < self.cfg.min_window.max(1) {
            return PRIOR_ALPHA;
        }
        let (succ, trials) = self
            .window
            .iter()
            .fold((0u64, 0u64), |(s, t), &(a, b)| (s + a as u64, t + b as u64));
        if trials == 0 {
            return PRIOR_ALPHA;
        }
        (succ as f64 / trials as f64).clamp(ALPHA_MIN, ALPHA_MAX)
    }

    /// Draft/target per-token cost ratio: pinned > measured > default.
    pub fn cost_ratio(&self) -> f64 {
        if let Some(r) = self.cfg.cost_ratio {
            return r;
        }
        if self.drafted == 0 || self.scored == 0 || self.target_us == 0 {
            return DEFAULT_COST_RATIO;
        }
        let per_draft = self.draft_us as f64 / self.drafted as f64;
        let per_target = self.target_us as f64 / self.scored as f64;
        if per_target <= 0.0 {
            return DEFAULT_COST_RATIO;
        }
        (per_draft / per_target).clamp(0.01, 10.0)
    }

    /// The arm the controller is currently running.
    pub fn current(&self) -> Decision {
        self.current
    }

    /// Pick the next iteration's arm.  `room` caps gamma by the slot's
    /// remaining ring space (`l - len - 2`); a slot out of room degrades
    /// to the smallest gamma rather than erroring.
    pub fn choose(&mut self, room: usize) -> Decision {
        let g_lo = self.cfg.gamma_min;
        let g_hi = self.cfg.gamma_max.min(room.max(g_lo));
        let alpha = self.alpha();
        let r = self.cost_ratio();
        let ks: Vec<usize> = match self.algo {
            Algo::MultiPath { .. } | Algo::Tree { .. } => (1..=self.algo.paths().max(1)).collect(),
            _ => vec![1],
        };
        let mut best = Decision { gamma: g_lo, k: 1 };
        let mut best_v = f64::MIN;
        let mut cur_v = f64::MIN;
        for g in g_lo..=g_hi {
            for &k in &ks {
                let v = self.arm_value(alpha, r, g, k);
                if v > best_v {
                    best_v = v;
                    best = Decision { gamma: g, k };
                }
                if g == self.current.gamma && k == self.current.k {
                    cur_v = v;
                }
            }
        }
        // Hysteresis: stay on the incumbent unless the challenger clears
        // the margin (or the incumbent fell out of the feasible band).
        let switch = cur_v == f64::MIN || best_v > cur_v * (1.0 + self.cfg.hysteresis);
        if switch {
            self.current = best;
        } else if best_v > 0.0 && cur_v < best_v {
            // Laziness has a price; account it so the regret counter can
            // surface a mis-tuned hysteresis in metrics.
            self.regret_milli += (((best_v - cur_v) / best_v) * 1000.0) as u64;
        }
        self.current
    }

    /// Drain the accumulated hysteresis-regret counter (millis of the
    /// per-step best arm's value).
    pub fn take_regret_milli(&mut self) -> u64 {
        std::mem::take(&mut self.regret_milli)
    }

    fn arm_value(&mut self, alpha: f64, r: f64, gamma: usize, k: usize) -> f64 {
        let a_bin =
            ((alpha * ALPHA_BINS as f64) as usize).min(ALPHA_BINS - 1);
        let a_q = (a_bin as f64 + 0.5) / ALPHA_BINS as f64;
        let r_bin = (r * 100.0) as u64;
        let algo = self.algo;
        *self
            .cache
            .entry((a_bin, gamma, k, r_bin))
            .or_insert_with(|| objective(algo, a_q, r, gamma, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdaptiveConfig {
        AdaptiveConfig {
            enabled: true,
            window: 16,
            min_window: 4,
            gamma_min: 1,
            gamma_max: 8,
            hysteresis: 0.0,
            cost_ratio: Some(0.25),
        }
    }

    #[test]
    fn truncation_corrected_alpha_is_unbiased_on_clean_streams() {
        // tau == gamma every time: successes 4/trials 4 -> alpha ~ 1.
        let mut c = Controller::new(cfg(), 4, Algo::Block);
        for _ in 0..8 {
            c.observe(4, 4);
        }
        assert!(c.alpha() > 0.95, "alpha {}", c.alpha());
        // tau == 0 every time: 0 successes, 1 trial each -> alpha ~ 0.
        let mut c = Controller::new(cfg(), 4, Algo::Block);
        for _ in 0..8 {
            c.observe(0, 4);
        }
        assert!(c.alpha() < 0.05, "alpha {}", c.alpha());
        // Mixed stream: 3 accepted then rejection = 3 succ / 4 trials.
        let mut c = Controller::new(cfg(), 4, Algo::Block);
        for _ in 0..8 {
            c.observe(3, 4);
        }
        assert!((c.alpha() - 0.75).abs() < 1e-9, "alpha {}", c.alpha());
    }

    #[test]
    fn prior_holds_until_min_window() {
        let mut c = Controller::new(cfg(), 4, Algo::Block);
        c.observe(0, 4);
        c.observe(0, 4);
        assert_eq!(c.alpha(), PRIOR_ALPHA);
        c.observe(0, 4);
        c.observe(0, 4);
        assert!(c.alpha() < 0.05);
    }

    #[test]
    fn high_acceptance_prefers_larger_gamma_than_low() {
        let mut hi = Controller::new(cfg(), 4, Algo::Block);
        let mut lo = Controller::new(cfg(), 4, Algo::Block);
        for _ in 0..16 {
            hi.observe(8, 8); // everything accepted
            lo.observe(0, 8); // everything rejected
        }
        let g_hi = hi.choose(64).gamma;
        let g_lo = lo.choose(64).gamma;
        assert!(
            g_hi > g_lo,
            "accepting stream chose gamma {g_hi}, rejecting stream {g_lo}"
        );
        assert_eq!(g_lo, 1, "hopeless stream should draft the minimum");
    }

    #[test]
    fn room_caps_gamma() {
        let mut c = Controller::new(cfg(), 8, Algo::Block);
        for _ in 0..16 {
            c.observe(8, 8);
        }
        assert!(c.choose(3).gamma <= 3);
        // Even out-of-room slots stay in the configured band's floor.
        assert_eq!(c.choose(0).gamma, 1);
    }

    #[test]
    fn hysteresis_holds_the_incumbent_near_plateaus() {
        let mut sticky = AdaptiveConfig { hysteresis: 10.0, ..cfg() };
        sticky.gamma_min = 2;
        let mut c = Controller::new(sticky, 4, Algo::Block);
        for _ in 0..16 {
            c.observe(8, 8);
        }
        // A 10x-improvement bar is unmeetable: the incumbent must hold,
        // and the counter must record the passed-up value.
        assert_eq!(c.choose(64), Decision { gamma: 4, k: 1 });
        assert!(c.take_regret_milli() > 0);
        assert_eq!(c.take_regret_milli(), 0, "take_ drains");
    }

    #[test]
    fn multipath_tunes_k_down_when_paths_stop_paying() {
        // With near-certain acceptance a single path already commits
        // gamma + 1 tokens; extra paths only add cost.
        let mut c = Controller::new(cfg(), 4, Algo::MultiPath { k: 4 });
        for _ in 0..16 {
            c.observe(8, 8);
        }
        assert_eq!(c.choose(64).k, 1);
    }

    #[test]
    fn measured_cost_ratio_falls_back_then_tracks() {
        let mut c = Controller::new(AdaptiveConfig { cost_ratio: None, ..cfg() }, 4, Algo::Block);
        assert_eq!(c.cost_ratio(), DEFAULT_COST_RATIO);
        // 10us/token draft vs 40us/token target -> r = 0.25.
        c.observe_costs(100, 10, 400, 10);
        assert!((c.cost_ratio() - 0.25).abs() < 1e-9);
        // Pinned ratio wins over measurements.
        let mut p = Controller::new(cfg(), 4, Algo::Block);
        p.observe_costs(100, 10, 100, 10);
        assert_eq!(p.cost_ratio(), 0.25);
    }

    #[test]
    fn objective_matches_cached_arm_values() {
        let mut c = Controller::new(cfg(), 4, Algo::Block);
        for _ in 0..16 {
            c.observe(3, 4);
        }
        let (alpha, r) = (c.alpha(), c.cost_ratio());
        let d = c.choose(64);
        // The decision maximises the public objective on the quantised
        // alpha (the replay harness relies on this equivalence).
        let a_bin = ((alpha * ALPHA_BINS as f64) as usize).min(ALPHA_BINS - 1);
        let a_q = (a_bin as f64 + 0.5) / ALPHA_BINS as f64;
        let best = (1..=8)
            .max_by(|&x, &y| {
                objective(Algo::Block, a_q, r, x, 1)
                    .total_cmp(&objective(Algo::Block, a_q, r, y, 1))
            })
            .unwrap();
        assert_eq!(d.gamma, best);
    }
}
