//! In-tree micro/macro benchmark harness (criterion is unavailable
//! offline).  Provides warmup + timed repetitions with mean/std/min and a
//! stable one-line report format consumed by EXPERIMENTS.md §Perf.
//!
//! Benches are `harness = false` binaries under rust/benches/ that call
//! [`Bench::run`] / [`Bench::run_n`], so `cargo bench` works as usual.

use std::time::{Duration, Instant};

use crate::stats::mean_std;
use crate::util::json::{self, Value};

/// Configuration for one benchmark group.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, sample_iters: 10 }
    }
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
    pub iters: usize,
}

impl Sample {
    pub fn report(&self) -> String {
        format!(
            "bench {:<42} mean {:>12} std {:>10} min {:>12} (n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.min),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 10_000 {
        format!("{ns}ns")
    } else if ns < 10_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 10_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Bench { warmup_iters, sample_iters }
    }

    /// Benchmark `f`, printing and returning the sample.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Sample {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let (mean, std) = mean_std(&times);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let s = Sample {
            name: name.to_string(),
            mean: Duration::from_secs_f64(mean),
            std: Duration::from_secs_f64(std),
            min: Duration::from_secs_f64(min.max(0.0)),
            iters: self.sample_iters,
        };
        println!("{}", s.report());
        s
    }

    /// Benchmark a batch of `n` inner operations, reporting per-op time.
    pub fn run_n<F: FnMut()>(&self, name: &str, n: usize, mut f: F) -> Sample {
        let s = self.run(name, &mut f);
        let per = Sample {
            name: format!("{name}/op"),
            mean: s.mean / n as u32,
            std: s.std / n as u32,
            min: s.min / n as u32,
            iters: s.iters * n,
        };
        println!("{}", per.report());
        per
    }
}

/// Read-merge-write for the shared CI bench reports (`BENCH_ci.json`,
/// `BENCH_native.json`): several writers each own one top-level *section*
/// (`"soak"`, `"serving"`, `"adaptive_replay"`, ...) and compose in any
/// order — whoever runs later re-reads the file and replaces only its own
/// key, so the tier1 soak and the perf-smoke bench can no longer clobber
/// each other's cells.  A missing file starts a fresh object; an
/// unparsable or non-object one is replaced *loudly* (stderr) rather than
/// propagated as an error, so a corrupt artifact cannot wedge the CI
/// perf jobs that gate on these numbers.
pub fn merge_section(path: &str, section: &str, cells: Value) -> std::io::Result<()> {
    let mut top = match std::fs::read_to_string(path) {
        Ok(text) => match json::parse(&text) {
            Ok(v @ Value::Obj(_)) => v,
            Ok(_) => {
                eprintln!("specd: {path} is not a JSON object; rewriting it from scratch");
                json::obj(vec![])
            }
            Err(e) => {
                eprintln!("specd: {path} is unparsable ({e}); rewriting it from scratch");
                json::obj(vec![])
            }
        },
        Err(_) => json::obj(vec![]),
    };
    match &mut top {
        Value::Obj(map) => {
            map.insert(section.to_string(), cells);
        }
        _ => unreachable!("top is always an object here"),
    }
    std::fs::write(path, json::to_string(&top))
}

/// Throughput helper: report items/sec from a closure returning item count.
pub fn throughput<F: FnMut() -> usize>(name: &str, reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    let mut items = 0usize;
    for _ in 0..reps {
        items += f();
    }
    let secs = t0.elapsed().as_secs_f64();
    let rate = items as f64 / secs.max(1e-12);
    println!("bench {name:<42} {rate:>12.1} items/s  ({items} items in {secs:.2}s)");
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_positive_times() {
        let b = Bench::new(1, 3);
        let s = b.run("noop-ish", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.mean.as_nanos() > 0);
        assert!(s.min <= s.mean);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(50)).ends_with('s'));
    }

    #[test]
    fn throughput_counts_items() {
        let r = throughput("count", 5, || 10);
        assert!(r > 0.0);
    }

    #[test]
    fn merge_section_composes_in_any_order() {
        let path = std::env::temp_dir()
            .join(format!("specd_merge_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let _ = std::fs::remove_file(&path);
        // Writer A (soak) lands first, writer B (serving) second: both
        // sections must survive, in either order.
        merge_section(&path, "soak", json::obj(vec![("p99", json::num(3.5))])).unwrap();
        merge_section(&path, "serving", json::obj(vec![("block_be", json::num(2.0))])).unwrap();
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("soak").and_then(|s| s.get("p99")).and_then(Value::as_f64), Some(3.5));
        assert_eq!(
            v.get("serving").and_then(|s| s.get("block_be")).and_then(Value::as_f64),
            Some(2.0)
        );
        // Re-running a writer replaces only its own section.
        merge_section(&path, "soak", json::obj(vec![("p99", json::num(4.0))])).unwrap();
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("soak").and_then(|s| s.get("p99")).and_then(Value::as_f64), Some(4.0));
        assert!(v.get("serving").is_some(), "other writer's section was clobbered");
        // A corrupt file is replaced, not propagated.
        std::fs::write(&path, "not json {{{").unwrap();
        merge_section(&path, "soak", json::obj(vec![("p99", json::num(1.0))])).unwrap();
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("soak").and_then(|s| s.get("p99")).and_then(Value::as_f64), Some(1.0));
        let _ = std::fs::remove_file(&path);
    }
}
