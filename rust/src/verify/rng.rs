//! Deterministic, dependency-free RNG for the host-verify path and the
//! simulator: SplitMix64 for seeding, xoshiro256** for the stream.
//!
//! Verification randomness must be (a) reproducible across runs for the
//! paper tables' seed-averaged cells and (b) independent of the device
//! programs' threefry streams (the two paths are cross-checked via golden
//! vectors with *explicit* uniforms, not via shared streams).

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion (Vigna).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-row / per-iteration keys).
    pub fn fold_in(&self, data: u64) -> Self {
        let mix = self.s[0] ^ self.s[2].rotate_left(17) ^ data.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fold_in_gives_distinct_streams() {
        let base = Rng::new(1);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn seeds_decorrelated() {
        let mut a = Rng::new(0);
        let mut b = Rng::new(1);
        let mut same = 0;
        for _ in 0..64 {
            if (a.next_u64() & 1) == (b.next_u64() & 1) {
                same += 1;
            }
        }
        assert!(same > 16 && same < 48);
    }
}
